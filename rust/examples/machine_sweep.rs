//! Reproduce every paper figure in one run, writing CSVs + text tables.
//!
//! Walks the whole experiment index of DESIGN.md §5: Tab. 1, Figs. 3a/3b,
//! 4a/4b, 8, 9, 10 and the barrier ablation, writing both the rendered
//! text tables (results/*.txt) and machine-readable CSV series
//! (results/*.csv) for external plotting. Also runs a small *functional*
//! sweep on the host to show every schedule is exact while the simulator
//! predicts the paper testbed.
//!
//! Run with: `cargo run --release --example machine_sweep`

use stencilwave::config::{RunConfig, Scheme};
use stencilwave::figures;
use stencilwave::launcher;

fn csv_of_wavefront(points: &[figures::WavefrontPoint]) -> String {
    let mut s = String::from("machine,n,t,wavefront_mlups,baseline_mlups,speedup\n");
    for p in points {
        s += &format!(
            "{},{},{},{:.1},{:.1},{:.3}\n",
            p.machine, p.n, p.blocking_factor, p.wavefront_mlups, p.baseline_mlups, p.speedup
        );
    }
    s
}

fn csv_of_baseline(rows: &[figures::BaselineRow]) -> String {
    let mut s = String::from("machine,c_cache,c_memory,opt_cache,opt_memory,eq1_limit\n");
    for r in rows {
        s += &format!(
            "{},{:.1},{:.1},{:.1},{:.1},{:.1}\n",
            r.machine, r.c_cache, r.c_memory, r.opt_cache, r.opt_memory, r.eq1_limit
        );
    }
    s
}

fn main() -> stencilwave::Result<()> {
    let out = std::path::Path::new("results");
    std::fs::create_dir_all(out)?;

    // ---- all figures: text tables + CSVs
    for id in figures::ALL_FIGURES {
        let text = figures::render(id).unwrap();
        std::fs::write(out.join(format!("{id}.txt")), &text)?;
        let csv = match id {
            "fig3a" => Some(csv_of_baseline(&figures::fig3a())),
            "fig3b" => Some(csv_of_baseline(&figures::fig3b())),
            "fig4a" => Some(csv_of_baseline(&figures::fig4a())),
            "fig4b" => Some(csv_of_baseline(&figures::fig4b())),
            "fig8" => Some(csv_of_wavefront(&figures::fig8())),
            "fig9" => Some(csv_of_wavefront(&figures::fig9())),
            "fig10" => Some(csv_of_wavefront(&figures::fig10())),
            _ => None,
        };
        if let Some(csv) = csv {
            std::fs::write(out.join(format!("{id}.csv")), csv)?;
        }
        println!("wrote results/{id}.txt");
    }

    // ---- headline summary (the paper's prose claims)
    println!("\n== headline speedups (wavefront vs threaded baseline, 200^3) ==");
    for (label, pts) in [
        ("Jacobi  (Fig. 8)", figures::fig8()),
        ("GS      (Fig. 9)", figures::fig9()),
        ("GS+SMT  (Fig.10)", figures::fig10()),
    ] {
        print!("{label}: ");
        let mut first = true;
        for p in pts.iter().filter(|p| p.n == 200) {
            if !first {
                print!(", ");
            }
            print!("{} {:.1}x", p.machine, p.speedup);
            first = false;
        }
        println!();
    }

    // ---- functional sweep on the host: every schedule must be exact
    println!("\n== functional verification sweep (host execution) ==");
    let mut configs = Vec::new();
    for scheme in Scheme::ALL {
        for t in [2usize, 4] {
            configs.push(RunConfig {
                scheme,
                size: (24, 24, 24),
                t,
                groups: 2,
                iters: 2 * t,
                machine: Some("Nehalem EX".into()),
                ..Default::default()
            });
        }
    }
    let reports = launcher::sweep(configs, 1);
    let mut csv_rows = Vec::new();
    for r in reports {
        let r = r?;
        println!(
            "  {:?} t={} : host {:>8.1} MLUP/s  verified diff={:.1e}  model[EX] {:.0} MLUP/s",
            r.scheme,
            r.t,
            r.host_mlups,
            r.verification_diff,
            r.predicted_mlups.unwrap_or(0.0)
        );
        anyhow::ensure!(r.verification_diff == 0.0, "schedule not exact!");
        csv_rows.push(r);
    }
    std::fs::write(out.join("functional_sweep.csv"), launcher::to_csv(&csv_rows))?;
    println!("\nall figures written to results/. ✔");
    Ok(())
}
