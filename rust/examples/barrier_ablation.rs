//! Ablation: synchronization primitives under fine-grained parallelism.
//!
//! Sec. 4 motivates the custom barriers: pthread barriers are too slow for
//! plane-granular synchronization, spin barriers win on physical cores,
//! tree barriers win with SMT. This example measures the *real* rust
//! barriers on this host (functional leg) and prints the calibrated cost
//! model next to them, then shows the end-to-end effect: wavefront Jacobi
//! throughput under each barrier kind.
//!
//! Run with: `cargo run --release --example barrier_ablation`

use std::sync::Arc;
use std::time::Instant;

use stencilwave::coordinator::barrier::AnyBarrier;
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::wavefront::{wavefront_jacobi_passes, SyncMode, WavefrontConfig};
use stencilwave::figures;
use stencilwave::metrics::mlups;
use stencilwave::simulator::perfmodel::BarrierKind;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

/// Measure ns/barrier for `threads` participants over `rounds` rounds.
fn measure(kind: BarrierKind, threads: usize, rounds: usize) -> f64 {
    let barrier = Arc::new(AnyBarrier::new(kind, threads));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for id in 0..threads {
            let b = Arc::clone(&barrier);
            scope.spawn(move || {
                for _ in 0..rounds {
                    b.wait(id);
                }
            });
        }
    });
    t0.elapsed().as_nanos() as f64 / rounds as f64
}

fn main() -> stencilwave::Result<()> {
    println!("== real barrier round-trip on this host (ns/barrier) ==");
    println!("{:<10} {:>10} {:>10}", "threads", "spin", "tree");
    for threads in [2usize, 4, 8] {
        let spin = measure(BarrierKind::Spin, threads, 20_000);
        let tree = measure(BarrierKind::Tree, threads, 20_000);
        println!("{threads:<10} {spin:>10.0} {tree:>10.0}");
    }
    println!("\nnote: this box has 1 physical core — oversubscribed threads");
    println!("spin against the scheduler, which is exactly the pathology the");
    println!("paper's SMT discussion predicts; the calibrated model below");
    println!("carries the testbed costs used by the simulator.\n");

    println!("{}", figures::render("barrier").unwrap());

    // ---- end-to-end: wavefront Jacobi under each barrier kind
    println!("== wavefront Jacobi (32^3, t=4) under each primitive ==");
    let f = Grid3::random(32, 32, 32, 5);
    let reference = {
        let mut u = Grid3::random(32, 32, 32, 6);
        let want = stencilwave::coordinator::wavefront::serial_reference(&u, &f, 1.0, 4);
        u.copy_from(&want);
        u
    };
    let mut pool = WorkerPool::new(4);
    for (label, barrier, sync) in [
        ("spin barrier", BarrierKind::Spin, SyncMode::Barrier),
        ("tree barrier", BarrierKind::Tree, SyncMode::Barrier),
        ("flow (p2p flags)", BarrierKind::Spin, SyncMode::Flow),
    ] {
        let mut u = Grid3::random(32, 32, 32, 6);
        let cfg = WavefrontConfig { threads: 4, barrier, sync, ..Default::default() };
        let t0 = Instant::now();
        wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &cfg, 1)?;
        let dt = t0.elapsed();
        let updates = (u.interior_len() * 4) as u64;
        anyhow::ensure!(u.max_abs_diff(&reference) == 0.0, "{label}: result differs");
        println!("  {:<18} {:>8.1} MLUP/s (exact ✓)", label, mlups(updates, dt));
    }
    Ok(())
}
