//! End-to-end driver: solve a 3D Poisson problem with wavefront-blocked
//! smoothing, cross-validated against the AOT Pallas artifacts via PJRT.
//!
//! This is the full-stack composition proof:
//!   L3 (rust)   — wavefront thread groups, barriers, pipeline GS
//!   L2 (JAX)    — `jacobi_smooth_residual_*` artifact executed via PJRT
//!   L1 (Pallas) — the plane/wavefront kernels inside that artifact
//!
//! The solver smooths `-Δu = f` on a 40³ grid until the residual norm
//! drops by 100×, logging the residual curve and MLUP/s for (a) the rust
//! wavefront engine and (b) the PJRT-executed Pallas artifact, and checks
//! the two solutions agree to fp round-off at every outer iteration.
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with (needs the vendored xla-rs runtime; see rust/Cargo.toml):
//!   make artifacts && cargo run --release --features xla --example poisson_solver

use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::wavefront::{wavefront_jacobi_passes, WavefrontConfig};
use stencilwave::metrics::{mlups, timed};
use stencilwave::runtime::{engine, Manifest, Runtime};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;
use stencilwave::stencil::residual::poisson_residual_norm;

const N: usize = 40;
const T: usize = 4; // temporal blocking factor
const INNER: usize = 8; // updates per outer iteration (matches artifact)
const TARGET_DROP: f64 = 100.0;
const MAX_OUTER: usize = 120;

fn main() -> stencilwave::Result<()> {
    let h2 = 1.0;
    let f = Grid3::from_fn(N, N, N, |k, j, i| {
        let s = |v: usize| (v as f64 / (N - 1) as f64 - 0.5) * 2.0;
        // a smooth, sign-changing source
        (3.0 * s(i)).sin() * (2.0 * s(j)).cos() * (1.0 - s(k) * s(k))
    });
    let u0 = Grid3::zeros(N, N, N);
    let r0 = poisson_residual_norm(&u0, &f, h2);
    println!("== poisson_solver: {N}^3, -Δu = f, wavefront t={T}, {INNER} updates/outer ==");
    println!("initial residual: {r0:.6e}   target: {:.6e}\n", r0 / TARGET_DROP);

    // ---- leg A: rust wavefront engine (one persistent team)
    // each pass performs T updates, so the inner count must divide evenly
    // (the deleted `wavefront_jacobi_iters` shim used to enforce this)
    anyhow::ensure!(INNER % T == 0, "INNER ({INNER}) must be a multiple of T ({T})");
    let cfg = WavefrontConfig { threads: T, ..Default::default() };
    let mut pool = WorkerPool::new(T);
    let mut u = u0.clone();
    let mut outer = 0;
    let mut total_updates = 0u64;
    let (_, dt_rust) = timed(|| -> stencilwave::Result<()> {
        while outer < MAX_OUTER {
            wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, h2, &cfg, INNER / T)?;
            total_updates += (u.interior_len() * INNER) as u64;
            outer += 1;
            let r = poisson_residual_norm(&u, &f, h2);
            if outer % 15 == 0 || r * TARGET_DROP <= r0 {
                println!("  [rust]  outer {outer:>3}: residual {r:.6e}");
            }
            if r * TARGET_DROP <= r0 {
                break;
            }
        }
        Ok(())
    });
    let r_rust = poisson_residual_norm(&u, &f, h2);
    println!(
        "[rust]   {:.1} MLUP/s over {} outer iterations, final residual {:.6e}\n",
        mlups(total_updates, dt_rust),
        outer,
        r_rust
    );
    anyhow::ensure!(r_rust * TARGET_DROP <= r0, "rust leg failed to converge");

    // ---- leg B: the same smoothing through the PJRT artifact
    let artifact = format!("jacobi_smooth_residual_n{N}_it{INNER}");
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("[pjrt]   skipped: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let mut rt = Runtime::load(&dir)?;
    println!("[pjrt]   platform = {}, artifact = {artifact}", rt.platform());
    let mut v = u0.clone();
    let mut pjrt_updates = 0u64;
    let mut pjrt_outer = 0;
    let mut r_pjrt = r0;
    let (res, dt_pjrt) = timed(|| -> stencilwave::Result<()> {
        while pjrt_outer < MAX_OUTER {
            let (next, rn) = rt.run_grid_scalar(&artifact, &[&v, &f])?;
            v = next;
            r_pjrt = rn;
            pjrt_updates += (v.interior_len() * INNER) as u64;
            pjrt_outer += 1;
            if pjrt_outer % 15 == 0 || rn * TARGET_DROP <= r0 {
                println!("  [pjrt]  outer {pjrt_outer:>3}: residual {rn:.6e}");
            }
            if rn * TARGET_DROP <= r0 {
                break;
            }
        }
        Ok(())
    });
    res?;
    println!(
        "[pjrt]   {:.1} MLUP/s over {} outer iterations, final residual {:.6e}\n",
        mlups(pjrt_updates, dt_pjrt),
        pjrt_outer,
        r_pjrt
    );

    // ---- cross-layer agreement
    anyhow::ensure!(pjrt_outer == outer, "iteration counts diverged: {pjrt_outer} vs {outer}");
    let diff = u.max_abs_diff(&v);
    println!("cross-layer max|rust - pallas| after {outer} outer iterations: {diff:.3e}");
    anyhow::ensure!(diff < 1e-10, "layers disagree: {diff}");

    // ---- bonus: validate every jacobi/gs artifact quickly
    println!("\ncross-layer validation of the full artifact catalog:");
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| matches!(a.scheme(), Some("jacobi") | Some("gauss_seidel")))
        .map(|a| a.name.clone())
        .collect();
    for name in names {
        let val = engine::validate(&mut rt, &name)?;
        println!(
            "  [{}] {:<36} {:.3e}",
            if val.passed() { "OK " } else { "FAIL" },
            val.artifact,
            val.max_abs_diff
        );
        anyhow::ensure!(val.passed(), "validation failed for {}", val.artifact);
    }
    println!("\npoisson_solver: all layers compose. ✔");
    Ok(())
}
