//! Quickstart: the wavefront scheme in five minutes — through the
//! unified `Solver` session API.
//!
//! 1. Build a Poisson problem on a 64³ grid.
//! 2. Smooth it with the plain threaded Jacobi baseline.
//! 3. Smooth it with wavefront temporal blocking (t = 4) via a `Solver`
//!    session — same numerics, a fraction of the memory traffic, one
//!    thread team spawned at `build()` and reused for every `run()`.
//! 4. Do the same for Gauss-Seidel via the pipeline-parallel wavefront.
//! 5. Ask the simulator what this configuration would do on the paper's
//!    Nehalem EX.
//!
//! Run with: `cargo run --release --example quickstart`

use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::affinity::PinPolicy;
use stencilwave::coordinator::solver::Solver;
use stencilwave::metrics::{mlups, timed};
use stencilwave::simulator::ecm::Kernel;
use stencilwave::simulator::machine::MachineSpec;
use stencilwave::simulator::perfmodel::{wavefront_prediction, WavefrontParams};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::residual::poisson_residual_norm;

fn main() -> stencilwave::Result<()> {
    const N: usize = 64;
    const ITERS: usize = 8;
    const T: usize = 4;
    let h2 = 1.0;

    println!("== stencilwave quickstart: {N}^3 Poisson problem, {ITERS} updates ==\n");
    let f = Grid3::from_fn(N, N, N, |k, j, i| {
        let (x, y, z) = (i as f64 / N as f64, j as f64 / N as f64, k as f64 / N as f64);
        (x * y * z).sin() + 1.0
    });
    let u0 = Grid3::random(N, N, N, 42);
    let updates = (u0.interior_len() * ITERS) as u64;

    // 1 — plain Jacobi baseline
    let (baseline, dt) = timed(|| jacobi_steps(&u0, &f, h2, ITERS));
    println!("jacobi baseline   : {:8.1} MLUP/s", mlups(updates, dt));

    // 2 — wavefront temporal blocking via a Solver session: the config
    // is validated once, the team is spawned (and compactly pinned)
    // once, and the result is bit-identical to the baseline.
    let cfg = RunConfig {
        scheme: Scheme::JacobiWavefront,
        size: (N, N, N),
        t: T,
        iters: ITERS,
        ..Default::default()
    };
    let mut solver = Solver::builder(&cfg).rhs(f.clone(), h2).pin(PinPolicy::Compact).build()?;
    let mut u = u0.clone();
    let (res, dt) = timed(|| solver.run(&mut u, ITERS));
    res?;
    println!(
        "jacobi wavefront  : {:8.1} MLUP/s   max|diff| vs baseline = {:.1e}",
        mlups(updates, dt),
        u.max_abs_diff(&baseline)
    );
    assert_eq!(u.max_abs_diff(&baseline), 0.0, "temporal blocking must not change numerics");
    println!(
        "residual after {ITERS} Jacobi updates: {:.6e}",
        poisson_residual_norm(&u, &f, h2)
    );

    // 3 — Gauss-Seidel wavefront (Laplace problem, in place); a second
    // session reuses the first session's thread team via `.pool(...)`.
    let gs_cfg = RunConfig {
        scheme: Scheme::GsWavefront,
        size: (N, N, N),
        t: T,
        groups: 2, // pipeline width per sweep
        iters: ITERS,
        ..Default::default()
    };
    let mut gs = Solver::builder(&gs_cfg).pool(solver.into_pool()).build()?;
    let mut g = u0.clone();
    let (res, dt) = timed(|| gs.run(&mut g, ITERS));
    res?;
    println!("\ngs wavefront      : {:8.1} MLUP/s", mlups(updates, dt));

    // 4 — what would the paper's testbed do?
    println!("\npredictions for this configuration (200^3, t = max blocking factor):");
    for m in MachineSpec::testbed() {
        let p = WavefrontParams::standard(&m, Kernel::JacobiOpt, false);
        let pred = wavefront_prediction(&m, &p, (200, 200, 200));
        println!(
            "  {:<12} t={}: {:6.0} MLUP/s (compute {:.0} | cache {:.0} | memory {:.0})",
            m.name, p.t, pred.mlups, pred.compute_mlups, pred.olc_mlups, pred.mem_mlups
        );
    }
    Ok(())
}
