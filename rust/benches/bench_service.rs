//! Multi-tenant solver service vs one-session-per-job execution.
//!
//! The same mixed job list (small batch-eligible grids plus mid-size
//! wavefront runs) goes through three strategies:
//!
//! * **sequential** — a private `Solver` session built, run and torn
//!   down per job: the no-service baseline every tenant pays alone.
//! * **service** — one `SolverService`: a persistent pool, per-window
//!   segments with their own scratch arenas, ECM-cost placement, and
//!   identical small jobs batched through one schedule.
//! * **service-unbatched** — the same service with `max_batch = 1`,
//!   isolating how much of the win is batching vs pool amortization.
//!
//! Results are written to `BENCH_service.json` (reusing the
//! `BenchRecord` shape: `scheme` carries the strategy, `threads` the
//! worker count) so CI keeps a greppable throughput history. A fourth
//! **queue-pressure** case oversubmits a bounded queue at 2× capacity
//! and sheds a deadline-doomed refill; its `rejected_full` /
//! `shed_expired` counters land in the JSON as record extras.
//!
//! `STENCILWAVE_BENCH_SMOKE=1` shrinks the job list and rep count — the
//! CI configuration.

use stencilwave::benchkit::{self, BenchRecord};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::service::{JobSpec, JobTicket, ServiceConfig, SolverService};
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::grid::Grid3;

/// The tenant mix: `small_each` identical batch-eligible jobs per small
/// scheme plus a few mid-size wavefront runs.
fn job_list(smoke: bool) -> Vec<RunConfig> {
    let (small_each, mid_n, iters) = if smoke { (4usize, 32usize, 4usize) } else { (8, 64, 8) };
    let mut jobs = Vec::new();
    for scheme in [Scheme::JacobiWavefront, Scheme::GsMultiGroup] {
        for _ in 0..small_each {
            jobs.push(RunConfig {
                scheme,
                size: (16, 18, 16),
                t: 4,
                groups: 2,
                iters: 4,
                ..Default::default()
            });
        }
    }
    for scheme in [Scheme::JacobiWavefront, Scheme::GsWavefront] {
        jobs.push(RunConfig {
            scheme,
            size: (mid_n, mid_n, mid_n),
            t: 4,
            groups: 2,
            iters,
            ..Default::default()
        });
    }
    jobs
}

fn total_updates(jobs: &[RunConfig]) -> u64 {
    jobs.iter()
        .map(|c| {
            let r = c.op.radius();
            let (nz, ny, nx) = c.size;
            ((nz - 2 * r) * (ny - 2 * r) * (nx - 2 * r) * c.iters) as u64
        })
        .sum()
}

fn main() {
    let smoke = benchkit::smoke();
    let reps = if smoke { 2usize } else { 3 };
    let jobs = job_list(smoke);
    let updates = total_updates(&jobs);
    let shape = ServiceConfig { groups: 2, group_width: 4, ..Default::default() };
    let workers = shape.groups * shape.group_width;
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut record = |strategy: &str, mlups: f64| {
        records.push(BenchRecord {
            scheme: strategy.to_string(),
            op: "mixed".to_string(),
            threads: workers,
            smt: false,
            nt_stores: false,
            ranks: 1,
            mlups,
            extras: vec![],
        });
    };

    benchkit::header(&format!(
        "multi-tenant service vs per-job sessions — {} jobs, {} groups x {} workers",
        jobs.len(),
        shape.groups,
        shape.group_width
    ));

    // the no-service baseline: every job pays its own session setup,
    // with the same seeded inputs run_service_jobs derives
    let s = benchkit::bench_mlups("sequential per-job sessions", updates, 1, reps, || {
        for (i, cfg) in jobs.iter().enumerate() {
            let (nz, ny, nx) = cfg.size;
            let f = Grid3::random(nz, ny, nx, 7 + i as u64);
            let mut u = Grid3::random(nz, ny, nx, 1008 + i as u64);
            let mut solver = Solver::builder(cfg).rhs(f, 1.0).build().unwrap();
            solver.run(&mut u, cfg.iters).unwrap();
            benchkit::black_box(u);
        }
    });
    benchkit::report(&s);
    record("sequential", s.mlups.unwrap());

    for (strategy, max_batch) in [("service", shape.max_batch), ("service-unbatched", 1)] {
        let svc_cfg = ServiceConfig { max_batch, ..shape.clone() };
        // the service outlives the reps — a long-running front end is
        // exactly what it is — so the measured loop is pure tenancy:
        // submit-all, then redeem every ticket
        let svc = SolverService::new(svc_cfg).unwrap();
        let s = benchkit::bench_mlups(strategy, updates, 1, reps, || {
            let tickets: Vec<JobTicket> = jobs
                .iter()
                .enumerate()
                .map(|(i, cfg)| {
                    let (nz, ny, nx) = cfg.size;
                    let f = Grid3::random(nz, ny, nx, 7 + i as u64);
                    let u0 = Grid3::random(nz, ny, nx, 1008 + i as u64);
                    svc.submit(JobSpec::new(cfg.clone(), u0).rhs(f, 1.0)).unwrap()
                })
                .collect();
            for t in tickets {
                benchkit::black_box(t.wait().unwrap().u);
            }
        });
        benchkit::report(&s);
        let stats = svc.stats();
        println!(
            "    {} jobs/rep, {} batched into {} windows, peak {} groups busy",
            jobs.len(),
            stats.batched_jobs,
            stats.batches,
            stats.peak_groups_busy
        );
        record(strategy, s.mlups.unwrap());
        drop(svc);
    }

    // queue-pressure smoke: oversubmit a bounded queue at 2× capacity
    // while paused — the second half must bounce with QueueFull — then
    // drain the accepted half (that drain is the recorded throughput),
    // then shed a deadline-doomed refill. The reject/shed counters ride
    // into BENCH_service.json as record extras so CI history keeps the
    // backpressure behavior greppable, not just the throughput.
    let cap = 4usize;
    let svc_cfg = ServiceConfig { max_batch: 1, queue_capacity: cap, ..shape.clone() };
    let mut svc = SolverService::new(svc_cfg).unwrap();
    let small = &jobs[0];
    let grids = |i: usize| {
        let (nz, ny, nx) = small.size;
        (Grid3::random(nz, ny, nx, 7 + i as u64), Grid3::random(nz, ny, nx, 1008 + i as u64))
    };
    svc.pause();
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..2 * cap {
        let (f, u0) = grids(i);
        match svc.submit(JobSpec::new(small.clone(), u0).rhs(f, 1.0)) {
            Ok(t) => accepted.push(t),
            Err(_) => rejected += 1,
        }
    }
    let t0 = std::time::Instant::now();
    svc.resume();
    for t in accepted.drain(..) {
        benchkit::black_box(t.wait().unwrap().u);
    }
    let drain = t0.elapsed();
    let pressure_mlups = (total_updates(&vec![small.clone(); cap]) as f64)
        / drain.as_secs_f64()
        / 1e6;
    // deadline-doomed refill: 1 ms deadlines on a paused queue shed
    // as typed Expired results without ever starting
    svc.pause();
    let mut doomed = small.clone();
    doomed.deadline_ms = Some(1);
    let shed_tickets: Vec<JobTicket> = (0..cap)
        .map(|i| {
            let (f, u0) = grids(i);
            svc.submit(JobSpec::new(doomed.clone(), u0).rhs(f, 1.0)).unwrap()
        })
        .collect();
    // a doomed ticket resolves to a typed Expired error, never a hang
    let shed = shed_tickets.into_iter().map(|t| t.wait()).filter(Result::is_err).count() as u64;
    let stats = svc.stats();
    println!(
        "queue-pressure smoke: {} accepted / {rejected} rejected at capacity {cap}, \
         {shed} shed on deadline, peak queue {}",
        stats.completed, stats.max_queue_depth
    );
    records.push(BenchRecord {
        scheme: "queue-pressure".to_string(),
        op: "mixed".to_string(),
        threads: workers,
        smt: false,
        nt_stores: false,
        ranks: 1,
        mlups: pressure_mlups,
        extras: vec![
            ("rejected_full".to_string(), stats.rejected_full as f64),
            ("shed_expired".to_string(), stats.shed_expired as f64),
            ("max_queue_depth".to_string(), stats.max_queue_depth as f64),
        ],
    });
    svc.shutdown();

    let path = std::path::Path::new("BENCH_service.json");
    benchkit::write_records(path, &records).unwrap();
    println!("\nwrote {} ({} records)", path.display(), records.len());
}
