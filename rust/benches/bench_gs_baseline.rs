//! Fig. 4 bench: Gauss-Seidel baselines — real kernels + modeled testbed.
//!
//! Measures the naive ("C") and dependency-interleaved ("asm") GS line
//! kernels for real — the host ratio between them is the live analog of
//! the paper's Fig. 4(a) C-vs-asm gap — plus the pipeline-parallel
//! threaded sweep, then regenerates the modeled five-machine figures.

use stencilwave::benchkit;
use stencilwave::coordinator::pipeline::{pipeline_gs_passes, PipelineConfig};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::figures;
use stencilwave::stencil::gauss_seidel::{gs_sweep, GsKernel};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

fn main() {
    benchkit::header("Fig. 4(a) host leg — serial GS sweep (real)");
    for (label, nz, ny, nx) in [
        ("100x50x50 (cache dataset)", 100usize, 50usize, 50usize),
        ("200x100x100", 200, 100, 100),
    ] {
        let updates = ((nz - 2) * (ny - 2) * (nx - 2)) as u64;
        for (kname, kernel) in [("C/naive", GsKernel::Naive), ("optimized", GsKernel::Interleaved)] {
            let mut u = Grid3::random(nz, ny, nx, 3);
            let s = benchkit::bench_mlups(&format!("gs {kname} {label}"), updates, 1, 5, || {
                gs_sweep(&mut u, kernel);
            });
            benchkit::report(&s);
        }
    }

    benchkit::header("Fig. 4(b) host leg — pipeline-parallel GS (real)");
    let mut pool = WorkerPool::new(0);
    for threads in [1usize, 2, 4] {
        let mut u = Grid3::random(128, 96, 96, 4);
        let updates = u.interior_len() as u64;
        let cfg = PipelineConfig { threads, kernel: GsKernel::Interleaved };
        let s = benchkit::bench_mlups(&format!("gs pipeline threads={threads} 128x96x96"), updates, 1, 5, || {
            pipeline_gs_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 1).unwrap();
        });
        benchkit::report(&s);
    }

    println!("\n{}", figures::render("fig4a").unwrap());
    println!("{}", figures::render("fig4b").unwrap());
}
