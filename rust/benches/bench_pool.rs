//! Persistent worker pool vs per-pass thread respawn — now through the
//! `Solver` session API.
//!
//! The old coordinators spawned a fresh `std::thread::scope` team for
//! every wavefront pass; a `Solver` session keeps one team parked between
//! passes. This bench measures both strategies end to end (same pass
//! count, same updates): "rebuild session/pass" pays the *whole* session
//! setup per pass — config validation, team spawn, rhs setup — while
//! "one session" pays it once at `build()`. The gap is therefore the full
//! amortization win of the session API, not thread creation alone.
//!
//! Scratch note (ROADMAP item, landed with the session API): the
//! multi-group scheme's per-worker x-line buffers — previously a `Vec`
//! allocated inside `spatial_mg::worker` on *every pass* — and the
//! temporary plane rings now live in the pool-owned `Scratch` arena, so
//! the repeated-pass loops below perform no scratch allocation after the
//! first pass. The multi-group table doubles as the regression check:
//! its per-pass times include zero allocator traffic on the hot path.

use stencilwave::benchkit;
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::grid::Grid3;

fn cfg(scheme: Scheme, n: usize, t: usize, groups: usize) -> RunConfig {
    RunConfig { scheme, size: (n, n, n), t, groups, iters: t, ..Default::default() }
}

fn main() {
    benchkit::header("one Solver session vs rebuild-per-pass — Jacobi wavefront");
    let t = 4usize;
    let passes = 8usize;
    for n in [24usize, 48, 64] {
        let f = Grid3::random(n, n, n, 1);
        let u0 = Grid3::random(n, n, n, 2);
        let c = cfg(Scheme::JacobiWavefront, n, t, 1);
        let updates = (u0.interior_len() * t * passes) as u64;

        let s = benchkit::bench_mlups(
            &format!("rebuild session/pass {n}^3 t={t} x{passes}"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                for _ in 0..passes {
                    // a fresh session per pass = the old spawn-per-pass cost
                    let mut solver =
                        Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
                    solver.step(&mut u).unwrap();
                }
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);

        let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
        let s = benchkit::bench_mlups(
            &format!("one session {n}^3 t={t} x{passes}"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                for _ in 0..passes {
                    solver.step(&mut u).unwrap();
                }
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);
    }

    benchkit::header("multi-group spatial x temporal blocking (one session, pool-owned scratch)");
    for groups in [1usize, 2, 4] {
        let n = 64usize;
        let f = Grid3::random(n, n, n, 3);
        let u0 = Grid3::random(n, n, n, 4);
        let c = cfg(Scheme::JacobiMultiGroup, n, 4, groups);
        let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
        let updates = (u0.interior_len() * 4) as u64;
        let s = benchkit::bench_mlups(
            &format!("multigroup t=4 G={groups} {n}^3"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                // plane rings, boundary arrays and the per-worker x-line
                // buffers are all reused from the session's scratch arena
                solver.step(&mut u).unwrap();
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);
    }
}
