//! Persistent worker pool vs per-pass thread respawn.
//!
//! The old coordinators spawned a fresh `std::thread::scope` team for
//! every wavefront pass; the pool keeps one team parked between passes.
//! This bench measures both strategies end to end (same schedule, same
//! grids, same pass count) so the respawn overhead is visible as an
//! MLUP/s gap — largest for small grids, where a pass is short relative
//! to thread creation. A second table shows the new multi-group blocked
//! scheme scaling over groups on one pool.

use stencilwave::benchkit;
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::spatial_mg::{multigroup_blocked_jacobi_on, MultiGroupConfig};
use stencilwave::coordinator::wavefront::{wavefront_jacobi_on, WavefrontConfig};
use stencilwave::stencil::grid::Grid3;

fn main() {
    benchkit::header("persistent pool vs per-pass respawn — Jacobi wavefront");
    let t = 4usize;
    let passes = 8usize;
    for n in [24usize, 48, 64] {
        let f = Grid3::random(n, n, n, 1);
        let u0 = Grid3::random(n, n, n, 2);
        let cfg = WavefrontConfig { threads: t, ..Default::default() };
        let updates = (u0.interior_len() * t * passes) as u64;

        let s = benchkit::bench_mlups(
            &format!("respawn team/pass {n}^3 t={t} x{passes}"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                for _ in 0..passes {
                    // a fresh pool per pass = the old spawn-per-pass cost
                    let mut pool = WorkerPool::new(t);
                    wavefront_jacobi_on(&mut pool, &mut u, &f, 1.0, &cfg).unwrap();
                }
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);

        let mut pool = WorkerPool::new(t);
        let s = benchkit::bench_mlups(
            &format!("persistent pool {n}^3 t={t} x{passes}"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                for _ in 0..passes {
                    wavefront_jacobi_on(&mut pool, &mut u, &f, 1.0, &cfg).unwrap();
                }
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);
    }

    benchkit::header("multi-group spatial x temporal blocking (one pool)");
    let mut pool = WorkerPool::new(4);
    for groups in [1usize, 2, 4] {
        let n = 64usize;
        let f = Grid3::random(n, n, n, 3);
        let u0 = Grid3::random(n, n, n, 4);
        let cfg = MultiGroupConfig { t: 4, groups };
        let updates = (u0.interior_len() * 4) as u64;
        let s = benchkit::bench_mlups(
            &format!("multigroup t=4 G={groups} {n}^3"),
            updates,
            1,
            3,
            || {
                let mut u = u0.clone();
                multigroup_blocked_jacobi_on(&mut pool, &mut u, &f, 1.0, &cfg).unwrap();
                benchkit::black_box(u);
            },
        );
        benchkit::report(&s);
    }
}
