//! Tab. 1 bench: STREAM triad — host measurement + testbed model.
//!
//! Regenerates the bandwidth block of Tab. 1 from the machine models and
//! measures the real triad on this host at STREAM-standard working-set
//! sizes, so the simulator's bandwidth assumptions can be sanity-checked
//! against at least one physical machine.

use stencilwave::benchkit::{self, black_box};
use stencilwave::figures;
use stencilwave::simulator::machine::MachineSpec;
use stencilwave::simulator::memory::StoreMode;
use stencilwave::simulator::stream::{triad_bandwidth_gbs, triad_updates_per_sec};
use stencilwave::stencil::streambench::stream_triad;

fn main() {
    println!("{}", figures::render("tab1").unwrap());

    benchkit::header("host STREAM triad (real)");
    for exp in [16usize, 20, 24] {
        let n = 1usize << exp;
        let s = benchkit::bench(&format!("triad n=2^{exp} ({} MB)", 3 * n * 8 >> 20), 1, 5, || {
            black_box(stream_triad(n, 1))
        });
        benchkit::report(&s);
        let r = stream_triad(n, 3);
        println!("{:<44} best {:.2} GB/s", "  -> bandwidth", r.best_gbs);
    }

    println!("\n=== modeled triad scaling (GB/s vs threads) ===");
    println!("{:<14} {:>4} {:>10} {:>10} {:>14}", "machine", "thr", "NT", "noNT", "upd/s (NT)");
    for m in MachineSpec::testbed() {
        for threads in [1, 2, m.cores] {
            println!(
                "{:<14} {:>4} {:>10.1} {:>10.1} {:>14.2e}",
                m.name,
                threads,
                triad_bandwidth_gbs(&m, threads, StoreMode::NonTemporal),
                triad_bandwidth_gbs(&m, threads, StoreMode::WriteAllocate),
                triad_updates_per_sec(&m, threads, StoreMode::NonTemporal),
            );
        }
    }
}
