//! Scheme × nt_stores × smt performance matrix with machine-readable
//! output.
//!
//! Runs the headline schedules — wavefront Jacobi, diamond-tiled
//! Jacobi, multi-group Jacobi, wavefront GS and multi-group GS —
//! through full [`Solver`] sessions at every `{nt_stores on/off} ×
//! {smt on/off}` combination, and writes the results to
//! `BENCH_perf_matrix.json` (`{scheme, op, threads, smt, nt_stores,
//! mlups}` records) so CI keeps a greppable perf history after the log
//! scrolls off. The diamond/multigroup pair additionally records the
//! model's crossover verdict (`*_predicted` rows) next to the measured
//! numbers, so the predicted diamond-vs-multigroup winner can be
//! checked against reality per machine.
//!
//! `nt_stores` changes the *executed* kernels here (streaming stores on
//! the writes no schedule re-reads), not just the model's traffic
//! accounting — so the on/off delta in this matrix is a real hardware
//! effect wherever AVX is available. GS schemes update in place and
//! always write-allocate; their nt rows measure that the flag is a
//! no-op there.
//!
//! A second axis sweeps the distributed rank layer: the same sessions
//! sharded over `--ranks`-style z shards, with the halo-exchange
//! overlap counters (overlapped vs stalled receives, message/byte
//! totals) printed per case and the records written to
//! `BENCH_halo_exchange.json` — the machine-readable evidence that
//! interior compute proceeds while exchanges are in flight.
//!
//! `STENCILWAVE_BENCH_SMOKE=1` shrinks the grid and rep count — the CI
//! configuration.

use stencilwave::benchkit::{self, BenchRecord};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::rank::RankSet;
use stencilwave::coordinator::runner::runner_for;
use stencilwave::coordinator::solver::Solver;
use stencilwave::simulator::machine::MachineSpec;
use stencilwave::stencil::grid::Grid3;

fn main() {
    let smoke = benchkit::smoke();
    let (n, iters, reps) = if smoke { (32usize, 4usize, 2usize) } else { (96, 8, 3) };
    let schemes = [
        Scheme::JacobiWavefront,
        Scheme::JacobiDiamond,
        Scheme::JacobiMultiGroup,
        Scheme::GsWavefront,
        Scheme::GsMultiGroup,
    ];

    let mut records: Vec<BenchRecord> = Vec::new();
    benchkit::header("scheme × nt_stores × smt matrix (Solver sessions)");
    for scheme in schemes {
        for nt_stores in [true, false] {
            for smt in [false, true] {
                let cfg = RunConfig {
                    scheme,
                    size: (n, n, n),
                    t: 4,
                    groups: 2,
                    iters,
                    smt,
                    nt_stores,
                    ..Default::default()
                };
                let mut solver = Solver::builder(&cfg).build().unwrap();
                let threads = solver.team_size();
                let u0 = Grid3::random(n, n, n, 7);
                let updates = (u0.interior_len() * iters) as u64;
                let s = benchkit::bench_mlups(
                    &format!("{} nt={} smt={} {n}^3", scheme.as_str(), nt_stores, smt),
                    updates,
                    1,
                    reps,
                    || {
                        let mut u = u0.clone();
                        solver.run(&mut u, iters).unwrap();
                        benchkit::black_box(u);
                    },
                );
                benchkit::report(&s);
                records.push(BenchRecord {
                    scheme: scheme.as_str().to_string(),
                    op: cfg.op.as_str().to_string(),
                    threads,
                    smt,
                    nt_stores,
                    ranks: 1,
                    mlups: s.mlups.unwrap(),
                    extras: vec![],
                });
            }
        }
    }

    // ---- diamond vs multigroup crossover: the model's verdict on a
    // Tab. 1 machine next to the measured host numbers at the same
    // (op, t, groups). Recorded as `*_predicted` rows in the same JSON
    // so CI history keeps predicted and measured side by side.
    benchkit::header("diamond vs multigroup crossover (predicted vs measured)");
    let machine = MachineSpec::by_name("Nehalem EP").unwrap();
    let crossover_cfg = |scheme| RunConfig {
        scheme,
        size: (n, n, n),
        t: 4,
        groups: 2,
        iters,
        ..Default::default()
    };
    let measured = |records: &[BenchRecord], name: &str| {
        records
            .iter()
            .find(|r| r.scheme == name && !r.smt && r.nt_stores)
            .map(|r| r.mlups)
            .unwrap_or(0.0)
    };
    let mut predicted = Vec::new();
    for scheme in [Scheme::JacobiDiamond, Scheme::JacobiMultiGroup] {
        let cfg = crossover_cfg(scheme);
        let p = runner_for(scheme, cfg.op).unwrap().predict(&machine, &cfg);
        println!(
            "  {:<18} predicted {:>8.0} MLUP/s ({})   measured {:>8.2} MLUP/s (host)",
            scheme.as_str(),
            p,
            machine.name,
            measured(&records, scheme.as_str()),
        );
        predicted.push((scheme, p));
        records.push(BenchRecord {
            scheme: format!("{}_predicted", scheme.as_str()),
            op: cfg.op.as_str().to_string(),
            threads: cfg.t,
            smt: false,
            nt_stores: cfg.nt_stores,
            ranks: 1,
            mlups: p,
            extras: vec![],
        });
    }
    let predicted_winner = if predicted[0].1 >= predicted[1].1 { predicted[0].0 } else { predicted[1].0 };
    let dia_meas = measured(&records, Scheme::JacobiDiamond.as_str());
    let mg_meas = measured(&records, Scheme::JacobiMultiGroup.as_str());
    let measured_winner =
        if dia_meas >= mg_meas { Scheme::JacobiDiamond } else { Scheme::JacobiMultiGroup };
    println!(
        "  crossover: predicted winner = {}, measured winner = {}",
        predicted_winner.as_str(),
        measured_winner.as_str()
    );

    let path = std::path::Path::new("BENCH_perf_matrix.json");
    benchkit::write_records(path, &records).unwrap();
    println!("\nwrote {} ({} records)", path.display(), records.len());

    // ---- rank axis: the same sessions sharded across z, halo traffic
    // counted. `overlapped` receives found their message already
    // delivered mid-compute; `stalled` had to block — together they are
    // the instrumented proof that interior progress and the exchange
    // really overlap (overlapped > 0 means at least one halo landed
    // while the receiver was still computing).
    let mut halo_records: Vec<BenchRecord> = Vec::new();
    benchkit::header("scheme × ranks halo-exchange axis (RankSet sessions)");
    let rank_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    for scheme in [Scheme::JacobiWavefront, Scheme::GsMultiGroup] {
        for &ranks in rank_counts {
            let cfg = RunConfig {
                scheme,
                size: (n, n, n),
                t: 4,
                groups: 2,
                iters,
                ranks,
                ..Default::default()
            };
            let mut set = RankSet::builder(&cfg).build().unwrap();
            let u0 = Grid3::random(n, n, n, 7);
            let updates = (u0.interior_len() * iters) as u64;
            let s = benchkit::bench_mlups(
                &format!("{} ranks={ranks} {n}^3", scheme.as_str()),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    set.run(&mut u, iters).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
            let h = set.halo_stats();
            println!(
                "    halo: {} msgs, {} KiB, {} overlapped / {} stalled recvs",
                h.messages,
                h.payload_bytes / 1024,
                h.overlapped_recvs,
                h.stalled_recvs
            );
            halo_records.push(BenchRecord {
                scheme: scheme.as_str().to_string(),
                op: cfg.op.as_str().to_string(),
                threads: cfg.t,
                smt: false,
                nt_stores: cfg.nt_stores,
                ranks,
                mlups: s.mlups.unwrap(),
                extras: vec![],
            });
        }
    }
    let halo_path = std::path::Path::new("BENCH_halo_exchange.json");
    benchkit::write_records(halo_path, &halo_records).unwrap();
    println!("\nwrote {} ({} records)", halo_path.display(), halo_records.len());
}
