//! Fig. 10 bench: Gauss-Seidel wavefront with SMT.
//!
//! SMT cannot be exercised on this 1-core host, so the host leg shows the
//! *oversubscription analog* (2 logical threads per "core slot": S groups
//! × 2 pipeline threads vs S × 1), and the model leg regenerates Fig. 10
//! — including the paper's three observations, asserted in the test
//! suite: EP/Westmere ≈ 2.5× their threaded baseline, EX up to 5×, and
//! EP ≈ Westmere ≈ EX absolute performance (arithmetic plateau).
//!
//! The multi-group leg runs `gs_multigroup` through a [`Solver`] session
//! with `smt = true`, which auto-promotes the placement to the
//! `smtpair` sibling-pair map — the full Sec. 6 co-scheduling path
//! (advisory on hosts without SMT; bit-exactness is asserted either
//! way by the test suite).
//!
//! `STENCILWAVE_BENCH_SMOKE=1` runs one small case per leg with two
//! timed reps — the CI configuration.

use stencilwave::benchkit;
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::solver::Solver;
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_passes, GsWavefrontConfig};
use stencilwave::figures;
use stencilwave::simulator::ecm::{Kernel, KernelClass};
use stencilwave::simulator::machine::Microarch;
use stencilwave::stencil::gauss_seidel::GsKernel;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

fn main() {
    let smoke = benchkit::smoke();
    let (sizes, reps): (&[usize], usize) = if smoke { (&[32], 2) } else { (&[48, 64], 3) };

    let mut pool = WorkerPool::new(0);
    benchkit::header("Fig. 10 host leg — GS wavefront width 1 vs 2 (SMT analog)");
    for &n in sizes {
        for width in [1usize, 2] {
            let u0 = Grid3::random(n, n, n, 11);
            let updates = (u0.interior_len() * 4) as u64;
            let cfg = GsWavefrontConfig {
                sweeps: 4,
                threads_per_group: width,
                kernel: GsKernel::Interleaved,
            };
            let s = benchkit::bench_mlups(
                &format!("gs wavefront S=4 width={width} {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    wavefront_gs_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 1).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
        }
    }

    benchkit::header("gs_multigroup × SMT-pair co-scheduling (Solver session)");
    for &n in sizes {
        for smt in [false, true] {
            let iters = 4;
            let cfg = RunConfig {
                scheme: Scheme::GsMultiGroup,
                size: (n, n, n),
                t: 4,
                groups: 2,
                iters,
                smt, // smt + pin "none" promotes the placement to smtpair
                ..Default::default()
            };
            let mut solver = Solver::builder(&cfg).build().unwrap();
            let u0 = Grid3::random(n, n, n, 13);
            let updates = (u0.interior_len() * iters) as u64;
            let s = benchkit::bench_mlups(
                &format!("gs_multigroup G=2 t=4 smt={smt} {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    solver.run(&mut u, iters).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
        }
    }

    println!("\n=== SMT in-core model: effective cycles per LUP ===");
    println!("{:<14} {:>10} {:>10} {:>8}", "kernel", "1 thread", "2 SMT", "gain");
    for k in [Kernel::JacobiOpt, Kernel::GsC, Kernel::GsOpt] {
        let c = KernelClass::of(k, Microarch::Nehalem);
        let one = c.effective_cpl(1);
        let two = c.effective_cpl(2);
        println!("{:<14} {:>10.2} {:>10.2} {:>7.2}x", format!("{k:?}"), one, two, one / two);
    }

    if !smoke {
        println!("\n{}", figures::render("fig10").unwrap());
    }
}
