//! Fig. 3 bench: Jacobi baselines — real kernels + modeled testbed.
//!
//! (a) serial: the line-update kernel on cache-resident (100×50×50) and
//!     memory-resident (this host: largest feasible) datasets;
//! (b) threaded socket predictions with the Eq. (1) limit.
//!
//! The host rows give real MLUP/s for the kernel implementations; the
//! modeled rows regenerate the paper's five-machine comparison.

use stencilwave::benchkit;
use stencilwave::figures;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_sweep;

fn bench_size(label: &str, nz: usize, ny: usize, nx: usize) {
    let src = Grid3::random(nz, ny, nx, 1);
    let f = Grid3::random(nz, ny, nx, 2);
    let mut dst = Grid3::zeros(nz, ny, nx);
    let updates = src.interior_len() as u64;
    let s = benchkit::bench_mlups(label, updates, 1, 5, || {
        jacobi_sweep(&mut dst, &src, &f, 1.0);
    });
    benchkit::report(&s);
}

fn main() {
    benchkit::header("Fig. 3(a) host leg — serial Jacobi sweep (real)");
    // the paper's cache dataset: 100×50×50 ≈ 4 MB for two arrays
    bench_size("jacobi serial 100x50x50 (cache dataset)", 100, 50, 50);
    // a larger dataset exercising the memory hierarchy of this host
    bench_size("jacobi serial 200x100x100", 200, 100, 100);
    bench_size("jacobi serial 256x128x128", 256, 128, 128);

    println!("\n{}", figures::render("fig3a").unwrap());
    println!("{}", figures::render("fig3b").unwrap());
}
