//! Fig. 9 bench: Gauss-Seidel wavefront temporal blocking.
//!
//! Host leg: S simultaneous pipelined sweeps vs S sequential pipelined
//! sweeps (the threaded baseline of Fig. 9's right axis), plus the
//! multi-group member (the Fig. 5b pipeline nested in y-blocks, one per
//! cache group). Model leg: the full five-machine Fig. 9 sweep.
//!
//! `STENCILWAVE_BENCH_SMOKE=1` shrinks the run to one small case with two
//! timed iterations — the CI regression canary for the GS schemes,
//! `gs_multigroup` included.

use stencilwave::benchkit;
use stencilwave::coordinator::gs_multigroup::{gs_multigroup_passes, GsMultiGroupConfig};
use stencilwave::coordinator::pipeline::{pipeline_gs_passes, PipelineConfig};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_passes, GsWavefrontConfig};
use stencilwave::figures;
use stencilwave::stencil::gauss_seidel::GsKernel;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

fn main() {
    let mut pool = WorkerPool::new(0);
    let (sizes, sweep_counts, reps): (&[usize], &[usize], usize) =
        if benchkit::smoke() { (&[20], &[2], 2) } else { (&[48, 64, 96], &[2, 4], 3) };

    benchkit::header("Fig. 9 host leg — GS wavefront vs pipelined baseline (real)");
    for &n in sizes {
        for &s_count in sweep_counts {
            let u0 = Grid3::random(n, n, n, 9);
            let updates = (u0.interior_len() * s_count) as u64;
            let base = PipelineConfig { threads: 2, kernel: GsKernel::Interleaved };
            let s = benchkit::bench_mlups(
                &format!("baseline {s_count} pipelined sweeps {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    pipeline_gs_passes(&mut pool, &ConstLaplace7, &mut u, &base, s_count).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
            let cfg = GsWavefrontConfig {
                sweeps: s_count,
                threads_per_group: 2,
                kernel: GsKernel::Interleaved,
            };
            let s = benchkit::bench_mlups(
                &format!("wavefront S={s_count}x2 {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    wavefront_gs_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 1).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
            let mg = GsMultiGroupConfig {
                t: s_count,
                groups: 2,
                kernel: GsKernel::Interleaved,
            };
            let s = benchkit::bench_mlups(
                &format!("multigroup t={s_count} G=2 {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    gs_multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &mg, 1).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
        }
    }

    println!("\n{}", figures::render("fig9").unwrap());
}
