//! Fig. 9 bench: Gauss-Seidel wavefront temporal blocking.
//!
//! Host leg: S simultaneous pipelined sweeps vs S sequential pipelined
//! sweeps (the threaded baseline of Fig. 9's right axis). Model leg: the
//! full five-machine Fig. 9 sweep.

use stencilwave::benchkit;
use stencilwave::coordinator::pipeline::{pipeline_gs_passes, PipelineConfig};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_passes, GsWavefrontConfig};
use stencilwave::figures;
use stencilwave::stencil::gauss_seidel::GsKernel;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

fn main() {
    let mut pool = WorkerPool::new(0);
    benchkit::header("Fig. 9 host leg — GS wavefront vs pipelined baseline (real)");
    for n in [48usize, 64, 96] {
        for s_count in [2usize, 4] {
            let u0 = Grid3::random(n, n, n, 9);
            let updates = (u0.interior_len() * s_count) as u64;
            let base = PipelineConfig { threads: 2, kernel: GsKernel::Interleaved };
            let s = benchkit::bench_mlups(
                &format!("baseline {s_count} pipelined sweeps {n}^3"),
                updates,
                1,
                3,
                || {
                    let mut u = u0.clone();
                    pipeline_gs_passes(&mut pool, &ConstLaplace7, &mut u, &base, s_count).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
            let cfg = GsWavefrontConfig {
                sweeps: s_count,
                threads_per_group: 2,
                kernel: GsKernel::Interleaved,
            };
            let s = benchkit::bench_mlups(
                &format!("wavefront S={s_count}x2 {n}^3"),
                updates,
                1,
                3,
                || {
                    let mut u = u0.clone();
                    wavefront_gs_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 1).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
        }
    }

    println!("\n{}", figures::render("fig9").unwrap());
}
