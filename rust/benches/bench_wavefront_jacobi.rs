//! Fig. 8 bench: Jacobi wavefront temporal blocking.
//!
//! Host leg: the real threaded wavefront engine vs the t-sweep baseline,
//! per-update throughput at several sizes and blocking factors, plus the
//! blocked (spatial × temporal) variant. Model leg: the full Fig. 8 sweep
//! over the five-machine testbed.

#![allow(deprecated)] // benches keep covering the shim matrix until removal

use stencilwave::benchkit;
use stencilwave::coordinator::spatial::{blocked_wavefront_jacobi, SpatialConfig};
use stencilwave::coordinator::wavefront::{wavefront_jacobi, WavefrontConfig};
use stencilwave::figures;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;

fn main() {
    benchkit::header("Fig. 8 host leg — wavefront vs t separate sweeps (real)");
    for n in [48usize, 64, 96] {
        for t in [2usize, 4] {
            let f = Grid3::random(n, n, n, 1);
            let u0 = Grid3::random(n, n, n, 2);
            let updates = (u0.interior_len() * t) as u64;
            let s = benchkit::bench_mlups(&format!("baseline {t} sweeps {n}^3"), updates, 1, 3, || {
                benchkit::black_box(jacobi_steps(&u0, &f, 1.0, t));
            });
            benchkit::report(&s);
            let cfg = WavefrontConfig { threads: t, ..Default::default() };
            let s = benchkit::bench_mlups(&format!("wavefront t={t} {n}^3"), updates, 1, 3, || {
                let mut u = u0.clone();
                wavefront_jacobi(&mut u, &f, 1.0, &cfg).unwrap();
                benchkit::black_box(u);
            });
            benchkit::report(&s);
            let sp = SpatialConfig { t, blocks: 4 };
            let s = benchkit::bench_mlups(&format!("blocked wavefront t={t} B=4 {n}^3"), updates, 1, 3, || {
                let mut u = u0.clone();
                blocked_wavefront_jacobi(&mut u, &f, 1.0, &sp).unwrap();
                benchkit::black_box(u);
            });
            benchkit::report(&s);
        }
    }

    println!("\n{}", figures::render("fig8").unwrap());
}
