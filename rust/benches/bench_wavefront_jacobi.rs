//! Fig. 8 bench: Jacobi wavefront temporal blocking.
//!
//! Host leg: the real threaded wavefront engine vs the t-sweep baseline,
//! per-update throughput at several sizes and blocking factors, the
//! blocked (spatial × temporal) variant, and the generic-op column
//! (varcoeff / radius-2 through the same schedule). Model leg: the full
//! Fig. 8 sweep over the five-machine testbed.
//!
//! `STENCILWAVE_BENCH_SMOKE=1` shrinks the run to one small case with two
//! timed iterations — the CI regression canary for the kernel layer.

use stencilwave::benchkit;
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::spatial::{blocked_wavefront_jacobi, SpatialConfig};
use stencilwave::coordinator::wavefront::{wavefront_jacobi_passes, WavefrontConfig};
use stencilwave::figures;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::op::{ConstLaplace7, Laplace13, StencilOp, VarCoeff7};

use stencilwave::benchkit::smoke;

fn bench_op<O: StencilOp>(
    pool: &mut WorkerPool,
    name: &str,
    op: &O,
    n: usize,
    t: usize,
    reps: usize,
) {
    let f = Grid3::random(n, n, n, 1);
    let u0 = Grid3::random(n, n, n, 2);
    // radius-aware: a radius-R op updates the (n-2R)^3 deep interior
    let interior = n - 2 * op.radius();
    let updates = (interior * interior * interior * t) as u64;
    let cfg = WavefrontConfig { threads: t, ..Default::default() };
    let s = benchkit::bench_mlups(name, updates, 1, reps, || {
        let mut u = u0.clone();
        wavefront_jacobi_passes(pool, op, &mut u, &f, 1.0, &cfg, 1).unwrap();
        benchkit::black_box(u);
    });
    benchkit::report(&s);
}

fn main() {
    let mut pool = WorkerPool::new(0);
    let (sizes, ts, reps): (&[usize], &[usize], usize) =
        if smoke() { (&[20], &[2], 2) } else { (&[48, 64, 96], &[2, 4], 3) };

    benchkit::header("Fig. 8 host leg — wavefront vs t separate sweeps (real)");
    for &n in sizes {
        for &t in ts {
            let f = Grid3::random(n, n, n, 1);
            let u0 = Grid3::random(n, n, n, 2);
            let updates = (u0.interior_len() * t) as u64;
            let s = benchkit::bench_mlups(
                &format!("baseline {t} sweeps {n}^3"),
                updates,
                1,
                reps,
                || {
                    benchkit::black_box(jacobi_steps(&u0, &f, 1.0, t));
                },
            );
            benchkit::report(&s);
            bench_op(&mut pool, &format!("wavefront t={t} {n}^3"), &ConstLaplace7, n, t, reps);
            let sp = SpatialConfig { t, blocks: 4, ..Default::default() };
            let s = benchkit::bench_mlups(
                &format!("blocked wavefront t={t} B=4 {n}^3"),
                updates,
                1,
                reps,
                || {
                    let mut u = u0.clone();
                    blocked_wavefront_jacobi(&ConstLaplace7, &mut u, &f, 1.0, &sp).unwrap();
                    benchkit::black_box(u);
                },
            );
            benchkit::report(&s);
        }
    }

    benchkit::header("generic-op column — same schedule, other operators");
    let n = if smoke() { 20 } else { 64 };
    bench_op(&mut pool, &format!("laplace7   t=2 {n}^3"), &ConstLaplace7, n, 2, reps);
    bench_op(
        &mut pool,
        &format!("varcoeff   t=2 {n}^3"),
        &VarCoeff7::default_for((n, n, n)),
        n,
        2,
        reps,
    );
    bench_op(&mut pool, &format!("laplace13  t=2 {n}^3"), &Laplace13, n, 2, reps);

    if !smoke() {
        println!("\n{}", figures::render("fig8").unwrap());
    }
}
