//! Barrier ablation bench (Sec. 4's synchronization discussion).
//!
//! Measures the real spin and tree barriers over many rounds at several
//! thread counts, then prints the calibrated testbed cost model the
//! simulator uses. On this 1-core host absolute numbers reflect scheduler
//! round-robin, but the *relative* spin-vs-tree ordering under
//! oversubscription mirrors the paper's SMT finding.

use std::sync::Arc;

use stencilwave::benchkit;
use stencilwave::coordinator::barrier::AnyBarrier;
use stencilwave::figures;
use stencilwave::simulator::perfmodel::BarrierKind;

fn rounds_per_sec(kind: BarrierKind, threads: usize, rounds: usize) -> f64 {
    let barrier = Arc::new(AnyBarrier::new(kind, threads));
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for id in 0..threads {
            let b = Arc::clone(&barrier);
            scope.spawn(move || {
                for _ in 0..rounds {
                    b.wait(id);
                }
            });
        }
    });
    rounds as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    benchkit::header("real barrier throughput on this host");
    for threads in [1usize, 2, 4, 8] {
        for kind in [BarrierKind::Spin, BarrierKind::Tree] {
            let rps = rounds_per_sec(kind, threads, 10_000);
            let s = benchkit::bench(
                &format!("{kind:?} barrier x{threads} (10k rounds)"),
                0,
                3,
                || rounds_per_sec(kind, threads, 2_000),
            );
            benchkit::report(&s);
            println!("{:<44} {rps:>10.0} rounds/s", "  -> sustained");
        }
    }

    println!("\n{}", figures::render("barrier").unwrap());
}
