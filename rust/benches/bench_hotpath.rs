//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Profiles the L3 building blocks in isolation so optimization work can
//! target the true bottleneck:
//!  * the Jacobi line-update kernel (per-line cost, vectorization),
//!  * the GS line kernels (naive vs interleaved — the ILP gap),
//!  * cache-simulator throughput (accesses/s),
//!  * trace generation throughput,
//!  * ECM model evaluation (figures must regenerate in milliseconds).

use stencilwave::benchkit::{self, black_box};
use stencilwave::figures;
use stencilwave::simulator::cache::Hierarchy;
use stencilwave::simulator::trace::{jacobi_sweep_trace, run_trace, Dims};
use stencilwave::stencil::gauss_seidel::{gs_line_update_interleaved, gs_line_update_naive};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::{jacobi_line_update, jacobi_sweep};

fn main() {
    let nx = 512usize;
    let lines: Vec<Vec<f64>> = (0..6).map(|i| Grid3::random(1, 1, nx, i).data().to_vec()).collect();
    let mut dst = vec![0.0f64; nx];

    benchkit::header("line-update kernels (512-wide lines)");
    let s = benchkit::bench_mlups("jacobi_line_update", (nx - 2) as u64, 10, 50, || {
        jacobi_line_update(
            &mut dst, &lines[0], &lines[1], &lines[2], &lines[3], &lines[4], &lines[5], 1.0,
        );
        black_box(&dst);
    });
    benchkit::report(&s);

    let mut line = lines[0].clone();
    let s = benchkit::bench_mlups("gs_line_update_naive", (nx - 2) as u64, 10, 50, || {
        gs_line_update_naive(&mut line, &lines[1], &lines[2], &lines[3], &lines[4]);
        black_box(&line);
    });
    benchkit::report(&s);
    let s = benchkit::bench_mlups("gs_line_update_interleaved", (nx - 2) as u64, 10, 50, || {
        gs_line_update_interleaved(&mut line, &lines[1], &lines[2], &lines[3], &lines[4]);
        black_box(&line);
    });
    benchkit::report(&s);

    benchkit::header("full sweeps");
    let src = Grid3::random(96, 96, 96, 1);
    let f = Grid3::random(96, 96, 96, 2);
    let mut out = Grid3::zeros(96, 96, 96);
    let s = benchkit::bench_mlups("jacobi_sweep 96^3", src.interior_len() as u64, 1, 5, || {
        jacobi_sweep(&mut out, &src, &f, 1.0);
    });
    benchkit::report(&s);

    benchkit::header("simulator throughput");
    let d = Dims::new(34, 32, 32);
    let s = benchkit::bench("trace generation 34x32x32", 1, 5, || {
        black_box(jacobi_sweep_trace(d, false).len())
    });
    benchkit::report(&s);
    let trace = jacobi_sweep_trace(d, false);
    let s = benchkit::bench(&format!("cache sim ({} accesses)", trace.len()), 1, 5, || {
        let mut h = Hierarchy::uniform(1, 32 << 10, 256 << 10, 2 << 20);
        black_box(run_trace(&mut h, &trace))
    });
    benchkit::report(&s);

    benchkit::header("figure regeneration (must be interactive-fast)");
    let s = benchkit::bench("all 9 figures", 1, 5, || {
        for id in figures::ALL_FIGURES {
            black_box(figures::render(id).unwrap().len());
        }
    });
    benchkit::report(&s);
}
