//! Set-associative LRU cache-hierarchy simulator.
//!
//! The ECM model *assumes* traffic volumes ("intermediate planes stay in
//! the shared cache"); this simulator *verifies* them: it executes the
//! exact cacheline access stream of a schedule against the Tab. 1 cache
//! topologies and reports per-level hits, misses and memory traffic. The
//! wavefront residency claim of Sec. 4 becomes a testable property:
//! memory bytes per LUP ≈ 16/t instead of 16–24.
//!
//! Model scope (documented simplifications):
//! * inclusive hierarchy with LRU replacement and write-back/write-allocate
//!   lines; an exclusive (victim) mode doubles inter-level volume
//!   accounting rather than simulating victim buffers cycle-accurately;
//! * coherence is not simulated — shared lines are served from the
//!   outermost shared level, which is exactly the sharing pattern the
//!   wavefront scheme is designed around;
//! * non-temporal stores bypass the hierarchy and count as pure memory
//!   write traffic.

use super::machine::MachineSpec;
use super::CACHELINE_BYTES;

/// Hit/miss/traffic counters for one cache instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }
    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }
}

/// One set-associative, write-back, LRU cache instance.
#[derive(Clone, Debug)]
pub struct Cache {
    sets: Vec<Vec<(u64, bool)>>, // (line tag, dirty), MRU at the back
    assoc: usize,
    n_sets: u64,
    set_shift: u32,
    pub stats: CacheStats,
}

impl Cache {
    /// Build a cache of `bytes` capacity and `assoc` ways (64 B lines).
    ///
    /// Set count need not be a power of two (Westmere's 12 MB/16-way L3
    /// has 12288 sets); indexing uses modulo, which is exact for the
    /// power-of-two case and a faithful hash otherwise.
    pub fn new(bytes: usize, assoc: usize) -> Self {
        let lines = bytes / CACHELINE_BYTES;
        let n_sets = (lines / assoc).max(1);
        Self {
            sets: vec![Vec::with_capacity(assoc); n_sets],
            assoc,
            n_sets: n_sets as u64,
            set_shift: CACHELINE_BYTES.trailing_zeros(),
            stats: CacheStats::default(),
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> usize {
        ((addr >> self.set_shift) % self.n_sets) as usize
    }

    #[inline]
    fn tag_of(&self, addr: u64) -> u64 {
        addr >> self.set_shift
    }

    /// Access a byte address. Returns `Hit` or `Miss { evicted_dirty }`.
    pub fn access(&mut self, addr: u64, write: bool) -> AccessResult {
        let set_idx = self.set_of(addr);
        let tag = self.tag_of(addr);
        let set = &mut self.sets[set_idx];
        if let Some(pos) = set.iter().position(|(t, _)| *t == tag) {
            let (_, dirty) = set.remove(pos);
            set.push((tag, dirty || write));
            self.stats.hits += 1;
            return AccessResult::Hit;
        }
        self.stats.misses += 1;
        let mut evicted_dirty = false;
        if set.len() >= self.assoc {
            let (_, dirty) = set.remove(0); // LRU front
            evicted_dirty = dirty;
            if dirty {
                self.stats.writebacks += 1;
            }
        }
        set.push((tag, write));
        AccessResult::Miss { evicted_dirty }
    }

    /// Is the line containing `addr` currently resident?
    pub fn contains(&self, addr: u64) -> bool {
        let set = &self.sets[self.set_of(addr)];
        let tag = self.tag_of(addr);
        set.iter().any(|(t, _)| *t == tag)
    }
}

/// Outcome of a single cache access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessResult {
    Hit,
    Miss { evicted_dirty: bool },
}

/// A multicore cache hierarchy: per-core L1/L2, shared outer level.
#[derive(Debug)]
pub struct Hierarchy {
    l1: Vec<Cache>,
    l2: Vec<Cache>,
    /// Map core → L2 instance (Harpertown: two cores share one L2).
    l2_of_core: Vec<usize>,
    olc: Cache,
    /// Exclusive-hierarchy volume factor (2 for Istanbul).
    volume_factor: u64,
    /// Bytes transferred from/to main memory.
    pub mem_read_bytes: u64,
    pub mem_write_bytes: u64,
    /// Bytes crossing the L2↔OLC boundary (volume-factor adjusted).
    pub olc_transfer_bytes: u64,
}

impl Hierarchy {
    /// Build the hierarchy of `m` for `cores` active cores.
    pub fn for_machine(m: &MachineSpec, cores: usize) -> Self {
        let l2_instances = cores.div_ceil(m.l2.shared_by);
        let olc = match m.l3 {
            Some(l3) => Cache::new(l3.bytes, l3.assoc),
            // Core 2: the shared L2 *is* the OLC; give cores tiny private
            // "L2"s so the level structure stays uniform.
            None => Cache::new(m.l2.bytes, m.l2.assoc),
        };
        let per_core_l2_bytes = if m.l3.is_some() { m.l2.bytes } else { 32 << 10 };
        let per_core_l2_assoc = if m.l3.is_some() { m.l2.assoc } else { 8 };
        Self {
            l1: (0..cores).map(|_| Cache::new(m.l1.bytes, m.l1.assoc)).collect(),
            l2: (0..l2_instances.max(1))
                .map(|_| Cache::new(per_core_l2_bytes, per_core_l2_assoc))
                .collect(),
            l2_of_core: (0..cores).map(|c| c / m.l2.shared_by.max(1)).collect(),
            olc,
            volume_factor: if m.exclusive { 2 } else { 1 },
            mem_read_bytes: 0,
            mem_write_bytes: 0,
            olc_transfer_bytes: 0,
        }
    }

    /// Simple uniform hierarchy for tests: `cores` × (l1, l2) + shared olc.
    pub fn uniform(cores: usize, l1_bytes: usize, l2_bytes: usize, olc_bytes: usize) -> Self {
        Self {
            l1: (0..cores).map(|_| Cache::new(l1_bytes, 8)).collect(),
            l2: (0..cores).map(|_| Cache::new(l2_bytes, 8)).collect(),
            l2_of_core: (0..cores).collect(),
            olc: Cache::new(olc_bytes, 16),
            volume_factor: 1,
            mem_read_bytes: 0,
            mem_write_bytes: 0,
            olc_transfer_bytes: 0,
        }
    }

    /// One load/store by `core` at byte address `addr`.
    pub fn access(&mut self, core: usize, addr: u64, write: bool) {
        let line = CACHELINE_BYTES as u64;
        if let AccessResult::Hit = self.l1[core].access(addr, write) {
            return;
        }
        let l2i = self.l2_of_core[core];
        if let AccessResult::Hit = self.l2[l2i].access(addr, write) {
            return;
        }
        self.olc_transfer_bytes += line * self.volume_factor;
        match self.olc.access(addr, write) {
            AccessResult::Hit => {}
            AccessResult::Miss { evicted_dirty } => {
                self.mem_read_bytes += line;
                if evicted_dirty {
                    self.mem_write_bytes += line;
                }
            }
        }
    }

    /// A non-temporal store: bypasses all levels, pure memory write.
    pub fn nt_store(&mut self, _core: usize, _addr: u64) {
        self.mem_write_bytes += CACHELINE_BYTES as u64;
    }

    /// Total main-memory traffic.
    pub fn mem_bytes(&self) -> u64 {
        self.mem_read_bytes + self.mem_write_bytes
    }

    /// Is the line resident in the shared outer cache?
    pub fn olc_contains(&self, addr: u64) -> bool {
        self.olc.contains(addr)
    }

    /// Outer-level cache statistics.
    pub fn olc_stats(&self) -> CacheStats {
        self.olc.stats
    }

    /// Aggregate L1 statistics.
    pub fn l1_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.l1 {
            s.hits += c.stats.hits;
            s.misses += c.stats.misses;
            s.writebacks += c.stats.writebacks;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_within_a_set() {
        // 4 lines capacity, 2-way: 2 sets. Addresses mapping to set 0:
        // multiples of 128.
        let mut c = Cache::new(4 * 64, 2);
        assert_eq!(c.access(0, false), AccessResult::Miss { evicted_dirty: false });
        assert_eq!(c.access(128, false), AccessResult::Miss { evicted_dirty: false });
        assert_eq!(c.access(0, false), AccessResult::Hit);
        // 256 evicts LRU = 128 (0 was just touched)
        assert_eq!(c.access(256, false), AccessResult::Miss { evicted_dirty: false });
        assert!(c.contains(0));
        assert!(!c.contains(128));
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = Cache::new(2 * 64, 1); // direct-mapped, 2 sets
        c.access(0, true); // dirty line in set 0
        match c.access(128, false) {
            AccessResult::Miss { evicted_dirty } => assert!(evicted_dirty),
            _ => panic!("expected miss"),
        }
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn hierarchy_serves_repeats_from_l1() {
        let mut h = Hierarchy::uniform(2, 1 << 10, 1 << 12, 1 << 16);
        h.access(0, 0, false);
        let mem_after_first = h.mem_bytes();
        for _ in 0..100 {
            h.access(0, 0, false);
        }
        assert_eq!(h.mem_bytes(), mem_after_first, "L1 hits cost no memory traffic");
    }

    #[test]
    fn shared_olc_serves_sibling_core() {
        let mut h = Hierarchy::uniform(2, 1 << 10, 1 << 12, 1 << 20);
        h.access(0, 4096, false); // core 0 pulls the line in
        let mem = h.mem_bytes();
        h.access(1, 4096, false); // core 1 misses private levels, hits OLC
        assert_eq!(h.mem_bytes(), mem, "no extra memory traffic for the sibling");
        assert!(h.olc_stats().hits >= 1);
    }

    #[test]
    fn streaming_overflows_small_cache() {
        let mut h = Hierarchy::uniform(1, 1 << 10, 1 << 12, 1 << 14); // 16 KB OLC
        // stream 1 MB: every line must come from memory
        let lines = (1 << 20) / 64;
        for i in 0..lines {
            h.access(0, (i * 64) as u64, false);
        }
        assert_eq!(h.mem_read_bytes, 1 << 20);
    }

    #[test]
    fn nt_store_bypasses_hierarchy() {
        let mut h = Hierarchy::uniform(1, 1 << 10, 1 << 12, 1 << 16);
        h.nt_store(0, 0);
        assert_eq!(h.mem_write_bytes, 64);
        assert!(!h.olc_contains(0));
    }

    #[test]
    fn machine_hierarchies_build() {
        for m in MachineSpec::testbed() {
            let h = Hierarchy::for_machine(&m, m.cores);
            assert_eq!(h.l1.len(), m.cores);
        }
    }
}
