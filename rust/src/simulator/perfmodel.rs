//! Composite performance predictors: Eq. (1) and the wavefront model.
//!
//! [`eq1_limit_mlups`] is the paper's Eq. (1): `P0 = M_S / 16 B` with the
//! appropriate STREAM figure. [`wavefront_prediction`] combines the ECM
//! kernel model, the traffic accounting, the OLC capacity constraint that
//! drives spatial blocking, and the barrier cost model into the curves of
//! Figs. 8–10.


use super::ecm::{EcmModel, Kernel, KernelProfile, Prediction};
use super::machine::MachineSpec;
use super::memory::{self, StoreMode};

/// Paper Eq. (1): the bandwidth ceiling in MLUP/s.
///
/// Jacobi uses the NT-store STREAM figure over 16 B/LUP; Gauss-Seidel the
/// no-NT figure (Sec. 3: "we therefore use the STREAM triad measurements
/// without non-temporal stores in the performance model for Gauss-Seidel").
pub fn eq1_limit_mlups(m: &MachineSpec, kernel: Kernel) -> f64 {
    let ms = if kernel.is_gs() { m.stream_socket_nont_gbs } else { m.stream_socket_nt_gbs };
    ms * 1e3 / 16.0
}

/// Synchronization primitive (Sec. 4: pthread barriers are unusable for
/// fine-grained parallelism; spin barriers win for physical cores; tree
/// barriers win as soon as SMT threads share cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BarrierKind {
    /// POSIX `pthread_barrier_t` (kernel futex round trip).
    Pthread,
    /// Busy-wait on a shared counter.
    #[default]
    Spin,
    /// Pairwise tree of flags — O(log t) depth, SMT-friendly.
    Tree,
}

impl BarrierKind {
    /// Modeled cost in core cycles for `threads` participants.
    ///
    /// Calibration: a futex barrier costs O(µs) (~5000 cy); a spin barrier
    /// ~100 cy per participant of coherence traffic, but spinning SMT
    /// siblings steal pipeline slots from the worker thread (3× penalty);
    /// a tree barrier pays ~150 cy per level of its log₂ depth.
    pub fn cycles(self, threads: usize, smt: bool) -> f64 {
        let t = threads.max(1) as f64;
        match self {
            BarrierKind::Pthread => 5000.0 + 400.0 * t,
            BarrierKind::Spin => {
                let base = 120.0 * t;
                if smt {
                    3.0 * base
                } else {
                    base
                }
            }
            BarrierKind::Tree => 150.0 * (t.log2().ceil().max(1.0)) * if smt { 1.3 } else { 1.0 },
        }
    }
}

/// Configuration of a wavefront run (Sec. 4 parameters).
#[derive(Clone, Copy, Debug)]
pub struct WavefrontParams {
    /// Threads per thread group = temporal blocking factor `t`.
    pub t: usize,
    /// Number of thread groups `N`.
    pub groups: usize,
    /// Use SMT hardware threads (two logical threads per core).
    pub smt: bool,
    /// Kernel the sweeps run.
    pub kernel: Kernel,
    /// Store flavour of the final sweep (Jacobi only).
    pub store: StoreMode,
    /// Synchronization primitive.
    pub barrier: BarrierKind,
}

impl WavefrontParams {
    /// The paper's standard configuration for a machine: one thread group
    /// spanning the cache group, blocking factor = threads available.
    pub fn standard(m: &MachineSpec, kernel: Kernel, smt: bool) -> Self {
        Self {
            t: m.max_blocking_factor(smt),
            groups: m.cores / m.cache_group_cores(),
            smt,
            kernel,
            store: StoreMode::NonTemporal,
            barrier: if smt { BarrierKind::Tree } else { BarrierKind::Spin },
        }
    }

    /// Logical threads this configuration occupies.
    pub fn total_threads(&self) -> usize {
        self.t * self.groups
    }
}

/// Spatial blocking derived from the OLC capacity constraint (Sec. 4:
/// "block sizes must be chosen so that the temporary data can be kept in
/// the outermost cache level").
#[derive(Clone, Copy, Debug)]
pub struct Blocking {
    /// Lines of y per block.
    pub block_y: usize,
    /// Number of blocks B along y.
    pub blocks: usize,
    /// Working-set bytes per thread group at this blocking.
    pub working_set_bytes: usize,
}

/// Choose the y block size for a problem `(nz, ny, nx)` and a radius-1
/// operator (see [`choose_blocking_r`]).
pub fn choose_blocking(m: &MachineSpec, t: usize, groups: usize, ny: usize, nx: usize) -> Blocking {
    choose_blocking_r(m, t, groups, ny, nx, 1)
}

/// Choose the y block size for a problem `(nz, ny, nx)` and an operator
/// of halo radius `r` — the in-cache layer condition derived from the
/// op's [`TrafficSignature`](crate::stencil::op::TrafficSignature).
///
/// The rolling window holds `(r+1)·t + 2r` planes of `block_y × nx`
/// doubles per thread group (`t` produced planes spaced `r+1` apart in
/// the skew, plus the `2r`-plane halo); for `r = 1` this is the paper's
/// `2t + 2`. All groups share the OLC, of which a utilization fraction
/// is realistically usable.
pub fn choose_blocking_r(
    m: &MachineSpec,
    t: usize,
    groups: usize,
    ny: usize,
    nx: usize,
    r: usize,
) -> Blocking {
    const UTILIZATION: f64 = 0.5;
    let cap = (m.olc_bytes() as f64 * UTILIZATION / groups.max(1) as f64) as usize;
    let bytes_per_line = ((r + 1) * t + 2 * r) * nx * 8;
    let block_y = (cap / bytes_per_line).clamp(1, ny);
    let blocks = ny.div_ceil(block_y);
    Blocking { block_y, blocks, working_set_bytes: bytes_per_line * block_y }
}

/// Predicted wavefront performance for one problem size (Figs. 8–10)
/// with the paper's calibrated radius-1 kernels.
pub fn wavefront_prediction(
    m: &MachineSpec,
    p: &WavefrontParams,
    size: (usize, usize, usize),
) -> Prediction {
    wavefront_prediction_for(m, p, &KernelProfile::of_kernel(p.kernel, m.arch), size)
}

/// Shared compute/OLC roofline of a temporally blocked pass on
/// `physical_cores` cores: in-core + in-hierarchy cycles per LUP (the
/// exclusive hierarchy — Istanbul — pays every transfer twice), the
/// resulting compute ceiling and the OLC bandwidth ceiling. One home for
/// the term so [`wavefront_prediction_for`] and [`multigroup_prediction`]
/// cannot silently diverge.
///
/// Returns `(compute MLUP/s, olc MLUP/s, cycles per LUP)`.
fn blocked_rooflines(
    m: &MachineSpec,
    profile: &KernelProfile,
    smt_per_core: usize,
    physical_cores: usize,
) -> (f64, f64, f64) {
    let t_core = profile.class.effective_cpl(smt_per_core);
    let vol = profile.sig.hierarchy_bytes_per_lup() * if m.exclusive { 2.0 } else { 1.0 };
    let transfer = super::ecm::TransferModel::of(m);
    let t_data = vol / transfer.l1l2_bpc + vol / transfer.l2olc_bpc * (m.clock_ghz / m.uncore_ghz);
    let cpl = t_core + t_data;
    let compute = physical_cores as f64 * m.clock_ghz * 1e3 / cpl;
    let olc = m.olc_bandwidth_gbs(physical_cores) * 1e3 / vol;
    (compute, olc, cpl)
}

/// Predicted wavefront performance for an arbitrary op profile: transfer
/// volumes, the layer condition and the blocking all derive from the
/// profile's [`TrafficSignature`](crate::stencil::op::TrafficSignature).
pub fn wavefront_prediction_for(
    m: &MachineSpec,
    p: &WavefrontParams,
    profile: &KernelProfile,
    (_nz, ny, nx): (usize, usize, usize),
) -> Prediction {
    let radius = profile.sig.radius;
    let smt_per_core = if p.smt { m.smt_per_core } else { 1 };
    let physical_cores = p.total_threads().div_ceil(smt_per_core).min(m.cores);
    let blocking = choose_blocking_r(m, p.t, p.groups, ny, nx, radius);

    // --- compute / OLC rooflines: all t threads of each group do useful
    // sweeps through the shared cache.
    let (compute, olc, cpl) = blocked_rooflines(m, profile, smt_per_core, physical_cores);

    // --- memory roofline: 1/t of the baseline traffic + boundary arrays.
    let boundary_overhead = if blocking.blocks > 1 {
        // boundary arrays touch R·(B-1) of the ny planes of the
        // t-amortized main stream; the term is charged as a fraction of
        // that stream (the seed model's accounting, kept so radius-1
        // predictions stay bit-identical to the pre-`StencilOp` figures).
        // `multigroup_prediction` charges its boundary arrays as
        // absolute bytes instead — the physically tighter accounting.
        radius as f64 * (blocking.blocks as f64 - 1.0) / ny as f64
    } else {
        0.0
    };
    let nt = matches!(p.store, StoreMode::NonTemporal) && !profile.sig.in_place;
    let mem_bytes =
        profile.sig.mem_bytes_per_lup(nt) / p.t as f64 * (1.0 + boundary_overhead);
    let mem = m.memory_bandwidth_gbs(p.total_threads(), nt) * 1e3 / mem_bytes;

    // --- synchronization efficiency: one barrier per block-plane step.
    let sites_between_barriers = (blocking.block_y * nx) as f64;
    let work_cycles = sites_between_barriers * cpl;
    let barrier_cycles = p.barrier.cycles(p.t, p.smt);
    let sync_eff = work_cycles / (work_cycles + barrier_cycles);

    Prediction::min3(compute, olc, mem, sync_eff)
}

/// Predicted performance of the multi-group spatial × temporal schemes
/// (`Scheme::JacobiMultiGroup` / `Scheme::GsMultiGroup`) — instead of
/// reusing the plain wavefront model, account the per-block
/// boundary-array traffic and the round-lag hand-off.
///
/// The decomposition is the scheme's own (`G` fixed y-blocks, one per
/// group), not the OLC-derived blocking: each group's rolling window
/// only needs its own block resident. On top of the wavefront memory
/// leg, the `G-1` interfaces move their boundary arrays through memory
/// twice per pass (written by one group, read by the next — they do not
/// share an OLC under scatter pinning), and the per-round neighbor
/// hand-off replaces the intra-group barrier. The boundary volume is
/// signature-dependent: the out-of-place Jacobi decomposition saves
/// `t/2` odd levels × `2R` x-lines per plane, the in-place GS one
/// (`in_place` signatures) saves `t-1` levels × `R` lines — and its
/// in-place updates already halve the main-stream write traffic via
/// [`TrafficSignature::mem_bytes_per_lup`].
///
/// [`TrafficSignature::mem_bytes_per_lup`]: crate::stencil::op::TrafficSignature::mem_bytes_per_lup
pub fn multigroup_prediction(
    m: &MachineSpec,
    p: &WavefrontParams,
    profile: &KernelProfile,
    size: (usize, usize, usize),
) -> Prediction {
    let (_nz, ny, nx) = size;
    let radius = profile.sig.radius;
    if p.groups <= 1 {
        return wavefront_prediction_for(m, p, profile, size);
    }
    let smt_per_core = if p.smt { m.smt_per_core } else { 1 };
    let physical_cores = p.groups.div_ceil(smt_per_core).min(m.cores);

    // --- compute / OLC rooflines: G workers, each sweeping its block at
    // the wavefront's in-hierarchy cost, each window in its cache share.
    let (compute, olc, cpl) = blocked_rooflines(m, profile, smt_per_core, physical_cores);

    // --- memory roofline: wavefront amortization + boundary arrays.
    // Per pass the boundary arrays move (G-1) · levels · lines · nz · nx
    // sites · 8 B, written once and read once; useful updates are
    // (nz·ny·nx)·t.
    let g = p.groups as f64;
    let (bnd_levels, bnd_lines) = if profile.sig.in_place {
        (p.t.saturating_sub(1) as f64, radius as f64)
    } else {
        (p.t as f64 / 2.0, (2 * radius) as f64)
    };
    let bnd_per_lup = 2.0 * 8.0 * (g - 1.0) * bnd_levels * bnd_lines / (ny as f64 * p.t as f64);
    let nt = matches!(p.store, StoreMode::NonTemporal) && !profile.sig.in_place;
    let mem_bytes = profile.sig.mem_bytes_per_lup(nt) / p.t as f64 + bnd_per_lup;
    let mem = m.memory_bandwidth_gbs(p.groups, nt) * 1e3 / mem_bytes;

    // --- synchronization: one neighbor watermark wait per round (the
    // round-lag hand-off), not a t-wide barrier; work per round is one
    // block-plane column of t levels.
    let block_y = (ny.saturating_sub(2 * radius) / p.groups.max(1)).max(1);
    let work_cycles = (block_y * nx * p.t) as f64 * cpl;
    let wait_cycles = p.barrier.cycles(2, p.smt);
    let sync_eff = work_cycles / (work_cycles + wait_cycles);

    Prediction::min3(compute, olc, mem, sync_eff)
}

/// Predicted performance of the diamond-tile temporal blocking scheme
/// (`Scheme::JacobiDiamond`): `G` shrinking A tiles plus `G-1` growing
/// B seam tiles exactly tile the y interior at every level, so — unlike
/// the multi-group decomposition — **no boundary arrays exist** and no
/// boundary bytes ever cross the memory interface.
///
/// Model structure, relative to [`multigroup_prediction`] at the same
/// `(op, t, groups)`:
///
/// * **team** — `2G - 1` workers (one per tile), not `G`; the compute
///   and OLC rooflines scale with the physical cores that team covers.
/// * **memory** — the plain `t`-amortized stream,
///   `mem_bytes_per_lup / t`, with *no* boundary term. This is strictly
///   below the multi-group per-LUP byte count for `G >= 2`, which is
///   the crossover the launcher's smoke bench records predicted vs
///   measured (see [`diamond_crossover`]).
/// * **synchronization** — per round each worker posts one watermark and
///   waits on *both* spatial neighbors (the shared-ring recycle makes
///   the dependency symmetric), so the hand-off is priced at two
///   pairwise waits per round against one tile-column of work. On small
///   tiles this can cost more than the multi-group's single wait — the
///   traffic win and the sync cost are exactly the trade the crossover
///   captures.
///
/// `groups <= 1` degenerates to the plain wavefront model (a single
/// unwaited tile is just a wavefront sweep).
pub fn diamond_prediction(
    m: &MachineSpec,
    p: &WavefrontParams,
    profile: &KernelProfile,
    size: (usize, usize, usize),
) -> Prediction {
    let (_nz, ny, nx) = size;
    if p.groups <= 1 {
        return wavefront_prediction_for(m, p, profile, size);
    }
    let radius = profile.sig.radius;
    let team = 2 * p.groups - 1;
    let smt_per_core = if p.smt { m.smt_per_core } else { 1 };
    let physical_cores = team.div_ceil(smt_per_core).min(m.cores);

    // --- compute / OLC rooflines: 2G-1 tile workers co-sweep one shared
    // window through the hierarchy at the wavefront's in-cache cost.
    let (compute, olc, cpl) = blocked_rooflines(m, profile, smt_per_core, physical_cores);

    // --- memory roofline: the t-amortized main stream and nothing else —
    // the exact tiling leaves no boundary-array stream to charge.
    let nt = matches!(p.store, StoreMode::NonTemporal) && !profile.sig.in_place;
    let mem_bytes = profile.sig.mem_bytes_per_lup(nt) / p.t as f64;
    let mem = m.memory_bandwidth_gbs(team, nt) * 1e3 / mem_bytes;

    // --- synchronization: two neighbor watermark waits per round; work
    // per round is one tile's share of the interior across t levels.
    let tile_y = (ny.saturating_sub(2 * radius) / team.max(1)).max(1);
    let work_cycles = (tile_y * nx * p.t) as f64 * cpl;
    let wait_cycles = 2.0 * p.barrier.cycles(2, p.smt);
    let sync_eff = work_cycles / (work_cycles + wait_cycles);

    Prediction::min3(compute, olc, mem, sync_eff)
}

/// The modeled diamond-vs-multigroup duel at one parameter point — the
/// autotuned crossover the launcher smoke bench records (predicted
/// winner next to the measured numbers).
#[derive(Clone, Copy, Debug)]
pub struct CrossoverChoice {
    /// [`diamond_prediction`] at these parameters, MLUP/s.
    pub diamond_mlups: f64,
    /// [`multigroup_prediction`] at the same parameters, MLUP/s.
    pub multigroup_mlups: f64,
}

impl CrossoverChoice {
    /// Whether the model picks the diamond scheme here.
    pub fn diamond_wins(&self) -> bool {
        self.diamond_mlups >= self.multigroup_mlups
    }

    /// The winning scheme's config-file name.
    pub fn winner_name(&self) -> &'static str {
        if self.diamond_wins() {
            "jacobi_diamond"
        } else {
            "jacobi_multigroup"
        }
    }
}

/// Evaluate the diamond-vs-multigroup crossover for one `(op, t, groups)`
/// point: both specialized predictions on the same profile and size.
pub fn diamond_crossover(
    m: &MachineSpec,
    p: &WavefrontParams,
    profile: &KernelProfile,
    size: (usize, usize, usize),
) -> CrossoverChoice {
    CrossoverChoice {
        diamond_mlups: diamond_prediction(m, p, profile, size).mlups,
        multigroup_mlups: multigroup_prediction(m, p, profile, size).mlups,
    }
}

/// Predicted performance of a z-sharded rank decomposition
/// ([`RankSet`](crate::coordinator::rank::RankSet)): the multigroup
/// model extended to `(ranks × groups × t)` with a halo-traffic leg.
///
/// `halo_depth` is the ghost-plane depth per interior interface side
/// (`rank_step · R` for the deep-halo Jacobi family, `R` for the
/// per-sweep GS exchange) and `rank_step` the sweeps one exchange round
/// amortizes over. Three effects on top of [`multigroup_prediction`]:
///
/// * **halo traffic** — per round each of the `ranks − 1` interfaces
///   moves `2 · depth` planes of `ny·nx` doubles, written by the sender
///   and read by the receiver; charged per useful LUP on the memory
///   leg. Note the deep-halo amortization exactly cancels the depth:
///   `depth/rank_step` is `R` per sweep either way — deep halos buy
///   *fewer messages* (latency), not fewer bytes.
/// * **redundant ghost compute** — the Jacobi family recomputes
///   `2·(ranks−1)·(depth − R)` ghost planes per block that are then
///   thrown away; the compute and OLC rooflines scale down by that
///   factor (zero for GS and the per-sweep baselines, whose ghosts are
///   only read).
/// * **exchange synchronization** — one watermark wait per round per
///   interface, composed with the inner model's sync efficiency.
///
/// `ranks <= 1` degenerates to `multigroup_prediction` exactly.
pub fn rank_prediction(
    m: &MachineSpec,
    p: &WavefrontParams,
    profile: &KernelProfile,
    size: (usize, usize, usize),
    ranks: usize,
    halo_depth: usize,
    rank_step: usize,
) -> Prediction {
    let inner = multigroup_prediction(m, p, profile, size);
    if ranks <= 1 {
        return inner;
    }
    let (nz, _ny, nx) = size;
    let radius = profile.sig.radius;
    let nz_int = nz.saturating_sub(2 * radius).max(1) as f64;
    let n = ranks as f64;

    // --- redundant ghost recomputation (deep halos only)
    let redundant_planes = 2.0 * (n - 1.0) * halo_depth.saturating_sub(radius) as f64;
    let rho = 1.0 + redundant_planes / nz_int;
    let compute = inner.compute_mlups / rho;
    let olc = inner.olc_mlups / rho;

    // --- memory roofline: recharge the inner per-LUP bytes (recovered
    // from the same bandwidth figure multigroup_prediction divides by)
    // with the redundancy factor plus the halo stream — each interface
    // moves 2·depth planes per round, written once and read once, over
    // nz_int planes of useful updates advancing rank_step sweeps
    let nt = matches!(p.store, StoreMode::NonTemporal) && !profile.sig.in_place;
    let bw_threads = if p.groups > 1 { p.groups } else { p.total_threads() };
    let bw = m.memory_bandwidth_gbs(bw_threads, nt) * 1e3;
    let halo_bytes_per_lup =
        2.0 * 2.0 * 8.0 * (n - 1.0) * halo_depth as f64 / (nz_int * rank_step as f64);
    let inner_bytes = bw / inner.mem_mlups;
    let mem = bw / (inner_bytes * rho + halo_bytes_per_lup);

    // --- synchronization: one watermark exchange (post + wait) per
    // round; work per round is one rank's share of rank_step sweeps
    let planes_per_rank = (nz_int / n).max(1.0);
    let round_lups = planes_per_rank * size.1 as f64 * nx as f64 * rank_step as f64;
    let work_cycles = round_lups * m.clock_ghz * 1e3 / inner.compute_mlups.max(1e-9);
    let wait_cycles = 2.0 * p.barrier.cycles(2, p.smt);
    let sync_eff = inner.sync_efficiency * work_cycles / (work_cycles + wait_cycles);

    Prediction::min3(compute, olc, mem, sync_eff)
}

/// Baseline threaded prediction at the paper's 200³ reference size.
pub fn baseline_threaded(m: &MachineSpec, kernel: Kernel, store: StoreMode) -> Prediction {
    let ecm = EcmModel::new(m.clone());
    ecm.socket(kernel, memory::Dataset::Memory, store, m.cores, false)
}

/// Speedup of the wavefront configuration over the threaded baseline.
pub fn wavefront_speedup(
    m: &MachineSpec,
    p: &WavefrontParams,
    problem: (usize, usize, usize),
) -> f64 {
    let base_store = if p.kernel.is_gs() { StoreMode::WriteAllocate } else { StoreMode::NonTemporal };
    let base = baseline_threaded(m, p.kernel, base_store).mlups;
    wavefront_prediction(m, p, problem).mlups / base
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIZE: (usize, usize, usize) = (200, 200, 200);

    #[test]
    fn eq1_matches_paper_arithmetic() {
        let ep = MachineSpec::nehalem_ep();
        // 18.5 GB/s / 16 B = 1156 MLUP/s
        assert!((eq1_limit_mlups(&ep, Kernel::JacobiOpt) - 1156.25).abs() < 0.1);
        // GS uses the noNT figure: 23.7 / 16 = 1481
        assert!((eq1_limit_mlups(&ep, Kernel::GsOpt) - 1481.25).abs() < 0.1);
    }

    #[test]
    fn pthread_barrier_is_unusable_spin_wins_tree_wins_smt() {
        // Sec. 4's synchronization findings.
        for t in [2usize, 4, 6, 8] {
            let pthread = BarrierKind::Pthread.cycles(t, false);
            let spin = BarrierKind::Spin.cycles(t, false);
            let tree = BarrierKind::Tree.cycles(t, false);
            assert!(spin < pthread && tree < pthread);
            assert!(spin <= tree * 6.0);
        }
        // with SMT the tree barrier must beat the spin barrier
        for t in [4usize, 8, 12, 16] {
            assert!(
                BarrierKind::Tree.cycles(t, true) < BarrierKind::Spin.cycles(t, true),
                "t={t}"
            );
        }
    }

    #[test]
    fn blocking_respects_olc_capacity() {
        for m in MachineSpec::testbed() {
            let t = m.max_blocking_factor(false);
            let b = choose_blocking(&m, t, 1, 200, 200);
            assert!(b.block_y >= 1);
            assert!(b.working_set_bytes <= m.olc_bytes());
            assert_eq!(b.blocks, 200usize.div_ceil(b.block_y));
        }
    }

    #[test]
    fn radius2_blocking_needs_more_cache_per_line() {
        let m = MachineSpec::nehalem_ep();
        let b1 = choose_blocking_r(&m, 4, 1, 200, 200, 1);
        let b2 = choose_blocking_r(&m, 4, 1, 200, 200, 2);
        assert!(b2.block_y <= b1.block_y, "wider halo cannot allow taller blocks");
        assert!(b2.working_set_bytes <= m.olc_bytes());
        // the legacy entry point is the r = 1 case
        let legacy = choose_blocking(&m, 4, 1, 200, 200);
        assert_eq!(legacy.block_y, b1.block_y);
    }

    #[test]
    fn multigroup_prediction_accounts_boundary_traffic() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let profile = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, m.arch);
        let base = WavefrontParams {
            t: 4,
            groups: 1,
            smt: false,
            kernel: Kernel::JacobiOpt,
            store: StoreMode::NonTemporal,
            barrier: BarrierKind::Spin,
        };
        let single = multigroup_prediction(&m, &base, &profile, SIZE);
        // groups = 1 degenerates to the plain wavefront model
        assert_eq!(single.mlups, wavefront_prediction_for(&m, &base, &profile, SIZE).mlups);
        let multi = WavefrontParams { groups: 4, ..base };
        let p4 = multigroup_prediction(&m, &multi, &profile, SIZE);
        assert!(p4.mlups.is_finite() && p4.mlups > 0.0);
        // boundary arrays strictly lower the memory roofline vs the
        // boundary-free wavefront memory leg at the same thread count
        let wf4 = wavefront_prediction_for(&m, &multi, &profile, SIZE);
        assert!(p4.mem_mlups < wf4.mem_mlups * 1.001, "{} vs {}", p4.mem_mlups, wf4.mem_mlups);
        // more interfaces → more boundary traffic → lower memory roofline
        let p8 = multigroup_prediction(
            &m,
            &WavefrontParams { groups: 8, ..base },
            &profile,
            SIZE,
        );
        assert!(p8.mem_mlups < p4.mem_mlups);
    }

    #[test]
    fn diamond_prediction_drops_the_boundary_stream() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let base = WavefrontParams {
            t: 4,
            groups: 1,
            smt: false,
            kernel: Kernel::JacobiOpt,
            store: StoreMode::NonTemporal,
            barrier: BarrierKind::Spin,
        };
        for op in OpKind::ALL {
            let profile = KernelProfile::of_op(op, false, true, m.arch);
            // groups = 1 degenerates to the plain wavefront model
            assert_eq!(
                diamond_prediction(&m, &base, &profile, SIZE).mlups,
                wavefront_prediction_for(&m, &base, &profile, SIZE).mlups,
                "{op:?}"
            );
            for g in [2usize, 4, 8] {
                let p = WavefrontParams { groups: g, ..base };
                let dia = diamond_prediction(&m, &p, &profile, SIZE);
                let mg = multigroup_prediction(&m, &p, &profile, SIZE);
                assert!(dia.mlups.is_finite() && dia.mlups > 0.0, "{op:?} g={g}");
                // the acceptance bound: the diamond memory leg charges
                // strictly fewer bytes per LUP than the multi-group leg
                // at the same (op, t, groups) — no boundary arrays, and
                // its 2G-1 team never sees less bandwidth than G threads
                assert!(
                    dia.mem_mlups > mg.mem_mlups,
                    "{op:?} g={g}: diamond mem {} !> multigroup mem {}",
                    dia.mem_mlups,
                    mg.mem_mlups
                );
            }
        }
        // the whole testbed yields finite positive diamond predictions
        for machine in MachineSpec::testbed() {
            let prof = KernelProfile::of_op(OpKind::Laplace13, false, true, machine.arch);
            let p = WavefrontParams { groups: 3, ..base };
            let pred = diamond_prediction(&machine, &p, &prof, SIZE);
            assert!(pred.mlups.is_finite() && pred.mlups > 0.0, "{}", machine.name);
        }
    }

    #[test]
    fn diamond_crossover_reports_both_legs() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let profile = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, m.arch);
        let p = WavefrontParams {
            t: 4,
            groups: 4,
            smt: false,
            kernel: Kernel::JacobiOpt,
            store: StoreMode::NonTemporal,
            barrier: BarrierKind::Spin,
        };
        let c = diamond_crossover(&m, &p, &profile, SIZE);
        assert_eq!(c.diamond_mlups, diamond_prediction(&m, &p, &profile, SIZE).mlups);
        assert_eq!(c.multigroup_mlups, multigroup_prediction(&m, &p, &profile, SIZE).mlups);
        assert_eq!(c.diamond_wins(), c.diamond_mlups >= c.multigroup_mlups);
        let name = c.winner_name();
        assert!(name == "jacobi_diamond" || name == "jacobi_multigroup");
        // the winner must actually be the larger modeled number
        if c.diamond_wins() {
            assert!(c.diamond_mlups >= c.multigroup_mlups);
        } else {
            assert!(c.multigroup_mlups > c.diamond_mlups);
        }
    }

    #[test]
    fn gs_multigroup_boundary_traffic_uses_the_inplace_signature() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let gs = KernelProfile::of_op(OpKind::ConstLaplace7, true, true, m.arch);
        assert!(gs.sig.in_place);
        let base = WavefrontParams {
            t: 4,
            groups: 4,
            smt: false,
            kernel: Kernel::GsOpt,
            store: StoreMode::WriteAllocate,
            barrier: BarrierKind::Spin,
        };
        // groups = 1 degenerates to the plain wavefront model
        let single = WavefrontParams { groups: 1, ..base };
        assert_eq!(
            multigroup_prediction(&m, &single, &gs, SIZE).mlups,
            wavefront_prediction_for(&m, &single, &gs, SIZE).mlups
        );
        // more interfaces -> more R-line boundary traffic
        let p4 = multigroup_prediction(&m, &base, &gs, SIZE);
        let p8 = multigroup_prediction(&m, &WavefrontParams { groups: 8, ..base }, &gs, SIZE);
        assert!(p4.mlups.is_finite() && p4.mlups > 0.0);
        assert!(p8.mem_mlups < p4.mem_mlups);
        // t = 1 saves no levels at all: the boundary term vanishes and
        // the memory leg matches the boundary-free accounting exactly
        let t1 = WavefrontParams { t: 1, ..base };
        let no_bnd = m.memory_bandwidth_gbs(t1.groups, false) * 1e3
            / gs.sig.mem_bytes_per_lup(false);
        assert_eq!(multigroup_prediction(&m, &t1, &gs, SIZE).mem_mlups, no_bnd);
        // the in-place hand-off ((t-1) x R lines at t = 4) moves fewer
        // boundary bytes than the Jacobi one (t/2 x 2R), and GS gets the
        // no-NT STREAM figure — so the GS memory roofline must sit
        // strictly above the Jacobi decomposition's at the same
        // parameters (a swapped signature branch flips this)
        let jac = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, m.arch);
        let jac_p4 = multigroup_prediction(
            &m,
            &WavefrontParams { store: StoreMode::NonTemporal, kernel: Kernel::JacobiOpt, ..base },
            &jac,
            SIZE,
        );
        assert!(
            p4.mem_mlups > jac_p4.mem_mlups,
            "GS {} !> Jacobi {}",
            p4.mem_mlups,
            jac_p4.mem_mlups
        );
    }

    #[test]
    fn rank_prediction_degenerates_and_charges_halo_traffic() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let profile = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, m.arch);
        let p = WavefrontParams {
            t: 4,
            groups: 2,
            smt: false,
            kernel: Kernel::JacobiOpt,
            store: StoreMode::NonTemporal,
            barrier: BarrierKind::Spin,
        };
        // ranks = 1 is exactly the multigroup model, every leg
        let one = rank_prediction(&m, &p, &profile, SIZE, 1, 4, 4);
        let inner = multigroup_prediction(&m, &p, &profile, SIZE);
        assert_eq!(one.mlups, inner.mlups);
        assert_eq!(one.mem_mlups, inner.mem_mlups);
        // more interfaces -> more halo bytes + more redundant ghost
        // compute -> every leg monotonically non-increasing in ranks
        let r2 = rank_prediction(&m, &p, &profile, SIZE, 2, 4, 4);
        let r4 = rank_prediction(&m, &p, &profile, SIZE, 4, 4, 4);
        assert!(r2.mlups.is_finite() && r2.mlups > 0.0);
        assert!(r4.mem_mlups < r2.mem_mlups && r2.mem_mlups < inner.mem_mlups);
        assert!(r4.compute_mlups < r2.compute_mlups && r2.compute_mlups < inner.compute_mlups);
    }

    #[test]
    fn deep_halos_cost_redundant_compute_not_extra_bytes() {
        use crate::stencil::op::OpKind;
        let m = MachineSpec::nehalem_ep();
        let jac = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, m.arch);
        let p = WavefrontParams {
            t: 4,
            groups: 2,
            smt: false,
            kernel: Kernel::JacobiOpt,
            store: StoreMode::NonTemporal,
            barrier: BarrierKind::Spin,
        };
        // a per-sweep R-deep exchange (step 1) and a 4-sweep 4R-deep
        // block move the same halo bytes per LUP: the amortization
        // cancels the depth...
        let deep = rank_prediction(&m, &p, &jac, SIZE, 4, 4, 4);
        let shallow = rank_prediction(&m, &p, &jac, SIZE, 4, 1, 1);
        // ...but only the deep variant recomputes ghosts, so its
        // compute/OLC rooflines sit strictly lower
        assert!(deep.compute_mlups < shallow.compute_mlups);
        assert!(deep.olc_mlups < shallow.olc_mlups);
        // GS at radius depth (depth == R): redundancy factor is exactly
        // 1, the compute leg matches the inner model untouched
        let gs = KernelProfile::of_op(OpKind::ConstLaplace7, true, true, m.arch);
        let pg = WavefrontParams { kernel: Kernel::GsOpt, store: StoreMode::WriteAllocate, ..p };
        let inner = multigroup_prediction(&m, &pg, &gs, SIZE);
        let ranked = rank_prediction(&m, &pg, &gs, SIZE, 4, 1, 1);
        assert_eq!(ranked.compute_mlups, inner.compute_mlups);
        assert!(ranked.mem_mlups < inner.mem_mlups, "halo bytes still charged");
        // and the whole testbed yields finite positive rank predictions
        for machine in MachineSpec::testbed() {
            let prof = KernelProfile::of_op(OpKind::Laplace13, false, true, machine.arch);
            let pred = rank_prediction(&machine, &p, &prof, SIZE, 3, 8, 4);
            assert!(pred.mlups.is_finite() && pred.mlups > 0.0, "{}", machine.name);
        }
    }

    #[test]
    fn jacobi_wavefront_speedups_match_fig8_shape() {
        // Fig. 8 prose: Core 2 ≈ 2×; Nehalem EP 1.25–1.5×; Nehalem EX ≈ 4×;
        // Istanbul comparable to Nehalem EP despite its bigger gap.
        let check = |m: MachineSpec, lo: f64, hi: f64| {
            let p = WavefrontParams::standard(&m, Kernel::JacobiOpt, false);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: speedup {s} ∉ [{lo},{hi}]", m.name);
        };
        check(MachineSpec::core2_harpertown(), 1.6, 2.6);
        check(MachineSpec::nehalem_ep(), 1.1, 1.7);
        check(MachineSpec::westmere(), 1.2, 2.0);
        check(MachineSpec::nehalem_ex(), 3.0, 5.0);
        check(MachineSpec::istanbul(), 1.0, 2.0);
    }

    #[test]
    fn gs_wavefront_speedups_match_fig9_shape() {
        // Fig. 9 prose: Core 2 ≈ 2×; EP 1.3–1.4×; Westmere > 1.5×; EX 3.8×.
        let check = |m: MachineSpec, lo: f64, hi: f64| {
            let p = WavefrontParams::standard(&m, Kernel::GsOpt, false);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: speedup {s} ∉ [{lo},{hi}]", m.name);
        };
        check(MachineSpec::core2_harpertown(), 1.5, 2.5);
        check(MachineSpec::nehalem_ep(), 1.1, 1.8);
        check(MachineSpec::westmere(), 1.3, 2.2);
        check(MachineSpec::nehalem_ex(), 2.8, 4.8);
        check(MachineSpec::istanbul(), 1.0, 2.2);
    }

    #[test]
    fn smt_lifts_gs_wavefront_to_fig10_levels() {
        // Fig. 10 prose: EP and Westmere reach ≈ 2.5× their threaded
        // baseline; EX reaches up to 5×; EP/Westmere/EX end up comparable.
        for (m, lo, hi) in [
            (MachineSpec::nehalem_ep(), 2.0, 3.2),
            (MachineSpec::westmere(), 1.8, 3.2),
            (MachineSpec::nehalem_ex(), 3.5, 5.5),
        ] {
            let p = WavefrontParams::standard(&m, Kernel::GsOpt, true);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: SMT speedup {s} ∉ [{lo},{hi}]", m.name);
        }
        // absolute performance plateau: EP ≈ Westmere ≈ EX within 35%
        let perf: Vec<f64> = [MachineSpec::nehalem_ep(), MachineSpec::westmere(), MachineSpec::nehalem_ex()]
            .into_iter()
            .map(|m| {
                let p = WavefrontParams::standard(&m, Kernel::GsOpt, true);
                wavefront_prediction(&m, &p, SIZE).mlups
            })
            .collect();
        let max = perf.iter().cloned().fold(0.0, f64::max);
        let min = perf.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.6, "plateau spread too wide: {perf:?}");
    }

    #[test]
    fn smt_gain_small_on_ex_for_gs() {
        // Paper: "The SMT benefit on Nehalem EX is not that large" —
        // it is already arithmetically limited.
        let ex = MachineSpec::nehalem_ex();
        let p_no = WavefrontParams::standard(&ex, Kernel::GsOpt, false);
        let p_smt = WavefrontParams::standard(&ex, Kernel::GsOpt, true);
        let gain = wavefront_prediction(&ex, &p_smt, SIZE).mlups
            / wavefront_prediction(&ex, &p_no, SIZE).mlups;
        let ep = MachineSpec::nehalem_ep();
        let e_no = WavefrontParams::standard(&ep, Kernel::GsOpt, false);
        let e_smt = WavefrontParams::standard(&ep, Kernel::GsOpt, true);
        let gain_ep = wavefront_prediction(&ep, &e_smt, SIZE).mlups
            / wavefront_prediction(&ep, &e_no, SIZE).mlups;
        assert!(gain < gain_ep, "EX SMT gain {gain} !< EP {gain_ep}");
    }
}

#[cfg(test)]
mod diag {
    use super::*;

    #[test]
    #[ignore]
    fn print_components() {
        const SIZE: (usize, usize, usize) = (200, 200, 200);
        for m in MachineSpec::testbed() {
            for (kernel, smt) in [
                (Kernel::JacobiOpt, false),
                (Kernel::GsOpt, false),
                (Kernel::GsOpt, true),
            ] {
                if smt && m.smt_per_core < 2 { continue; }
                let p = WavefrontParams::standard(&m, kernel, smt);
                let pred = wavefront_prediction(&m, &p, SIZE);
                let store = if kernel.is_gs() { StoreMode::WriteAllocate } else { StoreMode::NonTemporal };
                let base = baseline_threaded(&m, kernel, store);
                println!(
                    "{:<11} {:?} smt={} t={} | wf: {:.0} (c {:.0} olc {:.0} mem {:.0} sync {:.2}) | base {:.0} (c {:.0} olc {:.0} mem {:.0}) | speedup {:.2}",
                    m.name, kernel, smt, p.t,
                    pred.mlups, pred.compute_mlups, pred.olc_mlups, pred.mem_mlups, pred.sync_efficiency,
                    base.mlups, base.compute_mlups, base.olc_mlups, base.mem_mlups,
                    pred.mlups / base.mlups
                );
            }
        }
    }
}
