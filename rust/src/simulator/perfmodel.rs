//! Composite performance predictors: Eq. (1) and the wavefront model.
//!
//! [`eq1_limit_mlups`] is the paper's Eq. (1): `P0 = M_S / 16 B` with the
//! appropriate STREAM figure. [`wavefront_prediction`] combines the ECM
//! kernel model, the traffic accounting, the OLC capacity constraint that
//! drives spatial blocking, and the barrier cost model into the curves of
//! Figs. 8–10.


use super::ecm::{EcmModel, Kernel, KernelClass, Prediction};
use super::machine::MachineSpec;
use super::memory::{self, StoreMode};

/// Paper Eq. (1): the bandwidth ceiling in MLUP/s.
///
/// Jacobi uses the NT-store STREAM figure over 16 B/LUP; Gauss-Seidel the
/// no-NT figure (Sec. 3: "we therefore use the STREAM triad measurements
/// without non-temporal stores in the performance model for Gauss-Seidel").
pub fn eq1_limit_mlups(m: &MachineSpec, kernel: Kernel) -> f64 {
    let ms = if kernel.is_gs() { m.stream_socket_nont_gbs } else { m.stream_socket_nt_gbs };
    ms * 1e3 / 16.0
}

/// Synchronization primitive (Sec. 4: pthread barriers are unusable for
/// fine-grained parallelism; spin barriers win for physical cores; tree
/// barriers win as soon as SMT threads share cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BarrierKind {
    /// POSIX `pthread_barrier_t` (kernel futex round trip).
    Pthread,
    /// Busy-wait on a shared counter.
    #[default]
    Spin,
    /// Pairwise tree of flags — O(log t) depth, SMT-friendly.
    Tree,
}

impl BarrierKind {
    /// Modeled cost in core cycles for `threads` participants.
    ///
    /// Calibration: a futex barrier costs O(µs) (~5000 cy); a spin barrier
    /// ~100 cy per participant of coherence traffic, but spinning SMT
    /// siblings steal pipeline slots from the worker thread (3× penalty);
    /// a tree barrier pays ~150 cy per level of its log₂ depth.
    pub fn cycles(self, threads: usize, smt: bool) -> f64 {
        let t = threads.max(1) as f64;
        match self {
            BarrierKind::Pthread => 5000.0 + 400.0 * t,
            BarrierKind::Spin => {
                let base = 120.0 * t;
                if smt {
                    3.0 * base
                } else {
                    base
                }
            }
            BarrierKind::Tree => 150.0 * (t.log2().ceil().max(1.0)) * if smt { 1.3 } else { 1.0 },
        }
    }
}

/// Configuration of a wavefront run (Sec. 4 parameters).
#[derive(Clone, Copy, Debug)]
pub struct WavefrontParams {
    /// Threads per thread group = temporal blocking factor `t`.
    pub t: usize,
    /// Number of thread groups `N`.
    pub groups: usize,
    /// Use SMT hardware threads (two logical threads per core).
    pub smt: bool,
    /// Kernel the sweeps run.
    pub kernel: Kernel,
    /// Store flavour of the final sweep (Jacobi only).
    pub store: StoreMode,
    /// Synchronization primitive.
    pub barrier: BarrierKind,
}

impl WavefrontParams {
    /// The paper's standard configuration for a machine: one thread group
    /// spanning the cache group, blocking factor = threads available.
    pub fn standard(m: &MachineSpec, kernel: Kernel, smt: bool) -> Self {
        Self {
            t: m.max_blocking_factor(smt),
            groups: m.cores / m.cache_group_cores(),
            smt,
            kernel,
            store: StoreMode::NonTemporal,
            barrier: if smt { BarrierKind::Tree } else { BarrierKind::Spin },
        }
    }

    /// Logical threads this configuration occupies.
    pub fn total_threads(&self) -> usize {
        self.t * self.groups
    }
}

/// Spatial blocking derived from the OLC capacity constraint (Sec. 4:
/// "block sizes must be chosen so that the temporary data can be kept in
/// the outermost cache level").
#[derive(Clone, Copy, Debug)]
pub struct Blocking {
    /// Lines of y per block.
    pub block_y: usize,
    /// Number of blocks B along y.
    pub blocks: usize,
    /// Working-set bytes per thread group at this blocking.
    pub working_set_bytes: usize,
}

/// Choose the y block size for a problem `(nz, ny, nx)`.
///
/// The rolling window holds `2t + 2` planes of `block_y × nx` doubles per
/// thread group (t temporary planes + t source planes + halo); all groups
/// share the OLC, of which a utilization fraction is realistically usable.
pub fn choose_blocking(m: &MachineSpec, t: usize, groups: usize, ny: usize, nx: usize) -> Blocking {
    const UTILIZATION: f64 = 0.5;
    let cap = (m.olc_bytes() as f64 * UTILIZATION / groups.max(1) as f64) as usize;
    let bytes_per_line = (2 * t + 2) * nx * 8;
    let block_y = (cap / bytes_per_line).clamp(1, ny);
    let blocks = ny.div_ceil(block_y);
    Blocking { block_y, blocks, working_set_bytes: bytes_per_line * block_y }
}

/// Predicted wavefront performance for one problem size (Figs. 8–10).
pub fn wavefront_prediction(
    m: &MachineSpec,
    p: &WavefrontParams,
    (_nz, ny, nx): (usize, usize, usize),
) -> Prediction {
    let ecm = EcmModel::new(m.clone());
    let smt_per_core = if p.smt { m.smt_per_core } else { 1 };
    let physical_cores = p.total_threads().div_ceil(smt_per_core).min(m.cores);
    let blocking = choose_blocking(m, p.t, p.groups, ny, nx);

    // --- compute roofline: all t threads of each group do useful sweeps.
    let class = KernelClass::of(p.kernel, m.arch);
    let t_core = class.effective_cpl(smt_per_core);
    // in-hierarchy transfers now go through the *shared* cache each step
    let vol = memory::wavefront_olc_bytes_per_lup(p.kernel.is_gs(), m.exclusive);
    let transfer = super::ecm::TransferModel::of(m);
    let t_data = vol / transfer.l1l2_bpc + vol / transfer.l2olc_bpc * (m.clock_ghz / m.uncore_ghz);
    let cpl = t_core + t_data;
    let compute = physical_cores as f64 * m.clock_ghz * 1e3 / cpl;

    // --- OLC bandwidth roofline: every intermediate update is an OLC
    // round trip for all groups sharing it.
    let olc = m.olc_bandwidth_gbs(physical_cores) * 1e3 / vol;

    // --- memory roofline: 1/t of the baseline traffic + boundary arrays.
    let boundary_overhead = if blocking.blocks > 1 {
        // (B-1) interfaces × t planes × nz·nx sites × 16 B round trip per
        // pass, relative to nz·ny·nx·t useful updates.
        16.0 * (blocking.blocks as f64 - 1.0) / ny as f64 / 16.0
    } else {
        0.0
    };
    let mem_bytes = if p.kernel.is_gs() {
        memory::gs_mem_bytes_per_lup() / p.t as f64 * (1.0 + boundary_overhead)
    } else {
        memory::wavefront_mem_bytes_per_lup(p.t, p.store, boundary_overhead)
    };
    let nt = matches!(p.store, StoreMode::NonTemporal) && !p.kernel.is_gs();
    let mem = m.memory_bandwidth_gbs(p.total_threads(), nt) * 1e3 / mem_bytes;

    // --- synchronization efficiency: one barrier per block-plane step.
    let sites_between_barriers = (blocking.block_y * nx) as f64;
    let work_cycles = sites_between_barriers * cpl;
    let barrier_cycles = p.barrier.cycles(p.t, p.smt);
    let sync_eff = work_cycles / (work_cycles + barrier_cycles);

    let pred = Prediction::min3(compute, olc, mem, sync_eff);
    let _ = ecm; // EcmModel retained for API symmetry / future terms
    pred
}

/// Baseline threaded prediction at the paper's 200³ reference size.
pub fn baseline_threaded(m: &MachineSpec, kernel: Kernel, store: StoreMode) -> Prediction {
    let ecm = EcmModel::new(m.clone());
    ecm.socket(kernel, memory::Dataset::Memory, store, m.cores, false)
}

/// Speedup of the wavefront configuration over the threaded baseline.
pub fn wavefront_speedup(
    m: &MachineSpec,
    p: &WavefrontParams,
    problem: (usize, usize, usize),
) -> f64 {
    let base_store = if p.kernel.is_gs() { StoreMode::WriteAllocate } else { StoreMode::NonTemporal };
    let base = baseline_threaded(m, p.kernel, base_store).mlups;
    wavefront_prediction(m, p, problem).mlups / base
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIZE: (usize, usize, usize) = (200, 200, 200);

    #[test]
    fn eq1_matches_paper_arithmetic() {
        let ep = MachineSpec::nehalem_ep();
        // 18.5 GB/s / 16 B = 1156 MLUP/s
        assert!((eq1_limit_mlups(&ep, Kernel::JacobiOpt) - 1156.25).abs() < 0.1);
        // GS uses the noNT figure: 23.7 / 16 = 1481
        assert!((eq1_limit_mlups(&ep, Kernel::GsOpt) - 1481.25).abs() < 0.1);
    }

    #[test]
    fn pthread_barrier_is_unusable_spin_wins_tree_wins_smt() {
        // Sec. 4's synchronization findings.
        for t in [2usize, 4, 6, 8] {
            let pthread = BarrierKind::Pthread.cycles(t, false);
            let spin = BarrierKind::Spin.cycles(t, false);
            let tree = BarrierKind::Tree.cycles(t, false);
            assert!(spin < pthread && tree < pthread);
            assert!(spin <= tree * 6.0);
        }
        // with SMT the tree barrier must beat the spin barrier
        for t in [4usize, 8, 12, 16] {
            assert!(
                BarrierKind::Tree.cycles(t, true) < BarrierKind::Spin.cycles(t, true),
                "t={t}"
            );
        }
    }

    #[test]
    fn blocking_respects_olc_capacity() {
        for m in MachineSpec::testbed() {
            let t = m.max_blocking_factor(false);
            let b = choose_blocking(&m, t, 1, 200, 200);
            assert!(b.block_y >= 1);
            assert!(b.working_set_bytes <= m.olc_bytes());
            assert_eq!(b.blocks, 200usize.div_ceil(b.block_y));
        }
    }

    #[test]
    fn jacobi_wavefront_speedups_match_fig8_shape() {
        // Fig. 8 prose: Core 2 ≈ 2×; Nehalem EP 1.25–1.5×; Nehalem EX ≈ 4×;
        // Istanbul comparable to Nehalem EP despite its bigger gap.
        let check = |m: MachineSpec, lo: f64, hi: f64| {
            let p = WavefrontParams::standard(&m, Kernel::JacobiOpt, false);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: speedup {s} ∉ [{lo},{hi}]", m.name);
        };
        check(MachineSpec::core2_harpertown(), 1.6, 2.6);
        check(MachineSpec::nehalem_ep(), 1.1, 1.7);
        check(MachineSpec::westmere(), 1.2, 2.0);
        check(MachineSpec::nehalem_ex(), 3.0, 5.0);
        check(MachineSpec::istanbul(), 1.0, 2.0);
    }

    #[test]
    fn gs_wavefront_speedups_match_fig9_shape() {
        // Fig. 9 prose: Core 2 ≈ 2×; EP 1.3–1.4×; Westmere > 1.5×; EX 3.8×.
        let check = |m: MachineSpec, lo: f64, hi: f64| {
            let p = WavefrontParams::standard(&m, Kernel::GsOpt, false);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: speedup {s} ∉ [{lo},{hi}]", m.name);
        };
        check(MachineSpec::core2_harpertown(), 1.5, 2.5);
        check(MachineSpec::nehalem_ep(), 1.1, 1.8);
        check(MachineSpec::westmere(), 1.3, 2.2);
        check(MachineSpec::nehalem_ex(), 2.8, 4.8);
        check(MachineSpec::istanbul(), 1.0, 2.2);
    }

    #[test]
    fn smt_lifts_gs_wavefront_to_fig10_levels() {
        // Fig. 10 prose: EP and Westmere reach ≈ 2.5× their threaded
        // baseline; EX reaches up to 5×; EP/Westmere/EX end up comparable.
        for (m, lo, hi) in [
            (MachineSpec::nehalem_ep(), 2.0, 3.2),
            (MachineSpec::westmere(), 1.8, 3.2),
            (MachineSpec::nehalem_ex(), 3.5, 5.5),
        ] {
            let p = WavefrontParams::standard(&m, Kernel::GsOpt, true);
            let s = wavefront_speedup(&m, &p, SIZE);
            assert!(s >= lo && s <= hi, "{}: SMT speedup {s} ∉ [{lo},{hi}]", m.name);
        }
        // absolute performance plateau: EP ≈ Westmere ≈ EX within 35%
        let perf: Vec<f64> = [MachineSpec::nehalem_ep(), MachineSpec::westmere(), MachineSpec::nehalem_ex()]
            .into_iter()
            .map(|m| {
                let p = WavefrontParams::standard(&m, Kernel::GsOpt, true);
                wavefront_prediction(&m, &p, SIZE).mlups
            })
            .collect();
        let max = perf.iter().cloned().fold(0.0, f64::max);
        let min = perf.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.6, "plateau spread too wide: {perf:?}");
    }

    #[test]
    fn smt_gain_small_on_ex_for_gs() {
        // Paper: "The SMT benefit on Nehalem EX is not that large" —
        // it is already arithmetically limited.
        let ex = MachineSpec::nehalem_ex();
        let p_no = WavefrontParams::standard(&ex, Kernel::GsOpt, false);
        let p_smt = WavefrontParams::standard(&ex, Kernel::GsOpt, true);
        let gain = wavefront_prediction(&ex, &p_smt, SIZE).mlups
            / wavefront_prediction(&ex, &p_no, SIZE).mlups;
        let ep = MachineSpec::nehalem_ep();
        let e_no = WavefrontParams::standard(&ep, Kernel::GsOpt, false);
        let e_smt = WavefrontParams::standard(&ep, Kernel::GsOpt, true);
        let gain_ep = wavefront_prediction(&ep, &e_smt, SIZE).mlups
            / wavefront_prediction(&ep, &e_no, SIZE).mlups;
        assert!(gain < gain_ep, "EX SMT gain {gain} !< EP {gain_ep}");
    }
}

#[cfg(test)]
mod diag {
    use super::*;

    #[test]
    #[ignore]
    fn print_components() {
        const SIZE: (usize, usize, usize) = (200, 200, 200);
        for m in MachineSpec::testbed() {
            for (kernel, smt) in [
                (Kernel::JacobiOpt, false),
                (Kernel::GsOpt, false),
                (Kernel::GsOpt, true),
            ] {
                if smt && m.smt_per_core < 2 { continue; }
                let p = WavefrontParams::standard(&m, kernel, smt);
                let pred = wavefront_prediction(&m, &p, SIZE);
                let store = if kernel.is_gs() { StoreMode::WriteAllocate } else { StoreMode::NonTemporal };
                let base = baseline_threaded(&m, kernel, store);
                println!(
                    "{:<11} {:?} smt={} t={} | wf: {:.0} (c {:.0} olc {:.0} mem {:.0} sync {:.2}) | base {:.0} (c {:.0} olc {:.0} mem {:.0}) | speedup {:.2}",
                    m.name, kernel, smt, p.t,
                    pred.mlups, pred.compute_mlups, pred.olc_mlups, pred.mem_mlups, pred.sync_efficiency,
                    base.mlups, base.compute_mlups, base.olc_mlups, base.mem_mlups,
                    pred.mlups / base.mlups
                );
            }
        }
    }
}
