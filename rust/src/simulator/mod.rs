//! The hardware testbed substrate (paper Sec. 2, Tab. 1).
//!
//! The paper's evaluation runs on five 2008–2010 x86 sockets. This box is
//! a single-core sandbox, so — per the reproduction's substitution rule —
//! the testbed is rebuilt as a simulator with three cooperating parts:
//!
//! * [`machine`] — parameterized machine descriptions carrying every
//!   Tab. 1 quantity (clock, cores, SMT, cache topology, STREAM
//!   bandwidths) for Harpertown, Nehalem EP, Westmere, Nehalem EX and
//!   Istanbul.
//! * [`ecm`] — an Execution-Cache-Memory analytic performance model (after
//!   ref. [14] of the paper, by the same authors): per-cacheline in-core
//!   cycles plus per-level transfer cycles, with the Intel no-overlap rule,
//!   the Istanbul exclusive-cache penalty and the SMT bubble-filling model.
//!   This is what regenerates every figure.
//! * [`cache`] + [`trace`] — a set-associative LRU cache hierarchy
//!   simulator driven by exact cacheline traces of the schedules, used to
//!   *verify* the residency claims behind the wavefront scheme
//!   ("intermediate planes never leave the shared cache") and to
//!   cross-check the traffic terms the ECM model assumes.
//!
//! [`stream`] models the STREAM triad rows of Tab. 1; [`perfmodel`] holds
//! Eq. (1) and the composite predictors used by the figure generators.

pub mod cache;
pub mod ecm;
pub mod machine;
pub mod memory;
pub mod perfmodel;
pub mod stream;
pub mod trace;

/// Cacheline size shared by every paper machine (Tab. 1 caption).
pub const CACHELINE_BYTES: usize = 64;
/// Doubles per cacheline.
pub const DOUBLES_PER_CL: usize = CACHELINE_BYTES / 8;
