//! ECM-style analytic performance model (paper ref. [14], same authors).
//!
//! The Execution-Cache-Memory model predicts loop-kernel performance from
//! (a) in-core execution cycles and (b) cacheline transfer cycles through
//! the memory hierarchy, with no overlap between transfer phases on Intel
//! cores. It is the model the paper itself uses to explain every figure,
//! which makes it the right substitute for the missing hardware: all its
//! inputs come from Tab. 1 plus a small, documented calibration table of
//! in-core cycle counts.
//!
//! ## Kernel classes
//!
//! The four baseline kernels of Figs. 3/4 — Jacobi and Gauss-Seidel, each
//! as straightforward C and as the optimized kernel — are characterized by
//! two in-core numbers (cycles per LUP):
//!
//! * `lat_cpl` — the dependency-bound (latency-limited) cost one thread
//!   sees. For Gauss-Seidel this is dominated by the `add → mul` chain of
//!   the x recursion the paper describes; for Jacobi it is near the
//!   throughput bound because there is no loop-carried dependency.
//! * `thr_cpl` — the port-throughput lower bound with perfect scheduling.
//!
//! SMT is modeled exactly as the paper argues (Sec. 4): two hardware
//! threads interleave independent chains, so the effective in-core cost is
//! `max(lat/2, thr)` — a large win for Gauss-Seidel, none for Jacobi.
//!
//! All calibration constants live in [`KernelClass`] constructors and are
//! cross-checked against the paper's reported baselines in the test suite.


use super::machine::{MachineSpec, Microarch};
use super::memory::{Dataset, StoreMode};
use crate::stencil::op::{OpKind, TrafficSignature};

/// Which stencil kernel the model prices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Straightforward C Jacobi (compiler-vectorized at best).
    JacobiC,
    /// Optimized (assembly) Jacobi line-update kernel.
    JacobiOpt,
    /// Straightforward C Gauss-Seidel (exposed recursion).
    GsC,
    /// Dependency-interleaved Gauss-Seidel (the paper's optimized kernel).
    GsOpt,
}

impl Kernel {
    /// Is this an in-place Gauss-Seidel variant?
    pub fn is_gs(self) -> bool {
        matches!(self, Kernel::GsC | Kernel::GsOpt)
    }
}

/// In-core cost model of one kernel on one microarchitecture.
#[derive(Clone, Copy, Debug)]
pub struct KernelClass {
    /// Dependency-bound cycles per LUP (single thread).
    pub lat_cpl: f64,
    /// Port-throughput-bound cycles per LUP.
    pub thr_cpl: f64,
}

impl KernelClass {
    /// Calibration table (cycles per lattice-site update).
    ///
    /// Anchors: Fig. 3(a) — optimized in-cache Jacobi tracks clock speed on
    /// Intel (≈ 2 cy/LUP ⇒ 1600 MLUP/s at 3.2 GHz on Core 2); Fig. 4(a) —
    /// the interleaving optimization roughly doubles serial GS performance;
    /// the C Gauss-Seidel is pipeline-stalled at ≈ 2× the optimized cost.
    /// Istanbul's weak in-core showing is modeled via its transfer costs
    /// (exclusive hierarchy), not via different arithmetic.
    pub fn of(kernel: Kernel, arch: Microarch) -> Self {
        let (lat, thr) = match (kernel, arch) {
            (Kernel::JacobiOpt, Microarch::Istanbul) => (2.6, 2.4),
            (Kernel::JacobiOpt, _) => (2.2, 2.0),
            (Kernel::JacobiC, Microarch::Istanbul) => (3.6, 3.4),
            (Kernel::JacobiC, _) => (3.2, 3.0),
            // GS: latency of the add→mul recursion chain dominates.
            (Kernel::GsOpt, Microarch::Istanbul) => (6.5, 3.6),
            (Kernel::GsOpt, _) => (6.0, 3.0),
            (Kernel::GsC, Microarch::Istanbul) => (12.5, 4.4),
            (Kernel::GsC, _) => (12.0, 4.0),
        };
        Self { lat_cpl: lat, thr_cpl: thr }
    }

    /// Effective in-core cycles per LUP for `smt_threads` threads per core.
    ///
    /// The paper's SMT argument: hardware threads fill each other's
    /// pipeline bubbles, bounded below by port throughput.
    pub fn effective_cpl(&self, smt_threads: usize) -> f64 {
        (self.lat_cpl / smt_threads.max(1) as f64).max(self.thr_cpl)
    }
}

/// Everything the ECM machinery needs to price one operator: in-core
/// cycles plus the per-LUP [`TrafficSignature`] the transfer volumes are
/// derived from. The model no longer hard-codes Jacobi/GS byte counts —
/// they fall out of [`TrafficSignature::hierarchy_bytes_per_lup`] and
/// [`TrafficSignature::mem_bytes_per_lup`], so predictions stay
/// meaningful for every registered [`OpKind`].
#[derive(Clone, Copy, Debug)]
pub struct KernelProfile {
    /// In-core cost (calibrated, flop-scaled for non-baseline ops).
    pub class: KernelClass,
    /// Per-LUP stream/flop/radius shape.
    pub sig: TrafficSignature,
}

impl KernelProfile {
    /// Profile of one of the paper's four calibrated kernels — the
    /// [`ConstLaplace7`](crate::stencil::op::ConstLaplace7) signatures,
    /// reproducing the pre-`StencilOp` constants exactly.
    pub fn of_kernel(kernel: Kernel, arch: Microarch) -> Self {
        let sig = if kernel.is_gs() {
            OpKind::ConstLaplace7.gs_signature()
        } else {
            OpKind::ConstLaplace7.signature()
        };
        Self { class: KernelClass::of(kernel, arch), sig }
    }

    /// Profile of an arbitrary op: the matching baseline calibration
    /// (Jacobi- or GS-shaped, C or optimized) scaled by the op's flop
    /// count, plus the op's own traffic signature. For
    /// [`OpKind::ConstLaplace7`] this is exactly [`Self::of_kernel`].
    pub fn of_op(kind: OpKind, gs: bool, optimized: bool, arch: Microarch) -> Self {
        let base_kernel = match (gs, optimized) {
            (false, true) => Kernel::JacobiOpt,
            (false, false) => Kernel::JacobiC,
            (true, true) => Kernel::GsOpt,
            (true, false) => Kernel::GsC,
        };
        let base = KernelClass::of(base_kernel, arch);
        let (sig, base_sig) = if gs {
            (kind.gs_signature(), OpKind::ConstLaplace7.gs_signature())
        } else {
            (kind.signature(), OpKind::ConstLaplace7.signature())
        };
        let scale = sig.flops_per_lup as f64 / base_sig.flops_per_lup as f64;
        Self {
            class: KernelClass { lat_cpl: base.lat_cpl * scale, thr_cpl: base.thr_cpl * scale },
            sig,
        }
    }
}

/// Per-architecture cacheline transfer capabilities (bytes per core cycle).
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    /// L1 ↔ L2 bandwidth, bytes per core cycle.
    pub l1l2_bpc: f64,
    /// L2 ↔ outer-level cache bandwidth, bytes per core cycle.
    pub l2olc_bpc: f64,
    /// Multiplier on all in-hierarchy transfer volumes (2 for the
    /// exclusive Istanbul hierarchy: every fill is also a victim copy).
    pub volume_factor: f64,
    /// Fraction of the shorter of {core phase, memory phase} hidden
    /// behind the longer one (hardware prefetching). The classic ECM
    /// no-overlap rule is 0; Nehalem's aggressive prefetchers hide about
    /// half, Core 2's FSB much less, Istanbul's almost nothing — this is
    /// what makes the paper's EP "small drop" and Core 2 "largest drop"
    /// (Fig. 3a) come out of one formula.
    pub mem_overlap: f64,
}

impl TransferModel {
    pub fn of(m: &MachineSpec) -> Self {
        match m.arch {
            Microarch::Core2 => {
                Self { l1l2_bpc: 32.0, l2olc_bpc: 32.0, volume_factor: 1.0, mem_overlap: 0.3 }
            }
            Microarch::Nehalem => {
                Self { l1l2_bpc: 32.0, l2olc_bpc: 16.0, volume_factor: 1.0, mem_overlap: 0.5 }
            }
            // Exclusive caches + large latency overheads (paper Sec. 3 and
            // ref. [14]): halved usable transfer width, doubled volume.
            Microarch::Istanbul => {
                Self { l1l2_bpc: 16.0, l2olc_bpc: 8.0, volume_factor: 2.0, mem_overlap: 0.2 }
            }
        }
    }
}

/// Combine an execution phase and a memory phase (both in MLUP/s) with a
/// partial-overlap rule: the longer phase counts fully, `overlap` of the
/// shorter phase is hidden behind it.
fn combine_phases(a_mlups: f64, b_mlups: f64, overlap: f64) -> f64 {
    let (ta, tb) = (1.0 / a_mlups, 1.0 / b_mlups);
    let (long, short) = if ta >= tb { (ta, tb) } else { (tb, ta) };
    1.0 / (long + (1.0 - overlap) * short)
}

/// The full ECM prediction machinery for one machine.
#[derive(Clone, Debug)]
pub struct EcmModel {
    pub machine: MachineSpec,
    pub transfer: TransferModel,
}

/// A prediction with its constituent rooflines (all in MLUP/s).
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    /// The predicted performance: min over the rooflines × sync efficiency.
    pub mlups: f64,
    /// In-core + in-hierarchy execution roofline.
    pub compute_mlups: f64,
    /// Outer-level-cache bandwidth roofline.
    pub olc_mlups: f64,
    /// Main-memory bandwidth roofline (∞ for cache-resident datasets).
    pub mem_mlups: f64,
    /// Fraction of time not lost to synchronization.
    pub sync_efficiency: f64,
}

impl Prediction {
    pub(crate) fn min3(compute: f64, olc: f64, mem: f64, sync_eff: f64) -> Self {
        let mlups = compute.min(olc).min(mem) * sync_eff;
        Self { mlups, compute_mlups: compute, olc_mlups: olc, mem_mlups: mem, sync_efficiency: sync_eff }
    }
}

impl EcmModel {
    pub fn new(machine: MachineSpec) -> Self {
        let transfer = TransferModel::of(&machine);
        Self { machine, transfer }
    }

    /// Serial in-core + hierarchy cycles per LUP (no memory term).
    pub(crate) fn core_and_cache_cpl_profile(&self, profile: &KernelProfile, smt_threads: usize) -> f64 {
        let t_core = profile.class.effective_cpl(smt_threads);
        let vol = profile.sig.hierarchy_bytes_per_lup() * self.transfer.volume_factor;
        // Intel ECM: transfer phases do not overlap with core execution.
        let t_l1l2 = vol / self.transfer.l1l2_bpc;
        let t_l2olc =
            vol / self.transfer.l2olc_bpc * (self.machine.clock_ghz / self.machine.uncore_ghz);
        t_core + t_l1l2 + t_l2olc
    }

    /// Single-core performance in MLUP/s (Fig. 3a / 4a) for one of the
    /// paper's calibrated kernels.
    pub fn serial(&self, kernel: Kernel, dataset: Dataset, store: StoreMode) -> f64 {
        self.serial_profile(&KernelProfile::of_kernel(kernel, self.machine.arch), dataset, store)
    }

    /// Single-core performance in MLUP/s for an arbitrary op profile.
    pub fn serial_profile(&self, profile: &KernelProfile, dataset: Dataset, store: StoreMode) -> f64 {
        let cpl = self.core_and_cache_cpl_profile(profile, 1);
        let compute = self.machine.clock_ghz * 1e3 / cpl; // MLUP/s
        match dataset {
            Dataset::Cache => compute,
            Dataset::Memory => {
                let nt = matches!(store, StoreMode::NonTemporal);
                let bytes = profile.sig.mem_bytes_per_lup(nt);
                let mem = self.machine.stream_1t_gbs * 1e3 / bytes; // MLUP/s
                // ECM with partial overlap: the longer phase fully counts,
                // `mem_overlap` of the shorter phase hides behind it.
                combine_phases(compute, mem, self.transfer.mem_overlap)
            }
        }
    }

    /// Threaded socket performance (Fig. 3b / 4b baselines) for one of
    /// the paper's calibrated kernels.
    ///
    /// `threads` = logical threads; `smt` ⇒ two per core share a pipeline.
    pub fn socket(
        &self,
        kernel: Kernel,
        dataset: Dataset,
        store: StoreMode,
        threads: usize,
        smt: bool,
    ) -> Prediction {
        self.socket_profile(
            &KernelProfile::of_kernel(kernel, self.machine.arch),
            dataset,
            store,
            threads,
            smt,
        )
    }

    /// Threaded socket performance for an arbitrary op profile.
    pub fn socket_profile(
        &self,
        profile: &KernelProfile,
        dataset: Dataset,
        store: StoreMode,
        threads: usize,
        smt: bool,
    ) -> Prediction {
        let smt_per_core = if smt { self.machine.smt_per_core } else { 1 };
        let cores = threads.div_ceil(smt_per_core).min(self.machine.cores);
        let cpl = self.core_and_cache_cpl_profile(profile, smt_per_core);
        let compute = cores as f64 * self.machine.clock_ghz * 1e3 / cpl;
        let vol = profile.sig.hierarchy_bytes_per_lup() * self.transfer.volume_factor;
        let olc = self.machine.olc_bandwidth_gbs(cores) * 1e3 / vol;
        let (compute, mem) = match dataset {
            Dataset::Cache => (compute, f64::INFINITY),
            Dataset::Memory => {
                let nt_store = matches!(store, StoreMode::NonTemporal);
                let bytes = profile.sig.mem_bytes_per_lup(nt_store);
                let nt = nt_store && !profile.sig.in_place;
                // Per-thread ECM: the memory phase does not overlap with
                // execution (Intel rule), so each thread runs at the
                // harmonic combination; threads then scale until the bus
                // saturates at the socket STREAM limit.
                let mem_thread = self.machine.stream_1t_gbs * 1e3 / bytes;
                let compute_thread = compute / cores as f64;
                let thread = combine_phases(compute_thread, mem_thread, self.transfer.mem_overlap);
                let mem_roof = self.machine.memory_bandwidth_gbs(threads, nt) * 1e3 / bytes;
                (cores as f64 * thread.min(compute_thread), mem_roof)
            }
        };
        // GS pipeline-parallel fill/drain cost is folded into sync
        // efficiency by the wavefront predictor; the plain baseline is
        // long-running enough to amortize it.
        Prediction::min3(compute, olc, mem, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep() -> EcmModel {
        EcmModel::new(MachineSpec::nehalem_ep())
    }

    #[test]
    fn smt_helps_gs_not_jacobi() {
        let gs = KernelClass::of(Kernel::GsOpt, Microarch::Nehalem);
        let jac = KernelClass::of(Kernel::JacobiOpt, Microarch::Nehalem);
        let gs_gain = gs.effective_cpl(1) / gs.effective_cpl(2);
        let jac_gain = jac.effective_cpl(1) / jac.effective_cpl(2);
        assert!(gs_gain > 1.5, "GS SMT gain {gs_gain}");
        assert!(jac_gain < 1.15, "Jacobi SMT gain {jac_gain}");
    }

    #[test]
    fn optimized_kernels_beat_c() {
        for m in MachineSpec::testbed() {
            let e = EcmModel::new(m);
            for (c, opt) in [(Kernel::JacobiC, Kernel::JacobiOpt), (Kernel::GsC, Kernel::GsOpt)] {
                let pc = e.serial(c, Dataset::Cache, StoreMode::NonTemporal);
                let po = e.serial(opt, Dataset::Cache, StoreMode::NonTemporal);
                assert!(po > pc, "{}: {:?} {po} <= {:?} {pc}", e.machine.name, opt, c);
            }
        }
    }

    #[test]
    fn harpertown_has_largest_cache_to_memory_drop_for_jacobi() {
        // Paper Fig. 3a: "the highly clocked but bandwidth-starved
        // Harpertown shows the largest drop".
        let mut drops = vec![];
        for m in MachineSpec::testbed() {
            let e = EcmModel::new(m.clone());
            let pc = e.serial(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal);
            let pm = e.serial(Kernel::JacobiOpt, Dataset::Memory, StoreMode::NonTemporal);
            drops.push((m.name.clone(), pc / pm));
        }
        let core2 = drops.iter().find(|(n, _)| n == "Core 2").unwrap().1;
        for (name, d) in &drops {
            if name != "Core 2" && name != "Nehalem EX" {
                assert!(core2 >= *d, "Core2 drop {core2} vs {name} {d}");
            }
        }
    }

    #[test]
    fn ep_socket_jacobi_near_1008_mlups() {
        // Paper Sec. 4: "the threaded memory performance utilizing
        // non-temporal stores is already 1008 MLUPS" on Nehalem EP.
        let p = ep().socket(Kernel::JacobiOpt, Dataset::Memory, StoreMode::NonTemporal, 4, false);
        assert!(
            (p.mlups - 1008.0).abs() / 1008.0 < 0.2,
            "EP NT Jacobi socket: {} MLUP/s (paper: 1008)",
            p.mlups
        );
    }

    #[test]
    fn socket_memory_bound_below_cache_bound() {
        for m in MachineSpec::testbed() {
            let e = EcmModel::new(m.clone());
            let n = e.machine.cores;
            let mem = e.socket(Kernel::JacobiOpt, Dataset::Memory, StoreMode::NonTemporal, n, false);
            let cache = e.socket(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal, n, false);
            assert!(
                mem.mlups <= cache.mlups * 1.001,
                "{}: memory {} > cache {}",
                m.name,
                mem.mlups,
                cache.mlups
            );
        }
    }

    #[test]
    fn gs_slower_than_jacobi_despite_less_traffic() {
        // Paper: "Gauss-Seidel performance is inferior to Jacobi despite
        // comparable data transfer volumes and less computations".
        for m in MachineSpec::testbed() {
            let e = EcmModel::new(m.clone());
            let j = e.serial(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal);
            let g = e.serial(Kernel::GsOpt, Dataset::Cache, StoreMode::NonTemporal);
            assert!(g < j, "{}: GS {} !< Jacobi {}", m.name, g, j);
        }
    }

    #[test]
    fn kernel_profiles_reproduce_the_kernel_path_exactly() {
        // of_op(ConstLaplace7) must be the identity refactor: same
        // prediction as the old Kernel-enum path, bit for bit.
        for m in MachineSpec::testbed() {
            let e = EcmModel::new(m.clone());
            for (kernel, gs, opt) in [
                (Kernel::JacobiOpt, false, true),
                (Kernel::JacobiC, false, false),
                (Kernel::GsOpt, true, true),
                (Kernel::GsC, true, false),
            ] {
                let p = KernelProfile::of_op(OpKind::ConstLaplace7, gs, opt, m.arch);
                for store in [StoreMode::NonTemporal, StoreMode::WriteAllocate] {
                    for ds in [Dataset::Cache, Dataset::Memory] {
                        assert_eq!(
                            e.serial(kernel, ds, store),
                            e.serial_profile(&p, ds, store),
                            "{} {kernel:?} {ds:?} {store:?}",
                            m.name
                        );
                        assert_eq!(
                            e.socket(kernel, ds, store, m.cores, false).mlups,
                            e.socket_profile(&p, ds, store, m.cores, false).mlups,
                            "{} {kernel:?} socket",
                            m.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn op_profiles_order_sensibly() {
        let e = ep();
        let arch = e.machine.arch;
        let base = KernelProfile::of_op(OpKind::ConstLaplace7, false, true, arch);
        let var = KernelProfile::of_op(OpKind::VarCoeff7, false, true, arch);
        let l13 = KernelProfile::of_op(OpKind::Laplace13, false, true, arch);
        // extra coefficient stream: more memory traffic, lower mem-bound perf
        assert!(var.sig.mem_bytes_per_lup(true) > base.sig.mem_bytes_per_lup(true));
        // more flops: higher in-core cost
        assert!(l13.class.lat_cpl > base.class.lat_cpl);
        for p in [&base, &var, &l13] {
            let mlups =
                e.socket_profile(p, Dataset::Memory, StoreMode::NonTemporal, 4, false).mlups;
            assert!(mlups.is_finite() && mlups > 0.0);
        }
        // in-cache, the heavier ops cannot be faster than the baseline
        let perf = |p| e.serial_profile(p, Dataset::Cache, StoreMode::NonTemporal);
        assert!(perf(&var) < perf(&base));
        assert!(perf(&l13) < perf(&base));
    }

    #[test]
    fn istanbul_opt_gains_are_muted_in_cache() {
        // Paper Fig. 3a: on Istanbul "the applied optimizations do not show
        // a larger effect" because transfers dominate.
        let ist = EcmModel::new(MachineSpec::istanbul());
        let ratio_ist = ist.serial(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal)
            / ist.serial(Kernel::JacobiC, Dataset::Cache, StoreMode::NonTemporal);
        let ep = ep();
        let ratio_ep = ep.serial(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal)
            / ep.serial(Kernel::JacobiC, Dataset::Cache, StoreMode::NonTemporal);
        assert!(ratio_ist < ratio_ep, "ist {ratio_ist} vs ep {ratio_ep}");
    }
}
