//! Data-traffic accounting: bytes each scheme moves per lattice-site update.
//!
//! The paper's whole argument is a traffic argument (Sec. 3–4): once the
//! bus is saturated, performance is `bandwidth / bytes-per-LUP`, so every
//! optimization is a reduction of the numerator. This module encodes the
//! per-scheme accounting that feeds Eq. (1) and the ECM model.


/// Where the working set lives — the two columns of Figs. 3/4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Fits in the outer-level cache (e.g. 100×50×50 ≈ 4 MB).
    Cache,
    /// Must stream from main memory (e.g. 400×200×200 ≈ 256 MB per array).
    Memory,
}

/// Store instruction flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreMode {
    /// Non-temporal (streaming) stores: no write-allocate transfer.
    NonTemporal,
    /// Regular stores: each store line is first loaded (write-allocate).
    WriteAllocate,
}

/// Main-memory bytes per LUP for one plain Jacobi update.
///
/// Fig. 2: with three planes resident in the outer cache only the `src`
/// load stream misses (8 B) plus the `dst` store stream (8 B, +8 B
/// write-allocate without NT stores).
pub fn jacobi_mem_bytes_per_lup(store: StoreMode) -> f64 {
    match store {
        StoreMode::NonTemporal => 16.0,
        StoreMode::WriteAllocate => 24.0,
    }
}

/// Main-memory bytes per LUP for one Gauss-Seidel update.
///
/// In-place: the single array is loaded and stored; the in-place store
/// cannot use NT stores (paper Sec. 3), but the store hits the line the
/// load just brought in, so no *extra* write-allocate: 8 B in + 8 B out.
pub fn gs_mem_bytes_per_lup() -> f64 {
    16.0
}

/// Main-memory bytes per LUP for the wavefront scheme with blocking
/// factor `t` (Sec. 4): one load of the initial sweep and one store of the
/// final sweep amortized over `t` updates per site.
///
/// `boundary_overhead` adds the inter-block boundary-array traffic
/// (t z-x planes per block interface; small, grows with block count).
pub fn wavefront_mem_bytes_per_lup(t: usize, store: StoreMode, boundary_overhead: f64) -> f64 {
    assert!(t >= 1);
    jacobi_mem_bytes_per_lup(store) / t as f64 * (1.0 + boundary_overhead)
}

/// Outer-level-cache bytes per LUP inside a wavefront thread group.
///
/// Jacobi: each intermediate update reads its window from one array and
/// writes to another (plus the in-cache write allocate) — ~24 B/LUP of
/// OLC traffic. Gauss-Seidel is in place: read + write of one line,
/// 16 B/LUP. The exclusive hierarchy (Istanbul) pays every transfer
/// twice (victim copy-back), which is the paper's explanation for its
/// disappointing wavefront gains.
pub fn wavefront_olc_bytes_per_lup(is_gs: bool, exclusive: bool) -> f64 {
    let base = if is_gs { 16.0 } else { 24.0 };
    if exclusive {
        2.0 * base
    } else {
        base
    }
}

/// STREAM triad bus bytes per element: load b, load c, store a
/// (+ write-allocate for a without NT stores).
pub fn stream_triad_bytes_per_elem(store: StoreMode) -> f64 {
    match store {
        StoreMode::NonTemporal => 24.0,
        StoreMode::WriteAllocate => 32.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_constants() {
        assert_eq!(jacobi_mem_bytes_per_lup(StoreMode::NonTemporal), 16.0);
        assert_eq!(jacobi_mem_bytes_per_lup(StoreMode::WriteAllocate), 24.0);
        assert_eq!(gs_mem_bytes_per_lup(), 16.0);
    }

    #[test]
    fn wavefront_divides_traffic_by_t() {
        let base = jacobi_mem_bytes_per_lup(StoreMode::NonTemporal);
        for t in 1..=8 {
            let w = wavefront_mem_bytes_per_lup(t, StoreMode::NonTemporal, 0.0);
            assert!((w - base / t as f64).abs() < 1e-12);
        }
        // boundary overhead strictly increases traffic
        assert!(
            wavefront_mem_bytes_per_lup(4, StoreMode::NonTemporal, 0.05)
                > wavefront_mem_bytes_per_lup(4, StoreMode::NonTemporal, 0.0)
        );
    }

    #[test]
    fn exclusive_hierarchy_doubles_olc_traffic() {
        for is_gs in [false, true] {
            assert_eq!(
                wavefront_olc_bytes_per_lup(is_gs, true),
                2.0 * wavefront_olc_bytes_per_lup(is_gs, false)
            );
        }
        // in-place GS moves less through the shared cache than Jacobi
        assert!(wavefront_olc_bytes_per_lup(true, false) < wavefront_olc_bytes_per_lup(false, false));
    }
}
