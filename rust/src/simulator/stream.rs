//! STREAM triad model — regenerates the bandwidth rows of Tab. 1.
//!
//! Tab. 1 reports three STREAM numbers per machine: single-thread triad,
//! socket triad with NT stores, and socket triad counting the full bus
//! traffic (write-allocate included) without NT stores. The machine specs
//! carry the measured end points; this module reconstructs the whole
//! thread-scaling curve from them (saturating-bus model) so the Tab. 1
//! generator and the baseline figures can query bandwidth at any thread
//! count, and so the real in-process triad ([`crate::stencil::streambench`])
//! can be compared against the model on this box.

use super::machine::MachineSpec;
use super::memory::{stream_triad_bytes_per_elem, StoreMode};

/// One row of the Tab. 1 bandwidth block.
#[derive(Clone, Debug)]
pub struct StreamRow {
    pub machine: String,
    pub bw_theoretical_gbs: f64,
    pub stream_1t_gbs: f64,
    pub stream_socket_nt_gbs: f64,
    pub stream_socket_nont_gbs: f64,
    /// Fraction of the theoretical bus the NT triad achieves.
    pub nt_efficiency: f64,
}

/// Modeled triad bandwidth for `n` threads (GB/s of *useful* traffic).
pub fn triad_bandwidth_gbs(m: &MachineSpec, n_threads: usize, store: StoreMode) -> f64 {
    let nt = matches!(store, StoreMode::NonTemporal);
    m.memory_bandwidth_gbs(n_threads, nt)
}

/// Triad performance in updates/s for `n` threads — the quantity a user
/// observes; bandwidth divided by bytes per element.
pub fn triad_updates_per_sec(m: &MachineSpec, n_threads: usize, store: StoreMode) -> f64 {
    triad_bandwidth_gbs(m, n_threads, store) * 1e9 / stream_triad_bytes_per_elem(store)
}

/// Regenerate the Tab. 1 bandwidth block for the whole testbed.
pub fn tab1_rows() -> Vec<StreamRow> {
    MachineSpec::testbed()
        .into_iter()
        .map(|m| StreamRow {
            nt_efficiency: m.stream_socket_nt_gbs / m.bw_theoretical_gbs,
            machine: m.name.clone(),
            bw_theoretical_gbs: m.bw_theoretical_gbs,
            stream_1t_gbs: m.stream_1t_gbs,
            stream_socket_nt_gbs: m.stream_socket_nt_gbs,
            stream_socket_nont_gbs: m.stream_socket_nont_gbs,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_has_five_machines_with_sane_numbers() {
        let rows = tab1_rows();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.stream_1t_gbs > 0.0);
            assert!(r.stream_socket_nt_gbs <= r.bw_theoretical_gbs,
                "{}: STREAM cannot beat the bus", r.machine);
            assert!(r.stream_socket_nont_gbs >= r.stream_socket_nt_gbs,
                "{}: noNT row counts write-allocate traffic too", r.machine);
            assert!(r.nt_efficiency > 0.2 && r.nt_efficiency <= 1.0);
        }
    }

    #[test]
    fn triad_scaling_saturates() {
        let ep = MachineSpec::nehalem_ep();
        let one = triad_bandwidth_gbs(&ep, 1, StoreMode::NonTemporal);
        let four = triad_bandwidth_gbs(&ep, 4, StoreMode::NonTemporal);
        let eight = triad_bandwidth_gbs(&ep, 8, StoreMode::NonTemporal);
        assert!(four > one);
        assert_eq!(four, eight, "socket limit reached");
    }

    #[test]
    fn updates_per_sec_accounts_write_allocate() {
        let wm = MachineSpec::westmere();
        let nt = triad_updates_per_sec(&wm, 6, StoreMode::NonTemporal);
        let wa = triad_updates_per_sec(&wm, 6, StoreMode::WriteAllocate);
        // NT wins on updates/s even though the noNT *bus* figure is larger.
        assert!(nt > wa, "nt={nt} wa={wa}");
    }
}
