//! Exact cacheline access traces for stencil schedules.
//!
//! Bridges the coordinator's schedules and the cache simulator: each
//! generator emits the memory access stream (cacheline granularity) that a
//! schedule produces, with realistic array placement, so the hierarchy
//! simulator can measure what actually stays in cache. This is the
//! verification path for the paper's central claim — the wavefront scheme
//! turns `t` sweeps' worth of memory traffic into one.

use super::cache::Hierarchy;
use super::CACHELINE_BYTES;

/// One memory access of a trace.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    /// Executing core (logical thread mapped to a core).
    pub core: usize,
    /// Byte address.
    pub addr: u64,
    pub write: bool,
    /// Non-temporal store (bypasses the hierarchy).
    pub nt: bool,
}

/// A sequence of accesses in (simulated) program order.
pub type Trace = Vec<Access>;

/// Grid dimensions used by the generators.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub nz: usize,
    pub ny: usize,
    pub nx: usize,
}

impl Dims {
    pub fn new(nz: usize, ny: usize, nx: usize) -> Self {
        Self { nz, ny, nx }
    }
    #[inline]
    fn idx(&self, k: usize, j: usize, i: usize) -> u64 {
        ((k * self.ny + j) * self.nx + i) as u64
    }
    /// Bytes of one array.
    pub fn bytes(&self) -> u64 {
        (self.nz * self.ny * self.nx * 8) as u64
    }
    /// Interior lattice sites.
    pub fn interior(&self) -> u64 {
        ((self.nz - 2) * (self.ny - 2) * (self.nx - 2)) as u64
    }
}

/// Array placement: spaced, page-aligned base addresses.
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    pub src: u64,
    pub dst: u64,
    pub rhs: u64,
    pub tmp: u64,
}

impl Layout {
    pub fn for_dims(d: Dims) -> Self {
        let span = (d.bytes() + 4096).next_multiple_of(4096);
        Self { src: 0, dst: span, rhs: 2 * span, tmp: 3 * span }
    }
}

/// Append the accesses of one x-line of a stream (every cacheline once).
fn touch_line(trace: &mut Trace, core: usize, base: u64, d: Dims, k: usize, j: usize, write: bool, nt: bool) {
    let start = base + d.idx(k, j, 0) * 8;
    let end = base + d.idx(k, j, d.nx - 1) * 8;
    let mut addr = start & !(CACHELINE_BYTES as u64 - 1);
    while addr <= end {
        trace.push(Access { core, addr, write, nt });
        addr += CACHELINE_BYTES as u64;
    }
}

/// Accesses of one Jacobi line update (Fig. 2's five read streams + store).
#[allow(clippy::too_many_arguments)]
fn jacobi_line(
    trace: &mut Trace,
    core: usize,
    src: u64,
    dst: u64,
    rhs: u64,
    d: Dims,
    k: usize,
    j: usize,
    nt_store: bool,
) {
    touch_line(trace, core, src, d, k, j - 1, false, false);
    touch_line(trace, core, src, d, k, j, false, false);
    touch_line(trace, core, src, d, k, j + 1, false, false);
    touch_line(trace, core, src, d, k - 1, j, false, false);
    touch_line(trace, core, src, d, k + 1, j, false, false);
    touch_line(trace, core, rhs, d, k, j, false, false);
    touch_line(trace, core, dst, d, k, j, true, nt_store);
}

/// Serial Jacobi sweep trace (the paper's baseline, one core).
pub fn jacobi_sweep_trace(d: Dims, nt_store: bool) -> Trace {
    let l = Layout::for_dims(d);
    let mut t = Trace::new();
    for k in 1..d.nz - 1 {
        for j in 1..d.ny - 1 {
            jacobi_line(&mut t, 0, l.src, l.dst, l.rhs, d, k, j, nt_store);
        }
    }
    t
}

/// `n` serial Jacobi sweeps (ping-pong buffers) — baseline for `n` updates.
pub fn jacobi_steps_trace(d: Dims, n: usize, nt_store: bool) -> Trace {
    let l = Layout::for_dims(d);
    let mut t = Trace::new();
    let (mut a, mut b) = (l.src, l.dst);
    for _ in 0..n {
        for k in 1..d.nz - 1 {
            for j in 1..d.ny - 1 {
                jacobi_line(&mut t, 0, a, b, l.rhs, d, k, j, nt_store);
            }
        }
        std::mem::swap(&mut a, &mut b);
    }
    t
}

/// Wavefront Jacobi trace: one thread group of `t` threads (= blocking
/// factor), barrier-synchronized plane rounds, temporary array of `2t`
/// z-x planes reused round-robin (Sec. 4 / Fig. 6).
///
/// Thread `s` performs update step `s+1`; even steps (0-based threads with
/// even index) read `src`-side and write `tmp`-side and vice versa, the
/// final thread stores to `src` (in-place semantics of the scheme). Thread
/// `s` processes plane `r - 2s` in round `r` — the spatial shift of 2.
pub fn wavefront_jacobi_trace(d: Dims, t: usize, nt_store: bool) -> Trace {
    assert!(t >= 2 && t % 2 == 0, "paper configurations use even t >= 2");
    let l = Layout::for_dims(d);
    let mut trace = Trace::new();
    let tmp_planes = 2 * t as u64;
    let plane_bytes = (d.ny * d.nx * 8) as u64;
    // tmp plane address for logical plane k of odd-update level `lvl`
    let tmp_addr = |lvl: u64, k: usize| {
        l.tmp + (lvl * tmp_planes / 2 + (k as u64 % (tmp_planes / 2))) * plane_bytes
    };
    let last_round = (d.nz - 2) + 2 * (t - 1);
    for r in 1..=last_round {
        for s in 0..t {
            let k = r as isize - 2 * s as isize;
            if k < 1 || k as usize > d.nz - 2 {
                continue;
            }
            let k = k as usize;
            let lvl = (s / 2) as u64;
            // read side: thread 0 reads src; odd threads read tmp planes
            // written by thread s-1; even threads read src planes written
            // by thread s-1.
            for dk in [-1isize, 0, 1] {
                let kk = (k as isize + dk).clamp(0, d.nz as isize - 1) as usize;
                if s % 2 == 0 {
                    // reads from src (level s state)
                    for j in 1..d.ny - 1 {
                        touch_line(&mut trace, s, l.src, d, kk, j, false, false);
                    }
                } else {
                    let a = tmp_addr(lvl, kk);
                    for j in 1..d.ny - 1 {
                        let start = a + (j * d.nx * 8) as u64;
                        let mut addr = start & !(CACHELINE_BYTES as u64 - 1);
                        let end = a + ((j + 1) * d.nx * 8 - 8) as u64;
                        while addr <= end {
                            trace.push(Access { core: s, addr, write: false, nt: false });
                            addr += CACHELINE_BYTES as u64;
                        }
                    }
                }
            }
            // rhs stream (first update only needs it in the Poisson case;
            // every level reads it in general)
            for j in 1..d.ny - 1 {
                touch_line(&mut trace, s, l.rhs, d, k, j, false, false);
            }
            // write side
            if s % 2 == 0 {
                let a = tmp_addr(lvl, k);
                for j in 1..d.ny - 1 {
                    let start = a + (j * d.nx * 8) as u64;
                    let mut addr = start & !(CACHELINE_BYTES as u64 - 1);
                    let end = a + ((j + 1) * d.nx * 8 - 8) as u64;
                    while addr <= end {
                        trace.push(Access { core: s, addr, write: true, nt: false });
                        addr += CACHELINE_BYTES as u64;
                    }
                }
            } else {
                let nt = nt_store && s == t - 1;
                for j in 1..d.ny - 1 {
                    touch_line(&mut trace, s, l.src, d, k, j, true, nt);
                }
            }
        }
    }
    trace
}

/// Run a trace against a hierarchy; returns memory bytes moved.
pub fn run_trace(h: &mut Hierarchy, trace: &Trace) -> u64 {
    for a in trace {
        if a.nt {
            h.nt_store(a.core, a.addr);
        } else {
            h.access(a.core, a.addr, a.write);
        }
    }
    h.mem_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::cache::Hierarchy;

    const D: Dims = Dims { nz: 34, ny: 32, nx: 32 };

    /// A hierarchy scaled so one plane set fits the OLC but the full grid
    /// does not: grid = 256 KB/array, OLC = 128 KB.
    fn small_hierarchy(cores: usize) -> Hierarchy {
        Hierarchy::uniform(cores, 8 << 10, 32 << 10, 384 << 10)
    }

    #[test]
    fn baseline_traffic_near_model() {
        // One sweep over a memory-resident grid: ≥ src load + dst store.
        let mut h = small_hierarchy(1);
        let t = jacobi_sweep_trace(D, false);
        let mem = run_trace(&mut h, &t) as f64;
        let per_lup = mem / D.interior() as f64;
        assert!(per_lup >= 14.0, "at least load+store per LUP, got {per_lup}");
        assert!(per_lup <= 40.0, "three-plane reuse must hold, got {per_lup}");
    }

    #[test]
    fn nt_stores_reduce_baseline_traffic() {
        let mut h1 = small_hierarchy(1);
        let mut h2 = small_hierarchy(1);
        let m_wa = run_trace(&mut h1, &jacobi_sweep_trace(D, false));
        let m_nt = run_trace(&mut h2, &jacobi_sweep_trace(D, true));
        assert!(m_nt < m_wa, "NT {m_nt} !< WA {m_wa}");
    }

    #[test]
    fn wavefront_cuts_memory_traffic_versus_t_sweeps() {
        // The paper's core claim, verified in silico: t temporally blocked
        // updates move a fraction of the traffic of t separate sweeps.
        let t = 4;
        let mut h_base = small_hierarchy(1);
        let base = run_trace(&mut h_base, &jacobi_steps_trace(D, t, false)) as f64;
        let mut h_wf = small_hierarchy(t);
        let wf = run_trace(&mut h_wf, &wavefront_jacobi_trace(D, t, false)) as f64;
        assert!(
            wf < 0.55 * base,
            "wavefront {wf:.0} B should be well under t-sweep baseline {base:.0} B"
        );
    }

    #[test]
    fn wavefront_intermediate_planes_hit_shared_cache() {
        let mut h = small_hierarchy(4);
        run_trace(&mut h, &wavefront_jacobi_trace(D, 4, false));
        let olc = h.olc_stats();
        assert!(olc.hit_rate() > 0.5, "OLC hit rate {}", olc.hit_rate());
    }

    #[test]
    fn traces_are_nonempty_and_cover_interior() {
        let t = jacobi_sweep_trace(D, false);
        assert!(!t.is_empty());
        let writes = t.iter().filter(|a| a.write).count() as u64;
        // one dst line per (k,j): (nz-2)(ny-2) line walks of nx/8 lines
        let lines = (D.nz as u64 - 2) * (D.ny as u64 - 2);
        assert_eq!(writes, lines * (D.nx as u64 * 8).div_ceil(64));
    }
}
