//! Machine descriptions for the paper's testbed (Sec. 2, Tab. 1).
//!
//! Every quantity the performance model needs is a field here; the five
//! constructors encode Tab. 1. Where the scanned table is ambiguous the
//! assignment follows the paper's prose (e.g. "bandwidth-starved
//! Harpertown", "Nehalem EX equipped with only half of the possible
//! memory cards") and is documented in DESIGN.md §2.


/// One cache level of the hierarchy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheLevel {
    /// Capacity in bytes (per instance of this cache).
    pub bytes: usize,
    /// Associativity (ways).
    pub assoc: usize,
    /// Number of cores sharing one instance.
    pub shared_by: usize,
}

impl CacheLevel {
    /// Number of sets, assuming 64 B lines.
    pub fn sets(&self) -> usize {
        self.bytes / super::CACHELINE_BYTES / self.assoc
    }
}

/// Microarchitecture family — switches model behaviours, not parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Microarch {
    /// Intel Core 2 (Harpertown): FSB, no L3, inclusive L2 groups.
    Core2,
    /// Intel Nehalem / Westmere / Nehalem EX: inclusive shared L3, SMT-2.
    Nehalem,
    /// AMD Istanbul: exclusive cache hierarchy, high transfer overheads.
    Istanbul,
}

/// A socket of the paper's testbed with everything Tab. 1 reports.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    /// Display name used in figures ("Core 2", "Nehalem EP", ...).
    pub name: String,
    /// Vendor model ("Xeon X5482", ...).
    pub model: String,
    pub arch: Microarch,
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// Uncore (L3 + memory controller) clock in GHz — the paper notes
    /// Westmere's uncore runs at Nehalem EP speed, capping its L3 gains.
    pub uncore_ghz: f64,
    /// Physical cores per socket.
    pub cores: usize,
    /// Hardware (SMT) threads per core; 1 = no SMT.
    pub smt_per_core: usize,
    pub l1: CacheLevel,
    pub l2: CacheLevel,
    /// Outer-level cache; `None` for Core 2 (its shared L2 is the OLC).
    pub l3: Option<CacheLevel>,
    /// Exclusive (victim) hierarchy — Istanbul; costs extra transfers.
    pub exclusive: bool,
    /// Theoretical socket memory bandwidth in GB/s.
    pub bw_theoretical_gbs: f64,
    /// STREAM triad, one thread, GB/s.
    pub stream_1t_gbs: f64,
    /// STREAM triad, full socket, non-temporal stores, GB/s.
    pub stream_socket_nt_gbs: f64,
    /// STREAM triad, full socket, regular stores (bus traffic incl.
    /// write-allocate), GB/s.
    pub stream_socket_nont_gbs: f64,
    /// Outer-level-cache bandwidth per core in bytes/cycle (uncore cycles).
    pub olc_bytes_per_cycle_core: f64,
    /// Whether OLC bandwidth scales with cores (Nehalem EX segmented L3)
    /// or saturates (fraction of linear scaling retained per extra core).
    pub olc_scaling: f64,
}

impl MachineSpec {
    /// The cache group the wavefront scheme targets: cores sharing the OLC.
    ///
    /// Harpertown is "two independent dual-core processors" (L2 groups);
    /// everything else is the full socket (L3 group).
    pub fn cache_group_cores(&self) -> usize {
        match self.l3 {
            Some(l3) => l3.shared_by,
            None => self.l2.shared_by,
        }
    }

    /// Capacity of the outer-level (shared) cache in bytes.
    pub fn olc_bytes(&self) -> usize {
        self.l3.map(|l| l.bytes).unwrap_or(self.l2.bytes)
    }

    /// Maximum wavefront blocking factor: one update step per thread in
    /// the cache group (paper: "the maximum number of blocked updates is
    /// determined by the number of threads available").
    pub fn max_blocking_factor(&self, use_smt: bool) -> usize {
        let t = if use_smt { self.smt_per_core } else { 1 };
        self.cache_group_cores() * t
    }

    /// Logical threads on one socket.
    pub fn socket_threads(&self, use_smt: bool) -> usize {
        self.cores * if use_smt { self.smt_per_core } else { 1 }
    }

    /// Cpu-id distance between SMT siblings of one core under the
    /// split-style enumeration Linux uses on these machines: physical
    /// cores get ids `0..cores` and core `c`'s sibling threads answer
    /// to `c + t·stride`. With one thread per core the stride is moot
    /// (returned as `cores` for uniformity; no second sibling exists).
    pub fn smt_sibling_stride(&self) -> usize {
        self.cores.max(1)
    }

    /// Aggregate OLC bandwidth in GB/s when `n` cores stream from it.
    ///
    /// Linear up to the scaling fraction: each additional core adds
    /// `olc_scaling` of the first core's bandwidth (1.0 = perfect scaleup,
    /// the paper's Nehalem EX; < 1 models uncore saturation).
    pub fn olc_bandwidth_gbs(&self, n_cores: usize) -> f64 {
        let per_core = self.olc_bytes_per_cycle_core * self.uncore_ghz; // GB/s
        if n_cores == 0 {
            return 0.0;
        }
        per_core * (1.0 + self.olc_scaling * (n_cores as f64 - 1.0))
    }

    /// Memory bandwidth reachable by `n` threads (saturating, paper Fig. 3:
    /// Nehalem bandwidth "scales with the number of cores" until the
    /// socket limit).
    pub fn memory_bandwidth_gbs(&self, n_threads: usize, nt_stores: bool) -> f64 {
        let socket = if nt_stores { self.stream_socket_nt_gbs } else { self.stream_socket_nont_gbs };
        if n_threads == 0 {
            return 0.0;
        }
        (self.stream_1t_gbs * n_threads as f64).min(socket)
    }

    // ---- The five testbed machines (Tab. 1) -------------------------------

    /// Intel Core 2 "Harpertown" Xeon X5482 — treated as an L2 group of 2.
    pub fn core2_harpertown() -> Self {
        Self {
            name: "Core 2".into(),
            model: "Xeon X5482".into(),
            arch: Microarch::Core2,
            clock_ghz: 3.2,
            uncore_ghz: 3.2,
            cores: 4,
            smt_per_core: 1,
            l1: CacheLevel { bytes: 32 << 10, assoc: 8, shared_by: 1 },
            // two independent 6 MB L2s, each shared by 2 cores (Fig. 1a)
            l2: CacheLevel { bytes: 6 << 20, assoc: 24, shared_by: 2 },
            l3: None,
            exclusive: false,
            bw_theoretical_gbs: 12.8,
            stream_1t_gbs: 4.6,
            stream_socket_nt_gbs: 4.8,
            stream_socket_nont_gbs: 5.6,
            olc_bytes_per_cycle_core: 8.0,
            olc_scaling: 0.55,
        }
    }

    /// Intel Nehalem EP Xeon X5550 — first quad-core with shared L3, SMT-2.
    pub fn nehalem_ep() -> Self {
        Self {
            name: "Nehalem EP".into(),
            model: "Xeon X5550".into(),
            arch: Microarch::Nehalem,
            clock_ghz: 2.66,
            uncore_ghz: 2.66,
            cores: 4,
            smt_per_core: 2,
            l1: CacheLevel { bytes: 32 << 10, assoc: 8, shared_by: 1 },
            l2: CacheLevel { bytes: 256 << 10, assoc: 8, shared_by: 1 },
            l3: Some(CacheLevel { bytes: 8 << 20, assoc: 16, shared_by: 4 }),
            exclusive: false,
            bw_theoretical_gbs: 32.0,
            stream_1t_gbs: 11.0,
            stream_socket_nt_gbs: 18.5,
            stream_socket_nont_gbs: 23.7,
            olc_bytes_per_cycle_core: 8.6,
            olc_scaling: 0.25,
        }
    }

    /// Intel Westmere EP Xeon X5670 — 6 cores, 12 MB L3, same uncore clock
    /// as Nehalem EP (paper: "the uncore has the same clock speed ... and
    /// therefore reaches similar in-cache performance").
    pub fn westmere() -> Self {
        Self {
            name: "Westmere".into(),
            model: "Xeon X5670".into(),
            arch: Microarch::Nehalem,
            clock_ghz: 2.93,
            uncore_ghz: 2.66,
            cores: 6,
            smt_per_core: 2,
            l1: CacheLevel { bytes: 32 << 10, assoc: 8, shared_by: 1 },
            l2: CacheLevel { bytes: 256 << 10, assoc: 8, shared_by: 1 },
            l3: Some(CacheLevel { bytes: 12 << 20, assoc: 16, shared_by: 6 }),
            exclusive: false,
            bw_theoretical_gbs: 32.0,
            stream_1t_gbs: 11.9,
            stream_socket_nt_gbs: 21.0,
            stream_socket_nont_gbs: 23.6,
            olc_bytes_per_cycle_core: 8.0,
            olc_scaling: 0.32,
        }
    }

    /// Intel Nehalem EX Xeon X7560 — 8 cores, segmented 24 MB L3 with near
    /// perfect bandwidth scale-up; test system had half the memory cards,
    /// so socket bandwidth is artificially halved (paper Sec. 2).
    pub fn nehalem_ex() -> Self {
        Self {
            name: "Nehalem EX".into(),
            model: "Xeon X7560".into(),
            arch: Microarch::Nehalem,
            clock_ghz: 2.26,
            uncore_ghz: 2.26,
            cores: 8,
            smt_per_core: 2,
            l1: CacheLevel { bytes: 32 << 10, assoc: 8, shared_by: 1 },
            l2: CacheLevel { bytes: 256 << 10, assoc: 8, shared_by: 1 },
            l3: Some(CacheLevel { bytes: 24 << 20, assoc: 24, shared_by: 8 }),
            exclusive: false,
            bw_theoretical_gbs: 17.1,
            stream_1t_gbs: 5.3,
            stream_socket_nt_gbs: 9.8,
            stream_socket_nont_gbs: 11.4,
            olc_bytes_per_cycle_core: 3.4,
            // the paper: "a novel segmented L3 cache which shows a near to
            // perfect bandwidth scaleup with the number of cores"
            olc_scaling: 0.95,
        }
    }

    /// AMD Istanbul Opteron 2435 — exclusive hierarchy, 6 MB L3/48-way.
    pub fn istanbul() -> Self {
        Self {
            name: "Istanbul".into(),
            model: "Opteron 2435".into(),
            arch: Microarch::Istanbul,
            clock_ghz: 2.6,
            uncore_ghz: 2.2,
            cores: 6,
            smt_per_core: 1,
            l1: CacheLevel { bytes: 64 << 10, assoc: 2, shared_by: 1 },
            l2: CacheLevel { bytes: 512 << 10, assoc: 16, shared_by: 1 },
            l3: Some(CacheLevel { bytes: 6 << 20, assoc: 48, shared_by: 6 }),
            exclusive: true,
            bw_theoretical_gbs: 17.1,
            stream_1t_gbs: 7.2,
            stream_socket_nt_gbs: 9.1,
            stream_socket_nont_gbs: 13.6,
            olc_bytes_per_cycle_core: 6.0,
            olc_scaling: 0.40,
        }
    }

    /// The full testbed in the paper's column order.
    pub fn testbed() -> Vec<Self> {
        vec![
            Self::core2_harpertown(),
            Self::nehalem_ep(),
            Self::westmere(),
            Self::nehalem_ex(),
            Self::istanbul(),
        ]
    }

    /// Look a machine up by (case-insensitive, space/dash-insensitive) name.
    pub fn by_name(name: &str) -> Option<Self> {
        let norm = |s: &str| s.to_lowercase().replace([' ', '-', '_'], "");
        Self::testbed().into_iter().find(|m| norm(&m.name) == norm(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_matches_tab1_topology() {
        let tb = MachineSpec::testbed();
        assert_eq!(tb.len(), 5);
        let core2 = &tb[0];
        assert_eq!(core2.cache_group_cores(), 2, "Harpertown = two L2 groups");
        assert_eq!(core2.max_blocking_factor(false), 2);
        let ep = &tb[1];
        assert_eq!(ep.cache_group_cores(), 4);
        assert_eq!(ep.max_blocking_factor(true), 8, "SMT doubles the factor");
        let wm = &tb[2];
        assert_eq!(wm.cache_group_cores(), 6);
        let ex = &tb[3];
        assert_eq!(ex.cache_group_cores(), 8);
        assert_eq!(ex.l3.unwrap().bytes, 24 << 20);
        let ist = &tb[4];
        assert!(ist.exclusive);
        assert_eq!(ist.smt_per_core, 1);
    }

    #[test]
    fn bandwidth_saturates_at_socket_limit() {
        let ep = MachineSpec::nehalem_ep();
        assert!((ep.memory_bandwidth_gbs(1, true) - 11.0).abs() < 1e-12);
        assert!((ep.memory_bandwidth_gbs(4, true) - 18.5).abs() < 1e-12);
        assert!((ep.memory_bandwidth_gbs(8, true) - 18.5).abs() < 1e-12);
        assert!(ep.memory_bandwidth_gbs(4, false) > ep.memory_bandwidth_gbs(4, true));
    }

    #[test]
    fn ex_l3_scales_nearly_linearly() {
        let ex = MachineSpec::nehalem_ex();
        let b1 = ex.olc_bandwidth_gbs(1);
        let b8 = ex.olc_bandwidth_gbs(8);
        assert!(b8 / b1 > 7.0, "segmented L3 must scale: {}", b8 / b1);
        let ep = MachineSpec::nehalem_ep();
        let r = ep.olc_bandwidth_gbs(4) / ep.olc_bandwidth_gbs(1);
        assert!(r < 3.0, "EP L3 must saturate: {r}");
    }

    #[test]
    fn lookup_by_name() {
        assert!(MachineSpec::by_name("nehalem-ep").is_some());
        assert!(MachineSpec::by_name("NEHALEM EX").is_some());
        assert!(MachineSpec::by_name("core2").is_some());
        assert!(MachineSpec::by_name("no-such").is_none());
    }

    #[test]
    fn cache_level_sets() {
        let l1 = CacheLevel { bytes: 32 << 10, assoc: 8, shared_by: 1 };
        assert_eq!(l1.sets(), 64);
    }
}
