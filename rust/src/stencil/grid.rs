//! Contiguous 3D grids with the paper's memory layout.
//!
//! Layout is row-major `(z, y, x)` with `x` contiguous — the paper's Fig. 2
//! mapping: the domain decomposes into *lines* (y) and *planes* (z), the
//! innermost loop streams along x so the 7-point stencil becomes five read
//! streams + one write stream.

use std::fmt;

/// A dense, double-precision 3D grid in `(z, y, x)` order.
#[derive(Clone, PartialEq)]
pub struct Grid3 {
    /// Number of planes (z extent).
    pub nz: usize,
    /// Number of lines per plane (y extent).
    pub ny: usize,
    /// Line length (x extent, contiguous).
    pub nx: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Grid3 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Grid3({}x{}x{})", self.nz, self.ny, self.nx)
    }
}

impl Grid3 {
    /// Zero-initialized grid.
    pub fn zeros(nz: usize, ny: usize, nx: usize) -> Self {
        Self { nz, ny, nx, data: vec![0.0; nz * ny * nx] }
    }

    /// Grid initialized from a function of the `(k, j, i)` index.
    pub fn from_fn(nz: usize, ny: usize, nx: usize, f: impl Fn(usize, usize, usize) -> f64) -> Self {
        let mut g = Self::zeros(nz, ny, nx);
        for k in 0..nz {
            for j in 0..ny {
                for i in 0..nx {
                    let idx = g.idx(k, j, i);
                    g.data[idx] = f(k, j, i);
                }
            }
        }
        g
    }

    /// Deterministic pseudo-random grid (xorshift; test/bench workloads).
    pub fn random(nz: usize, ny: usize, nx: usize, seed: u64) -> Self {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // map to (-1, 1)
            (state as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let data = (0..nz * ny * nx).map(|_| next()).collect();
        Self { nz, ny, nx, data }
    }

    /// Total number of lattice sites.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the grid has no sites.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of *interior* (updateable) sites.
    #[inline]
    pub fn interior_len(&self) -> usize {
        self.nz.saturating_sub(2) * self.ny.saturating_sub(2) * self.nx.saturating_sub(2)
    }

    /// Linear index of `(k, j, i)`.
    #[inline(always)]
    pub fn idx(&self, k: usize, j: usize, i: usize) -> usize {
        (k * self.ny + j) * self.nx + i
    }

    /// Value at `(k, j, i)`.
    #[inline(always)]
    pub fn get(&self, k: usize, j: usize, i: usize) -> f64 {
        self.data[self.idx(k, j, i)]
    }

    /// Mutable value at `(k, j, i)`.
    #[inline(always)]
    pub fn set(&mut self, k: usize, j: usize, i: usize, v: f64) {
        let idx = self.idx(k, j, i);
        self.data[idx] = v;
    }

    /// Raw data slice.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// One x-line `(k, j, ..)` as a slice.
    #[inline]
    pub fn line(&self, k: usize, j: usize) -> &[f64] {
        let s = self.idx(k, j, 0);
        &self.data[s..s + self.nx]
    }

    /// One x-line as a mutable slice.
    #[inline]
    pub fn line_mut(&mut self, k: usize, j: usize) -> &mut [f64] {
        let s = self.idx(k, j, 0);
        &mut self.data[s..s + self.nx]
    }

    /// One z-plane as a slice of `ny * nx` values.
    #[inline]
    pub fn plane(&self, k: usize) -> &[f64] {
        let s = self.idx(k, 0, 0);
        &self.data[s..s + self.ny * self.nx]
    }

    /// Maximum absolute difference against another grid of the same shape.
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Shape tuple `(nz, ny, nx)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.nz, self.ny, self.nx)
    }

    /// Euclidean norm of all values.
    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Memory footprint in bytes (the paper's working-set accounting).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }

    /// True if `(k, j, i)` lies on the Dirichlet boundary.
    #[inline]
    pub fn is_boundary(&self, k: usize, j: usize, i: usize) -> bool {
        k == 0 || k == self.nz - 1 || j == 0 || j == self.ny - 1 || i == 0 || i == self.nx - 1
    }

    /// Copy every value from `other` (shapes must match).
    pub fn copy_from(&mut self, other: &Self) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        self.data.copy_from_slice(&other.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_row_major_zyx() {
        let g = Grid3::from_fn(2, 3, 4, |k, j, i| (k * 100 + j * 10 + i) as f64);
        assert_eq!(g.idx(0, 0, 1) - g.idx(0, 0, 0), 1, "x is contiguous");
        assert_eq!(g.idx(0, 1, 0) - g.idx(0, 0, 0), 4, "y stride = nx");
        assert_eq!(g.idx(1, 0, 0) - g.idx(0, 0, 0), 12, "z stride = ny*nx");
        assert_eq!(g.get(1, 2, 3), 123.0);
    }

    #[test]
    fn line_and_plane_views() {
        let g = Grid3::from_fn(3, 3, 5, |k, j, i| (k * 100 + j * 10 + i) as f64);
        assert_eq!(g.line(1, 2), &[120.0, 121.0, 122.0, 123.0, 124.0]);
        assert_eq!(g.plane(2).len(), 15);
        assert_eq!(g.plane(2)[0], 200.0);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = Grid3::random(4, 4, 4, 7);
        let b = Grid3::random(4, 4, 4, 7);
        let c = Grid3::random(4, 4, 4, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn diff_and_norms() {
        let a = Grid3::zeros(2, 2, 2);
        let mut b = Grid3::zeros(2, 2, 2);
        b.set(1, 1, 1, 3.0);
        assert_eq!(a.max_abs_diff(&b), 3.0);
        assert_eq!(b.l2_norm(), 3.0);
    }

    #[test]
    fn boundary_predicate() {
        let g = Grid3::zeros(4, 4, 4);
        assert!(g.is_boundary(0, 2, 2));
        assert!(g.is_boundary(3, 2, 2));
        assert!(g.is_boundary(2, 0, 2));
        assert!(g.is_boundary(2, 2, 3));
        assert!(!g.is_boundary(1, 1, 1));
        assert!(!g.is_boundary(2, 2, 2));
    }

    #[test]
    fn interior_len_counts() {
        let g = Grid3::zeros(4, 5, 6);
        assert_eq!(g.interior_len(), 2 * 3 * 4);
        let tiny = Grid3::zeros(2, 5, 5);
        assert_eq!(tiny.interior_len(), 0);
    }

    #[test]
    fn bytes_accounting() {
        let g = Grid3::zeros(10, 10, 10);
        assert_eq!(g.bytes(), 8000);
    }
}
