//! The Jacobi smoother: line-update kernel and sweeps (paper Sec. 3).
//!
//! The paper implements one optimized *line update kernel* and reuses it
//! for every parallel variant, which "only modify the processing order of
//! the outer loop nests". We follow the same discipline: every schedule in
//! [`crate::coordinator`] funnels through [`jacobi_line_update`], so a
//! correctness result for the serial sweep transfers to all of them.
//!
//! The update solves a Poisson problem `-Δu = f`:
//!
//! ```text
//! dst[k][j][i] = 1/6 ( src[k][j][i-1] + src[k][j][i+1]
//!                    + src[k][j-1][i] + src[k][j+1][i]
//!                    + src[k-1][j][i] + src[k+1][j][i] + h²·f[k][j][i] )
//! ```
//!
//! Dirichlet boundaries: face values are never written.

use super::grid::Grid3;

/// Central stencil weight.
pub const ONE_SIXTH: f64 = 1.0 / 6.0;

/// The paper's line update kernel: one x-line of a Jacobi update.
///
/// Maps the 7-point stencil onto five read streams (`center` ± x handled
/// in-line, `ym`/`yp` the y-neighbor lines, `zm`/`zp` the z-neighbor
/// lines) plus the `dst` write stream — exactly the Fig. 2 access pattern.
/// Interior x only; `dst[0]` and `dst[nx-1]` are left untouched.
#[inline]
pub fn jacobi_line_update(
    dst: &mut [f64],
    center: &[f64],
    ym: &[f64],
    yp: &[f64],
    zm: &[f64],
    zp: &[f64],
    rhs: &[f64],
    h2: f64,
) {
    let nx = dst.len();
    debug_assert!(
        center.len() == nx && ym.len() == nx && yp.len() == nx && zm.len() == nx && zp.len() == nx
    );
    // The compiler vectorizes this loop (no loop-carried dependency) — the
    // analog of the paper's SIMD-ized assembly kernel.
    for i in 1..nx - 1 {
        dst[i] = ONE_SIXTH
            * (center[i - 1]
                + center[i + 1]
                + ym[i]
                + yp[i]
                + zm[i]
                + zp[i]
                + h2 * rhs[i]);
    }
}

/// Update one interior plane `k` of `dst` from `src`.
pub fn jacobi_plane(dst: &mut Grid3, src: &Grid3, f: &Grid3, h2: f64, k: usize) {
    debug_assert!(k >= 1 && k + 1 < src.nz);
    let ny = src.ny;
    for j in 1..ny - 1 {
        jacobi_plane_line(dst, src, f, h2, k, j);
    }
}

/// Update one interior line `(k, j)` of `dst` from `src`.
///
/// The granularity every coordinator schedule dispatches at.
#[inline]
pub fn jacobi_plane_line(dst: &mut Grid3, src: &Grid3, f: &Grid3, h2: f64, k: usize, j: usize) {
    let nx = src.nx;
    let d = dst.idx(k, j, 0);
    // Split borrows: dst line is disjoint from all src/f reads.
    let (center, ym, yp, zm, zp, rhs) = (
        src.line(k, j),
        src.line(k, j - 1),
        src.line(k, j + 1),
        src.line(k - 1, j),
        src.line(k + 1, j),
        f.line(k, j),
    );
    let dst_line = &mut dst.data_mut()[d..d + nx];
    jacobi_line_update(dst_line, center, ym, yp, zm, zp, rhs, h2);
}

/// One full out-of-place Jacobi sweep; boundary of `dst` copied from `src`.
pub fn jacobi_sweep(dst: &mut Grid3, src: &Grid3, f: &Grid3, h2: f64) {
    assert_eq!(dst.shape(), src.shape());
    assert_eq!(f.shape(), src.shape());
    dst.copy_from(src); // boundary (and a safe default for degenerate dims)
    if src.nz < 3 || src.ny < 3 || src.nx < 3 {
        return;
    }
    for k in 1..src.nz - 1 {
        jacobi_plane(dst, src, f, h2, k);
    }
}

/// `n` Jacobi steps with double buffering; result returned.
pub fn jacobi_steps(u: &Grid3, f: &Grid3, h2: f64, n: usize) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        jacobi_sweep(&mut b, &a, f, h2);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn harmonic(nz: usize, ny: usize, nx: usize) -> Grid3 {
        Grid3::from_fn(nz, ny, nx, |k, j, i| i as f64 + 2.0 * j as f64 - 3.0 * k as f64)
    }

    #[test]
    fn harmonic_field_is_fixed_point() {
        let u = harmonic(6, 6, 6);
        let f = Grid3::zeros(6, 6, 6);
        let mut dst = Grid3::zeros(6, 6, 6);
        jacobi_sweep(&mut dst, &u, &f, 1.0);
        assert!(u.max_abs_diff(&dst) < 1e-13);
    }

    #[test]
    fn matches_direct_formula() {
        let u = Grid3::random(5, 6, 7, 42);
        let f = Grid3::random(5, 6, 7, 43);
        let h2 = 0.7;
        let mut dst = Grid3::zeros(5, 6, 7);
        jacobi_sweep(&mut dst, &u, &f, h2);
        for k in 1..4 {
            for j in 1..5 {
                for i in 1..6 {
                    let want = ONE_SIXTH
                        * (u.get(k, j, i - 1)
                            + u.get(k, j, i + 1)
                            + u.get(k, j - 1, i)
                            + u.get(k, j + 1, i)
                            + u.get(k - 1, j, i)
                            + u.get(k + 1, j, i)
                            + h2 * f.get(k, j, i));
                    assert!((dst.get(k, j, i) - want).abs() < 1e-15);
                }
            }
        }
    }

    #[test]
    fn boundary_is_copied() {
        let u = Grid3::random(4, 4, 4, 1);
        let f = Grid3::random(4, 4, 4, 2);
        let mut dst = Grid3::zeros(4, 4, 4);
        jacobi_sweep(&mut dst, &u, &f, 1.0);
        for k in 0..4 {
            for j in 0..4 {
                for i in 0..4 {
                    if u.is_boundary(k, j, i) {
                        assert_eq!(dst.get(k, j, i), u.get(k, j, i));
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_grids_are_identity() {
        let u = Grid3::random(2, 5, 5, 3);
        let f = Grid3::zeros(2, 5, 5);
        let mut dst = Grid3::zeros(2, 5, 5);
        jacobi_sweep(&mut dst, &u, &f, 1.0);
        assert_eq!(dst, u);
    }

    #[test]
    fn steps_compose() {
        let u = Grid3::random(5, 5, 5, 9);
        let f = Grid3::random(5, 5, 5, 10);
        let two = jacobi_steps(&u, &f, 1.0, 2);
        let one = jacobi_steps(&u, &f, 1.0, 1);
        let one_one = jacobi_steps(&one, &f, 1.0, 1);
        assert_eq!(two.max_abs_diff(&one_one), 0.0);
    }

    #[test]
    fn line_granularity_equals_plane_granularity() {
        let u = Grid3::random(5, 6, 7, 11);
        let f = Grid3::random(5, 6, 7, 12);
        let mut by_plane = Grid3::zeros(5, 6, 7);
        let mut by_line = Grid3::zeros(5, 6, 7);
        by_plane.copy_from(&u);
        by_line.copy_from(&u);
        for k in 1..4 {
            jacobi_plane(&mut by_plane, &u, &f, 1.0, k);
            for j in 1..5 {
                jacobi_plane_line(&mut by_line, &u, &f, 1.0, k, j);
            }
        }
        assert_eq!(by_plane.max_abs_diff(&by_line), 0.0);
    }
}
