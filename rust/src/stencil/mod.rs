//! Numerical substrate: 3D grids, stencil kernels, residuals, STREAM.
//!
//! Everything in this module is *serial* building blocks — the paper's
//! "line update kernel" (Sec. 3) and friends. Parallel schedules over these
//! kernels live in [`crate::coordinator`]; performance models over them in
//! [`crate::simulator`].

pub mod gauss_seidel;
pub mod grid;
pub mod jacobi;
pub mod op;
pub mod residual;
pub mod simd;
pub mod streambench;

/// Bytes per lattice-site update (double precision).
///
/// The paper's Eq. (1) traffic accounting: a Jacobi update with
/// non-temporal stores moves 8 B (load of `src`) + 8 B (store of `dst`);
/// without NT stores the write-allocate adds another 8 B load.
pub const BYTES_PER_LUP_NT: f64 = 16.0;
/// Bytes per LUP when the store incurs a write-allocate (no NT stores).
pub const BYTES_PER_LUP_NO_NT: f64 = 24.0;
