//! Runtime-dispatched SIMD line kernels with real non-temporal stores.
//!
//! The paper's optimized kernels are SIMD-ized assembly with streaming
//! (non-temporal) stores on the Jacobi write stream; until this module
//! existed, the crate's kernels were scalar and `StoreMode::NonTemporal`
//! lived only inside the ECM model. Here every [`StencilOp`] line update
//! has an AVX leg (`std::arch` x86_64 intrinsics, stable) selected at
//! runtime, and the NT flavour issues actual `_mm256_stream_pd` stores —
//! scalar head to 32-byte alignment, streamed 4-lane body, scalar tail,
//! one `_mm_sfence` per line — so the `nt_stores` config key finally
//! changes the executed code, not just the prediction.
//!
//! **Bit-exactness contract.** The scalar kernels are the reference; the
//! vector legs perform the identical fp operations in the identical
//! per-site association (element-wise adds/muls in the same order,
//! `_mm256_div_pd` is correctly rounded like scalar divide), so SIMD
//! on/off and NT on/off are all bit-identical — asserted across the full
//! scheme × op matrix by `tests/simd_parity.rs`. The Gauss-Seidel forms
//! carry an x recursion; their vector legs gather the four recursion-free
//! partial sums per 4-lane chunk (all loads precede any store of the
//! chunk) and close the recursion scalar per lane in ascending order,
//! which reproduces the naive recursion bit for bit.
//!
//! Dispatch: [`Isa::detect`] probes once (cached), honours the
//! `STENCILWAVE_FORCE_SCALAR` env (CI's forced-scalar leg) and can be
//! overridden by tests via [`Isa::force`]. On non-x86_64 targets the
//! scalar path is the only path.

use super::gauss_seidel::{gs_line_update_interleaved, gs_line_update_naive, GsKernel};
use super::jacobi::{jacobi_line_update, ONE_SIXTH};
use super::op::{GsWindow, StarWindow};
use crate::simulator::memory::StoreMode;
use std::sync::atomic::{AtomicU8, Ordering};

/// `1/90`, the inverse diagonal of the 4th-order 13-point operator.
pub(crate) const INV_90: f64 = 1.0 / 90.0;

/// One radius-2 site: `(16·S₁ − S₂ + 12h²f) / 90`. Shared by the scalar
/// and vector legs (and `op.rs`) so the association cannot drift.
#[inline(always)]
pub(crate) fn l13_site(s1: f64, s2: f64, rhs12h2: f64) -> f64 {
    (16.0 * s1 - s2 + rhs12h2) * INV_90
}

/// Instruction set a line kernel runs with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels — the bit-exactness reference and the
    /// only path off x86_64.
    Scalar,
    /// 4-lane AVX (`__m256d`) kernels with optional streaming stores.
    Avx,
}

/// Cached dispatch decision: 0 = undecided, 1 = scalar, 2 = AVX.
static ISA_CACHE: AtomicU8 = AtomicU8::new(0);

/// True when the CPU supports the AVX leg.
fn hw_avx() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

impl Isa {
    /// The ISA every non-`_with` kernel entry point dispatches to.
    /// Probed once (hardware + `STENCILWAVE_FORCE_SCALAR`) and cached.
    pub fn detect() -> Isa {
        match ISA_CACHE.load(Ordering::Relaxed) {
            1 => Isa::Scalar,
            2 => Isa::Avx,
            _ => {
                let isa = Self::probe();
                ISA_CACHE.store(if isa == Isa::Avx { 2 } else { 1 }, Ordering::Relaxed);
                isa
            }
        }
    }

    fn probe() -> Isa {
        let forced_scalar = crate::env_flag("STENCILWAVE_FORCE_SCALAR");
        if !forced_scalar && hw_avx() {
            Isa::Avx
        } else {
            Isa::Scalar
        }
    }

    /// Test hook: pin the dispatch decision (`None` re-probes lazily).
    /// A forced `Avx` is clamped to `Scalar` on hardware without AVX, so
    /// forcing can never make a dispatcher execute unsupported code.
    /// Process-global — tests driving it belong in their own process
    /// (see `tests/simd_parity.rs`), though because every ISA produces
    /// bit-identical results a mid-run flip is benign.
    pub fn force(isa: Option<Isa>) {
        let v = match isa {
            None => 0,
            Some(Isa::Scalar) => 1,
            Some(Isa::Avx) => {
                if hw_avx() {
                    2
                } else {
                    1
                }
            }
        };
        ISA_CACHE.store(v, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// dispatching entry points (one per StencilOp line-update flavour)

/// 7-point constant-coefficient Jacobi line update (interior x only),
/// with the store stream issued per `store`.
#[inline]
pub fn jacobi7(dst: &mut [f64], win: &StarWindow<'_>, rhs: &[f64], h2: f64, store: StoreMode) {
    jacobi7_with(Isa::detect(), dst, win, rhs, h2, store)
}

/// [`jacobi7`] at an explicit ISA (the parity-test entry point).
pub fn jacobi7_with(
    isa: Isa,
    dst: &mut [f64],
    win: &StarWindow<'_>,
    rhs: &[f64],
    h2: f64,
    store: StoreMode,
) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // SAFETY: `Isa::Avx` is only ever produced when AVX was detected
        // (Isa::force clamps an unsupported request to Scalar).
        unsafe { avx::jacobi7(dst, win, rhs, h2, store) };
        return;
    }
    let _ = (isa, store); // scalar stores are plain; NT is value-identical
    jacobi_line_update(dst, win.center, win.ym[0], win.yp[0], win.zm[0], win.zp[0], rhs, h2);
}

/// Variable-coefficient (Helmholtz-style) 7-point Jacobi line update:
/// divides by the variable diagonal `6 + h²λ`.
#[inline]
pub fn varcoeff7(
    dst: &mut [f64],
    win: &StarWindow<'_>,
    rhs: &[f64],
    lam: &[f64],
    h2: f64,
    store: StoreMode,
) {
    varcoeff7_with(Isa::detect(), dst, win, rhs, lam, h2, store)
}

/// [`varcoeff7`] at an explicit ISA.
#[allow(clippy::too_many_arguments)]
pub fn varcoeff7_with(
    isa: Isa,
    dst: &mut [f64],
    win: &StarWindow<'_>,
    rhs: &[f64],
    lam: &[f64],
    h2: f64,
    store: StoreMode,
) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::varcoeff7(dst, win, rhs, lam, h2, store) };
        return;
    }
    let _ = (isa, store);
    let nx = dst.len();
    if nx < 3 {
        return;
    }
    let (c, ym, yp, zm, zp) = (win.center, win.ym[0], win.yp[0], win.zm[0], win.zp[0]);
    for i in 1..nx - 1 {
        dst[i] = (c[i - 1] + c[i + 1] + ym[i] + yp[i] + zm[i] + zp[i] + h2 * rhs[i])
            / (6.0 + h2 * lam[i]);
    }
}

/// 4th-order 13-point (radius-2) Jacobi line update.
#[inline]
pub fn laplace13(dst: &mut [f64], win: &StarWindow<'_>, rhs: &[f64], h2: f64, store: StoreMode) {
    laplace13_with(Isa::detect(), dst, win, rhs, h2, store)
}

/// [`laplace13`] at an explicit ISA.
pub fn laplace13_with(
    isa: Isa,
    dst: &mut [f64],
    win: &StarWindow<'_>,
    rhs: &[f64],
    h2: f64,
    store: StoreMode,
) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::laplace13(dst, win, rhs, h2, store) };
        return;
    }
    let _ = (isa, store);
    let nx = dst.len();
    if nx < 5 {
        return;
    }
    let c = win.center;
    let (ym1, yp1, zm1, zp1) = (win.ym[0], win.yp[0], win.zm[0], win.zp[0]);
    let (ym2, yp2, zm2, zp2) = (win.ym[1], win.yp[1], win.zm[1], win.zp[1]);
    let f12 = 12.0 * h2;
    for i in 2..nx - 2 {
        let s1 = c[i - 1] + c[i + 1] + ym1[i] + yp1[i] + zm1[i] + zp1[i];
        let s2 = c[i - 2] + c[i + 2] + ym2[i] + yp2[i] + zm2[i] + zp2[i];
        dst[i] = l13_site(s1, s2, f12 * rhs[i]);
    }
}

/// 7-point constant-coefficient Gauss-Seidel line update (in place; no
/// store mode — the store hits the line the load just brought in).
#[inline]
pub fn gs7(line: &mut [f64], win: &GsWindow<'_>, kernel: GsKernel) {
    gs7_with(Isa::detect(), line, win, kernel)
}

/// [`gs7`] at an explicit ISA.
pub fn gs7_with(isa: Isa, line: &mut [f64], win: &GsWindow<'_>, kernel: GsKernel) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // One AVX routine serves both kernel flavours: Naive and
        // Interleaved are bit-identical by construction, and the chunked
        // gather below subsumes the interleaving (4 partial sums in
        // flight instead of 2).
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::gs7(line, win) };
        return;
    }
    let _ = isa;
    match kernel {
        GsKernel::Naive => {
            gs_line_update_naive(line, win.ym_new[0], win.yp_old[0], win.zm_new[0], win.zp_old[0])
        }
        GsKernel::Interleaved => gs_line_update_interleaved(
            line,
            win.ym_new[0],
            win.yp_old[0],
            win.zm_new[0],
            win.zp_old[0],
        ),
    }
}

/// Variable-coefficient 7-point Gauss-Seidel line update.
#[inline]
pub fn gs_var7(line: &mut [f64], win: &GsWindow<'_>, lam: &[f64]) {
    gs_var7_with(Isa::detect(), line, win, lam)
}

/// [`gs_var7`] at an explicit ISA.
pub fn gs_var7_with(isa: Isa, line: &mut [f64], win: &GsWindow<'_>, lam: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::gs_var7(line, win, lam) };
        return;
    }
    let _ = isa;
    let nx = line.len();
    if nx < 3 {
        return;
    }
    for i in 1..nx - 1 {
        let nb = line[i + 1]
            + win.ym_new[0][i]
            + win.yp_old[0][i]
            + win.zm_new[0][i]
            + win.zp_old[0][i];
        line[i] = (line[i - 1] + nb) / (6.0 + lam[i]);
    }
}

/// Radius-2 13-point Gauss-Seidel line update.
#[inline]
pub fn gs13(line: &mut [f64], win: &GsWindow<'_>) {
    gs13_with(Isa::detect(), line, win)
}

/// [`gs13`] at an explicit ISA.
pub fn gs13_with(isa: Isa, line: &mut [f64], win: &GsWindow<'_>) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx {
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::gs13(line, win) };
        return;
    }
    let _ = isa;
    let nx = line.len();
    if nx < 5 {
        return;
    }
    for i in 2..nx - 2 {
        // Recursion-free terms first (t1/t2), recursion terms joined per
        // shell — the grouping the chunked vector leg reproduces exactly.
        let t1 = line[i + 1]
            + win.ym_new[0][i]
            + win.yp_old[0][i]
            + win.zm_new[0][i]
            + win.zp_old[0][i];
        let t2 = line[i + 2]
            + win.ym_new[1][i]
            + win.yp_old[1][i]
            + win.zm_new[1][i]
            + win.zp_old[1][i];
        line[i] = l13_site(line[i - 1] + t1, line[i - 2] + t2, 0.0);
    }
}

/// Copy `src` into `dst` (equal lengths), streaming the stores when
/// `store` is non-temporal — the write stream of a schedule's final-level
/// result copy, which is never re-read within the pass.
pub fn stream_copy(dst: &mut [f64], src: &[f64], store: StoreMode) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if store == StoreMode::NonTemporal && Isa::detect() == Isa::Avx {
        // SAFETY: Avx implies the feature was detected (see jacobi7_with).
        unsafe { avx::stream_copy(dst, src) };
        return;
    }
    let _ = store;
    dst.copy_from_slice(src);
}

// ---------------------------------------------------------------------------
// AVX legs (x86_64 only)

#[cfg(target_arch = "x86_64")]
mod avx {
    use super::*;
    use std::arch::x86_64::*;

    /// Interior-store loop shared by the out-of-place kernels: 4-lane
    /// body with plain or streaming stores, scalar head/tail. The NT arm
    /// runs a scalar head up to 32-byte alignment of `dst` (stream
    /// stores require it), then `_mm256_stream_pd`, then one `_mm_sfence`
    /// so the weakly-ordered stores are globally visible before the
    /// schedule publishes progress.
    macro_rules! store_loop {
        ($dst:ident, $lo:expr, $hi:expr, $store:expr, $i:ident, $vec:expr, $site:expr) => {{
            let lo: usize = $lo;
            let hi: usize = $hi;
            let mut $i = lo;
            match $store {
                StoreMode::WriteAllocate => {
                    while $i + 4 <= hi {
                        let v = $vec;
                        _mm256_storeu_pd($dst.as_mut_ptr().add($i), v);
                        $i += 4;
                    }
                    while $i < hi {
                        $dst[$i] = $site;
                        $i += 1;
                    }
                }
                StoreMode::NonTemporal => {
                    while $i < hi && ($dst.as_ptr().add($i) as usize) & 31 != 0 {
                        $dst[$i] = $site;
                        $i += 1;
                    }
                    let body_end = if $i < hi { $i + (hi - $i) / 4 * 4 } else { $i };
                    let streamed = $i < body_end;
                    while $i < body_end {
                        let v = $vec;
                        _mm256_stream_pd($dst.as_mut_ptr().add($i), v);
                        $i += 4;
                    }
                    while $i < hi {
                        $dst[$i] = $site;
                        $i += 1;
                    }
                    if streamed {
                        _mm_sfence();
                    }
                }
            }
        }};
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn jacobi7(
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        store: StoreMode,
    ) {
        let nx = dst.len();
        if nx < 3 {
            return;
        }
        let (c, ym, yp, zm, zp) = (win.center, win.ym[0], win.yp[0], win.zm[0], win.zp[0]);
        let sixth = _mm256_set1_pd(ONE_SIXTH);
        let h2v = _mm256_set1_pd(h2);
        store_loop!(
            dst,
            1,
            nx - 1,
            store,
            i,
            {
                // same association as jacobi_line_update, 4 sites at a time
                let s = _mm256_add_pd(
                    _mm256_loadu_pd(c.as_ptr().add(i - 1)),
                    _mm256_loadu_pd(c.as_ptr().add(i + 1)),
                );
                let s = _mm256_add_pd(s, _mm256_loadu_pd(ym.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(yp.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(zm.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(zp.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_mul_pd(h2v, _mm256_loadu_pd(rhs.as_ptr().add(i))));
                _mm256_mul_pd(sixth, s)
            },
            ONE_SIXTH * (c[i - 1] + c[i + 1] + ym[i] + yp[i] + zm[i] + zp[i] + h2 * rhs[i])
        );
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn varcoeff7(
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        lam: &[f64],
        h2: f64,
        store: StoreMode,
    ) {
        let nx = dst.len();
        if nx < 3 {
            return;
        }
        let (c, ym, yp, zm, zp) = (win.center, win.ym[0], win.yp[0], win.zm[0], win.zp[0]);
        let h2v = _mm256_set1_pd(h2);
        let six = _mm256_set1_pd(6.0);
        store_loop!(
            dst,
            1,
            nx - 1,
            store,
            i,
            {
                let s = _mm256_add_pd(
                    _mm256_loadu_pd(c.as_ptr().add(i - 1)),
                    _mm256_loadu_pd(c.as_ptr().add(i + 1)),
                );
                let s = _mm256_add_pd(s, _mm256_loadu_pd(ym.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(yp.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(zm.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_loadu_pd(zp.as_ptr().add(i)));
                let s = _mm256_add_pd(s, _mm256_mul_pd(h2v, _mm256_loadu_pd(rhs.as_ptr().add(i))));
                let den =
                    _mm256_add_pd(six, _mm256_mul_pd(h2v, _mm256_loadu_pd(lam.as_ptr().add(i))));
                // _mm256_div_pd is correctly rounded: bit-equal to scalar /
                _mm256_div_pd(s, den)
            },
            (c[i - 1] + c[i + 1] + ym[i] + yp[i] + zm[i] + zp[i] + h2 * rhs[i])
                / (6.0 + h2 * lam[i])
        );
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn laplace13(
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        store: StoreMode,
    ) {
        let nx = dst.len();
        if nx < 5 {
            return;
        }
        let c = win.center;
        let (ym1, yp1, zm1, zp1) = (win.ym[0], win.yp[0], win.zm[0], win.zp[0]);
        let (ym2, yp2, zm2, zp2) = (win.ym[1], win.yp[1], win.zm[1], win.zp[1]);
        let f12 = 12.0 * h2;
        let f12v = _mm256_set1_pd(f12);
        let sixteen = _mm256_set1_pd(16.0);
        let inv90 = _mm256_set1_pd(INV_90);
        store_loop!(
            dst,
            2,
            nx - 2,
            store,
            i,
            {
                let s1 = _mm256_add_pd(
                    _mm256_loadu_pd(c.as_ptr().add(i - 1)),
                    _mm256_loadu_pd(c.as_ptr().add(i + 1)),
                );
                let s1 = _mm256_add_pd(s1, _mm256_loadu_pd(ym1.as_ptr().add(i)));
                let s1 = _mm256_add_pd(s1, _mm256_loadu_pd(yp1.as_ptr().add(i)));
                let s1 = _mm256_add_pd(s1, _mm256_loadu_pd(zm1.as_ptr().add(i)));
                let s1 = _mm256_add_pd(s1, _mm256_loadu_pd(zp1.as_ptr().add(i)));
                let s2 = _mm256_add_pd(
                    _mm256_loadu_pd(c.as_ptr().add(i - 2)),
                    _mm256_loadu_pd(c.as_ptr().add(i + 2)),
                );
                let s2 = _mm256_add_pd(s2, _mm256_loadu_pd(ym2.as_ptr().add(i)));
                let s2 = _mm256_add_pd(s2, _mm256_loadu_pd(yp2.as_ptr().add(i)));
                let s2 = _mm256_add_pd(s2, _mm256_loadu_pd(zm2.as_ptr().add(i)));
                let s2 = _mm256_add_pd(s2, _mm256_loadu_pd(zp2.as_ptr().add(i)));
                let v = _mm256_add_pd(
                    _mm256_sub_pd(_mm256_mul_pd(sixteen, s1), s2),
                    _mm256_mul_pd(f12v, _mm256_loadu_pd(rhs.as_ptr().add(i))),
                );
                _mm256_mul_pd(v, inv90)
            },
            l13_site(
                c[i - 1] + c[i + 1] + ym1[i] + yp1[i] + zm1[i] + zp1[i],
                c[i - 2] + c[i + 2] + ym2[i] + yp2[i] + zm2[i] + zp2[i],
                f12 * rhs[i],
            )
        );
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn gs7(line: &mut [f64], win: &GsWindow<'_>) {
        let nx = line.len();
        if nx < 3 {
            return;
        }
        let (ym, yp, zm, zp) =
            (win.ym_new[0], win.yp_old[0], win.zm_new[0], win.zp_old[0]);
        let hi = nx - 1;
        let mut i = 1usize;
        while i + 4 <= hi {
            // Recursion-free partial sums of 4 sites, gathered before any
            // store of the chunk touches line[i..i+4] (line[i+1..i+5] are
            // loaded here as *old* values — exactly what the ascending
            // scalar recursion would read).
            let s = _mm256_add_pd(
                _mm256_loadu_pd(line.as_ptr().add(i + 1)),
                _mm256_loadu_pd(ym.as_ptr().add(i)),
            );
            let s = _mm256_add_pd(s, _mm256_loadu_pd(yp.as_ptr().add(i)));
            let s = _mm256_add_pd(s, _mm256_loadu_pd(zm.as_ptr().add(i)));
            let s = _mm256_add_pd(s, _mm256_loadu_pd(zp.as_ptr().add(i)));
            let mut tmp = [0.0f64; 4];
            _mm256_storeu_pd(tmp.as_mut_ptr(), s);
            for (l, t) in tmp.iter().enumerate() {
                line[i + l] = ONE_SIXTH * (line[i + l - 1] + t);
            }
            i += 4;
        }
        while i < hi {
            line[i] = ONE_SIXTH * (line[i - 1] + (line[i + 1] + ym[i] + yp[i] + zm[i] + zp[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn gs_var7(line: &mut [f64], win: &GsWindow<'_>, lam: &[f64]) {
        let nx = line.len();
        if nx < 3 {
            return;
        }
        let (ym, yp, zm, zp) =
            (win.ym_new[0], win.yp_old[0], win.zm_new[0], win.zp_old[0]);
        let six = _mm256_set1_pd(6.0);
        let hi = nx - 1;
        let mut i = 1usize;
        while i + 4 <= hi {
            let s = _mm256_add_pd(
                _mm256_loadu_pd(line.as_ptr().add(i + 1)),
                _mm256_loadu_pd(ym.as_ptr().add(i)),
            );
            let s = _mm256_add_pd(s, _mm256_loadu_pd(yp.as_ptr().add(i)));
            let s = _mm256_add_pd(s, _mm256_loadu_pd(zm.as_ptr().add(i)));
            let s = _mm256_add_pd(s, _mm256_loadu_pd(zp.as_ptr().add(i)));
            let den = _mm256_add_pd(six, _mm256_loadu_pd(lam.as_ptr().add(i)));
            let mut tmp = [0.0f64; 4];
            let mut dv = [0.0f64; 4];
            _mm256_storeu_pd(tmp.as_mut_ptr(), s);
            _mm256_storeu_pd(dv.as_mut_ptr(), den);
            for l in 0..4 {
                line[i + l] = (line[i + l - 1] + tmp[l]) / dv[l];
            }
            i += 4;
        }
        while i < hi {
            line[i] = (line[i - 1] + (line[i + 1] + ym[i] + yp[i] + zm[i] + zp[i]))
                / (6.0 + lam[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn gs13(line: &mut [f64], win: &GsWindow<'_>) {
        let nx = line.len();
        if nx < 5 {
            return;
        }
        let (ym1, yp1, zm1, zp1) =
            (win.ym_new[0], win.yp_old[0], win.zm_new[0], win.zp_old[0]);
        let (ym2, yp2, zm2, zp2) =
            (win.ym_new[1], win.yp_old[1], win.zm_new[1], win.zp_old[1]);
        let hi = nx - 2;
        let mut i = 2usize;
        while i + 4 <= hi {
            // line[i+1..i+5] and line[i+2..i+6] loaded before the chunk
            // writes line[i..i+4]: both shells read *old* values, which is
            // what the ascending recursion reads (i+1, i+2 are always
            // ahead of the write index).
            let t1 = _mm256_add_pd(
                _mm256_loadu_pd(line.as_ptr().add(i + 1)),
                _mm256_loadu_pd(ym1.as_ptr().add(i)),
            );
            let t1 = _mm256_add_pd(t1, _mm256_loadu_pd(yp1.as_ptr().add(i)));
            let t1 = _mm256_add_pd(t1, _mm256_loadu_pd(zm1.as_ptr().add(i)));
            let t1 = _mm256_add_pd(t1, _mm256_loadu_pd(zp1.as_ptr().add(i)));
            let t2 = _mm256_add_pd(
                _mm256_loadu_pd(line.as_ptr().add(i + 2)),
                _mm256_loadu_pd(ym2.as_ptr().add(i)),
            );
            let t2 = _mm256_add_pd(t2, _mm256_loadu_pd(yp2.as_ptr().add(i)));
            let t2 = _mm256_add_pd(t2, _mm256_loadu_pd(zm2.as_ptr().add(i)));
            let t2 = _mm256_add_pd(t2, _mm256_loadu_pd(zp2.as_ptr().add(i)));
            let mut a1 = [0.0f64; 4];
            let mut a2 = [0.0f64; 4];
            _mm256_storeu_pd(a1.as_mut_ptr(), t1);
            _mm256_storeu_pd(a2.as_mut_ptr(), t2);
            for l in 0..4 {
                // recursion closes scalar per lane, ascending: lanes read
                // line[i+l-1] / line[i+l-2], already updated below them
                line[i + l] = l13_site(line[i + l - 1] + a1[l], line[i + l - 2] + a2[l], 0.0);
            }
            i += 4;
        }
        while i < hi {
            let t1 = line[i + 1] + ym1[i] + yp1[i] + zm1[i] + zp1[i];
            let t2 = line[i + 2] + ym2[i] + yp2[i] + zm2[i] + zp2[i];
            line[i] = l13_site(line[i - 1] + t1, line[i - 2] + t2, 0.0);
            i += 1;
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn stream_copy(dst: &mut [f64], src: &[f64]) {
        let n = dst.len().min(src.len());
        let mut i = 0usize;
        while i < n && (dst.as_ptr().add(i) as usize) & 31 != 0 {
            dst[i] = src[i];
            i += 1;
        }
        let body_end = i + (n - i) / 4 * 4;
        let streamed = i < body_end;
        while i < body_end {
            _mm256_stream_pd(dst.as_mut_ptr().add(i), _mm256_loadu_pd(src.as_ptr().add(i)));
            i += 4;
        }
        while i < n {
            dst[i] = src[i];
            i += 1;
        }
        if streamed {
            _mm_sfence();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic line data (xorshift) of length `n`.
    fn data(n: usize, seed: u64) -> Vec<f64> {
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) * 2.0 - 1.0
            })
            .collect()
    }

    struct Lines {
        c: Vec<f64>,
        n1: [Vec<f64>; 4],
        n2: [Vec<f64>; 4],
        rhs: Vec<f64>,
        lam: Vec<f64>,
    }

    fn lines(nx: usize, seed: u64) -> Lines {
        Lines {
            c: data(nx, seed),
            n1: [data(nx, seed + 1), data(nx, seed + 2), data(nx, seed + 3), data(nx, seed + 4)],
            n2: [data(nx, seed + 5), data(nx, seed + 6), data(nx, seed + 7), data(nx, seed + 8)],
            rhs: data(nx, seed + 9),
            lam: data(nx, seed + 10).iter().map(|v| v.abs() + 0.1).collect(),
        }
    }

    fn star(l: &Lines) -> StarWindow<'_> {
        StarWindow {
            center: &l.c,
            ym: [&l.n1[0], &l.n2[0]],
            yp: [&l.n1[1], &l.n2[1]],
            zm: [&l.n1[2], &l.n2[2]],
            zp: [&l.n1[3], &l.n2[3]],
        }
    }

    fn gs_win(l: &Lines) -> GsWindow<'_> {
        GsWindow {
            ym_new: [&l.n1[0], &l.n2[0]],
            yp_old: [&l.n1[1], &l.n2[1]],
            zm_new: [&l.n1[2], &l.n2[2]],
            zp_old: [&l.n1[3], &l.n2[3]],
        }
    }

    /// All lane-remainder shapes: below one lane, exactly one lane,
    /// lane + remainder, many lanes, and the radius-2 minima.
    const WIDTHS: [usize; 12] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 13, 16, 33];

    #[test]
    fn jacobi_kernels_match_scalar_bit_for_bit_at_every_width() {
        for &nx in &WIDTHS {
            for store in [StoreMode::WriteAllocate, StoreMode::NonTemporal] {
                let l = lines(nx, 42 + nx as u64);
                let win = star(&l);
                let mut a = data(nx, 7);
                let mut b = a.clone();
                jacobi7_with(Isa::Scalar, &mut a, &win, &l.rhs, 0.7, StoreMode::WriteAllocate);
                jacobi7_with(Isa::Avx, &mut b, &win, &l.rhs, 0.7, store);
                assert_eq!(a, b, "jacobi7 nx={nx} {store:?}");
                let mut a = data(nx, 8);
                let mut b = a.clone();
                varcoeff7_with(
                    Isa::Scalar,
                    &mut a,
                    &win,
                    &l.rhs,
                    &l.lam,
                    1.3,
                    StoreMode::WriteAllocate,
                );
                varcoeff7_with(Isa::Avx, &mut b, &win, &l.rhs, &l.lam, 1.3, store);
                assert_eq!(a, b, "varcoeff7 nx={nx} {store:?}");
                let mut a = data(nx, 9);
                let mut b = a.clone();
                laplace13_with(Isa::Scalar, &mut a, &win, &l.rhs, 0.6, StoreMode::WriteAllocate);
                laplace13_with(Isa::Avx, &mut b, &win, &l.rhs, 0.6, store);
                assert_eq!(a, b, "laplace13 nx={nx} {store:?}");
            }
        }
    }

    #[test]
    fn gs_kernels_match_scalar_bit_for_bit_at_every_width() {
        for &nx in &WIDTHS {
            let l = lines(nx, 99 + nx as u64);
            let win = gs_win(&l);
            for kernel in [GsKernel::Naive, GsKernel::Interleaved] {
                let mut a = data(nx, 3);
                let mut b = a.clone();
                gs7_with(Isa::Scalar, &mut a, &win, kernel);
                gs7_with(Isa::Avx, &mut b, &win, kernel);
                assert_eq!(a, b, "gs7 nx={nx} {kernel:?}");
            }
            let mut a = data(nx, 4);
            let mut b = a.clone();
            gs_var7_with(Isa::Scalar, &mut a, &win, &l.lam);
            gs_var7_with(Isa::Avx, &mut b, &win, &l.lam);
            assert_eq!(a, b, "gs_var7 nx={nx}");
            let mut a = data(nx, 5);
            let mut b = a.clone();
            gs13_with(Isa::Scalar, &mut a, &win);
            gs13_with(Isa::Avx, &mut b, &win);
            assert_eq!(a, b, "gs13 nx={nx}");
        }
    }

    #[test]
    fn stream_copy_is_exact_for_both_store_modes() {
        for &n in &WIDTHS {
            let src = data(n, 21);
            for store in [StoreMode::WriteAllocate, StoreMode::NonTemporal] {
                let mut dst = vec![0.0; n];
                stream_copy(&mut dst, &src, store);
                assert_eq!(dst, src, "n={n} {store:?}");
            }
        }
    }

    #[test]
    fn misaligned_destinations_stay_exact_under_nt_stores() {
        // slice a big buffer at every offset so the NT head/tail logic
        // sees all four 32-byte phases of the destination pointer
        let nx = 21;
        let l = lines(nx, 1234);
        let win = star(&l);
        let mut buf_a = data(nx + 4, 6);
        let mut buf_b = buf_a.clone();
        for off in 0..4 {
            let a = &mut buf_a[off..off + nx];
            let b = &mut buf_b[off..off + nx];
            jacobi7_with(Isa::Scalar, a, &win, &l.rhs, 0.7, StoreMode::WriteAllocate);
            jacobi7_with(Isa::Avx, b, &win, &l.rhs, 0.7, StoreMode::NonTemporal);
            assert_eq!(a, b, "offset {off}");
        }
    }

    #[test]
    fn detect_returns_a_supported_isa() {
        let isa = Isa::detect();
        if isa == Isa::Avx {
            assert!(hw_avx());
        }
        // cached probe is stable
        assert_eq!(Isa::detect(), isa);
    }
}
