//! The generic stencil-operator layer: every schedule in
//! [`crate::coordinator`] is generic over a [`StencilOp`].
//!
//! The paper implements one hard-coded 7-point constant-coefficient
//! Laplace update and reuses it for every parallel variant. The follow-up
//! schemes (wavefront diamond tiling, arXiv:1410.3060; intra-tile
//! parallelization, arXiv:1510.04995) instead treat the operator as a
//! *parameter* — halo radius, coefficient structure, per-LUP traffic —
//! and derive schedule depth and performance-model inputs from it. This
//! module is that parameterization:
//!
//! * [`StencilOp`] — the kernel contract: halo [`radius`](StencilOp::radius),
//!   a Jacobi-style out-of-place [`line_update`](StencilOp::line_update),
//!   a Gauss-Seidel-style in-place
//!   [`gs_line_update`](StencilOp::gs_line_update), and a
//!   [`TrafficSignature`] the ECM model prices instead of hard-coded
//!   byte counts.
//! * [`ConstLaplace7`] — the paper's operator; its updates dispatch to
//!   the seed kernels in [`super::jacobi`] / [`super::gauss_seidel`], so
//!   the generic path is **bit-identical** to the pre-refactor code
//!   (asserted by `tests/op_parity.rs`).
//! * [`VarCoeff7`] — a Helmholtz-style variable-coefficient 7-point
//!   operator: `(-Δ + λ(x)) u = f` with a per-site coefficient grid,
//!   adding one read stream to the traffic signature.
//! * [`Laplace13`] — the 4th-order 13-point star Laplacian (radius 2),
//!   which forces every schedule to honor halo depth > 1: wavefront lag
//!   `R+1`, temporary rings of `2R+2` planes, GS wavefront spacing
//!   `k+R`, and `2R`-line multi-group boundary arrays.
//!
//! Schedules are monomorphized over the op type (the registry in
//! [`crate::coordinator::runner`] instantiates each scheme per op), so
//! [`ConstLaplace7`] compiles to exactly the code the crate shipped
//! before this layer existed.

use super::gauss_seidel::GsKernel;
use super::grid::Grid3;
use super::simd;
use crate::simulator::memory::StoreMode;
use crate::Result;

/// Largest halo radius any registered op uses (window arrays are sized
/// by this; `radius()` may be smaller, unused slots are never read).
pub const MAX_RADIUS: usize = 2;

/// Per-LUP data-traffic shape of one operator — the numbers the ECM
/// model ([`crate::simulator::ecm`]) used to hard-code per kernel.
///
/// Streams count *arrays*, not neighbor accesses: with the `2R+1`-plane
/// rolling window resident in cache (the in-cache layer condition), each
/// grid an update touches is streamed exactly once per site, so a
/// 7-point and a 13-point Laplacian on one array both have a single read
/// stream — they differ in [`flops_per_lup`](Self::flops_per_lup) and in
/// [`radius`](Self::radius) (which sets how many planes the layer
/// condition must hold simultaneously). The right-hand side is not
/// counted, matching the paper's Eq. (1) accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrafficSignature {
    /// 8-byte read streams per LUP (source grid + any coefficient grids).
    pub read_streams: usize,
    /// 8-byte write streams per LUP (the destination grid).
    pub write_streams: usize,
    /// In-place update (GS-style): the store hits the line the load just
    /// brought in — no extra write-allocate, and non-temporal stores do
    /// not apply.
    pub in_place: bool,
    /// Floating-point operations per lattice-site update.
    pub flops_per_lup: usize,
    /// Halo radius of the operator.
    pub radius: usize,
}

impl TrafficSignature {
    /// Main-memory bytes per LUP (the Eq. (1) numerator). `nt_stores`
    /// elides the write-allocate of out-of-place stores; in-place ops
    /// ignore it (their store hits the loaded line).
    pub fn mem_bytes_per_lup(&self, nt_stores: bool) -> f64 {
        if self.in_place {
            (self.read_streams + self.write_streams) as f64 * 8.0
        } else {
            let wa = if nt_stores { 0 } else { self.write_streams };
            (self.read_streams + self.write_streams + wa) as f64 * 8.0
        }
    }

    /// In-hierarchy (L1↔L2↔OLC) bytes per LUP: reads miss inward, the
    /// store line moves out, and out-of-place stores add the in-cache
    /// write-allocate the ECM model charges.
    pub fn hierarchy_bytes_per_lup(&self) -> f64 {
        if self.in_place {
            (self.read_streams + self.write_streams) as f64 * 8.0
        } else {
            (self.read_streams + 2 * self.write_streams) as f64 * 8.0
        }
    }

    /// Planes the rolling window must keep cache-resident per sweep for
    /// the layer condition the signature assumes.
    pub fn window_planes(&self) -> usize {
        2 * self.radius + 1
    }
}

/// Read-only star window for one out-of-place x-line update.
///
/// `ym[d]` / `yp[d]` is the line at y offset `-(d+1)` / `+(d+1)` in the
/// same plane; `zm[d]` / `zp[d]` the center line of plane `k ∓ (d+1)`.
/// Only the first `radius()` entries of each array are meaningful; the
/// rest alias `center` and are never read by a well-formed op.
pub struct StarWindow<'a> {
    pub center: &'a [f64],
    pub ym: [&'a [f64]; MAX_RADIUS],
    pub yp: [&'a [f64]; MAX_RADIUS],
    pub zm: [&'a [f64]; MAX_RADIUS],
    pub zp: [&'a [f64]; MAX_RADIUS],
}

impl<'a> StarWindow<'a> {
    /// Window assembled from a line lookup: `line(dz, dy)` returns the
    /// x-line at z offset `dz`, y offset `dy` from the center (exactly
    /// one of the two is non-zero, with `1 <= |offset| <= r`). The single
    /// place the halo offsets are indexed — every schedule builds its
    /// window through this constructor.
    pub fn from_fn(
        center: &'a [f64],
        r: usize,
        mut line: impl FnMut(isize, isize) -> &'a [f64],
    ) -> Self {
        assert!(r <= MAX_RADIUS, "op radius {r} exceeds MAX_RADIUS ({MAX_RADIUS})");
        let mut w = Self {
            center,
            ym: [center; MAX_RADIUS],
            yp: [center; MAX_RADIUS],
            zm: [center; MAX_RADIUS],
            zp: [center; MAX_RADIUS],
        };
        for d in 0..r {
            let o = (d + 1) as isize;
            w.ym[d] = line(0, -o);
            w.yp[d] = line(0, o);
            w.zm[d] = line(-o, 0);
            w.zp[d] = line(o, 0);
        }
        w
    }

    /// Window over a grid's interior line `(k, j)` (all offsets must be
    /// in range: `r <= k < nz-r`, `r <= j < ny-r`).
    pub fn from_grid(src: &'a Grid3, r: usize, k: usize, j: usize) -> Self {
        Self::from_fn(src.line(k, j), r, |dz, dy| {
            src.line((k as isize + dz) as usize, (j as isize + dy) as usize)
        })
    }
}

/// Neighbor lines of one in-place lexicographic GS x-line update: the
/// `m` (minus) offsets hold *new* (this-sweep) values, the `p` (plus)
/// offsets *old* values — the lexicographic semantics at any radius.
pub struct GsWindow<'a> {
    pub ym_new: [&'a [f64]; MAX_RADIUS],
    pub yp_old: [&'a [f64]; MAX_RADIUS],
    pub zm_new: [&'a [f64]; MAX_RADIUS],
    pub zp_old: [&'a [f64]; MAX_RADIUS],
}

/// A stencil operator: the kernel parameter every schedule, the runner
/// registry and the performance model are generic over.
///
/// Implementations update **interior x only** (`i ∈ [R, nx-R)`); the
/// Dirichlet edge columns are the schedule's responsibility. `k`/`j`
/// locate the line for ops with per-site coefficients.
pub trait StencilOp: Sync {
    /// Halo radius `R` (1 for 7-point, 2 for the 13-point star).
    fn radius(&self) -> usize;

    /// Traffic signature of the Jacobi-style (out-of-place) update.
    fn signature(&self) -> TrafficSignature;

    /// Traffic signature of the GS-style (in-place) update.
    fn gs_signature(&self) -> TrafficSignature;

    /// Confirm the op can be applied to a `(nz, ny, nx)` domain. Ops
    /// with per-site state (coefficient grids) reject mismatched shapes
    /// here — the schedules call this before any line update, so a
    /// wrong-size coefficient grid fails fast instead of panicking in a
    /// worker or silently reading misaligned lines. Stateless ops accept
    /// every shape.
    fn validate_domain(&self, shape: (usize, usize, usize)) -> Result<()> {
        let _ = shape;
        Ok(())
    }

    /// Jacobi-style out-of-place update of one x-line. `store` selects
    /// the store-instruction flavour: [`StoreMode::NonTemporal`] streams
    /// the write (bit-identical values, no write-allocate) and is only
    /// worth requesting for lines that are not re-read within the pass.
    #[allow(clippy::too_many_arguments)]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        k: usize,
        j: usize,
        store: StoreMode,
    );

    /// Gauss-Seidel-style in-place update of one x-line (lexicographic:
    /// minus-offset window lines hold new values). Ops without a
    /// dependency-interleaved variant may ignore `kernel`.
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        k: usize,
        j: usize,
        kernel: GsKernel,
    );
}

/// Copy the `r` Dirichlet edge columns of `center` into `dst` (both
/// ends) — the x-boundary treatment a schedule performs when it writes a
/// line to a buffer later sweeps read edges from.
#[inline]
pub fn copy_x_edges(dst: &mut [f64], center: &[f64], r: usize) {
    let nx = dst.len();
    let r = r.min(nx);
    dst[..r].copy_from_slice(&center[..r]);
    dst[nx - r..].copy_from_slice(&center[nx - r..]);
}

// ---------------------------------------------------------------------------
// the three shipped operators

/// The paper's operator: constant-coefficient 7-point Laplace update.
///
/// Dispatches through [`simd`], whose scalar path is the seed kernels
/// (`jacobi_line_update`, `gs_line_update_naive` /
/// `gs_line_update_interleaved`) and whose AVX path is bit-identical to
/// them, so the generic path still produces the pre-`StencilOp` bits.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConstLaplace7;

impl StencilOp for ConstLaplace7 {
    #[inline]
    fn radius(&self) -> usize {
        1
    }
    fn signature(&self) -> TrafficSignature {
        OpKind::ConstLaplace7.signature()
    }
    fn gs_signature(&self) -> TrafficSignature {
        OpKind::ConstLaplace7.gs_signature()
    }
    #[inline]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        _k: usize,
        _j: usize,
        store: StoreMode,
    ) {
        simd::jacobi7(dst, win, rhs, h2, store);
    }
    #[inline]
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        _k: usize,
        _j: usize,
        kernel: GsKernel,
    ) {
        simd::gs7(line, win, kernel);
    }
}

/// Helmholtz-style variable-coefficient 7-point operator:
/// `(-Δ + λ(x)) u = f` discretized with a per-site coefficient grid `λ`,
/// so the update divides by a *variable* diagonal `6 + h²λ` (Jacobi) /
/// `6 + λ` (the homogeneous GS relaxation). The coefficient grid is one
/// extra read stream — visible in the [`TrafficSignature`] and hence in
/// every ECM prediction.
#[derive(Clone, Debug)]
pub struct VarCoeff7 {
    coef: Grid3,
}

impl VarCoeff7 {
    /// Operator with an explicit coefficient grid (`λ >= 0` keeps the
    /// diagonal positive; not enforced — callers own their physics).
    pub fn new(coef: Grid3) -> Self {
        Self { coef }
    }

    /// Deterministic smooth positive default coefficient field for a
    /// `(nz, ny, nx)` domain — what the config/CLI path instantiates.
    pub fn default_for(size: (usize, usize, usize)) -> Self {
        Self::default_for_offset(size, 0)
    }

    /// Default field for a z slab starting at global plane `z_offset`:
    /// the per-site formula is evaluated in global coordinates, so slab
    /// coefficients match the corresponding planes of the full-domain
    /// field exactly (the rank decomposition depends on this).
    pub fn default_for_offset(size: (usize, usize, usize), z_offset: usize) -> Self {
        let (nz, ny, nx) = size;
        Self::new(Grid3::from_fn(nz, ny, nx, |k, j, i| {
            0.25 + 0.125 * ((((k + z_offset) + 2 * j + 3 * i) % 8) as f64)
        }))
    }

    /// The coefficient grid.
    pub fn coefficients(&self) -> &Grid3 {
        &self.coef
    }
}

impl StencilOp for VarCoeff7 {
    #[inline]
    fn radius(&self) -> usize {
        1
    }
    fn signature(&self) -> TrafficSignature {
        OpKind::VarCoeff7.signature()
    }
    fn gs_signature(&self) -> TrafficSignature {
        OpKind::VarCoeff7.gs_signature()
    }
    fn validate_domain(&self, shape: (usize, usize, usize)) -> Result<()> {
        anyhow::ensure!(
            self.coef.shape() == shape,
            "coefficient grid shape {:?} does not match the domain {:?}",
            self.coef.shape(),
            shape
        );
        Ok(())
    }
    #[inline]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        k: usize,
        j: usize,
        store: StoreMode,
    ) {
        simd::varcoeff7(dst, win, rhs, self.coef.line(k, j), h2, store);
    }
    #[inline]
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        k: usize,
        j: usize,
        _kernel: GsKernel,
    ) {
        // the variable diagonal breaks the constant-weight interleaving
        // identity, so both kernel flavours run the straight recursion
        simd::gs_var7(line, win, self.coef.line(k, j));
    }
}

/// The 4th-order 13-point star Laplacian (radius 2):
///
/// ```text
/// -Δu ≈ (1/12h²) Σ_axis (-u_{-2} + 16 u_{-1} - 30 u_0 + 16 u_{+1} - u_{+2})
/// ```
///
/// Jacobi form: `u = (16·S₁ - S₂ + 12 h² f) / 90` with `S₁`/`S₂` the
/// distance-1/-2 neighbor sums. The GS form applies the same formula in
/// place (new values behind, old ahead). Its purpose here is structural:
/// a radius-2 halo exercises wavefront lag `R+1`, `2R+2`-slot temporary
/// rings and `2R`-line boundary arrays in every schedule. (As a
/// *smoother* the 4th-order stencil is not a contraction for
/// high-frequency modes; correctness is asserted as bit-parity with the
/// serial reference sweep, not as residual reduction.)
#[derive(Clone, Copy, Debug, Default)]
pub struct Laplace13;

impl StencilOp for Laplace13 {
    #[inline]
    fn radius(&self) -> usize {
        2
    }
    fn signature(&self) -> TrafficSignature {
        OpKind::Laplace13.signature()
    }
    fn gs_signature(&self) -> TrafficSignature {
        OpKind::Laplace13.gs_signature()
    }
    #[inline]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        _k: usize,
        _j: usize,
        store: StoreMode,
    ) {
        simd::laplace13(dst, win, rhs, h2, store);
    }
    #[inline]
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        _k: usize,
        _j: usize,
        _kernel: GsKernel,
    ) {
        // The GS form groups each shell's recursion-free terms first
        // (t1/t2, then `line[i-1] + t1` / `line[i-2] + t2`) so the
        // chunked vector leg can gather the independent sums per lane and
        // close the recursion scalar — all GS schemes share the op's
        // ordering, so the regrouping is observable only against a
        // hypothetical external bit-reference, which does not exist.
        simd::gs13(line, win);
    }
}

/// Fused residual + correction form of the 7-point Laplace update
/// (ROADMAP carry-over): instead of solving the stencil equation for
/// the center directly, the kernel computes the pointwise residual
/// `res = h²f + (Σ neighbors − 6c)` and applies the diagonal-scaled
/// correction `c + res/6` in the same pass — the building block of
/// residual-based smoothers, fused so the residual never round-trips
/// through memory as its own grid (zero extra streams in the
/// [`TrafficSignature`], three extra flops).
///
/// Algebraically this equals the plain Jacobi update; in floating
/// point the different association produces different bits, so the op
/// is its own parity family (the serial references in this module run
/// the same fused code). Both update flavours are plain scalar loops —
/// the `store` flavour is accepted for interface uniformity but the
/// values are bit-identical either way and the write path is the
/// compiler's.
#[derive(Clone, Copy, Debug, Default)]
pub struct FusedResidual7;

impl StencilOp for FusedResidual7 {
    #[inline]
    fn radius(&self) -> usize {
        1
    }
    fn signature(&self) -> TrafficSignature {
        OpKind::FusedResidual7.signature()
    }
    fn gs_signature(&self) -> TrafficSignature {
        OpKind::FusedResidual7.gs_signature()
    }
    #[inline]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        _k: usize,
        _j: usize,
        _store: StoreMode,
    ) {
        let nx = dst.len();
        if nx < 2 {
            return;
        }
        for i in 1..nx - 1 {
            let c = win.center[i];
            let sum = win.center[i - 1]
                + win.center[i + 1]
                + win.ym[0][i]
                + win.yp[0][i]
                + win.zm[0][i]
                + win.zp[0][i];
            let res = h2 * rhs[i] + (sum - 6.0 * c);
            dst[i] = c + res / 6.0;
        }
    }
    #[inline]
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        _k: usize,
        _j: usize,
        _kernel: GsKernel,
    ) {
        let nx = line.len();
        if nx < 2 {
            return;
        }
        // homogeneous relaxation: residual of the already-updated
        // (lexicographic) neighborhood, corrected in place
        for i in 1..nx - 1 {
            let c = line[i];
            let sum = line[i - 1]
                + line[i + 1]
                + win.ym_new[0][i]
                + win.yp_old[0][i]
                + win.zm_new[0][i]
                + win.zp_old[0][i];
            line[i] = c + (sum - 6.0 * c) / 6.0;
        }
    }
}

/// Anisotropic constant-coefficient 7-point star (ROADMAP carry-over):
/// a heat-equation-style operator with a distinct diffusion weight per
/// axis, `-(cx ∂²x + cy ∂²y + cz ∂²z) u = f` on a unit-spacing grid.
///
/// Jacobi form:
///
/// ```text
/// u = (cx·(u_W + u_E) + cy·(u_S + u_N) + cz·(u_B + u_T) + h²f) / (2(cx+cy+cz))
/// ```
///
/// The GS form applies the homogeneous relaxation in place (new values
/// behind, old ahead). The weights are compile-time constants chosen
/// exactly representable in binary ([`Self::CX`] etc.), so the op stays
/// stateless — same streams as `laplace7`, one more multiply per axis
/// pair. Both update flavours are plain scalar loops; `store` is
/// accepted for interface uniformity, values are bit-identical either
/// way.
#[derive(Clone, Copy, Debug, Default)]
pub struct Aniso7;

impl Aniso7 {
    /// x-axis diffusion weight.
    pub const CX: f64 = 1.0;
    /// y-axis diffusion weight.
    pub const CY: f64 = 2.0;
    /// z-axis diffusion weight.
    pub const CZ: f64 = 0.5;
    /// The constant diagonal `2(cx + cy + cz)`.
    pub const DIAG: f64 = 2.0 * (Self::CX + Self::CY + Self::CZ);
}

impl StencilOp for Aniso7 {
    #[inline]
    fn radius(&self) -> usize {
        1
    }
    fn signature(&self) -> TrafficSignature {
        OpKind::Aniso7.signature()
    }
    fn gs_signature(&self) -> TrafficSignature {
        OpKind::Aniso7.gs_signature()
    }
    #[inline]
    fn line_update(
        &self,
        dst: &mut [f64],
        win: &StarWindow<'_>,
        rhs: &[f64],
        h2: f64,
        _k: usize,
        _j: usize,
        _store: StoreMode,
    ) {
        let nx = dst.len();
        if nx < 2 {
            return;
        }
        for i in 1..nx - 1 {
            let sx = win.center[i - 1] + win.center[i + 1];
            let sy = win.ym[0][i] + win.yp[0][i];
            let sz = win.zm[0][i] + win.zp[0][i];
            dst[i] =
                (Self::CX * sx + Self::CY * sy + Self::CZ * sz + h2 * rhs[i]) / Self::DIAG;
        }
    }
    #[inline]
    fn gs_line_update(
        &self,
        line: &mut [f64],
        win: &GsWindow<'_>,
        _k: usize,
        _j: usize,
        _kernel: GsKernel,
    ) {
        let nx = line.len();
        if nx < 2 {
            return;
        }
        for i in 1..nx - 1 {
            let sx = line[i - 1] + line[i + 1];
            let sy = win.ym_new[0][i] + win.yp_old[0][i];
            let sz = win.zm_new[0][i] + win.zp_old[0][i];
            line[i] = (Self::CX * sx + Self::CY * sy + Self::CZ * sz) / Self::DIAG;
        }
    }
}

// ---------------------------------------------------------------------------
// op identity: config-level kind, runtime instance, static family

/// Config/CLI-level operator identity (`--op`, `op = "..."`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OpKind {
    /// The paper's constant-coefficient 7-point Laplacian.
    #[default]
    ConstLaplace7,
    /// Variable-coefficient (Helmholtz-style) 7-point operator.
    VarCoeff7,
    /// 4th-order 13-point radius-2 Laplacian.
    Laplace13,
    /// Fused residual + correction 7-point update.
    FusedResidual7,
    /// Anisotropic per-axis-coefficient 7-point star.
    Aniso7,
}

impl OpKind {
    /// Every registered op kind.
    pub const ALL: [OpKind; 5] = [
        OpKind::ConstLaplace7,
        OpKind::VarCoeff7,
        OpKind::Laplace13,
        OpKind::FusedResidual7,
        OpKind::Aniso7,
    ];

    /// Parse a `laplace7` / `varcoeff` / `laplace13` / `fused7` /
    /// `aniso7` op name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().replace('-', "_").as_str() {
            "laplace7" | "const7" | "const_laplace7" => OpKind::ConstLaplace7,
            "varcoeff" | "varcoeff7" | "helmholtz" => OpKind::VarCoeff7,
            "laplace13" | "radius2" => OpKind::Laplace13,
            "fused7" | "fused" | "residual7" | "fused_residual" => OpKind::FusedResidual7,
            "aniso7" | "aniso" | "anisotropic7" => OpKind::Aniso7,
            other => {
                anyhow::bail!("unknown op '{other}' (laplace7/varcoeff/laplace13/fused7/aniso7)")
            }
        })
    }

    /// The config/CLI name of the op.
    pub fn as_str(self) -> &'static str {
        match self {
            OpKind::ConstLaplace7 => "laplace7",
            OpKind::VarCoeff7 => "varcoeff",
            OpKind::Laplace13 => "laplace13",
            OpKind::FusedResidual7 => "fused7",
            OpKind::Aniso7 => "aniso7",
        }
    }

    /// Halo radius of the op (available without an instance — the
    /// config validator and the performance model need it).
    pub fn radius(self) -> usize {
        match self {
            OpKind::ConstLaplace7 | OpKind::VarCoeff7 | OpKind::FusedResidual7 | OpKind::Aniso7 => 1,
            OpKind::Laplace13 => 2,
        }
    }

    /// Out-of-place (Jacobi-style) traffic signature.
    pub fn signature(self) -> TrafficSignature {
        match self {
            // src read + dst write; 6 adds + central mul + rhs mul
            OpKind::ConstLaplace7 => TrafficSignature {
                read_streams: 1,
                write_streams: 1,
                in_place: false,
                flops_per_lup: 8,
                radius: 1,
            },
            // + the coefficient grid read stream and the variable divide
            OpKind::VarCoeff7 => TrafficSignature {
                read_streams: 2,
                write_streams: 1,
                in_place: false,
                flops_per_lup: 10,
                radius: 1,
            },
            // one array pair again, but 11 adds + 3 muls across two shells
            OpKind::Laplace13 => TrafficSignature {
                read_streams: 1,
                write_streams: 1,
                in_place: false,
                flops_per_lup: 16,
                radius: 2,
            },
            // same streams as laplace7; the explicit residual costs the
            // 6c multiply, the residual add and the scaled correction
            OpKind::FusedResidual7 => TrafficSignature {
                read_streams: 1,
                write_streams: 1,
                in_place: false,
                flops_per_lup: 11,
                radius: 1,
            },
            // same streams as laplace7; one extra multiply per axis pair
            // (3 coefficient muls + 6 adds + rhs mul + diagonal mul)
            OpKind::Aniso7 => TrafficSignature {
                read_streams: 1,
                write_streams: 1,
                in_place: false,
                flops_per_lup: 11,
                radius: 1,
            },
        }
    }

    /// In-place (GS-style) traffic signature.
    pub fn gs_signature(self) -> TrafficSignature {
        let s = self.signature();
        TrafficSignature {
            in_place: true,
            // GS drops the rhs multiply (the homogeneous relaxation)
            flops_per_lup: s.flops_per_lup - 1,
            ..s
        }
    }

    /// Instantiate the op for a domain (ops with coefficient grids
    /// materialize their deterministic default field).
    pub fn instantiate(self, size: (usize, usize, usize)) -> OpInstance {
        self.instantiate_at(size, 0)
    }

    /// Instantiate the op for a z-axis *slab* of a larger domain whose
    /// first plane sits at global plane index `z_offset` — what the
    /// rank decomposition builds its per-rank solvers from. Stateful
    /// ops evaluate their per-site default fields in **global**
    /// coordinates, so a slab instance is bit-identical to the matching
    /// planes of the full-domain instance (stateless ops ignore the
    /// offset).
    pub fn instantiate_at(self, size: (usize, usize, usize), z_offset: usize) -> OpInstance {
        match self {
            OpKind::ConstLaplace7 => OpInstance::Const7(ConstLaplace7),
            OpKind::VarCoeff7 => OpInstance::VarCoeff(VarCoeff7::default_for_offset(size, z_offset)),
            OpKind::Laplace13 => OpInstance::L13(Laplace13),
            OpKind::FusedResidual7 => OpInstance::Fused7(FusedResidual7),
            OpKind::Aniso7 => OpInstance::Aniso(Aniso7),
        }
    }
}

/// A constructed operator (owned by a
/// [`Solver`](crate::coordinator::solver::Solver) session). Schedules
/// never see this enum — the registry extracts the typed op via
/// [`OpFamily::extract`] so the hot path is monomorphized.
#[derive(Clone, Debug)]
pub enum OpInstance {
    Const7(ConstLaplace7),
    VarCoeff(VarCoeff7),
    L13(Laplace13),
    Fused7(FusedResidual7),
    Aniso(Aniso7),
}

impl OpInstance {
    /// The kind this instance was built from.
    pub fn kind(&self) -> OpKind {
        match self {
            OpInstance::Const7(_) => OpKind::ConstLaplace7,
            OpInstance::VarCoeff(_) => OpKind::VarCoeff7,
            OpInstance::L13(_) => OpKind::Laplace13,
            OpInstance::Fused7(_) => OpKind::FusedResidual7,
            OpInstance::Aniso(_) => OpKind::Aniso7,
        }
    }

    /// Dynamic view for serial (non-hot-path) consumers.
    pub fn as_dyn(&self) -> &dyn StencilOp {
        match self {
            OpInstance::Const7(op) => op,
            OpInstance::VarCoeff(op) => op,
            OpInstance::L13(op) => op,
            OpInstance::Fused7(op) => op,
            OpInstance::Aniso(op) => op,
        }
    }
}

/// Statically identified op type: what the scheme × op registry is
/// keyed on. `extract` recovers the typed op from a session's
/// [`OpInstance`]; the registry guarantees kinds match.
pub trait OpFamily: StencilOp + Sized + 'static {
    /// The kind this type implements.
    const KIND: OpKind;

    /// The typed op inside `inst`.
    ///
    /// # Panics
    /// When `inst` holds a different op — impossible through the
    /// registry, which resolves runners by `(Scheme, OpKind)`.
    fn extract(inst: &OpInstance) -> &Self;
}

impl OpFamily for ConstLaplace7 {
    const KIND: OpKind = OpKind::ConstLaplace7;
    fn extract(inst: &OpInstance) -> &Self {
        match inst {
            OpInstance::Const7(op) => op,
            other => panic!("op mismatch: runner wants laplace7, session holds {:?}", other.kind()),
        }
    }
}

impl OpFamily for VarCoeff7 {
    const KIND: OpKind = OpKind::VarCoeff7;
    fn extract(inst: &OpInstance) -> &Self {
        match inst {
            OpInstance::VarCoeff(op) => op,
            other => panic!("op mismatch: runner wants varcoeff, session holds {:?}", other.kind()),
        }
    }
}

impl OpFamily for Laplace13 {
    const KIND: OpKind = OpKind::Laplace13;
    fn extract(inst: &OpInstance) -> &Self {
        match inst {
            OpInstance::L13(op) => op,
            other => panic!("op mismatch: runner wants laplace13, session holds {:?}", other.kind()),
        }
    }
}

impl OpFamily for FusedResidual7 {
    const KIND: OpKind = OpKind::FusedResidual7;
    fn extract(inst: &OpInstance) -> &Self {
        match inst {
            OpInstance::Fused7(op) => op,
            other => panic!("op mismatch: runner wants fused7, session holds {:?}", other.kind()),
        }
    }
}

impl OpFamily for Aniso7 {
    const KIND: OpKind = OpKind::Aniso7;
    fn extract(inst: &OpInstance) -> &Self {
        match inst {
            OpInstance::Aniso(op) => op,
            other => panic!("op mismatch: runner wants aniso7, session holds {:?}", other.kind()),
        }
    }
}

// ---------------------------------------------------------------------------
// generic serial sweeps (the references every schedule is verified against)

/// One out-of-place sweep of `op`; boundary of `dst` copied from `src`.
///
/// The generic analog of [`super::jacobi::jacobi_sweep`] — bit-identical
/// to it for [`ConstLaplace7`]. Plain (write-allocate) stores; the
/// serial-reference flavour.
pub fn op_jacobi_sweep<O: StencilOp + ?Sized>(
    op: &O,
    dst: &mut Grid3,
    src: &Grid3,
    f: &Grid3,
    h2: f64,
) {
    op_jacobi_sweep_stored(op, dst, src, f, h2, StoreMode::WriteAllocate)
}

/// [`op_jacobi_sweep`] with an explicit store flavour: the baseline
/// scheme streams its write stream when `nt_stores` is on — every `dst`
/// line is written once and not re-read within the sweep (the paper's
/// Sec. 3 write-allocate elision). Values are bit-identical either way.
pub fn op_jacobi_sweep_stored<O: StencilOp + ?Sized>(
    op: &O,
    dst: &mut Grid3,
    src: &Grid3,
    f: &Grid3,
    h2: f64,
    store: StoreMode,
) {
    assert_eq!(dst.shape(), src.shape());
    assert_eq!(f.shape(), src.shape());
    op.validate_domain(src.shape()).expect("op rejects this domain");
    let r = op.radius();
    assert!(r <= MAX_RADIUS, "op radius {r} exceeds MAX_RADIUS ({MAX_RADIUS})");
    dst.copy_from(src); // boundary shell (and identity for degenerate dims)
    let (nz, ny, nx) = src.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 {
        return;
    }
    for k in r..nz - r {
        for j in r..ny - r {
            let win = StarWindow::from_grid(src, r, k, j);
            let d = dst.idx(k, j, 0);
            let dst_line = &mut dst.data_mut()[d..d + nx];
            op.line_update(dst_line, &win, f.line(k, j), h2, k, j, store);
        }
    }
}

/// `n` out-of-place sweeps with double buffering; result returned.
pub fn op_jacobi_steps<O: StencilOp + ?Sized>(
    op: &O,
    u: &Grid3,
    f: &Grid3,
    h2: f64,
    n: usize,
) -> Grid3 {
    op_jacobi_steps_stored(op, u, f, h2, n, StoreMode::WriteAllocate)
}

/// [`op_jacobi_steps`] with an explicit store flavour (see
/// [`op_jacobi_sweep_stored`]).
pub fn op_jacobi_steps_stored<O: StencilOp + ?Sized>(
    op: &O,
    u: &Grid3,
    f: &Grid3,
    h2: f64,
    n: usize,
    store: StoreMode,
) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        op_jacobi_sweep_stored(op, &mut b, &a, f, h2, store);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// In-place lexicographic GS update of line `(k, j)` through raw grid
/// storage — the dispatch granularity of the pipelined schedules at any
/// radius (the generic analog of
/// [`super::gauss_seidel::gs_plane_line_raw`]).
///
/// # Safety
/// `base` must point to an `nz × ny × nx` grid with `r <= k < nz-r`,
/// `r <= j < ny-r` for `r = op.radius()`; the caller must guarantee that
/// line `(k, j)` is not accessed concurrently and that the `4r` neighbor
/// lines are not concurrently written (the pipeline progress protocols
/// provide this).
pub unsafe fn op_gs_line_raw<O: StencilOp + ?Sized>(
    op: &O,
    base: *mut f64,
    ny: usize,
    nx: usize,
    k: usize,
    j: usize,
    kernel: GsKernel,
) {
    let r = op.radius();
    assert!(r <= MAX_RADIUS, "op radius {r} exceeds MAX_RADIUS ({MAX_RADIUS})");
    let at = |kk: usize, jj: usize| (kk * ny + jj) * nx;
    let line_at = |kk: usize, jj: usize| std::slice::from_raw_parts(base.add(at(kk, jj)), nx);
    // never read past index r-1; must not alias the mutable center line
    let dummy = line_at(k, j - 1);
    let mut win = GsWindow {
        ym_new: [dummy; MAX_RADIUS],
        yp_old: [dummy; MAX_RADIUS],
        zm_new: [dummy; MAX_RADIUS],
        zp_old: [dummy; MAX_RADIUS],
    };
    for d in 0..r {
        win.ym_new[d] = line_at(k, j - d - 1);
        win.yp_old[d] = line_at(k, j + d + 1);
        win.zm_new[d] = line_at(k - d - 1, j);
        win.zp_old[d] = line_at(k + d + 1, j);
    }
    let line = std::slice::from_raw_parts_mut(base.add(at(k, j)), nx);
    op.gs_line_update(line, &win, k, j, kernel);
}

/// One full in-place lexicographic GS sweep of `op` — the generic analog
/// of [`super::gauss_seidel::gs_sweep`], bit-identical to it for
/// [`ConstLaplace7`].
pub fn op_gs_sweep<O: StencilOp + ?Sized>(op: &O, u: &mut Grid3, kernel: GsKernel) {
    op.validate_domain(u.shape()).expect("op rejects this domain");
    let r = op.radius();
    assert!(r <= MAX_RADIUS, "op radius {r} exceeds MAX_RADIUS ({MAX_RADIUS})");
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 {
        return;
    }
    let base = u.data_mut().as_mut_ptr();
    for k in r..nz - r {
        for j in r..ny - r {
            // SAFETY: exclusive access via &mut; lines are disjoint.
            unsafe { op_gs_line_raw(op, base, ny, nx, k, j, kernel) }
        }
    }
}

/// `n` in-place GS sweeps of `op`.
pub fn op_gs_sweeps<O: StencilOp + ?Sized>(op: &O, u: &mut Grid3, n: usize, kernel: GsKernel) {
    for _ in 0..n {
        op_gs_sweep(op, u, kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gauss_seidel::gs_sweep;
    use crate::stencil::jacobi::jacobi_sweep;

    #[test]
    fn const7_jacobi_sweep_is_bit_identical_to_seed() {
        for seed in 0..4 {
            let u = Grid3::random(7, 6, 8, seed);
            let f = Grid3::random(7, 6, 8, seed + 100);
            let mut want = Grid3::zeros(7, 6, 8);
            jacobi_sweep(&mut want, &u, &f, 0.7);
            let mut have = Grid3::zeros(7, 6, 8);
            op_jacobi_sweep(&ConstLaplace7, &mut have, &u, &f, 0.7);
            assert_eq!(have.max_abs_diff(&want), 0.0, "seed {seed}");
        }
    }

    #[test]
    fn const7_gs_sweep_is_bit_identical_to_seed() {
        for kernel in [GsKernel::Naive, GsKernel::Interleaved] {
            let mut want = Grid3::random(6, 7, 9, 5);
            let mut have = want.clone();
            gs_sweep(&mut want, kernel);
            op_gs_sweep(&ConstLaplace7, &mut have, kernel);
            assert_eq!(have.max_abs_diff(&want), 0.0, "{kernel:?}");
        }
    }

    #[test]
    fn laplace13_matches_direct_formula() {
        let u = Grid3::random(7, 7, 7, 3);
        let f = Grid3::random(7, 7, 7, 4);
        let h2 = 0.6;
        let mut dst = Grid3::zeros(7, 7, 7);
        op_jacobi_sweep(&Laplace13, &mut dst, &u, &f, h2);
        for k in 2..5 {
            for j in 2..5 {
                for i in 2..5 {
                    let s1 = u.get(k, j, i - 1)
                        + u.get(k, j, i + 1)
                        + u.get(k, j - 1, i)
                        + u.get(k, j + 1, i)
                        + u.get(k - 1, j, i)
                        + u.get(k + 1, j, i);
                    let s2 = u.get(k, j, i - 2)
                        + u.get(k, j, i + 2)
                        + u.get(k, j - 2, i)
                        + u.get(k, j + 2, i)
                        + u.get(k - 2, j, i)
                        + u.get(k + 2, j, i);
                    let want = (16.0 * s1 - s2 + 12.0 * h2 * f.get(k, j, i)) / 90.0;
                    assert!((dst.get(k, j, i) - want).abs() < 1e-15);
                }
            }
        }
        // the two-deep boundary shell is copied, never updated
        for (k, j, i) in [(0, 3, 3), (1, 3, 3), (3, 1, 3), (3, 3, 5), (6, 3, 3)] {
            assert_eq!(dst.get(k, j, i), u.get(k, j, i), "({k},{j},{i})");
        }
    }

    #[test]
    fn varcoeff_reduces_to_helmholtz_formula() {
        let op = VarCoeff7::default_for((6, 6, 6));
        let u = Grid3::random(6, 6, 6, 8);
        let f = Grid3::random(6, 6, 6, 9);
        let h2 = 1.3;
        let mut dst = Grid3::zeros(6, 6, 6);
        op_jacobi_sweep(&op, &mut dst, &u, &f, h2);
        for k in 1..5 {
            for j in 1..5 {
                for i in 1..5 {
                    let num = u.get(k, j, i - 1)
                        + u.get(k, j, i + 1)
                        + u.get(k, j - 1, i)
                        + u.get(k, j + 1, i)
                        + u.get(k - 1, j, i)
                        + u.get(k + 1, j, i)
                        + h2 * f.get(k, j, i);
                    let want = num / (6.0 + h2 * op.coefficients().get(k, j, i));
                    assert!((dst.get(k, j, i) - want).abs() < 1e-15);
                }
            }
        }
    }

    #[test]
    fn signatures_reproduce_the_paper_constants() {
        let s = OpKind::ConstLaplace7.signature();
        assert_eq!(s.mem_bytes_per_lup(true), 16.0);
        assert_eq!(s.mem_bytes_per_lup(false), 24.0);
        assert_eq!(s.hierarchy_bytes_per_lup(), 24.0);
        let g = OpKind::ConstLaplace7.gs_signature();
        assert_eq!(g.mem_bytes_per_lup(true), 16.0);
        assert_eq!(g.mem_bytes_per_lup(false), 16.0);
        assert_eq!(g.hierarchy_bytes_per_lup(), 16.0);
        // varcoeff adds exactly one 8 B read stream everywhere
        let v = OpKind::VarCoeff7.signature();
        assert_eq!(v.mem_bytes_per_lup(true), 24.0);
        assert_eq!(v.hierarchy_bytes_per_lup(), 32.0);
        // radius widens the layer condition, not the stream count
        let l = OpKind::Laplace13.signature();
        assert_eq!(l.mem_bytes_per_lup(true), 16.0);
        assert_eq!(l.window_planes(), 5);
        assert_eq!(s.window_planes(), 3);
    }

    #[test]
    fn signatures_agree_with_the_eq1_helpers() {
        // the paper's Eq. (1) byte counts live twice — in
        // `simulator::memory` (the seed encoding) and derived from the
        // ConstLaplace7 TrafficSignature; tie them so they cannot drift
        use crate::simulator::memory::{self, StoreMode};
        let s = OpKind::ConstLaplace7.signature();
        let g = OpKind::ConstLaplace7.gs_signature();
        assert_eq!(
            s.mem_bytes_per_lup(true),
            memory::jacobi_mem_bytes_per_lup(StoreMode::NonTemporal)
        );
        assert_eq!(
            s.mem_bytes_per_lup(false),
            memory::jacobi_mem_bytes_per_lup(StoreMode::WriteAllocate)
        );
        assert_eq!(g.mem_bytes_per_lup(true), memory::gs_mem_bytes_per_lup());
        assert_eq!(s.hierarchy_bytes_per_lup(), memory::wavefront_olc_bytes_per_lup(false, false));
        assert_eq!(g.hierarchy_bytes_per_lup(), memory::wavefront_olc_bytes_per_lup(true, false));
        assert_eq!(
            2.0 * s.hierarchy_bytes_per_lup(),
            memory::wavefront_olc_bytes_per_lup(false, true)
        );
        // the wavefront amortization matches the seed helper too
        assert_eq!(
            s.mem_bytes_per_lup(true) / 4.0 * 1.5,
            memory::wavefront_mem_bytes_per_lup(4, StoreMode::NonTemporal, 0.5)
        );
    }

    #[test]
    fn varcoeff_rejects_mismatched_domains() {
        let op = VarCoeff7::default_for((6, 6, 6));
        assert!(op.validate_domain((6, 6, 6)).is_ok());
        assert!(op.validate_domain((6, 7, 6)).is_err());
        // stateless ops accept any shape
        assert!(ConstLaplace7.validate_domain((3, 99, 4)).is_ok());
        assert!(Laplace13.validate_domain((5, 5, 5)).is_ok());
    }

    #[test]
    fn kinds_roundtrip_and_instantiate() {
        for kind in OpKind::ALL {
            assert_eq!(OpKind::parse(kind.as_str()).unwrap(), kind);
            let inst = kind.instantiate((8, 8, 8));
            assert_eq!(inst.kind(), kind);
            assert_eq!(inst.as_dyn().radius(), kind.radius());
        }
        assert!(OpKind::parse("biharmonic").is_err());
        assert_eq!(OpKind::parse("radius2").unwrap(), OpKind::Laplace13);
    }

    #[test]
    fn fused_residual_matches_its_formula_and_fixed_points() {
        let u = Grid3::random(6, 6, 6, 11);
        let f = Grid3::random(6, 6, 6, 12);
        let h2 = 0.9;
        let mut dst = Grid3::zeros(6, 6, 6);
        op_jacobi_sweep(&FusedResidual7, &mut dst, &u, &f, h2);
        for k in 1..5 {
            for j in 1..5 {
                for i in 1..5 {
                    let c = u.get(k, j, i);
                    let sum = u.get(k, j, i - 1)
                        + u.get(k, j, i + 1)
                        + u.get(k, j - 1, i)
                        + u.get(k, j + 1, i)
                        + u.get(k - 1, j, i)
                        + u.get(k + 1, j, i);
                    let want = c + (h2 * f.get(k, j, i) + (sum - 6.0 * c)) / 6.0;
                    assert_eq!(dst.get(k, j, i), want, "fused form is the exact bit recipe");
                    // algebraically the plain Jacobi value (different bits)
                    let plain = (sum + h2 * f.get(k, j, i)) / 6.0;
                    assert!((dst.get(k, j, i) - plain).abs() < 1e-12);
                }
            }
        }
        // zero residual means zero correction: a constant grid with
        // f = 0 is a bit-exact fixed point of both update flavours
        let c0 = Grid3::from_fn(5, 5, 5, |_, _, _| 1.5);
        let zf = Grid3::zeros(5, 5, 5);
        let mut out = Grid3::zeros(5, 5, 5);
        op_jacobi_sweep(&FusedResidual7, &mut out, &c0, &zf, 1.0);
        assert_eq!(out, c0);
        let mut v = c0.clone();
        op_gs_sweep(&FusedResidual7, &mut v, GsKernel::Interleaved);
        assert_eq!(v, c0);
    }

    #[test]
    fn fused_residual_signature_and_names() {
        let s = OpKind::FusedResidual7.signature();
        assert_eq!((s.read_streams, s.write_streams, s.radius), (1, 1, 1));
        assert_eq!(s.flops_per_lup, 11);
        assert_eq!(s.mem_bytes_per_lup(true), 16.0); // same streams as laplace7
        assert!(OpKind::FusedResidual7.gs_signature().in_place);
        assert_eq!(OpKind::parse("fused7").unwrap(), OpKind::FusedResidual7);
        assert_eq!(OpKind::parse("fused-residual").unwrap(), OpKind::FusedResidual7);
        assert_eq!(OpKind::FusedResidual7.as_str(), "fused7");
    }

    #[test]
    fn aniso_matches_its_formula_and_names() {
        let u = Grid3::random(6, 6, 6, 21);
        let f = Grid3::random(6, 6, 6, 22);
        let h2 = 0.8;
        let mut dst = Grid3::zeros(6, 6, 6);
        op_jacobi_sweep(&Aniso7, &mut dst, &u, &f, h2);
        for k in 1..5 {
            for j in 1..5 {
                for i in 1..5 {
                    let sx = u.get(k, j, i - 1) + u.get(k, j, i + 1);
                    let sy = u.get(k, j - 1, i) + u.get(k, j + 1, i);
                    let sz = u.get(k - 1, j, i) + u.get(k + 1, j, i);
                    let want = (Aniso7::CX * sx
                        + Aniso7::CY * sy
                        + Aniso7::CZ * sz
                        + h2 * f.get(k, j, i))
                        / Aniso7::DIAG;
                    assert_eq!(dst.get(k, j, i), want, "({k},{j},{i})");
                }
            }
        }
        // a constant grid with f = 0 is a bit-exact fixed point of both
        // flavours (the weights sum to half the diagonal exactly)
        let c0 = Grid3::from_fn(5, 5, 5, |_, _, _| 2.25);
        let zf = Grid3::zeros(5, 5, 5);
        let mut out = Grid3::zeros(5, 5, 5);
        op_jacobi_sweep(&Aniso7, &mut out, &c0, &zf, 1.0);
        assert_eq!(out, c0);
        let mut v = c0.clone();
        op_gs_sweep(&Aniso7, &mut v, GsKernel::Interleaved);
        assert_eq!(v, c0);
        let s = OpKind::Aniso7.signature();
        assert_eq!((s.read_streams, s.write_streams, s.radius), (1, 1, 1));
        assert_eq!(s.mem_bytes_per_lup(true), 16.0); // same streams as laplace7
        assert!(OpKind::Aniso7.gs_signature().in_place);
        assert_eq!(OpKind::parse("aniso7").unwrap(), OpKind::Aniso7);
        assert_eq!(OpKind::parse("anisotropic7").unwrap(), OpKind::Aniso7);
        assert_eq!(OpKind::Aniso7.as_str(), "aniso7");
    }

    #[test]
    fn slab_instantiation_matches_global_coefficients() {
        // a varcoeff slab starting at global plane 3 must hold exactly
        // the full-domain field's planes 3..8 — the property the rank
        // decomposition's per-rank solvers rely on
        let full = OpKind::VarCoeff7.instantiate((10, 6, 7));
        let slab = OpKind::VarCoeff7.instantiate_at((5, 6, 7), 3);
        let (full, slab) = match (&full, &slab) {
            (OpInstance::VarCoeff(a), OpInstance::VarCoeff(b)) => (a, b),
            _ => unreachable!(),
        };
        for k in 0..5 {
            for j in 0..6 {
                for i in 0..7 {
                    assert_eq!(
                        slab.coefficients().get(k, j, i),
                        full.coefficients().get(k + 3, j, i)
                    );
                }
            }
        }
        // stateless ops ignore the offset
        for kind in [OpKind::ConstLaplace7, OpKind::Laplace13, OpKind::FusedResidual7, OpKind::Aniso7]
        {
            assert_eq!(kind.instantiate_at((5, 5, 5), 7).kind(), kind);
        }
    }

    #[test]
    fn degenerate_grids_are_identity_per_radius() {
        // 4^3 has interior for r=1 but none for r=2
        let u = Grid3::random(4, 4, 4, 2);
        let f = Grid3::zeros(4, 4, 4);
        let mut dst = Grid3::zeros(4, 4, 4);
        op_jacobi_sweep(&Laplace13, &mut dst, &u, &f, 1.0);
        assert_eq!(dst, u);
        let mut v = u.clone();
        op_gs_sweep(&Laplace13, &mut v, GsKernel::Interleaved);
        assert_eq!(v, u);
    }
}
