//! The lexicographic Gauss-Seidel smoother (paper Sec. 3).
//!
//! In-place update for a Laplace problem:
//!
//! ```text
//! u[k][j][i] = 1/6 ( u[k][j][i-1] + u[k][j][i+1]      // new , old
//!                  + u[k][j-1][i] + u[k][j+1][i]      // new , old
//!                  + u[k-1][j][i] + u[k+1][j][i] )    // new , old
//! ```
//!
//! The recursion on the central line rules out SIMD and limits pipelining;
//! the paper's optimized assembly kernel *interleaves two updates* to break
//! register dependency chains. [`gs_line_update_interleaved`] transcribes
//! that exact transformation (the `tmp1`/`tmp2` rotation of the listing) —
//! it is bit-identical to the naive recursion but exposes two independent
//! dependency chains to the out-of-order core, which is why it exists as a
//! separate function: the ECM model assigns it a lower in-core cycle count
//! ([`crate::simulator::ecm`]), reproducing the asm-vs-C gap of Fig. 4.

use super::grid::Grid3;
use super::jacobi::ONE_SIXTH;

/// Naive GS line update: the straight C listing ("C" curves of Fig. 4).
///
/// `line` is updated in place; `ym_new` is line `j-1` *after* its update
/// this sweep, `yp_old` line `j+1` before, `zm_new`/`zp_old` likewise for
/// the z neighbors.
#[inline]
pub fn gs_line_update_naive(
    line: &mut [f64],
    ym_new: &[f64],
    yp_old: &[f64],
    zm_new: &[f64],
    zp_old: &[f64],
) {
    let nx = line.len();
    for i in 1..nx - 1 {
        // Grouping matters: the recursion-free terms are summed first so
        // that this variant is bit-identical to the interleaved kernel
        // (same fp association), keeping the two comparable in tests.
        line[i] = ONE_SIXTH
            * (line[i - 1]
                + (line[i + 1] + ym_new[i] + yp_old[i] + zm_new[i] + zp_old[i]));
    }
}

/// Dependency-interleaved GS line update (the paper's optimized kernel).
///
/// Precomputes the recursion-free partial sums (`tmp` terms) one iteration
/// ahead so two updates are in flight, "partially hiding the recursion".
/// Numerically identical to [`gs_line_update_naive`]: the fp operation
/// order per site is preserved (same adds, same final multiply).
#[inline]
pub fn gs_line_update_interleaved(
    line: &mut [f64],
    ym_new: &[f64],
    yp_old: &[f64],
    zm_new: &[f64],
    zp_old: &[f64],
) {
    let nx = line.len();
    if nx < 3 {
        return;
    }
    let b = ONE_SIXTH;
    // tmp_i = sum of the recursion-free terms of site i.
    let mut tmp1 = line[2] + ym_new[1] + yp_old[1] + zm_new[1] + zp_old[1];
    let mut i = 1;
    while i + 1 < nx - 1 {
        // One iteration ahead: gather site i+1's independent terms while
        // site i's update closes its dependency chain — the `tmp1 = tmp2`
        // rotation of the paper's listing.
        let tmp2 = line[i + 2] + ym_new[i + 1] + yp_old[i + 1] + zm_new[i + 1] + zp_old[i + 1];
        line[i] = b * (line[i - 1] + tmp1);
        tmp1 = tmp2;
        i += 1;
    }
    // Last interior site (no successor to prefetch).
    line[i] = b * (line[i - 1] + tmp1);
}

/// Which line-update kernel a sweep uses (the C vs asm axis of Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum GsKernel {
    /// Straightforward recursion (the paper's "C" baseline).
    Naive,
    /// Two-way interleaved updates (the paper's optimized assembly).
    #[default]
    Interleaved,
}

/// Update one interior plane `k` in place (lexicographic order in y).
pub fn gs_plane(u: &mut Grid3, k: usize, kernel: GsKernel) {
    debug_assert!(k >= 1 && k + 1 < u.nz);
    let ny = u.ny;
    for j in 1..ny - 1 {
        gs_plane_line(u, k, j, kernel);
    }
}

/// Update one interior line `(k, j)` in place.
///
/// The dispatch granularity of the pipeline-parallel schedules (Fig. 5).
#[inline]
pub fn gs_plane_line(u: &mut Grid3, k: usize, j: usize, kernel: GsKernel) {
    let (ny, nx) = (u.ny, u.nx);
    // SAFETY: exclusive access via &mut; the five lines are disjoint.
    unsafe { gs_plane_line_raw(u.data_mut().as_mut_ptr(), ny, nx, k, j, kernel) }
}

/// Raw-pointer variant of [`gs_plane_line`] for the threaded schedules,
/// where several threads update disjoint lines of one shared grid.
///
/// # Safety
/// `base` must point to an `nz × ny × nx` grid with `1 ≤ k < nz-1`,
/// `1 ≤ j < ny-1`; the caller must guarantee that line `(k, j)` is not
/// accessed concurrently and that the four neighbor lines are not
/// concurrently *written* (the pipeline progress protocol provides this).
#[inline]
pub unsafe fn gs_plane_line_raw(
    base: *mut f64,
    ny: usize,
    nx: usize,
    k: usize,
    j: usize,
    kernel: GsKernel,
) {
    let at = |kk: usize, jj: usize| (kk * ny + jj) * nx;
    let ym_new = std::slice::from_raw_parts(base.add(at(k, j - 1)), nx);
    let yp_old = std::slice::from_raw_parts(base.add(at(k, j + 1)), nx);
    let zm_new = std::slice::from_raw_parts(base.add(at(k - 1, j)), nx);
    let zp_old = std::slice::from_raw_parts(base.add(at(k + 1, j)), nx);
    let line = std::slice::from_raw_parts_mut(base.add(at(k, j)), nx);
    match kernel {
        GsKernel::Naive => gs_line_update_naive(line, ym_new, yp_old, zm_new, zp_old),
        GsKernel::Interleaved => gs_line_update_interleaved(line, ym_new, yp_old, zm_new, zp_old),
    }
}

/// One full in-place lexicographic GS sweep.
pub fn gs_sweep(u: &mut Grid3, kernel: GsKernel) {
    if u.nz < 3 || u.ny < 3 || u.nx < 3 {
        return;
    }
    for k in 1..u.nz - 1 {
        gs_plane(u, k, kernel);
    }
}

/// `n` in-place GS sweeps.
pub fn gs_sweeps(u: &mut Grid3, n: usize, kernel: GsKernel) {
    for _ in 0..n {
        gs_sweep(u, kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::residual::laplace_residual_norm;

    #[test]
    fn interleaved_is_bit_identical_to_naive() {
        for seed in 0..5 {
            let mut a = Grid3::random(7, 6, 9, seed);
            let mut b = a.clone();
            gs_sweep(&mut a, GsKernel::Naive);
            gs_sweep(&mut b, GsKernel::Interleaved);
            assert_eq!(a.max_abs_diff(&b), 0.0, "seed {seed}");
        }
    }

    #[test]
    fn interleaved_handles_short_lines() {
        // nx = 3: single interior site; nx = 4: two sites (loop + epilogue).
        for nx in [3, 4, 5] {
            let mut a = Grid3::random(4, 4, nx, 99);
            let mut b = a.clone();
            gs_sweep(&mut a, GsKernel::Naive);
            gs_sweep(&mut b, GsKernel::Interleaved);
            assert_eq!(a.max_abs_diff(&b), 0.0, "nx {nx}");
        }
    }

    #[test]
    fn matches_scalar_reference() {
        let mut u = Grid3::random(5, 5, 5, 7);
        let reference = {
            let mut v = u.clone();
            for k in 1..4 {
                for j in 1..4 {
                    for i in 1..4 {
                        let val = ONE_SIXTH
                            * (v.get(k, j, i - 1)
                                + (v.get(k, j, i + 1)
                                    + v.get(k, j - 1, i)
                                    + v.get(k, j + 1, i)
                                    + v.get(k - 1, j, i)
                                    + v.get(k + 1, j, i)));
                        v.set(k, j, i, val);
                    }
                }
            }
            v
        };
        gs_sweep(&mut u, GsKernel::Interleaved);
        assert_eq!(u.max_abs_diff(&reference), 0.0);
    }

    #[test]
    fn harmonic_fixed_point() {
        let mut u = Grid3::from_fn(6, 6, 6, |k, j, i| {
            i as f64 - 2.0 * j as f64 + 0.5 * k as f64
        });
        let orig = u.clone();
        gs_sweep(&mut u, GsKernel::Interleaved);
        assert!(u.max_abs_diff(&orig) < 1e-13);
    }

    #[test]
    fn sweeps_reduce_laplace_residual() {
        let mut u = Grid3::random(10, 10, 10, 3);
        let r0 = laplace_residual_norm(&u);
        gs_sweeps(&mut u, 3, GsKernel::Interleaved);
        let r3 = laplace_residual_norm(&u);
        assert!(r3 < 0.5 * r0, "r0={r0} r3={r3}");
    }

    #[test]
    fn boundary_untouched() {
        let mut u = Grid3::random(5, 6, 7, 5);
        let orig = u.clone();
        gs_sweep(&mut u, GsKernel::Interleaved);
        for k in 0..5 {
            for j in 0..6 {
                for i in 0..7 {
                    if u.is_boundary(k, j, i) {
                        assert_eq!(u.get(k, j, i), orig.get(k, j, i));
                    }
                }
            }
        }
    }
}
