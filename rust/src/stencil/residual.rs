//! Residual diagnostics for the Poisson / Laplace problems.
//!
//! `r = h²·f + Δu` pointwise on the interior (zero on the Dirichlet
//! boundary); the solvers drive `‖r‖₂ → 0`. Mirrors
//! `python/compile/kernels/ref.py::residual` so the cross-layer validation
//! can compare norms directly.

use super::grid::Grid3;

/// Pointwise Poisson residual into `out` (interior only, boundary zeroed).
pub fn poisson_residual(out: &mut Grid3, u: &Grid3, f: &Grid3, h2: f64) {
    assert_eq!(out.shape(), u.shape());
    assert_eq!(f.shape(), u.shape());
    out.data_mut().fill(0.0);
    if u.nz < 3 || u.ny < 3 || u.nx < 3 {
        return;
    }
    for k in 1..u.nz - 1 {
        for j in 1..u.ny - 1 {
            for i in 1..u.nx - 1 {
                let lap = u.get(k, j, i - 1)
                    + u.get(k, j, i + 1)
                    + u.get(k, j - 1, i)
                    + u.get(k, j + 1, i)
                    + u.get(k - 1, j, i)
                    + u.get(k + 1, j, i)
                    - 6.0 * u.get(k, j, i);
                out.set(k, j, i, lap + h2 * f.get(k, j, i));
            }
        }
    }
}

/// `‖h²·f + Δu‖₂` without allocating a full residual grid.
pub fn poisson_residual_norm(u: &Grid3, f: &Grid3, h2: f64) -> f64 {
    if u.nz < 3 || u.ny < 3 || u.nx < 3 {
        return 0.0;
    }
    let mut acc = 0.0;
    for k in 1..u.nz - 1 {
        for j in 1..u.ny - 1 {
            for i in 1..u.nx - 1 {
                let lap = u.get(k, j, i - 1)
                    + u.get(k, j, i + 1)
                    + u.get(k, j - 1, i)
                    + u.get(k, j + 1, i)
                    + u.get(k - 1, j, i)
                    + u.get(k + 1, j, i)
                    - 6.0 * u.get(k, j, i);
                let r = lap + h2 * f.get(k, j, i);
                acc += r * r;
            }
        }
    }
    acc.sqrt()
}

/// Laplace residual norm (`f = 0` convenience).
pub fn laplace_residual_norm(u: &Grid3) -> f64 {
    let zero = Grid3::zeros(u.nz, u.ny, u.nx);
    poisson_residual_norm(u, &zero, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::jacobi::jacobi_steps;

    #[test]
    fn linear_field_has_zero_residual() {
        let u = Grid3::from_fn(6, 6, 6, |k, j, i| i as f64 + 2.0 * j as f64 + 3.0 * k as f64);
        assert!(laplace_residual_norm(&u) < 1e-12);
    }

    #[test]
    fn residual_norm_matches_grid_norm() {
        let u = Grid3::random(6, 7, 8, 4);
        let f = Grid3::random(6, 7, 8, 5);
        let mut r = Grid3::zeros(6, 7, 8);
        poisson_residual(&mut r, &u, &f, 0.5);
        let direct = poisson_residual_norm(&u, &f, 0.5);
        assert!((r.l2_norm() - direct).abs() < 1e-12 * direct.max(1.0));
    }

    #[test]
    fn jacobi_reduces_residual() {
        let u = Grid3::random(10, 10, 10, 6);
        let f = Grid3::zeros(10, 10, 10);
        let r0 = poisson_residual_norm(&u, &f, 1.0);
        let u5 = jacobi_steps(&u, &f, 1.0, 5);
        let r5 = poisson_residual_norm(&u5, &f, 1.0);
        assert!(r5 < r0);
    }

    #[test]
    fn degenerate_grid_residual_is_zero() {
        let u = Grid3::random(2, 4, 4, 8);
        let f = Grid3::zeros(2, 4, 4);
        assert_eq!(poisson_residual_norm(&u, &f, 1.0), 0.0);
    }
}
