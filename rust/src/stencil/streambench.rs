//! In-process STREAM triad microbenchmark (McCalpin [11], paper Tab. 1).
//!
//! The paper anchors its Eq. (1) performance model to measured STREAM
//! triad bandwidth with and without non-temporal stores. This module runs
//! the triad `a[i] = b[i] + s·c[i]` for real on the host — used by the
//! `stream` CLI subcommand and the Tab. 1 bench to report the *actual*
//! bandwidth of this box next to the modeled bandwidths of the paper's
//! five machines ([`crate::simulator::stream`]).
//!
//! Plain stores only: portable rust has no non-temporal store intrinsic on
//! stable; the NT/noNT distinction is carried by the machine *model*
//! (write-allocate accounting), not by this microbenchmark.

use std::time::Instant;

/// Result of a STREAM triad run.
#[derive(Clone, Copy, Debug)]
pub struct StreamResult {
    /// Best observed bandwidth over all repetitions, in GB/s.
    pub best_gbs: f64,
    /// Arithmetic mean bandwidth in GB/s.
    pub mean_gbs: f64,
    /// Working-set size in bytes (three arrays).
    pub bytes: usize,
}

/// Run the STREAM triad `a = b + s*c` over `n` doubles, `reps` times.
///
/// Traffic accounting follows STREAM convention: 3 × 8 B per element
/// (load b, load c, store a); the write-allocate for `a` is *not* counted,
/// matching the "NT" row semantics of Tab. 1.
pub fn stream_triad(n: usize, reps: usize) -> StreamResult {
    assert!(n > 0 && reps > 0);
    let mut a = vec![0.0f64; n];
    let b: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
    let c: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 + 1.0).collect();
    let s = 3.0f64;

    let bytes_per_rep = 3 * n * std::mem::size_of::<f64>();
    let mut best = 0.0f64;
    let mut sum = 0.0f64;
    for r in 0..reps {
        let scale = s + r as f64 * 1e-9; // defeat loop-invariant hoisting across reps
        let t0 = Instant::now();
        for i in 0..n {
            a[i] = b[i] + scale * c[i];
        }
        let dt = t0.elapsed().as_secs_f64();
        let gbs = bytes_per_rep as f64 / dt / 1e9;
        best = best.max(gbs);
        sum += gbs;
    }
    // Keep `a` observable so the triad loop cannot be eliminated.
    std::hint::black_box(&a);
    StreamResult { best_gbs: best, mean_gbs: sum / reps as f64, bytes: bytes_per_rep }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triad_reports_positive_bandwidth() {
        let r = stream_triad(1 << 16, 3);
        assert!(r.best_gbs > 0.0);
        assert!(r.mean_gbs > 0.0);
        assert!(r.best_gbs >= r.mean_gbs * 0.999);
        assert_eq!(r.bytes, 3 * (1 << 16) * 8);
    }
}
