//! # stencilwave
//!
//! A reproduction of *"Efficient multicore-aware parallelization strategies
//! for iterative stencil computations"* (Treibig, Wellein, Hager, 2010,
//! DOI 10.1016/j.jocs.2011.01.010) as a three-layer rust + JAX + Pallas
//! system.
//!
//! The paper's contribution — temporal blocking of Jacobi and Gauss-Seidel
//! smoothers via *multicore-aware wavefront parallelization* — lives in
//! [`coordinator`]: thread groups run time-shifted sweeps through the grid
//! so intermediate updates stay in the shared outer-level cache, plus the
//! pipeline-parallel scheme that extends it to the lexicographic
//! Gauss-Seidel method and the SMT-aware synchronization primitives.
//!
//! Because the paper's evaluation is performance on five 2008–2010 x86
//! sockets, [`simulator`] provides the testbed substrate: parameterized
//! machine models (Tab. 1), an ECM-style analytic performance model
//! (ref. [14] of the paper), a set-associative cache simulator driven by
//! exact access traces, and a STREAM triad model for the Eq. (1) roofline.
//!
//! [`stencil`] holds the numerical substrate (grids, line-update kernels,
//! residuals) and the generic [`stencil::op::StencilOp`] kernel layer:
//! every schedule, the scheme registry and the performance model are
//! parameterized over an operator (halo radius, coefficient structure,
//! per-LUP traffic), with the paper's 7-point Laplacian
//! ([`stencil::op::ConstLaplace7`]), a variable-coefficient Helmholtz-style
//! op and a radius-2 13-point Laplacian shipped. [`runtime`] loads the
//! AOT-compiled JAX/Pallas artifacts via PJRT and is the cross-layer
//! validation oracle; [`config`], [`launcher`] and [`figures`] form the
//! experiment harness that regenerates every table and figure of the
//! paper.
//!
//! ## Quick start
//!
//! Execution goes through a [`coordinator::solver::Solver`] session: the
//! builder validates the config once, resolves the scheme from the
//! [`coordinator::runner`] registry, and spawns (optionally core-pinned)
//! the worker team exactly once:
//!
//! ```no_run
//! use stencilwave::config::{RunConfig, Scheme};
//! use stencilwave::coordinator::solver::Solver;
//! use stencilwave::stencil::grid::Grid3;
//!
//! let cfg = RunConfig {
//!     scheme: Scheme::JacobiWavefront,
//!     size: (64, 64, 64),
//!     t: 4,
//!     ..Default::default()
//! };
//! let mut solver = Solver::builder(&cfg).build().unwrap();
//! let mut u = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! solver.run(&mut u, 8).unwrap(); // 8 updates on one persistent team
//! ```
//!
//! The pre-session free-function shims (`wavefront_jacobi`, …) were
//! removed in 0.3.0 after their one-release deprecation window; the
//! pool-level `*_passes` entry points remain for explicit-pool callers
//! (see the migration table in the README).

pub mod benchkit;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod launcher;
pub mod metrics;
pub mod runtime;
pub mod simulator;
pub mod stencil;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// One home for the `STENCILWAVE_*` boolean env-flag convention: unset,
/// empty, whitespace-only and `"0"` (after trimming) all mean **off**;
/// anything else means **on**. `benchkit::smoke` and the SIMD probe used
/// to parse this independently and disagreed on whitespace (` 0 ` turned
/// the SIMD override off but the bench smoke *on*); route every flag
/// through here so they can't drift again.
pub fn env_flag(name: &str) -> bool {
    std::env::var(name).is_ok_and(|v| {
        let v = v.trim();
        !v.is_empty() && v != "0"
    })
}

#[cfg(test)]
mod env_flag_tests {
    use super::env_flag;

    #[test]
    fn unset_empty_zero_and_whitespace_variants_agree() {
        // one process-unique name per case; set/remove is process-global,
        // so keep each name single-use to stay race-free under the
        // parallel test harness
        let cases: [(&str, Option<&str>, bool); 7] = [
            ("STENCILWAVE_ENVFLAG_T0", None, false),
            ("STENCILWAVE_ENVFLAG_T1", Some(""), false),
            ("STENCILWAVE_ENVFLAG_T2", Some("0"), false),
            ("STENCILWAVE_ENVFLAG_T3", Some(" 0 "), false),
            ("STENCILWAVE_ENVFLAG_T4", Some("   "), false),
            ("STENCILWAVE_ENVFLAG_T5", Some("1"), true),
            ("STENCILWAVE_ENVFLAG_T6", Some(" yes "), true),
        ];
        for (name, value, want) in cases {
            match value {
                Some(v) => std::env::set_var(name, v),
                None => std::env::remove_var(name),
            }
            assert_eq!(env_flag(name), want, "{name}={value:?}");
            std::env::remove_var(name);
        }
    }
}
