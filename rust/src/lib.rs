//! # stencilwave
//!
//! A reproduction of *"Efficient multicore-aware parallelization strategies
//! for iterative stencil computations"* (Treibig, Wellein, Hager, 2010,
//! DOI 10.1016/j.jocs.2011.01.010) as a three-layer rust + JAX + Pallas
//! system.
//!
//! The paper's contribution — temporal blocking of Jacobi and Gauss-Seidel
//! smoothers via *multicore-aware wavefront parallelization* — lives in
//! [`coordinator`]: thread groups run time-shifted sweeps through the grid
//! so intermediate updates stay in the shared outer-level cache, plus the
//! pipeline-parallel scheme that extends it to the lexicographic
//! Gauss-Seidel method and the SMT-aware synchronization primitives.
//!
//! Because the paper's evaluation is performance on five 2008–2010 x86
//! sockets, [`simulator`] provides the testbed substrate: parameterized
//! machine models (Tab. 1), an ECM-style analytic performance model
//! (ref. [14] of the paper), a set-associative cache simulator driven by
//! exact access traces, and a STREAM triad model for the Eq. (1) roofline.
//!
//! [`stencil`] holds the numerical substrate (grids, line-update kernels,
//! residuals); [`runtime`] loads the AOT-compiled JAX/Pallas artifacts via
//! PJRT and is the cross-layer validation oracle; [`config`], [`launcher`]
//! and [`figures`] form the experiment harness that regenerates every
//! table and figure of the paper.
//!
//! ## Quick start
//!
//! ```no_run
//! use stencilwave::stencil::grid::Grid3;
//! use stencilwave::coordinator::wavefront::{WavefrontConfig, wavefront_jacobi};
//!
//! let mut u = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! let f = Grid3::zeros(64, 64, 64);
//! let cfg = WavefrontConfig { threads: 4, ..Default::default() };
//! wavefront_jacobi(&mut u, &f, 1.0, &cfg).unwrap();
//! ```

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod launcher;
pub mod metrics;
pub mod runtime;
pub mod simulator;
pub mod stencil;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
