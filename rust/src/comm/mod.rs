//! Rank-to-rank communication: the transport layer under the
//! distributed halo-exchange subsystem ([`crate::coordinator::rank`]).
//!
//! The follow-on papers to the source paper (Wittmann et al.,
//! arXiv:0912.4506 / arXiv:1006.3148) extend multicore temporal
//! blocking to clusters: each process runs a temporal block over its
//! subdomain and exchanges halos with its neighbors. This module keeps
//! that layer MPI-free: a small [`Transport`] trait over a 1-D chain of
//! ranks with nearest-neighbor ([`Peer::Left`] / [`Peer::Right`])
//! message passing, implemented twice —
//!
//! * [`SharedMemTransport`] — ranks as threads in one process, wired by
//!   `std::sync::mpsc` channels (the default fabric);
//! * [`SocketTransport`] — the same protocol over localhost TCP with a
//!   length-prefixed little-endian frame, proving nothing in the rank
//!   layer assumes shared memory.
//!
//! [`HaloExchange`] layers the protocol bookkeeping on a transport:
//! monotone per-direction message tags (a violation is a typed
//! [`CommError::Protocol`]), and the *overlap instrumentation* — every
//! receive first polls non-blocking; a message that is already there
//! was fully overlapped by the receiver's interior compute, one the
//! receiver must block for is an exposed stall. The counters
//! ([`HaloStats`]) are how the tests demonstrate interior progress
//! while halos are in flight.
//!
//! Failure is typed, never a deadlock: a rank that panics drops its
//! transport endpoint, which closes its channels (or sockets), and
//! every neighbor blocked in `recv` gets [`CommError::Disconnected`]
//! instead of waiting forever.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

/// A neighbor in the 1-D rank chain (lower / higher z shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Peer {
    /// The rank owning the adjacent lower-z shard.
    Left,
    /// The rank owning the adjacent higher-z shard.
    Right,
}

impl Peer {
    fn idx(self) -> usize {
        match self {
            Peer::Left => 0,
            Peer::Right => 1,
        }
    }

    /// The opposite direction (a message sent `Right` arrives from the
    /// receiver's `Left`).
    pub fn opposite(self) -> Peer {
        match self {
            Peer::Left => Peer::Right,
            Peer::Right => Peer::Left,
        }
    }
}

impl std::fmt::Display for Peer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Peer::Left => write!(f, "left"),
            Peer::Right => write!(f, "right"),
        }
    }
}

/// Typed communication failure — what the rank layer surfaces through
/// `anyhow` so callers can `downcast_ref::<CommError>()` and branch on
/// a dead peer versus a protocol bug.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// The peer's endpoint is gone: its rank panicked, was torn down,
    /// or closed the connection. Raised from blocked receives (no
    /// deadlock) and from sends into a closed channel alike.
    Disconnected {
        /// The rank that observed the failure.
        rank: usize,
        /// Which neighbor vanished.
        peer: Peer,
    },
    /// A message arrived out of protocol order (its tag does not match
    /// the watermark the receiver expects next).
    Protocol {
        rank: usize,
        peer: Peer,
        expected: u64,
        got: u64,
    },
    /// A socket frame failed validation *before* its payload was
    /// trusted: the wire-supplied length exceeds the receiver's
    /// maximum expected halo payload, or its byte count would overflow.
    /// A corrupt or hostile header must never drive an unbounded
    /// allocation; the offending header rides along so the failure is
    /// attributable.
    Frame {
        rank: usize,
        peer: Peer,
        /// Tag of the rejected frame, straight off the wire.
        tag: u64,
        /// Claimed payload length in f64 words, straight off the wire.
        len: u64,
        /// The receiver's configured maximum payload length.
        limit: u64,
    },
    /// The fabric itself is unusable (no such neighbor, socket setup
    /// failure, corrupt frame).
    Fabric(String),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Disconnected { rank, peer } => {
                write!(f, "rank {rank}: {peer} neighbor disconnected (peer rank died?)")
            }
            CommError::Protocol { rank, peer, expected, got } => write!(
                f,
                "rank {rank}: protocol violation from {peer} neighbor \
                 (expected tag {expected}, got {got})"
            ),
            CommError::Frame { rank, peer, tag, len, limit } => write!(
                f,
                "rank {rank}: oversized frame from {peer} neighbor \
                 (tag {tag} claims {len} words, payload limit {limit})"
            ),
            CommError::Fabric(msg) => write!(f, "comm fabric error: {msg}"),
        }
    }
}

impl std::error::Error for CommError {}

/// Result alias for transport operations.
pub type CommResult<T> = std::result::Result<T, CommError>;

/// One halo message: a protocol tag plus the plane payload (the
/// receiver knows the geometry from its layout; the tag is the
/// watermark the exchange engine checks).
#[derive(Clone, Debug, PartialEq)]
pub struct HaloMsg {
    /// Monotone per-(sender, direction) sequence number.
    pub tag: u64,
    /// The halo planes, z-major, exactly as sliced from grid storage.
    pub payload: Vec<f64>,
}

/// Nearest-neighbor message passing over a 1-D chain of ranks. Send is
/// asynchronous (never blocks on the receiver); receive is available
/// blocking and non-blocking — the non-blocking probe is what the
/// overlap instrumentation is built on.
pub trait Transport: Send {
    /// This endpoint's rank id.
    fn rank(&self) -> usize;

    /// Total ranks in the fabric.
    fn ranks(&self) -> usize;

    /// Queue `msg` to the neighbor `to`. Errors if the neighbor's
    /// endpoint is gone or never existed.
    fn send(&mut self, to: Peer, msg: HaloMsg) -> CommResult<()>;

    /// Block until the next message from `from` arrives.
    fn recv(&mut self, from: Peer) -> CommResult<HaloMsg>;

    /// Non-blocking probe: `Ok(None)` when no message is queued yet.
    fn try_recv(&mut self, from: Peer) -> CommResult<Option<HaloMsg>>;

    /// Whether this rank has a neighbor in direction `peer`.
    fn has(&self, peer: Peer) -> bool {
        match peer {
            Peer::Left => self.rank() > 0,
            Peer::Right => self.rank() + 1 < self.ranks(),
        }
    }
}

// ---------------------------------------------------------------------------
// shared-memory fabric (ranks as threads)

/// In-process transport: each directed neighbor edge is one unbounded
/// mpsc channel. Dropping an endpoint closes its channels, so a dead
/// rank turns every neighbor's pending or future receive into
/// [`CommError::Disconnected`] — deadlock freedom by construction.
pub struct SharedMemTransport {
    rank: usize,
    ranks: usize,
    tx: [Option<Sender<HaloMsg>>; 2],
    rx: [Option<Receiver<HaloMsg>>; 2],
}

impl SharedMemTransport {
    /// Build the full fabric: one endpoint per rank, adjacent ranks
    /// wired both ways.
    pub fn fabric(ranks: usize) -> Vec<SharedMemTransport> {
        let mut eps: Vec<SharedMemTransport> = (0..ranks)
            .map(|rank| SharedMemTransport { rank, ranks, tx: [None, None], rx: [None, None] })
            .collect();
        for i in 0..ranks.saturating_sub(1) {
            let (up_tx, up_rx) = channel(); // i -> i+1
            let (down_tx, down_rx) = channel(); // i+1 -> i
            eps[i].tx[Peer::Right.idx()] = Some(up_tx);
            eps[i].rx[Peer::Right.idx()] = Some(down_rx);
            eps[i + 1].tx[Peer::Left.idx()] = Some(down_tx);
            eps[i + 1].rx[Peer::Left.idx()] = Some(up_rx);
        }
        eps
    }

    fn no_neighbor(&self, peer: Peer) -> CommError {
        CommError::Fabric(format!("rank {} has no {peer} neighbor", self.rank))
    }
}

impl Transport for SharedMemTransport {
    fn rank(&self) -> usize {
        self.rank
    }
    fn ranks(&self) -> usize {
        self.ranks
    }
    fn send(&mut self, to: Peer, msg: HaloMsg) -> CommResult<()> {
        let tx = self.tx[to.idx()].as_ref().ok_or_else(|| self.no_neighbor(to))?;
        tx.send(msg).map_err(|_| CommError::Disconnected { rank: self.rank, peer: to })
    }
    fn recv(&mut self, from: Peer) -> CommResult<HaloMsg> {
        let rx = self.rx[from.idx()].as_ref().ok_or_else(|| self.no_neighbor(from))?;
        rx.recv().map_err(|_| CommError::Disconnected { rank: self.rank, peer: from })
    }
    fn try_recv(&mut self, from: Peer) -> CommResult<Option<HaloMsg>> {
        let rx = self.rx[from.idx()].as_ref().ok_or_else(|| self.no_neighbor(from))?;
        match rx.try_recv() {
            Ok(msg) => Ok(Some(msg)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(CommError::Disconnected { rank: self.rank, peer: from })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// localhost socket fabric

/// Frame one message onto a socket: `[tag u64][len u64][len × f64]`,
/// all little-endian. `f64::to_le_bytes` round-trips bit-exactly, so
/// socket ranks stay bit-identical to shared-memory ranks.
fn write_frame(stream: &mut TcpStream, msg: &HaloMsg) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(16 + msg.payload.len() * 8);
    buf.extend_from_slice(&msg.tag.to_le_bytes());
    buf.extend_from_slice(&(msg.payload.len() as u64).to_le_bytes());
    for v in &msg.payload {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&buf)
}

/// A frame header the receiver refused to honor: the claimed payload
/// length is over the configured limit (or `len * 8` would overflow
/// the byte count). Produced by the reader thread, surfaced to the
/// consumer as [`CommError::Frame`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameViolation {
    pub tag: u64,
    pub len: u64,
    pub limit: u64,
}

fn read_frame(
    stream: &mut TcpStream,
    max_payload_len: usize,
) -> std::io::Result<Result<HaloMsg, FrameViolation>> {
    let mut header = [0u8; 16];
    stream.read_exact(&mut header)?;
    let tag = u64::from_le_bytes(header[..8].try_into().unwrap());
    let len = u64::from_le_bytes(header[8..].try_into().unwrap());
    // validate the wire length BEFORE allocating: the old `len * 8`
    // could overflow usize (debug panic / release wrap into a short,
    // non-multiple-of-8 buffer that `chunks_exact(8)` then silently
    // truncated), and even a non-overflowing corrupt length triggered
    // an unbounded allocation. Checked u64 arithmetic plus the
    // receiver's halo-payload cap close both; `bytes` is exactly
    // `len * 8` afterwards, so the f64 decode can never see a ragged
    // remainder.
    let bytes = match len.checked_mul(8).and_then(|b| usize::try_from(b).ok()) {
        Some(b) if len <= max_payload_len as u64 => b,
        _ => {
            return Ok(Err(FrameViolation { tag, len, limit: max_payload_len as u64 }));
        }
    };
    let mut raw = vec![0u8; bytes];
    stream.read_exact(&mut raw)?;
    let payload =
        raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
    Ok(Ok(HaloMsg { tag, payload }))
}

/// Socket transport over localhost TCP — the same chain protocol as
/// [`SharedMemTransport`] behind the same trait, so `RankSet` runs
/// unchanged on either fabric (and an out-of-process fabric only needs
/// a connect-by-address constructor, not new rank logic).
///
/// Each neighbor edge is one duplex TCP connection; a per-neighbor
/// reader thread decodes frames into an mpsc queue, which gives
/// `try_recv`/`recv` the exact shared-memory semantics and turns a
/// closed connection (peer death) into [`CommError::Disconnected`].
pub struct SocketTransport {
    rank: usize,
    ranks: usize,
    max_payload_len: usize,
    streams: [Option<TcpStream>; 2],
    rx: [Option<Receiver<Result<HaloMsg, FrameViolation>>>; 2],
}

/// Fallback frame-payload cap for [`SocketTransport::fabric_local`]
/// when the caller has no tighter bound: 2^24 f64 words = 128 MiB per
/// frame. Large enough for any halo this codebase exchanges, small
/// enough that a corrupt header cannot OOM the receiver. Callers that
/// know their geometry (the rank layer does: `depth × ny × nx`) should
/// use [`SocketTransport::fabric_local_with_limit`] instead.
pub const DEFAULT_MAX_FRAME_LEN: usize = 1 << 24;

impl SocketTransport {
    /// Build a loopback fabric: `ranks` endpoints connected in a chain
    /// over 127.0.0.1, frames capped at [`DEFAULT_MAX_FRAME_LEN`].
    /// Fails cleanly where an environment forbids sockets — callers
    /// treat that as "fabric unavailable", not a bug.
    pub fn fabric_local(ranks: usize) -> std::io::Result<Vec<SocketTransport>> {
        Self::fabric_local_with_limit(ranks, DEFAULT_MAX_FRAME_LEN)
    }

    /// [`fabric_local`](Self::fabric_local) with an explicit per-frame
    /// payload cap (in f64 words): a received header claiming more is
    /// rejected as [`CommError::Frame`] before any allocation.
    pub fn fabric_local_with_limit(
        ranks: usize,
        max_payload_len: usize,
    ) -> std::io::Result<Vec<SocketTransport>> {
        let mut eps: Vec<SocketTransport> = (0..ranks)
            .map(|rank| SocketTransport {
                rank,
                ranks,
                max_payload_len,
                streams: [None, None],
                rx: [None, None],
            })
            .collect();
        for i in 0..ranks.saturating_sub(1) {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            let lower = TcpStream::connect(addr)?;
            let (upper, _) = listener.accept()?;
            lower.set_nodelay(true)?;
            upper.set_nodelay(true)?;
            eps[i].install(Peer::Right, lower)?;
            eps[i + 1].install(Peer::Left, upper)?;
        }
        Ok(eps)
    }

    /// Build a single endpoint over an already-established stream —
    /// the injection hook the corrupt-frame tests use (the far side of
    /// `stream` stays a raw socket the test writes arbitrary bytes
    /// into), and the seam an out-of-process fabric would build on.
    pub fn from_stream(
        rank: usize,
        ranks: usize,
        peer: Peer,
        stream: TcpStream,
        max_payload_len: usize,
    ) -> std::io::Result<SocketTransport> {
        let mut ep = SocketTransport {
            rank,
            ranks,
            max_payload_len,
            streams: [None, None],
            rx: [None, None],
        };
        ep.install(peer, stream)?;
        Ok(ep)
    }

    fn install(&mut self, peer: Peer, stream: TcpStream) -> std::io::Result<()> {
        let (tx, rx) = channel();
        let mut read_half = stream.try_clone()?;
        let limit = self.max_payload_len;
        std::thread::spawn(move || {
            // EOF or any read error ends the feed; dropping `tx` then
            // surfaces Disconnected to the consumer. A frame violation
            // is forwarded typed, then the feed stops too: the stream
            // is desynchronized past a rejected header, so nothing
            // after it can be trusted.
            loop {
                match read_frame(&mut read_half, limit) {
                    Ok(frame) => {
                        let poisoned = frame.is_err();
                        if tx.send(frame).is_err() || poisoned {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        });
        self.streams[peer.idx()] = Some(stream);
        self.rx[peer.idx()] = Some(rx);
        Ok(())
    }

    fn accept(&self, from: Peer, frame: Result<HaloMsg, FrameViolation>) -> CommResult<HaloMsg> {
        frame.map_err(|v| CommError::Frame {
            rank: self.rank,
            peer: from,
            tag: v.tag,
            len: v.len,
            limit: v.limit,
        })
    }

    fn no_neighbor(&self, peer: Peer) -> CommError {
        CommError::Fabric(format!("rank {} has no {peer} neighbor", self.rank))
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        // shutdown (not just drop) so reader-thread clones on both ends
        // observe EOF and exit
        for s in self.streams.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl Transport for SocketTransport {
    fn rank(&self) -> usize {
        self.rank
    }
    fn ranks(&self) -> usize {
        self.ranks
    }
    fn send(&mut self, to: Peer, msg: HaloMsg) -> CommResult<()> {
        let rank = self.rank;
        let stream = self.streams[to.idx()].as_mut().ok_or_else(|| {
            CommError::Fabric(format!("rank {rank} has no {to} neighbor"))
        })?;
        write_frame(stream, &msg).map_err(|_| CommError::Disconnected { rank, peer: to })
    }
    fn recv(&mut self, from: Peer) -> CommResult<HaloMsg> {
        let rx = self.rx[from.idx()].as_ref().ok_or_else(|| self.no_neighbor(from))?;
        match rx.recv() {
            Ok(frame) => self.accept(from, frame),
            Err(_) => Err(CommError::Disconnected { rank: self.rank, peer: from }),
        }
    }
    fn try_recv(&mut self, from: Peer) -> CommResult<Option<HaloMsg>> {
        let rx = self.rx[from.idx()].as_ref().ok_or_else(|| self.no_neighbor(from))?;
        match rx.try_recv() {
            Ok(frame) => self.accept(from, frame).map(Some),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(CommError::Disconnected { rank: self.rank, peer: from })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the exchange engine: tags, watermark checks, overlap instrumentation

/// Snapshot of the fabric-wide halo traffic counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HaloStats {
    /// Receives whose message had already arrived when the consumer
    /// asked — the exchange was fully overlapped by interior compute.
    pub overlapped_recvs: u64,
    /// Receives that had to block — exposed (non-overlapped) waits.
    pub stalled_recvs: u64,
    /// Messages sent.
    pub messages: u64,
    /// Payload bytes sent.
    pub payload_bytes: u64,
}

/// Shared atomic counters aggregated across every rank's
/// [`HaloExchange`] (one `Arc` per `RankSet`).
#[derive(Debug, Default)]
pub struct SharedHaloStats {
    overlapped: AtomicU64,
    stalled: AtomicU64,
    messages: AtomicU64,
    payload_bytes: AtomicU64,
}

impl SharedHaloStats {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Zero all counters (a `RankSet` resets per run).
    pub fn reset(&self) {
        self.overlapped.store(0, Ordering::Relaxed);
        self.stalled.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
        self.payload_bytes.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HaloStats {
        HaloStats {
            overlapped_recvs: self.overlapped.load(Ordering::Relaxed),
            stalled_recvs: self.stalled.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
            payload_bytes: self.payload_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Per-rank halo-exchange engine: wraps a [`Transport`] endpoint with
/// monotone send/receive tags (the watermark protocol made explicit —
/// the generalization of `gs_multigroup`'s two-sided left-wait /
/// right-wait rounds to rank granularity) and the overlap counters.
pub struct HaloExchange {
    tp: Box<dyn Transport>,
    stats: Arc<SharedHaloStats>,
    next_send: [u64; 2],
    next_recv: [u64; 2],
}

impl HaloExchange {
    pub fn new(tp: Box<dyn Transport>, stats: Arc<SharedHaloStats>) -> Self {
        Self { tp, stats, next_send: [0, 0], next_recv: [0, 0] }
    }

    pub fn rank(&self) -> usize {
        self.tp.rank()
    }

    /// Whether this rank has a neighbor in direction `peer`.
    pub fn has(&self, peer: Peer) -> bool {
        self.tp.has(peer)
    }

    /// Post `planes` to the neighbor `to`, tagged with this direction's
    /// next watermark. Never blocks on the receiver — the send is in
    /// flight while this rank continues computing.
    pub fn send(&mut self, to: Peer, planes: Vec<f64>) -> CommResult<()> {
        let tag = self.next_send[to.idx()];
        self.next_send[to.idx()] += 1;
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.payload_bytes.fetch_add(planes.len() as u64 * 8, Ordering::Relaxed);
        self.tp.send(to, HaloMsg { tag, payload: planes })
    }

    /// Receive the next halo from `from`, verifying its watermark tag.
    ///
    /// Polls non-blocking first: a message already delivered means the
    /// exchange was hidden behind this rank's interior compute
    /// (counted `overlapped`); otherwise the wait is exposed (counted
    /// `stalled`) and blocks until the neighbor posts — or returns
    /// [`CommError::Disconnected`] if the neighbor died.
    pub fn recv(&mut self, from: Peer) -> CommResult<Vec<f64>> {
        let msg = match self.tp.try_recv(from)? {
            Some(msg) => {
                self.stats.overlapped.fetch_add(1, Ordering::Relaxed);
                msg
            }
            None => {
                self.stats.stalled.fetch_add(1, Ordering::Relaxed);
                self.tp.recv(from)?
            }
        };
        let expected = self.next_recv[from.idx()];
        if msg.tag != expected {
            return Err(CommError::Protocol {
                rank: self.tp.rank(),
                peer: from,
                expected,
                got: msg.tag,
            });
        }
        self.next_recv[from.idx()] += 1;
        Ok(msg.payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_mem_fabric_routes_and_orders_messages() {
        let mut eps = SharedMemTransport::fabric(3);
        assert!(!eps[0].has(Peer::Left) && eps[0].has(Peer::Right));
        assert!(eps[1].has(Peer::Left) && eps[1].has(Peer::Right));
        assert!(eps[2].has(Peer::Left) && !eps[2].has(Peer::Right));
        let m = |tag, v: f64| HaloMsg { tag, payload: vec![v, v + 0.5] };
        eps[0].send(Peer::Right, m(0, 1.0)).unwrap();
        eps[0].send(Peer::Right, m(1, 2.0)).unwrap();
        eps[2].send(Peer::Left, m(0, 3.0)).unwrap();
        assert_eq!(eps[1].recv(Peer::Left).unwrap(), m(0, 1.0));
        assert_eq!(eps[1].recv(Peer::Left).unwrap(), m(1, 2.0));
        assert_eq!(eps[1].recv(Peer::Right).unwrap(), m(0, 3.0));
        // sending off the end of the chain is a typed fabric error
        assert!(matches!(eps[2].send(Peer::Right, m(0, 0.0)), Err(CommError::Fabric(_))));
        assert!(matches!(eps[0].try_recv(Peer::Left), Err(CommError::Fabric(_))));
    }

    #[test]
    fn try_recv_distinguishes_empty_from_disconnected() {
        let mut eps = SharedMemTransport::fabric(2);
        let mut right = eps.pop().unwrap();
        let mut left = eps.pop().unwrap();
        assert_eq!(right.try_recv(Peer::Left).unwrap(), None);
        left.send(Peer::Right, HaloMsg { tag: 0, payload: vec![7.0] }).unwrap();
        assert!(right.try_recv(Peer::Left).unwrap().is_some());
        drop(left);
        assert_eq!(
            right.try_recv(Peer::Left),
            Err(CommError::Disconnected { rank: 1, peer: Peer::Left })
        );
        assert_eq!(
            right.recv(Peer::Left),
            Err(CommError::Disconnected { rank: 1, peer: Peer::Left })
        );
        assert!(matches!(
            right.send(Peer::Left, HaloMsg { tag: 0, payload: vec![] }),
            Err(CommError::Disconnected { .. })
        ));
    }

    #[test]
    fn blocked_recv_wakes_on_peer_death_not_deadlock() {
        let mut eps = SharedMemTransport::fabric(2);
        let right = eps.pop().unwrap();
        let mut left = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            drop(right); // rank 1 "dies" while rank 0 is blocked below
        });
        let err = left.recv(Peer::Right).unwrap_err();
        assert_eq!(err, CommError::Disconnected { rank: 0, peer: Peer::Right });
        t.join().unwrap();
    }

    #[test]
    fn exchange_engine_tags_and_counts_overlap() {
        let mut eps = SharedMemTransport::fabric(2);
        let stats = SharedHaloStats::new();
        let mut right = HaloExchange::new(Box::new(eps.pop().unwrap()), Arc::clone(&stats));
        let mut left = HaloExchange::new(Box::new(eps.pop().unwrap()), Arc::clone(&stats));
        // already-delivered message: overlapped
        left.send(Peer::Right, vec![1.0, 2.0]).unwrap();
        assert_eq!(right.recv(Peer::Left).unwrap(), vec![1.0, 2.0]);
        // not yet delivered: the consumer stalls until the peer posts
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            left.send(Peer::Right, vec![3.0]).unwrap();
            left
        });
        assert_eq!(right.recv(Peer::Left).unwrap(), vec![3.0]);
        let left = t.join().unwrap();
        let s = stats.snapshot();
        assert_eq!(s.overlapped_recvs, 1);
        assert_eq!(s.stalled_recvs, 1);
        assert_eq!(s.messages, 2);
        assert_eq!(s.payload_bytes, 3 * 8);
        drop(left);
        stats.reset();
        assert_eq!(stats.snapshot(), HaloStats::default());
    }

    #[test]
    fn exchange_engine_rejects_out_of_order_tags() {
        let mut eps = SharedMemTransport::fabric(2);
        let stats = SharedHaloStats::new();
        let mut raw_left = eps.remove(0);
        // hand-send a wrong-tag frame under the engine
        raw_left.send(Peer::Right, HaloMsg { tag: 5, payload: vec![0.0] }).unwrap();
        let mut right = HaloExchange::new(Box::new(eps.pop().unwrap()), stats);
        match right.recv(Peer::Left) {
            Err(CommError::Protocol { expected: 0, got: 5, peer: Peer::Left, .. }) => {}
            other => panic!("want protocol error, got {other:?}"),
        }
    }

    #[test]
    fn comm_errors_downcast_through_anyhow() {
        let err = anyhow::Error::new(CommError::Disconnected { rank: 2, peer: Peer::Left });
        let typed = err.downcast_ref::<CommError>().expect("typed comm error");
        assert_eq!(*typed, CommError::Disconnected { rank: 2, peer: Peer::Left });
        let msg = err.to_string();
        assert!(msg.contains("rank 2") && msg.contains("left"), "{msg}");
    }

    #[test]
    fn socket_fabric_enforces_its_payload_limit() {
        // a frame over the receiver's cap is rejected typed at the
        // receiver — before allocation — and the poisoned stream then
        // reads as Disconnected; an at-the-cap frame passes untouched
        let mut eps = match SocketTransport::fabric_local_with_limit(2, 3) {
            Ok(eps) => eps,
            Err(e) => {
                eprintln!("skipping socket limit test (no loopback): {e}");
                return;
            }
        };
        eps[0].send(Peer::Right, HaloMsg { tag: 0, payload: vec![1.0, 2.0, 3.0] }).unwrap();
        assert_eq!(eps[1].recv(Peer::Left).unwrap().payload.len(), 3);
        eps[0].send(Peer::Right, HaloMsg { tag: 1, payload: vec![0.0; 4] }).unwrap();
        assert_eq!(
            eps[1].recv(Peer::Left).unwrap_err(),
            CommError::Frame { rank: 1, peer: Peer::Left, tag: 1, len: 4, limit: 3 }
        );
        assert_eq!(
            eps[1].recv(Peer::Left).unwrap_err(),
            CommError::Disconnected { rank: 1, peer: Peer::Left }
        );
    }

    #[test]
    fn socket_fabric_matches_shared_memory_semantics() {
        // guarded: environments that forbid loopback sockets skip, they
        // don't fail — the fabric is an alternative, not a requirement
        let mut eps = match SocketTransport::fabric_local(3) {
            Ok(eps) => eps,
            Err(e) => {
                eprintln!("skipping socket fabric test (no loopback): {e}");
                return;
            }
        };
        // exact f64 bit round-trip through the wire frame
        let vals = vec![1.0, -0.0, f64::MIN_POSITIVE, 1.0 / 3.0, f64::MAX];
        eps[0].send(Peer::Right, HaloMsg { tag: 0, payload: vals.clone() }).unwrap();
        let got = eps[1].recv(Peer::Left).unwrap();
        assert_eq!(got.tag, 0);
        assert_eq!(got.payload.len(), vals.len());
        for (a, b) in got.payload.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // chain routing both ways
        eps[2].send(Peer::Left, HaloMsg { tag: 0, payload: vec![9.0] }).unwrap();
        assert_eq!(eps[1].recv(Peer::Right).unwrap().payload, vec![9.0]);
        // peer death surfaces as Disconnected on the blocked side
        let rank2 = eps.pop().unwrap();
        let mut rank1 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            drop(rank2);
        });
        let err = rank1.recv(Peer::Right).unwrap_err();
        assert!(matches!(err, CommError::Disconnected { rank: 1, peer: Peer::Right }), "{err:?}");
        t.join().unwrap();
    }
}
