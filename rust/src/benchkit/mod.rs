//! In-tree micro-benchmark harness (offline build: no criterion).
//!
//! Provides warmup + repeated timed runs with min/median/mean reporting,
//! a `black_box` sink, and an aligned table printer. Every bench binary
//! under `rust/benches/` uses this; output is plain text designed to be
//! `tee`-able into `bench_output.txt`.

use std::time::{Duration, Instant};

/// Re-export of the optimizer barrier.
pub use std::hint::black_box;

/// True when `STENCILWAVE_BENCH_SMOKE` asks for the CI smoke variant of
/// a bench (one small case, two timed reps). Usual env-flag convention:
/// unset, empty and `"0"` all mean off. One home for the check so every
/// bench binary interprets the flag identically.
pub fn smoke() -> bool {
    std::env::var("STENCILWAVE_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub reps: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    /// Optional throughput in MLUP/s (filled by [`bench_mlups`]).
    pub mlups: Option<f64>,
}

impl Sample {
    pub fn min_secs(&self) -> f64 {
        self.min.as_secs_f64()
    }
}

/// Run `f` with `warmup` untimed and `reps` timed repetitions.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    assert!(reps >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / reps as u32;
    Sample { name: name.to_string(), reps, min, median, mean, mlups: None }
}

/// Like [`bench`] but derives MLUP/s from `updates` per invocation.
pub fn bench_mlups<T>(
    name: &str,
    updates: u64,
    warmup: usize,
    reps: usize,
    f: impl FnMut() -> T,
) -> Sample {
    let mut s = bench(name, warmup, reps, f);
    s.mlups = Some(updates as f64 / s.min_secs() / 1e6);
    s
}

/// Print a header for a bench table.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>12}",
        "case", "min(ms)", "median(ms)", "mean(ms)", "MLUP/s"
    );
}

/// Print one sample row.
pub fn report(s: &Sample) {
    println!(
        "{:<44} {:>10.3} {:>10.3} {:>10.3} {:>12}",
        s.name,
        s.min.as_secs_f64() * 1e3,
        s.median.as_secs_f64() * 1e3,
        s.mean.as_secs_f64() * 1e3,
        s.mlups.map(|m| format!("{m:.1}")).unwrap_or_else(|| "-".into()),
    );
}

/// Convenience: run + report, returning the sample for assertions.
pub fn run_case<T>(name: &str, updates: u64, f: impl FnMut() -> T) -> Sample {
    let s = bench_mlups(name, updates, 1, 5, f);
    report(&s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_ordered_stats() {
        let s = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert_eq!(s.reps, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 2);
    }

    #[test]
    fn mlups_uses_min_time() {
        let s = bench_mlups("m", 1_000_000, 0, 3, || std::thread::sleep(Duration::from_millis(2)));
        let m = s.mlups.unwrap();
        assert!(m > 0.0 && m < 1000.0, "{m}");
    }
}
