//! In-tree micro-benchmark harness (offline build: no criterion).
//!
//! Provides warmup + repeated timed runs with min/median/mean reporting,
//! a `black_box` sink, and an aligned table printer. Every bench binary
//! under `rust/benches/` uses this; output is plain text designed to be
//! `tee`-able into `bench_output.txt`.

use std::time::{Duration, Instant};

/// Re-export of the optimizer barrier.
pub use std::hint::black_box;

/// True when `STENCILWAVE_BENCH_SMOKE` asks for the CI smoke variant of
/// a bench (one small case, two timed reps). Shares [`crate::env_flag`]'s
/// convention (unset / empty / whitespace / `"0"` mean off) so every
/// bench binary and the SIMD probe interpret flags identically.
pub fn smoke() -> bool {
    crate::env_flag("STENCILWAVE_BENCH_SMOKE")
}

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub reps: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    /// Optional throughput in MLUP/s (filled by [`bench_mlups`]).
    pub mlups: Option<f64>,
}

impl Sample {
    pub fn min_secs(&self) -> f64 {
        self.min.as_secs_f64()
    }
}

/// Run `f` with `warmup` untimed and `reps` timed repetitions.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    assert!(reps >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / reps as u32;
    Sample { name: name.to_string(), reps, min, median, mean, mlups: None }
}

/// Like [`bench`] but derives MLUP/s from `updates` per invocation.
pub fn bench_mlups<T>(
    name: &str,
    updates: u64,
    warmup: usize,
    reps: usize,
    f: impl FnMut() -> T,
) -> Sample {
    let mut s = bench(name, warmup, reps, f);
    s.mlups = Some(updates as f64 / s.min_secs() / 1e6);
    s
}

/// Print a header for a bench table.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>12}",
        "case", "min(ms)", "median(ms)", "mean(ms)", "MLUP/s"
    );
}

/// Print one sample row.
pub fn report(s: &Sample) {
    println!(
        "{:<44} {:>10.3} {:>10.3} {:>10.3} {:>12}",
        s.name,
        s.min.as_secs_f64() * 1e3,
        s.median.as_secs_f64() * 1e3,
        s.mean.as_secs_f64() * 1e3,
        s.mlups.map(|m| format!("{m:.1}")).unwrap_or_else(|| "-".into()),
    );
}

/// Convenience: run + report, returning the sample for assertions.
pub fn run_case<T>(name: &str, updates: u64, f: impl FnMut() -> T) -> Sample {
    let s = bench_mlups(name, updates, 1, 5, f);
    report(&s);
    s
}

/// One machine-readable benchmark result — the record the CI bench
/// smoke emits as `BENCH_*.json` so perf history survives the log
/// scroll-off.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Scheme config name (`jacobi_wavefront`, ...).
    pub scheme: String,
    /// Operator config name (`laplace7`, ...).
    pub op: String,
    /// Worker threads the schedule dispatched.
    pub threads: usize,
    /// Whether the run asked for SMT co-scheduling.
    pub smt: bool,
    /// Whether non-temporal stores were enabled.
    pub nt_stores: bool,
    /// z-axis rank shards the case ran across (1 = plain solver).
    pub ranks: usize,
    /// Best-rep throughput in MLUP/s.
    pub mlups: f64,
    /// Case-specific numeric extras appended as additional JSON keys
    /// (e.g. the queue-pressure smoke's `rejected_full`/`shed_expired`
    /// counters). Empty for plain throughput records.
    pub extras: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize records as a JSON array (hand-rolled: offline build, no
/// serde; round-trips through [`crate::config::json::parse`]).
pub fn records_to_json(records: &[BenchRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let mut extras = String::new();
        for (k, v) in &r.extras {
            extras.push_str(&format!(", \"{}\": {v:.3}", json_escape(k)));
        }
        out.push_str(&format!(
            "  {{\"scheme\": \"{}\", \"op\": \"{}\", \"threads\": {}, \
             \"smt\": {}, \"nt_stores\": {}, \"ranks\": {}, \"mlups\": {:.3}{}}}{}\n",
            json_escape(&r.scheme),
            json_escape(&r.op),
            r.threads,
            r.smt,
            r.nt_stores,
            r.ranks,
            r.mlups,
            extras,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push_str("]\n");
    out
}

/// Write `records` to `path` (conventionally `BENCH_<bench>.json`).
pub fn write_records(path: &std::path::Path, records: &[BenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, records_to_json(records))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_ordered_stats() {
        let s = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert_eq!(s.reps, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 2);
    }

    #[test]
    fn mlups_uses_min_time() {
        let s = bench_mlups("m", 1_000_000, 0, 3, || std::thread::sleep(Duration::from_millis(2)));
        let m = s.mlups.unwrap();
        assert!(m > 0.0 && m < 1000.0, "{m}");
    }

    #[test]
    fn bench_records_roundtrip_through_the_json_parser() {
        let records = vec![
            BenchRecord {
                scheme: "jacobi_wavefront".into(),
                op: "laplace7".into(),
                threads: 4,
                smt: false,
                nt_stores: true,
                ranks: 1,
                mlups: 123.456,
                extras: vec![("rejected_full".into(), 4.0), ("shed_expired".into(), 2.0)],
            },
            BenchRecord {
                scheme: "gs_multigroup".into(),
                op: "a\"b\\c".into(), // escaping never corrupts the doc
                threads: 8,
                smt: true,
                nt_stores: false,
                ranks: 2,
                mlups: 0.5,
                extras: vec![],
            },
        ];
        let text = records_to_json(&records);
        let v = crate::config::json::parse(&text).unwrap();
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("scheme").unwrap().as_str(), Some("jacobi_wavefront"));
        assert_eq!(arr[0].get("threads").unwrap().as_u64(), Some(4));
        assert_eq!(arr[0].get("nt_stores").unwrap().as_bool(), Some(true));
        assert!((arr[0].get("mlups").unwrap().as_f64().unwrap() - 123.456).abs() < 1e-9);
        assert_eq!(arr[0].get("ranks").unwrap().as_u64(), Some(1));
        // extras ride as ordinary top-level keys; absent when empty
        assert_eq!(arr[0].get("rejected_full").unwrap().as_f64(), Some(4.0));
        assert_eq!(arr[0].get("shed_expired").unwrap().as_f64(), Some(2.0));
        assert!(arr[1].get("rejected_full").is_none());
        assert_eq!(arr[1].get("op").unwrap().as_str(), Some("a\"b\\c"));
        assert_eq!(arr[1].get("smt").unwrap().as_bool(), Some(true));
        assert_eq!(arr[1].get("ranks").unwrap().as_u64(), Some(2));
        // empty record lists are still a valid (empty) JSON array
        let empty = crate::config::json::parse(&records_to_json(&[])).unwrap();
        assert!(empty.as_array().unwrap().is_empty());
    }
}
