//! Minimal argument parser for the CLI (offline build: no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with typed getters and an unknown-flag check —
//! the subset the `stencilwave` subcommands need.

use std::collections::BTreeMap;

use crate::Result;

/// Parsed arguments of one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Flags may appear as `--k v` or `--k=v`;
    /// flags in `boolean` take no value.
    pub fn parse(raw: &[String], boolean: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if boolean.contains(&name) {
                    out.flags.insert(name.to_string(), "true".to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Reject flags outside the allowed set (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            anyhow::ensure!(known.contains(&k.as_str()), "unknown flag --{k} (known: {known:?})");
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&v(&["fig8", "--n", "64", "--csv", "--out=x.txt"]), &["csv"]).unwrap();
        assert_eq!(a.positional(0), Some("fig8"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 64);
        assert!(a.get_bool("csv"));
        assert_eq!(a.get("out"), Some("x.txt"));
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(&v(&["--n"]), &[]).is_err());
    }

    #[test]
    fn unknown_flags_are_caught() {
        let a = Args::parse(&v(&["--typo", "1"]), &[]).unwrap();
        assert!(a.check_known(&["n", "t"]).is_err());
        let b = Args::parse(&v(&["--n", "1"]), &[]).unwrap();
        b.check_known(&["n"]).unwrap();
    }
}
