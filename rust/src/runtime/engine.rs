//! PJRT execution engine: compile-once, execute-many artifact runner.

use std::collections::HashMap;
use std::path::Path;

use crate::stencil::grid::Grid3;
use crate::Result;

use super::artifacts::Manifest;

/// A loaded PJRT runtime holding compiled executables.
///
/// Compilation is lazy and cached: the first `run_*` of an artifact
/// compiles it on the CPU PJRT client, later calls reuse the executable —
/// the request path is load → execute only.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e}"))?;
        let manifest = Manifest::load(dir)?;
        Ok(Self { client, manifest, compiled: HashMap::new() })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&Manifest::default_dir())
    }

    /// The artifact catalog.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let info = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
                .clone();
            let path = self.manifest.path_of(&info);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    fn grid_literal(g: &Grid3) -> Result<xla::Literal> {
        let (nz, ny, nx) = g.shape();
        xla::Literal::vec1(g.data())
            .reshape(&[nz as i64, ny as i64, nx as i64])
            .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
    }

    fn literal_grid(lit: &xla::Literal, shape: (usize, usize, usize)) -> Result<Grid3> {
        let data = lit.to_vec::<f64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        let (nz, ny, nx) = shape;
        anyhow::ensure!(data.len() == nz * ny * nx, "output size mismatch");
        let mut g = Grid3::zeros(nz, ny, nx);
        g.data_mut().copy_from_slice(&data);
        Ok(g)
    }

    /// Execute an artifact on grid inputs; returns the raw output tuple.
    fn run_raw(&mut self, name: &str, inputs: &[&Grid3]) -> Result<Vec<xla::Literal>> {
        let info = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            info.inputs.len() == inputs.len(),
            "{name}: expected {} inputs, got {}",
            info.inputs.len(),
            inputs.len()
        );
        for (t, g) in info.inputs.iter().zip(inputs) {
            let want = (t.shape[0], t.shape[1], t.shape[2]);
            anyhow::ensure!(g.shape() == want, "{name}: input shape {:?} != {:?}", g.shape(), want);
        }
        let n_outputs = info.n_outputs;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|g| Self::grid_literal(g)).collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple at top level.
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
        anyhow::ensure!(parts.len() == n_outputs, "{name}: {} outputs, expected {n_outputs}", parts.len());
        Ok(parts)
    }

    /// Execute a grid→grid artifact (smoother step / sweep).
    pub fn run_grid(&mut self, name: &str, inputs: &[&Grid3]) -> Result<Grid3> {
        let shape = inputs[0].shape();
        let parts = self.run_raw(name, inputs)?;
        Self::literal_grid(&parts[0], shape)
    }

    /// Execute a grid→scalar artifact (residual norm).
    pub fn run_scalar(&mut self, name: &str, inputs: &[&Grid3]) -> Result<f64> {
        let parts = self.run_raw(name, inputs)?;
        let v = parts[0].to_vec::<f64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        anyhow::ensure!(v.len() == 1, "expected a scalar, got {} values", v.len());
        Ok(v[0])
    }

    /// Execute a grid→(grid, scalar) artifact (smooth_and_residual).
    pub fn run_grid_scalar(&mut self, name: &str, inputs: &[&Grid3]) -> Result<(Grid3, f64)> {
        let shape = inputs[0].shape();
        let parts = self.run_raw(name, inputs)?;
        anyhow::ensure!(parts.len() == 2, "expected 2 outputs");
        let g = Self::literal_grid(&parts[0], shape)?;
        let s = parts[1].to_vec::<f64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        Ok((g, s[0]))
    }
}

/// Result of one cross-layer validation comparison.
#[derive(Clone, Debug)]
pub struct Validation {
    pub artifact: String,
    pub max_abs_diff: f64,
    pub tolerance: f64,
}

impl Validation {
    pub fn passed(&self) -> bool {
        self.max_abs_diff <= self.tolerance
    }
}

/// Compare the rust engine against a Pallas artifact on random inputs.
///
/// The two layers implement the same update with different fp association
/// (jnp reductions vs scalar loops), so the tolerance is round-off-scale
/// but not zero.
pub fn validate(rt: &mut Runtime, name: &str) -> Result<Validation> {
    use crate::stencil::gauss_seidel::{gs_sweeps, GsKernel};
    use crate::stencil::jacobi::jacobi_steps;

    let info = rt
        .manifest()
        .get(name)
        .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
        .clone();
    let shape = info.grid_shape().ok_or_else(|| anyhow::anyhow!("{name}: no grid input"))?;
    let (nz, ny, nx) = shape;
    let u = Grid3::random(nz, ny, nx, 2024);
    let f = Grid3::random(nz, ny, nx, 4048);
    let h2 = info.param_f64("h2").unwrap_or(1.0);
    let iters = info.param_usize("iters").unwrap_or(1);
    let scheme = info.params.get("scheme").and_then(|v| v.as_str()).unwrap_or("jacobi");

    let (pallas, rust) = match scheme {
        "gauss_seidel" => {
            let out = rt.run_grid(name, &[&u])?;
            let mut mine = u.clone();
            gs_sweeps(&mut mine, iters, GsKernel::Interleaved);
            (out, mine)
        }
        "jacobi" => {
            let out = rt.run_grid(name, &[&u, &f])?;
            (out, jacobi_steps(&u, &f, h2, iters))
        }
        other => anyhow::bail!("cannot validate scheme '{other}'"),
    };
    Ok(Validation {
        artifact: name.to_string(),
        max_abs_diff: rust.max_abs_diff(&pallas),
        tolerance: 1e-11 * iters.max(1) as f64,
    })
}
