//! The artifact manifest contract shared with `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::config::json::{self, Value};
use crate::Result;

/// Input tensor description.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorInfo>,
    pub n_outputs: usize,
    /// Static parameters recorded at lowering time (h2, iters, scheme, …).
    pub params: Value,
}

impl ArtifactInfo {
    fn from_json(v: &Value) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("artifact missing 'name'"))?
            .to_string();
        let file = v
            .get("file")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("{name}: missing 'file'"))?
            .to_string();
        let inputs = v
            .get("inputs")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow::anyhow!("{name}: missing 'inputs'"))?
            .iter()
            .map(|t| -> Result<TensorInfo> {
                let shape = t
                    .get("shape")
                    .and_then(Value::as_array)
                    .ok_or_else(|| anyhow::anyhow!("{name}: input missing 'shape'"))?
                    .iter()
                    .map(|d| d.as_u64().map(|v| v as usize))
                    .collect::<Option<Vec<_>>>()
                    .ok_or_else(|| anyhow::anyhow!("{name}: non-integer dim"))?;
                let dtype =
                    t.get("dtype").and_then(Value::as_str).unwrap_or("f64").to_string();
                Ok(TensorInfo { shape, dtype })
            })
            .collect::<Result<Vec<_>>>()?;
        let n_outputs = v.get("n_outputs").and_then(Value::as_u64).unwrap_or(1) as usize;
        let params = v.get("params").cloned().unwrap_or(Value::Null);
        Ok(Self { name, file, inputs, n_outputs, params })
    }

    /// Grid shape of the first input `(nz, ny, nx)`.
    pub fn grid_shape(&self) -> Option<(usize, usize, usize)> {
        match self.inputs.first().map(|t| t.shape.as_slice()) {
            Some([nz, ny, nx]) => Some((*nz, *ny, *nx)),
            _ => None,
        }
    }

    /// A named numeric parameter recorded at lowering time.
    pub fn param_f64(&self, key: &str) -> Option<f64> {
        self.params.get(key).and_then(Value::as_f64)
    }

    /// A named integer parameter.
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key).and_then(Value::as_u64).map(|v| v as usize)
    }

    /// The scheme tag ("jacobi" / "gauss_seidel" / "residual").
    pub fn scheme(&self) -> Option<&str> {
        self.params.get("scheme").and_then(Value::as_str)
    }
}

/// The `artifacts/manifest.json` catalog.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dtype: String,
    pub artifacts: Vec<ArtifactInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let v = json::parse(text)?;
        let dtype = v.get("dtype").and_then(Value::as_str).unwrap_or("f64").to_string();
        let artifacts = v
            .get("artifacts")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?
            .iter()
            .map(ArtifactInfo::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dtype, artifacts, dir: dir.to_path_buf() })
    }

    /// Load the manifest from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    /// Default artifacts directory: `$STENCILWAVE_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("STENCILWAVE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_catalog_json() {
        let text = r#"{
            "dtype": "f64",
            "artifacts": [{
                "name": "jacobi_step_n16",
                "file": "jacobi_step_n16.hlo.txt",
                "inputs": [{"shape": [16,16,16], "dtype": "f64"},
                           {"shape": [16,16,16], "dtype": "f64"}],
                "n_outputs": 1,
                "params": {"h2": 1.0, "iters": 1, "scheme": "jacobi"}
            }]
        }"#;
        let m = Manifest::parse(text, Path::new("/tmp")).unwrap();
        assert_eq!(m.dtype, "f64");
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("jacobi_step_n16").unwrap();
        assert_eq!(a.grid_shape(), Some((16, 16, 16)));
        assert_eq!(a.param_f64("h2"), Some(1.0));
        assert_eq!(a.param_usize("iters"), Some(1));
        assert_eq!(a.scheme(), Some("jacobi"));
        assert_eq!(a.inputs.len(), 2);
        assert!(m.get("nope").is_none());
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/jacobi_step_n16.hlo.txt"));
    }

    #[test]
    fn missing_fields_are_errors() {
        assert!(Manifest::parse(r#"{"dtype": "f64"}"#, Path::new(".")).is_err());
        assert!(
            Manifest::parse(r#"{"artifacts": [{"file": "x"}]}"#, Path::new(".")).is_err()
        );
    }

    #[test]
    fn real_manifest_parses_when_built() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 7);
            assert!(m.get("jacobi_step_n16").is_some());
        }
    }
}
