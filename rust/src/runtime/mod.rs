//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`) lowers the L2 smoother
//! graphs — which call the L1 Pallas kernels — to HLO *text* once;
//! this module loads the text through the `xla` crate
//! (`HloModuleProto::from_text_file` → `XlaComputation` → PJRT compile)
//! and executes it on the CPU PJRT client. Python never runs at runtime.
//!
//! The runtime has two jobs in this system:
//! * **cross-layer validation**: the rust stencil engine and the Pallas
//!   kernels must agree to fp round-off on identical inputs
//!   ([`validate`], exercised by the `validate` CLI subcommand and the
//!   integration tests);
//! * **artifact execution** for the examples (e.g. the Poisson driver
//!   dispatches `jacobi_smooth_residual_*` once per outer iteration).

//! The artifact *manifest* layer is always available (it is plain JSON
//! parsing and is what the compile pipeline's tests exercise); the PJRT
//! *execution* engine needs the xla-rs bindings and is gated behind the
//! `xla` cargo feature so the default build stays offline.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod engine;

pub use artifacts::Manifest;
#[cfg(feature = "xla")]
pub use engine::{Runtime, Validation};
