//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`) lowers the L2 smoother
//! graphs — which call the L1 Pallas kernels — to HLO *text* once;
//! this module loads the text through the `xla` crate
//! (`HloModuleProto::from_text_file` → `XlaComputation` → PJRT compile)
//! and executes it on the CPU PJRT client. Python never runs at runtime.
//!
//! The runtime has two jobs in this system:
//! * **cross-layer validation**: the rust stencil engine and the Pallas
//!   kernels must agree to fp round-off on identical inputs
//!   ([`validate`], exercised by the `validate` CLI subcommand and the
//!   integration tests);
//! * **artifact execution** for the examples (e.g. the Poisson driver
//!   dispatches `jacobi_smooth_residual_*` once per outer iteration).

pub mod artifacts;
pub mod engine;

pub use artifacts::Manifest;
pub use engine::{Runtime, Validation};
