//! Wavefront temporal blocking for Gauss-Seidel (paper Sec. 4, Fig. 5b),
//! generic over the [`StencilOp`] kernel layer.
//!
//! The adaptation of the wavefront scheme to the in-place GS method: since
//! all updates operate on one array, no temporary planes are needed at
//! all. A pass runs `S` complete sweeps through the grid *simultaneously*:
//! sweep `s` (a worker group, itself pipeline-parallel over y as in
//! Fig. 5a) trails sweep `s-1` in z so that when it updates plane `k`,
//! planes `k+1 … k+R` already carry post-sweep-`s-1` values and planes
//! `k-1 … k-R` carry its own freshly written values — the exact
//! lexicographic semantics, `S` times, in one traversal of memory.
//!
//! Dependencies enforced by the shared progress table:
//! * pipeline (within sweep `s`): worker `p` starts plane `k` after worker
//!   `p-1` finishes plane `k`;
//! * wavefront (between sweeps): sweep `s` starts plane `k` after *all*
//!   workers of sweep `s-1` finish plane `k+R` (halo radius `R`), so
//!   sweep `s-1` both finished the halo planes sweep `s` reads *and*
//!   stopped reading the planes sweep `s` writes.
//!
//! The pass is a [`Schedule`] on the persistent
//! [`WorkerPool`](super::pool::WorkerPool) (`S × width` workers). Bit-identical to `S` serial sweeps — asserted
//! by tests for all shapes, group counts, pipeline widths and radii.

use std::marker::PhantomData;

use crate::stencil::gauss_seidel::GsKernel;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{op_gs_line_raw, op_gs_sweep, StencilOp};
use crate::Result;

use super::pipeline::chunk_lines_r;
use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};

/// Configuration of a GS wavefront pass.
#[derive(Clone, Copy, Debug)]
pub struct GsWavefrontConfig {
    /// Simultaneous sweeps `S` = temporal blocking factor = worker groups.
    pub sweeps: usize,
    /// Workers per group (pipeline width over y). With SMT the paper runs
    /// two logical threads per core here.
    pub threads_per_group: usize,
    pub kernel: GsKernel,
}

impl Default for GsWavefrontConfig {
    fn default() -> Self {
        Self { sweeps: 4, threads_per_group: 1, kernel: GsKernel::Interleaved }
    }
}

impl GsWavefrontConfig {
    /// Validate the configuration (single source for every entry point).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.sweeps >= 1, "need at least one sweep");
        anyhow::ensure!(self.threads_per_group >= 1, "need at least one thread per group");
        Ok(())
    }
}

/// One GS wavefront pass of `op` as a [`Schedule`].
///
/// Worker `id` is thread `id % width` of sweep `id / width`; progress
/// slot `s * width + p` holds the last plane completed by thread `p` of
/// sweep `s`.
pub struct GsWavefrontSchedule<'g, O: StencilOp> {
    op: &'g O,
    base: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    r: usize,
    sweeps: usize,
    width: usize,
    chunks: Vec<(usize, usize)>,
    kernel: GsKernel,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: plane/chunk exclusivity is enforced by the progress protocol
// (module docs); neighbor lines are only read in states the protocol
// freezes.
unsafe impl<O: StencilOp> Send for GsWavefrontSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for GsWavefrontSchedule<'_, O> {}

impl<'g, O: StencilOp> GsWavefrontSchedule<'g, O> {
    /// Build one pass of `cfg.sweeps` simultaneous sweeps over `u`.
    pub fn new(op: &'g O, u: &'g mut Grid3, cfg: &GsWavefrontConfig) -> Result<Self> {
        cfg.validate()?;
        let r = op.radius();
        anyhow::ensure!(
            r >= 1 && r <= crate::stencil::op::MAX_RADIUS,
            "unsupported halo radius {r}"
        );
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} wavefront pass"
        );
        Ok(Self {
            op,
            base: u.data_mut().as_mut_ptr(),
            nz,
            ny,
            nx,
            r,
            sweeps: cfg.sweeps,
            width: cfg.threads_per_group,
            chunks: chunk_lines_r(ny, cfg.threads_per_group, r),
            kernel: cfg.kernel,
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for GsWavefrontSchedule<'_, O> {
    fn workers(&self) -> usize {
        self.sweeps * self.width
    }

    fn worker(&self, id: usize, progress: &Progress) {
        let width = self.width;
        let r = self.r;
        let s = id / width;
        let p = id % width;
        let (j0, j1) = self.chunks[p];
        for k in r..self.nz - r {
            // wavefront dependency: previous sweep fully past plane k+R
            // (so k+1..k+R hold post-sweep-(s-1) values and nobody still
            // reads our plane k).
            if s > 0 {
                let need = (k + r).min(self.nz - 1 - r) as isize;
                for q in 0..width {
                    progress.wait_min((s - 1) * width + q, need);
                }
            }
            // pipeline dependency within the sweep.
            if p > 0 {
                progress.wait_min(s * width + p - 1, k as isize);
            }
            // SAFETY: plane/chunk exclusivity by the protocol above;
            // neighbor lines are only read in states the protocol
            // freezes (see module docs).
            unsafe {
                for j in j0..j1 {
                    op_gs_line_raw(self.op, self.base, self.ny, self.nx, k, j, self.kernel);
                }
            }
            progress.publish(s * width + p, k as isize);
        }
    }
}

/// Run `passes` wavefront passes of `op` on `pool` with one schedule.
pub fn wavefront_gs_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    cfg: &GsWavefrontConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    if cfg.sweeps == 1 && cfg.threads_per_group == 1 {
        for _ in 0..passes {
            op_gs_sweep(op, u, cfg.kernel);
        }
        return Ok(());
    }
    let schedule = GsWavefrontSchedule::new(op, u, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

/// `iters` sweeps of `op` via passes of `cfg.sweeps` each (+ a remainder
/// pass with fewer simultaneous sweeps), all on one team — the
/// pool-level entry point the [`SchemeRunner`] registry, tests and
/// benches drive.
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn wavefront_gs_iters_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    cfg: &GsWavefrontConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    wavefront_gs_passes(pool, op, u, cfg, iters / cfg.sweeps)?;
    let rest = iters % cfg.sweeps;
    if rest > 0 {
        let tail = GsWavefrontConfig { sweeps: rest, ..*cfg };
        wavefront_gs_passes(pool, op, u, &tail, 1)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::stencil::gauss_seidel::gs_sweeps;
    use crate::stencil::op::{op_gs_sweeps, ConstLaplace7, Laplace13};

    fn run_gs_wf<O: StencilOp>(op: &O, u: &mut Grid3, cfg: &GsWavefrontConfig) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        wavefront_gs_passes(&mut pool, op, u, cfg, 1)
    }

    fn check(nz: usize, ny: usize, nx: usize, sweeps: usize, width: usize) {
        let mut u = Grid3::random(nz, ny, nx, 123);
        let mut want = u.clone();
        gs_sweeps(&mut want, sweeps, GsKernel::Interleaved);
        let cfg =
            GsWavefrontConfig { sweeps, threads_per_group: width, kernel: GsKernel::Interleaved };
        run_gs_wf(&ConstLaplace7, &mut u, &cfg).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} S={sweeps} width={width}");
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, sweeps: usize, width: usize) {
        let mut u = Grid3::random(nz, ny, nx, 321);
        let mut want = u.clone();
        op_gs_sweeps(&Laplace13, &mut want, sweeps, GsKernel::Interleaved);
        let cfg =
            GsWavefrontConfig { sweeps, threads_per_group: width, kernel: GsKernel::Interleaved };
        run_gs_wf(&Laplace13, &mut u, &cfg).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "radius-2 {nz}x{ny}x{nx} S={sweeps} width={width}"
        );
    }

    #[test]
    fn single_sweep_single_thread_is_serial() {
        check(8, 8, 8, 1, 1);
    }

    #[test]
    fn pure_temporal_wavefront() {
        // groups of one worker each — the Fig. 5b shifts in isolation
        for s in [2, 3, 4, 6] {
            check(14, 9, 8, s, 1);
        }
    }

    #[test]
    fn pipelined_groups() {
        // sweeps × pipeline width — the full Fig. 5b composition
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 8, 2, 4);
        check(8, 10, 8, 3, 3);
    }

    #[test]
    fn radius2_wavefront_matches_serial() {
        check_r2(12, 10, 9, 2, 1);
        check_r2(12, 10, 9, 3, 1);
        check_r2(10, 14, 9, 2, 2);
        check_r2(11, 12, 9, 4, 2);
        // pipeline longer than the z extent, radius 2
        check_r2(6, 8, 7, 5, 1);
        check_r2(5, 7, 7, 3, 2);
    }

    #[test]
    fn smt_like_oversubscription() {
        // more logical workers than this box has cores: 8 × 2 = 16
        check(9, 18, 8, 8, 2);
    }

    #[test]
    fn more_sweeps_than_planes() {
        // pathological: pipeline longer than the z extent
        check(4, 6, 6, 6, 1);
        check(3, 5, 5, 4, 2);
    }

    #[test]
    fn iters_with_remainder() {
        let mut u = Grid3::random(9, 9, 9, 7);
        let mut want = u.clone();
        gs_sweeps(&mut want, 7, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps: 3, threads_per_group: 2, kernel: GsKernel::Interleaved };
        let mut pool = WorkerPool::new(0);
        wavefront_gs_iters_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 7).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn iters_on_private_pool() {
        let mut u = Grid3::random(10, 11, 8, 77);
        let mut want = u.clone();
        gs_sweeps(&mut want, 8, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps: 4, threads_per_group: 2, kernel: GsKernel::Interleaved };
        let mut pool = WorkerPool::new(8);
        wavefront_gs_iters_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 8).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
