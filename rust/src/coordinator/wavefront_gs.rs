//! Wavefront temporal blocking for Gauss-Seidel (paper Sec. 4, Fig. 5b).
//!
//! The adaptation of the wavefront scheme to the in-place GS method: since
//! all updates operate on one array, no temporary planes are needed at
//! all. A pass runs `S` complete sweeps through the grid *simultaneously*:
//! sweep `s` (a thread group, itself pipeline-parallel over y as in
//! Fig. 5a) trails sweep `s-1` in z so that when it updates plane `k`,
//! plane `k+1` already carries post-sweep-`s-1` values and plane `k-1`
//! carries its own freshly written values — the exact lexicographic
//! semantics, `S` times, in one traversal of memory.
//!
//! Dependencies enforced by the progress protocol:
//! * pipeline (within sweep `s`): thread `p` starts plane `k` after thread
//!   `p-1` finishes plane `k`;
//! * wavefront (between sweeps): sweep `s` starts plane `k` after *all*
//!   threads of sweep `s-1` finish plane `k+1`.
//!
//! Bit-identical to `S` serial sweeps — asserted by tests for all shapes,
//! group counts and pipeline widths.

use std::sync::atomic::{AtomicIsize, Ordering};

use crate::stencil::gauss_seidel::{gs_plane_line_raw, gs_sweep, GsKernel};
use crate::stencil::grid::Grid3;
use crate::Result;

use super::pipeline::chunk_lines;

/// Configuration of a GS wavefront pass.
#[derive(Clone, Copy, Debug)]
pub struct GsWavefrontConfig {
    /// Simultaneous sweeps `S` = temporal blocking factor = thread groups.
    pub sweeps: usize,
    /// Threads per group (pipeline width over y). With SMT the paper runs
    /// two logical threads per core here.
    pub threads_per_group: usize,
    pub kernel: GsKernel,
}

impl Default for GsWavefrontConfig {
    fn default() -> Self {
        Self { sweeps: 4, threads_per_group: 1, kernel: GsKernel::Interleaved }
    }
}

#[derive(Clone, Copy)]
struct SharedPtr(*mut f64);
unsafe impl Send for SharedPtr {}
unsafe impl Sync for SharedPtr {}

impl SharedPtr {
    /// Accessor (method, not field) so closures capture the whole wrapper
    /// — RFC 2229 disjoint capture would otherwise capture the bare
    /// pointer, which is not `Send`.
    #[inline(always)]
    fn get(self) -> *mut f64 {
        self.0
    }
}

/// Run `cfg.sweeps` lexicographic GS sweeps in one wavefront pass.
pub fn wavefront_gs(u: &mut Grid3, cfg: &GsWavefrontConfig) -> Result<()> {
    let s_count = cfg.sweeps;
    let width = cfg.threads_per_group;
    anyhow::ensure!(s_count >= 1, "need at least one sweep");
    anyhow::ensure!(width >= 1, "need at least one thread per group");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 {
        return Ok(());
    }
    if s_count == 1 && width == 1 {
        gs_sweep(u, cfg.kernel);
        return Ok(());
    }

    let chunks = chunk_lines(ny, width);
    // progress[s * width + p] = last plane completed by thread p of sweep s
    let progress: Vec<AtomicIsize> =
        (0..s_count * width).map(|_| AtomicIsize::new(0)).collect();
    let base = SharedPtr(u.data_mut().as_mut_ptr());
    let kernel = cfg.kernel;

    std::thread::scope(|scope| {
        for s in 0..s_count {
            for (p, &(j0, j1)) in chunks.iter().enumerate() {
                let progress = &progress;
                let ptr = base;
                scope.spawn(move || {
                    for k in 1..nz - 1 {
                        // wavefront dependency: previous sweep fully past
                        // plane k+1 (so k+1 holds post-sweep-(s-1) values
                        // and nobody still reads our plane k).
                        if s > 0 {
                            let need = (k + 1).min(nz - 2) as isize;
                            for q in 0..width {
                                super::barrier::spin_wait(|| {
                                    progress[(s - 1) * width + q].load(Ordering::Acquire) >= need
                                });
                            }
                        }
                        // pipeline dependency within the sweep.
                        if p > 0 {
                            super::barrier::spin_wait(|| {
                                progress[s * width + p - 1].load(Ordering::Acquire) >= k as isize
                            });
                        }
                        // SAFETY: plane/chunk exclusivity by the protocol
                        // above; neighbor lines are only read in states the
                        // protocol freezes (see module docs).
                        unsafe {
                            for j in j0..j1 {
                                gs_plane_line_raw(ptr.get(), ny, nx, k, j, kernel);
                            }
                        }
                        progress[s * width + p].store(k as isize, Ordering::Release);
                    }
                });
            }
        }
    });
    Ok(())
}

/// `iters` sweeps via passes of `cfg.sweeps` each (+ a remainder pass).
pub fn wavefront_gs_iters(u: &mut Grid3, cfg: &GsWavefrontConfig, iters: usize) -> Result<()> {
    let full = iters / cfg.sweeps;
    for _ in 0..full {
        wavefront_gs(u, cfg)?;
    }
    let rest = iters % cfg.sweeps;
    if rest > 0 {
        let tail = GsWavefrontConfig { sweeps: rest, ..*cfg };
        wavefront_gs(u, &tail)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gauss_seidel::gs_sweeps;

    fn check(nz: usize, ny: usize, nx: usize, sweeps: usize, width: usize) {
        let mut u = Grid3::random(nz, ny, nx, 123);
        let mut want = u.clone();
        gs_sweeps(&mut want, sweeps, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps, threads_per_group: width, kernel: GsKernel::Interleaved };
        wavefront_gs(&mut u, &cfg).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "{nz}x{ny}x{nx} S={sweeps} width={width}"
        );
    }

    #[test]
    fn single_sweep_single_thread_is_serial() {
        check(8, 8, 8, 1, 1);
    }

    #[test]
    fn pure_temporal_wavefront() {
        // groups of one thread each — the Fig. 5b shifts in isolation
        for s in [2, 3, 4, 6] {
            check(14, 9, 8, s, 1);
        }
    }

    #[test]
    fn pipelined_groups() {
        // sweeps × pipeline width — the full Fig. 5b composition
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 8, 2, 4);
        check(8, 10, 8, 3, 3);
    }

    #[test]
    fn smt_like_oversubscription() {
        // more logical threads than this box has cores: 8 × 2 = 16 threads
        check(9, 18, 8, 8, 2);
    }

    #[test]
    fn more_sweeps_than_planes() {
        // pathological: pipeline longer than the z extent
        check(4, 6, 6, 6, 1);
        check(3, 5, 5, 4, 2);
    }

    #[test]
    fn iters_with_remainder() {
        let mut u = Grid3::random(9, 9, 9, 7);
        let mut want = u.clone();
        gs_sweeps(&mut want, 7, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps: 3, threads_per_group: 2, kernel: GsKernel::Interleaved };
        wavefront_gs_iters(&mut u, &cfg, 7).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
