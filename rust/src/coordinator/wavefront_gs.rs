//! Wavefront temporal blocking for Gauss-Seidel (paper Sec. 4, Fig. 5b).
//!
//! The adaptation of the wavefront scheme to the in-place GS method: since
//! all updates operate on one array, no temporary planes are needed at
//! all. A pass runs `S` complete sweeps through the grid *simultaneously*:
//! sweep `s` (a worker group, itself pipeline-parallel over y as in
//! Fig. 5a) trails sweep `s-1` in z so that when it updates plane `k`,
//! plane `k+1` already carries post-sweep-`s-1` values and plane `k-1`
//! carries its own freshly written values — the exact lexicographic
//! semantics, `S` times, in one traversal of memory.
//!
//! Dependencies enforced by the shared progress table:
//! * pipeline (within sweep `s`): worker `p` starts plane `k` after worker
//!   `p-1` finishes plane `k`;
//! * wavefront (between sweeps): sweep `s` starts plane `k` after *all*
//!   workers of sweep `s-1` finish plane `k+1`.
//!
//! The pass is a [`Schedule`] on the persistent [`WorkerPool`]
//! (`S × width` workers); `wavefront_gs_iters` reuses one team across all
//! passes. Bit-identical to `S` serial sweeps — asserted by tests for all
//! shapes, group counts and pipeline widths.

use std::marker::PhantomData;

use crate::stencil::gauss_seidel::{gs_plane_line_raw, gs_sweep, GsKernel};
use crate::stencil::grid::Grid3;
use crate::Result;

use super::pipeline::chunk_lines;
use super::pool::{self, WorkerPool};
use super::schedule::{Progress, Schedule};

/// Configuration of a GS wavefront pass.
#[derive(Clone, Copy, Debug)]
pub struct GsWavefrontConfig {
    /// Simultaneous sweeps `S` = temporal blocking factor = worker groups.
    pub sweeps: usize,
    /// Workers per group (pipeline width over y). With SMT the paper runs
    /// two logical threads per core here.
    pub threads_per_group: usize,
    pub kernel: GsKernel,
}

impl Default for GsWavefrontConfig {
    fn default() -> Self {
        Self { sweeps: 4, threads_per_group: 1, kernel: GsKernel::Interleaved }
    }
}

impl GsWavefrontConfig {
    /// Validate the configuration (single source for every entry point).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.sweeps >= 1, "need at least one sweep");
        anyhow::ensure!(self.threads_per_group >= 1, "need at least one thread per group");
        Ok(())
    }
}

/// One GS wavefront pass as a [`Schedule`].
///
/// Worker `id` is thread `id % width` of sweep `id / width`; progress
/// slot `s * width + p` holds the last plane completed by thread `p` of
/// sweep `s`.
pub struct GsWavefrontSchedule<'g> {
    base: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    sweeps: usize,
    width: usize,
    chunks: Vec<(usize, usize)>,
    kernel: GsKernel,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: plane/chunk exclusivity is enforced by the progress protocol
// (module docs); neighbor lines are only read in states the protocol
// freezes.
unsafe impl Send for GsWavefrontSchedule<'_> {}
unsafe impl Sync for GsWavefrontSchedule<'_> {}

impl<'g> GsWavefrontSchedule<'g> {
    /// Build one pass of `cfg.sweeps` simultaneous sweeps over `u`.
    pub fn new(u: &'g mut Grid3, cfg: &GsWavefrontConfig) -> Result<Self> {
        cfg.validate()?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(nz >= 3 && ny >= 3 && nx >= 3, "grid too small for a wavefront pass");
        Ok(Self {
            base: u.data_mut().as_mut_ptr(),
            nz,
            ny,
            nx,
            sweeps: cfg.sweeps,
            width: cfg.threads_per_group,
            chunks: chunk_lines(ny, cfg.threads_per_group),
            kernel: cfg.kernel,
            _borrow: PhantomData,
        })
    }
}

impl Schedule for GsWavefrontSchedule<'_> {
    fn workers(&self) -> usize {
        self.sweeps * self.width
    }

    fn worker(&self, id: usize, progress: &Progress) {
        let width = self.width;
        let s = id / width;
        let p = id % width;
        let (j0, j1) = self.chunks[p];
        for k in 1..self.nz - 1 {
            // wavefront dependency: previous sweep fully past plane k+1
            // (so k+1 holds post-sweep-(s-1) values and nobody still
            // reads our plane k).
            if s > 0 {
                let need = (k + 1).min(self.nz - 2) as isize;
                for q in 0..width {
                    progress.wait_min((s - 1) * width + q, need);
                }
            }
            // pipeline dependency within the sweep.
            if p > 0 {
                progress.wait_min(s * width + p - 1, k as isize);
            }
            // SAFETY: plane/chunk exclusivity by the protocol above;
            // neighbor lines are only read in states the protocol
            // freezes (see module docs).
            unsafe {
                for j in j0..j1 {
                    gs_plane_line_raw(self.base, self.ny, self.nx, k, j, self.kernel);
                }
            }
            progress.publish(s * width + p, k as isize);
        }
    }
}

/// Run `passes` wavefront passes on `pool` with one schedule.
pub(crate) fn wavefront_gs_passes(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    cfg: &GsWavefrontConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 || passes == 0 {
        return Ok(());
    }
    if cfg.sweeps == 1 && cfg.threads_per_group == 1 {
        for _ in 0..passes {
            gs_sweep(u, cfg.kernel);
        }
        return Ok(());
    }
    let schedule = GsWavefrontSchedule::new(u, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

/// `iters` sweeps via passes of `cfg.sweeps` each (+ a remainder pass
/// with fewer simultaneous sweeps), all on one team.
pub(crate) fn wavefront_gs_iters_passes(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    cfg: &GsWavefrontConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    wavefront_gs_passes(pool, u, cfg, iters / cfg.sweeps)?;
    let rest = iters % cfg.sweeps;
    if rest > 0 {
        let tail = GsWavefrontConfig { sweeps: rest, ..*cfg };
        wavefront_gs_passes(pool, u, &tail, 1)?;
    }
    Ok(())
}

/// Run `cfg.sweeps` lexicographic GS sweeps in one wavefront pass.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_gs(u: &mut Grid3, cfg: &GsWavefrontConfig) -> Result<()> {
    pool::with_local(|p| wavefront_gs_passes(p, u, cfg, 1))
}

/// [`wavefront_gs`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_gs_on(pool: &mut WorkerPool, u: &mut Grid3, cfg: &GsWavefrontConfig) -> Result<()> {
    wavefront_gs_passes(pool, u, cfg, 1)
}

/// `iters` sweeps via passes of `cfg.sweeps` each (+ a remainder pass),
/// all on one persistent team.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_gs_iters(u: &mut Grid3, cfg: &GsWavefrontConfig, iters: usize) -> Result<()> {
    pool::with_local(|p| wavefront_gs_iters_passes(p, u, cfg, iters))
}

/// [`wavefront_gs_iters`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_gs_iters_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    cfg: &GsWavefrontConfig,
    iters: usize,
) -> Result<()> {
    wavefront_gs_iters_passes(pool, u, cfg, iters)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim matrix stays covered until removal

    use super::*;
    use crate::stencil::gauss_seidel::gs_sweeps;

    fn check(nz: usize, ny: usize, nx: usize, sweeps: usize, width: usize) {
        let mut u = Grid3::random(nz, ny, nx, 123);
        let mut want = u.clone();
        gs_sweeps(&mut want, sweeps, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps, threads_per_group: width, kernel: GsKernel::Interleaved };
        wavefront_gs(&mut u, &cfg).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "{nz}x{ny}x{nx} S={sweeps} width={width}"
        );
    }

    #[test]
    fn single_sweep_single_thread_is_serial() {
        check(8, 8, 8, 1, 1);
    }

    #[test]
    fn pure_temporal_wavefront() {
        // groups of one worker each — the Fig. 5b shifts in isolation
        for s in [2, 3, 4, 6] {
            check(14, 9, 8, s, 1);
        }
    }

    #[test]
    fn pipelined_groups() {
        // sweeps × pipeline width — the full Fig. 5b composition
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 8, 2, 4);
        check(8, 10, 8, 3, 3);
    }

    #[test]
    fn smt_like_oversubscription() {
        // more logical workers than this box has cores: 8 × 2 = 16
        check(9, 18, 8, 8, 2);
    }

    #[test]
    fn more_sweeps_than_planes() {
        // pathological: pipeline longer than the z extent
        check(4, 6, 6, 6, 1);
        check(3, 5, 5, 4, 2);
    }

    #[test]
    fn iters_with_remainder() {
        let mut u = Grid3::random(9, 9, 9, 7);
        let mut want = u.clone();
        gs_sweeps(&mut want, 7, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps: 3, threads_per_group: 2, kernel: GsKernel::Interleaved };
        wavefront_gs_iters(&mut u, &cfg, 7).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn iters_on_private_pool() {
        let mut u = Grid3::random(10, 11, 8, 77);
        let mut want = u.clone();
        gs_sweeps(&mut want, 8, GsKernel::Interleaved);
        let cfg = GsWavefrontConfig { sweeps: 4, threads_per_group: 2, kernel: GsKernel::Interleaved };
        let mut pool = WorkerPool::new(8);
        wavefront_gs_iters_on(&mut pool, &mut u, &cfg, 8).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
