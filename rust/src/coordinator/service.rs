//! The multi-tenant `SolverService`: one persistent pool, many
//! concurrent jobs.
//!
//! A single experiment owns its [`Solver`] session; a *service* amortizes
//! one worker team across tenants. Jobs (mixed
//! [`Scheme`](crate::config::Scheme) × [`OpKind`](crate::stencil::op::OpKind)
//! × sizes) are:
//!
//! 1. **Admitted** by an ECM-cost placement model: a job's team is
//!    rounded up to whole *cache groups* (windows of `group_width` pool
//!    workers — the machine-topology unit of Sec. 5, where a shared
//!    outer-level cache makes intra-group synchronization cheap), its
//!    cost is estimated in modeled seconds from the scheme runner's
//!    performance-model leg, and the window with the lowest peak load is
//!    charged (ties go to the lowest group, so placement is
//!    deterministic — see [`ServiceConfig::admit_plan`]).
//! 2. **Executed** on a pre-created [`PoolSegment`] for that window: each
//!    window has its own progress table and scratch arena, so tenants on
//!    disjoint windows run truly concurrently on the one pool and the
//!    steady state allocates nothing.
//! 3. **Batched** when small: queued jobs with an identical configuration
//!    (modulo `machine`/`pin`, which affect placement and prediction but
//!    not numerics) and at most [`ServiceConfig::batch_cells`] grid cells
//!    ride one claimed window through a single session — one schedule,
//!    many right-hand sides, via [`Solver::run_with`].
//!
//! Admission is **bounded and deadline/priority aware**: the queue
//! holds at most [`ServiceConfig::queue_capacity`] jobs (overflow is
//! rejected with a typed [`AdmissionError::QueueFull`] whose
//! `retry_after_hint` is the ECM-predicted drain time of the least
//! loaded eligible window), each job carries a
//! [`priority`](crate::config::RunConfig::priority) level and an
//! optional [`deadline_ms`](crate::config::RunConfig::deadline_ms)
//! (never-started jobs past their deadline are shed with a typed
//! [`ExpiredError`] instead of running late), and a starving job —
//! e.g. a whole-machine-wide tenant behind a stream of narrow ones —
//! is *aged* after [`ServiceConfig::age_after`] passed-over claim
//! cycles: an aged job reserves its window so younger claims cannot
//! leapfrog it, which bounds every job's wait (property-tested in
//! `tests/service_property.rs`).
//!
//! Every job's result is bit-identical to a private per-job [`Solver`]
//! run of the same configuration — tenancy changes scheduling, never
//! numerics (locked down by `tests/service_stress.rs` and
//! `tests/service_property.rs`).
//!
//! ```no_run
//! use stencilwave::config::RunConfig;
//! use stencilwave::coordinator::service::{JobSpec, ServiceConfig, SolverService};
//! use stencilwave::stencil::grid::Grid3;
//!
//! let mut svc = SolverService::new(ServiceConfig::for_host()).unwrap();
//! let cfg = RunConfig { size: (64, 64, 64), t: 4, iters: 8, ..Default::default() };
//! let u0 = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! let out = svc.run_job(JobSpec::new(cfg, u0)).unwrap();
//! println!("ran on groups {}..{}", out.placement.group_start,
//!          out.placement.group_start + out.placement.group_count);
//! svc.shutdown();
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{RunConfig, PRIORITY_LEVELS};
use crate::simulator::machine::MachineSpec;
use crate::stencil::grid::Grid3;
use crate::Result;

use super::affinity::{pin_hook, PinPolicy, Topology};
use super::pool::{PoolSegment, WorkerPool};
use super::runner::runner_for;
use super::solver::Solver;

/// Static shape of a [`SolverService`]: how many cache groups the pool
/// is carved into and how jobs are admitted onto them.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Cache groups the pool is carved into (also the executor thread
    /// count: each claimed window is driven by one executor).
    pub groups: usize,
    /// Pool workers per cache group — the placement granularity. Jobs
    /// are rounded up to whole groups so no two tenants share a group's
    /// outer-level cache.
    pub group_width: usize,
    /// Tab. 1 machine model the admission cost is predicted on (`None`
    /// = a worker-count proxy; a job's own `machine` key wins).
    pub machine: Option<String>,
    /// Most jobs one claimed window executes as a single batch
    /// (1 disables batching).
    pub max_batch: usize,
    /// Largest grid (in cells) eligible for batching — small grids gain
    /// the most from amortizing one schedule over many right-hand sides.
    pub batch_cells: usize,
    /// Core-pinning policy for the pool's workers (applied once, at
    /// spawn; per-job `pin` keys are ignored — placement is the
    /// service's decision).
    pub pin: PinPolicy,
    /// Most jobs the service queues at once (admitted-but-unstarted,
    /// across every priority level). Submissions beyond this are
    /// rejected with [`AdmissionError::QueueFull`] carrying an
    /// ECM-predicted `retry_after_hint` — backpressure instead of an
    /// unbounded queue.
    pub queue_capacity: usize,
    /// Claim cycles a queued job may be passed over (its window busy
    /// while a younger or lower-priority job is claimed) before it is
    /// *aged*: an aged job is scanned first and reserves its window, so
    /// its wait is bounded by the in-flight batches holding that window.
    pub age_after: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            groups: 2,
            group_width: 4,
            machine: None,
            max_batch: 8,
            batch_cells: 32 * 32 * 32,
            pin: PinPolicy::None,
            queue_capacity: 64,
            age_after: 16,
        }
    }
}

impl ServiceConfig {
    /// A service shaped like the host: one cache group per sysfs
    /// outer-level cache domain, `group_width` = cores per domain.
    pub fn for_host() -> Self {
        let topo = Topology::host();
        let group_width = topo.group_size.max(1);
        let groups = (topo.cores / group_width).max(1);
        Self { groups, group_width, ..Self::default() }
    }

    /// Validate the service shape.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.groups >= 1, "service needs at least one cache group");
        anyhow::ensure!(self.group_width >= 1, "cache groups need at least one worker");
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1 (1 disables batching)");
        anyhow::ensure!(self.queue_capacity >= 1, "queue_capacity must be >= 1");
        anyhow::ensure!(self.age_after >= 1, "age_after must be >= 1 claim cycle");
        if let Some(name) = &self.machine {
            anyhow::ensure!(MachineSpec::by_name(name).is_some(), "unknown machine '{name}'");
        }
        Ok(())
    }

    /// The pure admission/placement model: the [`Placement`] sequence a
    /// fresh, idle service would charge for `jobs` submitted in order
    /// with no completions in between. Deterministic — same jobs, same
    /// plan — and exactly the helper [`SolverService::submit`] runs, so
    /// the property suite can pin the service's placement behavior
    /// without spawning a single thread.
    pub fn admit_plan(&self, jobs: &[RunConfig]) -> Result<Vec<Placement>> {
        self.validate()?;
        let mut loads = vec![0.0f64; self.groups];
        let mut out = Vec::with_capacity(jobs.len());
        for job in jobs {
            let (placement, cost) = admit(self, job, &loads)?;
            let w = placement.group_start..placement.group_start + placement.group_count;
            for l in &mut loads[w] {
                *l += cost;
            }
            out.push(placement);
        }
        Ok(out)
    }
}

/// Where a job was charged: a contiguous window of cache groups and the
/// pool-worker window it maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// First cache group of the window.
    pub group_start: usize,
    /// Cache groups in the window (`ceil(team / group_width)`).
    pub group_count: usize,
    /// First pool worker id of the window.
    pub worker_start: usize,
    /// Pool workers the window holds (`group_count * group_width`).
    pub workers: usize,
}

/// Typed admission failure. Callers branch on it by downcasting the
/// [`anyhow::Error`], like [`BlockWidthError`](crate::config::BlockWidthError).
///
/// `TooWide` is permanent — the job can never run on this service
/// shape. `QueueFull` is transient backpressure: the queue is at
/// [`ServiceConfig::queue_capacity`] and the caller should retry after
/// roughly `retry_after_hint` seconds, the ECM-predicted time for the
/// least loaded window this job fits on to drain its outstanding
/// modeled work. A rejected submission changes no service state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionError {
    /// The job's team needs more cache groups than the service holds.
    TooWide {
        /// Workers the job's scheme dispatches.
        team: usize,
        /// Cache groups that team occupies after rounding up.
        needed_groups: usize,
        /// Cache groups the service holds.
        groups: usize,
    },
    /// The queue is at capacity; retry after the hinted drain time.
    QueueFull {
        /// Jobs queued when the submission was rejected.
        queued: usize,
        /// The configured [`ServiceConfig::queue_capacity`].
        capacity: usize,
        /// ECM-predicted seconds until the least loaded eligible window
        /// drains its outstanding modeled work — always finite and > 0.
        retry_after_hint: f64,
    },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::TooWide { team, needed_groups, groups } => write!(
                f,
                "job needs {team} workers = {needed_groups} cache groups but the service holds {groups}"
            ),
            AdmissionError::QueueFull { queued, capacity, retry_after_hint } => write!(
                f,
                "service queue is full ({queued}/{capacity} jobs); retry in ~{retry_after_hint:.3}s"
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Typed result for a job shed by deadline expiry: it was never started
/// within its [`deadline_ms`](crate::config::RunConfig::deadline_ms),
/// so the service refunded its load and dropped it instead of running
/// it late. Delivered through [`JobTicket::wait`]; downcast to branch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpiredError {
    /// Submission-order id of the shed job.
    pub id: u64,
    /// The deadline the job carried.
    pub deadline_ms: u64,
    /// Milliseconds the job actually waited before being shed.
    pub waited_ms: u64,
}

impl std::fmt::Display for ExpiredError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {} expired: not started within its {} ms deadline (waited {} ms)",
            self.id, self.deadline_ms, self.waited_ms
        )
    }
}

impl std::error::Error for ExpiredError {}

/// One tenant job: a validated [`RunConfig`] plus the tenant's grids.
pub struct JobSpec {
    /// The run to execute (`ranks` must be 1 — the service is a
    /// single-node tenancy layer; rank decomposition lives above it).
    pub cfg: RunConfig,
    /// Initial grid, consumed and returned updated in [`JobOutput::u`].
    pub u0: Grid3,
    /// Right-hand side for the Jacobi family (`None` = homogeneous).
    pub f: Option<Grid3>,
    /// Mesh factor paired with `f`.
    pub h2: f64,
}

impl JobSpec {
    /// A job with the homogeneous right-hand side (`f = 0`, `h2 = 1`).
    pub fn new(cfg: RunConfig, u0: Grid3) -> Self {
        Self { cfg, u0, f: None, h2: 1.0 }
    }

    /// Attach a right-hand side (builder-style).
    pub fn rhs(mut self, f: Grid3, h2: f64) -> Self {
        self.f = Some(f);
        self.h2 = h2;
        self
    }
}

/// A finished job: the updated grid plus where and how it actually ran.
pub struct JobOutput {
    /// The tenant's grid after `cfg.iters` updates.
    pub u: Grid3,
    /// The window the job *executed* on (a batched job runs on the batch
    /// leader's window, which may differ from the window its ticket was
    /// charged at).
    pub placement: Placement,
    /// Jobs that shared the claimed window with this one (1 = unbatched).
    pub batch_size: usize,
    /// The priority level the job was queued at.
    pub priority: usize,
    /// Milliseconds between submission and the claim that started it.
    pub wait_ms: f64,
    /// Claim cycles that passed this job over (claimed some other job
    /// while this one's window was busy) before it started — the
    /// quantity the aging rule bounds.
    pub skipped_cycles: u64,
}

/// Handle to a submitted job; redeem with [`JobTicket::wait`].
pub struct JobTicket {
    id: u64,
    placement: Placement,
    rx: mpsc::Receiver<Result<JobOutput>>,
}

impl JobTicket {
    /// Submission-order id of the job.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The window the admission model charged for this job.
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Block until the job finishes and return its output.
    pub fn wait(self) -> Result<JobOutput> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("solver service dropped job {} without a result", self.id))?
    }
}

/// Service counters (a consistent snapshot via [`SolverService::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Jobs accepted by admission.
    pub submitted: u64,
    /// Jobs that finished successfully.
    pub completed: u64,
    /// Jobs that finished with an error.
    pub failed: u64,
    /// Claimed windows that executed more than one job.
    pub batches: u64,
    /// Jobs that rode a shared window (counted per job).
    pub batched_jobs: u64,
    /// Most cache groups ever busy at once (`<= groups`).
    pub peak_groups_busy: usize,
    /// Claims that found a window group already busy or its segment
    /// checked out — 0 unless the oversubscription invariant broke (the
    /// property suite asserts it stays 0).
    pub claim_conflicts: u64,
    /// Never-started jobs shed past their deadline (typed
    /// [`ExpiredError`] results).
    pub shed_expired: u64,
    /// Submissions rejected with [`AdmissionError::QueueFull`].
    pub rejected_full: u64,
    /// Most jobs ever queued at once (`<= queue_capacity`).
    pub max_queue_depth: usize,
    /// Jobs promoted to the aged list after
    /// [`ServiceConfig::age_after`] passed-over claim cycles.
    pub aged_jobs: u64,
    /// Started-job wait histogram per priority level:
    /// `wait_hist[priority][bucket]` with bucket bounds
    /// [`WAIT_BUCKET_BOUNDS_MS`] (the last bucket is unbounded).
    pub wait_hist: [[u64; WAIT_BUCKETS]; PRIORITY_LEVELS],
}

/// Upper bounds (milliseconds) of the wait-histogram buckets; a fifth,
/// unbounded bucket catches everything beyond the last bound.
pub const WAIT_BUCKET_BOUNDS_MS: [f64; 4] = [1.0, 10.0, 100.0, 1000.0];

/// Buckets per priority level in [`ServiceStats::wait_hist`].
pub const WAIT_BUCKETS: usize = WAIT_BUCKET_BOUNDS_MS.len() + 1;

/// The `wait_hist` bucket a wait of `ms` milliseconds falls into.
pub fn wait_bucket(ms: f64) -> usize {
    WAIT_BUCKET_BOUNDS_MS.iter().position(|&b| ms < b).unwrap_or(WAIT_BUCKETS - 1)
}

/// One queued job.
struct Pending {
    id: u64,
    spec: JobSpec,
    /// The window admission charged (loads are refunded here).
    placement: Placement,
    cost: f64,
    /// Numerics-relevant config key batch mates must share.
    key: String,
    batchable: bool,
    priority: usize,
    deadline_ms: Option<u64>,
    submitted: Instant,
    /// Claim cycles that passed this job over while it headed its ready
    /// list (its window busy, some other job claimed).
    skipped: u64,
    /// Milliseconds waited, filled in at claim time under the lock.
    wait_ms: f64,
    tx: mpsc::Sender<Result<JobOutput>>,
}

impl Pending {
    fn expired(&self, now: Instant) -> bool {
        self.deadline_ms.is_some_and(|d| {
            now.saturating_duration_since(self.submitted) >= Duration::from_millis(d)
        })
    }

    /// Time left until this job's deadline (`None` = no deadline).
    fn remaining(&self, now: Instant) -> Option<Duration> {
        self.deadline_ms.map(|d| {
            Duration::from_millis(d).saturating_sub(now.saturating_duration_since(self.submitted))
        })
    }
}

/// The per-priority ready lists, keyed by window availability: within a
/// level, jobs are bucketed by the `(group_start, group_count)` window
/// admission charged them to, each bucket FIFO. A claim therefore costs
/// O(windows) = O(groups²) bucket-front inspections instead of a linear
/// rescan of the whole queue.
#[derive(Default)]
struct ReadyLists {
    levels: Vec<HashMap<(usize, usize), VecDeque<Pending>>>,
    /// Jobs promoted after `age_after` passed-over cycles, FIFO. Aged
    /// jobs are scanned before every level and *reserve* their window
    /// when blocked, so younger claims cannot leapfrog them.
    aged: VecDeque<Pending>,
    /// Total queued jobs across every level and the aged list.
    queued: usize,
}

impl ReadyLists {
    fn new() -> Self {
        Self { levels: (0..PRIORITY_LEVELS).map(|_| HashMap::new()).collect(), ..Self::default() }
    }

    fn push(&mut self, p: Pending) {
        let key = (p.placement.group_start, p.placement.group_count);
        self.levels[p.priority].entry(key).or_default().push_back(p);
        self.queued += 1;
    }

    fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Drain every job matching `pred` (expiry sweeps), preserving
    /// bucket order for the rest.
    fn drain_matching(&mut self, mut pred: impl FnMut(&Pending) -> bool) -> Vec<Pending> {
        let mut out = Vec::new();
        for level in &mut self.levels {
            for q in level.values_mut() {
                let mut keep = VecDeque::with_capacity(q.len());
                for p in q.drain(..) {
                    if pred(&p) {
                        out.push(p);
                    } else {
                        keep.push_back(p);
                    }
                }
                *q = keep;
            }
            level.retain(|_, q| !q.is_empty());
        }
        let mut keep = VecDeque::with_capacity(self.aged.len());
        for p in self.aged.drain(..) {
            if pred(&p) {
                out.push(p);
            } else {
                keep.push_back(p);
            }
        }
        self.aged = keep;
        self.queued -= out.len();
        out
    }

    /// Earliest deadline over every queued job (`None` = no deadlines),
    /// as time remaining from `now` — the executors' wait timeout.
    fn earliest_deadline(&self, now: Instant) -> Option<Duration> {
        let level_min = self
            .levels
            .iter()
            .flat_map(|l| l.values().flatten())
            .filter_map(|p| p.remaining(now));
        let aged_min = self.aged.iter().filter_map(|p| p.remaining(now));
        level_min.chain(aged_min).min()
    }
}

/// Mutable service state, guarded by [`Shared::inner`].
struct Inner {
    ready: ReadyLists,
    /// Outstanding modeled seconds charged per cache group.
    loads: Vec<f64>,
    busy: Vec<bool>,
    groups_busy: usize,
    /// The pre-created window segments, keyed by
    /// `(group_start, group_count)`; absent while checked out.
    segments: HashMap<(usize, usize), PoolSegment>,
    shutdown: bool,
    paused: bool,
    stats: ServiceStats,
    next_id: u64,
}

struct Shared {
    cfg: ServiceConfig,
    /// The one pool all tenants share. Executors only touch it on the
    /// (unreachable-by-construction) segment-recovery path, so there is
    /// no steady-state contention; never locked while holding `inner`.
    pool: Mutex<WorkerPool>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

fn lock(m: &Mutex<Inner>) -> MutexGuard<'_, Inner> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The numerics-relevant identity of a config: everything except the
/// keys that only steer placement, prediction, and scheduling.
fn batch_key(cfg: &RunConfig) -> String {
    let mut c = cfg.clone();
    c.machine = None;
    c.pin = PinPolicy::None;
    c.priority = 0;
    c.deadline_ms = None;
    c.to_text()
}

/// Validate `job` and compute its window and modeled cost against the
/// current per-group `loads` — the single admission helper
/// [`SolverService::submit`] and [`ServiceConfig::admit_plan`] share.
fn admit(svc: &ServiceConfig, job: &RunConfig, loads: &[f64]) -> Result<(Placement, f64)> {
    job.validate()?;
    anyhow::ensure!(
        job.ranks == 1,
        "the service runs single-rank jobs (got ranks = {}); rank decomposition layers above it",
        job.ranks
    );
    let runner = runner_for(job.scheme, job.op)?;
    let team = runner.team_size(job);
    let needed_groups = team.max(1).div_ceil(svc.group_width);
    if needed_groups > svc.groups {
        return Err(anyhow::Error::new(AdmissionError::TooWide {
            team,
            needed_groups,
            groups: svc.groups,
        }));
    }
    // ECM cost in modeled seconds: interior updates over the modeled
    // MLUP/s rate. Without a machine model the proxy rate scales with
    // the team so wide and narrow jobs still order sensibly.
    let r = job.op.radius();
    let (nz, ny, nx) = job.size;
    let updates = nz.saturating_sub(2 * r)
        * ny.saturating_sub(2 * r)
        * nx.saturating_sub(2 * r)
        * job.iters.max(1);
    let spec = job
        .machine_spec()
        .or_else(|| svc.machine.as_deref().and_then(MachineSpec::by_name));
    let mlups = match spec {
        Some(m) => runner.predict(&m, job),
        None => 100.0 * team.max(1) as f64,
    };
    let cost = (updates as f64 / 1e6) / mlups.max(1e-9);
    // min-max-load contiguous window; ties go to the lowest start (the
    // strict `<` below), making placement deterministic
    let mut best = 0usize;
    let mut best_peak = f64::INFINITY;
    for (g0, window) in loads.windows(needed_groups).enumerate() {
        let peak = window.iter().fold(0.0f64, |a, &b| a.max(b));
        if peak < best_peak {
            best_peak = peak;
            best = g0;
        }
    }
    Ok((
        Placement {
            group_start: best,
            group_count: needed_groups,
            worker_start: best * svc.group_width,
            workers: needed_groups * svc.group_width,
        },
        cost,
    ))
}

/// The long-running multi-tenant solver front end: one persistent
/// [`WorkerPool`], per-window [`PoolSegment`]s, `groups` executor
/// threads claiming queued jobs onto free windows.
pub struct SolverService {
    shared: Arc<Shared>,
    executors: Vec<JoinHandle<()>>,
}

impl SolverService {
    /// Spawn the pool (pinned per `cfg.pin`), pre-create every
    /// contiguous window's segment, and start the executor threads.
    pub fn new(cfg: ServiceConfig) -> Result<Self> {
        cfg.validate()?;
        let mut pool = WorkerPool::new(0);
        let topo = cfg
            .machine
            .as_deref()
            .and_then(MachineSpec::by_name)
            .map(|m| Topology::of_machine(&m))
            .unwrap_or_else(Topology::host);
        match pin_hook(cfg.pin, topo) {
            Some(hook) => pool.set_start_hook(hook),
            None => pool.clear_start_hook(),
        }
        pool.ensure_workers(cfg.groups * cfg.group_width);
        // every contiguous (start, width) window gets its own segment up
        // front — progress table and scratch arena included — so the
        // steady state checks segments out and in without allocating
        let mut segments = HashMap::new();
        for g0 in 0..cfg.groups {
            for w in 1..=cfg.groups - g0 {
                segments.insert((g0, w), pool.segment(g0 * cfg.group_width, w * cfg.group_width));
            }
        }
        let groups = cfg.groups;
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                ready: ReadyLists::new(),
                loads: vec![0.0; groups],
                busy: vec![false; groups],
                groups_busy: 0,
                segments,
                shutdown: false,
                paused: false,
                stats: ServiceStats::default(),
                next_id: 0,
            }),
            cv: Condvar::new(),
            pool: Mutex::new(pool),
            cfg,
        });
        let executors = (0..groups)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("stencilwave-svc-{i}"))
                    .spawn(move || executor_loop(&s))
                    .expect("spawn service executor")
            })
            .collect();
        Ok(Self { shared, executors })
    }

    /// Cache groups the service holds.
    pub fn group_count(&self) -> usize {
        self.shared.cfg.groups
    }

    /// Pool workers per cache group.
    pub fn group_width(&self) -> usize {
        self.shared.cfg.group_width
    }

    /// Admit a job: validate it, charge the cheapest window, queue it
    /// on its priority's ready list. Fails with a downcastable
    /// [`AdmissionError`]: `TooWide` when the job's team exceeds the
    /// whole machine (permanent), `QueueFull` when the queue is at
    /// [`ServiceConfig::queue_capacity`] (transient — retry after the
    /// carried ECM drain hint). A rejected submission changes nothing
    /// except, for `QueueFull`, the `rejected_full` counter.
    pub fn submit(&self, spec: JobSpec) -> Result<JobTicket> {
        anyhow::ensure!(
            spec.u0.shape() == spec.cfg.size,
            "u0 shape {:?} does not match the job's configured size {:?}",
            spec.u0.shape(),
            spec.cfg.size
        );
        if let Some(f) = &spec.f {
            anyhow::ensure!(
                f.shape() == spec.cfg.size,
                "rhs shape {:?} does not match the job's configured size {:?}",
                f.shape(),
                spec.cfg.size
            );
        }
        let (tx, rx) = mpsc::channel();
        let mut inner = lock(&self.shared.inner);
        anyhow::ensure!(!inner.shutdown, "solver service is shut down");
        let (placement, cost) = admit(&self.shared.cfg, &spec.cfg, &inner.loads)?;
        if inner.ready.queued >= self.shared.cfg.queue_capacity {
            // backpressure: reject with the ECM-predicted drain time of
            // the window admission just picked (the least loaded one
            // this job fits on) — finite, and floored so an all-idle
            // hint is still positive
            let w = placement.group_start..placement.group_start + placement.group_count;
            let hint = inner.loads[w].iter().fold(0.0f64, |a, &b| a.max(b)).max(1e-6);
            inner.stats.rejected_full += 1;
            return Err(anyhow::Error::new(AdmissionError::QueueFull {
                queued: inner.ready.queued,
                capacity: self.shared.cfg.queue_capacity,
                retry_after_hint: hint,
            }));
        }
        let w = placement.group_start..placement.group_start + placement.group_count;
        for l in &mut inner.loads[w] {
            *l += cost;
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.stats.submitted += 1;
        let (nz, ny, nx) = spec.cfg.size;
        let batchable = self.shared.cfg.max_batch > 1 && nz * ny * nx <= self.shared.cfg.batch_cells;
        let priority = spec.cfg.priority;
        let deadline_ms = spec.cfg.deadline_ms;
        inner.ready.push(Pending {
            id,
            key: batch_key(&spec.cfg),
            batchable,
            priority,
            deadline_ms,
            submitted: Instant::now(),
            skipped: 0,
            wait_ms: 0.0,
            spec,
            placement,
            cost,
            tx,
        });
        inner.stats.max_queue_depth = inner.stats.max_queue_depth.max(inner.ready.queued);
        drop(inner);
        self.shared.cv.notify_all();
        Ok(JobTicket { id, placement, rx })
    }

    /// Submit one job and block for its result.
    pub fn run_job(&self, spec: JobSpec) -> Result<JobOutput> {
        self.submit(spec)?.wait()
    }

    /// Stop claiming queued jobs (in-flight windows finish; submissions
    /// still queue). The deterministic-batching tests use this to stage
    /// a whole batch before any executor can claim its leader.
    pub fn pause(&self) {
        lock(&self.shared.inner).paused = true;
        self.shared.cv.notify_all();
    }

    /// Resume claiming after [`SolverService::pause`].
    pub fn resume(&self) {
        lock(&self.shared.inner).paused = false;
        self.shared.cv.notify_all();
    }

    /// A snapshot of the service counters.
    pub fn stats(&self) -> ServiceStats {
        lock(&self.shared.inner).stats
    }

    /// Outstanding modeled load per cache group (charged at submit,
    /// refunded at completion — all zeros when idle).
    pub fn loads(&self) -> Vec<f64> {
        lock(&self.shared.inner).loads.clone()
    }

    /// Drain gracefully: every already-queued job still runs (shutdown
    /// overrides [`SolverService::pause`]), new submissions are
    /// rejected, and the executor threads are joined. Idempotent; also
    /// invoked by `Drop`.
    pub fn shutdown(&mut self) {
        lock(&self.shared.inner).shutdown = true;
        self.shared.cv.notify_all();
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for SolverService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn window_clear(busy: &[bool], reserved: &[bool], p: &Placement) -> bool {
    (p.group_start..p.group_start + p.group_count).all(|g| !busy[g] && !reserved[g])
}

/// Where the claim scan found the next job to start.
enum ClaimAt {
    Aged(usize),
    Bucket(usize, (usize, usize)),
}

/// The claim scan: aged jobs first (FIFO — a *blocked* aged job
/// reserves its window so no younger candidate can leapfrog onto it,
/// which is what bounds every aged job's wait), then priority levels
/// high → low, where within a level the eligible window-bucket front
/// with the smallest id wins (FIFO across the level). Cost is
/// O(aged + windows), independent of queue depth.
fn scan_claim(inner: &Inner) -> Option<ClaimAt> {
    let busy = &inner.busy;
    let mut reserved = vec![false; busy.len()];
    for (i, p) in inner.ready.aged.iter().enumerate() {
        if window_clear(busy, &reserved, &p.placement) {
            return Some(ClaimAt::Aged(i));
        }
        for g in p.placement.group_start..p.placement.group_start + p.placement.group_count {
            reserved[g] = true;
        }
    }
    for level in (0..PRIORITY_LEVELS).rev() {
        let mut best: Option<(u64, (usize, usize))> = None;
        for (&key, q) in &inner.ready.levels[level] {
            let front = q.front().expect("buckets are never empty");
            if window_clear(busy, &reserved, &front.placement)
                && best.map_or(true, |(id, _)| front.id < id)
            {
                best = Some((front.id, key));
            }
        }
        if let Some((_, key)) = best {
            return Some(ClaimAt::Bucket(level, key));
        }
    }
    None
}

fn take_claim(ready: &mut ReadyLists, at: ClaimAt) -> Pending {
    let p = match at {
        ClaimAt::Aged(i) => ready.aged.remove(i).expect("aged claim index is valid"),
        ClaimAt::Bucket(level, key) => {
            let q = ready.levels[level].get_mut(&key).expect("claimed bucket exists");
            let p = q.pop_front().expect("buckets are never empty");
            if q.is_empty() {
                ready.levels[level].remove(&key);
            }
            p
        }
    };
    ready.queued -= 1;
    p
}

/// Shed every queued job past its deadline: refund its charged load,
/// count it, and fail its ticket with a typed [`ExpiredError`].
fn shed_expired(inner: &mut Inner) {
    let now = Instant::now();
    for p in inner.ready.drain_matching(|p| p.expired(now)) {
        let w = p.placement.group_start..p.placement.group_start + p.placement.group_count;
        for l in &mut inner.loads[w] {
            *l -= p.cost;
        }
        inner.stats.shed_expired += 1;
        let waited_ms = now.saturating_duration_since(p.submitted).as_millis() as u64;
        let _ = p.tx.send(Err(anyhow::Error::new(ExpiredError {
            id: p.id,
            deadline_ms: p.deadline_ms.unwrap_or(0),
            waited_ms,
        })));
    }
}

/// After a successful claim, every job still heading a ready list was
/// passed over this cycle: bump its skip count and promote fronts that
/// crossed `age_after` to the aged list (in deterministic
/// priority-then-id order). Aged jobs keep counting too, so
/// [`JobOutput::skipped_cycles`] reports a job's full passed-over
/// total.
fn bump_passed_over(inner: &mut Inner, age_after: u64) {
    // already-aged jobs first, so a job promoted below is not counted
    // twice for the same cycle
    for p in &mut inner.ready.aged {
        p.skipped += 1;
    }
    let mut promote: Vec<(usize, (usize, usize), u64)> = Vec::new();
    for (level, lv) in inner.ready.levels.iter_mut().enumerate() {
        for (&key, q) in lv.iter_mut() {
            let front = q.front_mut().expect("buckets are never empty");
            front.skipped += 1;
            if front.skipped >= age_after {
                promote.push((level, key, front.id));
            }
        }
    }
    promote.sort_by(|a, b| b.0.cmp(&a.0).then(a.2.cmp(&b.2)));
    for (level, key, _) in promote {
        let q = inner.ready.levels[level].get_mut(&key).expect("promoted bucket exists");
        let p = q.pop_front().expect("buckets are never empty");
        if q.is_empty() {
            inner.ready.levels[level].remove(&key);
        }
        inner.stats.aged_jobs += 1;
        inner.ready.aged.push_back(p);
    }
}

fn executor_loop(shared: &Shared) {
    loop {
        // claim: aged jobs first, then the highest-priority ready-list
        // front whose charged window is entirely free, plus (atomically,
        // under the same lock) its batch mates
        let mut inner = lock(&shared.inner);
        let at = loop {
            // deadline pass first so an expired job is never claimed
            // (the scan below sees only live jobs)
            shed_expired(&mut inner);
            if inner.shutdown && inner.ready.is_empty() {
                return;
            }
            if !inner.paused || inner.shutdown {
                if let Some(at) = scan_claim(&inner) {
                    break at;
                }
            }
            // sleep until notified — or until the earliest queued
            // deadline, so expiry is shed promptly even when nothing
            // else wakes the executors (pause included)
            match inner.ready.earliest_deadline(Instant::now()) {
                Some(d) => {
                    let (g, _) = shared
                        .cv
                        .wait_timeout(inner, d.max(Duration::from_millis(1)))
                        .unwrap_or_else(|e| e.into_inner());
                    inner = g;
                }
                None => inner = shared.cv.wait(inner).unwrap_or_else(|e| e.into_inner()),
            }
        };
        let lead = take_claim(&mut inner.ready, at);
        let mut batch = vec![lead];
        if batch[0].batchable {
            // batch mates ride from any ready list in submission order,
            // like the seed's whole-queue scan
            let key = batch[0].key.clone();
            let want = shared.cfg.max_batch - 1;
            let mut ids: Vec<u64> = inner
                .ready
                .levels
                .iter()
                .flat_map(|l| l.values().flatten())
                .chain(inner.ready.aged.iter())
                .filter(|p| p.batchable && p.key == key)
                .map(|p| p.id)
                .collect();
            ids.sort_unstable();
            ids.truncate(want);
            let mut mates = inner.ready.drain_matching(|p| ids.binary_search(&p.id).is_ok());
            mates.sort_by_key(|p| p.id);
            batch.extend(mates);
        }
        bump_passed_over(&mut inner, shared.cfg.age_after);
        let now = Instant::now();
        for p in &mut batch {
            p.wait_ms = now.saturating_duration_since(p.submitted).as_secs_f64() * 1e3;
            inner.stats.wait_hist[p.priority][wait_bucket(p.wait_ms)] += 1;
        }
        let placement = batch[0].placement;
        let seg_key = (placement.group_start, placement.group_count);
        let window = placement.group_start..placement.group_start + placement.group_count;
        let conflicts = inner.busy[window.clone()].iter().filter(|&&b| b).count() as u64;
        inner.stats.claim_conflicts += conflicts;
        for b in &mut inner.busy[window] {
            *b = true;
        }
        inner.groups_busy += placement.group_count;
        inner.stats.peak_groups_busy = inner.stats.peak_groups_busy.max(inner.groups_busy);
        let segment = inner.segments.remove(&seg_key);
        drop(inner);

        let segment = match segment {
            Some(s) => s,
            None => {
                // busy flags make a double checkout impossible; if the
                // invariant ever breaks, rebuild the window from the pool
                // rather than wedging it forever
                let mut pool = shared.pool.lock().unwrap_or_else(|e| e.into_inner());
                lock(&shared.inner).stats.claim_conflicts += 1;
                pool.segment(placement.worker_start, placement.workers)
            }
        };
        let batch_size = batch.len();
        let refunds: Vec<(Placement, f64)> = batch.iter().map(|p| (p.placement, p.cost)).collect();
        let (segment, outcome) = run_batch(batch, segment, placement);

        // return the window: segment back to the registry, groups freed,
        // loads refunded where each job was charged (a batch mate's
        // charged window can differ from the leader's it executed on)
        let mut inner = lock(&shared.inner);
        if let Some(segment) = segment {
            inner.segments.insert(seg_key, segment);
        }
        for b in &mut inner.busy[placement.group_start..placement.group_start + placement.group_count]
        {
            *b = false;
        }
        inner.groups_busy -= placement.group_count;
        for (charged, cost) in refunds {
            for l in &mut inner.loads[charged.group_start..charged.group_start + charged.group_count]
            {
                *l -= cost;
            }
        }
        inner.stats.completed += outcome.completed;
        inner.stats.failed += outcome.failed;
        if batch_size > 1 {
            inner.stats.batches += 1;
            inner.stats.batched_jobs += batch_size as u64;
        }
        drop(inner);
        shared.cv.notify_all();
    }
}

/// Per-batch completion counts for the stats rollup.
struct BatchOutcome {
    completed: u64,
    failed: u64,
}

/// Execute one claimed batch on its window — one session, each job's
/// right-hand side through [`Solver::run_with`] — and send every job's
/// result. Returns the segment for reinsertion (`None` only on the
/// impossible-by-construction build failure, which consumes it; the
/// next claim of that window rebuilds one from the pool).
fn run_batch(
    batch: Vec<Pending>,
    segment: PoolSegment,
    placement: Placement,
) -> (Option<PoolSegment>, BatchOutcome) {
    let batch_size = batch.len();
    let lead_cfg = batch[0].spec.cfg.clone();
    let mut outcome = BatchOutcome { completed: 0, failed: 0 };
    match Solver::builder(&lead_cfg).segment(segment).build() {
        Ok(mut solver) => {
            let mut zero: Option<Grid3> = None;
            for p in batch {
                let Pending { spec, tx, priority, wait_ms, skipped, .. } = p;
                let JobSpec { cfg, u0, f, h2 } = spec;
                let mut u = u0;
                let res = {
                    let fref = match &f {
                        Some(f) => f,
                        None => zero.get_or_insert_with(|| {
                            let (nz, ny, nx) = lead_cfg.size;
                            Grid3::zeros(nz, ny, nx)
                        }),
                    };
                    solver.run_with(&mut u, fref, h2, cfg.iters)
                };
                match res {
                    Ok(()) => {
                        outcome.completed += 1;
                        let _ = tx.send(Ok(JobOutput {
                            u,
                            placement,
                            batch_size,
                            priority,
                            wait_ms,
                            skipped_cycles: skipped,
                        }));
                    }
                    Err(e) => {
                        outcome.failed += 1;
                        let _ = tx.send(Err(e));
                    }
                }
            }
            (Some(solver.into_segment().expect("segment-bound session")), outcome)
        }
        Err(e) => {
            // admission re-validates everything build checks, so this
            // path is unreachable by construction — but a wedged window
            // would be worse than a surfaced error, so fail the tickets
            // instead of panicking the executor
            let msg = format!("{e:#}");
            outcome.failed = batch.len() as u64;
            for p in batch {
                let _ = p.tx.send(Err(anyhow::anyhow!("batch session build failed: {msg}")));
            }
            (None, outcome)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    fn svc_cfg() -> ServiceConfig {
        ServiceConfig { groups: 2, group_width: 4, ..Default::default() }
    }

    fn job_cfg(scheme: Scheme) -> RunConfig {
        RunConfig { scheme, size: (10, 12, 9), t: 4, groups: 2, iters: 4, ..Default::default() }
    }

    #[test]
    fn jobs_run_and_match_the_serial_reference() {
        let mut svc = SolverService::new(svc_cfg()).unwrap();
        for (i, scheme) in [Scheme::JacobiWavefront, Scheme::GsMultiGroup, Scheme::JacobiBaseline]
            .into_iter()
            .enumerate()
        {
            let cfg = job_cfg(scheme);
            let f = Grid3::random(10, 12, 9, 7 + i as u64);
            let u0 = Grid3::random(10, 12, 9, 80 + i as u64);
            let out =
                svc.run_job(JobSpec::new(cfg.clone(), u0.clone()).rhs(f.clone(), 0.9)).unwrap();
            let solver = Solver::builder(&cfg).build().unwrap();
            let want = solver.reference_with(&u0, &f, 0.9, cfg.iters);
            assert_eq!(out.u.max_abs_diff(&want), 0.0, "{scheme:?}");
            assert!(out.placement.group_count >= 1);
        }
        let stats = svc.stats();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.claim_conflicts, 0);
        assert!(svc.loads().iter().all(|&l| l == 0.0), "loads refund on completion");
        svc.shutdown();
    }

    #[test]
    fn admission_rejects_jobs_wider_than_the_machine() {
        let svc = SolverService::new(ServiceConfig {
            groups: 2,
            group_width: 2,
            ..Default::default()
        })
        .unwrap();
        // GsWavefront team = t * groups = 8 > 2 * 2 workers
        let cfg = job_cfg(Scheme::GsWavefront);
        let err = svc.submit(JobSpec::new(cfg, Grid3::zeros(10, 12, 9))).map(|_| ()).unwrap_err();
        let typed = err.downcast_ref::<AdmissionError>().expect("typed admission error");
        assert_eq!(
            *typed,
            AdmissionError::TooWide { team: 8, needed_groups: 4, groups: 2 },
            "too-wide rejections carry the team and group arithmetic"
        );
        assert_eq!(svc.stats().submitted, 0, "rejected jobs are not counted as submitted");
    }

    #[test]
    fn full_queues_reject_with_a_finite_retry_hint() {
        let mut svc = SolverService::new(ServiceConfig {
            queue_capacity: 3,
            ..svc_cfg()
        })
        .unwrap();
        svc.pause(); // nothing is claimed, so the queue really fills
        let cfg = job_cfg(Scheme::JacobiWavefront);
        let tickets: Vec<JobTicket> = (0..3)
            .map(|i| svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, i))).unwrap())
            .collect();
        let loads_before = svc.loads();
        let err = svc
            .submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, 9)))
            .map(|_| ())
            .unwrap_err();
        match err.downcast_ref::<AdmissionError>().expect("typed admission error") {
            AdmissionError::QueueFull { queued, capacity, retry_after_hint } => {
                assert_eq!((*queued, *capacity), (3, 3));
                assert!(retry_after_hint.is_finite() && *retry_after_hint > 0.0);
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // the rejection changed nothing but the counter
        assert_eq!(svc.loads(), loads_before);
        let stats = svc.stats();
        assert_eq!(stats.rejected_full, 1);
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.max_queue_depth, 3);
        svc.resume();
        for t in tickets {
            t.wait().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn expired_jobs_are_shed_with_a_typed_result() {
        let mut svc = SolverService::new(svc_cfg()).unwrap();
        svc.pause(); // the job can never start, so its deadline must fire
        let cfg = RunConfig { deadline_ms: Some(1), ..job_cfg(Scheme::JacobiWavefront) };
        let t = svc.submit(JobSpec::new(cfg, Grid3::random(10, 12, 9, 1))).unwrap();
        let err = t.wait().map(|_| ()).unwrap_err();
        let typed = err.downcast_ref::<ExpiredError>().expect("typed expiry result");
        assert_eq!(typed.deadline_ms, 1);
        assert!(typed.waited_ms >= 1);
        let stats = svc.stats();
        assert_eq!(stats.shed_expired, 1);
        assert_eq!(stats.completed, 0);
        assert!(svc.loads().iter().all(|&l| l == 0.0), "shed jobs refund their charge");
        svc.shutdown();
    }

    #[test]
    fn higher_priority_jobs_are_claimed_first() {
        let mut svc = SolverService::new(ServiceConfig {
            groups: 1,
            group_width: 4,
            max_batch: 1, // no batching: strict one-at-a-time claim order
            ..Default::default()
        })
        .unwrap();
        svc.pause();
        let lo = RunConfig { priority: 0, ..job_cfg(Scheme::JacobiWavefront) };
        let hi = RunConfig { priority: 3, ..job_cfg(Scheme::JacobiWavefront) };
        // submitted low before high; the single window forces serial
        // execution in claim order
        let t_lo = svc.submit(JobSpec::new(lo, Grid3::random(10, 12, 9, 1))).unwrap();
        let t_hi = svc.submit(JobSpec::new(hi, Grid3::random(10, 12, 9, 2))).unwrap();
        svc.resume();
        let out_lo = t_lo.wait().unwrap();
        let out_hi = t_hi.wait().unwrap();
        assert_eq!(out_hi.priority, 3);
        assert_eq!(out_lo.priority, 0);
        assert!(
            out_hi.wait_ms <= out_lo.wait_ms,
            "the high-priority job started first (hi {} ms vs lo {} ms)",
            out_hi.wait_ms,
            out_lo.wait_ms
        );
        let stats = svc.stats();
        assert_eq!(stats.completed, 2);
        // both priorities landed in the wait histogram
        assert_eq!(stats.wait_hist[3].iter().sum::<u64>(), 1);
        assert_eq!(stats.wait_hist[0].iter().sum::<u64>(), 1);
        svc.shutdown();
    }

    #[test]
    fn passed_over_jobs_age_deterministically() {
        // single-window service, age_after = 1: claiming job A passes
        // job B over exactly once, promoting it to the aged list, from
        // which it runs when the window frees. Single-window
        // serialization makes the cycle counts exact.
        let mut svc = SolverService::new(ServiceConfig {
            groups: 1,
            group_width: 4,
            max_batch: 1,
            age_after: 1,
            ..Default::default()
        })
        .unwrap();
        svc.pause();
        let cfg = job_cfg(Scheme::JacobiWavefront);
        let ta = svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, 1))).unwrap();
        let tb = svc.submit(JobSpec::new(cfg, Grid3::random(10, 12, 9, 2))).unwrap();
        svc.resume();
        let a = ta.wait().unwrap();
        let b = tb.wait().unwrap();
        assert_eq!(a.skipped_cycles, 0, "the first claim is never passed over");
        assert_eq!(b.skipped_cycles, 1, "B was passed over once, by A's claim");
        let stats = svc.stats();
        assert_eq!(stats.aged_jobs, 1, "age_after = 1 promotes B on that one skip");
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.claim_conflicts, 0);
        svc.shutdown();
    }

    #[test]
    fn wait_buckets_partition_the_axis() {
        assert_eq!(wait_bucket(0.0), 0);
        assert_eq!(wait_bucket(0.99), 0);
        assert_eq!(wait_bucket(1.0), 1);
        assert_eq!(wait_bucket(99.9), 2);
        assert_eq!(wait_bucket(100.0), 3);
        assert_eq!(wait_bucket(1000.0), 4);
        assert_eq!(wait_bucket(f64::INFINITY), WAIT_BUCKETS - 1);
    }

    #[test]
    fn placement_balances_load_and_ties_go_low() {
        let svc = ServiceConfig { groups: 3, group_width: 4, ..Default::default() };
        // three identical one-group jobs spread across the groups; the
        // fourth ties on peak load and lands back on group 0
        let job = job_cfg(Scheme::JacobiWavefront); // team = t = 4 -> 1 group
        let plan = svc.admit_plan(&[job.clone(), job.clone(), job.clone(), job.clone()]).unwrap();
        let starts: Vec<usize> = plan.iter().map(|p| p.group_start).collect();
        assert_eq!(starts, vec![0, 1, 2, 0]);
        assert!(plan.iter().all(|p| p.group_count == 1 && p.workers == 4));
        // deterministic: the same sequence admits to the same plan
        assert_eq!(
            svc.admit_plan(&[job.clone(), job.clone(), job.clone(), job]).unwrap(),
            plan
        );
    }

    #[test]
    fn paused_submissions_follow_the_pure_admission_plan() {
        let mut svc = SolverService::new(ServiceConfig {
            groups: 3,
            group_width: 2,
            ..Default::default()
        })
        .unwrap();
        // distinct configs so batching cannot merge them
        let jobs = [
            job_cfg(Scheme::JacobiMultiGroup),                            // team 2 -> 1 group
            RunConfig { t: 2, ..job_cfg(Scheme::GsWavefront) },           // team 4 -> 2 groups
            RunConfig { iters: 8, ..job_cfg(Scheme::JacobiWavefront) },   // team 4 -> 2 groups
        ];
        let plan = svc.shared.cfg.admit_plan(&jobs).unwrap();
        svc.pause();
        let tickets: Vec<JobTicket> = jobs
            .iter()
            .map(|cfg| {
                svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, cfg.iters as u64)))
                    .unwrap()
            })
            .collect();
        // with no completions in between, live placement == the pure plan
        let charged: Vec<Placement> = tickets.iter().map(|t| t.placement()).collect();
        assert_eq!(charged, plan);
        svc.resume();
        for t in tickets {
            t.wait().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn batched_jobs_stay_bit_exact() {
        let mut svc = SolverService::new(svc_cfg()).unwrap();
        let cfg = job_cfg(Scheme::JacobiWavefront);
        svc.pause();
        let tickets: Vec<JobTicket> = (0..3)
            .map(|i| {
                let u0 = Grid3::random(10, 12, 9, 100 + i);
                let f = Grid3::random(10, 12, 9, 200 + i);
                svc.submit(JobSpec::new(cfg.clone(), u0).rhs(f, 0.8)).unwrap()
            })
            .collect();
        svc.resume();
        let solver = Solver::builder(&cfg).build().unwrap();
        for (i, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap();
            assert_eq!(out.batch_size, 3, "all three staged jobs share one window");
            let u0 = Grid3::random(10, 12, 9, 100 + i as u64);
            let f = Grid3::random(10, 12, 9, 200 + i as u64);
            let want = solver.reference_with(&u0, &f, 0.8, cfg.iters);
            assert_eq!(out.u.max_abs_diff(&want), 0.0, "batched job {i}");
        }
        let stats = svc.stats();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.batched_jobs, 3);
        svc.shutdown();
    }

    #[test]
    fn oversized_grids_are_never_batched() {
        let mut svc = SolverService::new(ServiceConfig {
            batch_cells: 10, // smaller than any valid grid here
            ..svc_cfg()
        })
        .unwrap();
        let cfg = job_cfg(Scheme::JacobiWavefront);
        svc.pause();
        let tickets: Vec<JobTicket> = (0..2)
            .map(|i| {
                svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, i))).unwrap()
            })
            .collect();
        svc.resume();
        for t in tickets {
            assert_eq!(t.wait().unwrap().batch_size, 1);
        }
        assert_eq!(svc.stats().batches, 0);
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_jobs_and_rejects_new_ones() {
        let mut svc = SolverService::new(svc_cfg()).unwrap();
        svc.pause();
        let cfg = job_cfg(Scheme::GsMultiGroup);
        let t1 = svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, 1))).unwrap();
        let t2 = svc.submit(JobSpec::new(cfg.clone(), Grid3::random(10, 12, 9, 2))).unwrap();
        svc.shutdown(); // overrides pause: both queued jobs still run
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
        let err = svc.submit(JobSpec::new(cfg, Grid3::random(10, 12, 9, 3))).map(|_| ());
        assert!(err.unwrap_err().to_string().contains("shut down"));
    }

    #[test]
    fn multi_rank_jobs_are_rejected() {
        let svc = SolverService::new(svc_cfg()).unwrap();
        let cfg = RunConfig { ranks: 2, size: (32, 12, 9), ..job_cfg(Scheme::JacobiWavefront) };
        let err = svc
            .submit(JobSpec::new(cfg, Grid3::zeros(32, 12, 9)))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("single-rank"), "{err}");
    }

    #[test]
    fn mismatched_grids_are_rejected_at_submit() {
        let svc = SolverService::new(svc_cfg()).unwrap();
        let cfg = job_cfg(Scheme::JacobiWavefront);
        assert!(svc.submit(JobSpec::new(cfg.clone(), Grid3::zeros(8, 8, 8))).is_err());
        let bad_rhs = JobSpec::new(cfg, Grid3::zeros(10, 12, 9)).rhs(Grid3::zeros(8, 8, 8), 1.0);
        assert!(svc.submit(bad_rhs).is_err());
    }

    #[test]
    fn for_host_yields_a_valid_shape() {
        let cfg = ServiceConfig::for_host();
        cfg.validate().unwrap();
        assert!(cfg.groups >= 1 && cfg.group_width >= 1);
    }
}
