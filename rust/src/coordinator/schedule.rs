//! The unified time-skew schedule abstraction.
//!
//! Every coordinator in this crate — the wavefront Jacobi group (Fig. 6),
//! the pipelined Gauss-Seidel sweep (Fig. 5a), the GS wavefront
//! composition (Fig. 5b) and the multi-group blocked Jacobi (Fig. 7 at
//! scale) — shares one execution shape: a fixed team of workers, each
//! owning a *role* (a time-shifted sweep, a y-chunk, a y-block), advances
//! through rounds of plane/line tasks while expressing forward
//! dependencies ("my producer has passed plane `k`") and back-pressure
//! ("my consumer is close enough that this buffer slot is still live")
//! against a shared table of per-role watermarks.
//!
//! [`Schedule`] captures that shape once; [`Progress`] is the single
//! shared watermark table every wait goes through (it replaces the three
//! per-coordinator `Vec<AtomicIsize>` copies the crate used to carry);
//! [`super::pool::WorkerPool`] executes schedules on a persistent worker
//! team so repeated passes do not respawn threads.

use std::sync::atomic::{AtomicBool, AtomicIsize, Ordering};

use super::barrier::spin_wait;

/// Shared progress table: one monotonically increasing watermark per
/// worker role, reset by the pool before every pass.
///
/// Watermarks are plane (or round) numbers counted from 1, so
/// [`Progress::NONE`]` = 0` means "nothing completed yet" and waits for
/// non-positive thresholds (back-pressure during pipeline fill) succeed
/// immediately.
///
/// A pass can be *poisoned* ([`Progress::poison`]) when a worker dies:
/// every [`Progress::wait_min`] whose watermark can no longer arrive
/// then panics instead of spinning forever, so the remaining workers
/// unwind and the pool can surface the original failure.
pub struct Progress {
    slots: Vec<AtomicIsize>,
    poisoned: AtomicBool,
}

impl Progress {
    /// Initial watermark: no plane completed yet.
    pub const NONE: isize = 0;

    /// A table of `n` slots, all at [`Progress::NONE`].
    pub fn new(n: usize) -> Self {
        Self {
            slots: (0..n).map(|_| AtomicIsize::new(Self::NONE)).collect(),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when the table has no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Reset every watermark to [`Progress::NONE`] and clear the poison
    /// flag (start of a pass).
    pub fn reset(&self) {
        for s in &self.slots {
            s.store(Self::NONE, Ordering::Release);
        }
        self.poisoned.store(false, Ordering::Release);
    }

    /// Mark the pass as failed: wake every worker blocked on a watermark
    /// that will never arrive (they panic out of [`Progress::wait_min`]).
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    /// True once [`Progress::poison`] was called this pass.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Publish that role `slot` has completed everything up to `value`.
    #[inline]
    pub fn publish(&self, slot: usize, value: isize) {
        self.slots[slot].store(value, Ordering::Release);
    }

    /// Current watermark of role `slot`.
    #[inline]
    pub fn load(&self, slot: usize) -> isize {
        self.slots[slot].load(Ordering::Acquire)
    }

    /// Forward-dependency / back-pressure wait: spin until role `slot`'s
    /// watermark reaches `min`.
    ///
    /// # Panics
    /// When the pass is poisoned (a peer worker died) and the awaited
    /// watermark has not arrived — the abort path that lets the
    /// remaining workers drain instead of spinning forever.
    #[inline]
    pub fn wait_min(&self, slot: usize, min: isize) {
        spin_wait(|| {
            self.slots[slot].load(Ordering::Acquire) >= min
                || self.poisoned.load(Ordering::Acquire)
        });
        if self.slots[slot].load(Ordering::Acquire) < min {
            panic!("pass aborted: a peer worker panicked");
        }
    }
}

/// One time-skewed parallel pass, executable on a worker pool.
///
/// Implementations hold raw views of the grids and buffers they traverse
/// (they are `Sync`, shared by reference across the team) and encode the
/// paper's flow-control protocol in [`Schedule::worker`]: per-round task
/// selection, forward-dependency waits and back-pressure waits, all
/// against the single [`Progress`] table the pool hands in.
pub trait Schedule: Sync {
    /// Workers the pass needs (the team size).
    fn workers(&self) -> usize;

    /// Progress slots the pass needs (defaults to one per worker).
    fn progress_slots(&self) -> usize {
        self.workers()
    }

    /// The body of worker `id` (`0 <= id < workers()`), executed
    /// concurrently on every worker of the team. `progress` has at least
    /// [`Schedule::progress_slots`] slots and is reset to
    /// [`Progress::NONE`] before the pass starts.
    fn worker(&self, id: usize, progress: &Progress);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_reset_and_watermarks() {
        let p = Progress::new(3);
        assert_eq!(p.len(), 3);
        assert!(!p.is_empty());
        assert_eq!(p.load(1), Progress::NONE);
        p.publish(1, 7);
        assert_eq!(p.load(1), 7);
        p.wait_min(1, 7); // already satisfied: returns immediately
        p.wait_min(2, -3); // NONE >= -3: fill-phase back-pressure
        p.reset();
        assert_eq!(p.load(1), Progress::NONE);
    }

    #[test]
    fn poison_aborts_unsatisfiable_waits() {
        let p = Progress::new(2);
        p.poison();
        assert!(p.is_poisoned());
        p.wait_min(0, 0); // satisfied waits still succeed when poisoned
        let aborted = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.wait_min(0, 5); // watermark 5 can never arrive
        }));
        assert!(aborted.is_err());
        p.reset();
        assert!(!p.is_poisoned());
    }
}
