//! Multi-group spatial × temporal blocking for Jacobi — the parallel
//! Fig. 7 scheme.
//!
//! [`super::spatial`] sweeps the y-blocks of the skewed decomposition one
//! after another on a single thread. Here `G` *groups* each own one
//! y-block and sweep it concurrently, time-shifted: group `g` executes
//! wavefront round `r` only after group `g-1` has completed round `r-1`.
//! The per-level update regions, the 4-slot temporary ring per odd level
//! and the odd-level boundary arrays are exactly those of the serial
//! blocked sweep — but the temporary ring and the boundary array are
//! per-group, and group `g` reads the boundary planes directly out of
//! group `g-1`'s array under the round-lag flow control (the hand-off
//! Wittmann et al., arXiv:1006.3148, identify as the key to multi-group
//! temporal blocking).
//!
//! ## Why a one-round lag suffices
//!
//! All cross-group traffic sits at the block interface. For the update of
//! level `s`, plane `k` (round `r = k + 2(s-1)`):
//!
//! * *flow*: every level-`s-1` value group `g` reads from group `g-1` —
//!   `src` lines for even `s-1`, boundary-array lines for odd `s-1` — was
//!   produced at plane `<= k+1`, i.e. at round `<= r-1`;
//! * *anti*: the deepest even level of group `g-1` that writes an
//!   interface `src` line group `g` still wants at level `s-1` *is*
//!   level `s-1` itself (deeper even levels end strictly left of it), so
//!   nothing group `g` needs is ever overwritten; conversely group `g`'s
//!   even-level `src` writes at lines group `g-1` reads happen one round
//!   *after* group `g-1`'s last read of them — guaranteed because group
//!   `g` trails by at least one round.
//!
//! The serial code's "forwarding pass" for width-1 blocks has no sound
//! one-round-lag analog, so the scheme requires every block to hold at
//! least two interior lines (`ny - 2 >= 2 * groups`); the constructor
//! rejects narrower decompositions.
//!
//! Result: bit-identical to `t` serial Jacobi sweeps for every
//! `(t, groups)` — asserted by the tests and by `launcher::run_experiment`
//! on every launch.

use std::marker::PhantomData;

use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::ONE_SIXTH;
use crate::Result;

use super::pool::{self, WorkerPool};
use super::schedule::{Progress, Schedule};

/// Temporary-ring slots per odd level (as in the serial blocked sweep).
const TMP_SLOTS: usize = 4;

/// Configuration of a multi-group blocked (spatial × temporal) pass.
#[derive(Clone, Copy, Debug)]
pub struct MultiGroupConfig {
    /// Temporal blocking factor `t` (even, >= 2).
    pub t: usize,
    /// Thread groups = y blocks (>= 1; each block needs >= 2 interior
    /// lines when `groups > 1`).
    pub groups: usize,
}

impl Default for MultiGroupConfig {
    fn default() -> Self {
        Self { t: 4, groups: 2 }
    }
}

impl MultiGroupConfig {
    /// Validate the grid-independent part of the configuration (single
    /// source for every entry point); the per-group width requirement
    /// needs the grid and lives in [`MultiGroupSchedule::new`].
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.t >= 2 && self.t % 2 == 0,
            "multi-group blocking needs even t >= 2, got {}",
            self.t
        );
        anyhow::ensure!(self.groups >= 1, "need at least one group");
        Ok(())
    }
}

/// One multi-group blocked pass (`t` fused updates) as a [`Schedule`]:
/// worker `g` wavefront-sweeps y-block `g`.
pub struct MultiGroupSchedule<'g> {
    src: *mut f64,
    f: *const f64,
    /// `groups * (t/2) * TMP_SLOTS` z-x planes (per-group odd-level rings).
    tmp: *mut f64,
    /// `groups * (t/2) * nz * 2` x-lines (per-group boundary arrays).
    bnd: *mut f64,
    /// `groups * nx` per-worker x-line update buffers (disjoint slices;
    /// pool-owned scratch instead of a per-pass `Vec` per worker).
    lines: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    groups: usize,
    h2: f64,
    /// Block boundaries over the interior lines `[1, ny-1)`.
    starts: Vec<usize>,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: groups write disjoint regions (own ring, own boundary array,
// own skewed src lines); the round-lag protocol orders every cross-group
// read/write pair (module docs).
unsafe impl Send for MultiGroupSchedule<'_> {}
unsafe impl Sync for MultiGroupSchedule<'_> {}

impl<'g> MultiGroupSchedule<'g> {
    /// Build a pass over `u`. `tmp`, `bnd` and `lines` are caller-owned
    /// scratch buffers (typically the pool's reusable
    /// [`Scratch`](super::pool::Scratch)), resized here; they must stay
    /// alive (and untouched) for as long as the schedule runs.
    pub fn new(
        u: &'g mut Grid3,
        f: &'g Grid3,
        tmp: &'g mut Vec<f64>,
        bnd: &'g mut Vec<f64>,
        lines: &'g mut Vec<f64>,
        h2: f64,
        cfg: &MultiGroupConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.t;
        let groups = cfg.groups;
        anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(nz >= 3 && ny >= 3 && nx >= 3, "grid too small for a blocked pass");
        let interior = ny - 2;
        anyhow::ensure!(
            groups == 1 || interior >= 2 * groups,
            "multi-group blocking needs >= 2 interior lines per group \
             (ny = {ny} gives {interior} interior lines for {groups} groups)"
        );
        let plane = ny * nx;
        let levels = t / 2;
        tmp.clear();
        tmp.resize(groups * levels * TMP_SLOTS * plane, 0.0);
        bnd.clear();
        bnd.resize(groups * levels * nz * 2 * nx, 0.0);
        lines.clear();
        lines.resize(groups * nx, 0.0);
        let starts: Vec<usize> = (0..=groups).map(|b| 1 + b * interior / groups).collect();
        Ok(Self {
            src: u.data_mut().as_mut_ptr(),
            f: f.data().as_ptr(),
            tmp: tmp.as_mut_ptr(),
            bnd: bnd.as_mut_ptr(),
            lines: lines.as_mut_ptr(),
            nz,
            ny,
            nx,
            t,
            groups,
            h2,
            starts,
            last_round: (nz - 2) as isize + 2 * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl Schedule for MultiGroupSchedule<'_> {
    fn workers(&self) -> usize {
        self.groups
    }

    fn worker(&self, g: usize, progress: &Progress) {
        let (nz, ny, nx, t) = (self.nz, self.ny, self.nx, self.t);
        let plane = ny * nx;
        let levels = t / 2;
        let bnd_stride = nz * 2 * nx; // per odd level
        let group_tmp = levels * TMP_SLOTS * plane;
        let group_bnd = levels * bnd_stride;
        let tmp = unsafe { self.tmp.add(g * group_tmp) };
        let bnd_own = unsafe { self.bnd.add(g * group_bnd) };
        let bnd_prev = if g > 0 {
            unsafe { self.bnd.add((g - 1) * group_bnd) as *const f64 }
        } else {
            std::ptr::null()
        };
        let src = self.src;
        let f_base = self.f;
        let b_count = self.groups;
        let block_start = self.starts[g];
        let block_end = self.starts[g + 1];

        // per-level y region of this block (clamped skew, as in the
        // serial blocked sweep)
        let region = |s: usize| -> (usize, usize) {
            let shift = s - 1;
            let lo = if g == 0 { 1 } else { block_start.saturating_sub(shift).max(1) };
            let hi = if g + 1 == b_count { ny - 1 } else { block_end.saturating_sub(shift).max(1) };
            (lo, hi)
        };

        // level-(s-1) value of line (k, y) as this group's level-s update
        // sees it: src for boundaries and even levels, own ring for odd
        // levels produced here, the previous group's boundary array for
        // the two interface lines below the region.
        let read_line = |s: usize, k: usize, y: usize| -> *const f64 {
            if k == 0 || k == nz - 1 || y == 0 || y == ny - 1 {
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let prev = s - 1;
            if prev % 2 == 0 {
                // even levels (incl. 0 = original) live in src: the
                // highest even level whose region covered this line is
                // exactly `prev`.
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let lvl = (prev - 1) / 2;
            let region_lo =
                if g == 0 { 1 } else { block_start.saturating_sub(prev - 1).max(1) };
            if y >= region_lo {
                unsafe { tmp.add((lvl * TMP_SLOTS + k % TMP_SLOTS) * plane + y * nx) as *const f64 }
            } else {
                // lines start_g - prev - 1 and start_g - prev of the
                // previous group's level-`prev` region, saved as boundary
                // index 0 / 1
                let iface_lo = block_start - prev - 1;
                debug_assert!(y == iface_lo || y == iface_lo + 1, "y={y} iface_lo={iface_lo} s={s}");
                let idx = y - iface_lo;
                unsafe { bnd_prev.add(lvl * bnd_stride + (k * 2 + idx) * nx) }
            }
        };

        // scratch line reused across every (round, level, y) iteration —
        // worker g's disjoint slice of the pool-owned line scratch, so no
        // allocation happens on the pass hot path.
        // SAFETY: slice `[g*nx, (g+1)*nx)` is written by worker g only.
        let out: &mut [f64] =
            unsafe { std::slice::from_raw_parts_mut(self.lines.add(g * nx), nx) };
        for r in 1..=self.last_round {
            if g > 0 {
                // round-lag flow control: the left neighbor is at least
                // one full round ahead (see module docs).
                progress.wait_min(g - 1, r - 1);
            }
            for s in 1..=t {
                let k = r - 2 * (s as isize - 1);
                if k < 1 || k > (nz - 2) as isize {
                    continue;
                }
                let k = k as usize;
                let (y_lo, y_hi) = region(s);
                let lvl = (s - 1) / 2; // odd-level index for writes of odd s
                for y in y_lo..y_hi {
                    // SAFETY: the round-lag protocol freezes every line the
                    // reads touch and gives this group exclusive write
                    // access to its skewed region (module docs).
                    unsafe {
                        let c = read_line(s, k, y);
                        let ym = read_line(s, k, y - 1);
                        let yp = read_line(s, k, y + 1);
                        let zm = read_line(s, k - 1, y);
                        let zp = read_line(s, k + 1, y);
                        let rhs = f_base.add((k * ny + y) * nx);
                        out[0] = *c;
                        out[nx - 1] = *c.add(nx - 1);
                        for i in 1..nx - 1 {
                            out[i] = ONE_SIXTH
                                * (*c.add(i - 1)
                                    + *c.add(i + 1)
                                    + *ym.add(i)
                                    + *yp.add(i)
                                    + *zm.add(i)
                                    + *zp.add(i)
                                    + self.h2 * *rhs.add(i));
                        }
                        if s % 2 == 1 {
                            let dst = tmp.add((lvl * TMP_SLOTS + k % TMP_SLOTS) * plane + y * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                            if g + 1 < b_count {
                                // interface lines end_g - s - 1 and
                                // end_g - s: save them for the right
                                // neighbor before the ring recycles them.
                                let iface_lo = block_end as isize - s as isize - 1;
                                let idx = y as isize - iface_lo;
                                if idx == 0 || idx == 1 {
                                    let o = bnd_own
                                        .add(lvl * bnd_stride + (k * 2 + idx as usize) * nx);
                                    std::ptr::copy_nonoverlapping(out.as_ptr(), o, nx);
                                }
                            }
                        } else {
                            let dst = src.add((k * ny + y) * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                        }
                    }
                }
            }
            progress.publish(g, r);
        }
    }
}

/// Run `passes` multi-group passes on `pool` with one schedule. All
/// scratch (plane rings, boundary arrays, per-worker x-lines) comes from
/// the pool's reusable [`Scratch`](super::pool::Scratch).
pub(crate) fn multigroup_passes(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 || passes == 0 {
        return Ok(());
    }
    let mut scratch = pool.take_scratch();
    let result = (|| -> Result<()> {
        let schedule = MultiGroupSchedule::new(
            u,
            f,
            &mut scratch.planes,
            &mut scratch.bnd,
            &mut scratch.lines,
            h2,
            cfg,
        )?;
        for _ in 0..passes {
            pool.run(&schedule)?;
        }
        Ok(())
    })();
    pool.restore_scratch(scratch);
    result
}

/// Perform exactly `cfg.t` Jacobi updates on `u` in place, `cfg.groups`
/// blocks swept concurrently on the calling thread's convenience pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn multigroup_blocked_jacobi(
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
) -> Result<()> {
    pool::with_local(|p| multigroup_passes(p, u, f, h2, cfg, 1))
}

/// [`multigroup_blocked_jacobi`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn multigroup_blocked_jacobi_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
) -> Result<()> {
    multigroup_passes(pool, u, f, h2, cfg, 1)
}

/// Run `iters` updates (a multiple of `cfg.t`) via repeated passes of one
/// persistent team.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn multigroup_blocked_jacobi_iters(
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    super::wavefront::check_iters_multiple(iters, cfg.t)?;
    pool::with_local(|p| multigroup_passes(p, u, f, h2, cfg, iters / cfg.t))
}

/// [`multigroup_blocked_jacobi_iters`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn multigroup_blocked_jacobi_iters_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    super::wavefront::check_iters_multiple(iters, cfg.t)?;
    multigroup_passes(pool, u, f, h2, cfg, iters / cfg.t)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim matrix stays covered until removal

    use super::*;
    use crate::coordinator::wavefront::serial_reference;

    fn check(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let f = Grid3::random(nz, ny, nx, 17);
        let mut u = Grid3::random(nz, ny, nx, 18);
        let want = serial_reference(&u, &f, 1.1, t);
        multigroup_blocked_jacobi(&mut u, &f, 1.1, &MultiGroupConfig { t, groups }).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} G={groups}");
    }

    #[test]
    fn single_group_matches_serial() {
        check(10, 9, 8, 2, 1);
        check(10, 9, 8, 4, 1);
        check(8, 7, 9, 6, 1);
    }

    #[test]
    fn two_groups_match_serial() {
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 9, 6, 2);
        check(8, 6, 9, 4, 2); // minimum width: two interior lines each
    }

    #[test]
    fn many_groups_match_serial() {
        check(8, 24, 8, 4, 4);
        check(8, 20, 8, 4, 8);
        check(6, 30, 7, 6, 5);
        check(6, 18, 7, 2, 7);
    }

    #[test]
    fn uneven_block_sizes() {
        // interior lines not divisible by the group count
        check(8, 13, 8, 4, 3);
        check(8, 11, 8, 2, 4);
        check(7, 17, 8, 6, 3);
    }

    #[test]
    fn deep_temporal_blocking_with_narrow_blocks() {
        // t exceeds the block width: skewed regions clamp at the domain
        // edge and some levels go empty near y = 1
        check(8, 10, 8, 8, 4);
        check(10, 8, 8, 6, 3);
    }

    #[test]
    fn iters_multiple_passes_reuse_one_team() {
        let f = Grid3::random(10, 14, 8, 5);
        let mut u = Grid3::random(10, 14, 8, 6);
        let want = serial_reference(&u, &f, 1.0, 12);
        let cfg = MultiGroupConfig { t: 4, groups: 3 };
        let mut pool = WorkerPool::new(3);
        multigroup_blocked_jacobi_iters_on(&mut pool, &mut u, &f, 1.0, &cfg, 12).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // non-multiple is an error
        let mut v = Grid3::random(10, 14, 8, 6);
        assert!(multigroup_blocked_jacobi_iters(&mut v, &f, 1.0, &cfg, 6).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let f = Grid3::zeros(8, 8, 8);
        let mut u = Grid3::random(8, 8, 8, 1);
        // odd t
        assert!(
            multigroup_blocked_jacobi(&mut u, &f, 1.0, &MultiGroupConfig { t: 3, groups: 2 })
                .is_err()
        );
        // zero groups
        assert!(
            multigroup_blocked_jacobi(&mut u, &f, 1.0, &MultiGroupConfig { t: 2, groups: 0 })
                .is_err()
        );
        // too many groups for the interior (8 - 2 = 6 lines < 2 * 4)
        assert!(
            multigroup_blocked_jacobi(&mut u, &f, 1.0, &MultiGroupConfig { t: 2, groups: 4 })
                .is_err()
        );
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        multigroup_blocked_jacobi(&mut u, &f, 1.0, &MultiGroupConfig::default()).unwrap();
        assert_eq!(u, orig);
    }
}
