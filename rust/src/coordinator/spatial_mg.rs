//! Multi-group spatial × temporal blocking — the parallel Fig. 7 scheme,
//! generic over the [`StencilOp`] kernel layer.
//!
//! [`super::spatial`] sweeps the y-blocks of the skewed decomposition one
//! after another on a single thread. Here `G` *groups* each own one
//! y-block and sweep it concurrently, time-shifted: group `g` executes
//! wavefront round `r` only after group `g-1` has completed round `r-1`.
//! The per-level update regions, the `2R+2`-slot temporary ring per odd
//! level and the `2R`-line odd-level boundary arrays are exactly those of
//! the serial blocked sweep — but the temporary ring and the boundary
//! array are per-group, and group `g` reads the boundary planes directly
//! out of group `g-1`'s array under the round-lag flow control (the
//! hand-off Wittmann et al., arXiv:1006.3148, identify as the key to
//! multi-group temporal blocking).
//!
//! ## Why a one-round lag suffices (any radius)
//!
//! All cross-group traffic sits at the block interface. For the update of
//! level `s`, plane `k` (round `r = k + (R+1)(s-1)` up to the constant
//! plane offset):
//!
//! * *flow*: every level-`s-1` value group `g` reads from group `g-1` —
//!   `src` lines for even `s-1`, boundary-array lines for odd `s-1` — was
//!   produced at plane `<= k+R`, i.e. at round `<= r-1` (the `R`-plane
//!   halo shift exactly cancels one level lag);
//! * *anti*: the deepest even level of group `g-1` that writes an
//!   interface `src` line group `g` still wants at level `s-1` *is*
//!   level `s-1` itself (deeper even levels end strictly left of it), so
//!   nothing group `g` needs is ever overwritten; conversely group `g`'s
//!   even-level `src` writes at lines group `g-1` reads happen one round
//!   *after* group `g-1`'s last read of them — guaranteed because group
//!   `g` trails by at least one round.
//!
//! The serial code's "forwarding pass" for narrow blocks has no sound
//! one-round-lag analog, so the scheme requires every block to hold at
//! least `2R` interior lines (`ny - 2R >= 2R * groups`); the constructor
//! rejects narrower decompositions.
//!
//! Result: bit-identical to `t` serial sweeps for every `(t, groups)` and
//! radius — asserted by the tests and by `launcher::run_experiment` on
//! every launch.

use std::marker::PhantomData;

use crate::config::{BlockWidthError, Scheme};
use crate::simulator::memory::StoreMode;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{StarWindow, StencilOp, MAX_RADIUS};
use crate::stencil::simd;
use crate::Result;

use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};
use super::wavefront::tmp_slots;

/// Configuration of a multi-group blocked (spatial × temporal) pass.
#[derive(Clone, Copy, Debug)]
pub struct MultiGroupConfig {
    /// Temporal blocking factor `t` (even, >= 2).
    pub t: usize,
    /// Thread groups = y blocks (>= 1; each block needs >= 2R interior
    /// lines when `groups > 1`).
    pub groups: usize,
    /// Store mode for the *final-level* (`s == t`) writes back into `u`.
    /// Earlier even levels are re-read by deeper levels and by the right
    /// neighbor group, so they always use write-allocate stores.
    pub store: StoreMode,
}

impl Default for MultiGroupConfig {
    fn default() -> Self {
        Self { t: 4, groups: 2, store: StoreMode::NonTemporal }
    }
}

impl MultiGroupConfig {
    /// Validate the grid-independent part of the configuration (single
    /// source for every entry point); the per-group width requirement
    /// needs the grid and the op radius and lives in
    /// [`MultiGroupSchedule::new`].
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.t >= 2 && self.t % 2 == 0,
            "multi-group blocking needs even t >= 2, got {}",
            self.t
        );
        anyhow::ensure!(self.groups >= 1, "need at least one group");
        Ok(())
    }
}

/// One multi-group blocked pass (`t` fused updates of `op`) as a
/// [`Schedule`]: worker `g` wavefront-sweeps y-block `g`.
pub struct MultiGroupSchedule<'g, O: StencilOp> {
    op: &'g O,
    src: *mut f64,
    f: *const f64,
    /// `groups * (t/2) * (2R+2)` z-x planes (per-group odd-level rings).
    tmp: *mut f64,
    /// `groups * (t/2) * nz * 2R` x-lines (per-group boundary arrays).
    bnd: *mut f64,
    /// `groups * nx` per-worker x-line update buffers (disjoint slices;
    /// pool-owned scratch instead of a per-pass `Vec` per worker).
    lines: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    r: usize,
    groups: usize,
    h2: f64,
    store: StoreMode,
    /// Block boundaries over the interior lines `[R, ny-R)`.
    starts: Vec<usize>,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: groups write disjoint regions (own ring, own boundary array,
// own skewed src lines); the round-lag protocol orders every cross-group
// read/write pair (module docs).
unsafe impl<O: StencilOp> Send for MultiGroupSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for MultiGroupSchedule<'_, O> {}

impl<'g, O: StencilOp> MultiGroupSchedule<'g, O> {
    /// Build a pass over `u`. `tmp`, `bnd` and `lines` are caller-owned
    /// scratch buffers (typically the pool's reusable
    /// [`Scratch`](super::pool::Scratch)), resized here; they must stay
    /// alive (and untouched) for as long as the schedule runs.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        op: &'g O,
        u: &'g mut Grid3,
        f: &'g Grid3,
        tmp: &'g mut Vec<f64>,
        bnd: &'g mut Vec<f64>,
        lines: &'g mut Vec<f64>,
        h2: f64,
        cfg: &MultiGroupConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.t;
        let groups = cfg.groups;
        let r = op.radius();
        anyhow::ensure!(r >= 1 && r <= MAX_RADIUS, "unsupported halo radius {r}");
        anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} blocked pass"
        );
        BlockWidthError::check(Scheme::JacobiMultiGroup, r, ny, groups, t)?;
        let interior = ny - 2 * r;
        let plane = ny * nx;
        let slots = tmp_slots(r);
        let levels = t / 2;
        tmp.clear();
        tmp.resize(groups * levels * slots * plane, 0.0);
        bnd.clear();
        bnd.resize(groups * levels * nz * 2 * r * nx, 0.0);
        lines.clear();
        lines.resize(groups * nx, 0.0);
        let starts: Vec<usize> = (0..=groups).map(|b| r + b * interior / groups).collect();
        let lag = (r + 1) as isize;
        Ok(Self {
            op,
            src: u.data_mut().as_mut_ptr(),
            f: f.data().as_ptr(),
            tmp: tmp.as_mut_ptr(),
            bnd: bnd.as_mut_ptr(),
            lines: lines.as_mut_ptr(),
            nz,
            ny,
            nx,
            t,
            r,
            groups,
            h2,
            store: cfg.store,
            starts,
            last_round: (nz - 2 * r) as isize + lag * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for MultiGroupSchedule<'_, O> {
    fn workers(&self) -> usize {
        self.groups
    }

    fn worker(&self, g: usize, progress: &Progress) {
        let (nz, ny, nx, t, r) = (self.nz, self.ny, self.nx, self.t, self.r);
        let plane = ny * nx;
        let slots = tmp_slots(r);
        let lag = (r + 1) as isize;
        let levels = t / 2;
        let bnd_stride = nz * 2 * r * nx; // per odd level
        let group_tmp = levels * slots * plane;
        let group_bnd = levels * bnd_stride;
        let tmp = unsafe { self.tmp.add(g * group_tmp) };
        let bnd_own = unsafe { self.bnd.add(g * group_bnd) };
        let bnd_prev = if g > 0 {
            unsafe { self.bnd.add((g - 1) * group_bnd) as *const f64 }
        } else {
            std::ptr::null()
        };
        let src = self.src;
        let f_base = self.f;
        let b_count = self.groups;
        let block_start = self.starts[g];
        let block_end = self.starts[g + 1];

        // per-level y region of this block (clamped skew, as in the
        // serial blocked sweep)
        let region = |s: usize| -> (usize, usize) {
            let shift = r * (s - 1);
            let lo = if g == 0 { r } else { block_start.saturating_sub(shift).max(r) };
            let hi = if g + 1 == b_count { ny - r } else { block_end.saturating_sub(shift).max(r) };
            (lo, hi)
        };

        // level-(s-1) value of line (k, y) as this group's level-s update
        // sees it: src for boundaries and even levels, own ring for odd
        // levels produced here, the previous group's boundary array for
        // the 2R interface lines below the region.
        let read_line = |s: usize, k: usize, y: usize| -> *const f64 {
            if k < r || k >= nz - r || y < r || y >= ny - r {
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let prev = s - 1;
            if prev % 2 == 0 {
                // even levels (incl. 0 = original) live in src: the
                // highest even level whose region covered this line is
                // exactly `prev`.
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let lvl = (prev - 1) / 2;
            let region_lo =
                if g == 0 { r } else { block_start.saturating_sub(r * (prev - 1)).max(r) };
            if y >= region_lo {
                unsafe { tmp.add((lvl * slots + k % slots) * plane + y * nx) as *const f64 }
            } else {
                // the 2R lines [start_g - R·prev - R, start_g - R·(prev-1))
                // of the previous group's level-`prev` region, saved as
                // boundary indices 0..2R
                let iface_lo = block_start as isize - (r * prev + r) as isize;
                let idx = (y as isize - iface_lo) as usize;
                debug_assert!(idx < 2 * r, "y={y} iface_lo={iface_lo} s={s} r={r}");
                unsafe { bnd_prev.add(lvl * bnd_stride + (k * 2 * r + idx) * nx) }
            }
        };

        // scratch line reused across every (round, level, y) iteration —
        // worker g's disjoint slice of the pool-owned line scratch, so no
        // allocation happens on the pass hot path.
        // SAFETY: slice `[g*nx, (g+1)*nx)` is written by worker g only.
        let out: &mut [f64] =
            unsafe { std::slice::from_raw_parts_mut(self.lines.add(g * nx), nx) };
        for round in 1..=self.last_round {
            if g > 0 {
                // round-lag flow control: the left neighbor is at least
                // one full round ahead (see module docs).
                progress.wait_min(g - 1, round - 1);
            }
            for s in 1..=t {
                let k = round + (r as isize - 1) - lag * (s as isize - 1);
                if k < r as isize || k > (nz - 1 - r) as isize {
                    continue;
                }
                let k = k as usize;
                let (y_lo, y_hi) = region(s);
                let lvl = (s - 1) / 2; // odd-level index for writes of odd s
                for y in y_lo..y_hi {
                    // SAFETY: the round-lag protocol freezes every line the
                    // reads touch and gives this group exclusive write
                    // access to its skewed region (module docs).
                    unsafe {
                        let line = |p: *const f64| std::slice::from_raw_parts(p, nx);
                        let c = line(read_line(s, k, y));
                        let win = StarWindow::from_fn(c, r, |dz, dy| {
                            let kk = (k as isize + dz) as usize;
                            let yy = (y as isize + dy) as usize;
                            line(read_line(s, kk, yy))
                        });
                        let rhs = std::slice::from_raw_parts(f_base.add((k * ny + y) * nx), nx);
                        crate::stencil::op::copy_x_edges(out, c, r);
                        // `out` is reused scratch every iteration — always
                        // write-allocate; streaming happens on the final
                        // copy back into `u` below.
                        self.op.line_update(out, &win, rhs, self.h2, k, y, StoreMode::WriteAllocate);
                        if s % 2 == 1 {
                            let dst = tmp.add((lvl * slots + k % slots) * plane + y * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                            if g + 1 < b_count {
                                // interface lines [end_g - R·s - R,
                                // end_g - R·(s-1)): save them for the
                                // right neighbor before the ring recycles
                                // them.
                                let iface_lo = block_end as isize - (r * s + r) as isize;
                                let idx = y as isize - iface_lo;
                                if (0..2 * r as isize).contains(&idx) {
                                    let o = bnd_own
                                        .add(lvl * bnd_stride + (k * 2 * r + idx as usize) * nx);
                                    std::ptr::copy_nonoverlapping(out.as_ptr(), o, nx);
                                }
                            }
                        } else if s == t {
                            // final level: nothing re-reads these lines
                            // within the pass, so honor the configured
                            // store mode (streaming skips write-allocate).
                            let dst = std::slice::from_raw_parts_mut(src.add((k * ny + y) * nx), nx);
                            simd::stream_copy(dst, out, self.store);
                        } else {
                            // intermediate even levels are re-read by
                            // deeper levels and the right neighbor group:
                            // keep them cache-resident.
                            let dst = src.add((k * ny + y) * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                        }
                    }
                }
            }
            progress.publish(g, round);
        }
    }
}

/// Run `passes` multi-group passes of `op` on `pool` with one schedule —
/// the entry point the [`SchemeRunner`] registry, tests and benches
/// drive. All scratch (plane rings, boundary arrays, per-worker
/// x-lines) comes from the dispatcher's reusable
/// [`Scratch`](super::pool::Scratch) arena, returned by the RAII guard
/// even when a sweep panics.
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn multigroup_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &MultiGroupConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    let mut scratch = pool.scratch();
    // split the guard once so the three arenas borrow disjointly
    let s = &mut *scratch;
    let schedule =
        MultiGroupSchedule::new(op, u, f, &mut s.planes, &mut s.bnd, &mut s.lines, h2, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::coordinator::wavefront::{check_iters_multiple, serial_reference, serial_reference_op};
    use crate::stencil::op::{ConstLaplace7, Laplace13, VarCoeff7};

    fn run_mg<O: StencilOp>(
        op: &O,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &MultiGroupConfig,
        passes: usize,
    ) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        multigroup_passes(&mut pool, op, u, f, h2, cfg, passes)
    }

    fn check(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let f = Grid3::random(nz, ny, nx, 17);
        let mut u = Grid3::random(nz, ny, nx, 18);
        let want = serial_reference(&u, &f, 1.1, t);
        run_mg(&ConstLaplace7, &mut u, &f, 1.1, &MultiGroupConfig { t, groups , ..Default::default() }, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} G={groups}");
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let f = Grid3::random(nz, ny, nx, 27);
        let mut u = Grid3::random(nz, ny, nx, 28);
        let want = serial_reference_op(&Laplace13, &u, &f, 1.1, t);
        run_mg(&Laplace13, &mut u, &f, 1.1, &MultiGroupConfig { t, groups , ..Default::default() }, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 {nz}x{ny}x{nx} t={t} G={groups}");
    }

    #[test]
    fn single_group_matches_serial() {
        check(10, 9, 8, 2, 1);
        check(10, 9, 8, 4, 1);
        check(8, 7, 9, 6, 1);
    }

    #[test]
    fn two_groups_match_serial() {
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 9, 6, 2);
        check(8, 6, 9, 4, 2); // minimum width: two interior lines each
    }

    #[test]
    fn many_groups_match_serial() {
        check(8, 24, 8, 4, 4);
        check(8, 20, 8, 4, 8);
        check(6, 30, 7, 6, 5);
        check(6, 18, 7, 2, 7);
    }

    #[test]
    fn uneven_block_sizes() {
        // interior lines not divisible by the group count
        check(8, 13, 8, 4, 3);
        check(8, 11, 8, 2, 4);
        check(7, 17, 8, 6, 3);
    }

    #[test]
    fn deep_temporal_blocking_with_narrow_blocks() {
        // t exceeds the block width: skewed regions clamp at the domain
        // edge and some levels go empty near y = 1
        check(8, 10, 8, 8, 4);
        check(10, 8, 8, 6, 3);
    }

    #[test]
    fn radius2_groups_match_serial() {
        check_r2(10, 13, 9, 2, 2); // minimum width: 4 interior lines each + 1
        check_r2(10, 12, 9, 2, 2);
        check_r2(10, 16, 9, 4, 2);
        check_r2(9, 20, 8, 4, 2);
        check_r2(9, 25, 8, 2, 3);
        check_r2(11, 28, 8, 6, 3);
    }

    #[test]
    fn varcoeff_groups_match_serial() {
        let op = VarCoeff7::default_for((9, 14, 8));
        let f = Grid3::random(9, 14, 8, 33);
        let mut u = Grid3::random(9, 14, 8, 34);
        let want = serial_reference_op(&op, &u, &f, 0.9, 4);
        run_mg(&op, &mut u, &f, 0.9, &MultiGroupConfig { t: 4, groups: 3 , ..Default::default() }, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn iters_multiple_passes_reuse_one_team() {
        let f = Grid3::random(10, 14, 8, 5);
        let mut u = Grid3::random(10, 14, 8, 6);
        let want = serial_reference(&u, &f, 1.0, 12);
        let cfg = MultiGroupConfig { t: 4, groups: 3 , ..Default::default() };
        check_iters_multiple(12, cfg.t).unwrap();
        let mut pool = WorkerPool::new(3);
        multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &cfg, 3).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // non-multiple is an error at the iters layer
        assert!(check_iters_multiple(6, cfg.t).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let f = Grid3::zeros(8, 8, 8);
        let mut u = Grid3::random(8, 8, 8, 1);
        // odd t
        assert!(run_mg(&ConstLaplace7, &mut u, &f, 1.0, &MultiGroupConfig { t: 3, groups: 2 , ..Default::default() }, 1)
            .is_err());
        // zero groups
        assert!(run_mg(&ConstLaplace7, &mut u, &f, 1.0, &MultiGroupConfig { t: 2, groups: 0 , ..Default::default() }, 1)
            .is_err());
        // too many groups for the interior (8 - 2 = 6 lines < 2 * 4):
        // the typed BlockWidthError, same as RunConfig::validate raises
        let err = run_mg(&ConstLaplace7, &mut u, &f, 1.0, &MultiGroupConfig { t: 2, groups: 4 , ..Default::default() }, 1)
            .unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed width error");
        assert_eq!((typed.required, typed.groups), (2, 4));
        // radius-2: 12 - 4 = 8 interior lines < 4 * 3 groups
        let mut v = Grid3::random(8, 12, 8, 2);
        let fv = Grid3::zeros(8, 12, 8);
        assert!(run_mg(&Laplace13, &mut v, &fv, 1.0, &MultiGroupConfig { t: 2, groups: 3 , ..Default::default() }, 1)
            .is_err());
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        run_mg(&ConstLaplace7, &mut u, &f, 1.0, &MultiGroupConfig::default(), 1).unwrap();
        assert_eq!(u, orig);
    }
}
