//! Persistent worker pool: one thread team, created once, reused across
//! passes, iterations and experiments.
//!
//! The paper's temporal-blocking schemes live on cheap, repeated
//! coordination of a *fixed* thread team (Sec. 4; also Wittmann et al.,
//! arXiv:1006.3148). Spawning a fresh `std::thread::scope` team per pass
//! — what every coordinator here used to do — pays thread creation,
//! stack setup and scheduler migration on every pass, which dwarfs the
//! plane-level synchronization the schemes optimize. [`WorkerPool`] keeps
//! the team parked between passes instead: dispatching a
//! [`Schedule`](super::schedule::Schedule) costs one condvar broadcast,
//! and the team grows on demand when a schedule needs more workers
//! (team-size reconfiguration without losing the existing threads).
//!
//! `benches/bench_pool.rs` measures respawn-per-pass vs persistent-pool
//! MLUP/s; `tests/pool_reuse.rs` asserts bit-exactness when one pool
//! instance is reused across schemes, passes and team sizes.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::schedule::{Progress, Schedule};

/// Reusable scratch buffers owned by the pool, handed to schedule
/// constructors instead of per-pass `Vec` allocations (ROADMAP item:
/// the x-line scratch of `spatial_mg::worker` and the temporary plane
/// rings used to reallocate on every entry-point call).
///
/// Buffers are taken out with [`WorkerPool::take_scratch`] while a
/// schedule borrows them (the pool itself stays mutably usable for
/// dispatch) and handed back with [`WorkerPool::restore_scratch`], so
/// capacity survives across passes, schemes and
/// [`Solver::run`](super::solver::Solver::run) calls.
#[derive(Default)]
pub struct Scratch {
    /// Temporary z-x plane rings (wavefront / multi-group odd levels).
    pub planes: Vec<f64>,
    /// Per-level boundary arrays (multi-group interface hand-off: odd
    /// levels for the Jacobi scheme, every non-final level for GS).
    pub bnd: Vec<f64>,
    /// Per-worker x-line buffers (`workers * nx`, disjoint slices).
    pub lines: Vec<f64>,
}

/// Per-worker start hook, called once with the worker id when the thread
/// starts — the place to pin the worker to a core (e.g. via
/// `sched_setaffinity` on Linux) or tag it for profiling.
pub type StartHook = Arc<dyn Fn(usize) + Send + Sync + 'static>;

/// Type-erased dispatch record for one pass.
#[derive(Clone, Copy)]
struct Job {
    /// The schedule under execution. The borrow is lifetime-erased; this
    /// is sound because [`WorkerPool::run`] blocks until every worker has
    /// acknowledged the epoch, so the pointer never outlives the borrow
    /// it was created from.
    schedule: *const (dyn Schedule + 'static),
    /// Team size of this pass; pool workers with `id >= workers` just
    /// acknowledge the epoch and go back to sleep.
    workers: usize,
    /// The pool-owned progress table (reset before dispatch).
    progress: *const Progress,
}

// SAFETY: the pointers reference a `Schedule: Sync` and a `Progress`
// (atomics) that outlive the pass; see the field docs above.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per dispatched pass (and on shutdown) to wake workers.
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not yet acknowledged the current epoch.
    active: usize,
    /// Captured panic messages of the current pass.
    panics: Vec<String>,
    shutdown: bool,
}

struct Control {
    state: Mutex<State>,
    /// Signaled when a new epoch (or shutdown) is published.
    go: Condvar,
    /// Signaled when `active` reaches zero.
    done: Condvar,
}

/// Best-effort extraction of a panic payload's message (shared with the
/// launcher's sweep fan-out).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(control: Arc<Control>, id: usize, mut seen: u64, hook: Option<StartHook>) {
    if let Some(h) = hook {
        // a dead worker would deadlock every later dispatch, so a hook
        // failure must not kill the thread
        if catch_unwind(AssertUnwindSafe(|| h(id))).is_err() {
            eprintln!("stencilwave-pool-{id}: start hook panicked; worker continues unpinned");
        }
    }
    loop {
        let job = {
            let mut st = control.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch bumped without a job");
                }
                st = control.go.wait(st).unwrap();
            }
        };
        if id < job.workers {
            // SAFETY: `run` keeps the schedule and progress table alive
            // until every worker acknowledges this epoch (below).
            let schedule = unsafe { &*job.schedule };
            let progress = unsafe { &*job.progress };
            let result = catch_unwind(AssertUnwindSafe(|| schedule.worker(id, progress)));
            if let Err(payload) = result {
                // abort peers spinning on watermarks this worker will
                // never publish (they drain via Progress::wait_min's
                // poison panic, which lands right back here)
                progress.poison();
                let msg = panic_message(payload.as_ref());
                let mut st = control.state.lock().unwrap();
                st.panics.push(format!("worker {id}: {msg}"));
                st.active -= 1;
                if st.active == 0 {
                    control.done.notify_all();
                }
                continue;
            }
        }
        let mut st = control.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            control.done.notify_all();
        }
    }
}

/// A persistent team of worker threads executing [`Schedule`] passes.
pub struct WorkerPool {
    control: Arc<Control>,
    handles: Vec<JoinHandle<()>>,
    progress: Progress,
    hook: Option<StartHook>,
    scratch: Scratch,
}

impl WorkerPool {
    /// A pool with `size` persistent workers. `size` may be 0: the pool
    /// grows on demand to fit each dispatched schedule.
    pub fn new(size: usize) -> Self {
        let control = Arc::new(Control {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panics: Vec::new(),
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let mut pool = Self {
            control,
            handles: Vec::new(),
            progress: Progress::new(0),
            hook: None,
            scratch: Scratch::default(),
        };
        pool.ensure_workers(size);
        pool
    }

    /// Take the pool's scratch arena out for the duration of a schedule
    /// (hand it back with [`WorkerPool::restore_scratch`] so buffer
    /// capacity is reused by later passes).
    pub fn take_scratch(&mut self) -> Scratch {
        std::mem::take(&mut self.scratch)
    }

    /// Return a scratch arena taken with [`WorkerPool::take_scratch`].
    pub fn restore_scratch(&mut self, scratch: Scratch) {
        self.scratch = scratch;
    }

    /// Install a per-worker start hook (e.g. core pinning). Applies to
    /// workers spawned afterwards, so install it before the first run.
    pub fn set_start_hook(&mut self, hook: StartHook) {
        self.hook = Some(hook);
    }

    /// Remove a previously installed start hook: workers spawned from now
    /// on start unpinned/untagged. Needed when a pool moves between
    /// sessions with different pin policies, so a session requesting no
    /// pinning does not apply the previous session's hook to *new*
    /// workers. (Workers already spawned keep their placement — hooks
    /// run once, at thread start.)
    pub fn clear_start_hook(&mut self) {
        self.hook = None;
    }

    /// Current team size.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Grow the team to at least `n` workers (no-op when already larger).
    pub fn ensure_workers(&mut self, n: usize) {
        let epoch = self.control.state.lock().unwrap().epoch;
        while self.handles.len() < n {
            let id = self.handles.len();
            let control = Arc::clone(&self.control);
            let hook = self.hook.clone();
            let handle = std::thread::Builder::new()
                .name(format!("stencilwave-pool-{id}"))
                .spawn(move || worker_loop(control, id, epoch, hook))
                .expect("spawn pool worker");
            self.handles.push(handle);
        }
    }

    /// Execute one pass of `schedule` on the team, blocking until every
    /// worker finishes. Grows the team if the schedule needs more workers
    /// than the pool currently holds; workers beyond the schedule's team
    /// size stay parked.
    ///
    /// Worker panics are captured and surfaced as an error and the pool
    /// itself survives them: the pass is poisoned so peers blocked in
    /// [`Progress::wait_min`] abort instead of spinning forever. (A
    /// schedule that synchronizes through a raw barrier instead of the
    /// progress table — the wavefront's `SyncMode::Barrier` — can still
    /// stall if a worker dies *between* barrier rounds; the progress
    /// protocol is the panic-safe path.)
    pub fn run(&mut self, schedule: &dyn Schedule) -> Result<()> {
        let n = schedule.workers();
        anyhow::ensure!(n >= 1, "schedule needs at least one worker");
        self.ensure_workers(n);
        let slots = schedule.progress_slots();
        if self.progress.len() < slots {
            self.progress = Progress::new(slots);
        }
        self.progress.reset();

        // Erase the borrow lifetime; sound because this function does not
        // return until every worker has acknowledged the epoch.
        let short: *const (dyn Schedule + '_) = schedule;
        let erased: *const (dyn Schedule + 'static) = unsafe { std::mem::transmute(short) };
        let job = Job { schedule: erased, workers: n, progress: &self.progress };

        let mut st = self.control.state.lock().unwrap();
        debug_assert!(st.job.is_none() && st.active == 0, "pool dispatched re-entrantly");
        st.job = Some(job);
        st.active = self.handles.len();
        st.epoch = st.epoch.wrapping_add(1);
        self.control.go.notify_all();
        while st.active > 0 {
            st = self.control.done.wait(st).unwrap();
        }
        st.job = None;
        let panics = std::mem::take(&mut st.panics);
        drop(st);
        anyhow::ensure!(panics.is_empty(), "schedule worker(s) panicked: {}", panics.join("; "));
        Ok(())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.control.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            self.control.go.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

thread_local! {
    /// One convenience pool per calling thread (grown on demand, parked
    /// between calls, joined when the thread exits).
    static LOCAL: RefCell<WorkerPool> = RefCell::new(WorkerPool::new(0));
}

/// Run `f` with the calling thread's convenience pool. Each caller
/// thread owns its own team, so concurrent callers run truly side by
/// side instead of serializing on a process mutex; repeated calls from
/// one thread still amortize one set of threads. Applications that fan
/// out over many of their own threads should hold an explicitly owned
/// team via a [`Solver`](super::solver::Solver) session instead.
///
/// (The 0.2.0 `with_global` shim — one process-wide mutexed team — was
/// removed in 0.3.0 along with the free-function scheme matrix.)
///
/// # Panics
/// When re-entered from within `f` (the per-thread pool is exclusively
/// borrowed while a pass runs).
pub fn with_local<R>(f: impl FnOnce(&mut WorkerPool) -> R) -> R {
    LOCAL.with(|p| f(&mut p.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct CountSchedule {
        hits: Vec<AtomicUsize>,
    }

    impl CountSchedule {
        fn new(n: usize) -> Self {
            Self { hits: (0..n).map(|_| AtomicUsize::new(0)).collect() }
        }
    }

    impl Schedule for CountSchedule {
        fn workers(&self) -> usize {
            self.hits.len()
        }
        fn worker(&self, id: usize, _progress: &Progress) {
            self.hits[id].fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Workers hand off through the progress table; the recorded order
    /// must be 0..n every pass — which only holds if the pool resets the
    /// table between passes.
    struct ChainSchedule {
        n: usize,
        order: Mutex<Vec<usize>>,
    }

    impl Schedule for ChainSchedule {
        fn workers(&self) -> usize {
            self.n
        }
        fn worker(&self, id: usize, progress: &Progress) {
            if id > 0 {
                progress.wait_min(id - 1, 1);
            }
            self.order.lock().unwrap().push(id);
            progress.publish(id, 1);
        }
    }

    struct PanicSchedule;

    impl Schedule for PanicSchedule {
        fn workers(&self) -> usize {
            2
        }
        fn worker(&self, id: usize, _progress: &Progress) {
            if id == 1 {
                panic!("boom from worker {id}");
            }
        }
    }

    #[test]
    fn all_workers_run_every_pass() {
        let mut pool = WorkerPool::new(3);
        let sched = CountSchedule::new(3);
        for _ in 0..5 {
            pool.run(&sched).unwrap();
        }
        for h in &sched.hits {
            assert_eq!(h.load(Ordering::SeqCst), 5);
        }
    }

    #[test]
    fn pool_grows_on_demand_and_larger_teams_idle() {
        let mut pool = WorkerPool::new(1);
        pool.run(&CountSchedule::new(4)).unwrap();
        assert_eq!(pool.size(), 4);
        // smaller schedule on the grown pool: extra workers idle
        let small = CountSchedule::new(2);
        pool.run(&small).unwrap();
        assert_eq!(small.hits[0].load(Ordering::SeqCst), 1);
        assert_eq!(small.hits[1].load(Ordering::SeqCst), 1);
        assert_eq!(pool.size(), 4);
    }

    #[test]
    fn progress_is_reset_between_passes() {
        let mut pool = WorkerPool::new(4);
        let sched = ChainSchedule { n: 4, order: Mutex::new(Vec::new()) };
        for pass in 0..10 {
            pool.run(&sched).unwrap();
            let mut order = sched.order.lock().unwrap();
            assert_eq!(*order, vec![0, 1, 2, 3], "pass {pass}");
            order.clear();
        }
    }

    #[test]
    fn worker_panic_is_captured_and_pool_survives() {
        let mut pool = WorkerPool::new(2);
        let err = pool.run(&PanicSchedule).unwrap_err().to_string();
        assert!(err.contains("boom"), "{err}");
        // the pool is still usable after the failed pass
        let sched = CountSchedule::new(2);
        pool.run(&sched).unwrap();
        assert_eq!(sched.hits[0].load(Ordering::SeqCst), 1);
    }

    /// Worker 0 dies before publishing anything; workers 1 and 2 wait on
    /// it. Without poisoning this deadlocks `run` forever.
    struct PanicChainSchedule;

    impl Schedule for PanicChainSchedule {
        fn workers(&self) -> usize {
            3
        }
        fn worker(&self, id: usize, progress: &Progress) {
            if id == 0 {
                panic!("chain head died");
            }
            progress.wait_min(id - 1, 1);
            progress.publish(id, 1);
        }
    }

    #[test]
    fn panic_poisons_waiting_peers_instead_of_deadlocking() {
        let mut pool = WorkerPool::new(3);
        let err = pool.run(&PanicChainSchedule).unwrap_err().to_string();
        assert!(err.contains("chain head died"), "{err}");
        // poison is cleared by the next pass's reset
        let sched = ChainSchedule { n: 3, order: Mutex::new(Vec::new()) };
        pool.run(&sched).unwrap();
        assert_eq!(*sched.order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn empty_schedule_is_rejected() {
        let mut pool = WorkerPool::new(1);
        assert!(pool.run(&CountSchedule::new(0)).is_err());
    }

    #[test]
    fn start_hook_sees_every_worker() {
        let seen = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(0);
        let s = Arc::clone(&seen);
        pool.set_start_hook(Arc::new(move |_id| {
            s.fetch_add(1, Ordering::SeqCst);
        }));
        pool.run(&CountSchedule::new(3)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn cleared_start_hook_does_not_reach_new_workers() {
        let seen = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(0);
        let s = Arc::clone(&seen);
        pool.set_start_hook(Arc::new(move |_id| {
            s.fetch_add(1, Ordering::SeqCst);
        }));
        pool.run(&CountSchedule::new(2)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 2);
        // a pool handed to a session with PinPolicy::None must not keep
        // applying the previous session's hook to workers spawned later
        pool.clear_start_hook();
        pool.run(&CountSchedule::new(4)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 2, "cleared hook leaked to new workers");
    }
}
