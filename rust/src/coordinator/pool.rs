//! Persistent worker pool: one thread team, created once, reused across
//! passes, iterations, experiments — and, since the multi-tenant
//! service, across *concurrent* solver sessions.
//!
//! The paper's temporal-blocking schemes live on cheap, repeated
//! coordination of a *fixed* thread team (Sec. 4; also Wittmann et al.,
//! arXiv:1006.3148). Spawning a fresh `std::thread::scope` team per pass
//! — what every coordinator here used to do — pays thread creation,
//! stack setup and scheduler migration on every pass, which dwarfs the
//! plane-level synchronization the schemes optimize. [`WorkerPool`] keeps
//! the team parked between passes instead: dispatching a
//! [`Schedule`](super::schedule::Schedule) costs one condvar broadcast,
//! and the team grows on demand when a schedule needs more workers
//! (team-size reconfiguration without losing the existing threads).
//!
//! Dispatch is *segmented*: a pass occupies a contiguous window of pool
//! workers, and windows that do not overlap execute truly concurrently.
//! [`PoolSegment`] is a handle to one such window — its own
//! [`Progress`] table and its own [`Scratch`] arena, so two-plus
//! [`Solver`](super::solver::Solver) sessions can share one pool without
//! contending on anything but the workers themselves. That is the
//! substrate the multi-tenant [`SolverService`](super::service) packs
//! cache-group jobs onto. Workers claim pending passes in submission
//! order, which keeps overlapping windows deadlock-free even for
//! schedules with two-sided watermark waits.
//!
//! `benches/bench_pool.rs` measures respawn-per-pass vs persistent-pool
//! MLUP/s; `tests/pool_reuse.rs` asserts bit-exactness when one pool
//! instance is reused across schemes, passes and team sizes.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::schedule::{Progress, Schedule};

/// Reusable scratch buffers handed to schedule constructors instead of
/// per-pass `Vec` allocations (the x-line scratch of
/// `spatial_mg::worker` and the temporary plane rings used to
/// reallocate on every entry-point call).
///
/// An arena is borrowed through a [`ScratchGuard`] (see
/// [`Dispatch::scratch`]); the guard returns the buffers on drop — on
/// the success path *and* during a panic unwind — so capacity survives
/// across passes, schemes, [`Solver::run`](super::solver::Solver::run)
/// calls and failed jobs alike. Each [`PoolSegment`] owns its own slot,
/// so concurrent sessions on one pool never fight over one arena.
#[derive(Default)]
pub struct Scratch {
    /// Temporary z-x plane rings (wavefront / multi-group odd levels).
    pub planes: Vec<f64>,
    /// Per-level boundary arrays (multi-group interface hand-off: odd
    /// levels for the Jacobi scheme, every non-final level for GS).
    pub bnd: Vec<f64>,
    /// Per-worker x-line buffers (`workers * nx`, disjoint slices).
    pub lines: Vec<f64>,
}

/// Where a checked-out [`Scratch`] arena goes back to when its
/// [`ScratchGuard`] drops.
type ScratchSlot = Arc<Mutex<Option<Scratch>>>;

/// RAII checkout of a [`Scratch`] arena. Dereferences to the arena;
/// hands the buffers back to their slot on drop, so a panicking sweep
/// cannot leak the arena and starve the next session on a shared pool
/// (the old `take_scratch`/`restore_scratch` pair did exactly that when
/// a schedule constructor or `run` unwound between the two calls).
pub struct ScratchGuard {
    data: Scratch,
    slot: ScratchSlot,
}

impl ScratchGuard {
    fn checkout(slot: &ScratchSlot) -> Self {
        // a poisoned mutex only means a peer panicked while holding it;
        // the arena itself is plain buffers, so keep going
        let data =
            slot.lock().unwrap_or_else(|e| e.into_inner()).take().unwrap_or_default();
        Self { data, slot: Arc::clone(slot) }
    }
}

impl Deref for ScratchGuard {
    type Target = Scratch;
    fn deref(&self) -> &Scratch {
        &self.data
    }
}

impl DerefMut for ScratchGuard {
    fn deref_mut(&mut self) -> &mut Scratch {
        &mut self.data
    }
}

impl Drop for ScratchGuard {
    fn drop(&mut self) {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        *slot = Some(std::mem::take(&mut self.data));
    }
}

/// Per-worker start hook, called once with the worker id when the thread
/// starts — the place to pin the worker to a core (e.g. via
/// `sched_setaffinity` on Linux) or tag it for profiling.
pub type StartHook = Arc<dyn Fn(usize) + Send + Sync + 'static>;

/// Type-erased dispatch record for one in-flight pass on a worker
/// window.
struct SegJob {
    /// Monotonic submission id. Workers claim pending slots in token
    /// order, which serializes overlapping windows FIFO and keeps the
    /// claim graph acyclic (no deadlock between two-sided watermark
    /// protocols on shared workers).
    token: u64,
    /// First pool worker id of the job's window.
    start: usize,
    /// Window width = the schedule's team size; pool worker
    /// `start + local` executes schedule slot `local`.
    workers: usize,
    /// The schedule under execution. The borrow is lifetime-erased;
    /// this is sound because the dispatching call blocks until the job
    /// leaves the list (every slot finished, or — on shutdown — every
    /// claimed slot finished and the rest provably never claimed), so
    /// the pointer never outlives the borrow it was created from.
    schedule: *const (dyn Schedule + 'static),
    /// The dispatcher-owned progress table (reset before dispatch;
    /// alive for exactly as long as `schedule`).
    progress: *const Progress,
    /// Which local slots a worker has claimed.
    claimed: Vec<bool>,
    /// Claimed-but-not-finished slots (shutdown drain accounting).
    in_flight: usize,
    /// Slots not yet finished, claimed or not.
    remaining: usize,
    /// Captured panic messages of this pass.
    panics: Vec<String>,
}

// SAFETY: the pointers reference a `Schedule: Sync` and a `Progress`
// (atomics) that outlive the pass; see the field docs above.
unsafe impl Send for SegJob {}

struct State {
    /// Every in-flight pass, newest last (completion uses swap_remove,
    /// so list position is not ordered — `token` is).
    jobs: Vec<SegJob>,
    next_token: u64,
    shutdown: bool,
}

struct Control {
    state: Mutex<State>,
    /// Signaled when a job is published (or on shutdown).
    go: Condvar,
    /// Signaled when a job's last slot finishes (or on shutdown).
    done: Condvar,
}

impl Control {
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Best-effort extraction of a panic payload's message (shared with the
/// launcher's sweep fan-out).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(control: Arc<Control>, id: usize, hook: Option<StartHook>) {
    if let Some(h) = hook {
        // a dead worker would deadlock every later dispatch, so a hook
        // failure must not kill the thread
        if catch_unwind(AssertUnwindSafe(|| h(id))).is_err() {
            eprintln!("stencilwave-pool-{id}: start hook panicked; worker continues unpinned");
        }
    }
    let mut st = control.lock();
    loop {
        if st.shutdown {
            return;
        }
        // claim this worker's slot of the oldest pending job that wants
        // it (token order — see `SegJob::token`)
        let mut pick: Option<(u64, usize)> = None;
        for job in st.jobs.iter() {
            if id >= job.start && id < job.start + job.workers && !job.claimed[id - job.start] {
                match pick {
                    Some((token, _)) if token <= job.token => {}
                    _ => pick = Some((job.token, id - job.start)),
                }
            }
        }
        let Some((token, local)) = pick else {
            st = control.go.wait(st).unwrap_or_else(|e| e.into_inner());
            continue;
        };
        let (schedule, progress) = {
            let job = st.jobs.iter_mut().find(|j| j.token == token).expect("picked job listed");
            job.claimed[local] = true;
            job.in_flight += 1;
            (job.schedule, job.progress)
        };
        drop(st);
        // SAFETY: the dispatcher keeps both alive until this job leaves
        // the list, which cannot happen before `in_flight` drops back
        // (below).
        let schedule = unsafe { &*schedule };
        let progress = unsafe { &*progress };
        let result = catch_unwind(AssertUnwindSafe(|| schedule.worker(local, progress)));
        if result.is_err() {
            // abort peers spinning on watermarks this worker will never
            // publish (they drain via Progress::wait_min's poison
            // panic, which lands right back here)
            progress.poison();
        }
        st = control.lock();
        let job = st.jobs.iter_mut().find(|j| j.token == token).expect("job vanished mid-pass");
        if let Err(payload) = result {
            job.panics.push(format!("worker {local}: {}", panic_message(payload.as_ref())));
        }
        job.in_flight -= 1;
        job.remaining -= 1;
        if job.remaining == 0 || st.shutdown {
            control.done.notify_all();
        }
    }
}

/// Publish one pass of `schedule` on workers `start..start + workers()`
/// and block until every slot has finished. The caller owns `progress`
/// (already sized and reset) and must keep both borrows alive for the
/// duration of this call — which it does, by being a call.
fn dispatch(control: &Control, schedule: &dyn Schedule, start: usize, progress: &Progress) -> Result<()> {
    let n = schedule.workers();
    anyhow::ensure!(n >= 1, "schedule needs at least one worker");

    // Erase the borrow lifetime; sound because this function does not
    // return while the job is listed (see SegJob::schedule).
    let short: *const (dyn Schedule + '_) = schedule;
    let erased: *const (dyn Schedule + 'static) = unsafe { std::mem::transmute(short) };

    let mut st = control.lock();
    anyhow::ensure!(!st.shutdown, "worker pool is shut down");
    let token = st.next_token;
    st.next_token += 1;
    st.jobs.push(SegJob {
        token,
        start,
        workers: n,
        schedule: erased,
        progress,
        claimed: vec![false; n],
        in_flight: 0,
        remaining: n,
        panics: Vec::new(),
    });
    control.go.notify_all();
    loop {
        let idx = st.jobs.iter().position(|j| j.token == token).expect("own job listed");
        if st.jobs[idx].remaining == 0 {
            let job = st.jobs.swap_remove(idx);
            drop(st);
            anyhow::ensure!(
                job.panics.is_empty(),
                "schedule worker(s) panicked: {}",
                job.panics.join("; ")
            );
            return Ok(());
        }
        if st.shutdown && st.jobs[idx].in_flight == 0 {
            // the pool dropped under us: no worker holds the schedule
            // borrow and (workers check shutdown before claiming) none
            // ever will, so the borrow may end here
            st.jobs.swap_remove(idx);
            drop(st);
            anyhow::bail!("worker pool shut down mid-pass");
        }
        st = control.done.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// Anything that can execute [`Schedule`] passes and lend a [`Scratch`]
/// arena: a whole [`WorkerPool`] or one [`PoolSegment`] window of it.
/// The schedule entry points and [`SchemeRunner::execute`] take
/// `&mut dyn Dispatch`, so a solver session bound to a segment shares
/// its pool with concurrent tenants transparently.
///
/// [`SchemeRunner::execute`]: super::runner::SchemeRunner::execute
pub trait Dispatch {
    /// Execute one pass of `schedule`, blocking until every worker
    /// finishes. Worker panics are captured and surfaced as an error;
    /// the dispatcher survives them (the pass is poisoned so peers
    /// blocked in [`Progress::wait_min`] abort instead of spinning).
    fn run(&mut self, schedule: &dyn Schedule) -> Result<()>;

    /// Check the reusable scratch arena out for the duration of a
    /// schedule; the guard hands it back on drop, panic or not.
    fn scratch(&mut self) -> ScratchGuard;
}

/// A persistent team of worker threads executing [`Schedule`] passes.
pub struct WorkerPool {
    control: Arc<Control>,
    handles: Vec<JoinHandle<()>>,
    progress: Progress,
    hook: Option<StartHook>,
    scratch: ScratchSlot,
}

impl WorkerPool {
    /// A pool with `size` persistent workers. `size` may be 0: the pool
    /// grows on demand to fit each dispatched schedule.
    pub fn new(size: usize) -> Self {
        let control = Arc::new(Control {
            state: Mutex::new(State { jobs: Vec::new(), next_token: 0, shutdown: false }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let mut pool = Self {
            control,
            handles: Vec::new(),
            progress: Progress::new(0),
            hook: None,
            scratch: Arc::new(Mutex::new(Some(Scratch::default()))),
        };
        pool.ensure_workers(size);
        pool
    }

    /// Check the pool-level scratch arena out (see [`Dispatch::scratch`]).
    pub fn scratch(&mut self) -> ScratchGuard {
        ScratchGuard::checkout(&self.scratch)
    }

    /// Install a per-worker start hook (e.g. core pinning). Applies to
    /// workers spawned afterwards, so install it before the first run.
    pub fn set_start_hook(&mut self, hook: StartHook) {
        self.hook = Some(hook);
    }

    /// Remove a previously installed start hook: workers spawned from now
    /// on start unpinned/untagged. Needed when a pool moves between
    /// sessions with different pin policies, so a session requesting no
    /// pinning does not apply the previous session's hook to *new*
    /// workers. (Workers already spawned keep their placement — hooks
    /// run once, at thread start.)
    pub fn clear_start_hook(&mut self) {
        self.hook = None;
    }

    /// Current team size.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Grow the team to at least `n` workers (no-op when already larger).
    pub fn ensure_workers(&mut self, n: usize) {
        while self.handles.len() < n {
            let id = self.handles.len();
            let control = Arc::clone(&self.control);
            let hook = self.hook.clone();
            let handle = std::thread::Builder::new()
                .name(format!("stencilwave-pool-{id}"))
                .spawn(move || worker_loop(control, id, hook))
                .expect("spawn pool worker");
            self.handles.push(handle);
        }
    }

    /// Carve out the worker window `start..start + len` as a
    /// [`PoolSegment`] — its own progress table and scratch arena, so a
    /// session bound to it runs concurrently with sessions on disjoint
    /// windows of the same pool. Grows the team so the window exists.
    /// Windows are allowed to overlap (overlapping passes serialize on
    /// the shared workers, in submission order); the multi-tenant
    /// service keeps them disjoint for real concurrency.
    pub fn segment(&mut self, start: usize, len: usize) -> PoolSegment {
        self.ensure_workers(start + len);
        PoolSegment {
            control: Arc::clone(&self.control),
            start,
            len,
            progress: Progress::new(0),
            scratch: Arc::new(Mutex::new(Some(Scratch::default()))),
        }
    }

    /// Execute one pass of `schedule` on the team, blocking until every
    /// worker finishes. Grows the team if the schedule needs more workers
    /// than the pool currently holds; workers beyond the schedule's team
    /// size stay parked (or serve other tenants' segments).
    ///
    /// Worker panics are captured and surfaced as an error and the pool
    /// itself survives them: the pass is poisoned so peers blocked in
    /// [`Progress::wait_min`] abort instead of spinning forever. (A
    /// schedule that synchronizes through a raw barrier instead of the
    /// progress table — the wavefront's `SyncMode::Barrier` — can still
    /// stall if a worker dies *between* barrier rounds; the progress
    /// protocol is the panic-safe path.)
    pub fn run(&mut self, schedule: &dyn Schedule) -> Result<()> {
        self.ensure_workers(schedule.workers());
        let slots = schedule.progress_slots();
        if self.progress.len() < slots {
            self.progress = Progress::new(slots);
        }
        self.progress.reset();
        dispatch(&self.control, schedule, 0, &self.progress)
    }
}

impl Dispatch for WorkerPool {
    fn run(&mut self, schedule: &dyn Schedule) -> Result<()> {
        WorkerPool::run(self, schedule)
    }
    fn scratch(&mut self) -> ScratchGuard {
        WorkerPool::scratch(self)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.control.lock();
            st.shutdown = true;
            for job in &st.jobs {
                // a tenant blocked in `dispatch` on another thread must
                // drain: poison so its in-flight workers abort instead
                // of spinning on watermarks of never-claimed slots.
                // SAFETY: a listed job's dispatcher is still inside
                // `dispatch`, so the progress borrow is alive.
                unsafe { &*job.progress }.poison();
            }
            self.control.go.notify_all();
            self.control.done.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// A handle to the worker window `start..start + len` of a shared
/// [`WorkerPool`], with its own [`Progress`] table and its own
/// [`Scratch`] arena — the per-segment state that lets two-plus solver
/// sessions run concurrently on one pool with zero steady-state
/// allocation. Created by [`WorkerPool::segment`]; sendable to the
/// tenant's thread. A segment does not keep the pool alive: passes
/// dispatched after the pool dropped fail with a "shut down" error.
pub struct PoolSegment {
    control: Arc<Control>,
    start: usize,
    len: usize,
    progress: Progress,
    scratch: ScratchSlot,
}

impl PoolSegment {
    /// Worker capacity of the window (schedules needing more are
    /// rejected — a segment never grows; growing is the pool owner's
    /// placement decision).
    pub fn capacity(&self) -> usize {
        self.len
    }

    /// The pool worker ids of the window, as `(start, len)`.
    pub fn worker_range(&self) -> (usize, usize) {
        (self.start, self.len)
    }

    /// Execute one pass of `schedule` on the window, blocking until
    /// every worker finishes (see [`Dispatch::run`]). Schedule slot
    /// `local` executes on pool worker `start + local`.
    pub fn run(&mut self, schedule: &dyn Schedule) -> Result<()> {
        let n = schedule.workers();
        anyhow::ensure!(
            n <= self.len,
            "schedule needs {n} workers but the segment holds {} (pool workers {}..{})",
            self.len,
            self.start,
            self.start + self.len
        );
        let slots = schedule.progress_slots();
        if self.progress.len() < slots {
            self.progress = Progress::new(slots);
        }
        self.progress.reset();
        dispatch(&self.control, schedule, self.start, &self.progress)
    }

    /// Check the segment's scratch arena out (see [`Dispatch::scratch`]).
    pub fn scratch(&mut self) -> ScratchGuard {
        ScratchGuard::checkout(&self.scratch)
    }
}

impl Dispatch for PoolSegment {
    fn run(&mut self, schedule: &dyn Schedule) -> Result<()> {
        PoolSegment::run(self, schedule)
    }
    fn scratch(&mut self) -> ScratchGuard {
        PoolSegment::scratch(self)
    }
}

thread_local! {
    /// One convenience pool per calling thread (grown on demand, parked
    /// between calls, joined when the thread exits).
    static LOCAL: RefCell<WorkerPool> = RefCell::new(WorkerPool::new(0));
}

/// Run `f` with the calling thread's convenience pool. Each caller
/// thread owns its own team, so concurrent callers run truly side by
/// side instead of serializing on a process mutex; repeated calls from
/// one thread still amortize one set of threads. Applications that fan
/// out over many of their own threads should hold an explicitly owned
/// team via a [`Solver`](super::solver::Solver) session instead.
///
/// (The 0.2.0 `with_global` shim — one process-wide mutexed team — was
/// removed in 0.3.0 along with the free-function scheme matrix.)
///
/// # Panics
/// When re-entered from within `f` (the per-thread pool is exclusively
/// borrowed while a pass runs).
pub fn with_local<R>(f: impl FnOnce(&mut WorkerPool) -> R) -> R {
    LOCAL.with(|p| f(&mut p.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    struct CountSchedule {
        hits: Vec<AtomicUsize>,
    }

    impl CountSchedule {
        fn new(n: usize) -> Self {
            Self { hits: (0..n).map(|_| AtomicUsize::new(0)).collect() }
        }
    }

    impl Schedule for CountSchedule {
        fn workers(&self) -> usize {
            self.hits.len()
        }
        fn worker(&self, id: usize, _progress: &Progress) {
            self.hits[id].fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Workers hand off through the progress table; the recorded order
    /// must be 0..n every pass — which only holds if the dispatcher
    /// resets the table between passes.
    struct ChainSchedule {
        n: usize,
        order: Mutex<Vec<usize>>,
    }

    impl Schedule for ChainSchedule {
        fn workers(&self) -> usize {
            self.n
        }
        fn worker(&self, id: usize, progress: &Progress) {
            if id > 0 {
                progress.wait_min(id - 1, 1);
            }
            self.order.lock().unwrap().push(id);
            progress.publish(id, 1);
        }
    }

    struct PanicSchedule;

    impl Schedule for PanicSchedule {
        fn workers(&self) -> usize {
            2
        }
        fn worker(&self, id: usize, _progress: &Progress) {
            if id == 1 {
                panic!("boom from worker {id}");
            }
        }
    }

    #[test]
    fn all_workers_run_every_pass() {
        let mut pool = WorkerPool::new(3);
        let sched = CountSchedule::new(3);
        for _ in 0..5 {
            pool.run(&sched).unwrap();
        }
        for h in &sched.hits {
            assert_eq!(h.load(Ordering::SeqCst), 5);
        }
    }

    #[test]
    fn pool_grows_on_demand_and_larger_teams_idle() {
        let mut pool = WorkerPool::new(1);
        pool.run(&CountSchedule::new(4)).unwrap();
        assert_eq!(pool.size(), 4);
        // smaller schedule on the grown pool: extra workers idle
        let small = CountSchedule::new(2);
        pool.run(&small).unwrap();
        assert_eq!(small.hits[0].load(Ordering::SeqCst), 1);
        assert_eq!(small.hits[1].load(Ordering::SeqCst), 1);
        assert_eq!(pool.size(), 4);
    }

    #[test]
    fn progress_is_reset_between_passes() {
        let mut pool = WorkerPool::new(4);
        let sched = ChainSchedule { n: 4, order: Mutex::new(Vec::new()) };
        for pass in 0..10 {
            pool.run(&sched).unwrap();
            let mut order = sched.order.lock().unwrap();
            assert_eq!(*order, vec![0, 1, 2, 3], "pass {pass}");
            order.clear();
        }
    }

    #[test]
    fn worker_panic_is_captured_and_pool_survives() {
        let mut pool = WorkerPool::new(2);
        let err = pool.run(&PanicSchedule).unwrap_err().to_string();
        assert!(err.contains("boom"), "{err}");
        // the pool is still usable after the failed pass
        let sched = CountSchedule::new(2);
        pool.run(&sched).unwrap();
        assert_eq!(sched.hits[0].load(Ordering::SeqCst), 1);
    }

    /// Worker 0 dies before publishing anything; workers 1 and 2 wait on
    /// it. Without poisoning this deadlocks `run` forever.
    struct PanicChainSchedule;

    impl Schedule for PanicChainSchedule {
        fn workers(&self) -> usize {
            3
        }
        fn worker(&self, id: usize, progress: &Progress) {
            if id == 0 {
                panic!("chain head died");
            }
            progress.wait_min(id - 1, 1);
            progress.publish(id, 1);
        }
    }

    #[test]
    fn panic_poisons_waiting_peers_instead_of_deadlocking() {
        let mut pool = WorkerPool::new(3);
        let err = pool.run(&PanicChainSchedule).unwrap_err().to_string();
        assert!(err.contains("chain head died"), "{err}");
        // poison is cleared by the next pass's reset
        let sched = ChainSchedule { n: 3, order: Mutex::new(Vec::new()) };
        pool.run(&sched).unwrap();
        assert_eq!(*sched.order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn empty_schedule_is_rejected() {
        let mut pool = WorkerPool::new(1);
        assert!(pool.run(&CountSchedule::new(0)).is_err());
    }

    #[test]
    fn start_hook_sees_every_worker() {
        let seen = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(0);
        let s = Arc::clone(&seen);
        pool.set_start_hook(Arc::new(move |_id| {
            s.fetch_add(1, Ordering::SeqCst);
        }));
        pool.run(&CountSchedule::new(3)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn cleared_start_hook_does_not_reach_new_workers() {
        let seen = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(0);
        let s = Arc::clone(&seen);
        pool.set_start_hook(Arc::new(move |_id| {
            s.fetch_add(1, Ordering::SeqCst);
        }));
        pool.run(&CountSchedule::new(2)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 2);
        // a pool handed to a session with PinPolicy::None must not keep
        // applying the previous session's hook to workers spawned later
        pool.clear_start_hook();
        pool.run(&CountSchedule::new(4)).unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 2, "cleared hook leaked to new workers");
    }

    /// Every worker checks in at a shared gate and spins until all
    /// `expect` workers (across *both* segments) have arrived — only
    /// possible if the two windows execute truly concurrently.
    struct RendezvousSchedule {
        n: usize,
        gate: Arc<AtomicUsize>,
        expect: usize,
    }

    impl Schedule for RendezvousSchedule {
        fn workers(&self) -> usize {
            self.n
        }
        fn worker(&self, _id: usize, _progress: &Progress) {
            self.gate.fetch_add(1, Ordering::SeqCst);
            let deadline = Instant::now() + Duration::from_secs(10);
            while self.gate.load(Ordering::SeqCst) < self.expect {
                if Instant::now() > deadline {
                    panic!("segments serialized: rendezvous never filled");
                }
                std::hint::spin_loop();
            }
        }
    }

    #[test]
    fn disjoint_segments_run_truly_concurrently() {
        let mut pool = WorkerPool::new(4);
        let mut a = pool.segment(0, 2);
        let mut b = pool.segment(2, 2);
        let gate = Arc::new(AtomicUsize::new(0));
        let (ga, gb) = (Arc::clone(&gate), Arc::clone(&gate));
        let ta = std::thread::spawn(move || {
            a.run(&RendezvousSchedule { n: 2, gate: ga, expect: 4 }).map(|()| a)
        });
        let tb = std::thread::spawn(move || {
            b.run(&RendezvousSchedule { n: 2, gate: gb, expect: 4 }).map(|()| b)
        });
        let mut a = ta.join().unwrap().unwrap();
        let mut b = tb.join().unwrap().unwrap();
        // both windows stay reusable, with ordered hand-off local to each
        for seg in [&mut a, &mut b] {
            let sched = ChainSchedule { n: 2, order: Mutex::new(Vec::new()) };
            seg.run(&sched).unwrap();
            assert_eq!(*sched.order.lock().unwrap(), vec![0, 1]);
        }
    }

    #[test]
    fn segment_rejects_schedules_beyond_its_capacity() {
        let mut pool = WorkerPool::new(0);
        let mut seg = pool.segment(1, 2);
        assert_eq!(pool.size(), 3, "segment creation spawns its window");
        let err = seg.run(&CountSchedule::new(3)).unwrap_err().to_string();
        assert!(err.contains("segment holds 2"), "{err}");
        // at-capacity schedules run, on pool workers 1 and 2
        seg.run(&CountSchedule::new(2)).unwrap();
    }

    #[test]
    fn segment_panics_do_not_poison_sibling_segments() {
        let mut pool = WorkerPool::new(4);
        let mut a = pool.segment(0, 2);
        let mut b = pool.segment(2, 2);
        let err = a.run(&PanicSchedule).unwrap_err().to_string();
        assert!(err.contains("boom"), "{err}");
        // b has its own progress table: the poison stays in a
        let sched = ChainSchedule { n: 2, order: Mutex::new(Vec::new()) };
        b.run(&sched).unwrap();
        assert_eq!(*sched.order.lock().unwrap(), vec![0, 1]);
        // and a itself recovers on its next pass
        let sched = ChainSchedule { n: 2, order: Mutex::new(Vec::new()) };
        a.run(&sched).unwrap();
        assert_eq!(*sched.order.lock().unwrap(), vec![0, 1]);
    }

    #[test]
    fn scratch_guard_restores_capacity_after_a_panic() {
        let mut pool = WorkerPool::new(1);
        {
            let mut s = pool.scratch();
            s.planes.resize(1000, 0.0);
        }
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            let mut s = pool.scratch();
            s.planes.resize(2000, 0.0);
            panic!("sweep died mid-pass");
        }));
        assert!(unwound.is_err());
        // the old take/restore pair leaked the arena here; the guard
        // hands it back during the unwind
        let s = pool.scratch();
        assert_eq!(s.planes.len(), 2000, "arena lost on panic");
    }

    #[test]
    fn segment_scratch_arenas_are_independent_and_persistent() {
        let mut pool = WorkerPool::new(2);
        let mut a = pool.segment(0, 1);
        let mut b = pool.segment(1, 1);
        a.scratch().planes.resize(64, 1.0);
        b.scratch().planes.resize(8, 2.0);
        assert_eq!(a.scratch().planes.len(), 64);
        assert_eq!(b.scratch().planes.len(), 8);
        // two checkouts from one slot may coexist (the second falls back
        // to a fresh arena rather than blocking or aliasing)
        let first = a.scratch();
        let second = a.scratch();
        assert_eq!(first.planes.len(), 64);
        assert_eq!(second.planes.len(), 0);
    }

    #[test]
    fn dispatch_through_the_trait_object_matches_direct_calls() {
        let mut pool = WorkerPool::new(2);
        {
            let d: &mut dyn Dispatch = &mut pool;
            let sched = ChainSchedule { n: 2, order: Mutex::new(Vec::new()) };
            d.run(&sched).unwrap();
            assert_eq!(*sched.order.lock().unwrap(), vec![0, 1]);
            d.scratch().bnd.resize(5, 0.0);
        }
        let mut seg = pool.segment(0, 2);
        let d: &mut dyn Dispatch = &mut seg;
        let sched = ChainSchedule { n: 2, order: Mutex::new(Vec::new()) };
        d.run(&sched).unwrap();
        assert_eq!(*sched.order.lock().unwrap(), vec![0, 1]);
        assert_eq!(pool.scratch().bnd.len(), 5);
    }
}
