//! Synchronization primitives for fine-grained plane-level parallelism.
//!
//! Sec. 4: "The pthread barrier turned out to have a very large overhead,
//! making it unsuitable for fine-grained parallelism. For small thread
//! counts ... an implementation of a spin waiting loop was used for the
//! barrier. Since this does not perform well with SMT threads, a tree
//! barrier was implemented which provided less overhead whenever more than
//! one logical thread per core was used."
//!
//! Both primitives are real, lock-free, and reusable (generation-counted);
//! `benches/bench_barrier.rs` reproduces the overhead comparison, and the
//! cost *model* used by the simulator lives in
//! [`crate::simulator::perfmodel::BarrierKind`].

use std::sync::atomic::{AtomicUsize, Ordering};

/// Spin briefly, then yield to the scheduler.
///
/// On the paper's testbed each participant owns a core (or an SMT thread)
/// and pure spinning is optimal; on an oversubscribed host (CI boxes, this
/// 1-core sandbox) a pure spin burns whole scheduler timeslices waiting
/// for a thread that cannot run. The hybrid keeps the fast path fast
/// (first `SPINS` iterations are pause instructions) and stays correct
/// and prompt under any core count. Used by every spin-wait in the
/// coordinator.
#[inline]
pub fn spin_wait(mut condition: impl FnMut() -> bool) {
    const SPINS: u32 = 64;
    let mut n = 0u32;
    while !condition() {
        n += 1;
        if n < SPINS {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// A reusable spin-wait barrier (central counter + generation flag).
///
/// Arrivals decrement a counter; the last arrival flips the generation and
/// resets the counter. Waiters spin on the generation word only, so the
/// hot path is a single shared cacheline read.
pub struct SpinBarrier {
    n: usize,
    remaining: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        Self { n, remaining: AtomicUsize::new(n), generation: AtomicUsize::new(0) }
    }

    /// Number of participants.
    pub fn participants(&self) -> usize {
        self.n
    }

    /// Block (spinning) until all `n` participants have arrived.
    pub fn wait(&self) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last arrival: reset and release the others
            self.remaining.store(self.n, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            spin_wait(|| self.generation.load(Ordering::Acquire) != gen);
        }
    }
}

/// A software combining-tree barrier (binary fan-in / broadcast fan-out).
///
/// Each node spins on at most its two children's flags instead of a single
/// contended counter, so SMT siblings spin on distinct cachelines and the
/// worst-case spin chain is `O(log n)` — the property the paper exploits
/// with two logical threads per core.
pub struct TreeBarrier {
    n: usize,
    /// Per-thread arrival counters (round number).
    arrive: Vec<AtomicUsize>,
    /// Broadcast round counter.
    release: AtomicUsize,
    round: AtomicUsize,
}

impl TreeBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        Self {
            n,
            arrive: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            release: AtomicUsize::new(0),
            round: AtomicUsize::new(0),
        }
    }

    pub fn participants(&self) -> usize {
        self.n
    }

    /// Block until all participants reach the barrier. `id` ∈ [0, n).
    pub fn wait(&self, id: usize) {
        debug_assert!(id < self.n);
        let round = self.round.load(Ordering::Acquire);
        let target = round + 1;
        // fan-in: wait for both children (binary heap layout), then signal
        let left = 2 * id + 1;
        let right = 2 * id + 2;
        if left < self.n {
            spin_wait(|| self.arrive[left].load(Ordering::Acquire) >= target);
        }
        if right < self.n {
            spin_wait(|| self.arrive[right].load(Ordering::Acquire) >= target);
        }
        self.arrive[id].store(target, Ordering::Release);
        if id == 0 {
            // root: everyone has arrived — broadcast the release
            self.round.store(target, Ordering::Relaxed);
            self.release.store(target, Ordering::Release);
        } else {
            spin_wait(|| self.release.load(Ordering::Acquire) >= target);
        }
    }
}

/// Object-safe façade so schedules can be generic over the barrier kind.
pub enum AnyBarrier {
    Spin(SpinBarrier),
    Tree(TreeBarrier),
}

impl AnyBarrier {
    pub fn new(kind: crate::simulator::perfmodel::BarrierKind, n: usize) -> Self {
        use crate::simulator::perfmodel::BarrierKind as K;
        match kind {
            K::Tree => AnyBarrier::Tree(TreeBarrier::new(n)),
            // the pthread flavour exists only as a cost model; functionally
            // it behaves like the spin barrier
            K::Spin | K::Pthread => AnyBarrier::Spin(SpinBarrier::new(n)),
        }
    }

    #[inline]
    pub fn wait(&self, id: usize) {
        match self {
            AnyBarrier::Spin(b) => b.wait(),
            AnyBarrier::Tree(b) => b.wait(id),
        }
    }

    pub fn participants(&self) -> usize {
        match self {
            AnyBarrier::Spin(b) => b.participants(),
            AnyBarrier::Tree(b) => b.participants(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    /// All threads must observe every other thread's pre-barrier increment
    /// after the barrier, for many rounds.
    fn exercise(barrier: Arc<AnyBarrier>, threads: usize, rounds: usize) {
        let counter = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|id| {
                let b = Arc::clone(&barrier);
                let c = Arc::clone(&counter);
                std::thread::spawn(move || {
                    for r in 1..=rounds {
                        c.fetch_add(1, Ordering::SeqCst);
                        b.wait(id);
                        let seen = c.load(Ordering::SeqCst);
                        assert!(
                            seen >= (r * threads) as u64,
                            "round {r}: saw {seen} < {}",
                            r * threads
                        );
                        b.wait(id); // second barrier so nobody races ahead
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), (threads * rounds) as u64);
    }

    #[test]
    fn spin_barrier_synchronizes() {
        for threads in [1, 2, 3, 4, 8] {
            exercise(Arc::new(AnyBarrier::Spin(SpinBarrier::new(threads))), threads, 50);
        }
    }

    #[test]
    fn tree_barrier_synchronizes() {
        for threads in [1, 2, 3, 4, 8, 13] {
            exercise(Arc::new(AnyBarrier::Tree(TreeBarrier::new(threads))), threads, 50);
        }
    }

    #[test]
    fn any_barrier_dispatch() {
        use crate::simulator::perfmodel::BarrierKind;
        for kind in [BarrierKind::Spin, BarrierKind::Tree, BarrierKind::Pthread] {
            let b = AnyBarrier::new(kind, 4);
            assert_eq!(b.participants(), 4);
            exercise(Arc::new(AnyBarrier::new(kind, 4)), 4, 20);
        }
    }
}
