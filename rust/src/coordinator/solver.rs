//! The unified `Solver` session: one builder, one owned team, one
//! resolved scheme runner — the API every scheme is driven through.
//! (Validation happens once at build; per-`run` schedule construction is
//! cheap and intentionally not cached, since it borrows the caller's
//! grid.)
//!
//! A session replaces the old four-way free-function matrix
//! (`x` / `x_on` / `x_iters` / `x_iters_on` per scheme): it validates the
//! [`RunConfig`] once at [`SolverBuilder::build`], resolves the scheme's
//! [`SchemeRunner`](super::runner::SchemeRunner) from the registry,
//! pre-spawns exactly the team the schedule needs (optionally pinned to
//! cores by a [`PinPolicy`]), and owns the pool plus its reusable scratch
//! arena — so repeated [`Solver::run`] calls spawn no threads and
//! allocate no scratch.
//!
//! ```no_run
//! use stencilwave::config::RunConfig;
//! use stencilwave::coordinator::affinity::PinPolicy;
//! use stencilwave::coordinator::solver::Solver;
//! use stencilwave::stencil::grid::Grid3;
//!
//! let cfg = RunConfig { size: (64, 64, 64), t: 4, ..Default::default() };
//! let mut solver = Solver::builder(&cfg).pin(PinPolicy::Compact).build().unwrap();
//! let mut u = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! solver.run(&mut u, 8).unwrap(); // 8 updates, one persistent team
//! solver.step(&mut u).unwrap();   // one more natural pass (t updates)
//! ```

use crate::config::RunConfig;
use crate::config::Scheme;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{OpInstance, OpKind};
use crate::Result;

use super::affinity::{pin_hook, PinPolicy, Topology};
use super::pool::{Dispatch, PoolSegment, WorkerPool};
use super::runner::{runner_for, SchemeRunner};

/// Builder for a [`Solver`] session. Obtained from [`Solver::builder`];
/// consumed by [`SolverBuilder::build`].
pub struct SolverBuilder {
    cfg: RunConfig,
    pool: Option<WorkerPool>,
    segment: Option<PoolSegment>,
    pin: PinPolicy,
    rhs: Option<(Grid3, f64)>,
    op: Option<OpInstance>,
}

impl SolverBuilder {
    /// Provide a caller-owned pool instead of a fresh private team.
    ///
    /// The pin policy only applies to workers spawned *after* [`build`]
    /// installs the hook: workers the pool already holds keep whatever
    /// placement a previous session gave them (pinning is applied once,
    /// at thread start). Pass an empty pool for a fully pinned — or,
    /// with [`PinPolicy::None`], fully unpinned — team.
    ///
    /// [`build`]: SolverBuilder::build
    pub fn pool(mut self, pool: WorkerPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Bind the session to a [`PoolSegment`] window of a shared pool
    /// instead of an owned team — the multi-tenant path: sessions on
    /// disjoint segments of one pool run concurrently, each with its
    /// own progress table and scratch arena. The segment must hold at
    /// least the scheme's team (checked at [`build`]; a segment never
    /// grows — sizing is the pool owner's placement decision), and the
    /// pin policy is ignored: segment workers are already spawned and
    /// placed by the pool owner. Mutually exclusive with
    /// [`SolverBuilder::pool`].
    ///
    /// [`build`]: SolverBuilder::build
    pub fn segment(mut self, segment: PoolSegment) -> Self {
        self.segment = Some(segment);
        self
    }

    /// Core-pinning policy for the team (default: the config's `pin`
    /// key, which itself defaults to [`PinPolicy::None`]).
    pub fn pin(mut self, pin: PinPolicy) -> Self {
        self.pin = pin;
        self
    }

    /// Right-hand side `f` and mesh factor `h2` for the Jacobi schemes
    /// (ignored by the Gauss-Seidel schemes). Defaults to `f = 0`,
    /// `h2 = 1` — the homogeneous problem.
    pub fn rhs(mut self, f: Grid3, h2: f64) -> Self {
        self.rhs = Some((f, h2));
        self
    }

    /// Provide a pre-built op instance instead of the default
    /// full-domain instantiation. The rank decomposition uses this to
    /// hand each per-rank solver a *slab* instance
    /// ([`OpKind::instantiate_at`](crate::stencil::op::OpKind::instantiate_at))
    /// whose per-site state is evaluated in global coordinates —
    /// `build` still checks the instance's kind against the config and
    /// validates it on the configured domain.
    pub fn op(mut self, op: OpInstance) -> Self {
        self.op = Some(op);
        self
    }

    /// Validate the configuration (the same checks — and the same
    /// errors — as [`RunConfig::validate`]), resolve the scheme's
    /// runner, and spawn the full team, pinned per the policy. After
    /// `build` returns, no [`Solver::run`] call spawns another thread.
    pub fn build(self) -> Result<Solver> {
        self.cfg.validate()?;
        let runner = runner_for(self.cfg.scheme, self.cfg.op)?;
        if let Some((f, _)) = &self.rhs {
            anyhow::ensure!(
                f.shape() == self.cfg.size,
                "rhs shape {:?} does not match the configured size {:?}",
                f.shape(),
                self.cfg.size
            );
        }
        let (nz, ny, nx) = self.cfg.size;
        let is_gs = self.cfg.scheme.is_gs();
        let (f, h2) = match self.rhs {
            Some(rhs) => rhs,
            // the Gauss-Seidel runners never read the rhs — keep the
            // placeholder tiny instead of materializing a dead N^3 grid
            None if is_gs => (Grid3::zeros(1, 1, 1), 1.0),
            None => (Grid3::zeros(nz, ny, nx), 1.0),
        };
        let team = match self.segment {
            Some(segment) => {
                anyhow::ensure!(
                    self.pool.is_none(),
                    "a session binds an owned pool or a borrowed segment, not both"
                );
                let need = runner.team_size(&self.cfg);
                anyhow::ensure!(
                    need <= segment.capacity(),
                    "scheme {:?} needs {need} workers but the bound segment holds {} — \
                     segments never grow; sizing is the pool owner's placement decision",
                    self.cfg.scheme,
                    segment.capacity()
                );
                // pinning is the pool owner's job: segment workers are
                // already spawned, so a hook installed here would never
                // fire anyway
                Team::Segment(segment)
            }
            None => {
                let mut pool = self.pool.unwrap_or_else(|| WorkerPool::new(0));
                let topo = self
                    .cfg
                    .machine_spec()
                    .map(|m| Topology::of_machine(&m))
                    .unwrap_or_else(Topology::host);
                // An SMT run with no explicit placement gets the
                // sibling-pair map: co-scheduled workers (adjacent ids —
                // e.g. one GS pipeline pair) share a core's two hardware
                // threads, which is the whole point of asking for SMT
                // (Sec. 6). An explicit policy always wins.
                let pin = if self.pin == PinPolicy::None && self.cfg.smt {
                    PinPolicy::SmtPair
                } else {
                    self.pin
                };
                match pin_hook(pin, topo) {
                    Some(hook) => pool.set_start_hook(hook),
                    // a reused pool may carry the previous session's hook
                    None => pool.clear_start_hook(),
                }
                pool.ensure_workers(runner.team_size(&self.cfg));
                Team::Pool(pool)
            }
        };
        let op = match self.op {
            Some(op) => {
                anyhow::ensure!(
                    op.kind() == self.cfg.op,
                    "injected op instance is {:?} but the config asks for {:?}",
                    op.kind(),
                    self.cfg.op
                );
                op.as_dyn().validate_domain(self.cfg.size)?;
                op
            }
            None => self.cfg.op.instantiate(self.cfg.size),
        };
        Ok(Solver { cfg: self.cfg, runner, op, team, f, h2 })
    }
}

/// The execution resource a session dispatches on: an owned pool, or a
/// borrowed window of a shared one (the multi-tenant path).
enum Team {
    Pool(WorkerPool),
    Segment(PoolSegment),
}

impl Team {
    fn dispatch(&mut self) -> &mut dyn Dispatch {
        match self {
            Team::Pool(p) => p,
            Team::Segment(s) => s,
        }
    }
}

/// A reusable execution session: config validated once, scheme resolved
/// from the registry, team spawned (and optionally pinned) once, scratch
/// owned by the pool or segment and reused across every [`Solver::run`]
/// call.
pub struct Solver {
    cfg: RunConfig,
    runner: &'static dyn SchemeRunner,
    /// The session's op instance (coefficient grids live here).
    op: OpInstance,
    team: Team,
    f: Grid3,
    h2: f64,
}

impl Solver {
    /// Start building a session for `cfg` (the config is cloned; the
    /// builder seeds its pin policy from `cfg.pin`).
    pub fn builder(cfg: &RunConfig) -> SolverBuilder {
        SolverBuilder {
            pin: cfg.pin,
            cfg: cfg.clone(),
            pool: None,
            segment: None,
            rhs: None,
            op: None,
        }
    }

    /// The scheme this session executes.
    pub fn scheme(&self) -> Scheme {
        self.cfg.scheme
    }

    /// The stencil operator this session applies.
    pub fn op_kind(&self) -> OpKind {
        self.op.kind()
    }

    /// Workers the session's team holds: the pool size for an owned
    /// team (workers are never retired, so a `team_size` that stays
    /// constant across [`Solver::run`] calls proves the session spawned
    /// no new threads after [`SolverBuilder::build`] — the accounting
    /// the tests assert), or the fixed window capacity for a
    /// segment-bound session.
    pub fn team_size(&self) -> usize {
        match &self.team {
            Team::Pool(p) => p.size(),
            Team::Segment(s) => s.capacity(),
        }
    }

    /// Updates performed by one [`Solver::step`] — the scheme's natural
    /// pass (`t` for the temporally blocked schemes, 1 for baselines).
    pub fn step_iters(&self) -> usize {
        self.runner.step_iters(&self.cfg)
    }

    /// Perform `iters` updates of `u` in place on the session's team.
    ///
    /// `u` must have the session's configured size; schemes with a fixed
    /// pass granularity keep their divisibility requirement (`iters`
    /// a multiple of `t` for wavefront Jacobi — the same error the old
    /// `*_iters` entry points raised).
    pub fn run(&mut self, u: &mut Grid3, iters: usize) -> Result<()> {
        anyhow::ensure!(
            u.shape() == self.cfg.size,
            "grid shape {:?} does not match the session's configured size {:?}",
            u.shape(),
            self.cfg.size
        );
        self.runner.execute(self.team.dispatch(), &self.op, u, &self.f, self.h2, &self.cfg, iters)
    }

    /// Perform `iters` updates of `u` against a caller-provided rhs,
    /// leaving the session's stored rhs untouched — the many-RHS /
    /// one-session path: the multi-tenant service batches small-grid
    /// jobs with identical configurations through one session, swapping
    /// only each tenant's grids.
    pub fn run_with(&mut self, u: &mut Grid3, f: &Grid3, h2: f64, iters: usize) -> Result<()> {
        anyhow::ensure!(
            u.shape() == self.cfg.size,
            "grid shape {:?} does not match the session's configured size {:?}",
            u.shape(),
            self.cfg.size
        );
        anyhow::ensure!(
            f.shape() == self.cfg.size,
            "rhs shape {:?} does not match the session's configured size {:?}",
            f.shape(),
            self.cfg.size
        );
        self.runner.execute(self.team.dispatch(), &self.op, u, f, h2, &self.cfg, iters)
    }

    /// One natural pass of the scheme ([`Solver::step_iters`] updates).
    pub fn step(&mut self, u: &mut Grid3) -> Result<()> {
        let iters = self.runner.step_iters(&self.cfg);
        self.run(u, iters)
    }

    /// The serial reference for `iters` updates from `u0` — what
    /// [`Solver::run`] must match bit-exactly.
    pub fn reference(&self, u0: &Grid3, iters: usize) -> Grid3 {
        self.runner.reference(&self.op, u0, &self.f, self.h2, &self.cfg, iters)
    }

    /// The serial reference against a caller-provided rhs — what a
    /// [`Solver::run_with`] call must match bit-exactly.
    pub fn reference_with(&self, u0: &Grid3, f: &Grid3, h2: f64, iters: usize) -> Grid3 {
        self.runner.reference(&self.op, u0, f, h2, &self.cfg, iters)
    }

    /// Modeled MLUP/s of this session's configuration on a Tab. 1
    /// machine (the scheme runner's performance-model leg).
    pub fn predict(&self, machine: &crate::simulator::machine::MachineSpec) -> f64 {
        self.runner.predict(machine, &self.cfg)
    }

    /// Tear the session down, returning the owned pool (team and
    /// scratch intact) for reuse by another session.
    ///
    /// # Panics
    /// When the session is bound to a borrowed [`PoolSegment`] — use
    /// [`Solver::into_segment`] there.
    pub fn into_pool(self) -> WorkerPool {
        match self.team {
            Team::Pool(pool) => pool,
            Team::Segment(_) => {
                panic!("session is bound to a borrowed PoolSegment; use into_segment()")
            }
        }
    }

    /// Tear a segment-bound session down, returning the segment (with
    /// its warmed scratch arena) to the pool owner; `None` for sessions
    /// on an owned pool.
    pub fn into_segment(self) -> Option<PoolSegment> {
        match self.team {
            Team::Segment(segment) => Some(segment),
            Team::Pool(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wavefront::serial_reference;

    fn cfg(scheme: Scheme, size: (usize, usize, usize)) -> RunConfig {
        // the diamond width rule (interior >= 2R(t-1)*groups) does not
        // admit t = 4 on these small grids; t = 2 fits every op radius
        let t = if scheme == Scheme::JacobiDiamond { 2 } else { 4 };
        RunConfig { scheme, size, t, groups: 2, iters: 4, ..Default::default() }
    }

    #[test]
    fn session_runs_and_matches_reference() {
        let c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        let f = Grid3::random(12, 10, 9, 5);
        let mut solver = Solver::builder(&c).rhs(f.clone(), 0.8).build().unwrap();
        let u0 = Grid3::random(12, 10, 9, 6);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = serial_reference(&u0, &f, 0.8, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn build_rejects_what_validate_rejects() {
        let mut c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        c.t = 3; // odd t
        let have = Solver::builder(&c).build().map(|_| ()).unwrap_err().to_string();
        let want = c.validate().unwrap_err().to_string();
        assert_eq!(have, want);
    }

    #[test]
    fn no_threads_spawned_after_build() {
        let c = cfg(Scheme::GsWavefront, (10, 12, 9));
        let mut solver = Solver::builder(&c).build().unwrap();
        let team = solver.team_size();
        assert_eq!(team, 4 * 2, "sweeps x width pre-spawned");
        for _ in 0..3 {
            let mut u = Grid3::random(10, 12, 9, 3);
            solver.run(&mut u, 8).unwrap();
            solver.step(&mut u).unwrap();
        }
        // workers are never retired, so an unchanged team size proves no
        // run() call spawned a thread
        assert_eq!(solver.team_size(), team);
    }

    #[test]
    fn wrong_grid_shape_is_rejected() {
        let c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        let mut solver = Solver::builder(&c).build().unwrap();
        let mut u = Grid3::random(8, 8, 8, 1);
        assert!(solver.run(&mut u, 4).is_err());
    }

    #[test]
    fn default_rhs_is_homogeneous() {
        let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let mut solver = Solver::builder(&c).build().unwrap();
        let u0 = Grid3::random(10, 9, 8, 2);
        let mut u = u0.clone();
        solver.run(&mut u, 4).unwrap();
        let want = serial_reference(&u0, &Grid3::zeros(10, 9, 8), 1.0, 4);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn mismatched_rhs_shape_is_rejected_at_build() {
        let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let bad = Grid3::zeros(8, 8, 8);
        assert!(Solver::builder(&c).rhs(bad, 1.0).build().is_err());
    }

    #[test]
    fn session_pool_carries_over_to_a_new_session() {
        let c1 = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let mut s1 = Solver::builder(&c1).build().unwrap();
        let mut u = Grid3::random(10, 9, 8, 4);
        s1.run(&mut u, 4).unwrap();
        let pool = s1.into_pool();
        let carried = pool.size();
        // same team, different scheme: no new threads for a smaller team
        let c2 = cfg(Scheme::JacobiMultiGroup, (10, 9, 8));
        let mut s2 = Solver::builder(&c2).pool(pool).build().unwrap();
        let u0 = Grid3::random(10, 9, 8, 5);
        let mut v = u0.clone();
        s2.run(&mut v, 4).unwrap();
        let want = s2.reference(&u0, 4);
        assert_eq!(v.max_abs_diff(&want), 0.0);
        assert_eq!(s2.team_size(), carried);
    }

    #[test]
    fn sessions_run_every_op_through_every_scheme() {
        // the tentpole acceptance: both new ops execute through every
        // registered scheme and match their serial references bit-exactly
        for op in OpKind::ALL {
            for scheme in Scheme::ALL {
                let mut c = cfg(scheme, (14, 14, 12));
                c.op = op;
                let f = Grid3::random(14, 14, 12, 3);
                let mut solver = Solver::builder(&c).rhs(f, 0.9).build().unwrap();
                assert_eq!(solver.op_kind(), op);
                let u0 = Grid3::random(14, 14, 12, 4);
                let mut u = u0.clone();
                solver.run(&mut u, 4).unwrap();
                let want = solver.reference(&u0, 4);
                assert_eq!(u.max_abs_diff(&want), 0.0, "{scheme:?} x {op:?}");
            }
        }
    }

    #[test]
    fn injected_op_instances_are_checked_and_used() {
        // kind mismatch fails at build
        let mut c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        c.op = OpKind::VarCoeff7;
        let wrong = OpKind::ConstLaplace7.instantiate((10, 9, 8));
        assert!(Solver::builder(&c).op(wrong).build().is_err());
        // a wrong-shape coefficient grid fails at build, not in a worker
        let bad = OpKind::VarCoeff7.instantiate((8, 8, 8));
        assert!(Solver::builder(&c).op(bad).build().is_err());
        // a matching instance is used verbatim: an offset slab instance
        // produces different (offset-field) values than the default
        let u0 = Grid3::random(10, 9, 8, 21);
        let mut plain = u0.clone();
        Solver::builder(&c).build().unwrap().run(&mut plain, 4).unwrap();
        let slab = OpKind::VarCoeff7.instantiate_at((10, 9, 8), 1);
        let mut shifted = u0.clone();
        Solver::builder(&c).op(slab).build().unwrap().run(&mut shifted, 4).unwrap();
        assert!(shifted.max_abs_diff(&plain) > 0.0, "offset coefficients must differ");
    }

    #[test]
    fn pinned_sessions_stay_bit_exact() {
        for pin in [PinPolicy::Compact, PinPolicy::Scatter, PinPolicy::SmtPair] {
            let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
            let mut solver = Solver::builder(&c).pin(pin).build().unwrap();
            let f = Grid3::zeros(10, 9, 8);
            let u0 = Grid3::random(10, 9, 8, 9);
            let mut u = u0.clone();
            solver.run(&mut u, 4).unwrap();
            let want = serial_reference(&u0, &f, 1.0, 4);
            assert_eq!(u.max_abs_diff(&want), 0.0, "{pin:?}");
        }
    }

    #[test]
    fn segment_bound_session_matches_reference() {
        let mut pool = WorkerPool::new(4);
        let c = cfg(Scheme::JacobiMultiGroup, (10, 12, 9)); // team = groups = 2
        let f = Grid3::random(10, 12, 9, 13);
        let mut solver =
            Solver::builder(&c).segment(pool.segment(2, 2)).rhs(f, 0.8).build().unwrap();
        assert_eq!(solver.team_size(), 2, "window capacity, not pool size");
        let u0 = Grid3::random(10, 12, 9, 14);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = solver.reference(&u0, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0);
        assert_eq!(pool.size(), 4, "segment sessions never grow the pool");
        let seg = solver.into_segment().expect("segment binding comes back");
        assert_eq!(seg.worker_range(), (2, 2));
    }

    #[test]
    fn undersized_segment_is_rejected_at_build() {
        let mut pool = WorkerPool::new(0);
        let c = cfg(Scheme::GsWavefront, (10, 12, 9)); // team = t * groups = 8
        let err = Solver::builder(&c)
            .segment(pool.segment(0, 4))
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs 8 workers"), "{err}");
        assert!(err.contains("holds 4"), "{err}");
    }

    #[test]
    fn concurrent_sessions_on_one_pool_stay_bit_exact() {
        // the multi-tenant acceptance: two sessions on disjoint segments
        // of one pool, running at the same time from different threads,
        // each bit-identical to its serial reference
        let mut pool = WorkerPool::new(4);
        let seg_a = pool.segment(0, 2);
        let seg_b = pool.segment(2, 2);
        let mk = |scheme, seed: u64, seg| {
            let c = cfg(scheme, (10, 12, 9));
            let f = Grid3::random(10, 12, 9, seed);
            let solver = Solver::builder(&c).segment(seg).rhs(f, 0.9).build().unwrap();
            let u0 = Grid3::random(10, 12, 9, seed ^ 0xA5A5);
            (solver, u0)
        };
        let (mut sa, ua0) = mk(Scheme::JacobiMultiGroup, 31, seg_a);
        let (mut sb, ub0) = mk(Scheme::GsMultiGroup, 32, seg_b);
        let ta = std::thread::spawn(move || {
            let mut u = ua0.clone();
            for _ in 0..4 {
                sa.run(&mut u, 4).unwrap();
            }
            u.max_abs_diff(&sa.reference(&ua0, 16))
        });
        let tb = std::thread::spawn(move || {
            let mut u = ub0.clone();
            for _ in 0..4 {
                sb.run(&mut u, 4).unwrap();
            }
            u.max_abs_diff(&sb.reference(&ub0, 16))
        });
        assert_eq!(ta.join().unwrap(), 0.0, "tenant A diverged");
        assert_eq!(tb.join().unwrap(), 0.0, "tenant B diverged");
        assert_eq!(pool.size(), 4, "no growth under concurrent tenants");
    }

    #[test]
    fn run_with_leaves_the_session_rhs_untouched() {
        let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let f1 = Grid3::random(10, 9, 8, 41);
        let f2 = Grid3::random(10, 9, 8, 42);
        let mut solver = Solver::builder(&c).rhs(f1.clone(), 0.7).build().unwrap();
        let u0 = Grid3::random(10, 9, 8, 43);
        // a foreign rhs runs against its own reference...
        let mut u = u0.clone();
        solver.run_with(&mut u, &f2, 0.5, 4).unwrap();
        assert_eq!(u.max_abs_diff(&solver.reference_with(&u0, &f2, 0.5, 4)), 0.0);
        // ...and the stored rhs still drives plain run()
        let mut v = u0.clone();
        solver.run(&mut v, 4).unwrap();
        assert_eq!(v.max_abs_diff(&serial_reference(&u0, &f1, 0.7, 4)), 0.0);
        // shape mismatches are rejected up front
        let bad = Grid3::zeros(8, 8, 8);
        assert!(solver.run_with(&mut u, &bad, 1.0, 4).is_err());
    }

    #[test]
    fn smt_runs_get_the_sibling_pair_placement_and_stay_bit_exact() {
        // the auto-promotion: smt + no explicit pin policy co-schedules
        // sibling pairs (placement is advisory, results stay bit-exact)
        let mut c = cfg(Scheme::GsWavefront, (10, 12, 9));
        c.smt = true;
        let mut solver = Solver::builder(&c).build().unwrap();
        let u0 = Grid3::random(10, 12, 9, 11);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = solver.reference(&u0, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
