//! The unified `Solver` session: one builder, one owned team, one
//! resolved scheme runner — the API every scheme is driven through.
//! (Validation happens once at build; per-`run` schedule construction is
//! cheap and intentionally not cached, since it borrows the caller's
//! grid.)
//!
//! A session replaces the old four-way free-function matrix
//! (`x` / `x_on` / `x_iters` / `x_iters_on` per scheme): it validates the
//! [`RunConfig`] once at [`SolverBuilder::build`], resolves the scheme's
//! [`SchemeRunner`](super::runner::SchemeRunner) from the registry,
//! pre-spawns exactly the team the schedule needs (optionally pinned to
//! cores by a [`PinPolicy`]), and owns the pool plus its reusable scratch
//! arena — so repeated [`Solver::run`] calls spawn no threads and
//! allocate no scratch.
//!
//! ```no_run
//! use stencilwave::config::RunConfig;
//! use stencilwave::coordinator::affinity::PinPolicy;
//! use stencilwave::coordinator::solver::Solver;
//! use stencilwave::stencil::grid::Grid3;
//!
//! let cfg = RunConfig { size: (64, 64, 64), t: 4, ..Default::default() };
//! let mut solver = Solver::builder(&cfg).pin(PinPolicy::Compact).build().unwrap();
//! let mut u = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! solver.run(&mut u, 8).unwrap(); // 8 updates, one persistent team
//! solver.step(&mut u).unwrap();   // one more natural pass (t updates)
//! ```

use crate::config::RunConfig;
use crate::config::Scheme;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{OpInstance, OpKind};
use crate::Result;

use super::affinity::{pin_hook, PinPolicy, Topology};
use super::pool::WorkerPool;
use super::runner::{runner_for, SchemeRunner};

/// Builder for a [`Solver`] session. Obtained from [`Solver::builder`];
/// consumed by [`SolverBuilder::build`].
pub struct SolverBuilder {
    cfg: RunConfig,
    pool: Option<WorkerPool>,
    pin: PinPolicy,
    rhs: Option<(Grid3, f64)>,
    op: Option<OpInstance>,
}

impl SolverBuilder {
    /// Provide a caller-owned pool instead of a fresh private team.
    ///
    /// The pin policy only applies to workers spawned *after* [`build`]
    /// installs the hook: workers the pool already holds keep whatever
    /// placement a previous session gave them (pinning is applied once,
    /// at thread start). Pass an empty pool for a fully pinned — or,
    /// with [`PinPolicy::None`], fully unpinned — team.
    ///
    /// [`build`]: SolverBuilder::build
    pub fn pool(mut self, pool: WorkerPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Core-pinning policy for the team (default: the config's `pin`
    /// key, which itself defaults to [`PinPolicy::None`]).
    pub fn pin(mut self, pin: PinPolicy) -> Self {
        self.pin = pin;
        self
    }

    /// Right-hand side `f` and mesh factor `h2` for the Jacobi schemes
    /// (ignored by the Gauss-Seidel schemes). Defaults to `f = 0`,
    /// `h2 = 1` — the homogeneous problem.
    pub fn rhs(mut self, f: Grid3, h2: f64) -> Self {
        self.rhs = Some((f, h2));
        self
    }

    /// Provide a pre-built op instance instead of the default
    /// full-domain instantiation. The rank decomposition uses this to
    /// hand each per-rank solver a *slab* instance
    /// ([`OpKind::instantiate_at`](crate::stencil::op::OpKind::instantiate_at))
    /// whose per-site state is evaluated in global coordinates —
    /// `build` still checks the instance's kind against the config and
    /// validates it on the configured domain.
    pub fn op(mut self, op: OpInstance) -> Self {
        self.op = Some(op);
        self
    }

    /// Validate the configuration (the same checks — and the same
    /// errors — as [`RunConfig::validate`]), resolve the scheme's
    /// runner, and spawn the full team, pinned per the policy. After
    /// `build` returns, no [`Solver::run`] call spawns another thread.
    pub fn build(self) -> Result<Solver> {
        self.cfg.validate()?;
        let runner = runner_for(self.cfg.scheme, self.cfg.op)?;
        if let Some((f, _)) = &self.rhs {
            anyhow::ensure!(
                f.shape() == self.cfg.size,
                "rhs shape {:?} does not match the configured size {:?}",
                f.shape(),
                self.cfg.size
            );
        }
        let (nz, ny, nx) = self.cfg.size;
        let is_gs = self.cfg.scheme.is_gs();
        let (f, h2) = match self.rhs {
            Some(rhs) => rhs,
            // the Gauss-Seidel runners never read the rhs — keep the
            // placeholder tiny instead of materializing a dead N^3 grid
            None if is_gs => (Grid3::zeros(1, 1, 1), 1.0),
            None => (Grid3::zeros(nz, ny, nx), 1.0),
        };
        let mut pool = self.pool.unwrap_or_else(|| WorkerPool::new(0));
        let topo = self
            .cfg
            .machine_spec()
            .map(|m| Topology::of_machine(&m))
            .unwrap_or_else(Topology::host);
        // An SMT run with no explicit placement gets the sibling-pair
        // map: co-scheduled workers (adjacent ids — e.g. one GS
        // pipeline pair) share a core's two hardware threads, which is
        // the whole point of asking for SMT (Sec. 6). An explicit
        // policy always wins.
        let pin = if self.pin == PinPolicy::None && self.cfg.smt {
            PinPolicy::SmtPair
        } else {
            self.pin
        };
        match pin_hook(pin, topo) {
            Some(hook) => pool.set_start_hook(hook),
            // a reused pool may carry the previous session's hook
            None => pool.clear_start_hook(),
        }
        pool.ensure_workers(runner.team_size(&self.cfg));
        let op = match self.op {
            Some(op) => {
                anyhow::ensure!(
                    op.kind() == self.cfg.op,
                    "injected op instance is {:?} but the config asks for {:?}",
                    op.kind(),
                    self.cfg.op
                );
                op.as_dyn().validate_domain(self.cfg.size)?;
                op
            }
            None => self.cfg.op.instantiate(self.cfg.size),
        };
        Ok(Solver { cfg: self.cfg, runner, op, pool, f, h2 })
    }
}

/// A reusable execution session: config validated once, scheme resolved
/// from the registry, team spawned (and optionally pinned) once, scratch
/// owned by the pool and reused across every [`Solver::run`] call.
pub struct Solver {
    cfg: RunConfig,
    runner: &'static dyn SchemeRunner,
    /// The session's op instance (coefficient grids live here).
    op: OpInstance,
    pool: WorkerPool,
    f: Grid3,
    h2: f64,
}

impl Solver {
    /// Start building a session for `cfg` (the config is cloned; the
    /// builder seeds its pin policy from `cfg.pin`).
    pub fn builder(cfg: &RunConfig) -> SolverBuilder {
        SolverBuilder { pin: cfg.pin, cfg: cfg.clone(), pool: None, rhs: None, op: None }
    }

    /// The scheme this session executes.
    pub fn scheme(&self) -> Scheme {
        self.cfg.scheme
    }

    /// The stencil operator this session applies.
    pub fn op_kind(&self) -> OpKind {
        self.op.kind()
    }

    /// Workers the session's pool holds. Pool workers are never retired,
    /// so a `team_size` that stays constant across [`Solver::run`] calls
    /// proves the session spawned no new threads after
    /// [`SolverBuilder::build`] — the accounting the tests assert.
    pub fn team_size(&self) -> usize {
        self.pool.size()
    }

    /// Updates performed by one [`Solver::step`] — the scheme's natural
    /// pass (`t` for the temporally blocked schemes, 1 for baselines).
    pub fn step_iters(&self) -> usize {
        self.runner.step_iters(&self.cfg)
    }

    /// Perform `iters` updates of `u` in place on the session's team.
    ///
    /// `u` must have the session's configured size; schemes with a fixed
    /// pass granularity keep their divisibility requirement (`iters`
    /// a multiple of `t` for wavefront Jacobi — the same error the old
    /// `*_iters` entry points raised).
    pub fn run(&mut self, u: &mut Grid3, iters: usize) -> Result<()> {
        anyhow::ensure!(
            u.shape() == self.cfg.size,
            "grid shape {:?} does not match the session's configured size {:?}",
            u.shape(),
            self.cfg.size
        );
        self.runner.execute(&mut self.pool, &self.op, u, &self.f, self.h2, &self.cfg, iters)
    }

    /// One natural pass of the scheme ([`Solver::step_iters`] updates).
    pub fn step(&mut self, u: &mut Grid3) -> Result<()> {
        let iters = self.runner.step_iters(&self.cfg);
        self.run(u, iters)
    }

    /// The serial reference for `iters` updates from `u0` — what
    /// [`Solver::run`] must match bit-exactly.
    pub fn reference(&self, u0: &Grid3, iters: usize) -> Grid3 {
        self.runner.reference(&self.op, u0, &self.f, self.h2, &self.cfg, iters)
    }

    /// Modeled MLUP/s of this session's configuration on a Tab. 1
    /// machine (the scheme runner's performance-model leg).
    pub fn predict(&self, machine: &crate::simulator::machine::MachineSpec) -> f64 {
        self.runner.predict(machine, &self.cfg)
    }

    /// Tear the session down, returning the pool (team and scratch
    /// intact) for reuse by another session.
    pub fn into_pool(self) -> WorkerPool {
        self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wavefront::serial_reference;

    fn cfg(scheme: Scheme, size: (usize, usize, usize)) -> RunConfig {
        RunConfig { scheme, size, t: 4, groups: 2, iters: 4, ..Default::default() }
    }

    #[test]
    fn session_runs_and_matches_reference() {
        let c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        let f = Grid3::random(12, 10, 9, 5);
        let mut solver = Solver::builder(&c).rhs(f.clone(), 0.8).build().unwrap();
        let u0 = Grid3::random(12, 10, 9, 6);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = serial_reference(&u0, &f, 0.8, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn build_rejects_what_validate_rejects() {
        let mut c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        c.t = 3; // odd t
        let have = Solver::builder(&c).build().map(|_| ()).unwrap_err().to_string();
        let want = c.validate().unwrap_err().to_string();
        assert_eq!(have, want);
    }

    #[test]
    fn no_threads_spawned_after_build() {
        let c = cfg(Scheme::GsWavefront, (10, 12, 9));
        let mut solver = Solver::builder(&c).build().unwrap();
        let team = solver.team_size();
        assert_eq!(team, 4 * 2, "sweeps x width pre-spawned");
        for _ in 0..3 {
            let mut u = Grid3::random(10, 12, 9, 3);
            solver.run(&mut u, 8).unwrap();
            solver.step(&mut u).unwrap();
        }
        // workers are never retired, so an unchanged team size proves no
        // run() call spawned a thread
        assert_eq!(solver.team_size(), team);
    }

    #[test]
    fn wrong_grid_shape_is_rejected() {
        let c = cfg(Scheme::JacobiWavefront, (12, 10, 9));
        let mut solver = Solver::builder(&c).build().unwrap();
        let mut u = Grid3::random(8, 8, 8, 1);
        assert!(solver.run(&mut u, 4).is_err());
    }

    #[test]
    fn default_rhs_is_homogeneous() {
        let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let mut solver = Solver::builder(&c).build().unwrap();
        let u0 = Grid3::random(10, 9, 8, 2);
        let mut u = u0.clone();
        solver.run(&mut u, 4).unwrap();
        let want = serial_reference(&u0, &Grid3::zeros(10, 9, 8), 1.0, 4);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn mismatched_rhs_shape_is_rejected_at_build() {
        let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let bad = Grid3::zeros(8, 8, 8);
        assert!(Solver::builder(&c).rhs(bad, 1.0).build().is_err());
    }

    #[test]
    fn session_pool_carries_over_to_a_new_session() {
        let c1 = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        let mut s1 = Solver::builder(&c1).build().unwrap();
        let mut u = Grid3::random(10, 9, 8, 4);
        s1.run(&mut u, 4).unwrap();
        let pool = s1.into_pool();
        let carried = pool.size();
        // same team, different scheme: no new threads for a smaller team
        let c2 = cfg(Scheme::JacobiMultiGroup, (10, 9, 8));
        let mut s2 = Solver::builder(&c2).pool(pool).build().unwrap();
        let u0 = Grid3::random(10, 9, 8, 5);
        let mut v = u0.clone();
        s2.run(&mut v, 4).unwrap();
        let want = s2.reference(&u0, 4);
        assert_eq!(v.max_abs_diff(&want), 0.0);
        assert_eq!(s2.team_size(), carried);
    }

    #[test]
    fn sessions_run_every_op_through_every_scheme() {
        // the tentpole acceptance: both new ops execute through every
        // registered scheme and match their serial references bit-exactly
        for op in OpKind::ALL {
            for scheme in Scheme::ALL {
                let mut c = cfg(scheme, (14, 14, 12));
                c.op = op;
                let f = Grid3::random(14, 14, 12, 3);
                let mut solver = Solver::builder(&c).rhs(f, 0.9).build().unwrap();
                assert_eq!(solver.op_kind(), op);
                let u0 = Grid3::random(14, 14, 12, 4);
                let mut u = u0.clone();
                solver.run(&mut u, 4).unwrap();
                let want = solver.reference(&u0, 4);
                assert_eq!(u.max_abs_diff(&want), 0.0, "{scheme:?} x {op:?}");
            }
        }
    }

    #[test]
    fn injected_op_instances_are_checked_and_used() {
        // kind mismatch fails at build
        let mut c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
        c.op = OpKind::VarCoeff7;
        let wrong = OpKind::ConstLaplace7.instantiate((10, 9, 8));
        assert!(Solver::builder(&c).op(wrong).build().is_err());
        // a wrong-shape coefficient grid fails at build, not in a worker
        let bad = OpKind::VarCoeff7.instantiate((8, 8, 8));
        assert!(Solver::builder(&c).op(bad).build().is_err());
        // a matching instance is used verbatim: an offset slab instance
        // produces different (offset-field) values than the default
        let u0 = Grid3::random(10, 9, 8, 21);
        let mut plain = u0.clone();
        Solver::builder(&c).build().unwrap().run(&mut plain, 4).unwrap();
        let slab = OpKind::VarCoeff7.instantiate_at((10, 9, 8), 1);
        let mut shifted = u0.clone();
        Solver::builder(&c).op(slab).build().unwrap().run(&mut shifted, 4).unwrap();
        assert!(shifted.max_abs_diff(&plain) > 0.0, "offset coefficients must differ");
    }

    #[test]
    fn pinned_sessions_stay_bit_exact() {
        for pin in [PinPolicy::Compact, PinPolicy::Scatter, PinPolicy::SmtPair] {
            let c = cfg(Scheme::JacobiWavefront, (10, 9, 8));
            let mut solver = Solver::builder(&c).pin(pin).build().unwrap();
            let f = Grid3::zeros(10, 9, 8);
            let u0 = Grid3::random(10, 9, 8, 9);
            let mut u = u0.clone();
            solver.run(&mut u, 4).unwrap();
            let want = serial_reference(&u0, &f, 1.0, 4);
            assert_eq!(u.max_abs_diff(&want), 0.0, "{pin:?}");
        }
    }

    #[test]
    fn smt_runs_get_the_sibling_pair_placement_and_stay_bit_exact() {
        // the auto-promotion: smt + no explicit pin policy co-schedules
        // sibling pairs (placement is advisory, results stay bit-exact)
        let mut c = cfg(Scheme::GsWavefront, (10, 12, 9));
        c.smt = true;
        let mut solver = Solver::builder(&c).build().unwrap();
        let u0 = Grid3::random(10, 12, 9, 11);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = solver.reference(&u0, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
