//! Pipeline-parallel lexicographic Gauss-Seidel (paper Sec. 3, Fig. 5a),
//! generic over the [`StencilOp`] kernel layer.
//!
//! A straightforward domain decomposition cannot parallelize GS — the
//! update at a site needs *new* values at every minus-offset neighbor.
//! Instead of switching to red-black ordering, the paper pipelines the
//! *same* lexicographic algorithm: workers partition the y dimension into
//! contiguous chunks, and worker `p` starts plane `k` only after worker
//! `p-1` has finished plane `k` — so worker p's first lines read worker
//! p-1's freshly updated last lines (up to `R` of them for halo radius
//! `R`), and worker p+1's chunk is still untouched (old values) when
//! worker p reads across its upper edge. Plane updates of the workers are
//! thereby "shifted in time" exactly as Fig. 5a shows, and the result is
//! **bit-identical** to the serial sweep — at any radius: the wait
//! condition ("previous worker finished this plane") already freezes the
//! full `R`-line halo on both chunk edges.
//!
//! The pass is a [`Schedule`] dispatched on the persistent
//! [`WorkerPool`](super::pool::WorkerPool) (or one tenant's
//! [`PoolSegment`](super::pool::PoolSegment) window of it); multi-sweep
//! runs reuse one team and one schedule.

use std::marker::PhantomData;

use crate::stencil::gauss_seidel::GsKernel;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{op_gs_line_raw, op_gs_sweep, StencilOp};
use crate::Result;

use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};

/// Configuration of a pipeline-parallel GS run.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Workers = y-chunks.
    pub threads: usize,
    pub kernel: GsKernel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { threads: 4, kernel: GsKernel::Interleaved }
    }
}

impl PipelineConfig {
    /// Validate the configuration (`threads >= 1` guards the chunking
    /// divide — a zero thread count used to panic in [`chunk_lines`]).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.threads >= 1,
            "pipeline needs at least one thread, got {}",
            self.threads
        );
        Ok(())
    }
}

/// Split the interior lines `r..ny-r` into `p` contiguous chunks.
///
/// Returns `(start, end)` half-open ranges; empty chunks allowed when
/// `p` exceeds the interior line count (those workers simply keep pace
/// in the pipeline), and an empty vector for `p == 0` (rejected earlier
/// by [`PipelineConfig::validate`]).
pub fn chunk_lines_r(ny: usize, p: usize, r: usize) -> Vec<(usize, usize)> {
    if p == 0 {
        return Vec::new();
    }
    let interior = ny.saturating_sub(2 * r);
    let base = interior / p;
    let extra = interior % p;
    let mut out = Vec::with_capacity(p);
    let mut start = r;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// [`chunk_lines_r`] for the paper's radius-1 stencils.
pub fn chunk_lines(ny: usize, p: usize) -> Vec<(usize, usize)> {
    chunk_lines_r(ny, p, 1)
}

/// One pipelined GS sweep of `op` as a [`Schedule`]: worker `p` owns
/// y-chunk `p`.
pub struct PipelineGsSchedule<'g, O: StencilOp> {
    op: &'g O,
    base: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    r: usize,
    chunks: Vec<(usize, usize)>,
    kernel: GsKernel,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: chunks are disjoint line ranges and the progress protocol
// freezes every cross-chunk read (see `worker`).
unsafe impl<O: StencilOp> Send for PipelineGsSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for PipelineGsSchedule<'_, O> {}

impl<'g, O: StencilOp> PipelineGsSchedule<'g, O> {
    /// Build one sweep over `u`.
    pub fn new(op: &'g O, u: &'g mut Grid3, cfg: &PipelineConfig) -> Result<Self> {
        cfg.validate()?;
        let r = op.radius();
        anyhow::ensure!(
            r >= 1 && r <= crate::stencil::op::MAX_RADIUS,
            "unsupported halo radius {r}"
        );
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} pipelined sweep"
        );
        Ok(Self {
            op,
            base: u.data_mut().as_mut_ptr(),
            nz,
            ny,
            nx,
            r,
            chunks: chunk_lines_r(ny, cfg.threads, r),
            kernel: cfg.kernel,
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for PipelineGsSchedule<'_, O> {
    fn workers(&self) -> usize {
        self.chunks.len()
    }

    fn worker(&self, tid: usize, progress: &Progress) {
        let (j0, j1) = self.chunks[tid];
        let r = self.r;
        for k in r..self.nz - r {
            if tid > 0 {
                // worker p-1 must have completed this plane so our first
                // lines see its new last lines, and it stopped reading
                // across our lower edge.
                progress.wait_min(tid - 1, k as isize);
            }
            // SAFETY: chunks are disjoint line ranges; the progress
            // protocol guarantees the only cross-chunk reads (the R
            // lines below = new, the R lines above = old) are race-free:
            // below has finished plane k, above has not started it.
            unsafe {
                for j in j0..j1 {
                    op_gs_line_raw(self.op, self.base, self.ny, self.nx, k, j, self.kernel);
                }
            }
            progress.publish(tid, k as isize);
        }
    }
}

/// Run `passes` pipelined sweeps of `op` on `pool` with one schedule —
/// the pool-level entry point the [`SchemeRunner`] registry, tests and
/// benches drive.
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn pipeline_gs_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    cfg: &PipelineConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    if cfg.threads == 1 {
        for _ in 0..passes {
            op_gs_sweep(op, u, cfg.kernel);
        }
        return Ok(());
    }
    let schedule = PipelineGsSchedule::new(op, u, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::stencil::gauss_seidel::gs_sweep;
    use crate::stencil::op::{op_gs_sweeps, ConstLaplace7, Laplace13};

    fn run_pipeline<O: StencilOp>(op: &O, u: &mut Grid3, cfg: &PipelineConfig, n: usize) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        pipeline_gs_passes(&mut pool, op, u, cfg, n)
    }

    fn check(nz: usize, ny: usize, nx: usize, threads: usize) {
        let mut u = Grid3::random(nz, ny, nx, 31);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Interleaved);
        let cfg = PipelineConfig { threads, kernel: GsKernel::Interleaved };
        run_pipeline(&ConstLaplace7, &mut u, &cfg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} p={threads}");
    }

    #[test]
    fn bit_identical_small_thread_counts() {
        for p in 1..=4 {
            check(8, 10, 9, p);
        }
    }

    #[test]
    fn bit_identical_many_threads() {
        check(6, 20, 8, 6);
        check(6, 9, 8, 8); // more threads than can be busy
        check(5, 5, 5, 7); // p > interior lines: some chunks empty
    }

    #[test]
    fn radius2_pipeline_matches_serial() {
        for threads in [1usize, 2, 3, 5] {
            let mut u = Grid3::random(8, 12, 9, 41);
            let mut want = u.clone();
            op_gs_sweeps(&Laplace13, &mut want, 1, GsKernel::Interleaved);
            let cfg = PipelineConfig { threads, kernel: GsKernel::Interleaved };
            run_pipeline(&Laplace13, &mut u, &cfg, 1).unwrap();
            assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 p={threads}");
        }
    }

    #[test]
    fn chunks_partition_interior() {
        for (ny, p) in [(10, 3), (20, 6), (5, 8), (3, 2)] {
            let ch = chunk_lines(ny, p);
            assert_eq!(ch.len(), p);
            assert_eq!(ch[0].0, 1);
            assert_eq!(ch.last().unwrap().1, ny - 1);
            for w in ch.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
        // radius-2 chunks cover r..ny-r
        let ch = chunk_lines_r(11, 3, 2);
        assert_eq!(ch[0].0, 2);
        assert_eq!(ch.last().unwrap().1, 9);
    }

    #[test]
    fn zero_threads_is_an_error_not_a_panic() {
        assert!(chunk_lines(10, 0).is_empty());
        let mut u = Grid3::random(6, 8, 7, 1);
        let cfg = PipelineConfig { threads: 0, kernel: GsKernel::Interleaved };
        assert!(cfg.validate().is_err());
        assert!(run_pipeline(&ConstLaplace7, &mut u, &cfg, 1).is_err());
    }

    #[test]
    fn multi_sweep_matches_serial() {
        let mut u = Grid3::random(7, 12, 8, 55);
        let mut want = u.clone();
        for _ in 0..3 {
            gs_sweep(&mut want, GsKernel::Interleaved);
        }
        run_pipeline(&ConstLaplace7, &mut u, &PipelineConfig { threads: 3, ..Default::default() }, 3)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn naive_kernel_also_exact() {
        let mut u = Grid3::random(6, 8, 7, 3);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Naive);
        run_pipeline(&ConstLaplace7, &mut u, &PipelineConfig { threads: 3, kernel: GsKernel::Naive }, 1)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
