//! Pipeline-parallel lexicographic Gauss-Seidel (paper Sec. 3, Fig. 5a).
//!
//! A straightforward domain decomposition cannot parallelize GS — the
//! update at `(k, j, i)` needs *new* values at `(k-1, j, i)`, `(k, j-1, i)`
//! and `(k, j, i-1)`. Instead of switching to red-black ordering, the
//! paper pipelines the *same* lexicographic algorithm: threads partition
//! the y dimension into contiguous chunks, and thread `p` starts plane `k`
//! only after thread `p-1` has finished plane `k` — so thread p's first
//! line reads thread p-1's freshly updated last line, and thread p+1's
//! chunk is still untouched (old values) when thread p reads across its
//! upper edge. Plane updates of the threads are thereby "shifted in time"
//! exactly as Fig. 5a shows, and the result is **bit-identical** to the
//! serial sweep.

use std::sync::atomic::{AtomicIsize, Ordering};

use crate::stencil::gauss_seidel::{gs_plane_line_raw, gs_sweep, GsKernel};
use crate::stencil::grid::Grid3;
use crate::Result;

/// Configuration of a pipeline-parallel GS run.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Threads = y-chunks.
    pub threads: usize,
    pub kernel: GsKernel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { threads: 4, kernel: GsKernel::Interleaved }
    }
}

/// Split `1..ny-1` interior lines into `p` contiguous chunks.
///
/// Returns `(start, end)` half-open ranges; empty chunks allowed when
/// `p > ny - 2` (those threads simply keep pace in the pipeline).
pub fn chunk_lines(ny: usize, p: usize) -> Vec<(usize, usize)> {
    let interior = ny.saturating_sub(2);
    let base = interior / p;
    let extra = interior % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 1;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[derive(Clone, Copy)]
struct SharedPtr(*mut f64);
unsafe impl Send for SharedPtr {}
unsafe impl Sync for SharedPtr {}

impl SharedPtr {
    /// Accessor (method, not field) so closures capture the whole wrapper
    /// — RFC 2229 disjoint capture would otherwise capture the bare
    /// pointer, which is not `Send`.
    #[inline(always)]
    fn get(self) -> *mut f64 {
        self.0
    }
}

/// One in-place lexicographic GS sweep, pipeline-parallel over y-chunks.
///
/// Bit-identical to [`gs_sweep`] for every thread count.
pub fn pipeline_gs_sweep(u: &mut Grid3, cfg: &PipelineConfig) -> Result<()> {
    let p = cfg.threads;
    anyhow::ensure!(p >= 1, "need at least one thread");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 {
        return Ok(());
    }
    if p == 1 {
        gs_sweep(u, cfg.kernel);
        return Ok(());
    }
    let chunks = chunk_lines(ny, p);
    let progress: Vec<AtomicIsize> = (0..p).map(|_| AtomicIsize::new(0)).collect();
    let base = SharedPtr(u.data_mut().as_mut_ptr());
    let kernel = cfg.kernel;

    std::thread::scope(|scope| {
        for (tid, &(j0, j1)) in chunks.iter().enumerate() {
            let progress = &progress;
            let ptr = base;
            scope.spawn(move || {
                for k in 1..nz - 1 {
                    if tid > 0 {
                        // thread p-1 must have completed this plane so our
                        // first line sees its new last line, and it stopped
                        // reading across our lower edge.
                        super::barrier::spin_wait(|| {
                            progress[tid - 1].load(Ordering::Acquire) >= k as isize
                        });
                    }
                    // SAFETY: chunks are disjoint line ranges; the progress
                    // protocol guarantees the only cross-chunk reads (j0-1
                    // from below = new, j1 from above = old) are race-free:
                    // below has finished plane k, above has not started it.
                    unsafe {
                        for j in j0..j1 {
                            gs_plane_line_raw(ptr.get(), ny, nx, k, j, kernel);
                        }
                    }
                    progress[tid].store(k as isize, Ordering::Release);
                }
            });
        }
    });
    Ok(())
}

/// `n` pipelined sweeps.
pub fn pipeline_gs_sweeps(u: &mut Grid3, cfg: &PipelineConfig, n: usize) -> Result<()> {
    for _ in 0..n {
        pipeline_gs_sweep(u, cfg)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(nz: usize, ny: usize, nx: usize, threads: usize) {
        let mut u = Grid3::random(nz, ny, nx, 31);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Interleaved);
        let cfg = PipelineConfig { threads, kernel: GsKernel::Interleaved };
        pipeline_gs_sweep(&mut u, &cfg).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} p={threads}");
    }

    #[test]
    fn bit_identical_small_thread_counts() {
        for p in 1..=4 {
            check(8, 10, 9, p);
        }
    }

    #[test]
    fn bit_identical_many_threads() {
        check(6, 20, 8, 6);
        check(6, 9, 8, 8); // more threads than can be busy
        check(5, 5, 5, 7); // p > interior lines: some chunks empty
    }

    #[test]
    fn chunks_partition_interior() {
        for (ny, p) in [(10, 3), (20, 6), (5, 8), (3, 2)] {
            let ch = chunk_lines(ny, p);
            assert_eq!(ch.len(), p);
            assert_eq!(ch[0].0, 1);
            assert_eq!(ch.last().unwrap().1, ny - 1);
            for w in ch.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn multi_sweep_matches_serial() {
        let mut u = Grid3::random(7, 12, 8, 55);
        let mut want = u.clone();
        for _ in 0..3 {
            gs_sweep(&mut want, GsKernel::Interleaved);
        }
        pipeline_gs_sweeps(&mut u, &PipelineConfig { threads: 3, ..Default::default() }, 3)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn naive_kernel_also_exact() {
        let mut u = Grid3::random(6, 8, 7, 3);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Naive);
        pipeline_gs_sweep(&mut u, &PipelineConfig { threads: 3, kernel: GsKernel::Naive })
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
