//! Pipeline-parallel lexicographic Gauss-Seidel (paper Sec. 3, Fig. 5a).
//!
//! A straightforward domain decomposition cannot parallelize GS — the
//! update at `(k, j, i)` needs *new* values at `(k-1, j, i)`, `(k, j-1, i)`
//! and `(k, j, i-1)`. Instead of switching to red-black ordering, the
//! paper pipelines the *same* lexicographic algorithm: workers partition
//! the y dimension into contiguous chunks, and worker `p` starts plane `k`
//! only after worker `p-1` has finished plane `k` — so worker p's first
//! line reads worker p-1's freshly updated last line, and worker p+1's
//! chunk is still untouched (old values) when worker p reads across its
//! upper edge. Plane updates of the workers are thereby "shifted in time"
//! exactly as Fig. 5a shows, and the result is **bit-identical** to the
//! serial sweep.
//!
//! The pass is a [`Schedule`] dispatched on the persistent
//! [`WorkerPool`]; multi-sweep runs reuse one team and one schedule.

use std::marker::PhantomData;

use crate::stencil::gauss_seidel::{gs_plane_line_raw, gs_sweep, GsKernel};
use crate::stencil::grid::Grid3;
use crate::Result;

use super::pool::{self, WorkerPool};
use super::schedule::{Progress, Schedule};

/// Configuration of a pipeline-parallel GS run.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Workers = y-chunks.
    pub threads: usize,
    pub kernel: GsKernel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { threads: 4, kernel: GsKernel::Interleaved }
    }
}

impl PipelineConfig {
    /// Validate the configuration (`threads >= 1` guards the chunking
    /// divide — a zero thread count used to panic in [`chunk_lines`]).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.threads >= 1,
            "pipeline needs at least one thread, got {}",
            self.threads
        );
        Ok(())
    }
}

/// Split `1..ny-1` interior lines into `p` contiguous chunks.
///
/// Returns `(start, end)` half-open ranges; empty chunks allowed when
/// `p > ny - 2` (those workers simply keep pace in the pipeline), and an
/// empty vector for `p == 0` (rejected earlier by
/// [`PipelineConfig::validate`]).
pub fn chunk_lines(ny: usize, p: usize) -> Vec<(usize, usize)> {
    if p == 0 {
        return Vec::new();
    }
    let interior = ny.saturating_sub(2);
    let base = interior / p;
    let extra = interior % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 1;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// One pipelined GS sweep as a [`Schedule`]: worker `p` owns y-chunk `p`.
pub struct PipelineGsSchedule<'g> {
    base: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    chunks: Vec<(usize, usize)>,
    kernel: GsKernel,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: chunks are disjoint line ranges and the progress protocol
// freezes every cross-chunk read (see `worker`).
unsafe impl Send for PipelineGsSchedule<'_> {}
unsafe impl Sync for PipelineGsSchedule<'_> {}

impl<'g> PipelineGsSchedule<'g> {
    /// Build one sweep over `u`.
    pub fn new(u: &'g mut Grid3, cfg: &PipelineConfig) -> Result<Self> {
        cfg.validate()?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(nz >= 3 && ny >= 3 && nx >= 3, "grid too small for a pipelined sweep");
        Ok(Self {
            base: u.data_mut().as_mut_ptr(),
            nz,
            ny,
            nx,
            chunks: chunk_lines(ny, cfg.threads),
            kernel: cfg.kernel,
            _borrow: PhantomData,
        })
    }
}

impl Schedule for PipelineGsSchedule<'_> {
    fn workers(&self) -> usize {
        self.chunks.len()
    }

    fn worker(&self, tid: usize, progress: &Progress) {
        let (j0, j1) = self.chunks[tid];
        for k in 1..self.nz - 1 {
            if tid > 0 {
                // worker p-1 must have completed this plane so our first
                // line sees its new last line, and it stopped reading
                // across our lower edge.
                progress.wait_min(tid - 1, k as isize);
            }
            // SAFETY: chunks are disjoint line ranges; the progress
            // protocol guarantees the only cross-chunk reads (j0-1 from
            // below = new, j1 from above = old) are race-free: below has
            // finished plane k, above has not started it.
            unsafe {
                for j in j0..j1 {
                    gs_plane_line_raw(self.base, self.ny, self.nx, k, j, self.kernel);
                }
            }
            progress.publish(tid, k as isize);
        }
    }
}

/// Run `passes` pipelined sweeps on `pool` with one schedule.
pub(crate) fn pipeline_gs_passes(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    cfg: &PipelineConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 || passes == 0 {
        return Ok(());
    }
    if cfg.threads == 1 {
        for _ in 0..passes {
            gs_sweep(u, cfg.kernel);
        }
        return Ok(());
    }
    let schedule = PipelineGsSchedule::new(u, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

/// One in-place lexicographic GS sweep, pipeline-parallel over y-chunks.
///
/// Bit-identical to [`gs_sweep`] for every thread count.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn pipeline_gs_sweep(u: &mut Grid3, cfg: &PipelineConfig) -> Result<()> {
    pool::with_local(|p| pipeline_gs_passes(p, u, cfg, 1))
}

/// [`pipeline_gs_sweep`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn pipeline_gs_sweep_on(pool: &mut WorkerPool, u: &mut Grid3, cfg: &PipelineConfig) -> Result<()> {
    pipeline_gs_passes(pool, u, cfg, 1)
}

/// `n` pipelined sweeps on one persistent team.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn pipeline_gs_sweeps(u: &mut Grid3, cfg: &PipelineConfig, n: usize) -> Result<()> {
    pool::with_local(|p| pipeline_gs_passes(p, u, cfg, n))
}

/// [`pipeline_gs_sweeps`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn pipeline_gs_sweeps_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    cfg: &PipelineConfig,
    n: usize,
) -> Result<()> {
    pipeline_gs_passes(pool, u, cfg, n)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim matrix stays covered until removal

    use super::*;

    fn check(nz: usize, ny: usize, nx: usize, threads: usize) {
        let mut u = Grid3::random(nz, ny, nx, 31);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Interleaved);
        let cfg = PipelineConfig { threads, kernel: GsKernel::Interleaved };
        pipeline_gs_sweep(&mut u, &cfg).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} p={threads}");
    }

    #[test]
    fn bit_identical_small_thread_counts() {
        for p in 1..=4 {
            check(8, 10, 9, p);
        }
    }

    #[test]
    fn bit_identical_many_threads() {
        check(6, 20, 8, 6);
        check(6, 9, 8, 8); // more threads than can be busy
        check(5, 5, 5, 7); // p > interior lines: some chunks empty
    }

    #[test]
    fn chunks_partition_interior() {
        for (ny, p) in [(10, 3), (20, 6), (5, 8), (3, 2)] {
            let ch = chunk_lines(ny, p);
            assert_eq!(ch.len(), p);
            assert_eq!(ch[0].0, 1);
            assert_eq!(ch.last().unwrap().1, ny - 1);
            for w in ch.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn zero_threads_is_an_error_not_a_panic() {
        assert!(chunk_lines(10, 0).is_empty());
        let mut u = Grid3::random(6, 8, 7, 1);
        let cfg = PipelineConfig { threads: 0, kernel: GsKernel::Interleaved };
        assert!(cfg.validate().is_err());
        assert!(pipeline_gs_sweep(&mut u, &cfg).is_err());
    }

    #[test]
    fn multi_sweep_matches_serial() {
        let mut u = Grid3::random(7, 12, 8, 55);
        let mut want = u.clone();
        for _ in 0..3 {
            gs_sweep(&mut want, GsKernel::Interleaved);
        }
        pipeline_gs_sweeps(&mut u, &PipelineConfig { threads: 3, ..Default::default() }, 3)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn naive_kernel_also_exact() {
        let mut u = Grid3::random(6, 8, 7, 3);
        let mut want = u.clone();
        gs_sweep(&mut want, GsKernel::Naive);
        pipeline_gs_sweep(&mut u, &PipelineConfig { threads: 3, kernel: GsKernel::Naive })
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }
}
