//! The distributed rank layer: multicore temporal blocks *inside* ranks,
//! overlapped halo exchange *between* them.
//!
//! The cluster-scale follow-ups to the source paper (arXiv:0912.4506,
//! arXiv:1006.3148) wrap the multicore wavefront schemes in a domain
//! decomposition: each process advances a whole temporal block over its
//! subdomain, then trades deep halos with its neighbors, so the network
//! sees one exchange per `t` sweeps instead of one per sweep. This
//! module reproduces that layer without MPI: a [`RankSet`] shards the
//! z axis across N *ranks* — threads over shared memory by default,
//! loopback sockets behind the same [`Transport`] trait to prove
//! nothing assumes shared memory — each rank owning a full
//! [`Solver`] session that runs any registered [`Scheme`] on its slab.
//!
//! ## The halo-depth rule
//!
//! * **Jacobi family** (out of place): ghost depth `rank_step · R` per
//!   interior interface. A rank receives that many planes, advances a
//!   whole temporal block of `rank_step` sweeps treating its slab edges
//!   as frozen, and the stale contamination creeping in from the frozen
//!   shell at `R` planes per sweep stays strictly inside the ghosts —
//!   the owned planes are bit-exact by the `depth ≥ step · R` bound
//!   (ghost planes are recomputed redundantly and overwritten by the
//!   next exchange).
//! * **Gauss-Seidel family** (in place, lexicographic): deep halos are
//!   *unsound* — the new-value recursion would propagate a stale
//!   lower-edge plane through the entire subdomain in one sweep. These
//!   schemes exchange `R` planes per sweep in a pipeline: rank `i`
//!   starts sweep `s` once its left neighbor's sweep-`s` top planes
//!   arrive (new values), reading its right neighbor's sweep-`s−1`
//!   bottom planes (old values) — exactly the serial update order, at
//!   rank granularity. This is [`gs_multigroup`](super::gs_multigroup)'s
//!   two-sided watermark protocol lifted from y-blocks to z shards.
//!
//! Both protocols overlap communication with compute: sends are posted
//! asynchronously right after the producing sweep, so they are in
//! flight while the sender (and, pipeline-skewed, the receiver) works
//! on interior planes; only the boundary read at the top of the next
//! block actually gates. The [`HaloExchange`] engine counts how often
//! that gate was already open (`overlapped_recvs`) versus an exposed
//! wait (`stalled_recvs`) — the observable the overlap test asserts.
//!
//! Faults surface, they never deadlock: each rank body runs under
//! `catch_unwind`, a dying rank drops its transport endpoint, and every
//! neighbor blocked on it gets a typed [`CommError::Disconnected`]
//! through the fabric instead of waiting forever.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use anyhow::anyhow;

use crate::comm::{
    CommError, HaloExchange, HaloStats, Peer, SharedHaloStats, SharedMemTransport,
    SocketTransport, Transport,
};
use crate::config::RunConfig;
use crate::simulator::ecm::{KernelProfile, Prediction};
use crate::simulator::machine::MachineSpec;
use crate::simulator::perfmodel::{rank_prediction, WavefrontParams};
use crate::stencil::grid::Grid3;
use crate::Result;

use super::solver::Solver;

/// One rank's slice of the z axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// First *owned* global plane.
    pub z0: usize,
    /// Owned plane count.
    pub planes: usize,
    /// Ghost planes below `z0` (the true `R`-deep Dirichlet shell on
    /// rank 0, `depth` exchanged planes on interior interfaces).
    pub d_lo: usize,
    /// Ghost planes above `z0 + planes`.
    pub d_hi: usize,
}

impl Shard {
    /// First global plane of the local slab (owned minus low ghosts).
    pub fn slab_z0(&self) -> usize {
        self.z0 - self.d_lo
    }

    /// z extent of the local slab.
    pub fn local_nz(&self) -> usize {
        self.d_lo + self.planes + self.d_hi
    }
}

/// The z-axis decomposition: interior planes dealt contiguously across
/// ranks (remainder planes to the lowest ranks), every rank's slab
/// extended by its ghost shells.
#[derive(Clone, Debug)]
pub struct RankLayout {
    /// Operator halo radius `R`.
    pub radius: usize,
    /// Ghost depth per interior interface side (the halo-depth rule).
    pub depth: usize,
    /// Per-rank shards, ascending in z.
    pub shards: Vec<Shard>,
}

impl RankLayout {
    /// The layout a configuration implies (validated by
    /// [`RankWidthError`](crate::config::RankWidthError) in
    /// `RunConfig::validate`).
    pub fn of(cfg: &RunConfig) -> Self {
        Self::partition(cfg.size.0, cfg.op.radius(), cfg.halo_depth(), cfg.ranks)
    }

    /// Partition `nz - 2·radius` interior planes across `ranks` shards
    /// with `depth` ghost planes per interior interface side.
    pub fn partition(nz: usize, radius: usize, depth: usize, ranks: usize) -> Self {
        assert!(ranks >= 1, "need at least one rank");
        let interior = nz - 2 * radius;
        let base = interior / ranks;
        let rem = interior % ranks;
        let mut z0 = radius;
        let shards = (0..ranks)
            .map(|i| {
                let planes = base + usize::from(i < rem);
                let shard = Shard {
                    z0,
                    planes,
                    d_lo: if i == 0 { radius } else { depth },
                    d_hi: if i + 1 == ranks { radius } else { depth },
                };
                z0 += planes;
                shard
            })
            .collect();
        Self { radius, depth, shards }
    }

    /// Number of ranks.
    pub fn ranks(&self) -> usize {
        self.shards.len()
    }
}

/// Which fabric wires the ranks together.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FabricKind {
    /// mpsc channels between rank threads (the default).
    #[default]
    SharedMem,
    /// Loopback TCP with framed messages — same protocol, no shared
    /// memory between the endpoints' payloads.
    SocketLocal,
}

/// Builder for a [`RankSet`], mirroring [`Solver::builder`].
pub struct RankSetBuilder {
    cfg: RunConfig,
    rhs: Option<(Grid3, f64)>,
    fabric: FabricKind,
}

impl RankSetBuilder {
    /// Right-hand side `f` and mesh factor `h2` for the Jacobi schemes
    /// (each rank receives the matching slab slice).
    pub fn rhs(mut self, f: Grid3, h2: f64) -> Self {
        self.rhs = Some((f, h2));
        self
    }

    /// Select the communication fabric (default shared-memory channels).
    pub fn fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    /// Validate the configuration, lay out the shards, and build one
    /// solver session per rank — each with a slab-offset op instance
    /// (coefficients evaluated in *global* coordinates) and its slice
    /// of the rhs. The fabric itself is wired lazily on first run.
    pub fn build(self) -> Result<RankSet> {
        self.cfg.validate()?;
        if let Some((f, _)) = &self.rhs {
            anyhow::ensure!(
                f.shape() == self.cfg.size,
                "rhs shape {:?} does not match the configured size {:?}",
                f.shape(),
                self.cfg.size
            );
        }
        let (nz, ny, nx) = self.cfg.size;
        let layout = RankLayout::of(&self.cfg);
        let (f, h2) = self.rhs.unwrap_or_else(|| (Grid3::zeros(nz, ny, nx), 1.0));
        let gs = self.cfg.scheme.is_gs();
        let mut solvers = Vec::with_capacity(layout.ranks());
        let mut locals = Vec::with_capacity(layout.ranks());
        for shard in &layout.shards {
            let local_size = (shard.local_nz(), ny, nx);
            let mut inner = self.cfg.clone();
            inner.size = local_size;
            inner.ranks = 1;
            let mut b = Solver::builder(&inner)
                .op(self.cfg.op.instantiate_at(local_size, shard.slab_z0()));
            if !gs {
                let mut f_slab = Grid3::zeros(local_size.0, local_size.1, local_size.2);
                let s = f.idx(shard.slab_z0(), 0, 0);
                f_slab.data_mut().copy_from_slice(&f.data()[s..s + local_size.0 * ny * nx]);
                b = b.rhs(f_slab, h2);
            }
            solvers.push(b.build()?);
            locals.push(Grid3::zeros(local_size.0, ny, nx));
        }
        let ranks = layout.ranks();
        Ok(RankSet {
            cfg: self.cfg,
            layout,
            solvers,
            locals,
            fabric: (0..ranks).map(|_| None).collect(),
            fabric_kind: self.fabric,
            stats: SharedHaloStats::new(),
            delays: vec![Duration::ZERO; ranks],
            faults: vec![None; ranks],
            f,
            h2,
        })
    }
}

/// A set of rank sessions coupled by halo exchange: the distributed
/// counterpart of one [`Solver`]. `run` scatters the global grid into
/// per-rank slabs, drives every rank concurrently under its exchange
/// protocol, and gathers the owned planes back — bit-exact with the
/// single-rank solve for every scheme × op.
pub struct RankSet {
    cfg: RunConfig,
    layout: RankLayout,
    solvers: Vec<Solver>,
    locals: Vec<Grid3>,
    fabric: Vec<Option<HaloExchange>>,
    fabric_kind: FabricKind,
    stats: Arc<SharedHaloStats>,
    delays: Vec<Duration>,
    faults: Vec<Option<usize>>,
    f: Grid3,
    h2: f64,
}

impl RankSet {
    /// Start building a rank set for `cfg` (`cfg.ranks` shards).
    pub fn builder(cfg: &RunConfig) -> RankSetBuilder {
        RankSetBuilder { cfg: cfg.clone(), rhs: None, fabric: FabricKind::default() }
    }

    /// Number of ranks.
    pub fn ranks(&self) -> usize {
        self.layout.ranks()
    }

    /// The z decomposition.
    pub fn layout(&self) -> &RankLayout {
        &self.layout
    }

    /// Halo-traffic counters of the most recent [`RankSet::run`].
    pub fn halo_stats(&self) -> HaloStats {
        self.stats.snapshot()
    }

    /// Artificially slow `rank`'s compute by `delay` per temporal block
    /// — a skew hook for demonstrating that neighbor messages land
    /// while a rank computes (its receives then count as overlapped).
    pub fn set_compute_delay(&mut self, rank: usize, delay: Duration) {
        self.delays[rank] = delay;
    }

    /// Inject a fault: `rank` panics at the start of temporal block
    /// `block` (1-based). Its neighbors must surface
    /// [`CommError::Disconnected`], not deadlock. The fabric is rebuilt
    /// on the next run; clear with [`RankSet::clear_fault`].
    pub fn set_fault(&mut self, rank: usize, block: usize) {
        self.faults[rank] = Some(block);
    }

    /// Remove an injected fault.
    pub fn clear_fault(&mut self, rank: usize) {
        self.faults[rank] = None;
    }

    /// Perform `iters` updates of `u` in place across all ranks.
    ///
    /// On error (rank panic, peer disconnect, protocol violation) `u`
    /// is left untouched — owned planes are only gathered back after
    /// every rank finished cleanly.
    pub fn run(&mut self, u: &mut Grid3, iters: usize) -> Result<()> {
        anyhow::ensure!(
            u.shape() == self.cfg.size,
            "grid shape {:?} does not match the configured size {:?}",
            u.shape(),
            self.cfg.size
        );
        if iters == 0 {
            return Ok(());
        }
        if self.ranks() == 1 {
            return self.solvers[0].run(u, iters);
        }
        let gs = self.cfg.scheme.is_gs();
        let step = self.cfg.rank_step();
        let (passes, per_pass) = if gs {
            (iters, 1)
        } else {
            anyhow::ensure!(
                iters % step == 0,
                "iters = {iters} must be a multiple of the temporal block depth t = {step}"
            );
            (iters / step, step)
        };
        self.ensure_fabric()?;
        self.stats.reset();
        for (shard, local) in self.layout.shards.iter().zip(&mut self.locals) {
            let s = u.idx(shard.slab_z0(), 0, 0);
            local.data_mut().copy_from_slice(&u.data()[s..s + local.len()]);
        }
        let delays = &self.delays;
        let faults = &self.faults;
        let shards = &self.layout.shards;
        let results: Vec<(Result<()>, Option<HaloExchange>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .solvers
                .iter_mut()
                .zip(self.locals.iter_mut())
                .zip(self.fabric.iter_mut())
                .enumerate()
                .map(|(rank, ((solver, local), slot))| {
                    let engine = slot.take().expect("fabric wired by ensure_fabric");
                    let task = RankTask {
                        solver,
                        local,
                        shard: shards[rank],
                        gs,
                        passes,
                        per_pass,
                        delay: delays[rank],
                        fault: faults[rank],
                    };
                    scope.spawn(move || {
                        // the engine moves *into* the unwind scope so a
                        // panicking rank drops its endpoint — that is
                        // what turns neighbors' blocked receives into
                        // typed Disconnected errors instead of deadlock
                        match catch_unwind(AssertUnwindSafe(move || {
                            let mut engine = engine;
                            let r = drive_rank(task, &mut engine);
                            (r, engine)
                        })) {
                            Ok((r, engine)) => (r, Some(engine)),
                            Err(payload) => {
                                (Err(anyhow!("rank {rank} panicked: {}", panic_text(&payload))), None)
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank wrapper is panic-free")).collect()
        });
        let mut comm_err = None;
        let mut other_err = None;
        for (rank, (res, engine)) in results.into_iter().enumerate() {
            match res {
                Ok(()) => self.fabric[rank] = engine,
                Err(e) => {
                    if comm_err.is_none() && e.downcast_ref::<CommError>().is_some() {
                        comm_err = Some(e);
                    } else if other_err.is_none() {
                        other_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = comm_err.or(other_err) {
            // some endpoint died: the surviving half-open channels are
            // useless, force a full rebuild on the next run
            self.fabric.iter_mut().for_each(|slot| *slot = None);
            return Err(e);
        }
        for (shard, local) in self.layout.shards.iter().zip(&self.locals) {
            let src = local.idx(shard.d_lo, 0, 0);
            let dst = u.idx(shard.z0, 0, 0);
            let n = shard.planes * u.ny * u.nx;
            u.data_mut()[dst..dst + n].copy_from_slice(&local.data()[src..src + n]);
        }
        Ok(())
    }

    /// The serial reference [`RankSet::run`] must match bit-exactly
    /// (the single-rank scheme reference on the full domain).
    pub fn reference(&self, u0: &Grid3, iters: usize) -> Grid3 {
        let mut cfg = self.cfg.clone();
        cfg.ranks = 1;
        let mut b = Solver::builder(&cfg);
        if !cfg.scheme.is_gs() {
            b = b.rhs(self.f.clone(), self.h2);
        }
        b.build().expect("cfg already validated").reference(u0, iters)
    }

    /// Modeled MLUP/s on a Tab. 1 machine: the multigroup model plus
    /// the halo-traffic leg (`(ranks × groups × t)` accounting).
    pub fn predict(&self, machine: &MachineSpec) -> Prediction {
        let p = WavefrontParams {
            t: self.cfg.t,
            groups: self.cfg.groups,
            smt: self.cfg.smt,
            kernel: self.cfg.scheme.kernel(self.cfg.optimized_kernel),
            store: self.cfg.store_mode(),
            barrier: self.cfg.barrier,
        };
        let profile = KernelProfile::of_op(
            self.cfg.op,
            self.cfg.scheme.is_gs(),
            self.cfg.optimized_kernel,
            machine.arch,
        );
        rank_prediction(
            machine,
            &p,
            &profile,
            self.cfg.size,
            self.cfg.ranks,
            self.cfg.halo_depth(),
            self.cfg.rank_step(),
        )
    }

    fn ensure_fabric(&mut self) -> Result<()> {
        if self.fabric.iter().all(Option::is_some) {
            return Ok(());
        }
        let n = self.ranks();
        let endpoints: Vec<Box<dyn Transport>> = match self.fabric_kind {
            FabricKind::SharedMem => SharedMemTransport::fabric(n)
                .into_iter()
                .map(|tp| Box::new(tp) as Box<dyn Transport>)
                .collect(),
            FabricKind::SocketLocal => {
                // every frame on this fabric is at most one deep-halo
                // shell of whole planes; cap the wire decoder there so
                // a corrupt length can't drive an unbounded allocation
                let (_, ny, nx) = self.cfg.size;
                let limit = self.cfg.halo_depth().max(self.cfg.op.radius()) * ny * nx;
                SocketTransport::fabric_local_with_limit(n, limit)
                    .map_err(|e| anyhow!(CommError::Fabric(format!("socket fabric: {e}"))))?
                    .into_iter()
                    .map(|tp| Box::new(tp) as Box<dyn Transport>)
                    .collect()
            }
        };
        self.fabric = endpoints
            .into_iter()
            .map(|tp| Some(HaloExchange::new(tp, Arc::clone(&self.stats))))
            .collect();
        Ok(())
    }
}

/// Everything one rank thread needs for a run.
struct RankTask<'a> {
    solver: &'a mut Solver,
    local: &'a mut Grid3,
    shard: Shard,
    gs: bool,
    passes: usize,
    per_pass: usize,
    delay: Duration,
    fault: Option<usize>,
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".into())
}

/// Copy `n` whole planes starting at `z0` out of the slab.
fn read_planes(g: &Grid3, z0: usize, n: usize) -> Vec<f64> {
    let s = g.idx(z0, 0, 0);
    g.data()[s..s + n * g.ny * g.nx].to_vec()
}

/// Overwrite `n` whole planes starting at `z0` with a halo payload.
fn write_planes(g: &mut Grid3, z0: usize, n: usize, planes: &[f64]) -> Result<()> {
    let want = n * g.ny * g.nx;
    anyhow::ensure!(
        planes.len() == want,
        CommError::Fabric(format!("halo payload holds {} values, expected {want}", planes.len()))
    );
    let s = g.idx(z0, 0, 0);
    g.data_mut()[s..s + want].copy_from_slice(planes);
    Ok(())
}

/// One rank's protocol loop. Errors are `anyhow` with a downcastable
/// [`CommError`] root wherever the fabric is the cause.
fn drive_rank(task: RankTask<'_>, engine: &mut HaloExchange) -> Result<()> {
    let RankTask { solver, local, shard, gs, passes, per_pass, delay, fault } = task;
    let nzl = local.nz;
    for pass in 1..=passes {
        if fault == Some(pass) {
            std::panic::panic_any(format!(
                "injected fault: rank {} dies at block {pass}",
                engine.rank()
            ));
        }
        if gs {
            // pipelined per-sweep exchange: left neighbor's *new* top
            // planes gate this sweep; right neighbor's previous-sweep
            // bottom planes refresh the old-value side
            if engine.has(Peer::Left) {
                let planes = engine.recv(Peer::Left).map_err(anyhow::Error::new)?;
                write_planes(local, 0, shard.d_lo, &planes)?;
            }
            if engine.has(Peer::Right) && pass >= 2 {
                let planes = engine.recv(Peer::Right).map_err(anyhow::Error::new)?;
                write_planes(local, nzl - shard.d_hi, shard.d_hi, &planes)?;
            }
        } else if pass >= 2 {
            // deep-halo exchange: refresh both ghost shells with the
            // neighbors' post-block owned planes before the next block
            if engine.has(Peer::Left) {
                let planes = engine.recv(Peer::Left).map_err(anyhow::Error::new)?;
                write_planes(local, 0, shard.d_lo, &planes)?;
            }
            if engine.has(Peer::Right) {
                let planes = engine.recv(Peer::Right).map_err(anyhow::Error::new)?;
                write_planes(local, nzl - shard.d_hi, shard.d_hi, &planes)?;
            }
        }
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        solver.run(local, per_pass)?;
        if gs {
            // always feed the right neighbor's next sweep; feed the
            // left neighbor's old-value side unless this was the last
            if engine.has(Peer::Right) {
                let top = read_planes(local, nzl - 2 * shard.d_hi, shard.d_hi);
                engine.send(Peer::Right, top).map_err(anyhow::Error::new)?;
            }
            if engine.has(Peer::Left) && pass < passes {
                let bottom = read_planes(local, shard.d_lo, shard.d_lo);
                engine.send(Peer::Left, bottom).map_err(anyhow::Error::new)?;
            }
        } else if pass < passes {
            // post both halves right after the block: the payloads are
            // in flight while this rank (and its skewed neighbors)
            // keep computing
            if engine.has(Peer::Left) {
                let bottom = read_planes(local, shard.d_lo, shard.d_lo);
                engine.send(Peer::Left, bottom).map_err(anyhow::Error::new)?;
            }
            if engine.has(Peer::Right) {
                let top = read_planes(local, nzl - 2 * shard.d_hi, shard.d_hi);
                engine.send(Peer::Right, top).map_err(anyhow::Error::new)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    #[test]
    fn partition_covers_the_interior_contiguously() {
        for (nz, radius, depth, ranks) in
            [(20, 1, 4, 3), (33, 2, 2, 4), (11, 1, 1, 1), (26, 1, 8, 2)]
        {
            let l = RankLayout::partition(nz, radius, depth, ranks);
            assert_eq!(l.ranks(), ranks);
            assert_eq!(l.shards[0].z0, radius, "first shard starts at the interior");
            let mut z = radius;
            for (i, s) in l.shards.iter().enumerate() {
                assert_eq!(s.z0, z, "shard {i} contiguous");
                z += s.planes;
                assert_eq!(s.d_lo, if i == 0 { radius } else { depth });
                assert_eq!(s.d_hi, if i + 1 == ranks { radius } else { depth });
                assert_eq!(s.local_nz(), s.d_lo + s.planes + s.d_hi);
                assert_eq!(s.slab_z0() + s.d_lo, s.z0);
            }
            assert_eq!(z, nz - radius, "shards cover every interior plane");
        }
    }

    #[test]
    fn remainder_planes_go_to_the_lowest_ranks() {
        let l = RankLayout::partition(2 + 11, 1, 1, 3); // 11 interior planes
        let counts: Vec<usize> = l.shards.iter().map(|s| s.planes).collect();
        assert_eq!(counts, vec![4, 4, 3]);
    }

    #[test]
    fn two_rank_jacobi_wavefront_matches_single_rank() {
        let cfg = RunConfig {
            scheme: Scheme::JacobiWavefront,
            size: (20, 9, 8),
            t: 2,
            iters: 6,
            ranks: 2,
            ..Default::default()
        };
        let f = Grid3::random(20, 9, 8, 31);
        let mut set = RankSet::builder(&cfg).rhs(f, 0.7).build().unwrap();
        let u0 = Grid3::random(20, 9, 8, 32);
        let mut u = u0.clone();
        set.run(&mut u, 6).unwrap();
        let want = set.reference(&u0, 6);
        assert_eq!(u.max_abs_diff(&want), 0.0, "bit-exact across ranks");
        let stats = set.halo_stats();
        assert!(stats.messages > 0 && stats.payload_bytes > 0, "halos actually moved");
    }

    #[test]
    fn three_rank_gs_multigroup_matches_single_rank() {
        let cfg = RunConfig {
            scheme: Scheme::GsMultiGroup,
            size: (16, 14, 9),
            t: 3,
            groups: 2,
            iters: 5,
            ranks: 3,
            ..Default::default()
        };
        let mut set = RankSet::builder(&cfg).build().unwrap();
        let u0 = Grid3::random(16, 14, 9, 33);
        let mut u = u0.clone();
        set.run(&mut u, 5).unwrap();
        let want = set.reference(&u0, 5);
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // GS pipeline: each of the 2 interfaces moves R planes per sweep
        assert_eq!(set.halo_stats().messages, 2 * (5 + 4));
    }

    #[test]
    fn single_rank_short_circuits_to_the_plain_solver() {
        let cfg = RunConfig { size: (12, 10, 9), t: 2, iters: 4, ranks: 1, ..Default::default() };
        let mut set = RankSet::builder(&cfg).build().unwrap();
        let u0 = Grid3::random(12, 10, 9, 34);
        let mut u = u0.clone();
        set.run(&mut u, 4).unwrap();
        assert_eq!(u.max_abs_diff(&set.reference(&u0, 4)), 0.0);
        assert_eq!(set.halo_stats().messages, 0, "no fabric traffic for one rank");
    }

    #[test]
    fn grid_is_untouched_when_a_rank_dies() {
        let cfg = RunConfig {
            scheme: Scheme::JacobiBaseline,
            size: (14, 8, 8),
            t: 1,
            iters: 4,
            ranks: 2,
            ..Default::default()
        };
        let mut set = RankSet::builder(&cfg).build().unwrap();
        set.set_fault(1, 2);
        let u0 = Grid3::random(14, 8, 8, 35);
        let mut u = u0.clone();
        let err = set.run(&mut u, 4).unwrap_err();
        assert!(
            err.downcast_ref::<CommError>().is_some(),
            "neighbor failure is a typed CommError, got: {err:#}"
        );
        assert_eq!(u.max_abs_diff(&u0), 0.0, "failed runs must not partially gather");
        // the fabric rebuilds and the set is usable again
        set.clear_fault(1);
        set.run(&mut u, 4).unwrap();
        assert_eq!(u.max_abs_diff(&set.reference(&u0, 4)), 0.0);
    }
}
