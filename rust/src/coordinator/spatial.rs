//! Spatial blocking for the wavefront scheme (paper Sec. 4, Fig. 7),
//! generic over the [`StencilOp`] kernel layer.
//!
//! For large planes, the rolling window of a whole-domain wavefront
//! overflows the shared cache, so the domain is decomposed into `B` blocks
//! along y and each block is swept with the full temporal depth `t` before
//! the next one starts. Because a site's step-`s` update needs step-`s-1`
//! neighbors within halo radius `R`, the per-level update regions are
//! *skewed*: level `s` of block `b` covers
//! `[start_b - R(s-1), end_b - R(s-1))` (clamped to the domain at the
//! first/last block, where the Dirichlet boundary makes the shift
//! unnecessary).
//!
//! At a block interface the next block needs values the rolling temporary
//! buffer has already recycled; the paper: "a boundary array must thus
//! hold t planes in z-x direction. Hence no additional computations are
//! necessary for the boundary treatment." Concretely (and provably — see
//! the tests): *even*-level values at the interface survive in `src`
//! because every later even level's region ends strictly left of them,
//! but *odd*-level values live in the `2R+2`-slot temporary ring and are
//! gone — so for each odd level the last `2R` lines of its region are
//! saved, for every plane, into a boundary array the next block reads
//! from.
//!
//! Result: bit-identical to `t` serial sweeps for every `(B, t)` and
//! every registered op radius.

use crate::simulator::memory::StoreMode;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{copy_x_edges, StarWindow, StencilOp, MAX_RADIUS};
use crate::stencil::simd;
use crate::Result;

use super::wavefront::tmp_slots;

/// Configuration of a blocked (spatially + temporally) sweep.
#[derive(Clone, Copy, Debug)]
pub struct SpatialConfig {
    /// Temporal blocking factor `t` (even, ≥ 2).
    pub t: usize,
    /// Number of y blocks `B` (Fig. 7 uses 8).
    pub blocks: usize,
    /// Store flavour of the final-level (`s == t`) result copy into `u`
    /// — the only write stream of the pass never re-read by a later
    /// level or a neighbor block.
    pub store: StoreMode,
}

impl Default for SpatialConfig {
    fn default() -> Self {
        Self { t: 4, blocks: 2, store: StoreMode::NonTemporal }
    }
}

/// Perform exactly `cfg.t` updates of `op` on `u` in place, block by
/// block.
pub fn blocked_wavefront_jacobi<O: StencilOp>(
    op: &O,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &SpatialConfig,
) -> Result<()> {
    let t = cfg.t;
    let b_count = cfg.blocks;
    let r = op.radius();
    anyhow::ensure!(t >= 2 && t % 2 == 0, "blocked wavefront needs even t >= 2, got {t}");
    anyhow::ensure!(b_count >= 1, "need at least one block");
    anyhow::ensure!(r >= 1 && r <= MAX_RADIUS, "unsupported halo radius {r}");
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    op.validate_domain(u.shape())?;
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 {
        return Ok(());
    }

    let plane = ny * nx;
    let slots = tmp_slots(r);
    let levels = t / 2; // odd levels 1, 3, …, t-1 → index u = (s-1)/2
    let mut tmp = vec![0.0f64; levels * slots * plane];
    // boundary arrays: per odd level, per z plane, 2R x-lines; double
    // buffered across blocks (read side = previous block's writes).
    let bnd_stride = nz * 2 * r * nx;
    let mut bnd_read = vec![0.0f64; levels * bnd_stride];
    let mut bnd_write = vec![0.0f64; levels * bnd_stride];

    // block boundaries over the interior lines [r, ny-r)
    let interior = ny - 2 * r;
    let starts: Vec<usize> = (0..=b_count).map(|b| r + b * interior / b_count).collect();

    let lag = r + 1; // z distance between successive levels per round
    let last_round = (nz - 2 * r) + lag * (t - 1);
    // scratch line reused across every (round, level, y) iteration —
    // allocating here instead of per plane was a 1.2–1.4× win on the
    // blocked-wavefront bench (EXPERIMENTS.md §Perf).
    let mut out = vec![0.0f64; nx];
    for b in 0..b_count {
        let block_start = starts[b];
        let block_end = starts[b + 1];
        if block_start == block_end {
            continue; // degenerate empty block (more blocks than lines)
        }
        // per-level y region of this block (clamped skew)
        let region = |s: usize| -> (usize, usize) {
            let shift = r * (s - 1);
            let lo = if b == 0 { r } else { block_start.saturating_sub(shift).max(r) };
            let hi = if b + 1 == b_count { ny - r } else { block_end.saturating_sub(shift).max(r) };
            (lo, hi)
        };

        for round in 1..=last_round {
            for s in 1..=t {
                let k = (round + r - 1) as isize - (lag * (s - 1)) as isize;
                if k < r as isize || k > (nz - 1 - r) as isize {
                    continue;
                }
                let k = k as usize;
                let (y_lo, y_hi) = region(s);
                let lvl = (s - 1) / 2; // odd-level index for writes of odd s
                for y in y_lo..y_hi {
                    {
                        // gather the level-(s-1) window lines + rhs
                        let ln = |kk: usize, yy: usize| {
                            read_line(u, &tmp, &bnd_read, b, s, kk, yy, &starts, r, nz, ny, nx)
                        };
                        let c = ln(k, y);
                        let win = StarWindow::from_fn(c, r, |dz, dy| {
                            ln((k as isize + dz) as usize, (y as isize + dy) as usize)
                        });
                        copy_x_edges(&mut out, c, r);
                        // `out` is a reused scratch line, always read right
                        // back by the copy below — plain stores only
                        op.line_update(&mut out, &win, f.line(k, y), h2, k, y, StoreMode::WriteAllocate);
                    }
                    // write to the level-s home (tmp ring for odd, src for
                    // even), plus the boundary array when this line is one
                    // of the last 2R of an odd level's region.
                    if s % 2 == 1 {
                        let slot = (lvl * slots + k % slots) * plane + y * nx;
                        tmp[slot..slot + nx].copy_from_slice(&out);
                        if b + 1 < b_count {
                            // interface lines [end_b - R·s - R, end_b - R·(s-1)):
                            // save whichever of the 2R this line is (the
                            // others may be boundary lines or produced by
                            // an earlier block — see the forwarding pass).
                            let iface_lo = block_end as isize - (r * s + r) as isize;
                            let idx = y as isize - iface_lo;
                            if (0..2 * r as isize).contains(&idx) {
                                let o = lvl * bnd_stride + (k * 2 * r + idx as usize) * nx;
                                bnd_write[o..o + nx].copy_from_slice(&out);
                            }
                        }
                    } else if s == t {
                        // final level: the pass never re-reads these lines,
                        // so the store stream may bypass the cache
                        simd::stream_copy(u.line_mut(k, y), &out, cfg.store);
                    } else {
                        // intermediate even levels stay cached: later
                        // levels and the next block read them from src
                        u.line_mut(k, y).copy_from_slice(&out);
                    }
                }
            }
        }
        // Forwarding pass: for narrow blocks an interface line block b+1
        // needs was not produced by block b at all — it was produced
        // earlier and still sits in `bnd_read` (shifted by the block
        // width). Carry it over so the boundary chain stays unbroken.
        if b + 1 < b_count {
            for o in (1..=t).step_by(2) {
                let lvl = (o - 1) / 2;
                let (region_lo, region_hi) = region(o);
                for idx in 0..2 * r {
                    let y = block_end as isize - (r * o + r) as isize + idx as isize;
                    if y < r as isize {
                        continue; // boundary line: reads redirect to src
                    }
                    let y = y as usize;
                    if y >= region_lo && y < region_hi {
                        continue; // produced this block: already saved
                    }
                    let ridx = y as isize - (block_start as isize - (r * o + r) as isize);
                    if (0..2 * r as isize).contains(&ridx) {
                        for k in 0..nz {
                            let dst = lvl * bnd_stride + (k * 2 * r + idx) * nx;
                            let src_off = lvl * bnd_stride + (k * 2 * r + ridx as usize) * nx;
                            bnd_write[dst..dst + nx]
                                .copy_from_slice(&bnd_read[src_off..src_off + nx]);
                        }
                    }
                }
            }
        }
        std::mem::swap(&mut bnd_read, &mut bnd_write);
    }
    Ok(())
}

/// Read the level-`s-1` value of line `(k, y)` during block `b`, level `s`.
#[allow(clippy::too_many_arguments)]
fn read_line<'a>(
    u: &'a Grid3,
    tmp: &'a [f64],
    bnd: &'a [f64],
    b: usize,
    s: usize,
    k: usize,
    y: usize,
    starts: &[usize],
    r: usize,
    nz: usize,
    ny: usize,
    nx: usize,
) -> &'a [f64] {
    let plane = ny * nx;
    // z or y domain boundary: level-invariant original values in src
    if k < r || k >= nz - r || y < r || y >= ny - r {
        return u.line(k, y);
    }
    let prev = s - 1;
    if prev % 2 == 0 {
        // even levels (incl. 0 = original) live in src: the highest even
        // level whose region covered this line is exactly `prev`.
        return u.line(k, y);
    }
    // odd level: the temporary ring if the line was produced during this
    // block's sweep, else the previous block's boundary array.
    let lvl = (prev - 1) / 2;
    let block_start = starts[b];
    let region_lo = if b == 0 { r } else { block_start.saturating_sub(r * (prev - 1)).max(r) };
    if y >= region_lo {
        let slots = tmp_slots(r);
        let slot = (lvl * slots + k % slots) * plane + y * nx;
        &tmp[slot..slot + nx]
    } else {
        // the 2R lines [start_b - R·prev - R, start_b - R·(prev-1)) of
        // the previous block's level-`prev` region, saved as boundary
        // indices 0..2R (iface_lo can go negative when the skew runs past
        // the domain edge; the negative slots are never populated or read)
        let iface_lo = block_start as isize - (r * prev + r) as isize;
        let idx = (y as isize - iface_lo) as usize;
        debug_assert!(idx < 2 * r, "y={y} iface_lo={iface_lo} s={s} r={r}");
        let stride = nz * 2 * r * nx;
        let o = lvl * stride + (k * 2 * r + idx) * nx;
        &bnd[o..o + nx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wavefront::{serial_reference, serial_reference_op};
    use crate::stencil::op::{ConstLaplace7, Laplace13, VarCoeff7};

    fn check(nz: usize, ny: usize, nx: usize, t: usize, blocks: usize) {
        let f = Grid3::random(nz, ny, nx, 17);
        let mut u = Grid3::random(nz, ny, nx, 18);
        let want = serial_reference(&u, &f, 1.1, t);
        blocked_wavefront_jacobi(&ConstLaplace7, &mut u, &f, 1.1, &SpatialConfig { t, blocks, ..Default::default() })
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} B={blocks}");
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, t: usize, blocks: usize) {
        let f = Grid3::random(nz, ny, nx, 19);
        let mut u = Grid3::random(nz, ny, nx, 20);
        let want = serial_reference_op(&Laplace13, &u, &f, 1.1, t);
        blocked_wavefront_jacobi(&Laplace13, &mut u, &f, 1.1, &SpatialConfig { t, blocks, ..Default::default() })
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 {nz}x{ny}x{nx} t={t} B={blocks}");
    }

    #[test]
    fn single_block_matches_serial() {
        check(10, 9, 8, 2, 1);
        check(10, 9, 8, 4, 1);
    }

    #[test]
    fn two_blocks_match_serial() {
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 9, 6, 2);
    }

    #[test]
    fn many_blocks_match_serial() {
        check(8, 24, 8, 4, 4);
        check(8, 24, 8, 4, 8); // blocks with very few lines
        check(6, 30, 7, 6, 5);
    }

    #[test]
    fn uneven_block_sizes() {
        // interior lines not divisible by block count
        check(8, 13, 8, 4, 3);
        check(8, 11, 8, 2, 4);
    }

    #[test]
    fn more_blocks_than_lines_degenerates_gracefully() {
        check(6, 6, 6, 2, 10);
    }

    #[test]
    fn radius2_blocked_matches_serial() {
        check_r2(10, 11, 9, 2, 1);
        check_r2(10, 13, 9, 2, 2);
        check_r2(10, 16, 9, 4, 2);
        check_r2(9, 20, 8, 4, 3);
        check_r2(8, 24, 8, 6, 2);
        // narrow blocks force the radius-2 forwarding pass
        check_r2(8, 14, 8, 4, 4);
        check_r2(7, 12, 8, 2, 6);
    }

    #[test]
    fn varcoeff_blocked_matches_serial() {
        let op = VarCoeff7::default_for((9, 14, 8));
        let f = Grid3::random(9, 14, 8, 23);
        let mut u = Grid3::random(9, 14, 8, 24);
        let want = serial_reference_op(&op, &u, &f, 0.9, 4);
        blocked_wavefront_jacobi(&op, &mut u, &f, 0.9, &SpatialConfig { t: 4, blocks: 3, ..Default::default() }).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn odd_t_rejected() {
        let mut u = Grid3::random(8, 8, 8, 1);
        let f = Grid3::zeros(8, 8, 8);
        assert!(blocked_wavefront_jacobi(
            &ConstLaplace7,
            &mut u,
            &f,
            1.0,
            &SpatialConfig { t: 3, blocks: 2, ..Default::default() }
        )
        .is_err());
    }
}
