//! Spatial blocking for the wavefront scheme (paper Sec. 4, Fig. 7).
//!
//! For large planes, the rolling window of a whole-domain wavefront
//! overflows the shared cache, so the domain is decomposed into `B` blocks
//! along y and each block is swept with the full temporal depth `t` before
//! the next one starts. Because a site's step-`s` update needs step-`s-1`
//! neighbors, the per-level update regions are *skewed*: level `s` of
//! block `b` covers `[start_b - (s-1), end_b - (s-1))` (clamped to the
//! domain at the first/last block, where the Dirichlet boundary makes the
//! shift unnecessary).
//!
//! At a block interface the next block needs values the rolling temporary
//! buffer has already recycled; the paper: "a boundary array must thus
//! hold t planes in z-x direction. Hence no additional computations are
//! necessary for the boundary treatment." Concretely (and provably — see
//! the tests): *even*-level values at the interface survive in `src`
//! because every later even level's region ends strictly left of them,
//! but *odd*-level values live in the 4-slot temporary ring and are gone
//! — so for each odd level the last two lines of its region are saved,
//! for every plane, into a boundary array the next block reads from.
//!
//! Result: bit-identical to `t` serial Jacobi sweeps for every `(B, t)`.

use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::ONE_SIXTH;
use crate::Result;

/// Temporary-ring slots per odd level (as in the threaded wavefront).
const TMP_SLOTS: usize = 4;

/// Configuration of a blocked (spatially + temporally) sweep.
#[derive(Clone, Copy, Debug)]
pub struct SpatialConfig {
    /// Temporal blocking factor `t` (even, ≥ 2).
    pub t: usize,
    /// Number of y blocks `B` (Fig. 7 uses 8).
    pub blocks: usize,
}

impl Default for SpatialConfig {
    fn default() -> Self {
        Self { t: 4, blocks: 2 }
    }
}

/// Perform exactly `cfg.t` Jacobi updates on `u` in place, block by block.
pub fn blocked_wavefront_jacobi(
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &SpatialConfig,
) -> Result<()> {
    let t = cfg.t;
    let b_count = cfg.blocks;
    anyhow::ensure!(t >= 2 && t % 2 == 0, "blocked wavefront needs even t >= 2, got {t}");
    anyhow::ensure!(b_count >= 1, "need at least one block");
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 {
        return Ok(());
    }

    let plane = ny * nx;
    let levels = t / 2; // odd levels 1, 3, …, t-1 → index u = (s-1)/2
    let mut tmp = vec![0.0f64; levels * TMP_SLOTS * plane];
    // boundary arrays: per odd level, per z plane, two x-lines; double
    // buffered across blocks (read side = previous block's writes).
    let bnd_stride = nz * 2 * nx;
    let mut bnd_read = vec![0.0f64; levels * bnd_stride];
    let mut bnd_write = vec![0.0f64; levels * bnd_stride];

    // block boundaries over the interior lines [1, ny-1)
    let interior = ny - 2;
    let starts: Vec<usize> = (0..=b_count)
        .map(|b| 1 + b * interior / b_count)
        .collect();

    let last_round = (nz - 2) + 2 * (t - 1);
    // scratch line reused across every (round, level, y) iteration —
    // allocating here instead of per plane was a 1.2–1.4× win on the
    // blocked-wavefront bench (EXPERIMENTS.md §Perf).
    let mut out = vec![0.0f64; nx];
    for b in 0..b_count {
        let block_start = starts[b];
        let block_end = starts[b + 1];
        if block_start == block_end {
            continue; // degenerate empty block (more blocks than lines)
        }
        // per-level y region of this block (clamped skew)
        let region = |s: usize| -> (usize, usize) {
            let shift = s - 1;
            let lo = if b == 0 { 1 } else { block_start.saturating_sub(shift).max(1) };
            let hi = if b + 1 == b_count { ny - 1 } else { block_end.saturating_sub(shift).max(1) };
            (lo, hi)
        };

        for r in 1..=last_round {
            for s in 1..=t {
                let k = r as isize - 2 * (s as isize - 1);
                if k < 1 || k > (nz - 2) as isize {
                    continue;
                }
                let k = k as usize;
                let (y_lo, y_hi) = region(s);
                let lvl = (s - 1) / 2; // odd-level index for writes of odd s
                for y in y_lo..y_hi {
                    {
                        // gather the six level-(s-1) neighbor lines + rhs
                        let c = read_line(u, &tmp, &bnd_read, b, s, k, y, &starts, nz, ny, nx);
                        let ym = read_line(u, &tmp, &bnd_read, b, s, k, y - 1, &starts, nz, ny, nx);
                        let yp = read_line(u, &tmp, &bnd_read, b, s, k, y + 1, &starts, nz, ny, nx);
                        let zm = read_line(u, &tmp, &bnd_read, b, s, k - 1, y, &starts, nz, ny, nx);
                        let zp = read_line(u, &tmp, &bnd_read, b, s, k + 1, y, &starts, nz, ny, nx);
                        let rhs = f.line(k, y);
                        out[0] = c[0];
                        out[nx - 1] = c[nx - 1];
                        for i in 1..nx - 1 {
                            out[i] = ONE_SIXTH
                                * (c[i - 1]
                                    + c[i + 1]
                                    + ym[i]
                                    + yp[i]
                                    + zm[i]
                                    + zp[i]
                                    + h2 * rhs[i]);
                        }
                    }
                    // write to the level-s home (tmp ring for odd, src for
                    // even), plus the boundary array when this line is one
                    // of the last two of an odd level's region.
                    if s % 2 == 1 {
                        let slot = (lvl * TMP_SLOTS + k % TMP_SLOTS) * plane + y * nx;
                        tmp[slot..slot + nx].copy_from_slice(&out);
                        if b + 1 < b_count {
                            // interface lines end_b - s - 1 and end_b - s:
                            // save whichever of the two this line is (the
                            // other may be a boundary line or produced by
                            // an earlier block — see the forwarding pass).
                            let iface_lo = block_end as isize - s as isize - 1;
                            let idx = y as isize - iface_lo;
                            if idx == 0 || idx == 1 {
                                let o = lvl * bnd_stride + (k * 2 + idx as usize) * nx;
                                bnd_write[o..o + nx].copy_from_slice(&out);
                            }
                        }
                    } else {
                        u.line_mut(k, y).copy_from_slice(&out);
                    }
                }
            }
        }
        // Forwarding pass: for narrow blocks (width 1) an interface line
        // block b+1 needs was not produced by block b at all — it was
        // produced earlier and still sits in `bnd_read` (one slot to the
        // left). Carry it over so the boundary chain stays unbroken.
        if b + 1 < b_count {
            for o in (1..=t).step_by(2) {
                let lvl = (o - 1) / 2;
                let (region_lo, region_hi) = region(o);
                for idx in 0..2usize {
                    let y = block_end as isize - o as isize - 1 + idx as isize;
                    if y < 1 {
                        continue; // boundary line: reads redirect to src
                    }
                    let y = y as usize;
                    if y >= region_lo && y < region_hi {
                        continue; // produced this block: already saved
                    }
                    let ridx = y as isize - (block_start as isize - o as isize - 1);
                    if ridx == 0 || ridx == 1 {
                        for k in 0..nz {
                            let dst = lvl * bnd_stride + (k * 2 + idx) * nx;
                            let src_off = lvl * bnd_stride + (k * 2 + ridx as usize) * nx;
                            bnd_write[dst..dst + nx]
                                .copy_from_slice(&bnd_read[src_off..src_off + nx]);
                        }
                    }
                }
            }
        }
        std::mem::swap(&mut bnd_read, &mut bnd_write);
    }
    Ok(())
}

/// Read the level-`s-1` value of line `(k, y)` during block `b`, level `s`.
#[allow(clippy::too_many_arguments)]
fn read_line<'a>(
    u: &'a Grid3,
    tmp: &'a [f64],
    bnd: &'a [f64],
    b: usize,
    s: usize,
    k: usize,
    y: usize,
    starts: &[usize],
    nz: usize,
    ny: usize,
    nx: usize,
) -> &'a [f64] {
    let plane = ny * nx;
    // z or y domain boundary: level-invariant original values in src
    if k == 0 || k == nz - 1 || y == 0 || y == ny - 1 {
        return u.line(k, y);
    }
    let prev = s - 1;
    if prev % 2 == 0 {
        // even levels (incl. 0 = original) live in src: the highest even
        // level whose region covered this line is exactly `prev`.
        return u.line(k, y);
    }
    // odd level: the temporary ring if the line was produced during this
    // block's sweep, else the previous block's boundary array.
    let lvl = (prev - 1) / 2;
    let block_start = starts[b];
    let region_lo = if b == 0 { 1 } else { block_start.saturating_sub(prev - 1).max(1) };
    if y >= region_lo {
        let slot = (lvl * TMP_SLOTS + k % TMP_SLOTS) * plane + y * nx;
        &tmp[slot..slot + nx]
    } else {
        // lines start_b - prev - 1 and start_b - prev of the previous
        // block's level-`prev` region, saved as boundary index 0 / 1
        let iface_lo = block_start - prev - 1;
        debug_assert!(y == iface_lo || y == iface_lo + 1, "y={y} iface_lo={iface_lo} s={s}");
        let idx = y - iface_lo;
        let stride = nz * 2 * nx;
        let o = lvl * stride + (k * 2 + idx) * nx;
        &bnd[o..o + nx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wavefront::serial_reference;

    fn check(nz: usize, ny: usize, nx: usize, t: usize, blocks: usize) {
        let f = Grid3::random(nz, ny, nx, 17);
        let mut u = Grid3::random(nz, ny, nx, 18);
        let want = serial_reference(&u, &f, 1.1, t);
        blocked_wavefront_jacobi(&mut u, &f, 1.1, &SpatialConfig { t, blocks }).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} B={blocks}");
    }

    #[test]
    fn single_block_matches_serial() {
        check(10, 9, 8, 2, 1);
        check(10, 9, 8, 4, 1);
    }

    #[test]
    fn two_blocks_match_serial() {
        check(10, 12, 8, 2, 2);
        check(10, 12, 8, 4, 2);
        check(8, 16, 9, 6, 2);
    }

    #[test]
    fn many_blocks_match_serial() {
        check(8, 24, 8, 4, 4);
        check(8, 24, 8, 4, 8); // blocks with very few lines
        check(6, 30, 7, 6, 5);
    }

    #[test]
    fn uneven_block_sizes() {
        // interior lines not divisible by block count
        check(8, 13, 8, 4, 3);
        check(8, 11, 8, 2, 4);
    }

    #[test]
    fn more_blocks_than_lines_degenerates_gracefully() {
        check(6, 6, 6, 2, 10);
    }

    #[test]
    fn odd_t_rejected() {
        let mut u = Grid3::random(8, 8, 8, 1);
        let f = Grid3::zeros(8, 8, 8);
        assert!(
            blocked_wavefront_jacobi(&mut u, &f, 1.0, &SpatialConfig { t: 3, blocks: 2 }).is_err()
        );
    }
}
