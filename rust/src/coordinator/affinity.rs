//! Core-pinning policies for the worker pool (ROADMAP item).
//!
//! The paper's wavefront groups only hit their cache-sharing sweet spot
//! when the group's threads actually land on cores that share the outer
//! level cache (Sec. 4; Tab. 1's "cache group"). The OS scheduler does
//! not know that, so [`PinPolicy`] encodes the classic placements:
//!
//! * [`PinPolicy::Compact`] — fill one cache group before touching the
//!   next (worker `i` → physical core `i`; SMT siblings only after every
//!   core holds one worker). The right policy for a single wavefront
//!   group: all `t` workers share one OLC.
//! * [`PinPolicy::Scatter`] — round-robin across cache groups (worker
//!   `i` → group `i mod G`, slot `i / G`; again physical cores first).
//!   The right policy for bandwidth-bound baselines and multi-group
//!   schemes where each group should own its own OLC.
//! * [`PinPolicy::SmtPair`] — co-schedule SMT sibling pairs: workers
//!   `s·c` … `s·c+s-1` land on the `s` hardware threads of physical
//!   core `c`. The placement for the paper's SMT wavefront experiment
//!   (Sec. 6): two pipeline threads share one core's private caches.
//!
//! The cpu map is computed from a [`MachineSpec`]'s cache-group topology
//! when the run names a Tab. 1 machine, and from the *host's* real cache
//! groups otherwise (parsed from
//! `/sys/devices/system/cpu/cpu0/cache/index*/shared_cpu_list`, with the
//! SMT sibling layout from
//! `/sys/devices/system/cpu/cpu0/topology/thread_siblings_list`, on
//! Linux; one flat group when sysfs is unreadable). The backend is a raw
//! `sched_setaffinity` syscall on Linux (x86_64 / aarch64) — the build
//! stays dependency-free — and a documented no-op everywhere else:
//! [`pin_current_thread`] returns `false` and workers simply run
//! unpinned, so schedules stay correct on every platform.
//!
//! Wired through [`WorkerPool::set_start_hook`](super::pool::WorkerPool::set_start_hook)
//! by [`pin_hook`]; the [`Solver`](super::solver::Solver) builder installs
//! it before spawning the team.

use std::sync::Arc;

use crate::simulator::machine::MachineSpec;
use crate::Result;

use super::pool::StartHook;

/// How pool workers are placed on cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PinPolicy {
    /// Leave placement to the OS scheduler (the default).
    #[default]
    None,
    /// Fill cache groups in order: worker `i` runs on cpu `i`.
    Compact,
    /// Spread across cache groups: worker `i` runs in group `i mod G`.
    ///
    /// Needs cache-group information to differ from [`PinPolicy::Compact`]:
    /// without a Tab. 1 machine model the host fallback is one flat group
    /// and scatter degenerates to compact (see [`Topology::host`]).
    Scatter,
    /// Co-schedule SMT sibling pairs: workers `s·c` … `s·c+s-1` run on
    /// the `s` hardware threads of physical core `c`, so consecutive
    /// worker ids share one core's pipeline and private caches.
    ///
    /// With the GS wavefront's `sweep·width + position` worker
    /// numbering, a width-2 pipeline pair becomes one core's two
    /// hyperthreads — the paper's Sec. 6 SMT co-scheduling. Degenerates
    /// to [`PinPolicy::Compact`] on hosts without SMT.
    SmtPair,
}

impl PinPolicy {
    /// Parse a `none` / `compact` / `scatter` / `smtpair` policy name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "none" => PinPolicy::None,
            "compact" => PinPolicy::Compact,
            "scatter" => PinPolicy::Scatter,
            "smtpair" => PinPolicy::SmtPair,
            other => anyhow::bail!("unknown pin policy '{other}' (none/compact/scatter/smtpair)"),
        })
    }

    /// The config/CLI name of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            PinPolicy::None => "none",
            PinPolicy::Compact => "compact",
            PinPolicy::Scatter => "scatter",
            PinPolicy::SmtPair => "smtpair",
        }
    }
}

/// The core/cache-group/SMT layout the cpu map is computed from.
///
/// All placement happens in units of *physical cores*; the SMT fields
/// only decide which cpu ids a core's hardware threads answer to, so
/// the cache-group arithmetic never straddles sibling enumeration
/// styles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Physical cores to place workers on.
    pub cores: usize,
    /// Physical cores sharing one outer-level cache (`<= cores`).
    pub group_size: usize,
    /// Hardware threads per physical core (1 = no SMT).
    pub smt_per_core: usize,
    /// Cpu-id distance between SMT siblings of one core: `<= 1` for
    /// adjacent enumeration (core `c` → cpus `c·s … c·s+s-1`), else the
    /// split-style stride (core `c` → cpus `c`, `c+stride`, …) Linux
    /// typically uses.
    pub smt_stride: usize,
}

impl Topology {
    /// Logical cpus this layout exposes (`cores × smt_per_core`).
    pub fn logical_cpus(&self) -> usize {
        self.cores.max(1) * self.smt_per_core.max(1)
    }

    /// The cpu id of hardware thread `th` of physical core `core`.
    pub fn cpu_of(&self, core: usize, th: usize) -> usize {
        let s = self.smt_per_core.max(1);
        if s == 1 {
            core
        } else if self.smt_stride <= 1 {
            core * s + th
        } else {
            core + th * self.smt_stride
        }
    }

    /// Topology of a Tab. 1 machine: its physical cores, grouped by the
    /// cache group the wavefront scheme targets (L3, or the shared L2 on
    /// Core 2). Sibling cpus are assumed split-style (`c` and
    /// `c + cores`), the enumeration Linux uses on that generation of
    /// Intel machines.
    pub fn of_machine(m: &MachineSpec) -> Self {
        Self {
            cores: m.cores.max(1),
            group_size: m.cache_group_cores().max(1),
            smt_per_core: m.smt_per_core.max(1),
            smt_stride: m.smt_sibling_stride(),
        }
    }

    /// Topology of the machine this process runs on.
    ///
    /// On Linux the real cache groups are read from
    /// `/sys/devices/system/cpu/cpu0/cache/index*/shared_cpu_list` (the
    /// deepest unified cache wins — the host analog of Tab. 1's "cache
    /// group") and the SMT sibling layout from
    /// `/sys/devices/system/cpu/cpu0/topology/thread_siblings_list`, so
    /// `compact`/`scatter`/`smtpair` place workers against the *host's*
    /// OLC sharing instead of a model's. A shared-cpu list is honored
    /// when it resolves to whole physical cores under the sibling
    /// layout — one contiguous block for adjacent enumeration, or `s`
    /// stride-translated copies of one block for split enumeration like
    /// `0-15,32-47` (see [`group_physical_cores`]). When sysfs is
    /// unreadable (non-Linux, sandboxes) or the layout does not resolve,
    /// every core falls into one flat group (compact and scatter then
    /// coincide); runs that name a Tab. 1 machine keep using
    /// [`Topology::of_machine`].
    pub fn host() -> Self {
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let (smt, stride) = sysfs_smt_siblings().unwrap_or((1, 1));
        let smt = smt.clamp(1, cpus);
        let cores = (cpus / smt).max(1);
        let group = sysfs_cache_group(smt, stride)
            .map(|g| g.clamp(1, cores))
            .unwrap_or(cores);
        Self { cores, group_size: group, smt_per_core: smt, smt_stride: stride }
    }
}

/// The maximal runs `(lo, hi)` of a sysfs cpu-list string like
/// `"0-3,8-11"`, in ascending order with adjacent ids coalesced so
/// `"0,1,2,3"` and `"0-3"` parse identically (`None` on malformed,
/// unsorted or overlapping input — callers fall back to the flat group).
fn parse_cpu_runs(s: &str) -> Option<Vec<(usize, usize)>> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let mut runs: Vec<(usize, usize)> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            return None;
        }
        let (lo, hi) = match part.split_once('-') {
            Some((lo, hi)) => (lo.trim().parse().ok()?, hi.trim().parse().ok()?),
            None => {
                let v: usize = part.trim().parse().ok()?;
                (v, v)
            }
        };
        if hi < lo {
            return None;
        }
        match runs.last_mut() {
            Some((_, prev_hi)) if lo == *prev_hi + 1 => *prev_hi = hi,
            Some((_, prev_hi)) if lo <= *prev_hi => return None,
            _ => runs.push((lo, hi)),
        }
    }
    Some(runs)
}

/// The *physical cores* a shared-cpu list covers under the host's SMT
/// sibling layout, or `None` when the list does not resolve to whole
/// cores (the caller then falls back to the flat group — compact ==
/// scatter, harmless).
///
/// Two layouts resolve:
///
/// * adjacent siblings (`stride <= 1`, or no SMT): one contiguous run of
///   `pc·smt` cpu ids → `pc` cores;
/// * split siblings (`stride > 1`): `smt` stride-translated copies of
///   one `pc`-wide block — `"0-15,32-47"` with `smt = 2`, `stride = 32`
///   → 16 cores. The copies merge into a single run exactly when the
///   block spans the whole stride.
///
/// Known limitation: only *cpu0's* group and siblings are inspected
/// (sysfs exposes one directory per cpu; enumerating all of them is
/// future work), so every group is assumed to have cpu0's shape. Hosts
/// with heterogeneous or offset groups (offline-cpu holes, asymmetric
/// clusters) can still be mis-pinned; pinning remains advisory and
/// never affects correctness.
fn group_physical_cores(s: &str, smt: usize, stride: usize) -> Option<usize> {
    let runs = parse_cpu_runs(s)?;
    let smt = smt.max(1);
    if smt == 1 || stride <= 1 {
        let [(lo, hi)] = runs[..] else { return None };
        let len = hi - lo + 1;
        return (len % smt == 0).then(|| len / smt);
    }
    if let [(lo, hi)] = runs[..] {
        // the `smt` sibling copies merged into one run: only possible
        // when the physical block is exactly `stride` wide
        let len = hi - lo + 1;
        return (len == smt * stride).then_some(stride);
    }
    if runs.len() != smt {
        return None;
    }
    let (lo0, hi0) = runs[0];
    let pc = hi0 - lo0 + 1;
    for (t, &(lo, hi)) in runs.iter().enumerate() {
        if lo != lo0 + t * stride || hi - lo + 1 != pc {
            return None;
        }
    }
    Some(pc)
}

/// `(threads per core, sibling cpu-id stride)` of cpu0 per sysfs,
/// `None` when the topology directory is unreadable (non-Linux,
/// sandboxes). A single-thread core reports `(1, 1)`.
fn sysfs_smt_siblings() -> Option<(usize, usize)> {
    let s = std::fs::read_to_string("/sys/devices/system/cpu/cpu0/topology/thread_siblings_list")
        .ok()?;
    let runs = parse_cpu_runs(&s)?;
    let count: usize = runs.iter().map(|&(lo, hi)| hi - lo + 1).sum();
    if count <= 1 {
        return Some((1, 1));
    }
    // second-lowest sibling id − lowest = the enumeration stride
    let (lo0, hi0) = runs[0];
    let second = if hi0 > lo0 { lo0 + 1 } else { runs[1].0 };
    Some((count, second - lo0))
}

/// Physical cores in cpu0's deepest shared cache group per sysfs,
/// `None` when the hierarchy is unreadable or does not resolve to whole
/// cores under the `(smt, stride)` sibling layout.
fn sysfs_cache_group(smt: usize, stride: usize) -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    let mut best: Option<(usize, usize)> = None; // (level, group size)
    for entry in std::fs::read_dir(base).ok()? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let is_index = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with("index"))
            .unwrap_or(false);
        if !is_index {
            continue;
        }
        // instruction caches are not sharing domains the schemes care about
        if let Ok(ty) = std::fs::read_to_string(path.join("type")) {
            if ty.trim() == "Instruction" {
                continue;
            }
        }
        let Some(level) = std::fs::read_to_string(path.join("level"))
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
        else {
            continue;
        };
        let Some(group) = std::fs::read_to_string(path.join("shared_cpu_list"))
            .ok()
            .and_then(|s| group_physical_cores(&s, smt, stride))
        else {
            continue;
        };
        if best.map(|(l, _)| level > l).unwrap_or(true) {
            best = Some((level, group));
        }
    }
    best.map(|(_, g)| g)
}

/// The physical core the `rank`-th worker of a scatter placement lands
/// on. Round-robin across cache groups, slot by slot. The tail group
/// may hold fewer than `group` cores, so walk the scatter order row by
/// row (`row` = groups that still have a core in slot `s`) instead of
/// assuming every group is full — a closed-form
/// `(rank % groups) * group + rank / groups` would collide workers onto
/// one core for non-divisible layouts.
fn scatter_core(rank: usize, cores: usize, group: usize) -> usize {
    let mut rem = rank;
    let mut s = 0;
    loop {
        let row = (cores - s).div_ceil(group);
        if rem < row {
            break rem * group + s;
        }
        rem -= row;
        s += 1;
    }
}

/// The cpu worker `id` is placed on under `policy` (pure map, unit
/// tested on every platform). Workers beyond the logical cpu count wrap
/// around. Compact and scatter fill every *physical core* before
/// touching a second hardware thread; smtpair packs sibling threads
/// first.
pub fn cpu_for(policy: PinPolicy, id: usize, topo: Topology) -> usize {
    let cores = topo.cores.max(1);
    let smt = topo.smt_per_core.max(1);
    let id = id % (cores * smt);
    match policy {
        PinPolicy::None => id,
        PinPolicy::Compact => topo.cpu_of(id % cores, id / cores),
        PinPolicy::Scatter => {
            let group = topo.group_size.clamp(1, cores);
            topo.cpu_of(scatter_core(id % cores, cores, group), id / cores)
        }
        PinPolicy::SmtPair => topo.cpu_of(id / smt, id % smt),
    }
}

/// Pin the calling thread to `cpu`. Returns `true` on success; `false`
/// when the platform has no affinity backend or the kernel refused the
/// mask (sandboxes, cpusets) — callers must treat pinning as advisory.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_current_thread(cpu: usize) -> bool {
    // cpu_set_t is 1024 bits on Linux.
    let mut mask = [0u64; 16];
    let cpu = cpu % (mask.len() * 64);
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    sched_setaffinity_raw(&mask) == 0
}

/// No-op backend: platforms without `sched_setaffinity` run unpinned.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Number of cpus the calling thread may run on (`None` when the
/// platform has no affinity backend or the query failed — including
/// hosts with more than 1024 possible cpus, where the kernel rejects
/// this fixed-size mask with EINVAL; callers must treat `None` as
/// "unknown", not "unpinned").
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn current_affinity_count() -> Option<usize> {
    let mut mask = [0u64; 16];
    let ret = sched_getaffinity_raw(&mut mask);
    if ret <= 0 {
        return None;
    }
    Some(mask.iter().map(|w| w.count_ones() as usize).sum())
}

/// No-op backend counterpart of [`current_affinity_count`].
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn current_affinity_count() -> Option<usize> {
    None
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_raw(mask: &[u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: sched_setaffinity(2) on the calling thread (pid 0) with a
    // valid, sized mask; the syscall only reads the mask.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") core::mem::size_of::<[u64; 16]>(),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_getaffinity_raw(mask: &mut [u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: sched_getaffinity(2) on the calling thread; the kernel
    // writes at most the passed size into the mask.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 204isize => ret, // __NR_sched_getaffinity
            in("rdi") 0usize,
            in("rsi") core::mem::size_of::<[u64; 16]>(),
            in("rdx") mask.as_mut_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_raw(mask: &[u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: as the x86_64 variant; aarch64 passes the number in x8.
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") 122isize, // __NR_sched_setaffinity
            inlateout("x0") 0isize => ret,
            in("x1") core::mem::size_of::<[u64; 16]>(),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_getaffinity_raw(mask: &mut [u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: as the x86_64 variant; aarch64 passes the number in x8.
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") 123isize, // __NR_sched_getaffinity
            inlateout("x0") 0isize => ret,
            in("x1") core::mem::size_of::<[u64; 16]>(),
            in("x2") mask.as_mut_ptr(),
            options(nostack),
        );
    }
    ret
}

/// Build the [`StartHook`] implementing `policy` on `topo` — `None` for
/// [`PinPolicy::None`] so unpinned pools skip the hook entirely.
///
/// Pinning is advisory: a refused mask (container cpusets, non-Linux
/// hosts) leaves the worker unpinned and the schedule untouched.
pub fn pin_hook(policy: PinPolicy, topo: Topology) -> Option<StartHook> {
    if policy == PinPolicy::None {
        return None;
    }
    Some(Arc::new(move |id: usize| {
        let host = Topology::host();
        // A machine model wider than this host would fold distinct
        // placements onto the same cpu under a modulo wrap (all of a
        // scatter group's leaders landing on cpu 0); pin against the
        // host's own topology instead.
        let eff = if topo.logical_cpus() <= host.logical_cpus() { topo } else { host };
        let _ = pin_current_thread(cpu_for(policy, id, eff));
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// SMT-free layout shorthand for the placement tests.
    fn flat(cores: usize, group_size: usize) -> Topology {
        Topology { cores, group_size, smt_per_core: 1, smt_stride: 1 }
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Scatter, PinPolicy::SmtPair] {
            assert_eq!(PinPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(PinPolicy::parse("diagonal").is_err());
    }

    #[test]
    fn compact_fills_groups_in_order() {
        let topo = flat(8, 4);
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Compact, i, topo)).collect();
        assert_eq!(cpus, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn scatter_round_robins_across_groups() {
        // 8 cores in two OLC groups of 4: workers alternate groups.
        let topo = flat(8, 4);
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Scatter, i, topo)).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn scatter_covers_every_cpu_when_groups_are_uneven() {
        // 6 cores in OLC groups of 4: group 0 = {0,1,2,3}, tail = {4,5}.
        // Every core must appear exactly once — no collisions, no idle.
        let topo = flat(6, 4);
        let cpus: Vec<usize> = (0..6).map(|i| cpu_for(PinPolicy::Scatter, i, topo)).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 2, 3]);
        let mut sorted = cpus.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn scatter_on_one_flat_group_is_compact() {
        let topo = flat(6, 6);
        for i in 0..6 {
            assert_eq!(
                cpu_for(PinPolicy::Scatter, i, topo),
                cpu_for(PinPolicy::Compact, i, topo)
            );
        }
    }

    #[test]
    fn workers_beyond_the_socket_wrap() {
        let topo = flat(4, 2);
        for i in 0..32 {
            assert!(cpu_for(PinPolicy::Scatter, i, topo) < 4);
            assert!(cpu_for(PinPolicy::Compact, i, topo) < 4);
        }
        // SMT widens the wrap to the logical cpu count
        let smt = Topology { cores: 4, group_size: 2, smt_per_core: 2, smt_stride: 4 };
        for i in 0..32 {
            assert!(cpu_for(PinPolicy::SmtPair, i, smt) < 8);
            assert!(cpu_for(PinPolicy::Compact, i, smt) < 8);
        }
    }

    #[test]
    fn compact_and_scatter_fill_physical_cores_before_siblings() {
        // 4 cores × 2 threads, split-style siblings (cpu c and c+4):
        // the first 4 workers must own distinct physical cores under
        // either policy; only workers 4..8 move onto second threads.
        let topo = Topology { cores: 4, group_size: 2, smt_per_core: 2, smt_stride: 4 };
        let compact: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Compact, i, topo)).collect();
        assert_eq!(compact, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let scatter: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Scatter, i, topo)).collect();
        assert_eq!(scatter, vec![0, 2, 1, 3, 4, 6, 5, 7]);
        // adjacent sibling enumeration (cpu 2c, 2c+1) spreads the same
        // physical placement over the other cpu numbering
        let adj = Topology { smt_stride: 1, ..topo };
        let compact: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Compact, i, adj)).collect();
        assert_eq!(compact, vec![0, 2, 4, 6, 1, 3, 5, 7]);
    }

    #[test]
    fn smtpair_packs_sibling_threads() {
        // split-style: workers 2c and 2c+1 land on cpus c and c+4 —
        // one physical core's two hyperthreads
        let topo = Topology { cores: 4, group_size: 4, smt_per_core: 2, smt_stride: 4 };
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::SmtPair, i, topo)).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 2, 6, 3, 7]);
        // adjacent-style: the pair becomes cpus 2c and 2c+1
        let adj = Topology { smt_stride: 1, ..topo };
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::SmtPair, i, adj)).collect();
        assert_eq!(cpus, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        // without SMT the policy degenerates to compact
        let none = flat(4, 4);
        for i in 0..4 {
            assert_eq!(
                cpu_for(PinPolicy::SmtPair, i, none),
                cpu_for(PinPolicy::Compact, i, none)
            );
        }
    }

    #[test]
    fn cpu_list_parser_handles_sysfs_shapes() {
        assert_eq!(parse_cpu_runs("0-3"), Some(vec![(0, 3)]));
        assert_eq!(parse_cpu_runs("0-3,8-11"), Some(vec![(0, 3), (8, 11)]));
        assert_eq!(parse_cpu_runs("5"), Some(vec![(5, 5)]));
        assert_eq!(parse_cpu_runs("0,2,4,6"), Some(vec![(0, 0), (2, 2), (4, 4), (6, 6)]));
        // adjacent ids coalesce into one run regardless of spelling
        assert_eq!(parse_cpu_runs("0,1,2,3"), Some(vec![(0, 3)]));
        assert_eq!(parse_cpu_runs("0-1,2-3"), Some(vec![(0, 3)]));
        assert_eq!(parse_cpu_runs("0-0"), Some(vec![(0, 0)]));
        assert_eq!(parse_cpu_runs(" 0-7 \n"), Some(vec![(0, 7)]));
        assert_eq!(parse_cpu_runs(""), None);
        assert_eq!(parse_cpu_runs("3-1"), None);
        assert_eq!(parse_cpu_runs("a-b"), None);
        assert_eq!(parse_cpu_runs("1,,2"), None);
        assert_eq!(parse_cpu_runs("4,2"), None); // unsorted
        assert_eq!(parse_cpu_runs("0-3,2-5"), None); // overlap
    }

    #[test]
    fn shared_cpu_lists_resolve_to_physical_cores() {
        // no SMT: one contiguous block, count = cores
        assert_eq!(group_physical_cores("0-7", 1, 1), Some(8));
        assert_eq!(group_physical_cores("4-7", 1, 1), Some(4));
        assert_eq!(group_physical_cores("5", 1, 1), Some(1));
        // adjacent siblings: 8 cpus = 4 cores × 2 threads
        assert_eq!(group_physical_cores("0-7", 2, 1), Some(4));
        assert_eq!(group_physical_cores("0-7", 4, 1), Some(2));
        // the satellite case: split siblings — 0-15 plus their 32-offset
        // twins is 16 physical cores, not a rejected layout
        assert_eq!(group_physical_cores("0-15,32-47", 2, 32), Some(16));
        assert_eq!(group_physical_cores("8-11,40-43", 2, 32), Some(4));
        assert_eq!(group_physical_cores("0,32", 2, 32), Some(1));
        // sibling copies merged into one run: block spans the stride
        assert_eq!(group_physical_cores("0-63", 2, 32), Some(32));
        // shapes that do not resolve fall back flat
        assert_eq!(group_physical_cores("0-6", 2, 1), None); // odd count
        assert_eq!(group_physical_cores("0-15,31-46", 2, 32), None); // bad offset
        assert_eq!(group_physical_cores("0-15,32-40", 2, 32), None); // width mismatch
        assert_eq!(group_physical_cores("0-15,32-47,64-79", 2, 32), None); // run count
        assert_eq!(group_physical_cores("0,2,4,6", 1, 1), None);
        assert_eq!(group_physical_cores("", 2, 32), None);
    }

    #[test]
    fn host_topology_is_well_formed() {
        // whatever the backend (sysfs or flat fallback), the invariants
        // the cpu map relies on must hold
        let t = Topology::host();
        assert!(t.cores >= 1);
        assert!(t.group_size >= 1 && t.group_size <= t.cores);
        assert!(t.smt_per_core >= 1);
        assert_eq!(t.logical_cpus(), t.cores * t.smt_per_core);
        // every placement stays a permutation of the logical cpus under
        // the host topology
        for p in [PinPolicy::Compact, PinPolicy::Scatter, PinPolicy::SmtPair] {
            let mut cpus: Vec<usize> =
                (0..t.logical_cpus()).map(|i| cpu_for(p, i, t)).collect();
            cpus.sort_unstable();
            cpus.dedup();
            assert_eq!(cpus.len(), t.logical_cpus(), "{p:?} collides workers");
        }
    }

    #[test]
    fn machine_topology_uses_cache_groups() {
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        let topo = Topology::of_machine(&m);
        assert_eq!(topo.cores, m.cores);
        assert_eq!(topo.group_size, m.cache_group_cores());
        assert_eq!(topo.smt_per_core, m.smt_per_core);
        assert_eq!(topo.logical_cpus(), m.socket_threads(true));
    }

    #[test]
    fn pinning_is_advisory_and_never_panics() {
        // On Linux this really pins (count == 1 when the kernel allowed
        // it); elsewhere it must be a clean no-op returning false.
        std::thread::spawn(|| {
            let ok = pin_current_thread(0);
            if cfg!(not(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))) {
                assert!(!ok, "no-op backend must report failure");
            }
            if ok {
                // None = the count query itself failed (e.g. hosts with
                // > 1024 possible cpus reject the fixed-size mask) —
                // only a Some answer can contradict the pin
                if let Some(n) = current_affinity_count() {
                    assert_eq!(n, 1);
                }
            }
        })
        .join()
        .unwrap();
    }

    #[test]
    fn none_policy_has_no_hook() {
        assert!(pin_hook(PinPolicy::None, Topology::host()).is_none());
        assert!(pin_hook(PinPolicy::Compact, Topology::host()).is_some());
    }
}
