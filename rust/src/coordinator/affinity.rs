//! Core-pinning policies for the worker pool (ROADMAP item).
//!
//! The paper's wavefront groups only hit their cache-sharing sweet spot
//! when the group's threads actually land on cores that share the outer
//! level cache (Sec. 4; Tab. 1's "cache group"). The OS scheduler does
//! not know that, so [`PinPolicy`] encodes the two classic placements:
//!
//! * [`PinPolicy::Compact`] — fill one cache group before touching the
//!   next (worker `i` → cpu `i`). The right policy for a single
//!   wavefront group: all `t` workers share one OLC.
//! * [`PinPolicy::Scatter`] — round-robin across cache groups (worker
//!   `i` → group `i mod G`, slot `i / G`). The right policy for
//!   bandwidth-bound baselines and multi-group schemes where each group
//!   should own its own OLC.
//!
//! The cpu map is computed from a [`MachineSpec`]'s cache-group topology
//! when the run names a Tab. 1 machine, and from the *host's* real cache
//! groups otherwise (parsed from
//! `/sys/devices/system/cpu/cpu0/cache/index*/shared_cpu_list` on Linux;
//! one flat group when sysfs is unreadable). The backend is a raw
//! `sched_setaffinity` syscall on Linux (x86_64 / aarch64) — the build
//! stays dependency-free — and a documented no-op everywhere else:
//! [`pin_current_thread`] returns `false` and workers simply run
//! unpinned, so schedules stay correct on every platform.
//!
//! Wired through [`WorkerPool::set_start_hook`](super::pool::WorkerPool::set_start_hook)
//! by [`pin_hook`]; the [`Solver`](super::solver::Solver) builder installs
//! it before spawning the team.

use std::sync::Arc;

use crate::simulator::machine::MachineSpec;
use crate::Result;

use super::pool::StartHook;

/// How pool workers are placed on cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PinPolicy {
    /// Leave placement to the OS scheduler (the default).
    #[default]
    None,
    /// Fill cache groups in order: worker `i` runs on cpu `i`.
    Compact,
    /// Spread across cache groups: worker `i` runs in group `i mod G`.
    ///
    /// Needs cache-group information to differ from [`PinPolicy::Compact`]:
    /// without a Tab. 1 machine model the host fallback is one flat group
    /// and scatter degenerates to compact (see [`Topology::host`]).
    Scatter,
}

impl PinPolicy {
    /// Parse a `none` / `compact` / `scatter` policy name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "none" => PinPolicy::None,
            "compact" => PinPolicy::Compact,
            "scatter" => PinPolicy::Scatter,
            other => anyhow::bail!("unknown pin policy '{other}' (none/compact/scatter)"),
        })
    }

    /// The config/CLI name of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            PinPolicy::None => "none",
            PinPolicy::Compact => "compact",
            PinPolicy::Scatter => "scatter",
        }
    }
}

/// The core/cache-group layout the cpu map is computed from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Logical cpus to place workers on.
    pub cpus: usize,
    /// Cpus sharing one outer-level cache (`<= cpus`).
    pub group_size: usize,
}

impl Topology {
    /// Topology of a Tab. 1 machine: its physical cores, grouped by the
    /// cache group the wavefront scheme targets (L3, or the shared L2 on
    /// Core 2).
    pub fn of_machine(m: &MachineSpec) -> Self {
        Self { cpus: m.cores.max(1), group_size: m.cache_group_cores().max(1) }
    }

    /// Topology of the machine this process runs on.
    ///
    /// On Linux the real cache groups are read from
    /// `/sys/devices/system/cpu/cpu0/cache/index*/shared_cpu_list` (the
    /// deepest unified cache wins — the host analog of Tab. 1's "cache
    /// group"), so `compact`/`scatter` place workers against the
    /// *host's* OLC sharing instead of a model's. Only groups that form
    /// one contiguous cpu-id block are honored — the cpu map indexes
    /// groups as `[g·size, (g+1)·size)`, so a sibling-split list like
    /// `0-15,32-47` would silently straddle two real caches. When sysfs
    /// is unreadable (non-Linux, sandboxes) or the layout is
    /// non-contiguous, every logical cpu falls into one flat group
    /// (compact and scatter then coincide); runs that name a Tab. 1
    /// machine keep using [`Topology::of_machine`].
    pub fn host() -> Self {
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        match sysfs_cache_group() {
            Some(group) if group >= 1 => Self { cpus, group_size: group.min(cpus) },
            _ => Self { cpus, group_size: cpus },
        }
    }
}

/// `(count, lowest cpu, highest cpu)` of a sysfs cpu-list string like
/// `"0-3,8-11"` (`None` on malformed input — callers fall back to the
/// flat group).
fn parse_cpu_list_span(s: &str) -> Option<(usize, usize, usize)> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let mut count = 0usize;
    let mut min = usize::MAX;
    let mut max = 0usize;
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            return None;
        }
        let (lo, hi) = match part.split_once('-') {
            Some((lo, hi)) => (lo.trim().parse().ok()?, hi.trim().parse().ok()?),
            None => {
                let v: usize = part.trim().parse().ok()?;
                (v, v)
            }
        };
        if hi < lo {
            return None;
        }
        count += hi - lo + 1;
        min = min.min(lo);
        max = max.max(hi);
    }
    Some((count, min, max))
}

/// The group size of a cpu list *if* the cpu map's contiguous-block
/// assumption holds for it (one unbroken id range). Sibling-split
/// layouts like `"0-15,32-47"` return `None` — [`cpu_for`] would place
/// teams across two real cache groups while claiming one, so those
/// hosts fall back to the flat group (compact == scatter, harmless).
///
/// Known limitation: only *cpu0's* group is inspected (sysfs exposes one
/// directory per cpu; enumerating all of them is future work), so the
/// check also assumes every group has cpu0's size and sits at a
/// `group_size`-aligned offset. Hosts with heterogeneous or offset
/// groups (offline-cpu holes, asymmetric clusters) can still be
/// mis-pinned; pinning remains advisory and never affects correctness.
fn contiguous_group_size(s: &str) -> Option<usize> {
    let (count, lo, hi) = parse_cpu_list_span(s)?;
    (hi - lo + 1 == count).then_some(count)
}

/// Size of cpu0's deepest shared cache group per sysfs, `None` when the
/// hierarchy is unreadable.
fn sysfs_cache_group() -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    let mut best: Option<(usize, usize)> = None; // (level, group size)
    for entry in std::fs::read_dir(base).ok()? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let is_index = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with("index"))
            .unwrap_or(false);
        if !is_index {
            continue;
        }
        // instruction caches are not sharing domains the schemes care about
        if let Ok(ty) = std::fs::read_to_string(path.join("type")) {
            if ty.trim() == "Instruction" {
                continue;
            }
        }
        let Some(level) = std::fs::read_to_string(path.join("level"))
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
        else {
            continue;
        };
        let Some(group) = std::fs::read_to_string(path.join("shared_cpu_list"))
            .ok()
            .and_then(|s| contiguous_group_size(&s))
        else {
            continue;
        };
        if best.map(|(l, _)| level > l).unwrap_or(true) {
            best = Some((level, group));
        }
    }
    best.map(|(_, g)| g)
}

/// The cpu worker `id` is placed on under `policy` (pure map, unit
/// tested on every platform). Workers beyond `cpus` wrap around.
pub fn cpu_for(policy: PinPolicy, id: usize, topo: Topology) -> usize {
    let cpus = topo.cpus.max(1);
    let id = id % cpus;
    match policy {
        PinPolicy::None => id,
        PinPolicy::Compact => id,
        PinPolicy::Scatter => {
            // Round-robin across cache groups, slot by slot. The tail
            // group may hold fewer than `group` cpus, so walk the scatter
            // order row by row (`row` = groups that still have a cpu in
            // slot `s`) instead of assuming every group is full — a
            // closed-form `(id % groups) * group + id / groups` would
            // collide workers onto one cpu for non-divisible layouts.
            let group = topo.group_size.clamp(1, cpus);
            let mut rem = id;
            let mut s = 0;
            loop {
                let row = (cpus - s).div_ceil(group);
                if rem < row {
                    break rem * group + s;
                }
                rem -= row;
                s += 1;
            }
        }
    }
}

/// Pin the calling thread to `cpu`. Returns `true` on success; `false`
/// when the platform has no affinity backend or the kernel refused the
/// mask (sandboxes, cpusets) — callers must treat pinning as advisory.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_current_thread(cpu: usize) -> bool {
    // cpu_set_t is 1024 bits on Linux.
    let mut mask = [0u64; 16];
    let cpu = cpu % (mask.len() * 64);
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    sched_setaffinity_raw(&mask) == 0
}

/// No-op backend: platforms without `sched_setaffinity` run unpinned.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Number of cpus the calling thread may run on (`None` when the
/// platform has no affinity backend or the query failed — including
/// hosts with more than 1024 possible cpus, where the kernel rejects
/// this fixed-size mask with EINVAL; callers must treat `None` as
/// "unknown", not "unpinned").
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn current_affinity_count() -> Option<usize> {
    let mut mask = [0u64; 16];
    let ret = sched_getaffinity_raw(&mut mask);
    if ret <= 0 {
        return None;
    }
    Some(mask.iter().map(|w| w.count_ones() as usize).sum())
}

/// No-op backend counterpart of [`current_affinity_count`].
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn current_affinity_count() -> Option<usize> {
    None
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_raw(mask: &[u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: sched_setaffinity(2) on the calling thread (pid 0) with a
    // valid, sized mask; the syscall only reads the mask.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") core::mem::size_of::<[u64; 16]>(),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_getaffinity_raw(mask: &mut [u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: sched_getaffinity(2) on the calling thread; the kernel
    // writes at most the passed size into the mask.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 204isize => ret, // __NR_sched_getaffinity
            in("rdi") 0usize,
            in("rsi") core::mem::size_of::<[u64; 16]>(),
            in("rdx") mask.as_mut_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_raw(mask: &[u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: as the x86_64 variant; aarch64 passes the number in x8.
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") 122isize, // __NR_sched_setaffinity
            inlateout("x0") 0isize => ret,
            in("x1") core::mem::size_of::<[u64; 16]>(),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_getaffinity_raw(mask: &mut [u64; 16]) -> isize {
    let ret: isize;
    // SAFETY: as the x86_64 variant; aarch64 passes the number in x8.
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") 123isize, // __NR_sched_getaffinity
            inlateout("x0") 0isize => ret,
            in("x1") core::mem::size_of::<[u64; 16]>(),
            in("x2") mask.as_mut_ptr(),
            options(nostack),
        );
    }
    ret
}

/// Build the [`StartHook`] implementing `policy` on `topo` — `None` for
/// [`PinPolicy::None`] so unpinned pools skip the hook entirely.
///
/// Pinning is advisory: a refused mask (container cpusets, non-Linux
/// hosts) leaves the worker unpinned and the schedule untouched.
pub fn pin_hook(policy: PinPolicy, topo: Topology) -> Option<StartHook> {
    if policy == PinPolicy::None {
        return None;
    }
    Some(Arc::new(move |id: usize| {
        let host = Topology::host();
        // A machine model wider than this host would fold distinct
        // placements onto the same cpu under a modulo wrap (all of a
        // scatter group's leaders landing on cpu 0); pin against the
        // host's own topology instead.
        let eff = if topo.cpus <= host.cpus { topo } else { host };
        let _ = pin_current_thread(cpu_for(policy, id, eff));
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Scatter] {
            assert_eq!(PinPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(PinPolicy::parse("diagonal").is_err());
    }

    #[test]
    fn compact_fills_groups_in_order() {
        let topo = Topology { cpus: 8, group_size: 4 };
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Compact, i, topo)).collect();
        assert_eq!(cpus, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn scatter_round_robins_across_groups() {
        // 8 cpus in two OLC groups of 4: workers alternate groups.
        let topo = Topology { cpus: 8, group_size: 4 };
        let cpus: Vec<usize> = (0..8).map(|i| cpu_for(PinPolicy::Scatter, i, topo)).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn scatter_covers_every_cpu_when_groups_are_uneven() {
        // 6 cpus in OLC groups of 4: group 0 = {0,1,2,3}, tail = {4,5}.
        // Every cpu must appear exactly once — no collisions, no idle cpu.
        let topo = Topology { cpus: 6, group_size: 4 };
        let cpus: Vec<usize> = (0..6).map(|i| cpu_for(PinPolicy::Scatter, i, topo)).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 2, 3]);
        let mut sorted = cpus.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn scatter_on_one_flat_group_is_compact() {
        let topo = Topology { cpus: 6, group_size: 6 };
        for i in 0..6 {
            assert_eq!(
                cpu_for(PinPolicy::Scatter, i, topo),
                cpu_for(PinPolicy::Compact, i, topo)
            );
        }
    }

    #[test]
    fn workers_beyond_the_socket_wrap() {
        let topo = Topology { cpus: 4, group_size: 2 };
        for i in 0..32 {
            assert!(cpu_for(PinPolicy::Scatter, i, topo) < 4);
            assert!(cpu_for(PinPolicy::Compact, i, topo) < 4);
        }
    }

    #[test]
    fn cpu_list_parser_handles_sysfs_shapes() {
        assert_eq!(parse_cpu_list_span("0-3"), Some((4, 0, 3)));
        assert_eq!(parse_cpu_list_span("0-3,8-11"), Some((8, 0, 11)));
        assert_eq!(parse_cpu_list_span("5"), Some((1, 5, 5)));
        assert_eq!(parse_cpu_list_span("0,2,4,6"), Some((4, 0, 6)));
        assert_eq!(parse_cpu_list_span("0-0"), Some((1, 0, 0)));
        assert_eq!(parse_cpu_list_span(" 0-7 \n"), Some((8, 0, 7)));
        assert_eq!(parse_cpu_list_span(""), None);
        assert_eq!(parse_cpu_list_span("3-1"), None);
        assert_eq!(parse_cpu_list_span("a-b"), None);
        assert_eq!(parse_cpu_list_span("1,,2"), None);
    }

    #[test]
    fn only_contiguous_cpu_lists_become_cache_groups() {
        // the cpu map assumes groups are contiguous id blocks; any other
        // layout (SMT sibling splits, offline holes) must fall back flat
        assert_eq!(contiguous_group_size("0-7"), Some(8));
        assert_eq!(contiguous_group_size("4-7"), Some(4));
        assert_eq!(contiguous_group_size("0,1,2,3"), Some(4));
        assert_eq!(contiguous_group_size("5"), Some(1));
        assert_eq!(contiguous_group_size("0-15,32-47"), None);
        assert_eq!(contiguous_group_size("0,32"), None);
        assert_eq!(contiguous_group_size("0,2,4,6"), None);
        assert_eq!(contiguous_group_size(""), None);
    }

    #[test]
    fn host_topology_is_well_formed() {
        // whatever the backend (sysfs or flat fallback), the invariants
        // the cpu map relies on must hold
        let t = Topology::host();
        assert!(t.cpus >= 1);
        assert!(t.group_size >= 1 && t.group_size <= t.cpus);
        // the scatter map stays a permutation under the host topology
        let cpus: Vec<usize> = (0..t.cpus).map(|i| cpu_for(PinPolicy::Scatter, i, t)).collect();
        let mut sorted = cpus.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..t.cpus).collect::<Vec<_>>());
    }

    #[test]
    fn machine_topology_uses_cache_groups() {
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        let topo = Topology::of_machine(&m);
        assert_eq!(topo.cpus, m.cores);
        assert_eq!(topo.group_size, m.cache_group_cores());
    }

    #[test]
    fn pinning_is_advisory_and_never_panics() {
        // On Linux this really pins (count == 1 when the kernel allowed
        // it); elsewhere it must be a clean no-op returning false.
        std::thread::spawn(|| {
            let ok = pin_current_thread(0);
            if cfg!(not(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))) {
                assert!(!ok, "no-op backend must report failure");
            }
            if ok {
                // None = the count query itself failed (e.g. hosts with
                // > 1024 possible cpus reject the fixed-size mask) —
                // only a Some answer can contradict the pin
                if let Some(n) = current_affinity_count() {
                    assert_eq!(n, 1);
                }
            }
        })
        .join()
        .unwrap();
    }

    #[test]
    fn none_policy_has_no_hook() {
        assert!(pin_hook(PinPolicy::None, Topology::host()).is_none());
        assert!(pin_hook(PinPolicy::Compact, Topology::host()).is_some());
    }
}
