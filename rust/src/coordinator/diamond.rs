//! Diamond-tile temporal blocking for Jacobi — the multicore wavefront
//! diamond scheme (Malas/Hager et al., arXiv:1410.3060) adapted to this
//! crate's pool/schedule core, generic over the [`StencilOp`] layer.
//!
//! [`super::spatial_mg`] decomposes y into blocks whose per-level update
//! regions all skew *downward*; exactness across block seams then needs
//! per-seam boundary arrays (saving odd-level lines the ring recycles)
//! and every block pays the z-pipeline wind-up/wind-down waste once per
//! temporal block. Diamond tiling removes both by alternating two tile
//! shapes along y that *exactly tile* the interior at every temporal
//! level:
//!
//! * **A tiles** (one per interval `i`) shrink with the level:
//!   `[starts[i] + R(s-1), starts[i+1] - R(s-1))` (domain edges do not
//!   shrink — the first/last A tile stays clamped at `R` / `ny-R`);
//! * **B tiles** (one per interior seam `i = 1..G-1`) grow into the gap
//!   the A tiles vacate: `[starts[i] - R(s-1), starts[i] + R(s-1))` —
//!   empty at `s = 1`.
//!
//! At every level `s` the A and B regions partition `[R, ny-R)` with no
//! overlap and no gap, so *one shared* `(t/2) × (2R+2)`-plane temporary
//! ring holds every odd-level value — a reader indexes it by
//! `(level, plane, y)` without knowing which tile produced the line,
//! and no boundary arrays exist at all. The `2G-1` workers interleave
//! `A_0, B_1, A_1, …, B_{G-1}, A_{G-1}` along y, so adjacent worker ids
//! are spatially adjacent (which is exactly what
//! [`PinPolicy::SmtPair`](super::affinity::PinPolicy) co-scheduling
//! wants: seam neighbors share a core and its cache).
//!
//! All tiles co-traverse z as one wavefront (same plane/round mapping as
//! the other temporally blocked schemes: level `s` updates plane
//! `k = round + (R-1) - (R+1)(s-1)`), so the whole pass pays the
//! z-pipeline fill once — not once per block.
//!
//! ## Why a symmetric one-round lag suffices (any radius)
//!
//! All cross-tile traffic is between y-adjacent tiles, i.e. adjacent
//! worker ids. For the level-`s` update of plane `k` in round `ρ`:
//!
//! * *flow*: every level-`s-1` value read from the neighbor tile (src
//!   lines for even `s-1`, shared-ring lines for odd `s-1`) was produced
//!   at plane `<= k+R`, which is round `<= ρ-1` — the `R`-plane halo
//!   shift exactly cancels one level of lag;
//! * *anti (ring recycle)*: a tile's odd-level write of plane `k`
//!   overwrites the ring slot holding plane `k - (2R+2)`, whose last
//!   neighbor read (level `s+1`, plane `k - (2R+2) + R`) happens exactly
//!   one round *before* the write — so waiting for the neighbor to
//!   finish round `ρ-1` is exactly the necessary back-pressure;
//! * *anti (src)*: an even-level write destroys level-`s-2` src values
//!   whose last neighbor halo read lies `2R+1` rounds earlier.
//!
//! Hence worker `w` at round `ρ` waits for *both* neighbors (`w-1` and
//! `w+1`) to have completed round `ρ-1`, works, and publishes `ρ`. The
//! waits only ever reference completed rounds, so the protocol is
//! acyclic and deadlock-free; `G = 1` degenerates to a single unwaited
//! worker (the plain single-group wavefront).
//!
//! A Gauss-Seidel diamond member is *deferred*: the lexicographic
//! in-place update order requires lower-y values of the same level
//! before higher-y ones, but a growing B tile would have to update its
//! seam lines before the A tile below it finishes that level — the
//! A-before-B within-level order diamonds need contradicts the GS
//! recursion (see ROADMAP).
//!
//! Result: bit-identical to `t` serial sweeps for every `(t, groups)`
//! and radius — asserted by the tests, `tests/diamond.rs` and
//! `launcher::run_experiment` on every launch.

use std::marker::PhantomData;

use crate::config::{BlockWidthError, Scheme};
use crate::simulator::memory::StoreMode;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{StarWindow, StencilOp, MAX_RADIUS};
use crate::stencil::simd;
use crate::Result;

use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};
use super::wavefront::tmp_slots;

/// Configuration of a diamond-tiled (temporal blocking) pass.
#[derive(Clone, Copy, Debug)]
pub struct DiamondConfig {
    /// Temporal blocking factor `t` (even, >= 2).
    pub t: usize,
    /// Tile intervals along y (>= 1). The pass runs `2·groups - 1`
    /// workers (one A tile per interval, one B tile per interior seam);
    /// each interval needs `>= 2R(t-1)` interior lines when
    /// `groups > 1` so two growing seam tiles never meet.
    pub groups: usize,
    /// Store mode for the *final-level* (`s == t`) writes back into `u`.
    /// Earlier even levels are re-read by deeper levels and by seam
    /// neighbors, so they always use write-allocate stores.
    pub store: StoreMode,
    /// Fault-injection knob **for tests only**: weakens every neighbor
    /// wait from "round - 1" to "round - 1 - wait_slack". 0 (the only
    /// value the runner ever passes) is the exact protocol; larger
    /// values let workers run ahead of their seam neighbors, which the
    /// negative-control test uses to demonstrate the waits are
    /// load-bearing (parity breaks).
    pub wait_slack: usize,
}

impl Default for DiamondConfig {
    fn default() -> Self {
        Self { t: 4, groups: 2, store: StoreMode::NonTemporal, wait_slack: 0 }
    }
}

impl DiamondConfig {
    /// Validate the grid-independent part of the configuration (single
    /// source for every entry point); the per-interval width requirement
    /// needs the grid and the op radius and lives in
    /// [`DiamondSchedule::new`].
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.t >= 2 && self.t % 2 == 0,
            "diamond blocking needs even t >= 2, got {}",
            self.t
        );
        anyhow::ensure!(self.groups >= 1, "need at least one tile interval");
        Ok(())
    }
}

/// One diamond-tiled pass (`t` fused updates of `op`) as a
/// [`Schedule`]: even workers sweep shrinking A tiles, odd workers the
/// growing B seam tiles, all time-shifted through z as one wavefront.
pub struct DiamondSchedule<'g, O: StencilOp> {
    op: &'g O,
    src: *mut f64,
    f: *const f64,
    /// `(t/2) * (2R+2)` z-x planes — **one shared ring** for every tile
    /// (the exact-tiling property makes the producer irrelevant).
    tmp: *mut f64,
    /// `(2·groups - 1) * nx` per-worker x-line update buffers (disjoint
    /// slices of pool-owned scratch).
    lines: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    r: usize,
    groups: usize,
    h2: f64,
    store: StoreMode,
    wait_slack: usize,
    /// Interval boundaries over the interior lines `[R, ny-R)`.
    starts: Vec<usize>,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: at every level the A/B tiles partition the interior, so all
// writes (shared ring, src, own line slice) are disjoint across
// workers; the symmetric one-round-lag protocol orders every cross-tile
// read/write pair (module docs).
unsafe impl<O: StencilOp> Send for DiamondSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for DiamondSchedule<'_, O> {}

impl<'g, O: StencilOp> DiamondSchedule<'g, O> {
    /// Build a pass over `u`. `tmp` and `lines` are caller-owned scratch
    /// buffers (typically the pool's reusable
    /// [`Scratch`](super::pool::Scratch)), resized here; they must stay
    /// alive (and untouched) for as long as the schedule runs.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        op: &'g O,
        u: &'g mut Grid3,
        f: &'g Grid3,
        tmp: &'g mut Vec<f64>,
        lines: &'g mut Vec<f64>,
        h2: f64,
        cfg: &DiamondConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.t;
        let groups = cfg.groups;
        let r = op.radius();
        anyhow::ensure!(r >= 1 && r <= MAX_RADIUS, "unsupported halo radius {r}");
        anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} diamond pass"
        );
        BlockWidthError::check(Scheme::JacobiDiamond, r, ny, groups, t)?;
        let interior = ny - 2 * r;
        let plane = ny * nx;
        let slots = tmp_slots(r);
        let levels = t / 2;
        tmp.clear();
        tmp.resize(levels * slots * plane, 0.0);
        lines.clear();
        lines.resize((2 * groups - 1) * nx, 0.0);
        let starts: Vec<usize> = (0..=groups).map(|b| r + b * interior / groups).collect();
        let lag = (r + 1) as isize;
        Ok(Self {
            op,
            src: u.data_mut().as_mut_ptr(),
            f: f.data().as_ptr(),
            tmp: tmp.as_mut_ptr(),
            lines: lines.as_mut_ptr(),
            nz,
            ny,
            nx,
            t,
            r,
            groups,
            h2,
            store: cfg.store,
            wait_slack: cfg.wait_slack,
            starts,
            last_round: (nz - 2 * r) as isize + lag * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for DiamondSchedule<'_, O> {
    fn workers(&self) -> usize {
        2 * self.groups - 1
    }

    fn worker(&self, w: usize, progress: &Progress) {
        let (nz, ny, nx, t, r) = (self.nz, self.ny, self.nx, self.t, self.r);
        let plane = ny * nx;
        let slots = tmp_slots(r);
        let lag = (r + 1) as isize;
        let n_workers = 2 * self.groups - 1;
        let tmp = self.tmp;
        let src = self.src;
        let f_base = self.f;
        // even worker 2i: A tile of interval i; odd worker 2i-1: B tile
        // of seam i (the boundary starts[i])
        let is_a = w % 2 == 0;
        let idx = if is_a { w / 2 } else { (w + 1) / 2 };
        let slack = self.wait_slack as isize;

        // per-level y region of this tile (A shrinks, B grows; the
        // domain-edge A tiles stay clamped — they absorb the skew the
        // boundary shell would otherwise demand)
        let region = |s: usize| -> (usize, usize) {
            let shift = r * (s - 1);
            if is_a {
                let lo = if idx == 0 { r } else { self.starts[idx] + shift };
                let hi =
                    if idx + 1 == self.groups { ny - r } else { self.starts[idx + 1] - shift };
                (lo, hi)
            } else {
                (self.starts[idx] - shift, self.starts[idx] + shift)
            }
        };

        // level-(s-1) value of line (k, y): src for boundaries and even
        // levels, the shared ring for odd levels — producer-agnostic, the
        // exact tiling guarantees a unique writer per (level, k, y).
        let read_line = |s: usize, k: usize, y: usize| -> *const f64 {
            if k < r || k >= nz - r || y < r || y >= ny - r {
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let prev = s - 1;
            if prev % 2 == 0 {
                // even levels (incl. 0 = original) live in src
                return unsafe { src.add((k * ny + y) * nx) as *const f64 };
            }
            let lvl = (prev - 1) / 2;
            unsafe { tmp.add((lvl * slots + k % slots) * plane + y * nx) as *const f64 }
        };

        // scratch line reused across every (round, level, y) iteration —
        // worker w's disjoint slice of the pool-owned line scratch.
        // SAFETY: slice `[w*nx, (w+1)*nx)` is written by worker w only.
        let out: &mut [f64] =
            unsafe { std::slice::from_raw_parts_mut(self.lines.add(w * nx), nx) };
        for round in 1..=self.last_round {
            // symmetric one-round lag: both seam neighbors must have
            // completed the previous round before this tile's reads
            // (flow) and overwrites (ring recycle, even-level src) of
            // shared lines are safe — see module docs. `wait_slack` is
            // the tests' fault-injection knob; the runner passes 0.
            if w > 0 {
                progress.wait_min(w - 1, round - 1 - slack);
            }
            if w + 1 < n_workers {
                progress.wait_min(w + 1, round - 1 - slack);
            }
            for s in 1..=t {
                let k = round + (r as isize - 1) - lag * (s as isize - 1);
                if k < r as isize || k > (nz - 1 - r) as isize {
                    continue;
                }
                let k = k as usize;
                let (y_lo, y_hi) = region(s);
                let lvl = (s - 1) / 2; // ring level index for odd-s writes
                for y in y_lo..y_hi {
                    // SAFETY: the one-round-lag protocol freezes every
                    // line the reads touch and the exact tiling gives
                    // this tile exclusive write access to its region
                    // (module docs).
                    unsafe {
                        let line = |p: *const f64| std::slice::from_raw_parts(p, nx);
                        let c = line(read_line(s, k, y));
                        let win = StarWindow::from_fn(c, r, |dz, dy| {
                            let kk = (k as isize + dz) as usize;
                            let yy = (y as isize + dy) as usize;
                            line(read_line(s, kk, yy))
                        });
                        let rhs = std::slice::from_raw_parts(f_base.add((k * ny + y) * nx), nx);
                        crate::stencil::op::copy_x_edges(out, c, r);
                        // `out` is reused scratch every iteration — always
                        // write-allocate; streaming happens on the final
                        // copy back into `u` below.
                        self.op.line_update(out, &win, rhs, self.h2, k, y, StoreMode::WriteAllocate);
                        if s % 2 == 1 {
                            let dst = tmp.add((lvl * slots + k % slots) * plane + y * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                        } else if s == t {
                            // final level: nothing re-reads these lines
                            // within the pass, so honor the configured
                            // store mode (streaming skips write-allocate).
                            let dst = std::slice::from_raw_parts_mut(src.add((k * ny + y) * nx), nx);
                            simd::stream_copy(dst, out, self.store);
                        } else {
                            // intermediate even levels are re-read by
                            // deeper levels and seam neighbors: keep them
                            // cache-resident.
                            let dst = src.add((k * ny + y) * nx);
                            std::ptr::copy_nonoverlapping(out.as_ptr(), dst, nx);
                        }
                    }
                }
            }
            progress.publish(w, round);
        }
    }
}

/// Run `passes` diamond-tiled passes of `op` on `pool` with one
/// schedule — the entry point the [`SchemeRunner`] registry, tests and
/// benches drive. All scratch (the shared plane ring and the per-worker
/// x-lines) comes from the dispatcher's reusable
/// [`Scratch`](super::pool::Scratch) arena, returned by the RAII guard
/// even when a sweep panics.
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn diamond_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &DiamondConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    let mut scratch = pool.scratch();
    // split the guard once so the two arenas borrow disjointly
    let s = &mut *scratch;
    let schedule = DiamondSchedule::new(op, u, f, &mut s.planes, &mut s.lines, h2, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::coordinator::wavefront::{check_iters_multiple, serial_reference, serial_reference_op};
    use crate::stencil::op::{Aniso7, ConstLaplace7, Laplace13, VarCoeff7};

    fn run_dia<O: StencilOp>(
        op: &O,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &DiamondConfig,
        passes: usize,
    ) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        diamond_passes(&mut pool, op, u, f, h2, cfg, passes)
    }

    fn check(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let f = Grid3::random(nz, ny, nx, 47);
        let mut u = Grid3::random(nz, ny, nx, 48);
        let want = serial_reference(&u, &f, 1.1, t);
        run_dia(&ConstLaplace7, &mut u, &f, 1.1, &DiamondConfig { t, groups, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} G={groups}");
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let f = Grid3::random(nz, ny, nx, 57);
        let mut u = Grid3::random(nz, ny, nx, 58);
        let want = serial_reference_op(&Laplace13, &u, &f, 1.1, t);
        run_dia(&Laplace13, &mut u, &f, 1.1, &DiamondConfig { t, groups, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 {nz}x{ny}x{nx} t={t} G={groups}");
    }

    #[test]
    fn single_interval_matches_serial() {
        // G = 1 degenerates to the unwaited single-group wavefront
        check(10, 9, 8, 2, 1);
        check(10, 9, 8, 4, 1);
        check(8, 7, 9, 6, 1);
    }

    #[test]
    fn two_intervals_match_serial() {
        check(10, 12, 8, 2, 2);
        check(10, 16, 8, 4, 2);
        check(8, 14, 9, 4, 2); // minimum width: 6 interior lines each
        check(8, 22, 9, 6, 2); // t = 6: 10-line intervals
    }

    #[test]
    fn many_intervals_match_serial() {
        check(8, 11, 8, 2, 4);
        check(8, 21, 8, 4, 3); // uneven: 19 interior lines over 3
        check(6, 18, 7, 2, 7);
    }

    #[test]
    fn radius2_intervals_match_serial() {
        check_r2(10, 13, 9, 2, 2); // uneven: 4 + 5 interior lines
        check_r2(10, 16, 9, 2, 2);
        check_r2(11, 28, 9, 4, 2); // minimum width: 12 interior lines each
        check_r2(9, 25, 8, 2, 3);
    }

    #[test]
    fn stateful_and_stateless_ops_match_serial() {
        let op = VarCoeff7::default_for((9, 16, 8));
        let f = Grid3::random(9, 16, 8, 63);
        let mut u = Grid3::random(9, 16, 8, 64);
        let want = serial_reference_op(&op, &u, &f, 0.9, 4);
        run_dia(&op, &mut u, &f, 0.9, &DiamondConfig { t: 4, groups: 2, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        let f = Grid3::random(9, 14, 8, 65);
        let mut u = Grid3::random(9, 14, 8, 66);
        let want = serial_reference_op(&Aniso7, &u, &f, 0.9, 2);
        run_dia(&Aniso7, &mut u, &f, 0.9, &DiamondConfig { t: 2, groups: 3, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn iters_multiple_passes_reuse_one_team() {
        let f = Grid3::random(10, 14, 8, 5);
        let mut u = Grid3::random(10, 14, 8, 6);
        let want = serial_reference(&u, &f, 1.0, 12);
        let cfg = DiamondConfig { t: 2, groups: 3, ..Default::default() };
        check_iters_multiple(12, cfg.t).unwrap();
        let mut pool = WorkerPool::new(5);
        diamond_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &cfg, 6).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // non-multiple is an error at the iters layer
        assert!(check_iters_multiple(7, cfg.t).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let f = Grid3::zeros(8, 8, 8);
        let mut u = Grid3::random(8, 8, 8, 1);
        // odd t
        assert!(run_dia(&ConstLaplace7, &mut u, &f, 1.0, &DiamondConfig { t: 3, groups: 2, ..Default::default() }, 1)
            .is_err());
        // zero intervals
        assert!(run_dia(&ConstLaplace7, &mut u, &f, 1.0, &DiamondConfig { t: 2, groups: 0, ..Default::default() }, 1)
            .is_err());
        // intervals too narrow for the seam diamonds (6 interior lines
        // < 2R(t-1) * 2 = 12): the typed BlockWidthError, same as
        // RunConfig::validate raises
        let err = run_dia(&ConstLaplace7, &mut u, &f, 1.0, &DiamondConfig { t: 4, groups: 2, ..Default::default() }, 1)
            .unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed width error");
        assert_eq!((typed.required, typed.groups), (6, 2));
        assert_eq!(typed.scheme, Scheme::JacobiDiamond);
        // radius-2: 8 interior lines < 4 * 3 groups at t = 2
        let mut v = Grid3::random(8, 12, 8, 2);
        let fv = Grid3::zeros(8, 12, 8);
        assert!(run_dia(&Laplace13, &mut v, &fv, 1.0, &DiamondConfig { t: 2, groups: 3, ..Default::default() }, 1)
            .is_err());
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        run_dia(&ConstLaplace7, &mut u, &f, 1.0, &DiamondConfig { t: 2, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(u, orig);
    }
}
