//! The scheme × op registry: one [`SchemeRunner`] per ([`Scheme`],
//! [`OpKind`]) pair, mapping a [`RunConfig`] to the scheme's schedule
//! construction *and* its performance-model leg.
//!
//! Before this registry existed, `launcher::run_experiment` re-dispatched
//! over `Scheme` in two hand-written `match` blocks and every scheme was
//! welded to the 7-point Laplace kernel. Now the coordinator layer is the
//! single place a scheme lives — implement [`SchemeRunner`] (usually via
//! one generic struct over [`OpFamily`]) and add the instantiations to
//! the registry — and the stencil layer is the single place an operator
//! lives: a new [`OpKind`] plus one registry line per scheme (seven
//! today) light it up in the
//! [`Solver`](super::solver::Solver) session, the launcher and the CLI.
//! Each (scheme, op) entry is a distinct monomorphization, so the
//! [`ConstLaplace7`] column compiles to exactly the pre-refactor code.
//!
//! The prediction legs no longer consult hard-coded Jacobi/GS byte
//! counts: every runner builds a [`KernelProfile`] from its op's
//! [`TrafficSignature`](crate::stencil::op::TrafficSignature), and
//! `JacobiMultiGroup` gets the specialized
//! [`multigroup_prediction`] (boundary-array traffic, round-lag
//! hand-off) instead of reusing the plain wavefront model.

use std::marker::PhantomData;

use crate::config::{RunConfig, Scheme};
use crate::simulator::ecm::{EcmModel, KernelProfile, Prediction};
use crate::simulator::machine::MachineSpec;
use crate::simulator::memory::Dataset;
use crate::simulator::perfmodel::{
    diamond_prediction, multigroup_prediction, wavefront_prediction_for, WavefrontParams,
};
use crate::stencil::grid::Grid3;
use crate::stencil::op::{
    op_gs_sweeps, op_jacobi_steps, op_jacobi_steps_stored, Aniso7, ConstLaplace7, FusedResidual7,
    Laplace13, OpFamily, OpInstance, OpKind, VarCoeff7,
};
use crate::Result;

use super::diamond::{diamond_passes, DiamondConfig};
use super::gs_multigroup::{gs_multigroup_iters_passes, GsMultiGroupConfig};
use super::pipeline::{pipeline_gs_passes, PipelineConfig};
use super::pool::Dispatch;
use super::spatial_mg::{multigroup_passes, MultiGroupConfig};
use super::wavefront::{check_iters_multiple, wavefront_jacobi_passes, SyncMode, WavefrontConfig};
use super::wavefront_gs::{wavefront_gs_iters_passes, GsWavefrontConfig};

/// Everything one (scheme, op) pair needs to participate in a [`Solver`]
/// session and an experiment launch: team sizing, execution on a pool,
/// the serial reference it must match bit-exactly, and the Tab. 1
/// performance-model leg.
///
/// [`Solver`]: super::solver::Solver
pub trait SchemeRunner: Sync {
    /// The scheme this runner implements.
    fn scheme(&self) -> Scheme;

    /// The op this runner is monomorphized over.
    fn op_kind(&self) -> OpKind;

    /// Workers the scheme's schedule dispatches for `cfg` — the team the
    /// [`Solver`](super::solver::Solver) builder pre-spawns so `run()`
    /// never grows the pool.
    fn team_size(&self, cfg: &RunConfig) -> usize;

    /// Updates performed by the scheme's natural pass (the granularity
    /// of [`Solver::step`](super::solver::Solver::step)): `t` fused
    /// updates for the temporally blocked schemes, one sweep for the
    /// baselines.
    fn step_iters(&self, cfg: &RunConfig) -> usize;

    /// Perform `iters` updates of `u` in place on `pool` (scratch comes
    /// from the pool's reusable arena). `op` is the session's op
    /// instance; its kind matches [`SchemeRunner::op_kind`].
    #[allow(clippy::too_many_arguments)]
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()>;

    /// The serial reference result the parallel execution must match
    /// bit-exactly (verified on every launch).
    #[allow(clippy::too_many_arguments)]
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Grid3;

    /// Modeled MLUP/s of `cfg` on a Tab. 1 machine.
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64;
}

/// The op-derived kernel profile of a configuration on a machine.
fn profile_for(machine: &MachineSpec, cfg: &RunConfig) -> KernelProfile {
    KernelProfile::of_op(cfg.op, cfg.scheme.is_gs(), cfg.optimized_kernel, machine.arch)
}

/// The wavefront-family parameters of a configuration.
fn wavefront_params(cfg: &RunConfig) -> WavefrontParams {
    WavefrontParams {
        t: cfg.t,
        groups: cfg.groups,
        smt: cfg.smt,
        kernel: cfg.scheme.kernel(cfg.optimized_kernel),
        store: cfg.store_mode(),
        barrier: cfg.barrier,
    }
}

/// The wavefront-family prediction leg (temporally blocked schemes).
fn predict_wavefront(machine: &MachineSpec, cfg: &RunConfig) -> f64 {
    wavefront_prediction_for(machine, &wavefront_params(cfg), &profile_for(machine, cfg), cfg.size)
        .mlups
}

/// The ECM prediction leg (memory-bound baselines).
fn predict_ecm(machine: &MachineSpec, cfg: &RunConfig) -> f64 {
    let e = EcmModel::new(machine.clone());
    let pred: Prediction = e.socket_profile(
        &profile_for(machine, cfg),
        Dataset::Memory,
        cfg.store_mode(),
        machine.socket_threads(cfg.smt),
        cfg.smt,
    );
    pred.mlups
}

/// Plain (serial) Jacobi-style baseline of one op.
struct JacobiBaselineRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for JacobiBaselineRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiBaseline
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, _cfg: &RunConfig) -> usize {
        0 // runs inline on the dispatching thread
    }
    fn step_iters(&self, _cfg: &RunConfig) -> usize {
        1
    }
    fn execute(
        &self,
        _pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        // every sweep's writes go to the other buffer and are not re-read
        // within the sweep, so the baseline honors nt_stores everywhere
        *u = op_jacobi_steps_stored(O::extract(op), u, f, h2, iters, cfg.store_mode());
        Ok(())
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        f: &Grid3,
        h2: f64,
        _cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        op_jacobi_steps(O::extract(op), u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_ecm(machine, cfg)
    }
}

/// Wavefront temporally-blocked Jacobi-style scheme (Fig. 6).
struct JacobiWavefrontRunner<O>(PhantomData<O>);

fn wf_config(cfg: &RunConfig) -> WavefrontConfig {
    WavefrontConfig {
        threads: cfg.t,
        barrier: cfg.barrier,
        sync: SyncMode::Barrier,
        store: cfg.store_mode(),
    }
}

impl<O: OpFamily> SchemeRunner for JacobiWavefrontRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiWavefront
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let wf = wf_config(cfg);
        wf.validate()?;
        check_iters_multiple(iters, wf.threads)?;
        wavefront_jacobi_passes(pool, O::extract(op), u, f, h2, &wf, iters / wf.threads)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        f: &Grid3,
        h2: f64,
        _cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        op_jacobi_steps(O::extract(op), u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_wavefront(machine, cfg)
    }
}

/// Multi-group spatial × temporal blocked Jacobi-style scheme (Fig. 7 at
/// scale).
struct JacobiMultiGroupRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for JacobiMultiGroupRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiMultiGroup
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        cfg.groups
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let mg = MultiGroupConfig { t: cfg.t, groups: cfg.groups, store: cfg.store_mode() };
        mg.validate()?;
        check_iters_multiple(iters, mg.t)?;
        multigroup_passes(pool, O::extract(op), u, f, h2, &mg, iters / mg.t)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        f: &Grid3,
        h2: f64,
        _cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        op_jacobi_steps(O::extract(op), u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        // the ROADMAP item: model the boundary-array traffic and the
        // round-lag hand-off instead of reusing the wavefront model
        multigroup_prediction(machine, &wavefront_params(cfg), &profile_for(machine, cfg), cfg.size)
            .mlups
    }
}

/// Diamond-tile temporally blocked Jacobi-style scheme
/// (arXiv:1410.3060 on this pool core).
struct JacobiDiamondRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for JacobiDiamondRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiDiamond
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        // one A tile per interval + one B tile per interior seam
        if cfg.groups <= 1 {
            1
        } else {
            2 * cfg.groups - 1
        }
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let dc = DiamondConfig {
            t: cfg.t,
            groups: cfg.groups,
            store: cfg.store_mode(),
            wait_slack: 0,
        };
        dc.validate()?;
        check_iters_multiple(iters, dc.t)?;
        diamond_passes(pool, O::extract(op), u, f, h2, &dc, iters / dc.t)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        f: &Grid3,
        h2: f64,
        _cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        op_jacobi_steps(O::extract(op), u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        // the diamond model leg: no boundary-array stream, same ring
        // amortization — strictly less traffic per LUP than the
        // multi-group decomposition at the same (op, t, groups)
        diamond_prediction(machine, &wavefront_params(cfg), &profile_for(machine, cfg), cfg.size)
            .mlups
    }
}

/// Pipeline-parallel lexicographic Gauss-Seidel baseline (Fig. 5a).
struct GsBaselineRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for GsBaselineRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::GsBaseline
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        if cfg.t <= 1 {
            0 // single-threaded pipeline short-circuits to the serial sweep
        } else {
            cfg.t
        }
    }
    fn step_iters(&self, _cfg: &RunConfig) -> usize {
        1
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let p = PipelineConfig { threads: cfg.t, kernel: cfg.gs_kernel() };
        pipeline_gs_passes(pool, O::extract(op), u, &p, iters)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        let mut r = u0.clone();
        op_gs_sweeps(O::extract(op), &mut r, iters, cfg.gs_kernel());
        r
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_ecm(machine, cfg)
    }
}

/// Wavefront temporally-blocked Gauss-Seidel (Fig. 5b).
struct GsWavefrontRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for GsWavefrontRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::GsWavefront
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        if cfg.t <= 1 && cfg.groups <= 1 {
            0 // short-circuits to the serial sweep
        } else {
            cfg.t * cfg.groups
        }
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let w = GsWavefrontConfig {
            sweeps: cfg.t,
            threads_per_group: cfg.groups,
            kernel: cfg.gs_kernel(),
        };
        wavefront_gs_iters_passes(pool, O::extract(op), u, &w, iters)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        let mut r = u0.clone();
        op_gs_sweeps(O::extract(op), &mut r, iters, cfg.gs_kernel());
        r
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_wavefront(machine, cfg)
    }
}

/// Multi-group spatial × temporal blocked Gauss-Seidel (the Fig. 5b
/// pipeline nested in the Fig. 7 y-block decomposition).
struct GsMultiGroupRunner<O>(PhantomData<O>);

impl<O: OpFamily> SchemeRunner for GsMultiGroupRunner<O> {
    fn scheme(&self) -> Scheme {
        Scheme::GsMultiGroup
    }
    fn op_kind(&self) -> OpKind {
        O::KIND
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        if cfg.t <= 1 && cfg.groups <= 1 {
            0 // short-circuits to the serial sweep
        } else {
            cfg.groups
        }
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut dyn Dispatch,
        op: &OpInstance,
        u: &mut Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let mg = GsMultiGroupConfig { t: cfg.t, groups: cfg.groups, kernel: cfg.gs_kernel() };
        gs_multigroup_iters_passes(pool, O::extract(op), u, &mg, iters)
    }
    fn reference(
        &self,
        op: &OpInstance,
        u0: &Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Grid3 {
        let mut r = u0.clone();
        op_gs_sweeps(O::extract(op), &mut r, iters, cfg.gs_kernel());
        r
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        // the multi-group model with the op's in-place GS signature:
        // half the write traffic of the Jacobi decomposition and
        // (t-1) x R-line boundary arrays per interface
        multigroup_prediction(machine, &wavefront_params(cfg), &profile_for(machine, cfg), cfg.size)
            .mlups
    }
}

/// Every registered (scheme, op) pair. Adding an op = one `OpFamily`
/// impl + one column entry per scheme; adding a scheme = one generic
/// `SchemeRunner` + one `op_column!` row. The launcher and CLI are
/// data-driven over this slice.
macro_rules! op_column {
    ($runner:ident, $c7:ident, $vc:ident, $l13:ident, $f7:ident, $a7:ident) => {
        static $c7: $runner<ConstLaplace7> = $runner(PhantomData);
        static $vc: $runner<VarCoeff7> = $runner(PhantomData);
        static $l13: $runner<Laplace13> = $runner(PhantomData);
        static $f7: $runner<FusedResidual7> = $runner(PhantomData);
        static $a7: $runner<Aniso7> = $runner(PhantomData);
    };
}

op_column!(JacobiBaselineRunner, JB_C7, JB_VC, JB_L13, JB_F7, JB_A7);
op_column!(JacobiWavefrontRunner, JW_C7, JW_VC, JW_L13, JW_F7, JW_A7);
op_column!(JacobiMultiGroupRunner, JM_C7, JM_VC, JM_L13, JM_F7, JM_A7);
op_column!(JacobiDiamondRunner, JD_C7, JD_VC, JD_L13, JD_F7, JD_A7);
op_column!(GsBaselineRunner, GB_C7, GB_VC, GB_L13, GB_F7, GB_A7);
op_column!(GsWavefrontRunner, GW_C7, GW_VC, GW_L13, GW_F7, GW_A7);
op_column!(GsMultiGroupRunner, GM_C7, GM_VC, GM_L13, GM_F7, GM_A7);

static REGISTRY: &[&dyn SchemeRunner] = &[
    &JB_C7, &JB_VC, &JB_L13, &JB_F7, &JB_A7, &JW_C7, &JW_VC, &JW_L13, &JW_F7, &JW_A7, &JM_C7,
    &JM_VC, &JM_L13, &JM_F7, &JM_A7, &JD_C7, &JD_VC, &JD_L13, &JD_F7, &JD_A7, &GB_C7, &GB_VC,
    &GB_L13, &GB_F7, &GB_A7, &GW_C7, &GW_VC, &GW_L13, &GW_F7, &GW_A7, &GM_C7, &GM_VC, &GM_L13,
    &GM_F7, &GM_A7,
];

/// All registered runners (one per scheme × op pair).
pub fn runners() -> impl Iterator<Item = &'static dyn SchemeRunner> {
    REGISTRY.iter().copied()
}

/// The runner registered for `(scheme, op)`.
pub fn runner_for(scheme: Scheme, op: OpKind) -> Result<&'static dyn SchemeRunner> {
    runners()
        .find(|r| r.scheme() == scheme && r.op_kind() == op)
        .ok_or_else(|| {
            anyhow::anyhow!("scheme {scheme:?} × op {op:?} has no registered SchemeRunner")
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::simulator::perfmodel::BarrierKind;

    fn base_cfg(scheme: Scheme, op: OpKind) -> RunConfig {
        // the diamond width rule (interior >= 2R(t-1)*groups) does not
        // admit t = 4 at radius 2 on this 14-line grid; t = 2 fits every
        // registered op and keeps iters = 4 a multiple of t
        let t = if scheme == Scheme::JacobiDiamond { 2 } else { 4 };
        RunConfig {
            scheme,
            op,
            size: (14, 14, 14),
            t,
            groups: 2,
            iters: 4,
            machine: Some("Nehalem EP".into()),
            barrier: BarrierKind::Spin,
            ..Default::default()
        }
    }

    #[test]
    fn every_scheme_times_op_is_registered() {
        for scheme in Scheme::ALL {
            for op in OpKind::ALL {
                let r = runner_for(scheme, op).unwrap();
                assert_eq!(r.scheme(), scheme);
                assert_eq!(r.op_kind(), op);
            }
        }
        assert_eq!(runners().count(), Scheme::ALL.len() * OpKind::ALL.len());
        // 7 schemes x 5 ops, derived from the two ALL lists, never from a
        // hand-maintained count
        assert_eq!(runners().count(), 35);
    }

    #[test]
    fn every_registered_runner_predicts_on_every_testbed_machine() {
        // registry-coverage half of the config/CLI round-trip satellite:
        // all 35 entries resolve and their model leg works everywhere
        for m in MachineSpec::testbed() {
            for scheme in Scheme::ALL {
                for op in OpKind::ALL {
                    let cfg = base_cfg(scheme, op);
                    let p = runner_for(scheme, op).unwrap().predict(&m, &cfg);
                    assert!(p.is_finite() && p > 0.0, "{} {scheme:?} x {op:?}: {p}", m.name);
                }
            }
        }
    }

    #[test]
    fn execute_matches_reference_for_all_runners() {
        let (nz, ny, nx) = (14, 14, 14);
        let f = Grid3::random(nz, ny, nx, 7);
        let u0 = Grid3::random(nz, ny, nx, 8);
        for r in runners() {
            let cfg = base_cfg(r.scheme(), r.op_kind());
            let op = cfg.op.instantiate(cfg.size);
            let mut pool = WorkerPool::new(0);
            let mut u = u0.clone();
            r.execute(&mut pool, &op, &mut u, &f, 1.0, &cfg, cfg.iters).unwrap();
            let want = r.reference(&op, &u0, &f, 1.0, &cfg, cfg.iters);
            assert_eq!(
                u.max_abs_diff(&want),
                0.0,
                "{:?} x {:?}",
                r.scheme(),
                r.op_kind()
            );
            assert!(
                pool.size() <= r.team_size(&cfg),
                "{:?} x {:?} team accounting",
                r.scheme(),
                r.op_kind()
            );
        }
    }

    #[test]
    fn predictions_are_positive_and_finite_on_the_testbed() {
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        for r in runners() {
            let cfg = base_cfg(r.scheme(), r.op_kind());
            let p = r.predict(&m, &cfg);
            assert!(p.is_finite() && p > 0.0, "{:?} x {:?}: {p}", r.scheme(), r.op_kind());
        }
    }

    #[test]
    fn multigroup_prediction_is_specialized() {
        // the multi-group runner no longer returns the plain wavefront
        // number once boundary arrays exist (groups > 1)
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        let cfg = base_cfg(Scheme::JacobiMultiGroup, OpKind::ConstLaplace7);
        let mg = runner_for(Scheme::JacobiMultiGroup, OpKind::ConstLaplace7).unwrap();
        let wf = runner_for(Scheme::JacobiWavefront, OpKind::ConstLaplace7).unwrap();
        assert_ne!(mg.predict(&m, &cfg), wf.predict(&m, &cfg));
        // the GS member gets the same specialization (in-place boundary
        // traffic), not the plain GS wavefront model
        let gs_cfg = base_cfg(Scheme::GsMultiGroup, OpKind::ConstLaplace7);
        let gs_mg = runner_for(Scheme::GsMultiGroup, OpKind::ConstLaplace7).unwrap();
        let gs_wf = runner_for(Scheme::GsWavefront, OpKind::ConstLaplace7).unwrap();
        assert_ne!(gs_mg.predict(&m, &gs_cfg), gs_wf.predict(&m, &gs_cfg));
        // and the in-place signature prices less traffic per LUP than
        // the out-of-place Jacobi decomposition at the same parameters
        assert_ne!(gs_mg.predict(&m, &gs_cfg), mg.predict(&m, &cfg));
    }

    #[test]
    fn diamond_prediction_is_specialized() {
        // the diamond runner gets its own model leg — no boundary-array
        // stream, 2G-1 workers — so it must not alias the plain
        // wavefront number nor the multi-group one at equal parameters
        // (the strict per-LUP traffic ordering vs multigroup is asserted
        // leg-by-leg in perfmodel's own tests)
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        let cfg = base_cfg(Scheme::JacobiDiamond, OpKind::ConstLaplace7);
        let dia = runner_for(Scheme::JacobiDiamond, OpKind::ConstLaplace7).unwrap();
        let wf = runner_for(Scheme::JacobiWavefront, OpKind::ConstLaplace7).unwrap();
        assert_ne!(dia.predict(&m, &cfg), wf.predict(&m, &cfg));
        let mut mg_cfg = base_cfg(Scheme::JacobiMultiGroup, OpKind::ConstLaplace7);
        mg_cfg.t = cfg.t; // base_cfg lowers t for the diamond scheme
        let mg = runner_for(Scheme::JacobiMultiGroup, OpKind::ConstLaplace7).unwrap();
        assert_ne!(dia.predict(&m, &cfg), mg.predict(&m, &mg_cfg));
    }

    #[test]
    fn step_iters_match_the_temporal_blocking() {
        let cfg = base_cfg(Scheme::JacobiWavefront, OpKind::ConstLaplace7);
        let wf = runner_for(Scheme::JacobiWavefront, OpKind::ConstLaplace7).unwrap();
        assert_eq!(wf.step_iters(&cfg), 4);
        let base = runner_for(Scheme::JacobiBaseline, OpKind::ConstLaplace7).unwrap();
        assert_eq!(base.step_iters(&cfg), 1);
    }

    #[test]
    fn unknown_pairs_error_cleanly() {
        // every pair is currently registered, so exercise the error path
        // by exhausting the registry lookup contract instead: a runner's
        // execute with a mismatched instance panics with a clear message
        let wf = runner_for(Scheme::JacobiWavefront, OpKind::Laplace13).unwrap();
        let cfg = base_cfg(Scheme::JacobiWavefront, OpKind::Laplace13);
        let wrong = OpKind::ConstLaplace7.instantiate(cfg.size);
        let mut pool = WorkerPool::new(0);
        let mut u = Grid3::random(14, 14, 14, 1);
        let f = Grid3::zeros(14, 14, 14);
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = wf.execute(&mut pool, &wrong, &mut u, &f, 1.0, &cfg, 4);
        }));
        assert!(panicked.is_err());
    }
}
