//! The scheme registry: one [`SchemeRunner`] per [`Scheme`], mapping a
//! [`RunConfig`] to the scheme's schedule construction *and* its
//! performance-model leg.
//!
//! Before this registry existed, `launcher::run_experiment` re-dispatched
//! over `Scheme` in two hand-written `match` blocks (execution and
//! prediction), every scheme exported a four-way free-function matrix,
//! and adding a scheme touched five layers. Now the coordinator layer is
//! the single place a scheme lives: implement [`SchemeRunner`], add the
//! unit struct to the registry, and the [`Solver`](super::solver::Solver)
//! session, the launcher and the CLI pick it up unchanged — the shape the
//! follow-up schemes (shared-cache group blocking, arXiv:1006.3148;
//! wavefront diamond tiling, arXiv:1410.3060) slot into.

use crate::config::{RunConfig, Scheme};
use crate::simulator::ecm::{EcmModel, Prediction};
use crate::simulator::machine::MachineSpec;
use crate::simulator::memory::Dataset;
use crate::simulator::perfmodel::{wavefront_prediction, WavefrontParams};
use crate::stencil::gauss_seidel::gs_sweeps;
use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::jacobi_steps;
use crate::Result;

use super::pipeline::{pipeline_gs_passes, PipelineConfig};
use super::pool::WorkerPool;
use super::spatial_mg::{multigroup_passes, MultiGroupConfig};
use super::wavefront::{check_iters_multiple, wavefront_jacobi_passes, SyncMode, WavefrontConfig};
use super::wavefront_gs::{wavefront_gs_iters_passes, GsWavefrontConfig};

/// Everything one scheme needs to participate in a [`Solver`] session
/// and an experiment launch: team sizing, execution on a pool, the
/// serial reference it must match bit-exactly, and the Tab. 1
/// performance-model leg.
///
/// [`Solver`]: super::solver::Solver
pub trait SchemeRunner: Sync {
    /// The scheme this runner implements.
    fn scheme(&self) -> Scheme;

    /// Workers the scheme's schedule dispatches for `cfg` — the team the
    /// [`Solver`](super::solver::Solver) builder pre-spawns so `run()`
    /// never grows the pool.
    fn team_size(&self, cfg: &RunConfig) -> usize;

    /// Updates performed by the scheme's natural pass (the granularity
    /// of [`Solver::step`](super::solver::Solver::step)): `t` fused
    /// updates for the temporally blocked schemes, one sweep for the
    /// baselines.
    fn step_iters(&self, cfg: &RunConfig) -> usize;

    /// Perform `iters` updates of `u` in place on `pool` (scratch comes
    /// from the pool's reusable arena).
    fn execute(
        &self,
        pool: &mut WorkerPool,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()>;

    /// The serial reference result the parallel execution must match
    /// bit-exactly (verified on every launch).
    fn reference(&self, u0: &Grid3, f: &Grid3, h2: f64, cfg: &RunConfig, iters: usize) -> Grid3;

    /// Modeled MLUP/s of `cfg` on a Tab. 1 machine.
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64;
}

/// The wavefront-family prediction leg (temporally blocked schemes).
fn predict_wavefront(machine: &MachineSpec, cfg: &RunConfig) -> f64 {
    let params = WavefrontParams {
        t: cfg.t,
        groups: cfg.groups,
        smt: cfg.smt,
        kernel: cfg.scheme.kernel(cfg.optimized_kernel),
        store: cfg.store_mode(),
        barrier: cfg.barrier,
    };
    wavefront_prediction(machine, &params, cfg.size).mlups
}

/// The ECM prediction leg (memory-bound baselines).
fn predict_ecm(machine: &MachineSpec, cfg: &RunConfig) -> f64 {
    let e = EcmModel::new(machine.clone());
    let pred: Prediction = e.socket(
        cfg.scheme.kernel(cfg.optimized_kernel),
        Dataset::Memory,
        cfg.store_mode(),
        machine.socket_threads(cfg.smt),
        cfg.smt,
    );
    pred.mlups
}

/// Plain (serial) Jacobi baseline.
struct JacobiBaselineRunner;

impl SchemeRunner for JacobiBaselineRunner {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiBaseline
    }
    fn team_size(&self, _cfg: &RunConfig) -> usize {
        0 // runs inline on the dispatching thread
    }
    fn step_iters(&self, _cfg: &RunConfig) -> usize {
        1
    }
    fn execute(
        &self,
        _pool: &mut WorkerPool,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        _cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        *u = jacobi_steps(u, f, h2, iters);
        Ok(())
    }
    fn reference(&self, u0: &Grid3, f: &Grid3, h2: f64, _cfg: &RunConfig, iters: usize) -> Grid3 {
        jacobi_steps(u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_ecm(machine, cfg)
    }
}

/// Wavefront temporally-blocked Jacobi (Fig. 6).
struct JacobiWavefrontRunner;

impl JacobiWavefrontRunner {
    fn wf_config(cfg: &RunConfig) -> WavefrontConfig {
        WavefrontConfig { threads: cfg.t, barrier: cfg.barrier, sync: SyncMode::Barrier }
    }
}

impl SchemeRunner for JacobiWavefrontRunner {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiWavefront
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut WorkerPool,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let wf = Self::wf_config(cfg);
        wf.validate()?;
        check_iters_multiple(iters, wf.threads)?;
        wavefront_jacobi_passes(pool, u, f, h2, &wf, iters / wf.threads)
    }
    fn reference(&self, u0: &Grid3, f: &Grid3, h2: f64, _cfg: &RunConfig, iters: usize) -> Grid3 {
        jacobi_steps(u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_wavefront(machine, cfg)
    }
}

/// Multi-group spatial × temporal blocked Jacobi (Fig. 7 at scale).
struct JacobiMultiGroupRunner;

impl SchemeRunner for JacobiMultiGroupRunner {
    fn scheme(&self) -> Scheme {
        Scheme::JacobiMultiGroup
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        cfg.groups
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut WorkerPool,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let mg = MultiGroupConfig { t: cfg.t, groups: cfg.groups };
        mg.validate()?;
        check_iters_multiple(iters, mg.t)?;
        multigroup_passes(pool, u, f, h2, &mg, iters / mg.t)
    }
    fn reference(&self, u0: &Grid3, f: &Grid3, h2: f64, _cfg: &RunConfig, iters: usize) -> Grid3 {
        jacobi_steps(u0, f, h2, iters)
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_wavefront(machine, cfg)
    }
}

/// Pipeline-parallel lexicographic Gauss-Seidel baseline (Fig. 5a).
struct GsBaselineRunner;

impl SchemeRunner for GsBaselineRunner {
    fn scheme(&self) -> Scheme {
        Scheme::GsBaseline
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        if cfg.t <= 1 {
            0 // single-threaded pipeline short-circuits to the serial sweep
        } else {
            cfg.t
        }
    }
    fn step_iters(&self, _cfg: &RunConfig) -> usize {
        1
    }
    fn execute(
        &self,
        pool: &mut WorkerPool,
        u: &mut Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let p = PipelineConfig { threads: cfg.t, kernel: cfg.gs_kernel() };
        pipeline_gs_passes(pool, u, &p, iters)
    }
    fn reference(&self, u0: &Grid3, _f: &Grid3, _h2: f64, cfg: &RunConfig, iters: usize) -> Grid3 {
        let mut r = u0.clone();
        gs_sweeps(&mut r, iters, cfg.gs_kernel());
        r
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_ecm(machine, cfg)
    }
}

/// Wavefront temporally-blocked Gauss-Seidel (Fig. 5b).
struct GsWavefrontRunner;

impl SchemeRunner for GsWavefrontRunner {
    fn scheme(&self) -> Scheme {
        Scheme::GsWavefront
    }
    fn team_size(&self, cfg: &RunConfig) -> usize {
        if cfg.t <= 1 && cfg.groups <= 1 {
            0 // short-circuits to the serial sweep
        } else {
            cfg.t * cfg.groups
        }
    }
    fn step_iters(&self, cfg: &RunConfig) -> usize {
        cfg.t
    }
    fn execute(
        &self,
        pool: &mut WorkerPool,
        u: &mut Grid3,
        _f: &Grid3,
        _h2: f64,
        cfg: &RunConfig,
        iters: usize,
    ) -> Result<()> {
        let w = GsWavefrontConfig {
            sweeps: cfg.t,
            threads_per_group: cfg.groups,
            kernel: cfg.gs_kernel(),
        };
        wavefront_gs_iters_passes(pool, u, &w, iters)
    }
    fn reference(&self, u0: &Grid3, _f: &Grid3, _h2: f64, cfg: &RunConfig, iters: usize) -> Grid3 {
        let mut r = u0.clone();
        gs_sweeps(&mut r, iters, cfg.gs_kernel());
        r
    }
    fn predict(&self, machine: &MachineSpec, cfg: &RunConfig) -> f64 {
        predict_wavefront(machine, cfg)
    }
}

/// Every registered scheme. Adding a scheme = implementing
/// [`SchemeRunner`] + one entry here; the launcher and CLI are
/// data-driven over this slice.
static REGISTRY: &[&(dyn SchemeRunner)] = &[
    &JacobiBaselineRunner,
    &JacobiWavefrontRunner,
    &JacobiMultiGroupRunner,
    &GsBaselineRunner,
    &GsWavefrontRunner,
];

/// All registered runners.
pub fn runners() -> &'static [&'static dyn SchemeRunner] {
    REGISTRY
}

/// The runner registered for `scheme`.
pub fn runner_for(scheme: Scheme) -> Result<&'static dyn SchemeRunner> {
    REGISTRY
        .iter()
        .copied()
        .find(|r| r.scheme() == scheme)
        .ok_or_else(|| anyhow::anyhow!("scheme {scheme:?} has no registered SchemeRunner"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::perfmodel::BarrierKind;

    fn base_cfg(scheme: Scheme) -> RunConfig {
        RunConfig {
            scheme,
            size: (12, 12, 12),
            t: 4,
            groups: 2,
            iters: 4,
            machine: Some("Nehalem EP".into()),
            barrier: BarrierKind::Spin,
            ..Default::default()
        }
    }

    #[test]
    fn every_scheme_is_registered() {
        for scheme in [
            Scheme::JacobiBaseline,
            Scheme::JacobiWavefront,
            Scheme::JacobiMultiGroup,
            Scheme::GsBaseline,
            Scheme::GsWavefront,
        ] {
            let r = runner_for(scheme).unwrap();
            assert_eq!(r.scheme(), scheme);
        }
        assert_eq!(runners().len(), 5);
    }

    #[test]
    fn execute_matches_reference_for_all_runners() {
        let (nz, ny, nx) = (12, 12, 12);
        let f = Grid3::random(nz, ny, nx, 7);
        let u0 = Grid3::random(nz, ny, nx, 8);
        for r in runners() {
            let cfg = base_cfg(r.scheme());
            let mut pool = WorkerPool::new(0);
            let mut u = u0.clone();
            r.execute(&mut pool, &mut u, &f, 1.0, &cfg, cfg.iters).unwrap();
            let want = r.reference(&u0, &f, 1.0, &cfg, cfg.iters);
            assert_eq!(u.max_abs_diff(&want), 0.0, "{:?}", r.scheme());
            assert!(pool.size() <= r.team_size(&cfg), "{:?} team accounting", r.scheme());
        }
    }

    #[test]
    fn predictions_are_positive_on_the_testbed() {
        let m = MachineSpec::by_name("Nehalem EP").unwrap();
        for r in runners() {
            let cfg = base_cfg(r.scheme());
            assert!(r.predict(&m, &cfg) > 0.0, "{:?}", r.scheme());
        }
    }

    #[test]
    fn step_iters_match_the_temporal_blocking() {
        let cfg = base_cfg(Scheme::JacobiWavefront);
        assert_eq!(runner_for(Scheme::JacobiWavefront).unwrap().step_iters(&cfg), 4);
        assert_eq!(runner_for(Scheme::JacobiBaseline).unwrap().step_iters(&cfg), 1);
    }
}
