//! Temporal wavefront blocking for Jacobi (paper Sec. 4, Fig. 6).
//!
//! A *thread group* of `t` workers performs `t` time-shifted sweeps over
//! the grid. Worker `s` (0-based) executes update step `s+1`, trailing
//! worker `s-1` by two planes so its three-plane read window only touches
//! completed planes. Odd-numbered updates are written to a small
//! round-robin temporary buffer; even-numbered updates go back to the
//! `src` array — so after the group passes, `src` holds the `t`-times
//! updated grid *in place*, without the second full grid of the
//! out-of-place Jacobi (the paper's "the second grid ... is not required").
//!
//! The temporary buffer holds 4 z-x planes per odd update level
//! (`2t` planes total for the paper's `t = 4` example, matching "for our
//! example eight"): producer step `2u+1` writes plane `k` to slot
//! `k mod 4` of region `u`, consumer step `2u+2` trails by exactly two
//! planes and reads slots `k-1 … k+1` — four live slots.
//!
//! The pass is expressed as a [`Schedule`] and dispatched on the
//! persistent [`WorkerPool`]: `wavefront_jacobi_iters` builds the
//! schedule once and reuses one thread team (and one temporary ring)
//! across all passes instead of respawning per pass.
//!
//! ## Safety argument (also enforced by the progress protocol)
//!
//! * worker `s` updates plane `k` only once `progress[s-1] >= k+1`
//!   (its entire read window holds step-`s` values);
//! * worker `s` never runs more than `TMP_SLOTS - 1` planes ahead of
//!   worker `s+1` (back-pressure), so no live temporary slot is reused;
//! * `src` writes by worker `s` land strictly behind every plane worker
//!   `s-2`'s window can still read (distance >= 4).
//!
//! Boundary planes (`k = 0`, `k = nz-1`) are never updated at any step,
//! so every step's "value" of a boundary plane is the original `src`
//! plane — window reads are redirected there instead of the temporary.
//!
//! Numerics are bit-identical to `t` serial [`jacobi_sweep`]s: same
//! kernel, same fp order — tests assert exact equality.

use std::marker::PhantomData;

use crate::simulator::perfmodel::BarrierKind;
use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::{jacobi_line_update, jacobi_sweep};
use crate::Result;

use super::barrier::AnyBarrier;
use super::pool::{self, WorkerPool};
use super::schedule::{Progress, Schedule};

/// Temporary-buffer slots per odd update level (see module docs).
const TMP_SLOTS: usize = 4;

/// How workers of a group synchronize plane hand-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// Global barrier after every plane round (the paper's scheme).
    #[default]
    Barrier,
    /// Point-to-point progress flags (producer/consumer flow control) —
    /// the "highly efficient synchronization" refinement: workers only
    /// wait for the neighbors they actually depend on.
    Flow,
}

/// Configuration of one wavefront thread group.
#[derive(Clone, Copy, Debug)]
pub struct WavefrontConfig {
    /// Workers in the group = temporal blocking factor `t` (even, >= 2).
    pub threads: usize,
    pub barrier: BarrierKind,
    pub sync: SyncMode,
}

impl Default for WavefrontConfig {
    fn default() -> Self {
        Self { threads: 4, barrier: BarrierKind::Spin, sync: SyncMode::Barrier }
    }
}

impl WavefrontConfig {
    /// Validate the configuration (single source for every entry point).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.threads >= 2 && self.threads % 2 == 0,
            "wavefront needs an even thread count >= 2, got {}",
            self.threads
        );
        Ok(())
    }
}

/// One wavefront pass (`t` fused updates) as a [`Schedule`].
///
/// Borrows the grids for `'g`; reusable across passes — the temporary
/// ring is fully rewritten before it is re-read within each pass.
pub struct WavefrontJacobiSchedule<'g> {
    src: *mut f64,
    tmp: *mut f64,
    f: *const f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    h2: f64,
    sync: SyncMode,
    barrier: AnyBarrier,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: workers index the shared grid and ring disjointly per the
// progress protocol (module docs); all shared access is through raw
// pointers whose aliasing discipline the schedule itself enforces.
unsafe impl Send for WavefrontJacobiSchedule<'_> {}
unsafe impl Sync for WavefrontJacobiSchedule<'_> {}

impl<'g> WavefrontJacobiSchedule<'g> {
    /// Build a pass over `u`. `tmp` is the caller-owned temporary ring;
    /// it is resized here and must stay alive (and untouched) for as
    /// long as the schedule runs.
    pub fn new(
        u: &'g mut Grid3,
        f: &'g Grid3,
        tmp: &'g mut Vec<f64>,
        h2: f64,
        cfg: &WavefrontConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.threads;
        anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(nz >= 3 && ny >= 3 && nx >= 3, "grid too small for a wavefront pass");
        let plane = ny * nx;
        tmp.clear();
        tmp.resize((t / 2) * TMP_SLOTS * plane, 0.0);
        Ok(Self {
            src: u.data_mut().as_mut_ptr(),
            tmp: tmp.as_mut_ptr(),
            f: f.data().as_ptr(),
            nz,
            ny,
            nx,
            t,
            h2,
            sync: cfg.sync,
            barrier: AnyBarrier::new(cfg.barrier, t),
            last_round: (nz - 2) as isize + 2 * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl Schedule for WavefrontJacobiSchedule<'_> {
    fn workers(&self) -> usize {
        self.t
    }

    fn worker(&self, s: usize, progress: &Progress) {
        let (nz, ny, nx, t) = (self.nz, self.ny, self.nx, self.t);
        let plane = ny * nx;
        let src = self.src;
        let tmpp = self.tmp;
        let f_base = self.f;
        // plane base pointer holding the step-`s` values of plane kk as
        // seen by worker `s` (its read side).
        let read_plane = |kk: usize| -> *const f64 {
            if kk == 0 || kk == nz - 1 || s % 2 == 0 {
                unsafe { src.add(kk * plane) as *const f64 }
            } else {
                let region = (s / 2) * TMP_SLOTS;
                unsafe { tmpp.add((region + kk % TMP_SLOTS) * plane) as *const f64 }
            }
        };
        let write_plane = |k: usize| -> *mut f64 {
            if s % 2 == 0 {
                let region = (s / 2) * TMP_SLOTS;
                unsafe { tmpp.add((region + k % TMP_SLOTS) * plane) }
            } else {
                unsafe { src.add(k * plane) }
            }
        };

        for r in 1..=self.last_round {
            let k = r - 2 * s as isize;
            if k >= 1 && k <= (nz - 2) as isize {
                let k = k as usize;
                if self.sync == SyncMode::Flow {
                    // forward dependency: window complete at step s.
                    // Plane nz-1 is boundary and never processed, so at
                    // k = nz-2 the window is complete once the producer
                    // finished its own last interior plane.
                    if s > 0 {
                        let need = (k as isize + 1).min((nz - 2) as isize);
                        progress.wait_min(s - 1, need);
                    }
                    // back-pressure: do not overwrite a tmp slot the
                    // consumer may still read
                    if s + 1 < t {
                        progress.wait_min(s + 1, k as isize - (TMP_SLOTS as isize - 1));
                    }
                }
                // SAFETY: the schedule guarantees exclusive write access
                // to plane k of the write side and that every read plane
                // holds completed step values (see module docs); lines
                // below are disjoint slices.
                unsafe {
                    let zm = read_plane(k - 1);
                    let zc = read_plane(k);
                    let zp = read_plane(k + 1);
                    let out = write_plane(k);
                    // boundary lines of the output plane must carry the
                    // (step-invariant) boundary values so later steps
                    // read correct y-edges from the tmp.
                    if s % 2 == 0 {
                        let src_line0 = src.add(k * plane) as *const f64;
                        std::ptr::copy_nonoverlapping(src_line0, out, nx);
                        std::ptr::copy_nonoverlapping(
                            src_line0.add((ny - 1) * nx),
                            out.add((ny - 1) * nx),
                            nx,
                        );
                        // x-edge columns are copied per line below.
                    }
                    for j in 1..ny - 1 {
                        let dst = std::slice::from_raw_parts_mut(out.add(j * nx), nx);
                        let center = std::slice::from_raw_parts(zc.add(j * nx), nx);
                        if s % 2 == 0 {
                            // carry the Dirichlet x-edges into tmp
                            dst[0] = center[0];
                            dst[nx - 1] = center[nx - 1];
                        }
                        jacobi_line_update(
                            dst,
                            center,
                            std::slice::from_raw_parts(zc.add((j - 1) * nx), nx),
                            std::slice::from_raw_parts(zc.add((j + 1) * nx), nx),
                            std::slice::from_raw_parts(zm.add(j * nx), nx),
                            std::slice::from_raw_parts(zp.add(j * nx), nx),
                            std::slice::from_raw_parts(f_base.add((k * ny + j) * nx), nx),
                            self.h2,
                        );
                    }
                }
                progress.publish(s, k as isize);
            }
            if self.sync == SyncMode::Barrier {
                self.barrier.wait(s);
            }
        }
    }
}

/// Run `passes` wavefront passes on `pool`, one team, one temporary ring
/// (the ring lives in the pool's reusable [`Scratch`](super::pool::Scratch),
/// so repeated calls reuse one allocation).
pub(crate) fn wavefront_jacobi_passes(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 || passes == 0 {
        return Ok(());
    }
    let mut scratch = pool.take_scratch();
    let result = (|| -> Result<()> {
        let schedule = WavefrontJacobiSchedule::new(u, f, &mut scratch.planes, h2, cfg)?;
        for _ in 0..passes {
            pool.run(&schedule)?;
        }
        Ok(())
    })();
    pool.restore_scratch(scratch);
    result
}

/// Check the iteration count divides into whole passes.
pub(crate) fn check_iters_multiple(iters: usize, t: usize) -> Result<()> {
    anyhow::ensure!(
        iters % t == 0,
        "iters ({iters}) must be a multiple of the blocking factor ({t})"
    );
    Ok(())
}

/// Perform exactly `cfg.threads` Jacobi updates on `u` in place.
///
/// Functionally equal to `cfg.threads` calls of [`jacobi_sweep`] with
/// ping-pong buffers, but executed by one wavefront thread group on the
/// calling thread's convenience pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_jacobi(u: &mut Grid3, f: &Grid3, h2: f64, cfg: &WavefrontConfig) -> Result<()> {
    pool::with_local(|p| wavefront_jacobi_passes(p, u, f, h2, cfg, 1))
}

/// [`wavefront_jacobi`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_jacobi_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
) -> Result<()> {
    wavefront_jacobi_passes(pool, u, f, h2, cfg, 1)
}

/// Run `iters` updates (a multiple of `cfg.threads`) via repeated passes
/// of one persistent team (no per-pass thread respawn).
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_jacobi_iters(
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    check_iters_multiple(iters, cfg.threads)?;
    pool::with_local(|p| wavefront_jacobi_passes(p, u, f, h2, cfg, iters / cfg.threads))
}

/// [`wavefront_jacobi_iters`] on a caller-owned pool.
#[deprecated(since = "0.2.0", note = "use a `coordinator::solver::Solver` session")]
pub fn wavefront_jacobi_iters_on(
    pool: &mut WorkerPool,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    check_iters_multiple(iters, cfg.threads)?;
    wavefront_jacobi_passes(pool, u, f, h2, cfg, iters / cfg.threads)
}

/// Reference: `n` serial Jacobi sweeps, returning the result.
pub fn serial_reference(u: &Grid3, f: &Grid3, h2: f64, n: usize) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        jacobi_sweep(&mut b, &a, f, h2);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim matrix stays covered until removal

    use super::*;

    fn check(nz: usize, ny: usize, nx: usize, t: usize, sync: SyncMode, barrier: BarrierKind) {
        let f = Grid3::random(nz, ny, nx, 77);
        let mut u = Grid3::random(nz, ny, nx, 42);
        let want = serial_reference(&u, &f, 0.8, t);
        let cfg = WavefrontConfig { threads: t, barrier, sync };
        wavefront_jacobi(&mut u, &f, 0.8, &cfg).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "bit-exactness {nz}x{ny}x{nx} t={t} {sync:?} {barrier:?}"
        );
    }

    #[test]
    fn bit_identical_to_serial_t2() {
        check(12, 9, 11, 2, SyncMode::Barrier, BarrierKind::Spin);
        check(12, 9, 11, 2, SyncMode::Flow, BarrierKind::Spin);
    }

    #[test]
    fn bit_identical_to_serial_t4() {
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Flow, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn bit_identical_to_serial_t6_t8() {
        check(20, 8, 9, 6, SyncMode::Barrier, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Flow, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn small_grids_where_wavefronts_overlap_fully() {
        // nz-2 < 2t: every worker is inside the pipeline fill/drain region.
        check(5, 6, 6, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(4, 5, 5, 6, SyncMode::Flow, BarrierKind::Spin);
        check(3, 4, 4, 2, SyncMode::Barrier, BarrierKind::Spin);
    }

    #[test]
    fn odd_thread_count_rejected() {
        let mut u = Grid3::random(8, 8, 8, 1);
        let f = Grid3::zeros(8, 8, 8);
        let cfg = WavefrontConfig { threads: 3, ..Default::default() };
        assert!(wavefront_jacobi(&mut u, &f, 1.0, &cfg).is_err());
    }

    #[test]
    fn iters_multiple_passes() {
        let f = Grid3::random(10, 8, 8, 5);
        let mut u = Grid3::random(10, 8, 8, 6);
        let want = serial_reference(&u, &f, 1.0, 8);
        let cfg = WavefrontConfig { threads: 4, ..Default::default() };
        wavefront_jacobi_iters(&mut u, &f, 1.0, &cfg, 8).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // non-multiple is an error
        let mut v = Grid3::random(10, 8, 8, 6);
        assert!(wavefront_jacobi_iters(&mut v, &f, 1.0, &cfg, 6).is_err());
    }

    #[test]
    fn many_passes_on_one_private_pool() {
        let f = Grid3::random(11, 9, 8, 15);
        let mut u = Grid3::random(11, 9, 8, 16);
        let want = serial_reference(&u, &f, 0.5, 24);
        let cfg = WavefrontConfig { threads: 4, sync: SyncMode::Flow, ..Default::default() };
        let mut pool = WorkerPool::new(4);
        wavefront_jacobi_iters_on(&mut pool, &mut u, &f, 0.5, &cfg, 24).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        wavefront_jacobi(&mut u, &f, 1.0, &WavefrontConfig::default()).unwrap();
        assert_eq!(u, orig);
    }
}
