//! Temporal wavefront blocking for Jacobi (paper Sec. 4, Fig. 6).
//!
//! A *thread group* of `t` threads performs `t` time-shifted sweeps over
//! the grid. Thread `s` (0-based) executes update step `s+1`, trailing
//! thread `s-1` by two planes so its three-plane read window only touches
//! completed planes. Odd-numbered updates are written to a small
//! round-robin temporary buffer; even-numbered updates go back to the
//! `src` array — so after the group passes, `src` holds the `t`-times
//! updated grid *in place*, without the second full grid of the
//! out-of-place Jacobi (the paper's "the second grid ... is not required").
//!
//! The temporary buffer holds 4 z-x planes per odd update level
//! (`2t` planes total for the paper's `t = 4` example, matching "for our
//! example eight"): producer step `2u+1` writes plane `k` to slot
//! `k mod 4` of region `u`, consumer step `2u+2` trails by exactly two
//! planes and reads slots `k-1 … k+1` — four live slots.
//!
//! ## Safety argument (also enforced by the progress protocol)
//!
//! * thread `s` updates plane `k` only once `progress[s-1] ≥ k+1`
//!   (its entire read window holds step-`s` values);
//! * thread `s` never runs more than `TMP_SLOTS - 1` planes ahead of
//!   thread `s+1` (back-pressure), so no live temporary slot is reused;
//! * `src` writes by thread `s` land strictly behind every plane thread
//!   `s-2`'s window can still read (distance ≥ 4).
//!
//! Boundary planes (`k = 0`, `k = nz-1`) are never updated at any step,
//! so every step's "value" of a boundary plane is the original `src`
//! plane — window reads are redirected there instead of the temporary.
//!
//! Numerics are bit-identical to `t` serial [`jacobi_sweep`]s: same
//! kernel, same fp order — tests assert exact equality.

use std::sync::atomic::{AtomicIsize, Ordering};

use crate::simulator::perfmodel::BarrierKind;
use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::{jacobi_line_update, jacobi_sweep};
use crate::Result;

use super::barrier::AnyBarrier;

/// Temporary-buffer slots per odd update level (see module docs).
const TMP_SLOTS: usize = 4;

/// How threads of a group synchronize plane hand-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// Global barrier after every plane round (the paper's scheme).
    #[default]
    Barrier,
    /// Point-to-point progress flags (producer/consumer flow control) —
    /// the "highly efficient synchronization" refinement: threads only
    /// wait for the neighbors they actually depend on.
    Flow,
}

/// Configuration of one wavefront thread group.
#[derive(Clone, Copy, Debug)]
pub struct WavefrontConfig {
    /// Threads in the group = temporal blocking factor `t` (even, ≥ 2).
    pub threads: usize,
    pub barrier: BarrierKind,
    pub sync: SyncMode,
}

impl Default for WavefrontConfig {
    fn default() -> Self {
        Self { threads: 4, barrier: BarrierKind::Spin, sync: SyncMode::Barrier }
    }
}

/// Raw shared-grid pointer that the scoped threads index disjointly.
#[derive(Clone, Copy)]
struct SharedPtr(*mut f64);
unsafe impl Send for SharedPtr {}
unsafe impl Sync for SharedPtr {}

impl SharedPtr {
    /// Accessor (method, not field) so closures capture the whole wrapper
    /// — RFC 2229 disjoint capture would otherwise capture the bare
    /// pointer, which is not `Send`.
    #[inline(always)]
    fn get(self) -> *mut f64 {
        self.0
    }
}

/// Perform exactly `cfg.threads` Jacobi updates on `u` in place.
///
/// Functionally equal to `cfg.threads` calls of [`jacobi_sweep`] with
/// ping-pong buffers, but executed by one wavefront thread group.
pub fn wavefront_jacobi(u: &mut Grid3, f: &Grid3, h2: f64, cfg: &WavefrontConfig) -> Result<()> {
    let t = cfg.threads;
    anyhow::ensure!(t >= 2 && t % 2 == 0, "wavefront needs an even thread count >= 2, got {t}");
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let (nz, ny, nx) = u.shape();
    if nz < 3 || ny < 3 || nx < 3 {
        return Ok(());
    }

    let plane = ny * nx;
    let mut tmp = vec![0.0f64; (t / 2) * TMP_SLOTS * plane];
    let src_ptr = SharedPtr(u.data_mut().as_mut_ptr());
    let tmp_ptr = SharedPtr(tmp.as_mut_ptr());
    let f_ptr = f.data().as_ptr() as usize;

    let barrier = AnyBarrier::new(cfg.barrier, t);
    let progress: Vec<AtomicIsize> = (0..t).map(|_| AtomicIsize::new(0)).collect();
    let last_round = (nz - 2) as isize + 2 * (t as isize - 1);

    std::thread::scope(|scope| {
        for s in 0..t {
            let barrier = &barrier;
            let progress = &progress;
            let src = src_ptr;
            let tmpp = tmp_ptr;
            scope.spawn(move || {
                let f_base = f_ptr as *const f64;
                // plane base pointer holding the step-`s` values of plane kk
                // as seen by thread `s` (its read side).
                let read_plane = |kk: usize| -> *const f64 {
                    if kk == 0 || kk == nz - 1 || s % 2 == 0 {
                        unsafe { src.get().add(kk * plane) as *const f64 }
                    } else {
                        let region = (s / 2) * TMP_SLOTS;
                        unsafe { tmpp.get().add((region + kk % TMP_SLOTS) * plane) as *const f64 }
                    }
                };
                let write_plane = |k: usize| -> *mut f64 {
                    if s % 2 == 0 {
                        let region = (s / 2) * TMP_SLOTS;
                        unsafe { tmpp.get().add((region + k % TMP_SLOTS) * plane) }
                    } else {
                        unsafe { src.get().add(k * plane) }
                    }
                };

                for r in 1..=last_round {
                    let k = r - 2 * s as isize;
                    if k >= 1 && k <= (nz - 2) as isize {
                        let k = k as usize;
                        if cfg.sync == SyncMode::Flow {
                            // forward dependency: window complete at step s.
                            // Plane nz-1 is boundary and never processed, so
                            // at k = nz-2 the window is complete once the
                            // producer finished its own last interior plane.
                            if s > 0 {
                                let need = (k as isize + 1).min((nz - 2) as isize);
                                super::barrier::spin_wait(|| {
                                    progress[s - 1].load(Ordering::Acquire) >= need
                                });
                            }
                            // back-pressure: do not overwrite a tmp slot the
                            // consumer may still read
                            if s + 1 < t {
                                super::barrier::spin_wait(|| {
                                    progress[s + 1].load(Ordering::Acquire)
                                        >= k as isize - (TMP_SLOTS as isize - 1)
                                });
                            }
                        }
                        // SAFETY: the schedule guarantees exclusive write
                        // access to plane k of the write side and that every
                        // read plane holds completed step values (see module
                        // docs); lines below are disjoint slices.
                        unsafe {
                            let zm = read_plane(k - 1);
                            let zc = read_plane(k);
                            let zp = read_plane(k + 1);
                            let out = write_plane(k);
                            // boundary lines of the output plane must carry
                            // the (step-invariant) boundary values so later
                            // steps read correct y-edges from the tmp.
                            if s % 2 == 0 {
                                let src_line0 = src.get().add(k * plane) as *const f64;
                                std::ptr::copy_nonoverlapping(src_line0, out, nx);
                                std::ptr::copy_nonoverlapping(
                                    src_line0.add((ny - 1) * nx),
                                    out.add((ny - 1) * nx),
                                    nx,
                                );
                                // x-edge columns are copied per line below.
                            }
                            for j in 1..ny - 1 {
                                let dst = std::slice::from_raw_parts_mut(out.add(j * nx), nx);
                                let center = std::slice::from_raw_parts(zc.add(j * nx), nx);
                                if s % 2 == 0 {
                                    // carry the Dirichlet x-edges into tmp
                                    dst[0] = center[0];
                                    dst[nx - 1] = center[nx - 1];
                                }
                                jacobi_line_update(
                                    dst,
                                    center,
                                    std::slice::from_raw_parts(zc.add((j - 1) * nx), nx),
                                    std::slice::from_raw_parts(zc.add((j + 1) * nx), nx),
                                    std::slice::from_raw_parts(zm.add(j * nx), nx),
                                    std::slice::from_raw_parts(zp.add(j * nx), nx),
                                    std::slice::from_raw_parts(f_base.add((k * ny + j) * nx), nx),
                                    h2,
                                );
                            }
                        }
                        progress[s].store(k as isize, Ordering::Release);
                    }
                    if cfg.sync == SyncMode::Barrier {
                        barrier.wait(s);
                    }
                }
            });
        }
    });
    Ok(())
}

/// Run `iters` updates (a multiple of `cfg.threads`) via repeated passes.
pub fn wavefront_jacobi_iters(
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
    iters: usize,
) -> Result<()> {
    anyhow::ensure!(
        iters % cfg.threads == 0,
        "iters ({iters}) must be a multiple of the blocking factor ({})",
        cfg.threads
    );
    for _ in 0..iters / cfg.threads {
        wavefront_jacobi(u, f, h2, cfg)?;
    }
    Ok(())
}

/// Reference: `n` serial Jacobi sweeps, returning the result.
pub fn serial_reference(u: &Grid3, f: &Grid3, h2: f64, n: usize) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        jacobi_sweep(&mut b, &a, f, h2);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(nz: usize, ny: usize, nx: usize, t: usize, sync: SyncMode, barrier: BarrierKind) {
        let f = Grid3::random(nz, ny, nx, 77);
        let mut u = Grid3::random(nz, ny, nx, 42);
        let want = serial_reference(&u, &f, 0.8, t);
        let cfg = WavefrontConfig { threads: t, barrier, sync };
        wavefront_jacobi(&mut u, &f, 0.8, &cfg).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "bit-exactness {nz}x{ny}x{nx} t={t} {sync:?} {barrier:?}"
        );
    }

    #[test]
    fn bit_identical_to_serial_t2() {
        check(12, 9, 11, 2, SyncMode::Barrier, BarrierKind::Spin);
        check(12, 9, 11, 2, SyncMode::Flow, BarrierKind::Spin);
    }

    #[test]
    fn bit_identical_to_serial_t4() {
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Flow, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn bit_identical_to_serial_t6_t8() {
        check(20, 8, 9, 6, SyncMode::Barrier, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Flow, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn small_grids_where_wavefronts_overlap_fully() {
        // nz-2 < 2t: every thread is inside the pipeline fill/drain region.
        check(5, 6, 6, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(4, 5, 5, 6, SyncMode::Flow, BarrierKind::Spin);
        check(3, 4, 4, 2, SyncMode::Barrier, BarrierKind::Spin);
    }

    #[test]
    fn odd_thread_count_rejected() {
        let mut u = Grid3::random(8, 8, 8, 1);
        let f = Grid3::zeros(8, 8, 8);
        let cfg = WavefrontConfig { threads: 3, ..Default::default() };
        assert!(wavefront_jacobi(&mut u, &f, 1.0, &cfg).is_err());
    }

    #[test]
    fn iters_multiple_passes() {
        let f = Grid3::random(10, 8, 8, 5);
        let mut u = Grid3::random(10, 8, 8, 6);
        let want = serial_reference(&u, &f, 1.0, 8);
        let cfg = WavefrontConfig { threads: 4, ..Default::default() };
        wavefront_jacobi_iters(&mut u, &f, 1.0, &cfg, 8).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        // non-multiple is an error
        let mut v = Grid3::random(10, 8, 8, 6);
        assert!(wavefront_jacobi_iters(&mut v, &f, 1.0, &cfg, 6).is_err());
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        wavefront_jacobi(&mut u, &f, 1.0, &WavefrontConfig::default()).unwrap();
        assert_eq!(u, orig);
    }
}
