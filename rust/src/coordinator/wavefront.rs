//! Temporal wavefront blocking for Jacobi-style ops (paper Sec. 4,
//! Fig. 6), generic over the [`StencilOp`] kernel layer.
//!
//! A *thread group* of `t` workers performs `t` time-shifted sweeps over
//! the grid. Worker `s` (0-based) executes update step `s+1`, trailing
//! worker `s-1` by `R+1` planes (for halo radius `R`) so its
//! `2R+1`-plane read window only touches completed planes. Odd-numbered
//! updates are written to a small round-robin temporary buffer;
//! even-numbered updates go back to the `src` array — so after the group
//! passes, `src` holds the `t`-times updated grid *in place*, without
//! the second full grid of the out-of-place sweep (the paper's "the
//! second grid ... is not required").
//!
//! The temporary buffer holds `2R+2` z-x planes per odd update level
//! (four for the paper's radius-1 stencil and `t = 4` example, matching
//! "for our example eight" in total): producer step `2u+1` writes plane
//! `k` to slot `k mod (2R+2)` of region `u`, consumer step `2u+2` trails
//! by exactly `R+1` planes and reads slots `k-R … k+R` — `2R+2` live
//! slots.
//!
//! The pass is expressed as a [`Schedule`] and dispatched on the
//! persistent [`WorkerPool`](super::pool::WorkerPool) (or one tenant's
//! [`PoolSegment`](super::pool::PoolSegment) window of it); repeated
//! passes reuse one thread team and one temporary ring.
//!
//! ## Safety argument (also enforced by the progress protocol)
//!
//! * worker `s` updates plane `k` only once `progress[s-1] >= k+R`
//!   (its entire read window holds step-`s` values);
//! * worker `s` never runs more than `TMP_SLOTS - 1 - (R-1)` planes
//!   ahead of worker `s+1` (back-pressure), so no live temporary slot is
//!   reused;
//! * `src` writes by worker `s` land strictly behind every plane an
//!   upstream worker's window can still read (lag `R+1` per step).
//!
//! Boundary planes (`k < R`, `k >= nz-R`) are never updated at any step,
//! so every step's "value" of a boundary plane is the original `src`
//! plane — window reads are redirected there instead of the temporary.
//!
//! Numerics are bit-identical to `t` serial [`op_jacobi_sweep`]s: same
//! kernel, same fp order — tests assert exact equality.

use std::marker::PhantomData;

use crate::simulator::memory::StoreMode;
use crate::simulator::perfmodel::BarrierKind;
use crate::stencil::grid::Grid3;
use crate::stencil::jacobi::jacobi_sweep;
use crate::stencil::op::{op_jacobi_sweep, StarWindow, StencilOp, MAX_RADIUS};
use crate::Result;

use super::barrier::AnyBarrier;
use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};

/// Temporary-ring slots per odd update level for halo radius `r`.
#[inline]
pub(crate) fn tmp_slots(r: usize) -> usize {
    2 * r + 2
}

/// How workers of a group synchronize plane hand-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// Global barrier after every plane round (the paper's scheme).
    #[default]
    Barrier,
    /// Point-to-point progress flags (producer/consumer flow control) —
    /// the "highly efficient synchronization" refinement: workers only
    /// wait for the neighbors they actually depend on.
    Flow,
}

/// Configuration of one wavefront thread group.
#[derive(Clone, Copy, Debug)]
pub struct WavefrontConfig {
    /// Workers in the group = temporal blocking factor `t` (even, >= 2).
    pub threads: usize,
    pub barrier: BarrierKind,
    pub sync: SyncMode,
    /// Store flavour of the *final* update level (the only write stream
    /// of the pass that is never re-read): non-temporal streams it past
    /// the cache, write-allocate keeps it resident. Intermediate levels
    /// always use plain stores — their output is the next level's input.
    pub store: StoreMode,
}

impl Default for WavefrontConfig {
    fn default() -> Self {
        Self {
            threads: 4,
            barrier: BarrierKind::Spin,
            sync: SyncMode::Barrier,
            store: StoreMode::NonTemporal,
        }
    }
}

impl WavefrontConfig {
    /// Validate the configuration (single source for every entry point).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.threads >= 2 && self.threads % 2 == 0,
            "wavefront needs an even thread count >= 2, got {}",
            self.threads
        );
        Ok(())
    }
}

/// One wavefront pass (`t` fused updates of `op`) as a [`Schedule`].
///
/// Borrows the op and grids for `'g`; reusable across passes — the
/// temporary ring is fully rewritten before it is re-read within each
/// pass.
pub struct WavefrontJacobiSchedule<'g, O: StencilOp> {
    op: &'g O,
    src: *mut f64,
    tmp: *mut f64,
    f: *const f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    /// Halo radius of `op` (cached; also the wavefront lag minus one).
    r: usize,
    h2: f64,
    sync: SyncMode,
    store: StoreMode,
    barrier: AnyBarrier,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: workers index the shared grid and ring disjointly per the
// progress protocol (module docs); all shared access is through raw
// pointers whose aliasing discipline the schedule itself enforces.
unsafe impl<O: StencilOp> Send for WavefrontJacobiSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for WavefrontJacobiSchedule<'_, O> {}

impl<'g, O: StencilOp> WavefrontJacobiSchedule<'g, O> {
    /// Build a pass over `u`. `tmp` is the caller-owned temporary ring;
    /// it is resized here and must stay alive (and untouched) for as
    /// long as the schedule runs.
    pub fn new(
        op: &'g O,
        u: &'g mut Grid3,
        f: &'g Grid3,
        tmp: &'g mut Vec<f64>,
        h2: f64,
        cfg: &WavefrontConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.threads;
        let r = op.radius();
        anyhow::ensure!(r >= 1 && r <= MAX_RADIUS, "unsupported halo radius {r}");
        anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} wavefront pass"
        );
        let plane = ny * nx;
        tmp.clear();
        tmp.resize((t / 2) * tmp_slots(r) * plane, 0.0);
        let lag = (r + 1) as isize;
        Ok(Self {
            op,
            src: u.data_mut().as_mut_ptr(),
            tmp: tmp.as_mut_ptr(),
            f: f.data().as_ptr(),
            nz,
            ny,
            nx,
            t,
            r,
            h2,
            sync: cfg.sync,
            store: cfg.store,
            barrier: AnyBarrier::new(cfg.barrier, t),
            last_round: (nz - 2 * r) as isize + lag * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for WavefrontJacobiSchedule<'_, O> {
    fn workers(&self) -> usize {
        self.t
    }

    fn worker(&self, s: usize, progress: &Progress) {
        let (nz, ny, nx, t, r) = (self.nz, self.ny, self.nx, self.t, self.r);
        let plane = ny * nx;
        let slots = tmp_slots(r);
        let lag = (r + 1) as isize;
        let interior_hi = (nz - 1 - r) as isize;
        let src = self.src;
        let tmpp = self.tmp;
        let f_base = self.f;
        // Only the last update level's writes leave the pass un-re-read;
        // every other level's output is a downstream worker's input, so
        // streaming it would evict the very planes the group keeps hot.
        let store = if s == t - 1 { self.store } else { StoreMode::WriteAllocate };
        // plane base pointer holding the step-`s` values of plane kk as
        // seen by worker `s` (its read side).
        let read_plane = |kk: usize| -> *const f64 {
            if kk < r || kk >= nz - r || s % 2 == 0 {
                unsafe { src.add(kk * plane) as *const f64 }
            } else {
                let region = (s / 2) * slots;
                unsafe { tmpp.add((region + kk % slots) * plane) as *const f64 }
            }
        };
        let write_plane = |k: usize| -> *mut f64 {
            if s % 2 == 0 {
                let region = (s / 2) * slots;
                unsafe { tmpp.add((region + k % slots) * plane) }
            } else {
                unsafe { src.add(k * plane) }
            }
        };

        for round in 1..=self.last_round {
            let k = round + (r as isize - 1) - lag * s as isize;
            if k >= r as isize && k <= interior_hi {
                let k = k as usize;
                if self.sync == SyncMode::Flow {
                    // forward dependency: window complete at step s.
                    // Planes beyond the interior are boundary and never
                    // processed, so near the top the window is complete
                    // once the producer finished its last interior plane.
                    if s > 0 {
                        let need = (k as isize + r as isize).min(interior_hi);
                        progress.wait_min(s - 1, need);
                    }
                    // back-pressure: do not overwrite a tmp slot the
                    // consumer may still read
                    if s + 1 < t {
                        progress.wait_min(s + 1, k as isize - slots as isize + r as isize);
                    }
                }
                // SAFETY: the schedule guarantees exclusive write access
                // to plane k of the write side and that every read plane
                // holds completed step values (see module docs); lines
                // below are disjoint slices.
                unsafe {
                    let out = write_plane(k);
                    // boundary lines of the output plane must carry the
                    // (step-invariant) boundary values so later steps
                    // read correct y-edges from the tmp.
                    if s % 2 == 0 {
                        let src_plane = src.add(k * plane) as *const f64;
                        for j in 0..r {
                            std::ptr::copy_nonoverlapping(src_plane.add(j * nx), out.add(j * nx), nx);
                            std::ptr::copy_nonoverlapping(
                                src_plane.add((ny - 1 - j) * nx),
                                out.add((ny - 1 - j) * nx),
                                nx,
                            );
                        }
                        // x-edge columns are copied per line below.
                    }
                    let zc = read_plane(k);
                    // z-plane base pointers are loop-invariant in j —
                    // hoisted out of the line loop as before the refactor
                    let mut zm_p = [zc; MAX_RADIUS];
                    let mut zp_p = [zc; MAX_RADIUS];
                    for d in 0..r {
                        zm_p[d] = read_plane(k - d - 1);
                        zp_p[d] = read_plane(k + d + 1);
                    }
                    let line = |p: *const f64, jj: usize| std::slice::from_raw_parts(p.add(jj * nx), nx);
                    for j in r..ny - r {
                        let dst = std::slice::from_raw_parts_mut(out.add(j * nx), nx);
                        let center = line(zc, j);
                        if s % 2 == 0 {
                            // carry the Dirichlet x-edges into tmp
                            crate::stencil::op::copy_x_edges(dst, center, r);
                        }
                        let win = StarWindow::from_fn(center, r, |dz, dy| {
                            if dz == 0 {
                                line(zc, (j as isize + dy) as usize)
                            } else if dz < 0 {
                                line(zm_p[(-dz - 1) as usize], j)
                            } else {
                                line(zp_p[(dz - 1) as usize], j)
                            }
                        });
                        self.op.line_update(
                            dst,
                            &win,
                            std::slice::from_raw_parts(f_base.add((k * ny + j) * nx), nx),
                            self.h2,
                            k,
                            j,
                            store,
                        );
                    }
                }
                progress.publish(s, k as isize);
            }
            if self.sync == SyncMode::Barrier {
                self.barrier.wait(s);
            }
        }
    }
}

/// Run `passes` wavefront passes of `op` on `pool`, one team, one
/// temporary ring (the ring lives in the dispatcher's reusable
/// [`Scratch`](super::pool::Scratch) arena, so repeated calls reuse one
/// allocation; the RAII guard hands it back even when a sweep panics).
/// The entry point the [`SchemeRunner`] registry, tests and benches
/// drive — `pool` may be a whole [`WorkerPool`](super::pool::WorkerPool)
/// or one tenant's [`PoolSegment`](super::pool::PoolSegment).
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn wavefront_jacobi_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    f: &Grid3,
    h2: f64,
    cfg: &WavefrontConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    anyhow::ensure!(u.shape() == f.shape(), "u/f shape mismatch");
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    let mut scratch = pool.scratch();
    let schedule = WavefrontJacobiSchedule::new(op, u, f, &mut scratch.planes, h2, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

/// Check the iteration count divides into whole passes.
pub(crate) fn check_iters_multiple(iters: usize, t: usize) -> Result<()> {
    anyhow::ensure!(
        iters % t == 0,
        "iters ({iters}) must be a multiple of the blocking factor ({t})"
    );
    Ok(())
}

/// Reference: `n` serial Jacobi sweeps of the paper's 7-point op.
pub fn serial_reference(u: &Grid3, f: &Grid3, h2: f64, n: usize) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        jacobi_sweep(&mut b, &a, f, h2);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Reference: `n` serial sweeps of an arbitrary op.
pub fn serial_reference_op<O: StencilOp + ?Sized>(
    op: &O,
    u: &Grid3,
    f: &Grid3,
    h2: f64,
    n: usize,
) -> Grid3 {
    let mut a = u.clone();
    let mut b = u.clone();
    for _ in 0..n {
        op_jacobi_sweep(op, &mut b, &a, f, h2);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::stencil::op::{ConstLaplace7, Laplace13};

    fn run_wf<O: StencilOp>(
        op: &O,
        u: &mut Grid3,
        f: &Grid3,
        h2: f64,
        cfg: &WavefrontConfig,
        passes: usize,
    ) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        wavefront_jacobi_passes(&mut pool, op, u, f, h2, cfg, passes)
    }

    fn check(nz: usize, ny: usize, nx: usize, t: usize, sync: SyncMode, barrier: BarrierKind) {
        let f = Grid3::random(nz, ny, nx, 77);
        let mut u = Grid3::random(nz, ny, nx, 42);
        let want = serial_reference(&u, &f, 0.8, t);
        // default store = NonTemporal: every bit-exactness check below
        // also validates the streamed final level against the serial
        // (write-allocate) reference
        let cfg = WavefrontConfig { threads: t, barrier, sync, ..Default::default() };
        run_wf(&ConstLaplace7, &mut u, &f, 0.8, &cfg, 1).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "bit-exactness {nz}x{ny}x{nx} t={t} {sync:?} {barrier:?}"
        );
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, t: usize, sync: SyncMode) {
        let f = Grid3::random(nz, ny, nx, 7);
        let mut u = Grid3::random(nz, ny, nx, 8);
        let want = serial_reference_op(&Laplace13, &u, &f, 0.8, t);
        let cfg = WavefrontConfig { threads: t, barrier: BarrierKind::Spin, sync, ..Default::default() };
        run_wf(&Laplace13, &mut u, &f, 0.8, &cfg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 {nz}x{ny}x{nx} t={t} {sync:?}");
    }

    #[test]
    fn bit_identical_to_serial_t2() {
        check(12, 9, 11, 2, SyncMode::Barrier, BarrierKind::Spin);
        check(12, 9, 11, 2, SyncMode::Flow, BarrierKind::Spin);
    }

    #[test]
    fn bit_identical_to_serial_t4() {
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Flow, BarrierKind::Spin);
        check(16, 10, 12, 4, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn bit_identical_to_serial_t6_t8() {
        check(20, 8, 9, 6, SyncMode::Barrier, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Flow, BarrierKind::Spin);
        check(22, 7, 9, 8, SyncMode::Barrier, BarrierKind::Tree);
    }

    #[test]
    fn small_grids_where_wavefronts_overlap_fully() {
        // nz-2 < 2t: every worker is inside the pipeline fill/drain region.
        check(5, 6, 6, 4, SyncMode::Barrier, BarrierKind::Spin);
        check(4, 5, 5, 6, SyncMode::Flow, BarrierKind::Spin);
        check(3, 4, 4, 2, SyncMode::Barrier, BarrierKind::Spin);
    }

    #[test]
    fn radius2_op_matches_its_serial_reference() {
        check_r2(14, 11, 10, 2, SyncMode::Barrier);
        check_r2(14, 11, 10, 2, SyncMode::Flow);
        check_r2(16, 9, 11, 4, SyncMode::Barrier);
        check_r2(16, 9, 11, 4, SyncMode::Flow);
        check_r2(12, 8, 9, 6, SyncMode::Flow);
        // fill/drain-only grid for radius 2
        check_r2(7, 6, 6, 4, SyncMode::Flow);
        check_r2(5, 5, 5, 2, SyncMode::Barrier);
    }

    #[test]
    fn odd_thread_count_rejected() {
        let mut u = Grid3::random(8, 8, 8, 1);
        let f = Grid3::zeros(8, 8, 8);
        let cfg = WavefrontConfig { threads: 3, ..Default::default() };
        assert!(run_wf(&ConstLaplace7, &mut u, &f, 1.0, &cfg, 1).is_err());
    }

    #[test]
    fn many_passes_on_one_private_pool() {
        let f = Grid3::random(11, 9, 8, 15);
        let mut u = Grid3::random(11, 9, 8, 16);
        let want = serial_reference(&u, &f, 0.5, 24);
        let cfg = WavefrontConfig { threads: 4, sync: SyncMode::Flow, ..Default::default() };
        let mut pool = WorkerPool::new(4);
        wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, 0.5, &cfg, 6).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        let f = Grid3::zeros(2, 6, 6);
        run_wf(&ConstLaplace7, &mut u, &f, 1.0, &WavefrontConfig::default(), 1).unwrap();
        assert_eq!(u, orig);
    }

    #[test]
    fn iters_guard_still_rejects_non_multiples() {
        assert!(check_iters_multiple(8, 4).is_ok());
        assert!(check_iters_multiple(6, 4).is_err());
    }
}
