//! Multi-group spatial × temporal blocking for Gauss-Seidel — the
//! Fig. 5b wavefront pipeline nested inside the y-block multi-group
//! decomposition of [`super::spatial_mg`], generic over the
//! [`StencilOp`] kernel layer.
//!
//! [`super::wavefront_gs`] runs `S` complete GS sweeps simultaneously
//! over the *whole* grid, shifted in z. Here `G` *groups* each own one
//! y-block of the Fig. 7 decomposition and run that pipeline over their
//! block concurrently: worker `g` executes rounds of `t` time-shifted
//! in-place sweep levels, level `s` updating plane
//! `k = round + (R-1) - (R+1)·(s-1)` of its block — the `k + R` sweep
//! spacing of the GS wavefront, expressed as the same round/lag
//! arithmetic the Jacobi multi-group scheme uses (groups of pipelined
//! sweeps per block: Wittmann et al., arXiv:0912.4506, carry the
//! block-of-groups decomposition over to ordered smoothers;
//! arXiv:1006.3148 motivates the per-cache-group y-block layout).
//!
//! ## Cross-group protocol (lexicographic order across block seams)
//!
//! Updating line `(k, y)` at level `s` reads, across the lower seam,
//! lines `y - d` at level `s` (*new* values) and, across the upper seam,
//! lines `y + d` at level `s - 1` (*old* values). Both are satisfied by
//! one watermark pair per round:
//!
//! * **left-wait** — worker `g` starts round `r` after `g-1` *finished*
//!   round `r`: the interface lines below the block then hold exactly
//!   level-`s` values when level `s` of round `r` reads them (level
//!   `s+1` of `g-1` only reaches plane `k` at round `r + R+1`, which the
//!   right-wait below blocks until `g` has published round `r`);
//! * **right-wait** — worker `g` starts round `r` after `g+1` finished
//!   round `r - (R+1)`: the boundary-array slots round `r` reads (see
//!   below) were written then. This is the round-lag hand-off; with lag
//!   `R+1 >= 2` the steady-state pipeline keeps every group busy
//!   (`g`'s round `r` and `g+1`'s round `r-1` overlap).
//!
//! Because GS updates in place, the level-`(s-1)` values of `g+1`'s
//! first `R` lines would be overwritten by its level-`s` pass before `g`
//! can read them across the seam. Each group therefore saves its first
//! `R` lines into a per-level **boundary array** (`(t-1)` levels ×
//! `nz` planes × `R` x-lines) right after updating them; the left
//! neighbor reads the saved copies. Level 0 (the original values) needs
//! no save — the left-wait ordering alone freezes it — and the deepest
//! level `t` is read by nobody.
//!
//! ## The width restriction is *lifted* to `R` lines per block
//!
//! The out-of-place Jacobi decomposition needs `2R` interior lines per
//! block (its serial forwarding pass has no sound one-round-lag analog).
//! In-place GS has no forwarded lines: every level lives in the single
//! array, and the boundary array only carries the `R`-line halo a seam
//! read can reach — so any decomposition with `>= R` interior lines per
//! block (`ny - 2R >= R·G`) is exact, radius-1 blocks may be a single
//! line wide, and narrower decompositions fail with the typed
//! [`BlockWidthError`] (shared with [`RunConfig::validate`], so the
//! config layer and this constructor raise the identical error).
//!
//! Result: bit-identical to `t` serial lexicographic sweeps for every
//! `(t, groups)` and radius — asserted by the tests, the shared parity
//! harness (`tests/common`) and `launcher::run_experiment` on every
//! launch.
//!
//! [`RunConfig::validate`]: crate::config::RunConfig::validate

use std::marker::PhantomData;

use crate::config::{BlockWidthError, Scheme};
use crate::stencil::gauss_seidel::GsKernel;
use crate::stencil::grid::Grid3;
use crate::stencil::op::{op_gs_sweep, GsWindow, StencilOp, MAX_RADIUS};
use crate::Result;

use super::pool::Dispatch;
use super::schedule::{Progress, Schedule};

/// Configuration of a multi-group blocked GS pass.
#[derive(Clone, Copy, Debug)]
pub struct GsMultiGroupConfig {
    /// Temporal blocking factor `t` = simultaneous in-place sweeps per
    /// block (>= 1; in-place GS has no even-`t` restriction).
    pub t: usize,
    /// Thread groups = y blocks (>= 1; each block needs >= R interior
    /// lines when `groups > 1`).
    pub groups: usize,
    pub kernel: GsKernel,
}

impl Default for GsMultiGroupConfig {
    fn default() -> Self {
        Self { t: 4, groups: 2, kernel: GsKernel::Interleaved }
    }
}

impl GsMultiGroupConfig {
    /// Validate the grid-independent part of the configuration (single
    /// source for every entry point); the per-group width requirement
    /// needs the grid and the op radius and lives in
    /// [`GsMultiGroupSchedule::new`].
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.t >= 1, "need at least one sweep level");
        anyhow::ensure!(self.groups >= 1, "need at least one group");
        Ok(())
    }
}

/// One multi-group blocked GS pass (`t` fused in-place sweeps of `op`)
/// as a [`Schedule`]: worker `g` runs the GS wavefront over y-block `g`.
pub struct GsMultiGroupSchedule<'g, O: StencilOp> {
    op: &'g O,
    base: *mut f64,
    /// `(groups-1) * (t-1) * nz * R` x-lines: one boundary-array slab
    /// per *seam* (slab `g-1` belongs to group `g`, which has a left
    /// neighbor), holding each non-final level's first `R` block lines
    /// for that neighbor's old-value seam reads. Group 0 saves nothing
    /// and owns no slab.
    bnd: *mut f64,
    nz: usize,
    ny: usize,
    nx: usize,
    t: usize,
    r: usize,
    groups: usize,
    kernel: GsKernel,
    /// Block boundaries over the interior lines `[R, ny-R)`.
    starts: Vec<usize>,
    last_round: isize,
    _borrow: PhantomData<&'g mut f64>,
}

// SAFETY: groups write disjoint regions (own block lines, own boundary
// array); the left-wait/right-wait watermark pair orders every
// cross-group read/write pair (module docs).
unsafe impl<O: StencilOp> Send for GsMultiGroupSchedule<'_, O> {}
unsafe impl<O: StencilOp> Sync for GsMultiGroupSchedule<'_, O> {}

impl<'g, O: StencilOp> GsMultiGroupSchedule<'g, O> {
    /// Build a pass over `u`. `bnd` is a caller-owned scratch buffer
    /// (typically the pool's reusable [`Scratch`](super::pool::Scratch)),
    /// resized here; it must stay alive (and untouched) for as long as
    /// the schedule runs.
    pub fn new(
        op: &'g O,
        u: &'g mut Grid3,
        bnd: &'g mut Vec<f64>,
        cfg: &GsMultiGroupConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let t = cfg.t;
        let groups = cfg.groups;
        let r = op.radius();
        anyhow::ensure!(r >= 1 && r <= MAX_RADIUS, "unsupported halo radius {r}");
        op.validate_domain(u.shape())?;
        let (nz, ny, nx) = u.shape();
        anyhow::ensure!(
            nz >= 2 * r + 1 && ny >= 2 * r + 1 && nx >= 2 * r + 1,
            "grid too small for a radius-{r} blocked pass"
        );
        BlockWidthError::check(Scheme::GsMultiGroup, r, ny, groups, t)?;
        let interior = ny - 2 * r;
        bnd.clear();
        bnd.resize(groups.saturating_sub(1) * t.saturating_sub(1) * nz * r * nx, 0.0);
        let starts: Vec<usize> = (0..=groups).map(|b| r + b * interior / groups).collect();
        let lag = (r + 1) as isize;
        Ok(Self {
            op,
            base: u.data_mut().as_mut_ptr(),
            bnd: bnd.as_mut_ptr(),
            nz,
            ny,
            nx,
            t,
            r,
            groups,
            kernel: cfg.kernel,
            starts,
            last_round: (nz - 2 * r) as isize + lag * (t as isize - 1),
            _borrow: PhantomData,
        })
    }
}

impl<O: StencilOp> Schedule for GsMultiGroupSchedule<'_, O> {
    fn workers(&self) -> usize {
        self.groups
    }

    fn worker(&self, g: usize, progress: &Progress) {
        let (nz, ny, nx, t, r) = (self.nz, self.ny, self.nx, self.t, self.r);
        let lag = (r + 1) as isize;
        let lvl_stride = nz * r * nx; // per saved level
        let slab = t.saturating_sub(1) * lvl_stride;
        // seam slab g-1 is written by group g; group g reads its right
        // neighbor's slab (g+1)-1 = g
        let bnd_own = if g > 0 {
            unsafe { self.bnd.add((g - 1) * slab) }
        } else {
            std::ptr::null_mut()
        };
        let bnd_next = if g + 1 < self.groups {
            unsafe { self.bnd.add(g * slab) as *const f64 }
        } else {
            std::ptr::null()
        };
        let base = self.base;
        let block_start = self.starts[g];
        let block_end = self.starts[g + 1];
        let at = |kk: usize, yy: usize| (kk * ny + yy) * nx;

        for round in 1..=self.last_round {
            if g > 0 {
                // lexicographic flow: the left neighbor's level-s seam
                // lines for this round are live once it finished the
                // same round (module docs).
                progress.wait_min(g - 1, round);
            }
            if g + 1 < self.groups {
                // round-lag hand-off: the boundary-array slots this
                // round reads were written by the right neighbor at
                // round - lag; the same wait keeps the right neighbor
                // from overwriting seam lines the left-wait freezes.
                progress.wait_min(g + 1, round - lag);
            }
            for s in 1..=t {
                let k = round + (r as isize - 1) - lag * (s as isize - 1);
                if k < r as isize || k > (nz - 1 - r) as isize {
                    continue;
                }
                let k = k as usize;
                for y in block_start..block_end {
                    // SAFETY: the watermark protocol above freezes every
                    // line the window reads and gives this group
                    // exclusive write access to its block (module docs);
                    // the five-line window never aliases the mutable
                    // center line.
                    unsafe {
                        let line_at = |kk: usize, yy: usize| {
                            std::slice::from_raw_parts(base.add(at(kk, yy)) as *const f64, nx)
                        };
                        // never read past index r-1; must not alias the
                        // mutable center line
                        let dummy = line_at(k, y - 1);
                        let mut win = GsWindow {
                            ym_new: [dummy; MAX_RADIUS],
                            yp_old: [dummy; MAX_RADIUS],
                            zm_new: [dummy; MAX_RADIUS],
                            zp_old: [dummy; MAX_RADIUS],
                        };
                        for d in 0..r {
                            win.ym_new[d] = line_at(k, y - d - 1);
                            win.zm_new[d] = line_at(k - d - 1, y);
                            win.zp_old[d] = line_at(k + d + 1, y);
                            let yy = y + d + 1;
                            win.yp_old[d] = if s >= 2 && !bnd_next.is_null() && yy >= block_end {
                                // the right neighbor's level-(s-1) value
                                // of its line yy, saved before its
                                // level-s pass overwrote it
                                std::slice::from_raw_parts(
                                    bnd_next.add(
                                        (s - 2) * lvl_stride + (k * r + (yy - block_end)) * nx,
                                    ),
                                    nx,
                                )
                            } else {
                                line_at(k, yy)
                            };
                        }
                        let line = std::slice::from_raw_parts_mut(base.add(at(k, y)), nx);
                        self.op.gs_line_update(line, &win, k, y, self.kernel);
                        if g > 0 && s < t && y < block_start + r {
                            // save the freshly written level-s value of
                            // this seam line for the left neighbor's
                            // level-(s+1) old-value reads
                            let dst = bnd_own
                                .add((s - 1) * lvl_stride + (k * r + (y - block_start)) * nx);
                            std::ptr::copy_nonoverlapping(line.as_ptr(), dst, nx);
                        }
                    }
                }
            }
            progress.publish(g, round);
        }
    }
}

/// Run `passes` multi-group GS passes (`t` sweeps each) of `op` on
/// `pool` with one schedule — boundary arrays come from the
/// dispatcher's reusable [`Scratch`](super::pool::Scratch) arena,
/// returned by the RAII guard even when a sweep panics.
pub fn gs_multigroup_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    cfg: &GsMultiGroupConfig,
    passes: usize,
) -> Result<()> {
    cfg.validate()?;
    let r = op.radius();
    let (nz, ny, nx) = u.shape();
    if nz < 2 * r + 1 || ny < 2 * r + 1 || nx < 2 * r + 1 || passes == 0 {
        return Ok(());
    }
    if cfg.groups == 1 && cfg.t == 1 {
        for _ in 0..passes {
            op_gs_sweep(op, u, cfg.kernel);
        }
        return Ok(());
    }
    let mut scratch = pool.scratch();
    let schedule = GsMultiGroupSchedule::new(op, u, &mut scratch.bnd, cfg)?;
    for _ in 0..passes {
        pool.run(&schedule)?;
    }
    Ok(())
}

/// `iters` sweeps of `op` via passes of `cfg.t` each (+ a remainder pass
/// with a shallower temporal depth), all on one team — the pool-level
/// entry point the [`SchemeRunner`] registry, tests and benches drive.
///
/// [`SchemeRunner`]: super::runner::SchemeRunner
pub fn gs_multigroup_iters_passes<O: StencilOp>(
    pool: &mut dyn Dispatch,
    op: &O,
    u: &mut Grid3,
    cfg: &GsMultiGroupConfig,
    iters: usize,
) -> Result<()> {
    cfg.validate()?;
    gs_multigroup_passes(pool, op, u, cfg, iters / cfg.t)?;
    let rest = iters % cfg.t;
    if rest > 0 {
        let tail = GsMultiGroupConfig { t: rest, ..*cfg };
        gs_multigroup_passes(pool, op, u, &tail, 1)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::WorkerPool;
    use crate::stencil::gauss_seidel::gs_sweeps;
    use crate::stencil::op::{op_gs_sweeps, ConstLaplace7, Laplace13, VarCoeff7};

    fn run_mg<O: StencilOp>(op: &O, u: &mut Grid3, cfg: &GsMultiGroupConfig) -> Result<()> {
        let mut pool = WorkerPool::new(0);
        gs_multigroup_passes(&mut pool, op, u, cfg, 1)
    }

    fn check(nz: usize, ny: usize, nx: usize, t: usize, groups: usize, kernel: GsKernel) {
        let mut u = Grid3::random(nz, ny, nx, 123);
        let mut want = u.clone();
        gs_sweeps(&mut want, t, kernel);
        run_mg(&ConstLaplace7, &mut u, &GsMultiGroupConfig { t, groups, kernel }).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "{nz}x{ny}x{nx} t={t} G={groups} {kernel:?}");
    }

    fn check_r2(nz: usize, ny: usize, nx: usize, t: usize, groups: usize) {
        let mut u = Grid3::random(nz, ny, nx, 321);
        let mut want = u.clone();
        op_gs_sweeps(&Laplace13, &mut want, t, GsKernel::Interleaved);
        let cfg = GsMultiGroupConfig { t, groups, kernel: GsKernel::Interleaved };
        run_mg(&Laplace13, &mut u, &cfg).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 {nz}x{ny}x{nx} t={t} G={groups}");
    }

    #[test]
    fn single_group_matches_serial() {
        check(8, 8, 8, 1, 1, GsKernel::Interleaved);
        check(10, 9, 8, 4, 1, GsKernel::Interleaved);
        check(8, 7, 9, 3, 1, GsKernel::Naive);
    }

    #[test]
    fn two_groups_match_serial() {
        check(10, 12, 8, 2, 2, GsKernel::Interleaved);
        check(10, 12, 8, 4, 2, GsKernel::Interleaved);
        check(8, 16, 9, 6, 2, GsKernel::Naive);
        check(8, 4, 9, 4, 2, GsKernel::Interleaved); // one interior line each
    }

    #[test]
    fn many_groups_and_uneven_blocks_match_serial() {
        check(8, 24, 8, 4, 4, GsKernel::Interleaved);
        check(8, 13, 8, 4, 3, GsKernel::Interleaved); // 11 lines over 3 blocks
        check(6, 11, 7, 3, 5, GsKernel::Naive); // 9 lines over 5 blocks
        check(6, 6, 7, 2, 4, GsKernel::Interleaved); // width-1 blocks
        check(7, 9, 8, 5, 7, GsKernel::Interleaved); // all blocks width 1
    }

    #[test]
    fn deep_temporal_blocking_and_short_z() {
        check(10, 10, 8, 8, 4, GsKernel::Interleaved);
        check(4, 10, 8, 6, 3, GsKernel::Interleaved); // pipeline > z extent
        check(3, 8, 6, 5, 2, GsKernel::Naive);
    }

    #[test]
    fn radius2_groups_match_serial() {
        check_r2(10, 9, 9, 2, 2); // minimum width: 2 interior lines each + 1
        check_r2(10, 12, 9, 2, 2);
        check_r2(10, 16, 9, 4, 2);
        check_r2(9, 11, 8, 3, 3); // 7 interior lines over 3 blocks, uneven
        check_r2(11, 14, 8, 5, 4);
        check_r2(5, 10, 7, 4, 3); // short z, exactly 2 lines per block
    }

    #[test]
    fn varcoeff_groups_match_serial() {
        let op = VarCoeff7::default_for((9, 14, 8));
        let mut u = Grid3::random(9, 14, 8, 33);
        let mut want = u.clone();
        op_gs_sweeps(&op, &mut want, 4, GsKernel::Interleaved);
        let cfg = GsMultiGroupConfig { t: 4, groups: 3, kernel: GsKernel::Interleaved };
        run_mg(&op, &mut u, &cfg).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn iters_with_remainder_reuse_one_team() {
        let mut u = Grid3::random(10, 14, 8, 5);
        let mut want = u.clone();
        gs_sweeps(&mut want, 11, GsKernel::Interleaved);
        let cfg = GsMultiGroupConfig { t: 4, groups: 3, kernel: GsKernel::Interleaved };
        let mut pool = WorkerPool::new(3);
        gs_multigroup_iters_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 11).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0);
        assert_eq!(pool.size(), 3, "no extra workers for the remainder pass");
    }

    #[test]
    fn invalid_configs_rejected_with_typed_width_error() {
        let mut u = Grid3::random(8, 8, 8, 1);
        // zero sweeps / zero groups
        let cfg = GsMultiGroupConfig { t: 0, groups: 2, kernel: GsKernel::Interleaved };
        assert!(run_mg(&ConstLaplace7, &mut u, &cfg).is_err());
        let cfg = GsMultiGroupConfig { t: 2, groups: 0, kernel: GsKernel::Interleaved };
        assert!(run_mg(&ConstLaplace7, &mut u, &cfg).is_err());
        // more blocks than interior lines (8 - 2 = 6 < 7)
        let cfg = GsMultiGroupConfig { t: 2, groups: 7, kernel: GsKernel::Interleaved };
        let err = run_mg(&ConstLaplace7, &mut u, &cfg).unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed width error");
        assert_eq!((typed.scheme, typed.required), (Scheme::GsMultiGroup, 1));
        // radius-2: 12 - 4 = 8 interior lines < 2 * 5 groups
        let mut v = Grid3::random(8, 12, 8, 2);
        let cfg = GsMultiGroupConfig { t: 2, groups: 5, kernel: GsKernel::Interleaved };
        let err = run_mg(&Laplace13, &mut v, &cfg).unwrap_err();
        assert!(err.downcast_ref::<BlockWidthError>().is_some());
        // ...while 4 radius-2 blocks of 2 lines are exact (lifted bound)
        check_r2(8, 12, 8, 2, 4);
    }

    #[test]
    fn degenerate_grid_is_identity() {
        let mut u = Grid3::random(2, 6, 6, 9);
        let orig = u.clone();
        run_mg(&ConstLaplace7, &mut u, &GsMultiGroupConfig::default()).unwrap();
        assert_eq!(u, orig);
    }
}
