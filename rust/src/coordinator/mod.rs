//! The paper's contribution: multicore-aware wavefront parallelization.
//!
//! * [`barrier`] — the synchronization primitives of Sec. 4: a spin-wait
//!   barrier for physical cores and a tree barrier for SMT, both built for
//!   the fine-grained plane-level synchronization pthread barriers cannot
//!   sustain.
//! * [`schedule`] — the unified time-skew abstraction: every scheme below
//!   is a [`schedule::Schedule`] (per-worker role, per-round plane/line
//!   task, forward-dependency and back-pressure waits against one shared
//!   [`schedule::Progress`] table).
//! * [`pool`] — the persistent worker pool the schedules run on: one
//!   thread team created once and reused across passes, iterations and
//!   experiments, with on-demand team growth and an optional core-pinning
//!   hook.
//! * [`wavefront`] — temporal blocking for Jacobi: a thread group of `t`
//!   workers runs `t` time-shifted z-sweeps with intermediate planes in a
//!   small round-robin temporary buffer (Fig. 6).
//! * [`pipeline`] — pipeline-parallel lexicographic Gauss-Seidel
//!   (Fig. 5a): workers partition y; plane updates are shifted in time to
//!   retain the serial update order.
//! * [`wavefront_gs`] — the composition (Fig. 5b): multiple pipelined GS
//!   sweeps run through the grid simultaneously, shifted in z.
//! * [`spatial`] — the improved spatial blocking of Sec. 4 (Fig. 7):
//!   y-blocks with skewed per-level update regions and the t-plane
//!   boundary arrays that make block sweeps exact (serial reference).
//! * [`spatial_mg`] — the multi-group version of Fig. 7: `G` groups
//!   wavefront-sweep their y-blocks concurrently, handing the odd-level
//!   boundary arrays to the next group under round-lag flow control.
//! * [`gs_multigroup`] — the Gauss-Seidel member of that family: each
//!   group runs the Fig. 5b pipeline over its y-block in place, saving
//!   `R`-line per-level boundary arrays for the left neighbor's
//!   old-value seam reads (width restriction lifted from `2R` to `R`).
//! * [`diamond`] — diamond-tile temporal blocking (arXiv:1410.3060):
//!   shrinking/growing y tiles that exactly tile the interior at every
//!   level, co-swept through z as one wavefront — no boundary arrays,
//!   no per-block pipeline wind-up, one shared temporary ring.
//!
//! Every scheme is generic over a [`StencilOp`](crate::stencil::op::StencilOp)
//! — the kernel layer supplies the halo radius the schedules honor in
//! wavefront lag (`R+1` planes), temporary-ring depth (`2R+2` slots),
//! pipeline spacing and boundary-array width (`2R` lines) — and every
//! scheme is *numerically exact*: tests assert bit-identical grids
//! against the serial reference sweeps, for all thread counts, blocking
//! factors, ops and radii. Temporal blocking changes traffic, never
//! numerics.
//!
//! ## The session API
//!
//! Schemes are driven through a [`solver::Solver`] session: one builder
//! validates the [`RunConfig`](crate::config::RunConfig), resolves the
//! scheme's [`runner::SchemeRunner`] from the registry, spawns (and
//! optionally pins, [`affinity::PinPolicy`]) the team once, and owns the
//! pool plus its reusable scratch — so repeated `run()` calls spawn no
//! threads and allocate no scratch:
//!
//! ```no_run
//! use stencilwave::config::RunConfig;
//! use stencilwave::coordinator::solver::Solver;
//! use stencilwave::stencil::grid::Grid3;
//!
//! let cfg = RunConfig { size: (64, 64, 64), t: 4, iters: 8, ..Default::default() };
//! let mut solver = Solver::builder(&cfg).build().unwrap();
//! let mut u = Grid3::from_fn(64, 64, 64, |k, j, i| (k + j + i) as f64);
//! solver.run(&mut u, 8).unwrap();
//! ```
//!
//! The 0.2.0 free-function shim matrix (`wavefront_jacobi`,
//! `pipeline_gs_sweep`, …; 16 functions plus `pool::with_global`) was
//! removed in 0.3.0 after its one-release deprecation window — see the
//! migration table in the README. Pool-level entry points
//! (`wavefront_jacobi_passes`, `pipeline_gs_passes`,
//! `wavefront_gs_iters_passes`, `multigroup_passes`,
//! `gs_multigroup_iters_passes`, `diamond_passes`) remain public for callers that drive an
//! explicit [`pool::WorkerPool`] — or, since the multi-tenant service,
//! any [`pool::Dispatch`] implementor such as a [`pool::PoolSegment`].
//!
//! ## The multi-tenant service
//!
//! [`service::SolverService`] runs many concurrent jobs on *one* pool:
//! each job is admitted by an ECM-cost placement model onto a window of
//! cache groups (a [`pool::PoolSegment`] with its own progress table and
//! scratch arena), and small-grid jobs with identical configurations
//! batch through one session (many RHS, one schedule).

pub mod affinity;
pub mod barrier;
pub mod diamond;
pub mod gs_multigroup;
pub mod pipeline;
pub mod pool;
pub mod rank;
pub mod runner;
pub mod schedule;
pub mod service;
pub mod solver;
pub mod spatial;
pub mod spatial_mg;
pub mod wavefront;
pub mod wavefront_gs;
