//! `stencilwave` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! * `run`      — execute one experiment (config file or flags), verified
//!                against the serial reference, with optional testbed
//!                prediction.
//! * `figures`  — regenerate any/all of the paper's tables and figures.
//! * `stream`   — run the real STREAM triad on this host and show the
//!                modeled Tab. 1 bandwidths next to it.
//! * `validate` — cross-layer check: rust engine vs the AOT Pallas
//!                artifacts via PJRT.
//! * `service`  — run a job file of experiments through the multi-tenant
//!                solver service (one pool, ECM-cost placement onto cache
//!                groups, small-grid batching), each tenant verified.
//! * `machines` — list the modeled testbed.

use stencilwave::cli::Args;
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::affinity::PinPolicy;
use stencilwave::coordinator::service::ServiceConfig;
use stencilwave::figures;
use stencilwave::launcher;
use stencilwave::metrics;
#[cfg(feature = "xla")]
use stencilwave::runtime::{engine::validate, Manifest, Runtime};
use stencilwave::simulator::machine::MachineSpec;
use stencilwave::stencil::op::OpKind;
use stencilwave::stencil::streambench::stream_triad;
use stencilwave::Result;

const USAGE: &str = "\
stencilwave — multicore-aware wavefront parallelization for iterative
stencil computations (Treibig, Wellein, Hager 2010)

USAGE: stencilwave <COMMAND> [FLAGS]

COMMANDS:
  run        run one experiment
               --config <file> | --scheme <s> --n <N> --t <T> --groups <G>
               --iters <I> --op <o> --ranks <R> --machine <name>
               --pin <none|compact|scatter|smtpair> --smt --csv
               --priority <0..3> --deadline-ms <ms>  (service queueing
               keys; carried by the config/job-file round-trip and used
               when the config is submitted to the solver service)
               schemes: jacobi-baseline jacobi-wavefront jacobi-multigroup
                        jacobi-diamond gs-baseline gs-wavefront gs-multigroup
               ops:     laplace7 (paper 7-point) varcoeff (Helmholtz-style
                        coefficient grid) laplace13 (4th-order, radius 2)
                        fused7 (residual folded into the update sweep)
                        aniso7 (7-point star, per-axis coefficients)
               --pin places workers on cores (cache-group and SMT aware;
               from the Tab. 1 model when --machine names one, else from
               sysfs; Linux backend, no-op elsewhere)
               --smt co-schedules sibling hardware threads: with --pin none
               it implies the smtpair placement (adjacent workers share one
               core) and widens the modeled thread count
               --ranks shards the z axis across R halo-exchange-coupled
               rank sessions (deep 2R-per-sweep halos for the Jacobi
               family, per-sweep R halos for Gauss-Seidel)
  service    run a job file through the multi-tenant solver service
               --jobs <file> [--groups <G>] [--group-width <W>]
               [--machine <name>] [--max-batch <B>] [--csv]
               [--queue-capacity <N>] [--age-after <C>]
               the job file holds `run` config blocks separated by `---`
               lines; jobs are admitted onto cache-group windows by the
               ECM-cost placement model, identical small-grid jobs batch
               through one schedule, and every tenant's result is
               verified against its serial reference. Defaults to the
               host's cache-group shape (sysfs). Per-job `priority` and
               `deadline_ms` keys steer the scheduler: claiming runs
               high priority first, a full queue (--queue-capacity)
               rejects with a typed retry hint, an expired deadline
               sheds the job, and after --age-after passed-over claim
               cycles a starving job outranks everything younger
  figures    regenerate paper tables/figures
               [id|all] --out-dir <dir>
               ids: tab1 fig3a fig3b fig4a fig4b fig8 fig9 fig10 barrier
  stream     host STREAM triad + modeled Tab. 1
               --n <elements> --reps <R>
  validate   cross-layer validation vs AOT artifacts (needs --features xla)
               --artifact <name> --dir <artifacts-dir>
  machines   list the modeled testbed
";

fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "scheme", "op", "n", "t", "groups", "iters", "ranks", "machine", "csv", "smt",
        "pin", "priority", "deadline-ms",
    ])?;
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => {
            let n = args.get_usize("n", 64)?;
            RunConfig {
                scheme: Scheme::parse(args.get("scheme").unwrap_or("jacobi-wavefront"))?,
                size: (n, n, n),
                t: args.get_usize("t", 4)?,
                groups: args.get_usize("groups", 1)?,
                iters: args.get_usize("iters", 4)?,
                smt: args.get_bool("smt"),
                machine: args.get("machine").map(|s| s.to_string()),
                ..Default::default()
            }
        }
    };
    if let Some(op) = args.get("op") {
        // the flag overrides the config file's `op = "..."` key
        cfg.op = OpKind::parse(op)?;
    }
    if let Some(pin) = args.get("pin") {
        // the flag overrides the config file's `pin = "..."` key
        cfg.pin = PinPolicy::parse(pin)?;
    }
    if args.get("ranks").is_some() {
        // the flag overrides the config file's `ranks = N` key
        cfg.ranks = args.get_usize("ranks", 1)?;
    }
    if args.get("priority").is_some() {
        // the flag overrides the config file's `priority = N` key
        cfg.priority = args.get_usize("priority", 0)?;
    }
    if args.get("deadline-ms").is_some() {
        // the flag overrides the config file's `deadline_ms = N` key
        cfg.deadline_ms = Some(args.get_usize("deadline-ms", 0)? as u64);
    }
    let report = launcher::run_experiment(&cfg)?;
    if args.get_bool("csv") {
        print!("{}", launcher::to_csv(&[report]));
    } else {
        println!(
            "{:?} op={} {:?} iters={} t={} groups={}",
            report.scheme,
            report.op.as_str(),
            report.size,
            report.iters,
            report.t,
            report.groups
        );
        println!(
            "  host: {:.1} MLUP/s in {:.3}s  (verification max|diff| = {:.1e})",
            report.host_mlups, report.host_seconds, report.verification_diff
        );
        if let (Some(m), Some(p)) = (&report.machine, report.predicted_mlups) {
            println!("  model[{m}]: {p:.0} MLUP/s");
        }
        anyhow::ensure!(
            report.verification_diff == 0.0,
            "verification failed: schedules must be bit-exact"
        );
    }
    Ok(())
}

fn cmd_service(args: &Args) -> Result<()> {
    args.check_known(&[
        "jobs",
        "groups",
        "group-width",
        "machine",
        "max-batch",
        "csv",
        "queue-capacity",
        "age-after",
    ])?;
    let path = args
        .get("jobs")
        .ok_or_else(|| anyhow::anyhow!("service needs --jobs <file> (blocks separated by ---)"))?;
    let jobs = RunConfig::load_job_file(std::path::Path::new(path))?;
    anyhow::ensure!(!jobs.is_empty(), "job file '{path}' holds no jobs");
    let host = ServiceConfig::for_host();
    let svc_cfg = ServiceConfig {
        groups: args.get_usize("groups", host.groups)?,
        group_width: args.get_usize("group-width", host.group_width)?,
        machine: args.get("machine").map(|s| s.to_string()),
        max_batch: args.get_usize("max-batch", host.max_batch)?,
        queue_capacity: args.get_usize("queue-capacity", host.queue_capacity)?,
        age_after: args.get_usize("age-after", host.age_after as usize)? as u64,
        ..host
    };
    let report = launcher::run_service_jobs(svc_cfg, &jobs)?;
    if args.get_bool("csv") {
        // two CSV blocks, blank-line separated: per-job rows, then the
        // service-level admission/wait counters
        print!("{}", launcher::service_to_csv(&report));
        print!("\n{}", launcher::service_stats_to_csv(&report.stats));
    } else {
        for &(i, hint) in &report.rejected {
            println!("job {i:>3}: REJECTED queue full — retry in ~{hint:.3}s");
        }
        for &i in &report.shed {
            println!("job {i:>3}: EXPIRED before starting — shed past its deadline_ms");
        }
        for j in &report.jobs {
            println!(
                "job {:>3}: {:?} op={} {:?} iters={} prio={} -> groups {}..{} batch={} \
                 wait={:.1}ms max|diff|={:.1e}",
                j.job,
                j.scheme,
                j.op.as_str(),
                j.size,
                j.iters,
                j.priority,
                j.group_start,
                j.group_start + j.group_count,
                j.batch_size,
                j.wait_ms,
                j.verification_diff
            );
        }
        println!(
            "{} jobs in {:.3}s aggregate {:.1} MLUP/s ({} batched into {} windows, \
             {} shed expired, {} rejected full, peak queue {})",
            report.jobs.len(),
            report.seconds,
            report.throughput_mlups,
            report.stats.batched_jobs,
            report.stats.batches,
            report.stats.shed_expired,
            report.stats.rejected_full,
            report.stats.max_queue_depth
        );
    }
    let diverged = report.jobs.iter().filter(|j| j.verification_diff != 0.0).count();
    anyhow::ensure!(diverged == 0, "{diverged} tenant(s) diverged from the serial reference");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.check_known(&["out-dir"])?;
    let id = args.positional(0).unwrap_or("all");
    let ids: Vec<&str> =
        if id == "all" { figures::ALL_FIGURES.to_vec() } else { vec![id] };
    for id in ids {
        let text = figures::render(id).ok_or_else(|| {
            anyhow::anyhow!("unknown figure '{id}' (try: {:?})", figures::ALL_FIGURES)
        })?;
        match args.get("out-dir") {
            Some(dir) => {
                let dir = std::path::Path::new(dir);
                std::fs::create_dir_all(dir)?;
                let path = dir.join(format!("{id}.txt"));
                std::fs::write(&path, &text)?;
                println!("wrote {}", path.display());
            }
            None => println!("{text}"),
        }
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    args.check_known(&["n", "reps"])?;
    let n = args.get_usize("n", 1 << 22)?;
    let reps = args.get_usize("reps", 5)?;
    let (r, _) = metrics::timed(|| stream_triad(n, reps));
    println!(
        "host STREAM triad ({} MB working set): best {:.2} GB/s, mean {:.2} GB/s\n",
        r.bytes / (1 << 20),
        r.best_gbs,
        r.mean_gbs
    );
    println!("{}", figures::render("tab1").unwrap());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_validate(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "the 'validate' subcommand needs the PJRT runtime: rebuild with \
         `--features xla` (see rust/Cargo.toml for how to vendor xla-rs)"
    )
}

#[cfg(feature = "xla")]
fn cmd_validate(args: &Args) -> Result<()> {
    args.check_known(&["artifact", "dir"])?;
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let mut rt = Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let names: Vec<String> = match args.get("artifact") {
        Some(a) => vec![a.to_string()],
        None => rt
            .manifest()
            .artifacts
            .iter()
            .filter(|a| matches!(a.scheme(), Some("jacobi") | Some("gauss_seidel")))
            .map(|a| a.name.clone())
            .collect(),
    };
    let mut failures = 0;
    for name in names {
        let v = validate(&mut rt, &name)?;
        let status = if v.passed() { "OK " } else { "FAIL" };
        println!(
            "  [{status}] {:<36} max|rust - pallas| = {:.3e} (tol {:.1e})",
            v.artifact, v.max_abs_diff, v.tolerance
        );
        if !v.passed() {
            failures += 1;
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact(s) failed cross-layer validation");
    Ok(())
}

fn cmd_machines() -> Result<()> {
    for m in MachineSpec::testbed() {
        println!(
            "{:<12} {:<14} {} cores × {} SMT @ {:.2} GHz, OLC {} MB shared by {}, STREAM NT {:.1} GB/s",
            m.name,
            m.model,
            m.cores,
            m.smt_per_core,
            m.clock_ghz,
            m.olc_bytes() >> 20,
            m.cache_group_cores(),
            m.stream_socket_nt_gbs,
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = raw.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&raw[1..], &["csv", "smt"])?;
    match cmd {
        "run" => cmd_run(&args),
        "service" => cmd_service(&args),
        "figures" => cmd_figures(&args),
        "stream" => cmd_stream(&args),
        "validate" => cmd_validate(&args),
        "machines" => cmd_machines(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("{USAGE}");
            anyhow::bail!("unknown command '{other}'")
        }
    }
}
