//! Regenerators for every table and figure of the paper's evaluation.
//!
//! Each `figN*` function returns structured rows (testable) and the CLI
//! renders them as aligned text tables. Absolute MLUP/s live on the
//! simulator substrate (DESIGN.md §2), so what must match the paper is the
//! *shape*: who wins, by what factor, where the crossovers fall — asserted
//! in `rust/tests/figures.rs`.


use crate::simulator::ecm::{EcmModel, Kernel};
use crate::simulator::machine::MachineSpec;
use crate::simulator::memory::{Dataset, StoreMode};
use crate::simulator::perfmodel::{
    self, eq1_limit_mlups, BarrierKind, WavefrontParams,
};
use crate::simulator::stream;

/// The paper's serial-baseline domain sizes (Fig. 3 caption).
pub const CACHE_SIZE: (usize, usize, usize) = (100, 50, 50);
pub const MEMORY_SIZE: (usize, usize, usize) = (400, 200, 200);
/// Threaded-baseline reference size (Figs. 8–10 right axis).
pub const BASELINE_SIZE: (usize, usize, usize) = (200, 200, 200);
/// Problem-size sweep of the wavefront figures (cubic N³).
pub const SWEEP_SIZES: [usize; 8] = [120, 160, 200, 240, 280, 320, 360, 400];

/// One machine's row in a baseline figure.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub machine: String,
    pub c_cache: f64,
    pub c_memory: f64,
    pub opt_cache: f64,
    pub opt_memory: f64,
    /// Eq. (1) bandwidth ceiling (threaded figures only; 0 for serial).
    pub eq1_limit: f64,
}

/// One point of a wavefront sweep figure.
#[derive(Clone, Debug)]
pub struct WavefrontPoint {
    pub machine: String,
    pub n: usize,
    pub wavefront_mlups: f64,
    pub baseline_mlups: f64,
    pub speedup: f64,
    pub blocking_factor: usize,
}

/// Tab. 1 — machine specs and STREAM bandwidths.
pub fn tab1() -> Vec<stream::StreamRow> {
    stream::tab1_rows()
}

/// Fig. 3(a) — serial Jacobi, C vs optimized kernel, cache vs memory.
pub fn fig3a() -> Vec<BaselineRow> {
    MachineSpec::testbed()
        .into_iter()
        .map(|m| {
            let e = EcmModel::new(m.clone());
            BaselineRow {
                machine: m.name,
                c_cache: e.serial(Kernel::JacobiC, Dataset::Cache, StoreMode::WriteAllocate),
                c_memory: e.serial(Kernel::JacobiC, Dataset::Memory, StoreMode::WriteAllocate),
                opt_cache: e.serial(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal),
                opt_memory: e.serial(Kernel::JacobiOpt, Dataset::Memory, StoreMode::NonTemporal),
                eq1_limit: 0.0,
            }
        })
        .collect()
}

/// Fig. 3(b) — threaded socket Jacobi vs the Eq. (1) limit.
pub fn fig3b() -> Vec<BaselineRow> {
    MachineSpec::testbed()
        .into_iter()
        .map(|m| {
            let e = EcmModel::new(m.clone());
            let n = m.cores;
            BaselineRow {
                eq1_limit: eq1_limit_mlups(&m, Kernel::JacobiOpt),
                c_cache: e.socket(Kernel::JacobiC, Dataset::Cache, StoreMode::WriteAllocate, n, false).mlups,
                c_memory: e.socket(Kernel::JacobiC, Dataset::Memory, StoreMode::WriteAllocate, n, false).mlups,
                opt_cache: e.socket(Kernel::JacobiOpt, Dataset::Cache, StoreMode::NonTemporal, n, false).mlups,
                opt_memory: e.socket(Kernel::JacobiOpt, Dataset::Memory, StoreMode::NonTemporal, n, false).mlups,
                machine: m.name,
            }
        })
        .collect()
}

/// Fig. 4(a) — serial Gauss-Seidel (C without the dependency optimization).
pub fn fig4a() -> Vec<BaselineRow> {
    MachineSpec::testbed()
        .into_iter()
        .map(|m| {
            let e = EcmModel::new(m.clone());
            BaselineRow {
                machine: m.name,
                c_cache: e.serial(Kernel::GsC, Dataset::Cache, StoreMode::WriteAllocate),
                c_memory: e.serial(Kernel::GsC, Dataset::Memory, StoreMode::WriteAllocate),
                opt_cache: e.serial(Kernel::GsOpt, Dataset::Cache, StoreMode::WriteAllocate),
                opt_memory: e.serial(Kernel::GsOpt, Dataset::Memory, StoreMode::WriteAllocate),
                eq1_limit: 0.0,
            }
        })
        .collect()
}

/// Fig. 4(b) — threaded pipeline-parallel GS vs the noNT Eq. (1) limit.
pub fn fig4b() -> Vec<BaselineRow> {
    MachineSpec::testbed()
        .into_iter()
        .map(|m| {
            let e = EcmModel::new(m.clone());
            let n = m.cores;
            BaselineRow {
                eq1_limit: eq1_limit_mlups(&m, Kernel::GsOpt),
                c_cache: e.socket(Kernel::GsC, Dataset::Cache, StoreMode::WriteAllocate, n, false).mlups,
                c_memory: e.socket(Kernel::GsC, Dataset::Memory, StoreMode::WriteAllocate, n, false).mlups,
                opt_cache: e.socket(Kernel::GsOpt, Dataset::Cache, StoreMode::WriteAllocate, n, false).mlups,
                opt_memory: e.socket(Kernel::GsOpt, Dataset::Memory, StoreMode::WriteAllocate, n, false).mlups,
                machine: m.name,
            }
        })
        .collect()
}

fn wavefront_sweep(kernel: Kernel, smt: bool) -> Vec<WavefrontPoint> {
    let mut out = Vec::new();
    for m in MachineSpec::testbed() {
        if smt && m.smt_per_core < 2 {
            continue; // Fig. 10 has no Core 2 / Istanbul SMT curves
        }
        let params = WavefrontParams::standard(&m, kernel, smt);
        let store = if kernel.is_gs() { StoreMode::WriteAllocate } else { StoreMode::NonTemporal };
        let base = perfmodel::baseline_threaded(&m, kernel, store).mlups;
        for n in SWEEP_SIZES {
            let p = perfmodel::wavefront_prediction(&m, &params, (n, n, n));
            out.push(WavefrontPoint {
                machine: m.name.clone(),
                n,
                wavefront_mlups: p.mlups,
                baseline_mlups: base,
                speedup: p.mlups / base,
                blocking_factor: params.t,
            });
        }
    }
    out
}

/// Fig. 8 — Jacobi wavefront blocking vs problem size, all machines.
pub fn fig8() -> Vec<WavefrontPoint> {
    wavefront_sweep(Kernel::JacobiOpt, false)
}

/// Fig. 9 — Gauss-Seidel wavefront blocking vs problem size.
pub fn fig9() -> Vec<WavefrontPoint> {
    wavefront_sweep(Kernel::GsOpt, false)
}

/// Fig. 10 — Gauss-Seidel wavefront with SMT (Nehalem machines only).
pub fn fig10() -> Vec<WavefrontPoint> {
    wavefront_sweep(Kernel::GsOpt, true)
}

/// Barrier-cost ablation (Sec. 4's synchronization discussion).
#[derive(Clone, Debug)]
pub struct BarrierRow {
    pub threads: usize,
    pub pthread_cycles: f64,
    pub spin_cycles: f64,
    pub tree_cycles: f64,
    pub spin_cycles_smt: f64,
    pub tree_cycles_smt: f64,
}

pub fn barrier_table() -> Vec<BarrierRow> {
    [2usize, 4, 6, 8, 12, 16]
        .into_iter()
        .map(|t| BarrierRow {
            threads: t,
            pthread_cycles: BarrierKind::Pthread.cycles(t, false),
            spin_cycles: BarrierKind::Spin.cycles(t, false),
            tree_cycles: BarrierKind::Tree.cycles(t, false),
            spin_cycles_smt: BarrierKind::Spin.cycles(t, true),
            tree_cycles_smt: BarrierKind::Tree.cycles(t, true),
        })
        .collect()
}

// ---------------------------------------------------------------- rendering

fn fmt_row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}"))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Render a baseline figure as an aligned text table.
pub fn render_baseline(title: &str, rows: &[BaselineRow], threaded: bool) -> String {
    let mut out = format!("## {title}\n\n");
    let mut header = vec![
        "machine".to_string(),
        "C cache".into(),
        "C memory".into(),
        "opt cache".into(),
        "opt memory".into(),
    ];
    if threaded {
        header.push("Eq.(1) limit".into());
    }
    let widths: Vec<usize> = header.iter().map(|h| h.len().max(12)).collect();
    out += &fmt_row(&header, &widths);
    out.push('\n');
    for r in rows {
        let mut cells = vec![
            r.machine.clone(),
            format!("{:.0}", r.c_cache),
            format!("{:.0}", r.c_memory),
            format!("{:.0}", r.opt_cache),
            format!("{:.0}", r.opt_memory),
        ];
        if threaded {
            cells.push(format!("{:.0}", r.eq1_limit));
        }
        out += &fmt_row(&cells, &widths);
        out.push('\n');
    }
    out += "\n(all values in MLUP/s)\n";
    out
}

/// Render a wavefront sweep figure.
pub fn render_wavefront(title: &str, points: &[WavefrontPoint]) -> String {
    let mut out = format!("## {title}\n\n");
    let header: Vec<String> = ["machine", "N", "t", "wavefront", "baseline", "speedup"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let widths = [12usize, 5, 3, 12, 12, 8];
    out += &fmt_row(&header, &widths);
    out.push('\n');
    for p in points {
        out += &fmt_row(
            &[
                p.machine.clone(),
                p.n.to_string(),
                p.blocking_factor.to_string(),
                format!("{:.0}", p.wavefront_mlups),
                format!("{:.0}", p.baseline_mlups),
                format!("{:.2}x", p.speedup),
            ],
            &widths,
        );
        out.push('\n');
    }
    out += "\n(MLUP/s; baseline = threaded 200^3 without temporal blocking)\n";
    out
}

/// Render Tab. 1.
pub fn render_tab1(rows: &[stream::StreamRow]) -> String {
    let mut out = String::from("## Tab. 1 — testbed bandwidths (modeled)\n\n");
    let header: Vec<String> =
        ["machine", "theoretical", "STREAM 1T", "socket NT", "socket noNT", "NT eff"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let widths = [12usize, 12, 12, 12, 12, 8];
    out += &fmt_row(&header, &widths);
    out.push('\n');
    for r in rows {
        out += &fmt_row(
            &[
                r.machine.clone(),
                format!("{:.1}", r.bw_theoretical_gbs),
                format!("{:.1}", r.stream_1t_gbs),
                format!("{:.1}", r.stream_socket_nt_gbs),
                format!("{:.1}", r.stream_socket_nont_gbs),
                format!("{:.0}%", r.nt_efficiency * 100.0),
            ],
            &widths,
        );
        out.push('\n');
    }
    out += "\n(GB/s; noNT row counts write-allocate bus traffic, as in the paper)\n";
    out
}

/// Render the barrier ablation.
pub fn render_barriers(rows: &[BarrierRow]) -> String {
    let mut out = String::from("## Barrier cost model (cycles per synchronization)\n\n");
    let header: Vec<String> =
        ["threads", "pthread", "spin", "tree", "spin+SMT", "tree+SMT"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let widths = [8usize; 6];
    out += &fmt_row(&header, &widths.to_vec());
    out.push('\n');
    for r in rows {
        out += &fmt_row(
            &[
                r.threads.to_string(),
                format!("{:.0}", r.pthread_cycles),
                format!("{:.0}", r.spin_cycles),
                format!("{:.0}", r.tree_cycles),
                format!("{:.0}", r.spin_cycles_smt),
                format!("{:.0}", r.tree_cycles_smt),
            ],
            &widths.to_vec(),
        );
        out.push('\n');
    }
    out
}

/// Render any figure by id ("tab1", "fig3a", … "fig10", "barrier").
pub fn render(id: &str) -> Option<String> {
    Some(match id {
        "tab1" => render_tab1(&tab1()),
        "fig3a" => render_baseline("Fig. 3(a) — serial Jacobi baseline", &fig3a(), false),
        "fig3b" => render_baseline("Fig. 3(b) — threaded socket Jacobi", &fig3b(), true),
        "fig4a" => render_baseline("Fig. 4(a) — serial Gauss-Seidel baseline", &fig4a(), false),
        "fig4b" => render_baseline("Fig. 4(b) — threaded pipelined Gauss-Seidel", &fig4b(), true),
        "fig8" => render_wavefront("Fig. 8 — Jacobi wavefront temporal blocking", &fig8()),
        "fig9" => render_wavefront("Fig. 9 — Gauss-Seidel wavefront temporal blocking", &fig9()),
        "fig10" => render_wavefront("Fig. 10 — Gauss-Seidel wavefront with SMT", &fig10()),
        "barrier" => render_barriers(&barrier_table()),
        _ => return None,
    })
}

/// Every figure id in paper order.
pub const ALL_FIGURES: [&str; 9] =
    ["tab1", "fig3a", "fig3b", "fig4a", "fig4b", "fig8", "fig9", "fig10", "barrier"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render() {
        for id in ALL_FIGURES {
            let text = render(id).unwrap();
            assert!(text.len() > 100, "{id} too short");
        }
        assert!(render("fig99").is_none());
    }

    #[test]
    fn fig3a_has_five_machines() {
        assert_eq!(fig3a().len(), 5);
        assert_eq!(fig4b().len(), 5);
    }

    #[test]
    fn fig10_excludes_non_smt_machines() {
        let pts = fig10();
        assert!(pts.iter().all(|p| p.machine != "Core 2" && p.machine != "Istanbul"));
        assert_eq!(pts.len(), 3 * SWEEP_SIZES.len());
    }

    #[test]
    fn sweeps_cover_all_sizes() {
        let pts = fig8();
        assert_eq!(pts.len(), 5 * SWEEP_SIZES.len());
        for p in &pts {
            assert!(p.wavefront_mlups > 0.0);
            assert!(p.speedup > 0.5, "{}: {}", p.machine, p.speedup);
        }
    }
}
