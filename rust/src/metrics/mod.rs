//! Measurement utilities: the MLUP/s metric, timers, simple statistics.
//!
//! The paper reports lattice-site updates per second (LUP/s, Sec. 3);
//! every bench and example funnels through [`mlups`] so the unit is
//! consistent across real runs and simulator predictions.

use std::time::{Duration, Instant};

/// Million lattice-site updates per second.
pub fn mlups(updates: u64, elapsed: Duration) -> f64 {
    updates as f64 / elapsed.as_secs_f64() / 1e6
}

/// Time a closure, returning `(result, elapsed)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Run `f` `reps` times, returning the minimum elapsed time (STREAM-style
/// best-of-N, robust against scheduler noise on a busy box).
pub fn best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, Duration) {
    assert!(reps >= 1);
    let (mut out, mut best) = timed(&mut f);
    for _ in 1..reps {
        let (r, dt) = timed(&mut f);
        if dt < best {
            best = dt;
            out = r;
        }
    }
    (out, best)
}

/// Online mean/min/max accumulator for series reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn push(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlups_arithmetic() {
        let p = mlups(2_000_000, Duration::from_secs(2));
        assert!((p - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = Stats::default();
        for v in [2.0, 4.0, 6.0] {
            s.push(v);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 6.0);
        assert!((s.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn best_of_returns_min() {
        let mut calls = 0;
        let (_, dt) = best_of(3, || {
            calls += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(calls, 3);
        assert!(dt >= Duration::from_millis(1));
    }
}
