//! Experiment configuration: file-loadable run descriptions.
//!
//! A [`RunConfig`] fully determines one experiment — scheme, kernel,
//! problem size, wavefront parameters, target machine model — so every
//! figure regeneration and every CLI invocation is reproducible from a
//! file. The format is a TOML-compatible `key = value` subset parsed
//! in-tree (offline build: no external parser crates); `configs/` ships
//! the paper's standard setups.

pub mod json;

use crate::coordinator::affinity::PinPolicy;
use crate::simulator::ecm::Kernel;
use crate::simulator::machine::MachineSpec;
use crate::simulator::memory::StoreMode;
use crate::simulator::perfmodel::BarrierKind;
use crate::stencil::gauss_seidel::GsKernel;
use crate::stencil::op::OpKind;
use crate::Result;

/// Which algorithm family a run exercises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Plain (threaded) Jacobi baseline.
    JacobiBaseline,
    /// Wavefront temporally-blocked Jacobi (Sec. 4, Fig. 6).
    JacobiWavefront,
    /// Pipeline-parallel Gauss-Seidel baseline (Fig. 5a).
    GsBaseline,
    /// Wavefront temporally-blocked Gauss-Seidel (Fig. 5b).
    GsWavefront,
    /// Multi-group spatial × temporal blocked Jacobi (Fig. 7 at scale):
    /// `groups` thread groups each wavefront-sweep one y-block.
    JacobiMultiGroup,
    /// Multi-group spatial × temporal blocked Gauss-Seidel: `groups`
    /// thread groups each run a pipelined GS wavefront (Fig. 5b) over
    /// one y-block of the Fig. 7 decomposition, handing `R`-line
    /// interface boundary arrays to the left neighbor under round-lag
    /// flow control.
    GsMultiGroup,
    /// Diamond-tile temporally blocked Jacobi (Malas/Hager et al.,
    /// arXiv:1410.3060 adapted to this pool): shrinking/growing y tiles
    /// that exactly tile the interior at every temporal level, swept by
    /// a z wavefront — no per-seam boundary arrays and no per-block
    /// pipeline wind-up, at the price of a wider block requirement
    /// (`2R·(t-1)` interior lines per tile interval).
    JacobiDiamond,
}

impl Scheme {
    /// Every registered scheme (mirrors [`OpKind::ALL`]) — the single
    /// list the tests and sweeps iterate, so a new scheme cannot be
    /// silently missing from coverage.
    pub const ALL: [Scheme; 7] = [
        Scheme::JacobiBaseline,
        Scheme::JacobiWavefront,
        Scheme::JacobiMultiGroup,
        Scheme::JacobiDiamond,
        Scheme::GsBaseline,
        Scheme::GsWavefront,
        Scheme::GsMultiGroup,
    ];

    pub fn is_gs(self) -> bool {
        matches!(self, Scheme::GsBaseline | Scheme::GsWavefront | Scheme::GsMultiGroup)
    }

    /// The config/CLI name of the scheme (the `scheme = "..."` key).
    pub fn as_str(self) -> &'static str {
        match self {
            Scheme::JacobiBaseline => "jacobi_baseline",
            Scheme::JacobiWavefront => "jacobi_wavefront",
            Scheme::JacobiMultiGroup => "jacobi_multigroup",
            Scheme::JacobiDiamond => "jacobi_diamond",
            Scheme::GsBaseline => "gs_baseline",
            Scheme::GsWavefront => "gs_wavefront",
            Scheme::GsMultiGroup => "gs_multigroup",
        }
    }

    pub fn kernel(self, optimized: bool) -> Kernel {
        match (self.is_gs(), optimized) {
            (false, true) => Kernel::JacobiOpt,
            (false, false) => Kernel::JacobiC,
            (true, true) => Kernel::GsOpt,
            (true, false) => Kernel::GsC,
        }
    }

    /// Parse `jacobi_wavefront` / `jacobi-wavefront` style names.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().replace('-', "_").as_str() {
            "jacobi_baseline" => Scheme::JacobiBaseline,
            "jacobi_wavefront" => Scheme::JacobiWavefront,
            "jacobi_multigroup" => Scheme::JacobiMultiGroup,
            "jacobi_diamond" => Scheme::JacobiDiamond,
            "gs_baseline" => Scheme::GsBaseline,
            "gs_wavefront" => Scheme::GsWavefront,
            "gs_multigroup" => Scheme::GsMultiGroup,
            other => anyhow::bail!("unknown scheme '{other}'"),
        })
    }
}

/// Typed validation error for the multi-group schemes' per-block width
/// requirement — the one decomposition constraint a grid can violate.
///
/// The out-of-place Jacobi decomposition needs `2R` interior lines per
/// block (the serial forwarding pass for narrower blocks has no sound
/// one-round-lag analog); the in-place GS decomposition only needs the
/// `R`-line halo per block (the restriction is *lifted* to `R`: all
/// levels live in one array, so no forwarded lines exist); the diamond
/// decomposition needs `2R·(t-1)` lines per tile interval so that two
/// growing tiles at adjacent seams never overlap at the deepest
/// temporal level. Callers that want to branch on this failure
/// downcast the [`anyhow::Error`]:
///
/// ```
/// use stencilwave::config::{BlockWidthError, RunConfig, Scheme};
/// let cfg = RunConfig {
///     scheme: Scheme::JacobiMultiGroup, size: (16, 8, 16), groups: 4,
///     ..Default::default()
/// };
/// let err = cfg.validate().unwrap_err();
/// assert!(err.downcast_ref::<BlockWidthError>().is_some());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockWidthError {
    /// Scheme that rejected the decomposition.
    pub scheme: Scheme,
    /// Halo radius of the configured op.
    pub radius: usize,
    /// y extent of the grid.
    pub ny: usize,
    /// Requested group (= y-block) count.
    pub groups: usize,
    /// Interior lines the grid offers (`ny - 2R`).
    pub interior: usize,
    /// Interior lines every block must hold for this scheme.
    pub required: usize,
}

impl BlockWidthError {
    /// Interior lines per block `scheme` requires for halo radius
    /// `radius` and temporal depth `t` (0 for schemes without a block
    /// decomposition). Only the diamond rule depends on `t`: its
    /// growing seam tiles reach `R·(t-1)` lines into each neighboring
    /// interval.
    pub fn required_lines(scheme: Scheme, radius: usize, t: usize) -> usize {
        match scheme {
            Scheme::JacobiMultiGroup => 2 * radius,
            Scheme::JacobiDiamond => 2 * radius * t.saturating_sub(1),
            Scheme::GsMultiGroup => radius,
            _ => 0,
        }
    }

    /// Check the width requirement of `scheme` on a grid of y extent
    /// `ny` split into `groups` blocks — the single source every entry
    /// point (config validation and the schedule constructors) uses, so
    /// the error is identical wherever it surfaces.
    pub fn check(scheme: Scheme, radius: usize, ny: usize, groups: usize, t: usize) -> Result<()> {
        let required = Self::required_lines(scheme, radius, t);
        let interior = ny.saturating_sub(2 * radius);
        if required == 0 || groups <= 1 || interior >= required * groups {
            return Ok(());
        }
        Err(anyhow::Error::new(BlockWidthError {
            scheme,
            radius,
            ny,
            groups,
            interior,
            required,
        }))
    }
}

impl std::fmt::Display for BlockWidthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} needs >= {} interior lines per block for a radius-{} op \
             (ny = {} gives {} interior lines for {} groups)",
            self.scheme.as_str(),
            self.required,
            self.radius,
            self.ny,
            self.interior,
            self.groups
        )
    }
}

impl std::error::Error for BlockWidthError {}

/// Typed validation error for the rank decomposition's per-rank depth
/// requirement (the z-axis analog of [`BlockWidthError`]).
///
/// Every rank must own at least one full halo depth of z planes,
/// otherwise a neighbor's ghost region would reach *through* the rank
/// into the next subdomain and the exchange protocol could not close
/// over nearest neighbors. The depth follows the halo-depth rule (see
/// [`RunConfig::halo_depth`]): `t·R` per side for the temporally
/// blocked Jacobi family, `R` for per-sweep exchanges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankWidthError {
    /// Scheme that rejected the decomposition.
    pub scheme: Scheme,
    /// Halo radius of the configured op.
    pub radius: usize,
    /// z extent of the grid.
    pub nz: usize,
    /// Requested rank count.
    pub ranks: usize,
    /// Interior planes the grid offers (`nz - 2R`).
    pub interior: usize,
    /// Interior planes every rank must own (= the exchange halo depth).
    pub required: usize,
}

impl RankWidthError {
    /// Check that an `nz`-plane grid split into `ranks` z shards gives
    /// every rank at least `depth` owned planes — the single source
    /// used by [`RunConfig::validate`] and `RankSet::new`.
    pub fn check(scheme: Scheme, radius: usize, depth: usize, nz: usize, ranks: usize) -> Result<()> {
        let interior = nz.saturating_sub(2 * radius);
        if ranks <= 1 || interior >= depth * ranks {
            return Ok(());
        }
        Err(anyhow::Error::new(RankWidthError {
            scheme,
            radius,
            nz,
            ranks,
            interior,
            required: depth,
        }))
    }
}

impl std::fmt::Display for RankWidthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} needs >= {} interior z planes per rank for a radius-{} op \
             (nz = {} gives {} interior planes for {} ranks)",
            self.scheme.as_str(),
            self.required,
            self.radius,
            self.nz,
            self.interior,
            self.ranks
        )
    }
}

impl std::error::Error for RankWidthError {}

/// Distinct scheduling priority levels a job may request
/// (`priority = 0..PRIORITY_LEVELS`). Kept small and fixed so the
/// solver service can hold one ready list per level; 0 is the default
/// (lowest) urgency.
pub const PRIORITY_LEVELS: usize = 4;

/// One experiment description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub scheme: Scheme,
    /// Stencil operator the scheme applies (`op` config key / `--op`).
    pub op: OpKind,
    /// Problem size (nz, ny, nx).
    pub size: (usize, usize, usize),
    /// Temporal blocking factor t (threads per group).
    pub t: usize,
    /// Number of thread groups.
    pub groups: usize,
    /// Updates to perform in total (multiple of t for wavefront Jacobi).
    pub iters: usize,
    /// Use SMT hardware threads: widens the modeled thread count *and*,
    /// with `pin = "none"`, promotes the placement to
    /// [`PinPolicy::SmtPair`] so co-scheduled workers really share a
    /// core (Sec. 6).
    pub smt: bool,
    pub optimized_kernel: bool,
    /// Stream the stores no schedule re-reads within a pass
    /// (`movntpd`-style, skipping the write-allocate). Selects both the
    /// ECM model's Eq. (1) traffic accounting *and* the executed kernel
    /// code path — see [`RunConfig::store_mode`]. GS schemes update in
    /// place and always write-allocate.
    pub nt_stores: bool,
    pub barrier: BarrierKind,
    /// Machine model to predict on (`None` = host execution only).
    pub machine: Option<String>,
    /// Core-pinning policy for the worker team (cache-group and SMT
    /// aware; cache groups come from the Tab. 1 model when `machine`
    /// names one, else from the host's sysfs).
    pub pin: PinPolicy,
    /// Number of z-axis rank shards (`ranks` key / `--ranks`). 1 runs
    /// the plain single-rank `Solver`; larger counts run a
    /// `coordinator::rank::RankSet` of per-rank solvers coupled by
    /// halo exchange over a `comm::Transport`.
    pub ranks: usize,
    /// Scheduling priority when this run is submitted to the solver
    /// service (`priority` key / `--priority`): `0` (default, lowest)
    /// to [`PRIORITY_LEVELS`]` - 1`. Higher levels are claimed first;
    /// single-run execution ignores it.
    pub priority: usize,
    /// Admission deadline when this run is submitted to the solver
    /// service (`deadline_ms` key / `--deadline-ms`): if the job has
    /// not *started* within this many milliseconds of submission it is
    /// shed with a typed `Expired` result instead of running late.
    /// `None` (the default) never expires; single-run execution
    /// ignores it.
    pub deadline_ms: Option<u64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            scheme: Scheme::JacobiWavefront,
            op: OpKind::ConstLaplace7,
            size: (64, 64, 64),
            t: 4,
            groups: 1,
            iters: 4,
            smt: false,
            optimized_kernel: true,
            nt_stores: true,
            barrier: BarrierKind::Spin,
            machine: None,
            pin: PinPolicy::None,
            ranks: 1,
            priority: 0,
            deadline_ms: None,
        }
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" => Ok(true),
        "false" => Ok(false),
        other => anyhow::bail!("expected true/false, got '{other}'"),
    }
}

impl RunConfig {
    /// The Gauss-Seidel line kernel the `optimized_kernel` flag selects.
    pub fn gs_kernel(&self) -> GsKernel {
        if self.optimized_kernel {
            GsKernel::Interleaved
        } else {
            GsKernel::Naive
        }
    }

    /// The store mode `nt_stores` selects for this scheme — consumed by
    /// both the performance model and the executed kernels (the same
    /// key describes predicted and real traffic). Gauss-Seidel updates
    /// in place (its writes are re-read as left neighbors), so NT
    /// stores never apply there.
    pub fn store_mode(&self) -> StoreMode {
        if self.nt_stores && !self.scheme.is_gs() {
            StoreMode::NonTemporal
        } else {
            StoreMode::WriteAllocate
        }
    }

    pub fn machine_spec(&self) -> Option<MachineSpec> {
        self.machine.as_deref().and_then(MachineSpec::by_name)
    }

    /// Sweeps one rank advances between two halo exchanges (one
    /// *temporal block*): `t` for the temporally blocked Jacobi family
    /// (the wavefront must run whole `t`-deep blocks), 1 for the
    /// per-sweep schemes. The in-place GS family always exchanges per
    /// sweep: its lexicographic new-value recursion would propagate a
    /// stale deep-halo edge through the entire subdomain, so deep
    /// halos are unsound there (see the README halo-depth rule).
    pub fn rank_step(&self) -> usize {
        match self.scheme {
            Scheme::JacobiWavefront | Scheme::JacobiMultiGroup | Scheme::JacobiDiamond => self.t,
            _ => 1,
        }
    }

    /// Ghost planes per rank interface side — the halo-depth rule:
    /// `rank_step · R` for the out-of-place Jacobi family (a `t`-sweep
    /// temporal block consumes `t·R` planes of redundantly recomputed
    /// trapezoid overlap, i.e. `2R` per interface per sweep counting
    /// both directions), plain `R` for the per-sweep in-place GS
    /// exchange.
    pub fn halo_depth(&self) -> usize {
        if self.scheme.is_gs() {
            self.op.radius()
        } else {
            self.rank_step() * self.op.radius()
        }
    }

    /// Parse the `key = value` config format:
    ///
    /// ```text
    /// scheme = "jacobi_wavefront"   # comments allowed
    /// size = [64, 64, 64]
    /// t = 4
    /// smt = false
    /// machine = "Nehalem EX"
    /// ```
    pub fn from_text(text: &str) -> Result<Self> {
        let mut cfg = RunConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim().trim_matches('"');
            match key {
                "scheme" => cfg.scheme = Scheme::parse(value)?,
                "op" => {
                    cfg.op = OpKind::parse(value)
                        .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?
                }
                "size" => {
                    let nums: Vec<usize> = value
                        .trim_start_matches('[')
                        .trim_end_matches(']')
                        .split(',')
                        .map(|s| s.trim().parse::<usize>())
                        .collect::<std::result::Result<_, _>>()
                        .map_err(|e| anyhow::anyhow!("line {}: bad size: {e}", lineno + 1))?;
                    anyhow::ensure!(nums.len() == 3, "line {}: size needs 3 dims", lineno + 1);
                    cfg.size = (nums[0], nums[1], nums[2]);
                }
                "t" => cfg.t = value.parse()?,
                "groups" => cfg.groups = value.parse()?,
                "iters" => cfg.iters = value.parse()?,
                "smt" => cfg.smt = parse_bool(value)?,
                "optimized_kernel" => cfg.optimized_kernel = parse_bool(value)?,
                "nt_stores" => cfg.nt_stores = parse_bool(value)?,
                "barrier" => {
                    cfg.barrier = match value {
                        "spin" => BarrierKind::Spin,
                        "tree" => BarrierKind::Tree,
                        "pthread" => BarrierKind::Pthread,
                        other => anyhow::bail!("line {}: unknown barrier '{other}'", lineno + 1),
                    }
                }
                "ranks" => cfg.ranks = value.parse()?,
                "priority" => cfg.priority = value.parse()?,
                "deadline_ms" => cfg.deadline_ms = Some(value.parse()?),
                "machine" => cfg.machine = Some(value.to_string()),
                "pin" => {
                    cfg.pin = PinPolicy::parse(value)
                        .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?
                }
                other => anyhow::bail!("line {}: unknown key '{other}'", lineno + 1),
            }
        }
        Ok(cfg)
    }

    /// Load from a config file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_text(&std::fs::read_to_string(path)?)
    }

    /// Parse a *job file*: multiple [`RunConfig`] blocks in the same
    /// `key = value` format, separated by `---` lines — the input format
    /// of the multi-tenant solver service (`stencilwave service`). Blank
    /// blocks (leading/trailing separators, `---` runs) are skipped;
    /// parse errors carry the 1-based block number.
    ///
    /// ```text
    /// scheme = "jacobi_wavefront"
    /// size = [64, 64, 64]
    /// ---
    /// scheme = "gs_multigroup"
    /// groups = 2
    /// ```
    pub fn from_job_text(text: &str) -> Result<Vec<Self>> {
        let mut jobs = Vec::new();
        let mut block = String::new();
        let mut blockno = 0usize;
        let mut flush = |block: &mut String, blockno: &mut usize| -> Result<()> {
            if block.lines().all(|l| l.split('#').next().unwrap_or("").trim().is_empty()) {
                block.clear();
                return Ok(());
            }
            *blockno += 1;
            let cfg = Self::from_text(block)
                .map_err(|e| anyhow::anyhow!("job {}: {e}", *blockno))?;
            block.clear();
            jobs.push(cfg);
            Ok(())
        };
        for line in text.lines() {
            if line.trim() == "---" {
                flush(&mut block, &mut blockno)?;
            } else {
                block.push_str(line);
                block.push('\n');
            }
        }
        flush(&mut block, &mut blockno)?;
        Ok(jobs)
    }

    /// [`RunConfig::from_job_text`] from a file on disk.
    pub fn load_job_file(path: &std::path::Path) -> Result<Vec<Self>> {
        Self::from_job_text(&std::fs::read_to_string(path)?)
    }

    /// Serialize back to the config format.
    pub fn to_text(&self) -> String {
        let scheme = self.scheme.as_str();
        let barrier = match self.barrier {
            BarrierKind::Spin => "spin",
            BarrierKind::Tree => "tree",
            BarrierKind::Pthread => "pthread",
        };
        let mut s = format!(
            "scheme = \"{scheme}\"\nop = \"{}\"\nsize = [{}, {}, {}]\nt = {}\ngroups = {}\n\
             iters = {}\nsmt = {}\noptimized_kernel = {}\nnt_stores = {}\nbarrier = \"{barrier}\"\n\
             pin = \"{}\"\nranks = {}\npriority = {}\n",
            self.op.as_str(),
            self.size.0,
            self.size.1,
            self.size.2,
            self.t,
            self.groups,
            self.iters,
            self.smt,
            self.optimized_kernel,
            self.nt_stores,
            self.pin.as_str(),
            self.ranks,
            self.priority,
        );
        if let Some(d) = self.deadline_ms {
            s += &format!("deadline_ms = {d}\n");
        }
        if let Some(m) = &self.machine {
            s += &format!("machine = \"{m}\"\n");
        }
        s
    }

    /// Validate internal consistency (op-radius aware: minimum grid
    /// extent and multi-group block width scale with the halo).
    pub fn validate(&self) -> Result<()> {
        let (nz, ny, nx) = self.size;
        let r = self.op.radius();
        let min = 2 * r + 1;
        anyhow::ensure!(
            nz >= min && ny >= min && nx >= min,
            "grid too small for a radius-{r} op: {:?} (need >= {min} per dim)",
            self.size
        );
        anyhow::ensure!(self.t >= 1, "blocking factor must be >= 1");
        anyhow::ensure!(self.groups >= 1, "need at least one thread group");
        if matches!(
            self.scheme,
            Scheme::JacobiWavefront | Scheme::JacobiMultiGroup | Scheme::JacobiDiamond
        ) {
            anyhow::ensure!(self.t % 2 == 0, "wavefront Jacobi needs even t (in-place tmp scheme)");
            anyhow::ensure!(
                self.iters % self.t == 0,
                "iters ({}) must be a multiple of t ({})",
                self.iters,
                self.t
            );
        }
        BlockWidthError::check(self.scheme, r, ny, self.groups, self.t)?;
        anyhow::ensure!(self.ranks >= 1, "need at least one rank");
        anyhow::ensure!(
            self.priority < PRIORITY_LEVELS,
            "priority {} out of range (levels are 0..{})",
            self.priority,
            PRIORITY_LEVELS
        );
        RankWidthError::check(self.scheme, r, self.halo_depth(), nz, self.ranks)?;
        if let Some(name) = &self.machine {
            anyhow::ensure!(MachineSpec::by_name(name).is_some(), "unknown machine '{name}'");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let cfg = RunConfig {
            scheme: Scheme::GsWavefront,
            op: OpKind::VarCoeff7,
            size: (40, 50, 60),
            t: 6,
            groups: 2,
            iters: 12,
            smt: true,
            optimized_kernel: false,
            nt_stores: false,
            barrier: BarrierKind::Tree,
            machine: Some("Westmere".into()),
            pin: PinPolicy::Scatter,
            ranks: 2,
            priority: 2,
            deadline_ms: Some(1500),
        };
        let back = RunConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.size, cfg.size);
        assert_eq!(back.scheme, cfg.scheme);
        assert_eq!(back.op, OpKind::VarCoeff7);
        assert_eq!(back.t, 6);
        assert!(back.smt);
        assert!(!back.optimized_kernel);
        assert_eq!(back.barrier, BarrierKind::Tree);
        assert_eq!(back.machine.as_deref(), Some("Westmere"));
        assert_eq!(back.pin, PinPolicy::Scatter);
        assert_eq!(back.ranks, 2);
        assert_eq!(back.priority, 2);
        assert_eq!(back.deadline_ms, Some(1500));
        back.validate().unwrap();
    }

    #[test]
    fn priority_and_deadline_keys_roundtrip_and_validate() {
        // unparsed configs default to lowest priority, no deadline
        let cfg = RunConfig::from_text("scheme = \"gs_baseline\"\n").unwrap();
        assert_eq!(cfg.priority, 0);
        assert_eq!(cfg.deadline_ms, None);
        // `deadline_ms` is only printed when set (like `machine`)
        assert!(!cfg.to_text().contains("deadline_ms"));
        let cfg = RunConfig { priority: 3, deadline_ms: Some(250), ..Default::default() };
        let text = cfg.to_text();
        assert!(text.contains("priority = 3"), "{text}");
        assert!(text.contains("deadline_ms = 250"), "{text}");
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.priority, 3);
        assert_eq!(back.deadline_ms, Some(250));
        back.validate().unwrap();
        // out-of-range priorities are rejected at validation
        let cfg = RunConfig { priority: PRIORITY_LEVELS, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn op_key_roundtrips_and_gates_validation() {
        for op in OpKind::ALL {
            let cfg = RunConfig { op, ..Default::default() };
            let text = cfg.to_text();
            assert!(text.contains(&format!("op = \"{}\"", op.as_str())), "{text}");
            assert_eq!(RunConfig::from_text(&text).unwrap().op, op);
        }
        // unparsed configs default to the paper's operator
        let cfg = RunConfig::from_text("scheme = \"gs_baseline\"\n").unwrap();
        assert_eq!(cfg.op, OpKind::ConstLaplace7);
        // bad op names carry the line number
        let err = RunConfig::from_text("op = \"biharmonic\"\n").unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("biharmonic"), "{err}");
        // a radius-2 op tightens the minimum grid and block width
        let mut cfg = RunConfig {
            op: OpKind::Laplace13,
            size: (4, 4, 4),
            ..Default::default()
        };
        assert!(cfg.validate().is_err(), "4^3 has no radius-2 interior");
        cfg.size = (16, 16, 16);
        cfg.validate().unwrap();
        cfg.scheme = Scheme::JacobiMultiGroup;
        cfg.groups = 4; // 12 interior lines < 4 * 4
        assert!(cfg.validate().is_err());
        cfg.groups = 3; // 12 interior lines == 4 * 3: minimum width
        cfg.validate().unwrap();
    }

    #[test]
    fn pin_key_roundtrips_and_rejects_unknown_policies() {
        for pin in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Scatter, PinPolicy::SmtPair] {
            let cfg = RunConfig { pin, ..Default::default() };
            let text = cfg.to_text();
            assert!(text.contains(&format!("pin = \"{}\"", pin.as_str())), "{text}");
            assert_eq!(RunConfig::from_text(&text).unwrap().pin, pin);
        }
        // unparsed configs default to no pinning
        let cfg = RunConfig::from_text("scheme = \"gs_baseline\"\n").unwrap();
        assert_eq!(cfg.pin, PinPolicy::None);
        // bad policies carry the line number
        let err = RunConfig::from_text("pin = \"diagonal\"\n").unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("diagonal"), "{err}");
    }

    #[test]
    fn minimal_text_uses_defaults() {
        let cfg = RunConfig::from_text(
            "scheme = \"gs_baseline\"  # the pipelined baseline\nsize = [32, 32, 32]\n",
        )
        .unwrap();
        assert_eq!(cfg.t, 4);
        assert_eq!(cfg.groups, 1);
        assert!(cfg.optimized_kernel);
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg =
            RunConfig::from_text("scheme = \"jacobi_wavefront\"\nsize = [32,32,32]\n").unwrap();
        cfg.t = 3; // odd
        assert!(cfg.validate().is_err());
        cfg.t = 4;
        cfg.iters = 6; // not a multiple of 4
        assert!(cfg.validate().is_err());
        cfg.iters = 8;
        cfg.validate().unwrap();
        cfg.machine = Some("pentium4".into());
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = RunConfig::from_text("scheme = \"gs_baseline\"\nbogus_key = 3\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(RunConfig::from_text("not a kv line\n").is_err());
    }

    #[test]
    fn multigroup_scheme_roundtrip_and_validation() {
        let mut cfg =
            RunConfig::from_text("scheme = \"jacobi_multigroup\"\nsize = [16, 16, 16]\n").unwrap();
        assert_eq!(cfg.scheme, Scheme::JacobiMultiGroup);
        assert!(!cfg.scheme.is_gs());
        cfg.groups = 4;
        cfg.validate().unwrap(); // 14 interior lines >= 2 * 4
        let back = RunConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.scheme, Scheme::JacobiMultiGroup);
        cfg.groups = 8; // 14 < 16: blocks would be narrower than 2 lines
        assert!(cfg.validate().is_err());
        cfg.groups = 2;
        cfg.t = 3; // odd temporal depth
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn gs_multigroup_scheme_roundtrip_and_validation() {
        let mut cfg =
            RunConfig::from_text("scheme = \"gs_multigroup\"\nsize = [16, 16, 16]\n").unwrap();
        assert_eq!(cfg.scheme, Scheme::GsMultiGroup);
        assert!(cfg.scheme.is_gs());
        cfg.groups = 14; // in-place GS: one interior line per block suffices
        cfg.validate().unwrap();
        let back = RunConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.scheme, Scheme::GsMultiGroup);
        assert_eq!(back.groups, 14);
        cfg.groups = 15; // 14 interior lines < 15 blocks
        assert!(cfg.validate().is_err());
        // GS has no even-t or iters-divisibility requirement (the
        // remainder pass handles partial temporal depth)
        cfg.groups = 2;
        cfg.t = 3;
        cfg.iters = 7;
        cfg.validate().unwrap();
        // hyphenated CLI spelling parses too
        assert_eq!(Scheme::parse("gs-multigroup").unwrap(), Scheme::GsMultiGroup);
    }

    #[test]
    fn diamond_scheme_roundtrip_and_validation() {
        let mut cfg =
            RunConfig::from_text("scheme = \"jacobi_diamond\"\nsize = [16, 16, 16]\n").unwrap();
        assert_eq!(cfg.scheme, Scheme::JacobiDiamond);
        assert!(!cfg.scheme.is_gs());
        // t = 4, radius 1: each tile interval needs 2·1·3 = 6 lines
        cfg.groups = 2;
        cfg.validate().unwrap(); // 14 interior lines >= 6 * 2
        let back = RunConfig::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.scheme, Scheme::JacobiDiamond);
        cfg.groups = 3; // 14 < 6 * 3
        let err = cfg.validate().unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed error");
        assert_eq!((typed.required, typed.groups), (6, 3));
        // shallower temporal depth relaxes the requirement to 2R(t-1)
        cfg.t = 2;
        cfg.iters = 4;
        cfg.groups = 7; // 14 >= 2 * 7
        cfg.validate().unwrap();
        // the even-t / iters-multiple gate applies like the other
        // temporally blocked Jacobi schemes
        cfg.t = 3;
        assert!(cfg.validate().is_err());
        cfg.t = 2;
        cfg.iters = 5;
        assert!(cfg.validate().is_err());
        // deep-halo rank rule: a t-sweep temporal block per exchange
        cfg.t = 4;
        cfg.iters = 8;
        assert_eq!((cfg.rank_step(), cfg.halo_depth()), (4, 4));
        // hyphenated CLI spelling parses too
        assert_eq!(Scheme::parse("jacobi-diamond").unwrap(), Scheme::JacobiDiamond);
    }

    #[test]
    fn every_scheme_roundtrips_through_text() {
        // a future variant cannot ship without a parse + print mapping
        for scheme in Scheme::ALL {
            let cfg = RunConfig { scheme, ..Default::default() };
            let text = cfg.to_text();
            assert!(text.contains(&format!("scheme = \"{}\"", scheme.as_str())), "{text}");
            assert_eq!(RunConfig::from_text(&text).unwrap().scheme, scheme);
            assert_eq!(Scheme::parse(scheme.as_str()).unwrap(), scheme);
        }
    }

    #[test]
    fn block_width_errors_are_typed_and_scheme_specific() {
        // radius-2 op, 12 interior lines: the Jacobi decomposition needs
        // 4 lines per block, the in-place GS one only 2
        let mut cfg = RunConfig {
            op: OpKind::Laplace13,
            size: (16, 16, 16),
            groups: 4,
            ..Default::default()
        };
        cfg.scheme = Scheme::JacobiMultiGroup;
        let err = cfg.validate().unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed error");
        assert_eq!(typed.required, 4);
        assert_eq!(typed.interior, 12);
        assert_eq!(typed.scheme, Scheme::JacobiMultiGroup);
        cfg.scheme = Scheme::GsMultiGroup;
        cfg.validate().unwrap(); // 12 >= 2 * 4: the lifted restriction
        cfg.groups = 7; // 12 < 2 * 7
        let err = cfg.validate().unwrap_err();
        let typed = err.downcast_ref::<BlockWidthError>().expect("typed error");
        assert_eq!(typed.required, 2);
        assert_eq!(typed.scheme, Scheme::GsMultiGroup);
        // non-decomposing schemes never produce the error
        cfg.scheme = Scheme::GsWavefront;
        cfg.validate().unwrap();
    }

    #[test]
    fn ranks_key_roundtrips_and_validates_depth() {
        let cfg = RunConfig { ranks: 3, size: (64, 16, 16), ..Default::default() };
        let text = cfg.to_text();
        assert!(text.contains("ranks = 3"), "{text}");
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.ranks, 3);
        back.validate().unwrap();
        // unparsed configs default to a single rank
        let cfg = RunConfig::from_text("scheme = \"gs_baseline\"\n").unwrap();
        assert_eq!(cfg.ranks, 1);
        // zero ranks is rejected outright
        let cfg = RunConfig { ranks: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn halo_depth_rule_per_scheme() {
        // Jacobi wavefront/multigroup: t·R deep (a whole temporal block
        // per exchange); baselines and the in-place GS family: R deep.
        let mut cfg = RunConfig { t: 4, ..Default::default() };
        cfg.scheme = Scheme::JacobiWavefront;
        assert_eq!((cfg.rank_step(), cfg.halo_depth()), (4, 4));
        cfg.scheme = Scheme::JacobiMultiGroup;
        assert_eq!(cfg.halo_depth(), 4);
        cfg.scheme = Scheme::JacobiBaseline;
        assert_eq!((cfg.rank_step(), cfg.halo_depth()), (1, 1));
        cfg.scheme = Scheme::GsMultiGroup;
        assert_eq!((cfg.rank_step(), cfg.halo_depth()), (1, 1));
        cfg.op = OpKind::Laplace13;
        assert_eq!(cfg.halo_depth(), 2);
        cfg.scheme = Scheme::JacobiWavefront;
        assert_eq!(cfg.halo_depth(), 8);
    }

    #[test]
    fn rank_width_errors_are_typed() {
        // radius-1 wavefront Jacobi at t = 4 needs 4 owned planes per
        // rank; nz = 16 has 14 interior planes — 3 ranks don't fit
        let mut cfg = RunConfig { size: (16, 16, 16), ranks: 3, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        let typed = err.downcast_ref::<RankWidthError>().expect("typed error");
        assert_eq!(typed.required, 4);
        assert_eq!(typed.interior, 14);
        assert_eq!(typed.ranks, 3);
        cfg.ranks = 2; // 14 >= 4 * 2
        cfg.validate().unwrap();
        // the per-sweep GS exchange only needs R planes per rank
        cfg.scheme = Scheme::GsWavefront;
        cfg.ranks = 14;
        cfg.validate().unwrap();
        cfg.ranks = 15;
        let err = cfg.validate().unwrap_err();
        assert!(err.downcast_ref::<RankWidthError>().is_some());
        // single-rank runs never produce the error
        cfg.ranks = 1;
        cfg.validate().unwrap();
    }

    #[test]
    fn job_files_parse_block_per_job() {
        let text = "\
scheme = \"jacobi_wavefront\"  # tenant A
size = [16, 16, 16]
---
scheme = \"gs_multigroup\"
size = [16, 16, 16]
groups = 2
---
# a block of only comments is skipped
---
scheme = \"gs_baseline\"
";
        let jobs = RunConfig::from_job_text(text).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].scheme, Scheme::JacobiWavefront);
        assert_eq!(jobs[1].scheme, Scheme::GsMultiGroup);
        assert_eq!(jobs[1].groups, 2);
        assert_eq!(jobs[2].scheme, Scheme::GsBaseline);
        // an empty file (or all separators) holds no jobs
        assert!(RunConfig::from_job_text("").unwrap().is_empty());
        assert!(RunConfig::from_job_text("---\n---\n").unwrap().is_empty());
        // errors carry the block number, not just the line
        let err = RunConfig::from_job_text("scheme = \"gs_baseline\"\n---\nbogus = 1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("job 2"), "{err}");
    }

    #[test]
    fn scheme_kernel_mapping() {
        assert_eq!(Scheme::JacobiBaseline.kernel(true), Kernel::JacobiOpt);
        assert_eq!(Scheme::GsWavefront.kernel(false), Kernel::GsC);
        assert_eq!(Scheme::GsMultiGroup.kernel(true), Kernel::GsOpt);
        assert!(Scheme::GsBaseline.is_gs());
        assert!(Scheme::GsMultiGroup.is_gs());
        assert!(!Scheme::JacobiWavefront.is_gs());
        assert!(Scheme::parse("jacobi-wavefront").is_ok());
        assert!(Scheme::parse("nope").is_err());
    }
}
