//! Minimal JSON parser for the artifact manifest (offline build: no serde).
//!
//! Supports the full JSON grammar the `aot.py` manifest uses — objects,
//! arrays, strings (with escapes), numbers, booleans, null — with an API
//! shaped like `serde_json::Value` so the call sites read naturally.

use std::collections::BTreeMap;

use crate::Result;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(got == b, "expected '{}' at byte {}, got '{}'", b as char, self.pos - 1, got as char);
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}' at byte {}, got '{}'", self.pos - 1, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(out)),
                c => anyhow::bail!("expected ',' or ']' at byte {}, got '{}'", self.pos - 1, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                },
                c => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number '{text}' at byte {start}: {e}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let v = parse(
            r#"{"dtype": "f64", "artifacts": [
                {"name": "a", "inputs": [{"shape": [16, 16, 16]}],
                 "n_outputs": 2, "params": {"h2": 1.0, "scheme": "jacobi"}}
            ]}"#,
        )
        .unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f64"));
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts.len(), 1);
        let a = &arts[0];
        assert_eq!(a.get("n_outputs").unwrap().as_u64(), Some(2));
        let shape = a.get("inputs").unwrap().as_array().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape.iter().filter_map(|v| v.as_u64()).collect::<Vec<_>>(), [16, 16, 16]);
        assert_eq!(a.get("params").unwrap().get("h2").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse(r#""a\nbA""#).unwrap().as_str(), Some("a\nbA"));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "tru", "1 2", "{\"a\":}"] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn non_integer_numbers_are_not_u64() {
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
    }
}
