//! Experiment launcher: run configs on the host, predict on the testbed.
//!
//! Every experiment has two legs:
//! * **execute** — run the configured schedule for real on this box with
//!   real threads and real barriers, measure MLUP/s, and *verify* the
//!   result grid against the serial reference (numerical exactness is
//!   checked on every launch, not only in tests);
//! * **predict** — evaluate the same configuration on a Tab. 1 machine
//!   model, yielding the MLUP/s the paper's testbed would see.
//!
//! The CLI (`stencilwave run|sweep`) and the figure regenerators are thin
//! wrappers over this module. Sweeps fan out over scoped threads so a
//! parameter grid keeps the host busy end to end.


use crate::config::{RunConfig, Scheme, PRIORITY_LEVELS};
use crate::coordinator::pool::panic_message;
use crate::coordinator::rank::RankSet;
use crate::coordinator::runner::runner_for;
use crate::coordinator::service::{
    AdmissionError, ExpiredError, JobSpec, ServiceConfig, ServiceStats, SolverService,
    WAIT_BUCKET_BOUNDS_MS,
};
use crate::coordinator::solver::Solver;
use crate::metrics::{mlups, timed};
use crate::stencil::grid::Grid3;
use crate::stencil::op::OpKind;
use crate::Result;

/// Outcome of one launched experiment.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub scheme: Scheme,
    pub op: OpKind,
    pub size: (usize, usize, usize),
    pub iters: usize,
    pub t: usize,
    pub groups: usize,
    /// z-axis rank shards the experiment ran across (1 = plain solver).
    pub ranks: usize,
    /// Measured on this host (functional leg).
    pub host_mlups: f64,
    pub host_seconds: f64,
    /// Max |diff| against the serial reference (must be 0.0).
    pub verification_diff: f64,
    /// Modeled performance on the requested Tab. 1 machine, if any.
    pub predicted_mlups: Option<f64>,
    pub machine: Option<String>,
    /// Analytic pipeline-fill waste over the whole run (see [`fill_lups`]).
    pub fill_lups: f64,
}

/// Analytic pipeline-fill waste of a configuration, in LUP-equivalents:
/// the idle update slots a scheme's wind-up and wind-down phases leave
/// empty over the whole run, before any cache or bandwidth effect.
///
/// Each temporally blocked pass sweeps a z-wavefront whose `t` levels
/// trail each other by the scheme's plane lag (`R+1` for the Jacobi
/// family, `R` for Gauss-Seidel): every level idles `lag·(t-1)` rounds
/// per pass, each round worth one interior plane of updates. On top of
/// that the multi-group schemes skew their `G` y-blocks by one t-level
/// column per interface, adding `(G-1)·t` plane-slots per pass — the
/// term the diamond decomposition deletes: its tiles co-sweep one
/// z-wavefront with no inter-block skew, so its fill waste at the same
/// `(t, groups)` is exactly the wavefront's, strictly below the
/// multi-group number for `G >= 2`. The pipelined GS baseline pays its
/// `t-1`-stage wind-up one thread-share of a plane at a time, per sweep;
/// the serial Jacobi baseline wastes nothing.
pub fn fill_lups(cfg: &RunConfig) -> f64 {
    let (_nz, ny, nx) = cfg.size;
    let r = cfg.op.radius();
    let rf = r as f64;
    let plane = (ny.saturating_sub(2 * r) * nx.saturating_sub(2 * r)) as f64;
    let t = cfg.t as f64;
    let g = cfg.groups as f64;
    let sweeps = cfg.iters as f64;
    let z_fill = |lag: f64| t * lag * (t - 1.0) * plane;
    let skew = (g - 1.0).max(0.0) * t * plane;
    let (per_pass, passes) = match cfg.scheme {
        Scheme::JacobiBaseline => (0.0, sweeps),
        Scheme::GsBaseline => {
            let w = if cfg.t <= 1 { 0.0 } else { (t - 1.0) * plane / t };
            (w, sweeps)
        }
        Scheme::JacobiWavefront => (z_fill(rf + 1.0), sweeps / t),
        Scheme::JacobiDiamond => (z_fill(rf + 1.0), sweeps / t),
        Scheme::JacobiMultiGroup => (z_fill(rf + 1.0) + skew, sweeps / t),
        Scheme::GsWavefront => (z_fill(rf), sweeps / t),
        Scheme::GsMultiGroup => (z_fill(rf) + skew, sweeps / t),
    };
    per_pass * passes
}

/// Execute one configuration: real run + verification + prediction.
///
/// Fully data-driven over the [`SchemeRunner`] registry — no per-scheme
/// dispatch lives here: the [`Solver`] session executes, the runner
/// supplies the serial reference and the performance-model leg. Adding a
/// scheme touches the coordinator layer only.
///
/// [`SchemeRunner`]: crate::coordinator::runner::SchemeRunner
pub fn run_experiment(cfg: &RunConfig) -> Result<RunReport> {
    // fail fast before materializing the grids (build() re-validates,
    // which is cheap and keeps the builder's error parity intact)
    cfg.validate()?;
    let (nz, ny, nx) = cfg.size;
    let f = Grid3::random(nz, ny, nx, 7);
    let u0 = Grid3::random(nz, ny, nx, 8);
    let h2 = 1.0;

    // ---- functional leg on the host.
    // Each experiment gets its own session (validated and team-spawned at
    // build, before the timer starts) so parallel sweeps really run side
    // by side and the timed section never includes thread creation or
    // waiting for another experiment's team. `ranks > 1` swaps the
    // single solver for a RankSet of halo-exchange-coupled sessions;
    // verification and the model leg switch with it (the rank model
    // adds the halo-traffic term to the multigroup prediction).
    let (dt, diff, predicted) = if cfg.ranks > 1 {
        let mut set = RankSet::builder(cfg).rhs(f, h2).build()?;
        let mut u = u0.clone();
        let (res, dt) = timed(|| set.run(&mut u, cfg.iters));
        res?;
        let diff = u.max_abs_diff(&set.reference(&u0, cfg.iters));
        (dt, diff, cfg.machine_spec().map(|m| set.predict(&m).mlups))
    } else {
        let mut solver = Solver::builder(cfg).rhs(f, h2).build()?;
        let mut u = u0.clone();
        let (res, dt) = timed(|| solver.run(&mut u, cfg.iters));
        res?;
        let diff = u.max_abs_diff(&solver.reference(&u0, cfg.iters));
        (dt, diff, cfg.machine_spec().map(|m| solver.predict(&m)))
    };

    // radius-aware update count: a radius-R op only updates the
    // (n-2R)^3 deep interior, so wider halos must not inflate MLUP/s
    let r = cfg.op.radius();
    let updates = ((nz - 2 * r) * (ny - 2 * r) * (nx - 2 * r) * cfg.iters) as u64;
    Ok(RunReport {
        scheme: cfg.scheme,
        op: cfg.op,
        size: cfg.size,
        iters: cfg.iters,
        t: cfg.t,
        groups: cfg.groups,
        ranks: cfg.ranks,
        host_mlups: mlups(updates, dt),
        host_seconds: dt.as_secs_f64(),
        verification_diff: diff,
        predicted_mlups: predicted,
        machine: cfg.machine.clone(),
        fill_lups: fill_lups(cfg),
    })
}

/// Run a set of configurations, one scoped thread each.
///
/// Experiments already saturate the host with their own thread teams, so
/// the sweep runs them with modest outer concurrency: chunks of
/// `max_parallel` at a time (1 = fully sequential, the default for
/// benchmarking; larger for functional sweeps).
pub fn sweep(configs: Vec<RunConfig>, max_parallel: usize) -> Vec<Result<RunReport>> {
    let max_parallel = max_parallel.max(1);
    let mut out = Vec::with_capacity(configs.len());
    for chunk in configs.chunks(max_parallel) {
        let mut results: Vec<Option<Result<RunReport>>> =
            (0..chunk.len()).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for cfg in chunk {
                handles.push(scope.spawn(move || run_experiment(cfg)));
            }
            for (slot, h) in results.iter_mut().zip(handles) {
                *slot = Some(h.join().unwrap_or_else(|payload| {
                    // surface the panic payload instead of swallowing it
                    Err(anyhow::anyhow!(
                        "sweep worker panicked: {}",
                        panic_message(payload.as_ref())
                    ))
                }));
            }
        });
        out.extend(results.into_iter().map(|r| r.expect("filled")));
    }
    out
}

/// Outcome of one job in a service launch.
#[derive(Clone, Debug)]
pub struct ServiceJobReport {
    /// Submission-order index of the job in the job file.
    pub job: usize,
    pub scheme: Scheme,
    pub op: OpKind,
    pub size: (usize, usize, usize),
    pub iters: usize,
    /// Priority level the job was queued at.
    pub priority: usize,
    /// First cache group the job executed on.
    pub group_start: usize,
    /// Cache groups the job's window spans.
    pub group_count: usize,
    /// Jobs that shared the claimed window (1 = unbatched).
    pub batch_size: usize,
    /// Milliseconds between submission and the claim that started it.
    pub wait_ms: f64,
    /// Max |diff| against the serial reference (must be 0.0).
    pub verification_diff: f64,
}

/// Aggregate outcome of a [`run_service_jobs`] launch.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Completed jobs only — rejected and shed jobs have no result grid.
    pub jobs: Vec<ServiceJobReport>,
    /// Jobs bounced at admission with `QueueFull`, as
    /// `(job index, retry_after_hint seconds)` — overload is a reported
    /// outcome of a launch, not a launch failure.
    pub rejected: Vec<(usize, f64)>,
    /// Jobs shed past their `deadline_ms` before starting (job indices).
    pub shed: Vec<usize>,
    /// Wall seconds from first submission to last completion.
    pub seconds: f64,
    /// Aggregate interior updates over those wall seconds.
    pub throughput_mlups: f64,
    pub stats: ServiceStats,
}

/// Run a job file through the multi-tenant [`SolverService`] —
/// everything submitted up front, completions in flight concurrently —
/// and verify every tenant's grid against its serial reference (the
/// launcher's exactness contract applies per tenant, not just per
/// process). Grids are seeded per job index, so a service launch is as
/// reproducible as a `run` launch.
pub fn run_service_jobs(svc_cfg: ServiceConfig, jobs: &[RunConfig]) -> Result<ServiceReport> {
    let mut svc = SolverService::new(svc_cfg)?;
    let inputs: Vec<(Grid3, Grid3)> = jobs
        .iter()
        .enumerate()
        .map(|(i, cfg)| {
            let (nz, ny, nx) = cfg.size;
            (Grid3::random(nz, ny, nx, 7 + i as u64), Grid3::random(nz, ny, nx, 1008 + i as u64))
        })
        .collect();
    let h2 = 1.0;
    let mut rejected: Vec<(usize, f64)> = Vec::new();
    let mut shed: Vec<usize> = Vec::new();
    // admission overload and deadline shedding are *reported* launch
    // outcomes (the backpressure contract a front end consumes), not
    // launch failures; anything else typed is still a hard error
    let (outputs, dt) = {
        let (res, dt) = timed(|| -> Result<Vec<_>> {
            let mut tickets = Vec::with_capacity(jobs.len());
            for (i, (cfg, (f, u0))) in jobs.iter().zip(&inputs).enumerate() {
                match svc.submit(JobSpec::new(cfg.clone(), u0.clone()).rhs(f.clone(), h2)) {
                    Ok(t) => tickets.push((i, t)),
                    Err(e) => match e.downcast_ref::<AdmissionError>() {
                        Some(AdmissionError::QueueFull { retry_after_hint, .. }) => {
                            rejected.push((i, *retry_after_hint));
                        }
                        _ => return Err(e),
                    },
                }
            }
            let mut outs = Vec::with_capacity(tickets.len());
            for (i, t) in tickets {
                match t.wait() {
                    Ok(out) => outs.push((i, out)),
                    Err(e) if e.downcast_ref::<ExpiredError>().is_some() => shed.push(i),
                    Err(e) => return Err(e),
                }
            }
            Ok(outs)
        });
        (res?, dt)
    };
    let mut reports = Vec::with_capacity(outputs.len());
    let mut updates = 0u64;
    for (i, out) in outputs {
        let cfg = &jobs[i];
        let (f, u0) = &inputs[i];
        let r = cfg.op.radius();
        let (nz, ny, nx) = cfg.size;
        updates += ((nz - 2 * r) * (ny - 2 * r) * (nx - 2 * r) * cfg.iters) as u64;
        // the registry reference needs no pool of its own
        let op = cfg.op.instantiate(cfg.size);
        let want = runner_for(cfg.scheme, cfg.op)?.reference(&op, u0, f, h2, cfg, cfg.iters);
        reports.push(ServiceJobReport {
            job: i,
            scheme: cfg.scheme,
            op: cfg.op,
            size: cfg.size,
            iters: cfg.iters,
            priority: out.priority,
            group_start: out.placement.group_start,
            group_count: out.placement.group_count,
            batch_size: out.batch_size,
            wait_ms: out.wait_ms,
            verification_diff: out.u.max_abs_diff(&want),
        });
    }
    let stats = svc.stats();
    svc.shutdown();
    Ok(ServiceReport {
        jobs: reports,
        rejected,
        shed,
        seconds: dt.as_secs_f64(),
        throughput_mlups: mlups(updates, dt),
        stats,
    })
}

/// Render a service report as a CSV block (one row per job).
pub fn service_to_csv(report: &ServiceReport) -> String {
    let mut s = String::from(
        "job,scheme,op,nz,ny,nx,iters,priority,group_start,group_count,batch_size,\
         wait_ms,verify_diff\n",
    );
    for j in &report.jobs {
        s += &format!(
            "{},{:?},{},{},{},{},{},{},{},{},{},{:.3},{:.3e}\n",
            j.job,
            j.scheme,
            j.op.as_str(),
            j.size.0,
            j.size.1,
            j.size.2,
            j.iters,
            j.priority,
            j.group_start,
            j.group_count,
            j.batch_size,
            j.wait_ms,
            j.verification_diff,
        );
    }
    s
}

/// Stable label for wait-histogram bucket `b`: `le_<bound>ms` below each
/// bound in [`WAIT_BUCKET_BOUNDS_MS`], `gt_<last>ms` for the open tail.
fn wait_bucket_label(b: usize) -> String {
    match WAIT_BUCKET_BOUNDS_MS.get(b) {
        Some(bound) => format!("le_{bound}ms"),
        None => format!("gt_{}ms", WAIT_BUCKET_BOUNDS_MS[WAIT_BUCKET_BOUNDS_MS.len() - 1]),
    }
}

/// Render the service-level counters — admission, shedding, queue
/// pressure and the per-priority wait histograms — as a two-column
/// `metric,value` CSV block (the stats companion to
/// [`service_to_csv`]'s per-job rows).
pub fn service_stats_to_csv(stats: &ServiceStats) -> String {
    let mut s = String::from("metric,value\n");
    for (k, v) in [
        ("submitted", stats.submitted),
        ("completed", stats.completed),
        ("failed", stats.failed),
        ("shed_expired", stats.shed_expired),
        ("rejected_full", stats.rejected_full),
        ("aged_jobs", stats.aged_jobs),
        ("batches", stats.batches),
        ("batched_jobs", stats.batched_jobs),
        ("claim_conflicts", stats.claim_conflicts),
        ("max_queue_depth", stats.max_queue_depth as u64),
        ("peak_groups_busy", stats.peak_groups_busy as u64),
    ] {
        s += &format!("{k},{v}\n");
    }
    for p in 0..PRIORITY_LEVELS {
        for (b, count) in stats.wait_hist[p].iter().enumerate() {
            s += &format!("wait_p{p}_{},{count}\n", wait_bucket_label(b));
        }
    }
    s
}

/// Render reports as a CSV block (one row per report).
pub fn to_csv(reports: &[RunReport]) -> String {
    let mut s = String::from(
        "scheme,op,nz,ny,nx,iters,t,groups,ranks,host_mlups,verify_diff,machine,predicted_mlups,fill_lups\n",
    );
    for r in reports {
        s += &format!(
            "{:?},{},{},{},{},{},{},{},{},{:.2},{:.3e},{},{},{:.0}\n",
            r.scheme,
            r.op.as_str(),
            r.size.0,
            r.size.1,
            r.size.2,
            r.iters,
            r.t,
            r.groups,
            r.ranks,
            r.host_mlups,
            r.verification_diff,
            r.machine.as_deref().unwrap_or("-"),
            r.predicted_mlups.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into()),
            r.fill_lups,
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::perfmodel::BarrierKind;

    fn cfg(scheme: Scheme) -> RunConfig {
        // the diamond width rule (interior >= 2R(t-1)*groups) does not
        // admit t = 4 on these small grids; t = 2 fits every op radius
        let t = if scheme == Scheme::JacobiDiamond { 2 } else { 4 };
        RunConfig {
            scheme,
            size: (12, 12, 12),
            t,
            groups: 2,
            iters: 4,
            smt: false,
            optimized_kernel: true,
            nt_stores: true,
            barrier: BarrierKind::Spin,
            machine: Some("Nehalem EP".into()),
            ..Default::default()
        }
    }

    #[test]
    fn all_schemes_run_verified() {
        for scheme in Scheme::ALL {
            let report = run_experiment(&cfg(scheme)).unwrap();
            assert_eq!(report.verification_diff, 0.0, "{scheme:?} must be exact");
            assert!(report.host_mlups > 0.0);
            assert!(report.predicted_mlups.unwrap() > 0.0);
        }
    }

    #[test]
    fn every_op_runs_verified_with_finite_predictions() {
        // the acceptance criterion: both new ops run through every
        // scheme from the launcher and get finite, op-derived predictions
        for op in OpKind::ALL {
            for scheme in Scheme::ALL {
                let mut c = cfg(scheme);
                c.op = op;
                c.size = (14, 14, 14); // radius-2 multigroup needs wider blocks
                let report = run_experiment(&c).unwrap();
                assert_eq!(report.verification_diff, 0.0, "{scheme:?} x {op:?} must be exact");
                assert_eq!(report.op, op);
                let p = report.predicted_mlups.unwrap();
                assert!(p.is_finite() && p > 0.0, "{scheme:?} x {op:?}: {p}");
            }
        }
    }

    #[test]
    fn multi_rank_experiments_run_verified_with_rank_predictions() {
        // the launcher leg of the rank subsystem: ranks > 1 routes
        // through the RankSet, stays bit-exact, reports its rank count
        // in the CSV, and gets the halo-aware prediction
        for scheme in [Scheme::JacobiWavefront, Scheme::GsMultiGroup] {
            let mut c = cfg(scheme);
            c.size = (24, 12, 12);
            c.ranks = 2;
            c.iters = 8; // two temporal blocks -> at least one real exchange
            let report = run_experiment(&c).unwrap();
            assert_eq!(report.verification_diff, 0.0, "{scheme:?} must be exact across ranks");
            assert_eq!(report.ranks, 2);
            let p = report.predicted_mlups.unwrap();
            assert!(p.is_finite() && p > 0.0);
            let csv = to_csv(&[report]);
            assert!(csv.starts_with("scheme,op,nz,ny,nx,iters,t,groups,ranks,"));
            assert!(csv.lines().nth(1).unwrap().contains(",2,"), "rank column present:\n{csv}");
        }
    }

    #[test]
    fn fill_waste_column_orders_the_schemes() {
        // the analytic fill column: the serial baseline wastes nothing,
        // the diamond decomposition deletes the multi-group skew term at
        // the same (t, groups), and the CSV carries the column last
        assert_eq!(fill_lups(&cfg(Scheme::JacobiBaseline)), 0.0);
        let dia = cfg(Scheme::JacobiDiamond);
        let mut mg = cfg(Scheme::JacobiMultiGroup);
        mg.t = dia.t; // same temporal depth for an apples-to-apples waste
        assert!(fill_lups(&dia) > 0.0);
        assert!(
            fill_lups(&dia) < fill_lups(&mg),
            "diamond {} !< multigroup {}",
            fill_lups(&dia),
            fill_lups(&mg)
        );
        // wavefront and diamond share the z-pipeline fill exactly: the
        // whole diamond saving is the deleted inter-block skew
        let mut wf = cfg(Scheme::JacobiWavefront);
        wf.t = dia.t;
        assert_eq!(fill_lups(&dia), fill_lups(&wf));
        // GS lags by R, not R+1, so its z-fill sits strictly below
        let mut gs = cfg(Scheme::GsWavefront);
        gs.t = dia.t;
        assert!(fill_lups(&gs) < fill_lups(&wf));
        let report = run_experiment(&dia).unwrap();
        assert_eq!(report.fill_lups, fill_lups(&dia));
        let csv = to_csv(&[report]);
        assert!(csv.lines().next().unwrap().ends_with(",fill_lups"));
        assert!(csv.starts_with("scheme,op,nz,ny,nx,iters,t,groups,ranks,"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = run_experiment(&cfg(Scheme::JacobiBaseline)).unwrap();
        let csv = to_csv(&[r]);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("scheme,"));
    }

    #[test]
    fn csv_names_every_scheme() {
        // the launcher-CSV half of the round-trip satellite: every
        // registered scheme (gs_multigroup included) appears by name in
        // its verified report row
        let reports: Vec<RunReport> =
            Scheme::ALL.iter().map(|&s| run_experiment(&cfg(s)).unwrap()).collect();
        let csv = to_csv(&reports);
        assert_eq!(csv.lines().count(), 1 + Scheme::ALL.len());
        for scheme in Scheme::ALL {
            assert!(csv.contains(&format!("{scheme:?},")), "{scheme:?} missing from:\n{csv}");
        }
        assert!(csv.contains("GsMultiGroup,"));
    }

    #[test]
    fn service_launch_verifies_every_tenant() {
        // a mixed job file through the multi-tenant service: every
        // tenant bit-exact, CSV row per job, coherent stats
        let mut jobs = vec![
            cfg(Scheme::JacobiWavefront),
            cfg(Scheme::GsMultiGroup),
            cfg(Scheme::JacobiWavefront), // identical twin -> batchable
            cfg(Scheme::JacobiBaseline),
        ];
        jobs[1].priority = 2; // priority must round-trip into the report
        let svc_cfg = ServiceConfig { groups: 2, group_width: 4, ..Default::default() };
        let report = run_service_jobs(svc_cfg, &jobs).unwrap();
        assert_eq!(report.jobs.len(), 4);
        for j in &report.jobs {
            assert_eq!(j.verification_diff, 0.0, "job {} ({:?}) diverged", j.job, j.scheme);
            assert!(j.group_count >= 1);
            assert!(j.wait_ms >= 0.0);
        }
        assert_eq!(report.jobs[1].priority, 2);
        assert!(report.rejected.is_empty() && report.shed.is_empty());
        assert_eq!(report.stats.completed, 4);
        assert_eq!(report.stats.claim_conflicts, 0);
        assert!(report.throughput_mlups > 0.0);
        let csv = service_to_csv(&report);
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("job,scheme,"));
        assert!(csv.lines().next().unwrap().contains(",priority,"));
        assert!(csv.lines().next().unwrap().contains(",wait_ms,"));
        assert!(csv.contains("GsMultiGroup,"));
    }

    #[test]
    fn service_stats_csv_carries_the_admission_counters() {
        // the stats companion block: every admission/shedding counter
        // and one histogram row per priority × bucket, labeled by the
        // bucket bounds so downstream tooling never re-derives them
        let stats = ServiceStats {
            submitted: 7,
            completed: 5,
            shed_expired: 1,
            rejected_full: 2,
            aged_jobs: 1,
            max_queue_depth: 4,
            ..Default::default()
        };
        let csv = service_stats_to_csv(&stats);
        assert!(csv.starts_with("metric,value\n"));
        for row in
            ["shed_expired,1", "rejected_full,2", "max_queue_depth,4", "aged_jobs,1"]
        {
            assert!(csv.contains(&format!("\n{row}\n")), "missing {row} in:\n{csv}");
        }
        let hist_rows = csv.lines().filter(|l| l.starts_with("wait_p")).count();
        assert_eq!(hist_rows, PRIORITY_LEVELS * (WAIT_BUCKET_BOUNDS_MS.len() + 1));
        assert!(csv.contains("wait_p0_le_1ms,0"));
        assert!(csv.contains("wait_p3_gt_1000ms,0"));
    }

    #[test]
    fn overloaded_launches_report_sheds_without_failing() {
        // a deadline_ms = 0 job is shed before any claim can reach it
        // (the shed pass runs at the top of every executor wakeup,
        // under the same lock as the claim scan): the launch still
        // succeeds, the shed job is reported by index with no result
        // row, and the completed tenant stays verified
        let mut jobs = vec![cfg(Scheme::JacobiBaseline), cfg(Scheme::JacobiWavefront)];
        jobs[1].deadline_ms = Some(0);
        let svc_cfg = ServiceConfig { groups: 2, group_width: 4, ..Default::default() };
        let report = run_service_jobs(svc_cfg, &jobs).unwrap();
        assert_eq!(report.shed, vec![1]);
        assert!(report.rejected.is_empty());
        assert_eq!(report.jobs.len(), 1);
        assert_eq!(report.jobs[0].job, 0);
        assert_eq!(report.jobs[0].verification_diff, 0.0);
        assert_eq!(report.stats.shed_expired, 1);
        assert_eq!(report.stats.completed, 1);
        let csv = service_stats_to_csv(&report.stats);
        assert!(csv.contains("\nshed_expired,1\n"), "{csv}");
    }

    #[test]
    fn sweep_runs_all_configs() {
        let reports = sweep(vec![cfg(Scheme::JacobiBaseline), cfg(Scheme::GsBaseline)], 2);
        assert_eq!(reports.len(), 2);
        for r in reports {
            assert_eq!(r.unwrap().verification_diff, 0.0);
        }
    }

    #[test]
    fn sweep_surfaces_invalid_config_errors() {
        // groups too large for the grid: run_experiment must fail with a
        // real error (not a swallowed panic) while valid configs succeed.
        let mut bad = cfg(Scheme::JacobiMultiGroup);
        bad.groups = 50;
        let reports = sweep(vec![bad, cfg(Scheme::JacobiBaseline)], 2);
        assert!(reports[0].is_err());
        assert_eq!(reports[1].as_ref().unwrap().verification_diff, 0.0);
    }
}
