//! One persistent pool reused across schemes, passes and team sizes must
//! stay bit-exact against the serial references — the suite that catches
//! stale progress-table or scratch-buffer state surviving a pass. The
//! serial-reference scaffolding comes from the shared harness
//! (`tests/common`).

mod common;

use stencilwave::coordinator::gs_multigroup::{gs_multigroup_passes, GsMultiGroupConfig};
use stencilwave::coordinator::pipeline::{pipeline_gs_passes, PipelineConfig};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::spatial_mg::{multigroup_passes, MultiGroupConfig};
use stencilwave::coordinator::wavefront::{
    serial_reference_op, wavefront_jacobi_passes, SyncMode, WavefrontConfig,
};
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_passes, GsWavefrontConfig};
use stencilwave::simulator::perfmodel::BarrierKind;
use stencilwave::stencil::gauss_seidel::GsKernel;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::{ConstLaplace7, Laplace13};

use common::seed_reference;

#[test]
fn one_pool_survives_scheme_and_team_size_changes() {
    let mut pool = WorkerPool::new(2);
    let f = Grid3::random(12, 14, 10, 3);
    for round in 0u64..3 {
        // wavefront Jacobi with a reconfigured team every call
        for (t, sync) in [(2usize, SyncMode::Flow), (6, SyncMode::Barrier), (4, SyncMode::Flow)] {
            let mut u = Grid3::random(12, 14, 10, 40 + round * 10 + t as u64);
            let want = seed_reference(false, &u, &f, 1.0, t);
            let cfg = WavefrontConfig { threads: t, barrier: BarrierKind::Spin, sync, ..Default::default() };
            wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &cfg, 1).unwrap();
            assert_eq!(u.max_abs_diff(&want), 0.0, "jacobi t={t} round={round}");
        }
        // pipelined GS on the same pool
        let mut u = Grid3::random(12, 14, 10, 70 + round);
        let want = seed_reference(true, &u, &f, 1.0, 2);
        let p = PipelineConfig { threads: 3, kernel: GsKernel::Interleaved };
        pipeline_gs_passes(&mut pool, &ConstLaplace7, &mut u, &p, 2).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "pipeline round={round}");
        // GS wavefront (different worker count again)
        let mut u = Grid3::random(12, 14, 10, 80 + round);
        let want = seed_reference(true, &u, &f, 1.0, 3);
        let w = GsWavefrontConfig { sweeps: 3, threads_per_group: 2, kernel: GsKernel::Interleaved };
        wavefront_gs_passes(&mut pool, &ConstLaplace7, &mut u, &w, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "gs wavefront round={round}");
        // multi-group blocked Jacobi
        let mut u = Grid3::random(12, 14, 10, 90 + round);
        let want = seed_reference(false, &u, &f, 1.0, 4);
        let mg = MultiGroupConfig { t: 4, groups: 3, ..Default::default() };
        multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &mg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "multigroup round={round}");
        // multi-group blocked GS (same pool, same scratch arena: its
        // boundary array reuses the buffer the Jacobi scheme just sized)
        let mut u = Grid3::random(12, 14, 10, 95 + round);
        let want = seed_reference(true, &u, &f, 1.0, 4);
        let gmg = GsMultiGroupConfig { t: 4, groups: 4, kernel: GsKernel::Interleaved, ..Default::default() };
        gs_multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &gmg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "gs multigroup round={round}");
    }
    // the pool grew to the largest team it ever hosted and kept it
    assert!(pool.size() >= 6, "pool size {}", pool.size());
}

#[test]
fn many_passes_amortize_one_team() {
    // 40 updates = 10 wavefront passes through one pool: any watermark or
    // temporary-ring state leaking between passes breaks exactness.
    let f = Grid3::random(14, 10, 9, 11);
    let mut u = Grid3::random(14, 10, 9, 12);
    let want = seed_reference(false, &u, &f, 0.7, 40);
    let cfg = WavefrontConfig { threads: 4, sync: SyncMode::Flow, ..Default::default() };
    let mut pool = WorkerPool::new(4);
    wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, 0.7, &cfg, 10).unwrap();
    assert_eq!(u.max_abs_diff(&want), 0.0);

    // and 12 more multi-group updates on the *same* pool
    let mut v = Grid3::random(14, 10, 9, 13);
    let want = seed_reference(false, &v, &f, 0.7, 12);
    let mg = MultiGroupConfig { t: 2, groups: 4, ..Default::default() };
    multigroup_passes(&mut pool, &ConstLaplace7, &mut v, &f, 0.7, &mg, 6).unwrap();
    assert_eq!(v.max_abs_diff(&want), 0.0);

    // and 12 in-place GS multi-group updates, again on the same team
    let mut w = Grid3::random(14, 10, 9, 14);
    let want = seed_reference(true, &w, &f, 0.7, 12);
    let gmg = GsMultiGroupConfig { t: 3, groups: 4, kernel: GsKernel::Interleaved, ..Default::default() };
    gs_multigroup_passes(&mut pool, &ConstLaplace7, &mut w, &gmg, 4).unwrap();
    assert_eq!(w.max_abs_diff(&want), 0.0);
}

#[test]
fn scratch_sized_for_radius2_is_safe_for_radius1_and_back() {
    // ops of different radius alternate on one pool: the scratch arena's
    // plane ring and boundary arrays are resized per schedule, so stale
    // capacity (or stale contents) from the wider op must never leak
    let f = Grid3::random(12, 14, 10, 21);
    let mut pool = WorkerPool::new(0);
    for round in 0u64..3 {
        let mut u = Grid3::random(12, 14, 10, 60 + round);
        let want = serial_reference_op(&Laplace13, &u, &f, 0.8, 2);
        let cfg = WavefrontConfig { threads: 2, sync: SyncMode::Flow, ..Default::default() };
        wavefront_jacobi_passes(&mut pool, &Laplace13, &mut u, &f, 0.8, &cfg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "radius-2 round={round}");

        let mut v = Grid3::random(12, 14, 10, 70 + round);
        let want = seed_reference(false, &v, &f, 0.8, 4);
        let mg = MultiGroupConfig { t: 4, groups: 2, ..Default::default() };
        multigroup_passes(&mut pool, &ConstLaplace7, &mut v, &f, 0.8, &mg, 1).unwrap();
        assert_eq!(v.max_abs_diff(&want), 0.0, "radius-1 round={round}");

        let mut w = Grid3::random(12, 14, 10, 80 + round);
        let want = serial_reference_op(&Laplace13, &w, &f, 0.8, 2);
        let mg2 = MultiGroupConfig { t: 2, groups: 2, ..Default::default() };
        multigroup_passes(&mut pool, &Laplace13, &mut w, &f, 0.8, &mg2, 1).unwrap();
        assert_eq!(w.max_abs_diff(&want), 0.0, "radius-2 multigroup round={round}");

        // the GS multi-group boundary array reuses the same scratch.bnd
        // the Jacobi scheme just resized for radius 2
        let mut x = Grid3::random(12, 14, 10, 85 + round);
        let mut want = x.clone();
        stencilwave::stencil::op::op_gs_sweeps(&Laplace13, &mut want, 2, GsKernel::Interleaved);
        let gmg = GsMultiGroupConfig { t: 2, groups: 3, kernel: GsKernel::Interleaved, ..Default::default() };
        gs_multigroup_passes(&mut pool, &Laplace13, &mut x, &gmg, 1).unwrap();
        assert_eq!(x.max_abs_diff(&want), 0.0, "radius-2 gs multigroup round={round}");
    }
}

#[test]
fn shrinking_then_growing_team_sizes_stay_exact() {
    // zig-zag through team sizes so earlier (larger) progress tables and
    // parked extra workers are re-used by later (smaller) schedules
    let f = Grid3::random(10, 18, 8, 1);
    let mut pool = WorkerPool::new(0);
    for t in [8usize, 2, 6, 2, 4, 8, 2] {
        let mut u = Grid3::random(10, 18, 8, 100 + t as u64);
        let want = seed_reference(false, &u, &f, 1.0, t);
        let cfg = WavefrontConfig { threads: t, sync: SyncMode::Flow, ..Default::default() };
        wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &cfg, 1).unwrap();
        assert_eq!(u.max_abs_diff(&want), 0.0, "t={t}");
    }
    assert_eq!(pool.size(), 8);
}
