//! Property-style integration tests: every parallel schedule must be
//! bit-identical to the serial reference for randomized shapes and
//! configurations (the in-tree analog of a proptest suite — seeded
//! xorshift case generation, failures print the offending case).
//!
//! Schedules are driven through their public pool-level entry points
//! (`*_passes`) on private [`WorkerPool`]s, generic over the
//! [`StencilOp`] layer — the radius-1 paper op here; radius-2 and
//! variable-coefficient coverage lives in `tests/op_parity.rs`. Case
//! generation comes from the shared harness (`tests/common`).

mod common;

use stencilwave::coordinator::gs_multigroup::{gs_multigroup_passes, GsMultiGroupConfig};
use stencilwave::coordinator::pipeline::{pipeline_gs_passes, PipelineConfig};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::spatial::{blocked_wavefront_jacobi, SpatialConfig};
use stencilwave::coordinator::spatial_mg::{multigroup_passes, MultiGroupConfig};
use stencilwave::coordinator::wavefront::{
    serial_reference, wavefront_jacobi_passes, SyncMode, WavefrontConfig,
};
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_passes, GsWavefrontConfig};
use stencilwave::simulator::perfmodel::BarrierKind;
use stencilwave::stencil::gauss_seidel::{gs_sweeps, GsKernel};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::ConstLaplace7;

use common::Gen;

#[test]
fn wavefront_jacobi_is_exact_for_random_cases() {
    let mut g = Gen(0xBEEF);
    let mut pool = WorkerPool::new(0);
    for case in 0..24 {
        let (nz, ny, nx) = (g.range(3, 18), g.range(3, 14), g.range(3, 14));
        let t = g.pick(&[2usize, 4, 6]);
        let sync = g.pick(&[SyncMode::Barrier, SyncMode::Flow]);
        let barrier = g.pick(&[BarrierKind::Spin, BarrierKind::Tree]);
        let h2 = g.range(0, 3) as f64 / 2.0;
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let f = Grid3::random(nz, ny, nx, g.next());
        let want = serial_reference(&u0, &f, h2, t);
        let mut u = u0.clone();
        let cfg = WavefrontConfig { threads: t, barrier, sync, ..Default::default() };
        wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut u, &f, h2, &cfg, 1).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} t={t} {sync:?} {barrier:?}"
        );
    }
}

#[test]
fn blocked_wavefront_is_exact_for_random_cases() {
    let mut g = Gen(0xCAFE);
    for case in 0..24 {
        let (nz, ny, nx) = (g.range(3, 14), g.range(3, 24), g.range(3, 12));
        let t = g.pick(&[2usize, 4, 6]);
        let blocks = g.range(1, 6);
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let f = Grid3::random(nz, ny, nx, g.next());
        let want = serial_reference(&u0, &f, 1.0, t);
        let mut u = u0.clone();
        blocked_wavefront_jacobi(&ConstLaplace7, &mut u, &f, 1.0, &SpatialConfig { t, blocks, ..Default::default() })
            .unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} t={t} B={blocks}"
        );
    }
}

#[test]
fn multigroup_blocked_is_exact_for_random_cases() {
    let mut g = Gen(0x5EED);
    let mut pool = WorkerPool::new(0);
    for case in 0..20 {
        let t = g.pick(&[2usize, 4, 6]);
        let groups = g.range(1, 4);
        // interior lines >= 2 per group (the scheme's width requirement)
        let ny = 2 + 2 * groups + g.range(0, 12);
        let (nz, nx) = (g.range(3, 14), g.range(3, 12));
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let f = Grid3::random(nz, ny, nx, g.next());
        let want = serial_reference(&u0, &f, 1.0, t);
        let mut u = u0.clone();
        multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &MultiGroupConfig { t, groups, ..Default::default() }, 1)
            .unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} t={t} G={groups}"
        );
    }
}

#[test]
fn multigroup_agrees_with_serial_blocked_sweep() {
    // same decomposition, two engines: the concurrent multi-group pass
    // and the serial Fig. 7 sweep must land on the identical grid.
    let mut pool = WorkerPool::new(0);
    for (t, blocks) in [(2usize, 2usize), (4, 3), (6, 2)] {
        let u0 = Grid3::random(9, 15, 8, 21);
        let f = Grid3::random(9, 15, 8, 22);
        let mut serial = u0.clone();
        blocked_wavefront_jacobi(&ConstLaplace7, &mut serial, &f, 0.9, &SpatialConfig { t, blocks, ..Default::default() })
            .unwrap();
        let mut parallel = u0.clone();
        multigroup_passes(
            &mut pool,
            &ConstLaplace7,
            &mut parallel,
            &f,
            0.9,
            &MultiGroupConfig { t, groups: blocks, ..Default::default() },
            1,
        )
        .unwrap();
        assert_eq!(parallel.max_abs_diff(&serial), 0.0, "t={t} B={blocks}");
    }
}

#[test]
fn pipeline_gs_is_exact_for_random_cases() {
    let mut g = Gen(0xF00D);
    let mut pool = WorkerPool::new(0);
    for case in 0..20 {
        let (nz, ny, nx) = (g.range(3, 14), g.range(3, 20), g.range(3, 12));
        let threads = g.range(1, 6);
        let kernel = g.pick(&[GsKernel::Naive, GsKernel::Interleaved]);
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let mut want = u0.clone();
        gs_sweeps(&mut want, 1, kernel);
        let mut u = u0.clone();
        pipeline_gs_passes(&mut pool, &ConstLaplace7, &mut u, &PipelineConfig { threads, kernel }, 1)
            .unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} p={threads} {kernel:?}"
        );
    }
}

#[test]
fn gs_wavefront_is_exact_for_random_cases() {
    let mut g = Gen(0xABCD);
    let mut pool = WorkerPool::new(0);
    for case in 0..20 {
        let (nz, ny, nx) = (g.range(3, 12), g.range(3, 14), g.range(3, 10));
        let sweeps = g.range(1, 5);
        let width = g.range(1, 3);
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let mut want = u0.clone();
        gs_sweeps(&mut want, sweeps, GsKernel::Interleaved);
        let mut u = u0.clone();
        wavefront_gs_passes(
            &mut pool,
            &ConstLaplace7,
            &mut u,
            &GsWavefrontConfig { sweeps, threads_per_group: width, kernel: GsKernel::Interleaved },
            1,
        )
        .unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} S={sweeps} w={width}"
        );
    }
}

#[test]
fn gs_multigroup_is_exact_for_random_cases() {
    let mut g = Gen(0x6B17);
    let mut pool = WorkerPool::new(0);
    for case in 0..20 {
        let t = g.range(1, 5);
        let groups = g.range(1, 4);
        // >= 1 interior line per group (the lifted width requirement)
        let ny = 2 + groups + g.range(0, 10);
        let (nz, nx) = (g.range(3, 12), g.range(3, 10));
        let kernel = g.pick(&[GsKernel::Naive, GsKernel::Interleaved]);
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let mut want = u0.clone();
        gs_sweeps(&mut want, t, kernel);
        let mut u = u0.clone();
        let cfg = GsMultiGroupConfig { t, groups, kernel, ..Default::default() };
        gs_multigroup_passes(&mut pool, &ConstLaplace7, &mut u, &cfg, 1).unwrap();
        assert_eq!(
            u.max_abs_diff(&want),
            0.0,
            "case {case}: {nz}x{ny}x{nx} t={t} G={groups} {kernel:?}"
        );
    }
}

#[test]
fn schemes_compose_interchangeably() {
    // 8 updates via any mix of schedules must land on the same grid.
    let u0 = Grid3::random(12, 12, 12, 99);
    let f = Grid3::random(12, 12, 12, 98);
    let want = serial_reference(&u0, &f, 1.0, 8);
    let mut pool = WorkerPool::new(0);

    // wavefront(4) twice
    let mut a = u0.clone();
    let cfg4 = WavefrontConfig { threads: 4, ..Default::default() };
    wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut a, &f, 1.0, &cfg4, 2).unwrap();
    assert_eq!(a.max_abs_diff(&want), 0.0);

    // blocked(2 blocks, t=2) four times
    let mut b = u0.clone();
    for _ in 0..4 {
        blocked_wavefront_jacobi(&ConstLaplace7, &mut b, &f, 1.0, &SpatialConfig { t: 2, blocks: 2, ..Default::default() })
            .unwrap();
    }
    assert_eq!(b.max_abs_diff(&want), 0.0);

    // wavefront(2) + blocked(t=6, 3 blocks)
    let mut c = u0.clone();
    let cfg2 = WavefrontConfig { threads: 2, ..Default::default() };
    wavefront_jacobi_passes(&mut pool, &ConstLaplace7, &mut c, &f, 1.0, &cfg2, 1).unwrap();
    blocked_wavefront_jacobi(&ConstLaplace7, &mut c, &f, 1.0, &SpatialConfig { t: 6, blocks: 3, ..Default::default() })
        .unwrap();
    assert_eq!(c.max_abs_diff(&want), 0.0);
}

#[test]
fn gs_pipeline_wavefront_and_multigroup_compose() {
    // 9 GS sweeps via any mix of the three GS engines on one pool must
    // land on the identical grid
    let u0 = Grid3::random(10, 16, 9, 5);
    let mut want = u0.clone();
    gs_sweeps(&mut want, 9, GsKernel::Interleaved);
    let mut pool = WorkerPool::new(0);

    let mut u = u0.clone();
    pipeline_gs_passes(
        &mut pool,
        &ConstLaplace7,
        &mut u,
        &PipelineConfig { threads: 3, kernel: GsKernel::Interleaved },
        2,
    )
    .unwrap();
    wavefront_gs_passes(
        &mut pool,
        &ConstLaplace7,
        &mut u,
        &GsWavefrontConfig { sweeps: 4, threads_per_group: 2, kernel: GsKernel::Interleaved },
        1,
    )
    .unwrap();
    gs_multigroup_passes(
        &mut pool,
        &ConstLaplace7,
        &mut u,
        &GsMultiGroupConfig { t: 3, groups: 3, kernel: GsKernel::Interleaved, ..Default::default() },
        1,
    )
    .unwrap();
    assert_eq!(u.max_abs_diff(&want), 0.0);
}
