//! Diamond-scheme integration suite: randomized awkward extents across
//! every op and radius, the `STENCILWAVE_THREADS` parity matrix,
//! schedule-order invariance against the other Jacobi-family schemes,
//! and a negative control proving the seam-neighbor waits are
//! load-bearing (a weakened protocol corrupts the grid).

mod common;

use common::{assert_bit_parity, parity_config, thread_counts, Gen};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::diamond::{diamond_passes, DiamondConfig, DiamondSchedule};
use stencilwave::coordinator::pool::WorkerPool;
use stencilwave::coordinator::schedule::{Progress, Schedule};
use stencilwave::coordinator::solver::Solver;
use stencilwave::coordinator::wavefront::serial_reference;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::{ConstLaplace7, OpKind};

#[test]
fn randomized_awkward_shapes_stay_bit_exact() {
    // every op (radius 1 and 2) x t in {2, 4, 6} on grids hugging the
    // diamond width floor, with deliberately uneven interval splits
    let mut gen = Gen(0xD1A40D);
    for op in OpKind::ALL {
        let r = op.radius();
        for t in [2usize, 4, 6] {
            for _ in 0..2 {
                let groups = gen.range(1, 3);
                // interior floor: 2R(t-1) lines per interval, plus a
                // few extra so splits come out uneven
                let ny = 2 * r + 2 * r * (t - 1) * groups + gen.range(0, 5);
                let nz = 2 * r + 2 + gen.range(0, 5);
                let nx = 2 * r + 3 + gen.range(0, 4);
                let cfg = RunConfig {
                    scheme: Scheme::JacobiDiamond,
                    op,
                    size: (nz, ny, nx),
                    t,
                    groups,
                    iters: 2 * t,
                    ..Default::default()
                };
                cfg.validate().unwrap_or_else(|e| {
                    panic!("{op:?} t={t} G={groups} {nz}x{ny}x{nx}: {e}")
                });
                assert_bit_parity(&cfg, gen.next());
            }
        }
    }
}

#[test]
fn thread_matrix_parity() {
    // the STENCILWAVE_THREADS leg: the shared harness config for every
    // op at every CI-pinned parallel width
    for threads in thread_counts() {
        for op in OpKind::ALL {
            let cfg = parity_config(Scheme::JacobiDiamond, op, threads);
            assert_bit_parity(&cfg, 0xD1A5 + threads as u64);
        }
    }
}

#[test]
fn result_is_schedule_order_invariant() {
    // one fixed problem through different tile counts, a repeated run,
    // and the other Jacobi-family schemes: since every member shares the
    // per-line update (same fp association), all results must be the
    // identical bit pattern — the traversal order never leaks into the
    // numerics
    let (nz, ny, nx) = (12, 14, 9);
    let f = Grid3::random(nz, ny, nx, 21);
    let u0 = Grid3::random(nz, ny, nx, 22);
    let (t, iters) = (2, 4);
    let run_scheme = |scheme: Scheme, groups: usize| -> Grid3 {
        let cfg =
            RunConfig { scheme, size: (nz, ny, nx), t, groups, iters, ..Default::default() };
        let mut solver = Solver::builder(&cfg).rhs(f.clone(), 0.9).build().unwrap();
        let mut u = u0.clone();
        solver.run(&mut u, iters).unwrap();
        u
    };
    let base = run_scheme(Scheme::JacobiDiamond, 2);
    for groups in [1usize, 3] {
        assert_eq!(
            base.max_abs_diff(&run_scheme(Scheme::JacobiDiamond, groups)),
            0.0,
            "tile count {groups} changed the bits"
        );
    }
    // run-to-run stability at the same width
    assert_eq!(base.max_abs_diff(&run_scheme(Scheme::JacobiDiamond, 2)), 0.0);
    // cross-scheme: wavefront and multigroup compute the same updates
    assert_eq!(base.max_abs_diff(&run_scheme(Scheme::JacobiWavefront, 1)), 0.0);
    assert_eq!(base.max_abs_diff(&run_scheme(Scheme::JacobiMultiGroup, 2)), 0.0);
}

#[test]
fn weakened_waits_break_parity() {
    // negative control for the synchronization protocol. The exact
    // schedule (wait_slack = 0) through the pool is bit-exact; the same
    // schedule with its neighbor waits weakened into no-ops, executed in
    // a deterministic dependency-violating order (each worker runs to
    // completion before the next starts — no racing threads, so the
    // corruption is reproducible), must NOT match the serial reference.
    // A hypothetical diamond schedule whose waits were not load-bearing
    // would pass both runs and fail this test.
    let (nz, ny, nx) = (20, 12, 8);
    let f = Grid3::random(nz, ny, nx, 31);
    let u0 = Grid3::random(nz, ny, nx, 32);
    let (t, groups) = (2, 2);
    let want = serial_reference(&u0, &f, 1.0, t);

    let mut u = u0.clone();
    let mut pool = WorkerPool::new(0);
    let exact = DiamondConfig { t, groups, wait_slack: 0, ..Default::default() };
    diamond_passes(&mut pool, &ConstLaplace7, &mut u, &f, 1.0, &exact, 1).unwrap();
    assert_eq!(u.max_abs_diff(&want), 0.0, "exact protocol must be bit-exact");

    let mut v = u0.clone();
    let mut tmp = Vec::new();
    let mut lines = Vec::new();
    let weak = DiamondConfig { t, groups, wait_slack: 1_000_000, ..Default::default() };
    let schedule =
        DiamondSchedule::new(&ConstLaplace7, &mut v, &f, &mut tmp, &mut lines, 1.0, &weak)
            .unwrap();
    let progress = Progress::new(schedule.workers());
    for w in 0..schedule.workers() {
        schedule.worker(w, &progress);
    }
    drop(schedule);
    assert!(
        v.max_abs_diff(&want) > 0.0,
        "running tiles to completion out of dependency order must corrupt \
         the result — the seam-neighbor waits are doing real work"
    );
}
