//! Property layer for the service's admission/placement model, driven by
//! the same seeded tenant-job generator as the stress suite. Three
//! families of invariants, checked over randomized workloads:
//!
//! * **Determinism** — `ServiceConfig::admit_plan` is a pure function of
//!   the job sequence: regenerating a workload from the same seed admits
//!   to the identical plan, and the live (paused) service charges
//!   exactly the plan's windows.
//! * **No oversubscription** — every placement is a whole in-bounds
//!   window of cache groups, live claims never find a busy group
//!   (`claim_conflicts == 0`), and `peak_groups_busy` never exceeds the
//!   machine. Rejected jobs leave the loads untouched.
//! * **Batching is a scheduling decision** — the same jobs through a
//!   batching service, a `max_batch = 1` service, and a private serial
//!   reference produce bit-identical grids.

mod common;

use common::{
    parity_config, tenant_grids, tenant_jobs, tenant_reference, tenant_service_shape,
    thread_counts, Gen, TenantJob,
};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::service::{
    AdmissionError, JobSpec, JobTicket, Placement, ServiceConfig, SolverService,
};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::OpKind;

fn cfgs(jobs: &[TenantJob]) -> Vec<RunConfig> {
    jobs.iter().map(|j| j.cfg.clone()).collect()
}

#[test]
fn admission_plans_are_deterministic_in_the_seed() {
    let widths = thread_counts();
    for trial in 0..6u64 {
        let mut g1 = Gen((0x5EED << 4) | trial);
        let mut g2 = Gen((0x5EED << 4) | trial);
        let a = tenant_jobs(&mut g1, 12, &widths);
        let b = tenant_jobs(&mut g2, 12, &widths);
        let shape = tenant_service_shape(&a, 4);
        let plan_a = shape.admit_plan(&cfgs(&a)).unwrap();
        let plan_b = shape.admit_plan(&cfgs(&b)).unwrap();
        assert_eq!(plan_a, plan_b, "trial {trial}: same seed, same jobs, same plan");
        // and replanning the very same sequence is a fixpoint
        assert_eq!(shape.admit_plan(&cfgs(&a)).unwrap(), plan_a);
    }
}

#[test]
fn plans_stay_inside_the_machine() {
    let widths = thread_counts();
    for trial in 0..6u64 {
        let mut gen = Gen(0xB0_A2D + trial);
        let jobs = tenant_jobs(&mut gen, 16, &widths);
        let shape = tenant_service_shape(&jobs, 3); // odd width: rounding exercised
        for (p, job) in shape.admit_plan(&cfgs(&jobs)).unwrap().iter().zip(&jobs) {
            let ctx = format!("trial {trial}: {:?} x {:?} -> {p:?}", job.cfg.scheme, job.cfg.op);
            assert!(p.group_count >= 1, "{ctx}");
            assert!(p.group_start + p.group_count <= shape.groups, "{ctx}");
            assert_eq!(p.worker_start, p.group_start * shape.group_width, "{ctx}");
            assert_eq!(p.workers, p.group_count * shape.group_width, "{ctx}");
        }
    }
}

#[test]
fn paused_services_charge_exactly_the_pure_plan() {
    let widths = thread_counts();
    let mut gen = Gen(0xAD417);
    let jobs = tenant_jobs(&mut gen, 10, &widths);
    let shape = tenant_service_shape(&jobs, 4);
    let plan = shape.admit_plan(&cfgs(&jobs)).unwrap();
    let mut svc = SolverService::new(shape).unwrap();
    svc.pause();
    let tickets: Vec<JobTicket> = jobs
        .iter()
        .map(|job| {
            let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
            svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    let charged: Vec<Placement> = tickets.iter().map(|t| t.placement()).collect();
    assert_eq!(charged, plan, "live admission under pause == the pure plan");
    svc.resume();
    for (job, t) in jobs.iter().zip(tickets) {
        let out = t.wait().unwrap();
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&job.cfg, job.seed)), 0.0);
    }
    let stats = svc.stats();
    assert_eq!(stats.claim_conflicts, 0, "no claim ever finds a busy group");
    assert!(stats.peak_groups_busy <= svc.group_count());
    svc.shutdown();
}

#[test]
fn rejected_jobs_leave_the_service_untouched() {
    // narrow staged jobs (width 1 -> teams of at most 2) on a 2 × 2
    // service, then a GsWavefront job with a team of 8: admission must
    // reject it with the typed error and charge nothing
    let mut gen = Gen(0x2E_1EC7);
    let jobs = tenant_jobs(&mut gen, 4, &[1]);
    let mut svc =
        SolverService::new(ServiceConfig { groups: 2, group_width: 2, ..Default::default() })
            .unwrap();
    svc.pause();
    for job in &jobs {
        let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
        svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap();
    }
    let loads_before = svc.loads();
    let stats_before = svc.stats();
    let wide = parity_config(Scheme::GsWavefront, OpKind::ConstLaplace7, 4); // team 4 * 2 = 8
    let (nz, ny, nx) = wide.size;
    let err = svc.submit(JobSpec::new(wide, Grid3::zeros(nz, ny, nx))).map(|_| ()).unwrap_err();
    let typed = err.downcast_ref::<AdmissionError>().expect("typed admission error");
    assert!(typed.needed_groups > typed.groups, "{typed}");
    assert_eq!(svc.loads(), loads_before, "rejected jobs charge nothing");
    assert_eq!(svc.stats(), stats_before, "rejected jobs count nowhere");
    svc.resume();
    svc.shutdown(); // drains the four staged valid jobs
    assert_eq!(svc.stats().completed, 4);
}

#[test]
fn batching_is_invisible_in_the_bits() {
    let widths = thread_counts();
    let mut gen = Gen(0xB175);
    let lead = tenant_jobs(&mut gen, 1, &widths).remove(0);
    let seeds: Vec<u64> = (0..5).map(|_| gen.next()).collect();
    let shape = tenant_service_shape(&[lead.clone()], 4);

    // (a) staged through the batching service: one window, many RHS
    let mut batching = SolverService::new(shape.clone()).unwrap();
    batching.pause();
    let tickets: Vec<JobTicket> = seeds
        .iter()
        .map(|&seed| {
            let (f, u0, h2) = tenant_grids(&lead.cfg, seed);
            batching.submit(JobSpec::new(lead.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    batching.resume();
    let batched: Vec<Grid3> = tickets
        .into_iter()
        .map(|t| {
            let out = t.wait().unwrap();
            assert_eq!(out.batch_size, 5, "staged identical small jobs must actually batch");
            out.u
        })
        .collect();
    assert_eq!(batching.stats().batches, 1);
    batching.shutdown();

    // (b) the same jobs one-by-one through a batching-disabled service
    let mut solo = SolverService::new(ServiceConfig { max_batch: 1, ..shape }).unwrap();
    for (&seed, from_batch) in seeds.iter().zip(&batched) {
        let (f, u0, h2) = tenant_grids(&lead.cfg, seed);
        let out = solo.run_job(JobSpec::new(lead.cfg.clone(), u0).rhs(f, h2)).unwrap();
        assert_eq!(out.batch_size, 1);
        assert_eq!(out.u.max_abs_diff(from_batch), 0.0, "batched vs unbatched, seed {seed:#x}");
        // (c) and both match the private serial reference
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&lead.cfg, seed)), 0.0);
    }
    assert_eq!(solo.stats().batches, 0, "max_batch = 1 disables batching outright");
    solo.shutdown();
}
