//! Property layer for the service's admission/placement model, driven by
//! the same seeded tenant-job generator as the stress suite. Three
//! families of invariants, checked over randomized workloads:
//!
//! * **Determinism** — `ServiceConfig::admit_plan` is a pure function of
//!   the job sequence: regenerating a workload from the same seed admits
//!   to the identical plan, and the live (paused) service charges
//!   exactly the plan's windows.
//! * **No oversubscription** — every placement is a whole in-bounds
//!   window of cache groups, live claims never find a busy group
//!   (`claim_conflicts == 0`), and `peak_groups_busy` never exceeds the
//!   machine. Rejected jobs leave the loads untouched.
//! * **Batching is a scheduling decision** — the same jobs through a
//!   batching service, a `max_batch = 1` service, and a private serial
//!   reference produce bit-identical grids.
//! * **Admission control** — a full queue rejects with a typed
//!   `QueueFull` carrying a finite retry hint and changes nothing but
//!   the rejection counter; deadline-carrying jobs that never start are
//!   shed with a typed `ExpiredError` and refund their charge; and an
//!   aged wide job under continuous narrow load is claimed within
//!   `age_after` + slack claim cycles (bounded-wait fairness).

mod common;

use common::{
    parity_config, tenant_grids, tenant_jobs, tenant_reference, tenant_service_shape,
    thread_counts, Gen, TenantJob,
};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::service::{
    AdmissionError, ExpiredError, JobSpec, JobTicket, Placement, ServiceConfig, ServiceStats,
    SolverService,
};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::OpKind;

fn cfgs(jobs: &[TenantJob]) -> Vec<RunConfig> {
    jobs.iter().map(|j| j.cfg.clone()).collect()
}

#[test]
fn admission_plans_are_deterministic_in_the_seed() {
    let widths = thread_counts();
    for trial in 0..6u64 {
        let mut g1 = Gen((0x5EED << 4) | trial);
        let mut g2 = Gen((0x5EED << 4) | trial);
        let a = tenant_jobs(&mut g1, 12, &widths);
        let b = tenant_jobs(&mut g2, 12, &widths);
        let shape = tenant_service_shape(&a, 4);
        let plan_a = shape.admit_plan(&cfgs(&a)).unwrap();
        let plan_b = shape.admit_plan(&cfgs(&b)).unwrap();
        assert_eq!(plan_a, plan_b, "trial {trial}: same seed, same jobs, same plan");
        // and replanning the very same sequence is a fixpoint
        assert_eq!(shape.admit_plan(&cfgs(&a)).unwrap(), plan_a);
    }
}

#[test]
fn plans_stay_inside_the_machine() {
    let widths = thread_counts();
    for trial in 0..6u64 {
        let mut gen = Gen(0xB0_A2D + trial);
        let jobs = tenant_jobs(&mut gen, 16, &widths);
        let shape = tenant_service_shape(&jobs, 3); // odd width: rounding exercised
        for (p, job) in shape.admit_plan(&cfgs(&jobs)).unwrap().iter().zip(&jobs) {
            let ctx = format!("trial {trial}: {:?} x {:?} -> {p:?}", job.cfg.scheme, job.cfg.op);
            assert!(p.group_count >= 1, "{ctx}");
            assert!(p.group_start + p.group_count <= shape.groups, "{ctx}");
            assert_eq!(p.worker_start, p.group_start * shape.group_width, "{ctx}");
            assert_eq!(p.workers, p.group_count * shape.group_width, "{ctx}");
        }
    }
}

#[test]
fn paused_services_charge_exactly_the_pure_plan() {
    let widths = thread_counts();
    let mut gen = Gen(0xAD417);
    let jobs = tenant_jobs(&mut gen, 10, &widths);
    let shape = tenant_service_shape(&jobs, 4);
    let plan = shape.admit_plan(&cfgs(&jobs)).unwrap();
    let mut svc = SolverService::new(shape).unwrap();
    svc.pause();
    let tickets: Vec<JobTicket> = jobs
        .iter()
        .map(|job| {
            let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
            svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    let charged: Vec<Placement> = tickets.iter().map(|t| t.placement()).collect();
    assert_eq!(charged, plan, "live admission under pause == the pure plan");
    svc.resume();
    for (job, t) in jobs.iter().zip(tickets) {
        let out = t.wait().unwrap();
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&job.cfg, job.seed)), 0.0);
    }
    let stats = svc.stats();
    assert_eq!(stats.claim_conflicts, 0, "no claim ever finds a busy group");
    assert!(stats.peak_groups_busy <= svc.group_count());
    svc.shutdown();
}

#[test]
fn rejected_jobs_leave_the_service_untouched() {
    // narrow staged jobs (width 1 -> teams of at most 2) on a 2 × 2
    // service, then a GsWavefront job with a team of 8: admission must
    // reject it with the typed error and charge nothing
    let mut gen = Gen(0x2E_1EC7);
    let jobs = tenant_jobs(&mut gen, 4, &[1]);
    let mut svc =
        SolverService::new(ServiceConfig { groups: 2, group_width: 2, ..Default::default() })
            .unwrap();
    svc.pause();
    for job in &jobs {
        let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
        svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap();
    }
    let loads_before = svc.loads();
    let stats_before = svc.stats();
    let wide = parity_config(Scheme::GsWavefront, OpKind::ConstLaplace7, 4); // team 4 * 2 = 8
    let (nz, ny, nx) = wide.size;
    let err = svc.submit(JobSpec::new(wide, Grid3::zeros(nz, ny, nx))).map(|_| ()).unwrap_err();
    let typed = err.downcast_ref::<AdmissionError>().expect("typed admission error");
    match typed {
        AdmissionError::TooWide { needed_groups, groups, .. } => {
            assert!(needed_groups > groups, "{typed}")
        }
        other => panic!("expected TooWide, got {other:?}"),
    }
    assert_eq!(svc.loads(), loads_before, "rejected jobs charge nothing");
    assert_eq!(svc.stats(), stats_before, "rejected jobs count nowhere");
    svc.resume();
    svc.shutdown(); // drains the four staged valid jobs
    assert_eq!(svc.stats().completed, 4);
}

#[test]
fn full_queue_rejections_change_nothing_and_hint_finitely() {
    // fill a paused bounded service to capacity with seeded workloads,
    // then oversubmit: every extra job is rejected with a typed
    // QueueFull carrying a finite positive ECM drain hint, and the
    // rejection leaves loads, queue, and every counter except
    // `rejected_full` untouched — the rejected-jobs-change-nothing
    // invariant extended to backpressure
    let widths = thread_counts();
    for trial in 0..4u64 {
        let mut gen = Gen(0xF0_11 + trial);
        let capacity = 3 + (trial as usize % 3);
        let jobs = tenant_jobs(&mut gen, capacity + 3, &widths);
        let shape = ServiceConfig {
            queue_capacity: capacity,
            ..tenant_service_shape(&jobs, 4)
        };
        let mut svc = SolverService::new(shape).unwrap();
        svc.pause();
        let tickets: Vec<JobTicket> = jobs[..capacity]
            .iter()
            .map(|job| {
                let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
                svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap()
            })
            .collect();
        let loads_before = svc.loads();
        let stats_before = svc.stats();
        for (i, job) in jobs[capacity..].iter().enumerate() {
            let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
            let err = svc
                .submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2))
                .map(|_| ())
                .unwrap_err();
            match err.downcast_ref::<AdmissionError>().expect("typed admission error") {
                AdmissionError::QueueFull { queued, capacity: cap, retry_after_hint } => {
                    assert_eq!((*queued, *cap), (capacity, capacity), "trial {trial} extra {i}");
                    assert!(
                        retry_after_hint.is_finite() && *retry_after_hint > 0.0,
                        "trial {trial} extra {i}: hint {retry_after_hint}"
                    );
                }
                other => panic!("trial {trial} extra {i}: expected QueueFull, got {other:?}"),
            }
            assert_eq!(svc.loads(), loads_before, "trial {trial}: rejections charge nothing");
        }
        let stats = svc.stats();
        assert_eq!(stats.rejected_full, 3, "trial {trial}");
        assert_eq!(
            ServiceStats { rejected_full: stats_before.rejected_full, ..stats },
            stats_before,
            "trial {trial}: only the rejection counter moved"
        );
        svc.resume();
        for (job, t) in jobs[..capacity].iter().zip(tickets) {
            let out = t.wait().unwrap();
            assert_eq!(out.u.max_abs_diff(&tenant_reference(&job.cfg, job.seed)), 0.0);
        }
        assert_eq!(svc.stats().completed, capacity as u64, "trial {trial}: accepted jobs drain");
        svc.shutdown();
    }
}

#[test]
fn aged_wide_jobs_are_claimed_within_bounded_cycles() {
    // the bounded-wait fairness property: a whole-machine-wide job
    // queued behind a backlog of narrow jobs — with more narrow jobs
    // arriving behind it — is passed over at most `age_after` claim
    // cycles before aging promotes it; once aged it reserves its window,
    // so no younger narrow job can leapfrog it and its start is bounded
    // by the in-flight batches draining. The seed scheduler's
    // oldest-runnable scan starves exactly this shape.
    for trial in 0..3u64 {
        let mut gen = Gen(0xA6ED + trial);
        let age_after = 2 + (gen.next() % 4); // 2..=5 claim cycles
        let backlog = 4 + (gen.next() as usize % 5); // narrow jobs ahead
        let tail = 8 + (gen.next() as usize % 8); // narrow jobs behind
        let shape = ServiceConfig {
            groups: 2,
            group_width: 1,
            max_batch: 1, // every claim is its own cycle
            age_after,
            queue_capacity: 128,
            ..Default::default()
        };
        // narrow: inline baseline (team 0 -> one group); wide: a t = 2
        // wavefront team spanning both single-worker groups
        let narrow = parity_config(Scheme::JacobiBaseline, OpKind::ConstLaplace7, 1);
        let wide = parity_config(Scheme::JacobiWavefront, OpKind::ConstLaplace7, 2);
        let mut svc = SolverService::new(shape).unwrap();
        svc.pause();
        let mut narrow_tickets: Vec<JobTicket> = Vec::new();
        for i in 0..backlog {
            let (f, u0, h2) = tenant_grids(&narrow, i as u64);
            narrow_tickets
                .push(svc.submit(JobSpec::new(narrow.clone(), u0).rhs(f, h2)).unwrap());
        }
        let (f, u0, h2) = tenant_grids(&wide, 0xA1DE);
        let wide_ticket = svc.submit(JobSpec::new(wide.clone(), u0).rhs(f, h2)).unwrap();
        for i in 0..tail {
            let (f, u0, h2) = tenant_grids(&narrow, (backlog + i) as u64);
            narrow_tickets
                .push(svc.submit(JobSpec::new(narrow.clone(), u0).rhs(f, h2)).unwrap());
        }
        svc.resume();
        let out = wide_ticket.wait().unwrap();
        // slack: one cycle per cache group — the in-flight batches an
        // aged job's reservation still has to wait out
        assert!(
            out.skipped_cycles <= age_after + 2,
            "trial {trial}: wide job passed over {} cycles (age_after {age_after})",
            out.skipped_cycles
        );
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&wide, 0xA1DE)), 0.0);
        for t in narrow_tickets {
            t.wait().unwrap();
        }
        // whether the wide job actually had to age is timing-dependent
        // (it claims sooner if both windows happen to free at once —
        // that's better, not worse); the bound above is what matters
        assert_eq!(svc.stats().claim_conflicts, 0, "trial {trial}");
        svc.shutdown();
    }
}

#[test]
fn expired_jobs_shed_cleanly_and_refund_their_charge() {
    // a paused service cannot start anything, so every deadline-carrying
    // job must shed with a typed ExpiredError while the rest drain
    // normally after resume; loads return to zero either way
    let widths = thread_counts();
    let mut gen = Gen(0xDEAD11);
    let jobs = tenant_jobs(&mut gen, 6, &widths);
    let mut svc = SolverService::new(tenant_service_shape(&jobs, 4)).unwrap();
    svc.pause();
    let tickets: Vec<(bool, JobTicket)> = jobs
        .iter()
        .enumerate()
        .map(|(i, job)| {
            let doomed = i % 2 == 0;
            let mut cfg = job.cfg.clone();
            cfg.deadline_ms = doomed.then_some(1);
            let (f, u0, h2) = tenant_grids(&cfg, job.seed);
            (doomed, svc.submit(JobSpec::new(cfg, u0).rhs(f, h2)).unwrap())
        })
        .collect();
    // the executors' deadline timeout sheds the doomed jobs even while
    // paused; redeem those tickets before resuming so the shed cannot
    // race a claim
    let mut shed = 0u64;
    let mut live = Vec::new();
    for (doomed, t) in tickets {
        if doomed {
            let err = t.wait().map(|_| ()).unwrap_err();
            let typed = err.downcast_ref::<ExpiredError>().expect("typed expiry");
            assert_eq!(typed.deadline_ms, 1);
            shed += 1;
        } else {
            live.push(t);
        }
    }
    svc.resume();
    for t in live {
        t.wait().unwrap();
    }
    let stats = svc.stats();
    assert_eq!(stats.shed_expired, shed);
    assert_eq!(stats.completed + stats.shed_expired, 6);
    assert_eq!(stats.failed, 0, "expired jobs are shed, not failed");
    assert!(svc.loads().iter().all(|&l| l == 0.0), "every charge was refunded");
    svc.shutdown();
}

#[test]
fn batching_is_invisible_in_the_bits() {
    let widths = thread_counts();
    let mut gen = Gen(0xB175);
    let lead = tenant_jobs(&mut gen, 1, &widths).remove(0);
    let seeds: Vec<u64> = (0..5).map(|_| gen.next()).collect();
    let shape = tenant_service_shape(&[lead.clone()], 4);

    // (a) staged through the batching service: one window, many RHS
    let mut batching = SolverService::new(shape.clone()).unwrap();
    batching.pause();
    let tickets: Vec<JobTicket> = seeds
        .iter()
        .map(|&seed| {
            let (f, u0, h2) = tenant_grids(&lead.cfg, seed);
            batching.submit(JobSpec::new(lead.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    batching.resume();
    let batched: Vec<Grid3> = tickets
        .into_iter()
        .map(|t| {
            let out = t.wait().unwrap();
            assert_eq!(out.batch_size, 5, "staged identical small jobs must actually batch");
            out.u
        })
        .collect();
    assert_eq!(batching.stats().batches, 1);
    batching.shutdown();

    // (b) the same jobs one-by-one through a batching-disabled service
    let mut solo = SolverService::new(ServiceConfig { max_batch: 1, ..shape }).unwrap();
    for (&seed, from_batch) in seeds.iter().zip(&batched) {
        let (f, u0, h2) = tenant_grids(&lead.cfg, seed);
        let out = solo.run_job(JobSpec::new(lead.cfg.clone(), u0).rhs(f, h2)).unwrap();
        assert_eq!(out.batch_size, 1);
        assert_eq!(out.u.max_abs_diff(from_batch), 0.0, "batched vs unbatched, seed {seed:#x}");
        // (c) and both match the private serial reference
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&lead.cfg, seed)), 0.0);
    }
    assert_eq!(solo.stats().batches, 0, "max_batch = 1 disables batching outright");
    solo.shutdown();
}
